"""SpecPlane: model-free speculative decoding on the paged KV plane.

Covers the PR-8 contract:

  · drafting sources — prompt-lookup n-gram maps (longest-gram-first, most
    recent previous occurrence), read-only RadixTree continuation lookup
    (deterministic, no LRU perturbation), and the cross-request suffix
    table's LRU eviction;
  · controller policy — k=0 / no-config degrade to a None controller (the
    engine then runs the unchanged baseline step), refusal to compose with
    OmniAttn online top-k selection and with SSM stacks;
  · the headline equivalence — greedy token streams bit-identical to
    non-speculative decode, under GOOD drafts (n-gram hits), ADVERSARIAL
    drafts (always-wrong source: every window rolls back), and a mixed
    greedy/sampled batch — across block sizes {8, 16};
  · rollback hygiene — after every verify step with rejections the pool
    invariants hold (zero stale key summaries on the arena plane, PR-5
    contract), the over-extended tail blocks are back on the free list,
    and `host_fetches == steps` survives speculation.
"""
import jax
import numpy as np
import pytest

from repro.configs import reduced_config
from repro.core.proxy.radix import RadixTree
from repro.distributed.ctx import local_mesh_ctx
from repro.models import LM
from repro.serving import DecodeEngine, PrefillEngine, SamplingParams
from repro.serving.spec import (DraftSource, PromptLookupSource,
                                SpecConfig, SpecController,
                                SuffixTableSource)


@pytest.fixture(scope="module")
def small():
    mesh = local_mesh_ctx()
    cfg = reduced_config("qwen2-1.5b").with_updates(
        compute_dtype="float32", param_dtype="float32", n_layers=2,
        vocab_size=128)
    lm = LM.build(cfg, mesh, pattern=[0, 0])
    params = lm.init(jax.random.PRNGKey(0))
    return cfg, lm, params


@pytest.fixture(scope="module", autouse=True)
def _release_compiled_executables():
    yield
    jax.clear_caches()


# ---------------------------------------------------------------------
# drafting sources (host-side, no model)
# ---------------------------------------------------------------------
def test_prompt_lookup_drafts_previous_continuation():
    src = PromptLookupSource(ngram=3)
    h = [1, 2, 3, 9, 8, 1, 2, 3]
    src.on_admit(0, h)
    # tail gram (1,2,3) previously occurred at position 0..2 → drafts 9, 8
    assert src.draft(0, h, 2) == [9, 8]
    # the draft window is clamped by k
    assert src.draft(0, h, 1) == [9]
    # incremental registration matches from-scratch registration
    src2 = PromptLookupSource(ngram=3)
    src2.on_admit(1, h[:5])
    h2 = list(h)
    src2.on_tokens(1, h2, 3)
    assert src2.draft(1, h2, 2) == src.draft(0, h, 2)
    src.on_release(0, h)
    assert src.draft(0, h, 2) == []


def test_prompt_lookup_prefers_most_recent_occurrence():
    src = PromptLookupSource(ngram=2)
    h = [5, 6, 1, 5, 6, 2, 5, 6]
    src.on_admit(0, h)
    # (5,6) occurred at 0 (→1) and 3 (→2); most recent previous wins → 2
    assert src.draft(0, h, 1) == [2]


def test_radix_continuation_deterministic_and_read_only():
    tree = RadixTree(capacity_tokens=1 << 20)
    p1 = (1, 2, 3, 4, 5, 6)
    p2 = (1, 2, 3, 7, 8, 9)
    tree.insert(p1, now=1.0)
    tree.insert(p2, now=2.0)
    before = tree.total_tokens
    # exact-prefix continuation: the stored suffix of the matching prompt
    assert tuple(tree.continuation((1, 2, 3, 4), 2)) == (5, 6)
    # branch point: the most recently accessed child wins, repeatably
    first = tuple(tree.continuation((1, 2, 3), 3))
    assert first == (7, 8, 9)
    for _ in range(5):
        assert tuple(tree.continuation((1, 2, 3), 3)) == first
    # absent sequence → no draft; lookup never mutated the tree
    assert tree.continuation((9, 9, 9), 4) == []
    assert tree.total_tokens == before


def test_suffix_table_lru_eviction():
    src = SuffixTableSource(ngram=2, max_entries=2, cont_len=4)
    src.on_release(0, [1, 2, 10, 11])       # (1,2)→(10,11), (2,10)→(11,)
    assert src.draft(9, [0, 1, 2], 2) == [10, 11]
    # capacity 2: folding a third gram evicts the stalest — but the
    # draft() above LRU-touched (1,2), so (2,10) is the one to go
    src.on_release(1, [7, 8, 42])
    assert src.draft(9, [2, 10], 4) == []
    assert src.draft(9, [0, 1, 2], 2) == [10, 11]
    assert src.draft(9, [7, 8], 1) == [42]
    assert len(src.table) == 2


# ---------------------------------------------------------------------
# controller policy
# ---------------------------------------------------------------------
def test_controller_degrades_off(small):
    cfg, lm, params = small
    assert SpecController.from_model(lm, None) is None
    assert SpecController.from_model(lm, SpecConfig(k=0)) is None
    de = DecodeEngine(lm, params, None, n_slots=2, max_len=64,
                      spec=SpecConfig(k=0))
    assert de.spec_ctl is None and de._verify is None
    assert "spec" not in de.state


def test_controller_refuses_online_sparsity(small):
    cfg, lm, params = small
    with pytest.raises(ValueError, match="top-k"):
        SpecController.from_model(lm, SpecConfig(k=4), sparsity=object())


def test_controller_refuses_ssm_stack(small):
    cfg, lm, params = small

    class _Spec:
        kind = "mamba"

    class _Plan:
        def all_specs(self):
            return [_Spec()]

    class _LM:
        plan = _Plan()

    with pytest.raises(ValueError, match="SSM"):
        SpecController.from_model(_LM(), SpecConfig(k=4))


# ---------------------------------------------------------------------
# engine equivalence + rollback hygiene
# ---------------------------------------------------------------------
class _WrongSource(DraftSource):
    """Adversarial source: always proposes out-of-band tokens, so every
    verify window rejects the full draft and rolls back."""

    name = "wrong"

    def __init__(self, vocab):
        self.bad = vocab - 1

    def draft(self, rid, h, k):
        return [self.bad] * k


def _decode_engine(lm, params, block_size, spec=None, n_slots=4,
                   max_len=192):
    return DecodeEngine(lm, params, None, n_slots=n_slots, max_len=max_len,
                        block_size=block_size, spec=spec)


def _run_engine(lm, params, de, prompts, n, sparams=None):
    pe = PrefillEngine(lm, params, None, max_len=de.max_len)
    outs = {}
    for i, p in enumerate(prompts):
        cache, first, _ = pe.process(p)
        sp = None if sparams is None else sparams[i]
        assert de.admit(i, cache, first, len(p), prompt=p, params=sp)
        outs[i] = [first]
    while any(len(v) < n + 1 for v in outs.values()):
        toks = de.step()
        for rid, t in toks.items():
            outs[rid].extend(t if isinstance(t, list) else [t])
        if de.spec_ctl is not None:
            # rollback hygiene at EVERY quiescent point, not just the end:
            # zero stale summaries, refcounts consistent, freed tail blocks
            # back in circulation
            de.pool.check_invariants(arena=de.arena)
    assert de.stats["host_fetches"] == de.stats["steps"]
    return {i: v[:n + 1] for i, v in outs.items()}


@pytest.mark.parametrize("block_size", [8, 16])
def test_spec_greedy_bit_identical(small, block_size):
    """Greedy streams under speculation are bit-identical to baseline
    decode, with real n-gram drafts accepted along the way."""
    cfg, lm, params = small
    rng = np.random.default_rng(0)
    gram = tuple(int(t) for t in rng.integers(0, 32, 6))
    prompts = [gram * 4,
               tuple(int(t) for t in rng.integers(0, 32, 11))]
    base = _run_engine(lm, params, _decode_engine(lm, params, block_size),
                       prompts, 20)
    de = _decode_engine(lm, params, block_size, spec=SpecConfig(k=4))
    out = _run_engine(lm, params, de, prompts, 20)
    assert out == base
    v = de.take_spec_stats()
    assert v is not None and de.stats["spec_emitted"] > 0
    assert de.stats["spec_accepted"] > 0, "no draft ever accepted"
    assert de.stats["steps"] < 20, "speculation never shortened the run"


@pytest.mark.parametrize("block_size", [8, 16])
def test_spec_rollback_all_rejected_bit_identical(small, block_size):
    """Adversarial drafting: every window rolls back (acceptance 0), the
    stream is still bit-identical, and every pre-extended tail block is
    handed back with summaries clean — the full rollback lifecycle."""
    cfg, lm, params = small
    rng = np.random.default_rng(1)
    prompts = [tuple(int(t) for t in rng.integers(0, 32, 9)),
               tuple(int(t) for t in rng.integers(0, 32, 14))]
    base = _run_engine(lm, params, _decode_engine(lm, params, block_size),
                       prompts, 16)
    de = _decode_engine(lm, params, block_size, spec=SpecConfig(k=3))
    de.spec_ctl.sources = [_WrongSource(cfg.vocab_size)]
    out = _run_engine(lm, params, de, prompts, 16)
    assert out == base
    de.take_spec_stats()
    assert de.stats["spec_accepted"] == 0
    assert de.stats["spec_drafted"] > 0
    # every emitted token was the verify's own position-0 baseline token
    assert de.stats["spec_emitted"] == de.stats["spec_verifies"] * 2


def test_spec_mixed_sampled_slots_ride_baseline_rows(small):
    """A sampled (temperature > 0) request sharing the batch never drafts;
    the greedy request's stream still matches its baseline."""
    cfg, lm, params = small
    rng = np.random.default_rng(2)
    gram = tuple(int(t) for t in rng.integers(0, 32, 5))
    prompts = [gram * 4, tuple(int(t) for t in rng.integers(0, 32, 8))]
    sparams = [None, SamplingParams(temperature=0.8, seed=7)]
    base = _run_engine(lm, params, _decode_engine(lm, params, 16),
                       prompts, 12, sparams=sparams)
    de = _decode_engine(lm, params, 16, spec=SpecConfig(k=4))
    out = _run_engine(lm, params, de, prompts, 12, sparams=sparams)
    assert out[0] == base[0]
    de.take_spec_stats()
    assert de.stats["spec_emitted"] > 0
