"""OmniProxy: radix tree properties, OAS policies, lifecycle, fault handling."""
import numpy as np
import pytest
pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.proxy import (
    MetricsAggregator, OASConfig, OmniProxy, Phase, RadixTree, Request,
)

token_seqs = st.lists(st.integers(0, 7), min_size=0, max_size=24)


@settings(max_examples=40, deadline=None)
@given(seqs=st.lists(token_seqs, min_size=1, max_size=12), probe=token_seqs)
def test_radix_match_is_longest_cached_prefix(seqs, probe):
    tree = RadixTree()
    for s in seqs:
        tree.insert(tuple(s))
    got = tree.match(tuple(probe))
    # brute force: longest common prefix with any *prefix-closed* stored seq
    best = 0
    for s in seqs:
        n = 0
        for a, b in zip(s, probe):
            if a != b:
                break
            n += 1
        best = max(best, n)
    assert got == best


def test_radix_eviction_under_capacity():
    tree = RadixTree(capacity_tokens=32)
    for i in range(20):
        tree.insert(tuple(range(i * 100, i * 100 + 8)), now=float(i))
    assert tree.size_tokens() <= 32
    # most recent entries survive
    assert tree.match(tuple(range(1900, 1908)), now=99.0) == 8


def test_prefill_cache_affinity_wins():
    """A request matching instance 1's cache should go there (eq. 8)."""
    p = OmniProxy(2, 1, OASConfig(defer_window=0.0, alpha=0.3))
    warm = Request(0, tuple(range(100)), 8, arrival=0.0)
    p.submit(warm, 0.0)
    acts = p.tick(0.0)
    iid = acts[0][1].iid
    p.on_prefill_start(warm, 0.0)
    p.on_prefill_done(warm, 0.1, 0.1)
    # same-prefix request must pick the same instance
    r2 = Request(1, tuple(range(100)) + (7, 8), 8, arrival=0.2)
    p.submit(r2, 0.2)
    acts = p.tick(0.2)
    assert acts[0][1].iid == iid
    assert r2.prefix_match == 100


def test_round_robin_when_cache_unaware():
    p = OmniProxy(3, 1, OASConfig(defer_window=0.0, cache_aware=False))
    seen = []
    for i in range(6):
        r = Request(i, (1, 2, 3), 4, arrival=float(i))
        p.submit(r, float(i))
        acts = p.tick(float(i))
        seen.append(acts[0][1].iid)
    assert seen == [0, 1, 2, 0, 1, 2]


def test_decode_lpt_ordering():
    p = OmniProxy(1, 2, OASConfig(defer_window=0.0, lpt=True))
    reqs = []
    for i, (plen, mt) in enumerate([(10, 5), (500, 900), (50, 100)]):
        r = Request(i, tuple(range(plen)), mt, arrival=0.0)
        p.submit(r, 0.0)
        reqs.append(r)
    p.tick(0.0)
    for r in reqs:
        p.on_prefill_start(r, 0.0)
        p.on_prefill_done(r, 0.1, 0.1)
    acts = p.tick(0.2)
    decode_order = [a[0].rid for a in acts if a[2] == "decode"]
    assert decode_order[0] == 1            # longest ℓ_i = T_prompt + T_max first


def test_straggler_penalized():
    p = OmniProxy(2, 1, OASConfig(defer_window=0.0, alpha=0.0,
                                  cache_aware=True, straggler_factor=1.5))
    p.prefill[0].observe_batch_time(1.0, 1.0)    # slow instance
    p.prefill[1].observe_batch_time(0.1, 1.0)
    for i in range(4):
        r = Request(i, (i,), 4, arrival=0.0)
        p.submit(r, 0.0)
    acts = p.tick(0.0)
    assert all(a[1].iid == 1 for a in acts)


def test_failure_requeue_and_retry_budget():
    p = OmniProxy(2, 1, OASConfig(defer_window=0.0, max_retries=1))
    r = Request(0, (1, 2, 3), 4, arrival=0.0)
    p.submit(r, 0.0)
    p.tick(0.0)
    assert r.phase == Phase.PREFILL_SCHEDULED
    requeued = p.mark_unhealthy("prefill", r.prefill_instance, 0.1)
    assert r in requeued and r.n_retries == 1
    acts = p.tick(0.2)                      # re-dispatched to healthy instance
    assert acts and acts[0][1].healthy
    requeued = p.mark_unhealthy("prefill", r.prefill_instance, 0.3)
    assert r.phase == Phase.FAILED          # retry budget exhausted


def test_lifecycle_phases_and_metrics():
    p = OmniProxy(1, 1, OASConfig(defer_window=0.0))
    m = MetricsAggregator()
    r = Request(0, (1, 2), 3, arrival=0.0)
    p.submit(r, 0.0)
    p.tick(0.0)
    p.on_prefill_start(r, 0.01)
    p.on_prefill_done(r, 0.05, 0.04)
    p.on_first_token(r, 0.05)
    p.tick(0.06)
    p.on_decode_start(r, 0.06)
    r.output_tokens = [1, 2, 3]
    p.on_decode_done(r, 0.26, 0.1)
    m.add(r)
    s = m.summary(wall_time=0.26)
    assert abs(s["ttft_mean"] - 0.05) < 1e-9
    assert abs(s["tpot_mean_ms"] - (0.21 / 2) * 1e3) < 1e-6
    assert s["n_done"] == 1
    for ph in ("TOKENIZE", "PREFILL_SCHEDULED", "PREFILL_RUNNING",
               "DECODE_WAIT", "DECODE_SCHEDULED", "DECODE_RUNNING", "DONE"):
        assert ph in r.phase_times


def test_deferred_submission_holds_then_releases():
    p = OmniProxy(1, 1, OASConfig(defer_window=0.5, deferred=True))
    p.prefill[0].observe_batch_time(0.6, 1.0)   # predicted cycle > window
    r = Request(0, (1,), 2, arrival=0.0)
    p.submit(r, 0.0)
    assert p.tick(0.1) == []               # held (within defer window)
    acts = p.tick(0.6)                     # released after window
    assert len(acts) == 1
