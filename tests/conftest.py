import jax
import pytest

# NOTE: no XLA_FLAGS here — smoke tests and benches must see 1 device
# (the 512-device override lives ONLY in launch/dryrun.py).

jax.config.update("jax_enable_x64", False)


def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "jaxpr_audit: ContractGuard layer-2 tests that trace live-server "
        "hot loops (CI runs them in the static-analysis job; the tp=2,ep=4 "
        "case additionally needs XLA_FLAGS="
        "--xla_force_host_platform_device_count=8)")


@pytest.fixture(scope="session")
def mesh1():
    from repro.distributed.ctx import local_mesh_ctx
    return local_mesh_ctx()
