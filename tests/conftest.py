import jax
import pytest

# NOTE: no XLA_FLAGS here — smoke tests and benches must see 1 device
# (the 512-device override lives ONLY in launch/dryrun.py).

jax.config.update("jax_enable_x64", False)


@pytest.fixture(scope="session")
def mesh1():
    from repro.distributed.ctx import local_mesh_ctx
    return local_mesh_ctx()
