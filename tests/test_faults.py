"""FaultPlane: deterministic fault injection + full-stack recovery.

Covers the PR-6 robustness contract end to end:

  · schedule determinism — a FaultConfig seed fully determines the fault
    schedule (step, kind, arg), so chaos runs replay exactly;
  · bounded retries — repeated KV loss for one request exhausts
    `OASConfig.max_retries` and retires it with finish_reason="error"
    (counted in n_errors), with zero leaked arena blocks;
  · orphan-handoff sweep — a dropped `("handoff", i)` payload is reclaimed
    by the step-top sweep and the request recovers via the kv-lost path
    (the rename-stage leak regression);
  · watchdog — a request that can make no progress (no healthy decode
    instance) is retired with finish_reason="timeout";
  · graceful shedding — infeasible prompts and over-cap admission backlogs
    raise a typed BackpressureError at the door (counted in n_shed);
  · corruption recovery — a corrupted block's stale key summary is
    detected, its holders are restarted, the block is quarantined+scrubbed,
    and the restarted request's greedy output is bit-identical;
  · chaos soak — under a full seeded fault schedule (kills, corruption, KV
    loss, handoff drops, allocation failures, stragglers), every request
    completes with output bit-identical to the fault-free run, streamed
    deltas are never replayed, and the pool/summary invariants hold with
    zero leaked blocks.
"""
import time

import numpy as np
import pytest

from repro.configs import reduced_config
from repro.core.proxy import MetricsAggregator, OASConfig, Phase
from repro.serving import (BackpressureError, FaultConfig, FaultPlane,
                           SamplingParams, Server, ServerConfig, SpecConfig)
from repro.serving.faults import FAULT_KINDS, corrupt_block


@pytest.fixture(scope="module")
def small():
    cfg = reduced_config("qwen2-1.5b").with_updates(
        compute_dtype="float32", param_dtype="float32", n_layers=2)
    return cfg


@pytest.fixture(scope="module", autouse=True)
def _release_compiled_executables():
    """This module builds ~15 Servers (each with its own jit entries for
    prefill chunks, admission batches, decode buckets, scrub). Drop the
    compiled executables when the module finishes so the compile-heavy
    modules that follow alphabetically (kernels, paged_prefill, serving,
    sparsity) don't run on top of them."""
    yield
    import jax
    jax.clear_caches()


def _drive(srv, reqs, max_steps=3000):
    """Submit every request at t=0 and step() until quiescent, collecting
    the per-rid streamed token deltas and finish records — the raw material
    for the no-replay and delivered-counter asserts."""
    t0 = time.monotonic()
    rids = []
    for p, spec in reqs:
        params = spec if isinstance(spec, SamplingParams) \
            else SamplingParams(max_tokens=int(spec))
        try:
            rids.append(srv.add_request(p, params, now=t0))
        except BackpressureError:
            rids.append(None)
    deltas: dict = {}
    finishes: dict = {}
    steps = 0
    while srv.proxy.inflight and steps < max_steps:
        for out in srv.step():
            deltas.setdefault(out.rid, []).extend(out.new_tokens)
            if out.finished:
                finishes[out.rid] = (out.finish_reason, out.n_generated)
        steps += 1
    assert not srv.proxy.inflight, f"not quiescent after {steps} steps"
    return rids, deltas, finishes


def _assert_no_leaks(srv):
    """Quiescent-point hygiene: pool invariants hold (including the arena's
    zero-stale-summary scan) and the only residual block mappings are
    prefix-store snapshots — no request, prefill, or handoff key survives."""
    if srv.kv_arena is None:
        return
    pool = srv.kv_arena.pool
    pool.check_invariants(arena=srv.kv_arena)
    for key in pool.per_request:
        assert isinstance(key, tuple) and key[0] == "store", \
            f"leaked block mapping under {key!r}"


# ---------------------------------------------------------------------
def test_fault_schedule_deterministic():
    """Same seed → identical schedule; the schedule respects the config's
    step window and only names known fault kinds."""
    cfg = FaultConfig(seed=3, horizon=40)
    a, b = FaultPlane(cfg), FaultPlane(cfg)
    assert list(a.schedule) == list(b.schedule)
    assert list(a.schedule) != list(FaultPlane(FaultConfig(seed=4,
                                                           horizon=40)).schedule)
    for spec in a.schedule:
        assert spec.kind in FAULT_KINDS
        assert cfg.warmup_steps <= spec.step < cfg.horizon
    n_expected = (cfg.n_kill_prefill + cfg.n_kill_decode + cfg.n_kv_corrupt
                  + cfg.n_kv_lost + cfg.n_handoff_drop + cfg.n_alloc_fail
                  + cfg.n_straggler)
    assert len(a.schedule) == n_expected


def test_metrics_robustness_keys():
    """The robustness counters ride along in BOTH summary branches (the
    zero-done early return included)."""
    m = MetricsAggregator()
    empty = m.summary(1.0)
    for k in ("n_errors", "n_timeouts", "n_shed", "n_retries",
              "blocks_quarantined"):
        assert k in empty and empty[k] == 0
    m.note_shed()
    m.note_quarantine(3)
    assert m.summary(1.0)["n_shed"] == 1
    assert m.summary(1.0)["blocks_quarantined"] == 3


def test_kv_lost_retry_cap_surfaces_error(small):
    """Satellite 1: losing a request's decode KV more often than
    `max_retries` allows must retire it with finish_reason="error" (not
    loop forever) and leak nothing."""
    cfg = small
    scfg = ServerConfig(n_prefill=1, n_decode=1, decode_slots=4, max_len=96,
                        oas=OASConfig(defer_window=0.0, max_retries=1))
    srv = Server(cfg, scfg, pattern=[0, 0])
    rng = np.random.default_rng(21)
    prompt = tuple(rng.integers(0, cfg.vocab_size, 10))
    rid = srv.add_request(prompt, SamplingParams(max_tokens=8))
    finish, injections = None, 0
    for _ in range(200):
        if any(rid in eng.rid_slot for eng in srv.decodes):
            srv.inject_kv_lost(rid)
            injections += 1
        for out in srv.step():
            if out.rid == rid and out.finished:
                finish = out.finish_reason
        if finish is not None:
            break
    assert finish == "error"
    assert injections == 2          # retry 1 granted, retry 2 over the cap
    assert not srv.proxy.inflight
    s = srv.metrics.summary(1.0)
    assert s["n_errors"] == 1 and s["n_retries"] >= 1
    _assert_no_leaks(srv)


def test_orphan_handoff_sweep_reclaims_and_recovers(small):
    """Satellite 2: dropping a parked prefill→decode handoff WITHOUT
    releasing its pool key (the rename-stage leak) must be reclaimed by the
    orphan sweep, and the request must still complete via the kv-lost
    reroute — with pool invariants intact throughout."""
    cfg = small
    scfg = ServerConfig(n_prefill=1, n_decode=1, decode_slots=4, max_len=96,
                        oas=OASConfig(defer_window=0.0))
    srv = Server(cfg, scfg, pattern=[0, 0])
    rng = np.random.default_rng(22)
    rid = srv.add_request(tuple(rng.integers(0, cfg.vocab_size, 12)),
                          SamplingParams(max_tokens=4))
    dropped, finish = False, None
    for _ in range(200):
        if not dropped and rid in srv._pending_kv:
            assert srv.inject_handoff_drop(rid)
            assert rid not in srv._pending_kv
            dropped = True
        for out in srv.step():
            if out.rid == rid and out.finished:
                finish = out.finish_reason
        srv.kv_arena.pool.check_invariants()
        if finish is not None:
            break
    assert dropped, "handoff never parked — test lost its injection point"
    assert srv.n_handoffs_swept >= 1
    assert finish == "length"
    assert srv.metrics.summary(1.0)["n_retries"] >= 1
    _assert_no_leaks(srv)


def test_watchdog_retires_stuck_request(small):
    """With every decode instance dead and no revival, a prefilled request
    can never progress past DECODE_WAIT: the step-count watchdog must
    retire it with finish_reason="timeout" and release its parked KV."""
    cfg = small
    scfg = ServerConfig(n_prefill=1, n_decode=1, decode_slots=4, max_len=96,
                        watchdog_steps=5,
                        oas=OASConfig(defer_window=0.0, max_retries=10))
    srv = Server(cfg, scfg, pattern=[0, 0])
    srv.inject_instance_failure("decode", 0)
    rng = np.random.default_rng(23)
    rid = srv.add_request(tuple(rng.integers(0, cfg.vocab_size, 8)),
                          SamplingParams(max_tokens=6))
    finish = None
    for _ in range(60):
        for out in srv.step():
            if out.rid == rid and out.finished:
                finish = out.finish_reason
        if finish is not None:
            break
    assert finish == "timeout"
    assert not srv.proxy.inflight
    assert srv.metrics.summary(1.0)["n_timeouts"] == 1
    _assert_no_leaks(srv)


def test_backpressure_shedding(small):
    """Typed load shedding at the door: a prompt no release sequence could
    ever fit raises BackpressureError, as does an admission backlog over
    `admission_queue_cap` — and the shed requests never enter the proxy."""
    cfg = small
    scfg = ServerConfig(n_prefill=1, n_decode=1, decode_slots=2, max_len=96,
                        kv_blocks=6, admission_queue_cap=2,
                        oas=OASConfig(defer_window=0.0))
    srv = Server(cfg, scfg, pattern=[0, 0])
    rng = np.random.default_rng(24)
    # 6 blocks × 16 tokens = 96-token ceiling → a 200-token prompt is
    # infeasible no matter what frees up
    with pytest.raises(BackpressureError):
        srv.add_request(tuple(rng.integers(0, cfg.vocab_size, 200)),
                        SamplingParams(max_tokens=2))
    assert not srv.proxy.inflight
    short = [tuple(rng.integers(0, cfg.vocab_size, 6)) for _ in range(3)]
    r0 = srv.add_request(short[0], SamplingParams(max_tokens=2))
    r1 = srv.add_request(short[1], SamplingParams(max_tokens=2))
    with pytest.raises(BackpressureError):     # backlog 2 >= cap 2
        srv.add_request(short[2], SamplingParams(max_tokens=2))
    assert srv.metrics.summary(1.0)["n_shed"] == 2
    # the admitted pair still serves normally after the shed
    done = set()
    for _ in range(200):
        done |= {o.rid for o in srv.step() if o.finished}
        if done == {r0, r1}:
            break
    assert done == {r0, r1}
    _assert_no_leaks(srv)


def test_corruption_detected_quarantined_bit_identical(small):
    """KV corruption under a live decode request: the summary-plane scan
    must detect exactly the corrupted block, quarantine+scrub it, restart
    the mapping request, and the replayed greedy output must be
    bit-identical to an unfaulted run."""
    cfg = small
    scfg = ServerConfig(n_prefill=1, n_decode=1, decode_slots=4, max_len=96,
                        oas=OASConfig(defer_window=0.0, max_retries=4))
    rng = np.random.default_rng(25)
    reqs = [(tuple(rng.integers(0, cfg.vocab_size, 14)), 6) for _ in range(2)]

    base = Server(cfg, scfg, pattern=[0, 0])
    _, _, _ = _drive(base, reqs)
    ref = {r.rid: tuple(r.output_tokens) for r in base.metrics.done}

    srv = Server(cfg, scfg, pattern=[0, 0])
    kv = srv.kv_arena.kv
    assert any(e is not None and "kmin" in e for e in kv["period"]), \
        "pattern=[0,0] should give every layer a summary plane"
    t0 = time.monotonic()
    for i, (p, m) in enumerate(reqs):
        srv.submit(i, p, m, t0)
    corrupted = None
    for _ in range(300):
        if corrupted is None:
            pool = srv.kv_arena.pool
            for eng in srv.decodes:
                for rid in list(eng.rid_slot):
                    owned = pool.owned(rid)
                    if owned:
                        corrupted = owned[0]
                        break
            if corrupted is not None:
                corrupt_block(srv.kv_arena, corrupted, offset=0.75)
                bad = srv.recover_corruption()
                assert bad == [corrupted]
                assert corrupted in pool.quarantined
                assert corrupted not in pool.refcount
                srv.kv_arena.check_summaries()   # scrubbed block is coherent
        srv.step()
        if not srv.proxy.inflight:
            break
    assert corrupted is not None, "no decode-resident block to corrupt"
    assert not srv.proxy.inflight
    outs = {r.rid: tuple(r.output_tokens) for r in srv.metrics.done}
    assert outs == ref, "post-corruption replay diverged from fault-free run"
    assert srv.metrics.summary(1.0)["blocks_quarantined"] == 1
    _assert_no_leaks(srv)


def test_alloc_failure_burst_recovers(small):
    """A burst of injected allocation failures (transient HBM pressure)
    must only defer/preempt — every request still completes and the pool
    balances."""
    cfg = small
    scfg = ServerConfig(n_prefill=1, n_decode=1, decode_slots=4, max_len=96,
                        oas=OASConfig(defer_window=0.0, max_retries=4))
    srv = Server(cfg, scfg, pattern=[0, 0])
    srv.kv_arena.pool.inject_alloc_failures = 3
    rng = np.random.default_rng(26)
    reqs = [(tuple(rng.integers(0, cfg.vocab_size, 12)), 5) for _ in range(3)]
    _, _, finishes = _drive(srv, reqs)
    assert srv.kv_arena.pool.inject_alloc_failures == 0, \
        "armed failures never consumed — injection point dead"
    assert {f[0] for f in finishes.values()} == {"length"}
    assert len(finishes) == 3
    _assert_no_leaks(srv)


def test_disaggregated_failure_drill(small):
    """Satellite 3: the serve_disaggregated example's failure drill as a
    tier-1 test — streaming sampled requests over 2 prefill instances, a
    mid-stream prefill death+revival, and an abort — asserting delivered
    counters, no replayed deltas, and zero leaked blocks."""
    cfg = small
    scfg = ServerConfig(n_prefill=2, n_decode=1, decode_slots=4, max_len=96,
                        chunk_tokens=8, prefill_tick_budget=8,
                        oas=OASConfig(defer_window=0.0, max_retries=4))
    srv = Server(cfg, scfg, pattern=[0, 0])
    rng = np.random.default_rng(1)
    prompts = [tuple(rng.integers(0, cfg.vocab_size,
                                  int(rng.integers(6, 20))))
               for _ in range(6)]
    params = [SamplingParams(temperature=0.7, top_k=32, seed=i, max_tokens=4)
              for i in range(6)]
    deltas: dict = {}
    finishes: dict = {}
    kicked = aborted = None
    for out in srv.generate(prompts, params, max_wall_s=120):
        deltas.setdefault(out.rid, []).extend(out.new_tokens)
        if out.finished:
            finishes[out.rid] = (out.finish_reason, out.n_generated)
        if kicked is None and out.new_tokens:
            kicked = out.rid
            srv.inject_instance_failure("prefill", 0)
            srv.revive_instance("prefill", 0)
        if aborted is None and kicked is not None:
            quiet = [r for r in range(6)
                     if r not in finishes and not deltas.get(r)]
            if quiet:
                aborted = quiet[0]
                assert srv.abort(aborted)
    assert len(finishes) == 6
    for rid, (reason, n_out) in finishes.items():
        if rid == aborted:
            assert reason == "abort"
            assert len(deltas.get(rid, [])) <= n_out
        else:
            assert reason in ("stop", "length")
            # delivered-counter contract: the streamed deltas ARE the
            # output — nothing replayed, nothing missing
            assert len(deltas[rid]) == n_out == 4
    done = {r.rid: tuple(r.output_tokens) for r in srv.metrics.done}
    for rid, toks in done.items():
        assert tuple(deltas[rid]) == toks
    s = srv.metrics.summary(1.0)
    assert s["n_done"] == 5 and len(srv.metrics.aborted) == 1
    _assert_no_leaks(srv)


# ---------------------------------------------------------------------
SOAK_SEEDS = (1, 2, 5, 7, 9)


def _soak_server(cfg, faults=None, spec=None, quant=None):
    scfg = ServerConfig(n_prefill=2, n_decode=2, decode_slots=4, max_len=128,
                        chunk_tokens=32, prefill_tick_budget=64, kv_blocks=96,
                        watchdog_steps=200, spec=spec, quant=quant,
                        oas=OASConfig(defer_window=0.0, max_retries=10))
    return Server(cfg, scfg, pattern=[0, 0], faults=faults)


def _soak_workload(vocab):
    rng = np.random.default_rng(42)
    return [(tuple(rng.integers(0, vocab, 24)), 12) for _ in range(8)]


def test_chaos_soak_bit_identical(small):
    """The headline contract: across ≥5 fault seeds mixing instance kills,
    KV corruption, KV loss, handoff drops, allocation failures and
    stragglers, every request completes with greedy output bit-identical
    to the fault-free run, no streamed delta is ever replayed, and the
    quiescent pool passes invariants (zero stale summaries, zero leaks)."""
    cfg = small
    reqs = _soak_workload(cfg.vocab_size)

    base = _soak_server(cfg)
    _, base_deltas, base_fin = _drive(base, reqs)
    ref = {r.rid: tuple(r.output_tokens) for r in base.metrics.done}
    assert len(ref) == 8
    _assert_no_leaks(base)

    for seed in SOAK_SEEDS:
        plane = FaultPlane(FaultConfig(seed=seed, horizon=20))
        srv = _soak_server(cfg, faults=plane)
        _, deltas, finishes = _drive(srv, reqs)
        outs = {r.rid: tuple(r.output_tokens) for r in srv.metrics.done}
        assert len(outs) == 8, \
            f"seed {seed}: {8 - len(outs)} requests did not complete " \
            f"({ {r: f for r, f in finishes.items() if f[0] not in ('stop', 'length')} })"
        assert outs == ref, f"seed {seed}: outputs diverged from fault-free run"
        for rid, toks in outs.items():
            assert tuple(deltas[rid]) == toks, \
                f"seed {seed}: rid {rid} streamed deltas replayed or lost"
        assert sum(plane.injected.values()) > 0, \
            f"seed {seed}: chaos run injected nothing"
        pool = srv.kv_arena.pool
        assert len(pool.quarantined) == srv.metrics.blocks_quarantined
        s = srv.metrics.summary(1.0)
        assert s["n_errors"] == 0 and s["n_timeouts"] == 0
        _assert_no_leaks(srv)


def test_chaos_soak_spec_bit_identical(small):
    """SpecPlane × FaultPlane composition: with model-free speculative
    decoding on, chaos runs (instance kills, KV corruption/loss, handoff
    drops, allocation failures, stragglers) must still complete every
    request with greedy output bit-identical to the fault-free
    NON-speculative run — drafts change how many tokens a verify step
    lands, never which, and every recovery path (preempt, restart,
    re-admission) re-seeds the drafting history cleanly. Quiescent pools
    pass the zero-stale-summary scan after every rollback."""
    cfg = small
    rng = np.random.default_rng(7)
    gram = tuple(int(t) for t in rng.integers(0, cfg.vocab_size, 6))
    reqs = [(gram * 3, 12) for _ in range(4)] + \
        [(tuple(int(t) for t in rng.integers(0, cfg.vocab_size, 24)), 12)
         for _ in range(4)]

    base = _soak_server(cfg)
    _, base_deltas, _ = _drive(base, reqs)
    ref = {r.rid: tuple(r.output_tokens) for r in base.metrics.done}
    assert len(ref) == 8
    _assert_no_leaks(base)

    for seed in (2, 5):
        plane = FaultPlane(FaultConfig(seed=seed, horizon=20))
        srv = _soak_server(cfg, faults=plane, spec=SpecConfig(k=4))
        _, deltas, finishes = _drive(srv, reqs)
        outs = {r.rid: tuple(r.output_tokens) for r in srv.metrics.done}
        assert len(outs) == 8, f"seed {seed}: incomplete ({finishes})"
        assert outs == ref, f"seed {seed}: spec+faults diverged"
        for rid, toks in outs.items():
            assert tuple(deltas[rid]) == toks, \
                f"seed {seed}: rid {rid} streamed deltas replayed or lost"
        assert sum(plane.injected.values()) > 0
        for eng in srv.decodes:
            eng.take_spec_stats()
            assert eng.stats["host_fetches"] == eng.stats["steps"]
        _assert_no_leaks(srv)


def test_chaos_soak_quant_bit_identical(small):
    """QuantPlane × FaultPlane composition: with int8 arenas on, a chaos
    seed mixing instance kills, KV corruption (now perturbing int8
    payloads by a clipped integer delta), KV loss, handoff drops,
    allocation failures and stragglers must still complete every request
    with greedy output bit-identical to the fault-free QUANT run —
    detection rides the summary-vs-dequantized-content scan, scrub zeroes
    payloads AND the scale plane, and recovery replays re-quantize to the
    exact same ints (per-token/seal quantization is a pure function of
    the written content). Quiescent pools pass the extended
    zero-stale-summary + zero-stale-scale scan."""
    from repro.serving.quant import QuantConfig
    cfg = small
    reqs = _soak_workload(cfg.vocab_size)

    base = _soak_server(cfg, quant=QuantConfig())
    _, _, _ = _drive(base, reqs)
    ref = {r.rid: tuple(r.output_tokens) for r in base.metrics.done}
    assert len(ref) == 8
    assert base.kv_arena.quant          # arenas actually carry the plane
    _assert_no_leaks(base)

    for seed in (2, 7):
        plane = FaultPlane(FaultConfig(seed=seed, horizon=20))
        srv = _soak_server(cfg, faults=plane, quant=QuantConfig())
        _, deltas, finishes = _drive(srv, reqs)
        outs = {r.rid: tuple(r.output_tokens) for r in srv.metrics.done}
        assert len(outs) == 8, f"seed {seed}: incomplete ({finishes})"
        assert outs == ref, f"seed {seed}: quant+faults diverged"
        for rid, toks in outs.items():
            assert tuple(deltas[rid]) == toks, \
                f"seed {seed}: rid {rid} streamed deltas replayed or lost"
        assert sum(plane.injected.values()) > 0
        _assert_no_leaks(srv)


def test_corruption_quant_scrub_zeroes_scales(small):
    """Direct int8 corruption drill: perturbing a quantized block's payload
    ints must be caught by the summary scan (the summaries bound the
    DEQUANTIZED content), and the quarantine scrub must zero the payload,
    the summaries, AND every scale-plane row for that block — a stale
    nonzero kscale row would mark a scrubbed block as sealed."""
    import numpy as np
    from repro.serving.quant import QuantConfig
    cfg = small
    scfg = ServerConfig(n_prefill=1, n_decode=1, decode_slots=4, max_len=96,
                        quant=QuantConfig(),
                        oas=OASConfig(defer_window=0.0, max_retries=4))
    rng = np.random.default_rng(27)
    reqs = [(tuple(rng.integers(0, cfg.vocab_size, 14)), 6) for _ in range(2)]

    base = Server(cfg, scfg, pattern=[0, 0])
    _drive(base, reqs)
    ref = {r.rid: tuple(r.output_tokens) for r in base.metrics.done}

    srv = Server(cfg, scfg, pattern=[0, 0])
    t0 = time.monotonic()
    for i, (p, m) in enumerate(reqs):
        srv.submit(i, p, m, t0)
    corrupted = None
    for _ in range(300):
        if corrupted is None:
            pool = srv.kv_arena.pool
            for eng in srv.decodes:
                for rid in list(eng.rid_slot):
                    owned = pool.owned(rid)
                    if owned:
                        corrupted = owned[0]
                        break
            if corrupted is not None:
                corrupt_block(srv.kv_arena, corrupted, offset=0.75)
                bad = srv.recover_corruption()
                assert bad == [corrupted]
                assert corrupted in pool.quarantined
                srv.kv_arena.check_summaries()
                for part, stacked in (("period", True), ("rem", False)):
                    for e in srv.kv_arena.kv[part]:
                        if e is None or "kscale" not in e:
                            continue
                        for leaf in ("k", "v", "kscale", "vscale",
                                     "ktok", "vtok", "kmin", "kmax", "kmean"):
                            x = np.asarray(e[leaf])
                            blk = x[:, corrupted] if stacked else x[corrupted]
                            assert not blk.any(), \
                                f"scrub left {leaf} nonzero on block " \
                                f"{corrupted}"
        srv.step()
        if not srv.proxy.inflight:
            break
    assert corrupted is not None, "no decode-resident block to corrupt"
    outs = {r.rid: tuple(r.output_tokens) for r in srv.metrics.done}
    assert outs == ref, "post-corruption quant replay diverged"
    _assert_no_leaks(srv)
