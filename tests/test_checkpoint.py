"""Checkpoint: roundtrip, atomic commit, rotation, elastic restore."""
import json
import os
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager, load_checkpoint, save_checkpoint


def _tree(seed=0):
    rng = np.random.default_rng(seed)
    return {
        "a": jnp.asarray(rng.standard_normal((8, 16)), jnp.float32),
        "nested": {"b": jnp.asarray(rng.integers(0, 10, (4,)), jnp.int32),
                   "tup": (jnp.ones((2, 2), jnp.bfloat16),
                           jnp.zeros((3,), jnp.float32))},
    }


def test_roundtrip(tmp_path):
    t = _tree()
    save_checkpoint(tmp_path, 5, t, extra={"note": "x"})
    template = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), t)
    got, step, extra = load_checkpoint(tmp_path, template=template)
    assert step == 5 and extra == {"note": "x"}
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(got)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))
        assert a.dtype == b.dtype


def test_atomic_commit_no_partial_visible(tmp_path):
    t = _tree()
    save_checkpoint(tmp_path, 1, t)
    # fake a crashed write
    crash = tmp_path / "step_00000002.tmp"
    crash.mkdir()
    (crash / "chunk_p0_00000.msgpack.zst").write_bytes(b"garbage")
    got, step, _ = load_checkpoint(tmp_path)   # ignores .tmp
    assert step == 1
    mgr = CheckpointManager(tmp_path)          # cleanup removes crash garbage
    assert not crash.exists()


def test_rotation_keeps_last_n(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2)
    for s in (1, 2, 3, 4):
        mgr.save(s, {"x": jnp.full((2,), s, jnp.float32)})
    dirs = sorted(p.name for p in Path(tmp_path).iterdir() if p.is_dir())
    assert dirs == ["step_00000003", "step_00000004"]
    assert mgr.latest_step() == 4


def test_elastic_restore_resharding(tmp_path, mesh1):
    """Restore places leaves per the CURRENT mesh shardings (1-device here,
    but exercised through the same device_put path used at scale)."""
    from jax.sharding import PartitionSpec as P
    t = {"w": jnp.arange(64, dtype=jnp.float32).reshape(8, 8)}
    save_checkpoint(tmp_path, 7, t)
    template = {"w": jax.ShapeDtypeStruct((8, 8), jnp.float32)}
    shardings = {"w": mesh1.sharding(P(None, None))}
    got, step, _ = load_checkpoint(tmp_path, template=template,
                                   shardings=shardings)
    np.testing.assert_array_equal(np.asarray(got["w"]), np.asarray(t["w"]))
    assert got["w"].sharding == shardings["w"]


def test_missing_leaf_raises(tmp_path):
    save_checkpoint(tmp_path, 1, {"a": jnp.ones((2,))})
    with pytest.raises(KeyError):
        load_checkpoint(tmp_path, template={"a": jax.ShapeDtypeStruct((2,), jnp.float32),
                                            "b": jax.ShapeDtypeStruct((2,), jnp.float32)})


def test_dtype_cast_on_restore(tmp_path):
    save_checkpoint(tmp_path, 1, {"a": jnp.ones((4,), jnp.float32)})
    got, _, _ = load_checkpoint(
        tmp_path, template={"a": jax.ShapeDtypeStruct((4,), jnp.bfloat16)})
    assert got["a"].dtype == jnp.bfloat16
