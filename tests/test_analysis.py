"""ContractGuard: linter fixtures (good/bad pair per rule + waivers), the
HotLoopRegistry completeness contract, and the layer-2 jaxpr audits over
live servers (1-device and tp=2,ep=4 under device forcing).

Fixture snippets run through the exact production pipeline via
`run_lint(files=...)` — same parsing, same rules, same waiver handling
the CLI uses on the real tree.
"""
import ast
from pathlib import Path

import jax
import numpy as np
import pytest

from repro.analysis.lint import run_lint
from repro.configs import reduced_config

REPO = Path(__file__).resolve().parents[1]


def rules_hit(files, **kw):
    rep = run_lint(files=files, tracked_files=kw.pop("tracked_files", []),
                   gitignore_text=kw.pop("gitignore_text",
                                         "__pycache__/\n*.pyc\n"), **kw)
    return rep, {d.rule for d in rep.diagnostics if not d.waived}


# ---------------------------------------------------------------------------
# rule fixtures: one good/bad pair per rule
# ---------------------------------------------------------------------------

def test_donate_jit_choke_point_pair():
    bad = {"src/repro/serving/decode.py":
           "import jax\n"
           "class D:\n"
           "    def __post_init__(self):\n"
           "        self._step = jax.jit(self._step_impl)\n"}
    rep, hit = rules_hit(bad)
    assert "donate-jit-choke-point" in hit
    (d,) = [x for x in rep.diagnostics if x.rule == "donate-jit-choke-point"]
    assert (d.path, d.line) == ("src/repro/serving/decode.py", 4)

    good = {"src/repro/serving/decode.py":
            "class D:\n"
            "    def __post_init__(self):\n"
            "        pl = self.placement\n"
            "        self._step = pl.donate_jit(self._step_impl,\n"
            "                                   donate_argnums=(1,))\n",
            # the choke point itself is allowed to build the jit
            "src/repro/serving/placement.py":
            "import jax\n"
            "def donate_jit(fn):\n"
            "    return jax.jit(fn)\n"}
    _, hit = rules_hit(good)
    assert "donate-jit-choke-point" not in hit


def test_choke_point_catches_decorator_and_from_import():
    bad = {"src/repro/serving/prefill.py":
           "from jax import jit\n"
           "import functools, jax\n"
           "@jit\n"
           "def f(x):\n"
           "    return x\n"
           "@functools.partial(jax.jit, static_argnums=(1,))\n"
           "def g(x, n):\n"
           "    return x\n"}
    rep, hit = rules_hit(bad)
    lines = {d.line for d in rep.diagnostics
             if d.rule == "donate-jit-choke-point"}
    assert lines == {3, 6}


def test_proxy_jax_free_direct_import():
    bad = {"src/repro/core/proxy/params.py": "import jax.numpy as jnp\n"}
    rep, hit = rules_hit(bad)
    assert "proxy-jax-free" in hit
    good = {"src/repro/core/proxy/params.py": "import numpy as np\n"}
    _, hit = rules_hit(good)
    assert "proxy-jax-free" not in hit


def test_proxy_jax_free_transitive_import():
    files = {
        "src/repro/core/proxy/oas.py":
            "from repro.serving.helper import f\n",
        "src/repro/serving/helper.py":
            # two hops: helper itself is jax-free but pulls in a module
            # that is not
            "from repro.serving.deep import g\ndef f():\n    pass\n",
        "src/repro/serving/deep.py": "import jax\ndef g():\n    pass\n"}
    rep, hit = rules_hit(files)
    assert "proxy-jax-free" in hit
    (d,) = [x for x in rep.diagnostics if x.rule == "proxy-jax-free"]
    assert "repro.serving.helper" in d.msg and "repro.serving.deep" in d.msg
    # numpy-only intra-repo deps stay clean
    ok = {"src/repro/core/proxy/oas.py":
          "from repro.core.proxy.radix import RadixTree\n",
          "src/repro/core/proxy/radix.py": "import numpy as np\n"}
    _, hit = rules_hit(ok)
    assert "proxy-jax-free" not in hit


def test_host_sync_item_and_int_in_impl():
    bad = {"src/repro/serving/decode.py":
           "class D:\n"
           "    def _step_impl(self, params, cache, state):\n"
           "        v = state['t'].item()\n"
           "        n = int(cache[0])\n"
           "        return v, n\n"}
    rep, hit = rules_hit(bad)
    lines = {d.line for d in rep.diagnostics
             if d.rule == "no-host-sync-in-impl"}
    assert lines == {3, 4}


def test_host_sync_allows_host_side_glue_and_static_args():
    good = {"src/repro/serving/decode.py":
            "import numpy as np\n"
            "class D:\n"
            "    def __post_init__(self):\n"
            "        self._r = pl.donate_jit(self._r_impl,\n"
            "                                static_argnums=(1,))\n"
            "    def _r_impl(self, x, n):\n"
            "        a = int(x.shape[0])\n"       # shapes are trace-time
            "        b = int(n) + len(x)\n"       # n is static, len is too
            "        return a + b\n"
            "    def step_host(self, out):\n"     # not a jitted body
            "        return int(np.asarray(out)[0])\n"}
    _, hit = rules_hit(good)
    assert "no-host-sync-in-impl" not in hit


def test_host_sync_device_get_asarray_block_until_ready():
    bad = {"src/repro/serving/arena.py":
           "import jax\n"
           "import numpy as np\n"
           "def _copy_impl(src, dst):\n"
           "    jax.device_get(src)\n"
           "    np.asarray(dst)\n"
           "    src.block_until_ready()\n"
           "    return dst\n"}
    rep, _ = rules_hit(bad)
    lines = {d.line for d in rep.diagnostics
             if d.rule == "no-host-sync-in-impl"}
    assert lines == {4, 5, 6}


def test_seeded_rng_only_pair():
    bad = {"src/repro/serving/sched.py":
           "import time, random\n"
           "import numpy as np\n"
           "def schedule():\n"
           "    return (time.time(), np.random.rand(3),\n"
           "            np.random.default_rng(), random.randint(0, 5))\n"}
    rep, hit = rules_hit(bad)
    assert len([d for d in rep.diagnostics
                if d.rule == "seeded-rng-only"]) == 4
    good = {"src/repro/serving/sched.py":
            "import time, random\n"
            "import numpy as np\n"
            "def schedule(seed):\n"
            "    rng = np.random.default_rng(seed)\n"
            "    r2 = random.Random(seed)\n"
            "    t = time.monotonic()\n"
            "    return rng, r2, t\n",
            # out of scope: launch/ may use wall-clock
            "src/repro/launch/bench.py":
            "import time\nt = time.time()\n"}
    _, hit = rules_hit(good)
    assert "seeded-rng-only" not in hit


def test_no_shape_leak_pair():
    src = ("class P:\n"
           "    def __post_init__(self):\n"
           "        self._resume = pl.donate_jit(self._resume_impl,\n"
           "                                     donate_argnums=(2,),\n"
           "                                     static_argnums=(5,))\n"
           "    def go(self, params, toks, cache, cl, tables, x):\n"
           "        bad = self._resume(params, toks, cache, cl, tables,\n"
           "                           {})\n")
    bad = {"src/repro/serving/prefill.py":
           src.replace("{}", "x.shape[0]")}
    rep, hit = rules_hit(bad)
    assert "no-shape-leak" in hit
    good = {"src/repro/serving/prefill.py":
            src.replace("{}", "_bucket(x.shape[0])")}
    _, hit = rules_hit(good)
    assert "no-shape-leak" not in hit


def test_repo_hygiene_tracked_artifacts_and_gitignore():
    rep, hit = rules_hit({}, tracked_files=["src/repro/__pycache__/x.pyc",
                                            "tests/.pytest_cache/v/cache",
                                            "src/repro/core/oas.py"],
                         gitignore_text="")
    ds = [d for d in rep.diagnostics if d.rule == "repo-hygiene"]
    # 2 tracked artifacts + 2 missing .gitignore patterns
    assert len(ds) == 4 and "repo-hygiene" in hit
    rep, hit = rules_hit({}, tracked_files=["src/repro/core/oas.py"],
                         gitignore_text="__pycache__/\n*.pyc\n")
    assert "repo-hygiene" not in hit


# ---------------------------------------------------------------------------
# waiver handling
# ---------------------------------------------------------------------------

BAD_IMPL = ("class D:\n"
            "    def _step_impl(self, state):\n"
            "        {}\n"
            "        return state\n")


def test_waiver_downgrades_and_echoes_justification():
    files = {"src/repro/serving/decode.py": BAD_IMPL.format(
        "v = state.item()  # contract: waive no-host-sync-in-impl "
        "-- warmup-only probe, removed by DCE in the steady-state trace")}
    rep = run_lint(files=files, tracked_files=[],
                   gitignore_text="__pycache__/\n*.pyc\n")
    assert rep.ok() and rep.ok(strict=True)
    (d,) = rep.waived()
    assert d.justification.startswith("warmup-only probe")
    assert "warmup-only probe" in rep.format()  # report echoes the why


def test_waiver_on_line_above():
    files = {"src/repro/serving/decode.py": BAD_IMPL.format(
        "# contract: waive no-host-sync-in-impl -- fixture reason\n"
        "        v = state.item()")}
    rep = run_lint(files=files, tracked_files=[],
                   gitignore_text="__pycache__/\n*.pyc\n")
    assert rep.ok() and len(rep.waived()) == 1


def test_waiver_is_rule_and_line_narrow():
    # wrong rule id -> violation stays, waiver goes stale
    files = {"src/repro/serving/decode.py": BAD_IMPL.format(
        "v = state.item()  # contract: waive seeded-rng-only -- wrong rule")}
    rep = run_lint(files=files, tracked_files=[],
                   gitignore_text="__pycache__/\n*.pyc\n")
    assert not rep.ok()
    assert any(d.rule == "stale-waiver" for d in rep.errors(strict=True))


def test_waiver_without_justification_fails_strict():
    files = {"src/repro/serving/decode.py": BAD_IMPL.format(
        "v = state.item()  # contract: waive no-host-sync-in-impl")}
    rep = run_lint(files=files, tracked_files=[],
                   gitignore_text="__pycache__/\n*.pyc\n")
    assert rep.ok() and not rep.ok(strict=True)  # CI (--strict) still fails
    assert any(d.rule == "waiver-missing-justification"
               for d in rep.errors(strict=True))


# ---------------------------------------------------------------------------
# the real tree is contract-clean (what `python -m repro.analysis --strict`
# gates in CI)
# ---------------------------------------------------------------------------

def test_real_tree_is_contract_clean():
    rep = run_lint()
    assert rep.ok(strict=True), rep.format(strict=True)


# ---------------------------------------------------------------------------
# HotLoopRegistry completeness: every donate_jit call site in serving/
# shows up in the registry of a constructed server
# ---------------------------------------------------------------------------

def _donate_jit_call_sites():
    """Scrape serving/*.py for the fn names handed to donate_jit."""
    names = set()
    for f in sorted((REPO / "src/repro/serving").glob("*.py")):
        for node in ast.walk(ast.parse(f.read_text())):
            if isinstance(node, ast.Call) \
                    and isinstance(node.func, ast.Attribute) \
                    and node.func.attr == "donate_jit" and node.args:
                tgt = node.args[0]
                if isinstance(tgt, ast.Attribute):
                    names.add(tgt.attr)
                elif isinstance(tgt, ast.Name):
                    names.add(tgt.id)
    return names


@pytest.fixture(scope="module")
def tiny():
    cfg = reduced_config("qwen2-1.5b").with_updates(
        compute_dtype="float32", param_dtype="float32", n_layers=2)
    rng = np.random.default_rng(0)
    reqs = [(tuple(rng.integers(0, cfg.vocab_size, 9).tolist()), 5),
            (tuple(rng.integers(0, cfg.vocab_size, 17).tolist()), 5)]
    return cfg, reqs


def test_registry_covers_every_serving_donate_jit_site(tiny):
    from repro.core.placement.migration import MigrationPlan
    from repro.serving import Server, ServerConfig
    from repro.serving.spec import SpecConfig
    cfg, reqs = tiny
    sites = _donate_jit_call_sites()
    assert sites, "scrape found no donate_jit call sites?"

    registered = set()
    # paged + spec server: paged insert/extract, step, verify, arenas
    srv = Server(cfg, ServerConfig(decode_slots=4, max_len=96,
                                   spec=SpecConfig()), pattern=[0, 0])
    registered |= {n.split(".")[-1] for n in srv.placement.hot_loops.names()}
    # dense server: dense insert/extract
    srv = Server(cfg, ServerConfig(decode_slots=4, max_len=96,
                                   paged_kv=False), pattern=[0, 0])
    registered |= {n.split(".")[-1] for n in srv.placement.hot_loops.names()}
    # MoE server + one forced migration: the lazily-built remap jit
    mcfg = reduced_config("qwen2-moe-a2.7b").with_updates(
        n_layers=2, compute_dtype="float32", param_dtype="float32")
    msrv = Server(mcfg, ServerConfig(decode_slots=2, max_len=64))
    old_se = np.asarray(msrv.tables["slot_expert"]).copy()
    new_se = old_se.copy()
    new_se[0, 0], new_se[0, 1] = old_se[0, 1], old_se[0, 0]
    msrv._apply_migration(MigrationPlan(old_se, new_se, ((0, 0, 0),), 1))
    registered |= {n.split(".")[-1]
                   for n in msrv.placement.hot_loops.names()}

    missing = sites - registered
    assert not missing, \
        f"donate_jit call sites never registered: {sorted(missing)}"


def test_registry_entry_metadata(tiny):
    from repro.serving import Server, ServerConfig
    cfg, reqs = tiny
    srv = Server(cfg, ServerConfig(decode_slots=4, max_len=96),
                 pattern=[0, 0])
    by_name = {e.name.split(".")[-1]: e
               for e in srv.placement.hot_loops.entries}
    step = by_name["_step_impl"]
    assert step.donate_argnums == (1, 2) and step.out_specs is not None
    assert step.calls == 0 and step.abstract_args is None
    srv.run(reqs)
    assert step.calls > 0 and step.abstract_args is not None


# ---------------------------------------------------------------------------
# layer 2: jaxpr audit over live servers
# ---------------------------------------------------------------------------

@pytest.mark.jaxpr_audit
def test_audit_one_device_server(tiny):
    from repro.serving import Server, ServerConfig
    from repro.serving.spec import SpecConfig
    cfg, reqs = tiny
    for scfg in (ServerConfig(decode_slots=4, max_len=96),
                 ServerConfig(decode_slots=4, max_len=96,
                              spec=SpecConfig())):
        srv = Server(cfg, scfg, pattern=[0, 0])
        srv.run(reqs)
        rep = srv.audit_hot_loops()
        assert rep.ok(), rep.format()
        # the decode hot loop must have been audited, with its donation
        # verified on the lowered module
        assert any("_step_impl" in n or "_verify_impl" in n
                   for n in rep.audited)
        assert rep.checks.get("donation", 0) >= 1
        assert rep.checks.get("purity", 0) >= 1


@pytest.mark.jaxpr_audit
def test_audit_catches_callback_and_dropped_donation(tiny):
    """Negative control: a hot loop with a debug callback and one whose
    donation cannot alias must both be flagged."""
    import jax.numpy as jnp
    from repro.analysis.jaxpr_audit import audit_placement
    from repro.serving import DevicePlacement
    pl = DevicePlacement.local()

    def noisy(x):
        jax.debug.print("x={x}", x=x)
        return x * 2

    def no_alias(x):  # f32 in, i32 out: donated buffer can't be reused
        return (x * 2).astype(jnp.int32)

    noisy_jit = pl.donate_jit(noisy)
    drop_jit = pl.donate_jit(no_alias, donate_argnums=(0,))
    noisy_jit(jnp.ones((4,), jnp.float32))
    drop_jit(jnp.ones((512, 512), jnp.float32))
    rep = audit_placement(pl)
    checks = {(f.entry.split(".")[-1], f.check) for f in rep.findings}
    assert ("noisy", "purity") in checks, rep.format()
    assert ("no_alias", "donation") in checks, rep.format()


@pytest.mark.jaxpr_audit
def test_audit_catches_f64_convert(tiny):
    import jax.numpy as jnp
    from repro.analysis.jaxpr_audit import audit_placement
    from repro.serving import DevicePlacement
    jax.config.update("jax_enable_x64", True)
    try:
        pl = DevicePlacement.local()
        f64_jit = pl.donate_jit(lambda x: x.astype(jnp.float64).sum())
        f64_jit(jnp.ones((4,), jnp.float32))
        rep = audit_placement(pl)
    finally:
        jax.config.update("jax_enable_x64", False)
    assert any(f.check == "f64" for f in rep.findings), rep.format()


@pytest.mark.jaxpr_audit
def test_audit_quant_server_no_upcast(tiny):
    """Quantized server: every called hot loop that touches int8 arena
    leaves passes the quant-upcast check — no full-arena f32 twin is ever
    materialized (dequant stays in-tile / on gathered views)."""
    from repro.serving import Server, ServerConfig
    from repro.serving.quant import QuantConfig
    cfg, reqs = tiny
    srv = Server(cfg, ServerConfig(decode_slots=4, max_len=96,
                                   quant=QuantConfig()), pattern=[0, 0])
    srv.run(reqs)
    rep = srv.audit_hot_loops()
    assert rep.ok(), rep.format()
    assert rep.checks.get("quant-upcast", 0) >= 1, \
        "no hot loop carried int8 arena leaves — check never armed"


@pytest.mark.jaxpr_audit
def test_audit_catches_full_arena_dequant(tiny):
    """Negative control: a hot loop that dequantizes the ENTIRE int8
    arena into an f32 twin must be flagged by quant-upcast, while a
    gathered-view dequant (tabled blocks only) must pass."""
    import jax.numpy as jnp
    from repro.analysis.jaxpr_audit import audit_placement
    from repro.serving import DevicePlacement
    pl = DevicePlacement.local()

    def upcast(pages, scale):        # [N,K,bs,h] int8 → full f32 twin
        return (pages.astype(jnp.float32)
                * scale[:, :, None, :]).sum()

    def gathered(pages, scale, tables):   # dequant only tabled blocks
        g = pages[tables].astype(jnp.float32)
        return (g * scale[tables][:, :, :, None, :]).sum()

    N, K, bs, h = 16, 2, 8, 4
    pages = jnp.zeros((N, K, bs, h), jnp.int8)
    scale = jnp.ones((N, K, h), jnp.float32)
    tables = jnp.zeros((2, 3), jnp.int32)
    pl.donate_jit(upcast)(pages, scale)
    pl.donate_jit(gathered)(pages, scale, tables)
    rep = audit_placement(pl)
    flagged = {f.entry.split(".")[-1] for f in rep.findings
               if f.check == "quant-upcast"}
    assert flagged == {"upcast"}, rep.format()


@pytest.mark.jaxpr_audit
@pytest.mark.skipif(jax.device_count() < 8,
                    reason="needs XLA_FLAGS="
                           "--xla_force_host_platform_device_count=8")
def test_audit_tp2_ep4_server_out_shardings(tiny):
    """Acceptance: donation + pinned out-shardings verified for every
    called hot-loop jit of a tp=2,ep=4 server (device-forced CPU mesh)."""
    from repro.models import LM
    from repro.serving import DevicePlacement, Server, ServerConfig
    _, reqs = tiny
    cfg = reduced_config("qwen2-moe-a2.7b").with_updates(
        compute_dtype="float32", param_dtype="float32")
    pl1 = DevicePlacement.local()
    lm1 = LM.build(cfg, pl1.ctx)
    params1 = lm1.init(jax.random.PRNGKey(0))
    pl8 = DevicePlacement.build(tp=2, ep=4)
    lm8 = LM.build(cfg, pl8.ctx)
    params8 = pl8.transfer_params(lm1, params1, lm8)
    srv = Server(cfg, ServerConfig(decode_slots=4, max_len=96),
                 placement=pl8, params=params8)
    rng = np.random.default_rng(3)
    srv.run([(tuple(rng.integers(0, cfg.vocab_size, 9).tolist()), 5),
             (tuple(rng.integers(0, cfg.vocab_size, 17).tolist()), 5)])
    rep = srv.audit_hot_loops()
    assert rep.ok(), rep.format()
    # every audited entry that pins out_specs had its compiled output
    # shardings compared against the placement's own spec tree
    pinned = [e for e in srv.placement.hot_loops.called()
              if e.out_specs is not None]
    assert pinned and rep.checks.get("out-shardings", 0) == len(pinned)
    assert rep.checks.get("donation", 0) >= 1
