"""Chunked attention vs dense reference; cache semantics; hypothesis sweeps."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.distributed.ctx import local_mesh_ctx
from repro.models import attention as A

MESH = local_mesh_ctx()


def dense_ref(q, k, v, causal, window=0, sink=0):
    B, S, H, h = q.shape
    K = k.shape[2]
    G = H // K
    kr = jnp.repeat(k, G, axis=2)
    vr = jnp.repeat(v, G, axis=2)
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                   kr.astype(jnp.float32)) / np.sqrt(h)
    qp, kp = jnp.arange(S)[:, None], jnp.arange(S)[None, :]
    mask = jnp.ones((S, S), bool)
    if causal:
        mask &= kp <= qp
    if window:
        w = (qp - kp) < window
        if sink:
            w |= kp < sink
        mask &= w
    s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, -1)
    return jnp.einsum("bhqk,bkhd->bqhd", p, vr.astype(jnp.float32))


@settings(max_examples=15, deadline=None)
@given(
    S=st.sampled_from([16, 32, 64]),
    H=st.sampled_from([2, 4, 6]),
    K=st.sampled_from([1, 2]),
    causal=st.booleans(),
    window=st.sampled_from([0, 8, 24]),
    qc=st.sampled_from([8, 16, 64]),
    kc=st.sampled_from([8, 32]),
)
def test_chunked_matches_dense(S, H, K, causal, window, qc, kc):
    if H % K:
        H = K * (H // K + 1)
    rng = jax.random.PRNGKey(S * 1000 + H * 100 + K)
    r1, r2, r3 = jax.random.split(rng, 3)
    q = jax.random.normal(r1, (2, S, H, 32), jnp.float32)
    k = jax.random.normal(r2, (2, S, K, 32), jnp.float32)
    v = jax.random.normal(r3, (2, S, K, 32), jnp.float32)
    out = A.chunked_attention(q, k, v, causal=causal, window=window,
                              q_chunk=qc, kv_chunk=kc, mesh=MESH)
    ref = dense_ref(q, k, v, causal, window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


def test_sink_window_mask():
    rng = jax.random.PRNGKey(0)
    r1, r2, r3 = jax.random.split(rng, 3)
    q = jax.random.normal(r1, (1, 64, 4, 32))
    k = jax.random.normal(r2, (1, 64, 4, 32))
    v = jax.random.normal(r3, (1, 64, 4, 32))
    out = A.chunked_attention(q, k, v, causal=True, window=16, sink=8,
                              q_chunk=16, kv_chunk=16, mesh=MESH)
    ref = dense_ref(q, k, v, True, 16, 8)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("window,sink", [(0, 0), (24, 0), (24, 8), (40, 16)])
def test_skip_masked_chunks_equivalent(window, sink):
    """Static block skipping (causal / window / sink) ≡ full masked scan."""
    rng = jax.random.PRNGKey(1)
    r1, r2, r3 = jax.random.split(rng, 3)
    q = jax.random.normal(r1, (1, 128, 4, 32))
    k = jax.random.normal(r2, (1, 128, 2, 32))
    v = jax.random.normal(r3, (1, 128, 2, 32))
    a = A.chunked_attention(q, k, v, causal=True, window=window, sink=sink,
                            q_chunk=32, kv_chunk=32, mesh=MESH,
                            skip_masked_chunks=False)
    b = A.chunked_attention(q, k, v, causal=True, window=window, sink=sink,
                            q_chunk=32, kv_chunk=32, mesh=MESH,
                            skip_masked_chunks=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5,
                               atol=1e-5)


# ----------------------------------------------------------------------
def test_ring_slot_layout():
    sink, recent = 4, 8
    # before wrap: identity
    for t in range(sink + recent):
        assert int(A.ring_slot(jnp.int32(t), sink, recent)) == t
    # after wrap: ring over [sink, sink+recent)
    assert int(A.ring_slot(jnp.int32(12), sink, recent)) == 4
    assert int(A.ring_slot(jnp.int32(19), sink, recent)) == 11
    assert int(A.ring_slot(jnp.int32(20), sink, recent)) == 4


@settings(max_examples=10, deadline=None)
@given(S=st.integers(8, 48), sink=st.sampled_from([0, 2, 4]),
       recent=st.sampled_from([4, 8, 16]))
def test_compress_prefill_matches_sequential_writes(S, sink, recent):
    """Compressed prefill cache == writing tokens one-by-one into the ring."""
    rng = jax.random.PRNGKey(S)
    k = jax.random.normal(rng, (1, S, 2, 8))
    v = k + 1
    kc, vc = A.compress_prefill_kv(k, v, sink=sink, recent=recent)
    W = sink + recent
    k_seq = jnp.zeros((1, W, 2, 8))
    v_seq = jnp.zeros((1, W, 2, 8))
    for t in range(S):
        k_seq, v_seq = A.cache_write(k_seq, v_seq, k[:, t], v[:, t],
                                     jnp.int32(t), sink=sink, recent=recent)
    occ = min(S, W)
    np.testing.assert_allclose(np.asarray(kc[:, :occ]),
                               np.asarray(k_seq[:, :occ]), rtol=1e-6)


def test_decode_attention_matches_dense(mesh1):
    rng = jax.random.PRNGKey(3)
    r1, r2, r3 = jax.random.split(rng, 3)
    B, W, H, K, h = 2, 32, 4, 2, 16
    q = jax.random.normal(r1, (B, H, h))
    kc = jax.random.normal(r2, (B, W, K, h))
    vc = jax.random.normal(r3, (B, W, K, h))
    t = jnp.int32(20)
    out = A.decode_attention(q, kc, vc, t, mesh=mesh1, strategy="kv")
    kr = jnp.repeat(kc[:, :20], 2, axis=2)
    vr = jnp.repeat(vc[:, :20], 2, axis=2)
    s = jnp.einsum("bhd,bwhd->bhw", q.astype(jnp.float32),
                   kr.astype(jnp.float32)) / np.sqrt(h)
    ref = jnp.einsum("bhw,bwhd->bhd", jax.nn.softmax(s, -1),
                     vr.astype(jnp.float32))
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-5,
                               atol=2e-5)
    # per-request t vector
    tv = jnp.array([20, 7])
    out_v = A.decode_attention(q, kc, vc, tv, mesh=mesh1, strategy="kv")
    np.testing.assert_allclose(np.asarray(out_v[0]), np.asarray(out[0]),
                               rtol=1e-6)
