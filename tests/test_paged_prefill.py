"""Paged prefill: chunk KV written straight into shared block arenas.

Covers the prefill-side completion of the paging subsystem: greedy
bit-equivalence of the paged path against the dense engines across chunk
sizes × block sizes × layer classes, zero-copy admission handoff, store
snapshots as refcounted block lists (with partial-tail copy-on-write),
pool backpressure (defer instead of over-commit), abort hygiene, and the
satellite accounting fixes (true-byte transfer metering, prefix-sized
store entries, unified pad-bucket floor)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import reduced_config
from repro.configs.base import OmniAttnConfig
from repro.distributed.ctx import local_mesh_ctx
from repro.models import LM
from repro.serving import (BlockHandoff, DecodeEngine, KVArena,
                           PrefillEngine)


@pytest.fixture(scope="module")
def full_stack():
    """Two full-attention layers (every KV block pool-managed)."""
    mesh = local_mesh_ctx()
    cfg = reduced_config("qwen2-1.5b").with_updates(
        compute_dtype="float32", param_dtype="float32", n_layers=2)
    lm = LM.build(cfg, mesh, pattern=[0, 0])
    return cfg, lm, lm.init(jax.random.PRNGKey(0))


@pytest.fixture(scope="module")
def mixed_stack():
    """Full + sliding-window + sink+recent-compressed attention layers:
    paged arenas for the full layers, dense per-task rings for the rest
    (prefill_sparse so chunked/compressed prefill is exact)."""
    mesh = local_mesh_ctx()
    cfg = reduced_config("qwen2-1.5b").with_updates(
        compute_dtype="float32", param_dtype="float32", n_layers=4,
        local_per_global=1, local_window=16, prefill_sparse=True,
        omniattn=OmniAttnConfig(sink_tokens=8, recent_tokens=24))
    lm = LM.build(cfg, mesh, pattern=[0, 0, 0, 1])
    specs = lm.plan.all_specs()
    assert any(s.window > 0 and not s.compressed for s in specs)
    assert any(s.compressed for s in specs)
    assert any(s.kind == "attn" and s.window == 0 and not s.compressed
               for s in specs)
    return cfg, lm, lm.init(jax.random.PRNGKey(1))


def _greedy_ref(lm, params, prompt, n, max_len=96):
    toks = jnp.asarray([list(prompt)], jnp.int32)
    cache, logits, _ = lm.prefill(params, {"tokens": toks}, max_len=max_len)
    out, pos = [], len(prompt)
    for i in range(n):
        nxt = int(jnp.argmax(logits[0]))
        out.append(nxt)
        if i == n - 1:
            break
        cache, logits, _ = lm.decode(params, cache, jnp.asarray([[nxt]]),
                                     jnp.int32(pos))
        pos += 1
    return out


def _drive(pe, de, prompts, hints, n_decode):
    """start+step every prompt through prefill (with snapshot hints), admit
    every handoff, decode n steps → {rid: [tokens]}."""
    outs = {}
    for rid, (p, hint) in enumerate(zip(prompts, hints)):
        pe.start(rid, p, prefix_hint=hint)
        recs = []
        while len(recs) == 0:
            recs = pe.step()
        (rec,) = recs
        assert rec.rid == rid
        assert de.admit(rid, rec.cache, rec.first_token, rec.prompt_len,
                        cached_tokens=rec.reused, prompt=p)
        outs[rid] = [rec.first_token]
    for _ in range(n_decode):
        for rid, t in de.step().items():
            outs[rid].append(t)
    return outs


@pytest.mark.parametrize("chunk", [8, 64])
@pytest.mark.parametrize("block_size", [8, 16])
@pytest.mark.parametrize("stack", ["full", "mixed"])
def test_paged_vs_dense_prefill_equivalence(chunk, block_size, stack,
                                            full_stack, mixed_stack):
    """Greedy bit-equivalence: paged prefill (chunk KV into shared arenas,
    zero-copy handoff, store snapshots as block lists) against the dense
    engines, over shared-prefix prompts that exercise snapshot-at-boundary
    AND store resume, across the chunk × block × layer-class matrix."""
    cfg, lm, params = full_stack if stack == "full" else mixed_stack
    rng = np.random.default_rng(7 + chunk + block_size)
    base = tuple(rng.integers(0, cfg.vocab_size, 24))
    prompts = [base + tuple(rng.integers(0, cfg.vocab_size, 9)),
               base + tuple(rng.integers(0, cfg.vocab_size, 14)),
               tuple(rng.integers(0, cfg.vocab_size, 11))]
    hints = [24, 24, 0]
    refs = [_greedy_ref(lm, params, p, 7) for p in prompts]

    pe_d = PrefillEngine(lm, params, None, max_len=96, chunk_tokens=chunk)
    de_d = DecodeEngine(lm, params, None, n_slots=4, max_len=96, paged=False)
    dense = _drive(pe_d, de_d, prompts, hints, 6)

    arena = KVArena.build(lm, n_blocks=64, block_size=block_size)
    pe_p = PrefillEngine(lm, params, None, max_len=96, chunk_tokens=chunk,
                         arena=arena)
    de_p = DecodeEngine(lm, params, None, n_slots=4, max_len=96,
                        block_size=block_size, arena=arena)
    assert pe_p.paged
    paged = _drive(pe_p, de_p, prompts, hints, 6)

    for rid in range(len(prompts)):
        assert paged[rid] == dense[rid] == refs[rid], f"request {rid}"
    # the sharers resumed at the snapshot boundary, mapping its full blocks
    assert pe_p.stats["prefix_hits"] >= 1
    assert pe_p.stats["blocks_mapped"] >= 24 // block_size
    # zero-copy handoff: no full-attention KV byte was copied at admission
    assert de_p.stats["handoff_copy_bytes"] == 0
    assert de_d.stats["handoff_copy_bytes"] > 0
    arena.pool.check_invariants()


def test_store_snapshot_blocks_and_tail_cow(full_stack):
    """A paged store entry holds REFCOUNTED blocks (zero-copy snapshot); a
    resume borrower maps the full prefix blocks and privately copies the
    partial tail block, so the original's later appends never leak into
    the borrower (and vice versa)."""
    cfg, lm, params = full_stack
    rng = np.random.default_rng(11)
    base = tuple(rng.integers(0, cfg.vocab_size, 20))     # 2.5 blocks @ bs=8
    p1 = base + tuple(rng.integers(0, cfg.vocab_size, 10))
    p2 = base + tuple(rng.integers(0, cfg.vocab_size, 13))
    ref2 = _greedy_ref(lm, params, p2, 6)

    arena = KVArena.build(lm, n_blocks=48, block_size=8)
    pe = PrefillEngine(lm, params, None, max_len=96, chunk_tokens=8,
                       arena=arena)
    de = DecodeEngine(lm, params, None, n_slots=4, max_len=96, arena=arena)
    pe.start(0, p1, prefix_hint=20)
    (r1,) = pe.step()
    assert isinstance(r1.cache, BlockHandoff)
    ent = pe.store.lookup_entry(base)
    assert ent is not None and ent.blocks is not None
    assert len(ent.blocks) == arena.pool.blocks_for(20)   # 3 (tail partial)
    # snapshot blocks are the task's own blocks, refcounted — not copies
    assert set(ent.blocks) <= set(r1.cache.blocks)
    assert de.admit(0, r1.cache, r1.first_token, len(p1), prompt=p1)

    pe.start(1, p2, prefix_hint=20)
    (r2,) = pe.step()
    assert pe.stats["prefix_hits"] == 1 and pe.stats["reused_tokens"] == 20
    # borrower maps the 2 FULL prefix blocks, owns a private tail copy
    assert r2.cache.blocks[:2] == ent.blocks[:2]
    assert r2.cache.blocks[2] != ent.blocks[2]
    for b in ent.blocks[:2]:
        assert arena.pool.refcount[b] >= 3    # store + p1 + p2
    assert de.admit(1, r2.cache, r2.first_token, len(p2), prompt=p2)
    outs = {1: [r2.first_token]}
    de.release(0)                             # original leaves mid-stream
    while len(outs[1]) < len(ref2):
        outs[1].append(de.step()[1])
    assert outs[1] == ref2
    arena.pool.check_invariants()


def test_backpressure_defers_instead_of_failing(full_stack):
    """Pool exhaustion must DEFER prefill (stats.defers, task stays queued)
    rather than raising or over-committing; freed blocks let it finish."""
    cfg, lm, params = full_stack
    rng = np.random.default_rng(13)
    p0 = tuple(rng.integers(0, cfg.vocab_size, 24))       # 3 blocks @ bs=8
    p1 = tuple(rng.integers(0, cfg.vocab_size, 24))
    arena = KVArena.build(lm, n_blocks=4, block_size=8)   # 32 tokens total
    pe = PrefillEngine(lm, params, None, max_len=64, chunk_tokens=8,
                      arena=arena)
    pe.start(0, p0)
    pe.start(1, p1)
    recs = pe.step()
    # p0 finished (its handoff + store snapshot pin 3 blocks); p1 cannot
    # grow past its first block and defers
    assert [r.rid for r in recs] == [0]
    assert pe.stats["defers"] >= 1
    assert any(t.rid == 1 for t in pe.queue)
    assert pe.step() == []                    # still parked, still no error
    arena.pool.check_invariants()
    # consumer releases the handoff (as decode would at request finish) —
    # the deferred task resumes and completes
    arena.pool.release(recs[0].cache.key)
    recs2 = pe.step()
    assert [r.rid for r in recs2] == [1]
    arena.pool.release(recs2[0].cache.key)
    arena.pool.check_invariants()


def test_resume_reclaim_cannot_free_entry_in_use(full_stack):
    """Regression: when a resume's block allocation triggers store reclaim,
    the LRU victim can be the very entry being resumed — its blocks must be
    pinned for the duration, or the retry maps freshly freed ids as
    'shared' and the pool hands the same block out twice (block both free
    and mapped). The resume falls back to scratch prefill instead."""
    cfg, lm, params = full_stack
    rng = np.random.default_rng(29)
    base = tuple(rng.integers(0, cfg.vocab_size, 20))     # 3 blocks @ bs=8
    sharer = base + tuple(rng.integers(0, cfg.vocab_size, 8))
    ref = _greedy_ref(lm, params, sharer, 4, max_len=64)
    arena = KVArena.build(lm, n_blocks=6, block_size=8)
    pe = PrefillEngine(lm, params, None, max_len=64, chunk_tokens=8,
                       arena=arena)
    pe.start(0, base)
    (r0,) = pe.step()
    arena.pool.release(r0.cache.key)          # only the store entry remains
    assert pe.store.lookup_entry(base) is not None
    blocker = arena.pool.allocate("blocker", 24)          # free_blocks → 0
    assert blocker is not None and arena.pool.free_blocks == 0
    pe.start(1, sharer)
    assert pe.step() == []                    # resume + scratch both defer
    arena.pool.check_invariants()             # ← corrupted before the fix
    # the entry was sacrificed to reclaim, but nothing was double-mapped
    assert pe.store.lookup_entry(base) is None
    arena.pool.release("blocker")
    recs = []
    while not recs:
        recs = pe.step()
    assert [r.rid for r in recs] == [1]
    de = DecodeEngine(lm, params, None, n_slots=2, max_len=64, arena=arena)
    assert de.admit(1, recs[0].cache, recs[0].first_token, len(sharer))
    outs = [recs[0].first_token]
    for _ in range(3):
        outs.append(de.step()[1])
    assert outs == ref
    arena.pool.check_invariants()


def test_abort_paged_prefill_releases_blocks(full_stack):
    """Abort mid-chunked-prefill and of a superseded task must release
    every prefill-phase block reservation (zero leaks)."""
    cfg, lm, params = full_stack
    rng = np.random.default_rng(17)
    prompt = tuple(rng.integers(0, cfg.vocab_size, 30))
    arena = KVArena.build(lm, n_blocks=32, block_size=8)
    pe = PrefillEngine(lm, params, None, max_len=96, chunk_tokens=8,
                       arena=arena)
    pe.start(0, prompt)
    assert pe.step(token_budget=8) == []      # one chunk: task half done
    assert ("prefill", 0) in arena.pool
    assert pe.abort(0)
    assert ("prefill", 0) not in arena.pool
    assert arena.pool.free_blocks == arena.pool.n_blocks
    arena.pool.check_invariants()

    # re-dispatch supersede: the old task's blocks must not leak either
    pe.start(1, prompt)
    pe.step(token_budget=8)
    pe.start(1, prompt)                       # instance fail/recover path
    recs = []
    while not recs:
        recs = pe.step()
    assert [r.rid for r in recs] == [1]
    held = [k for k in arena.pool.per_request
            if isinstance(k, tuple) and k[0] == "prefill"]
    assert held == []
    arena.pool.check_invariants()


def test_pending_handoff_abort_releases_blocks(full_stack):
    """A BlockHandoff parked outside the engines (the server's _pending_kv)
    owns its blocks under the handoff key; releasing it returns every
    non-store block to the pool."""
    cfg, lm, params = full_stack
    rng = np.random.default_rng(19)
    arena = KVArena.build(lm, n_blocks=32, block_size=8)
    pe = PrefillEngine(lm, params, None, max_len=96, chunk_tokens=8,
                       arena=arena)
    pe.start(0, tuple(rng.integers(0, cfg.vocab_size, 22)))
    (rec,) = pe.step()
    hb = rec.cache
    assert isinstance(hb, BlockHandoff) and hb.key in arena.pool
    arena.pool.release(hb.key)                # what Server.abort does
    assert hb.key not in arena.pool
    held = {k for k in arena.pool.per_request
            if not (isinstance(k, tuple) and k[0] == "store")}
    assert not held
    arena.pool.check_invariants()


def test_prefill_peak_blocks_proportional_to_prompt(full_stack):
    """The paged engine pins blocks ∝ prompt length; the dense engine pins
    blocks_for(max_len) per live task regardless (the over-commit the
    tentpole removes) — the bench column's contrast, asserted."""
    cfg, lm, params = full_stack
    rng = np.random.default_rng(23)
    prompt = tuple(rng.integers(0, cfg.vocab_size, 16))
    arena = KVArena.build(lm, n_blocks=64, block_size=8)
    pe_p = PrefillEngine(lm, params, None, max_len=256, chunk_tokens=8,
                         arena=arena)
    pe_p.start(0, prompt)
    (rec,) = pe_p.step()
    arena.pool.release(rec.cache.key)
    pe_d = PrefillEngine(lm, params, None, max_len=256, chunk_tokens=8,
                         block_size=8)
    pe_d.process(prompt)
    assert pe_p.stats["prefill_kv_peak_blocks"] == \
        arena.pool.blocks_for(len(prompt))                # 2 blocks
    assert pe_d.stats["prefill_kv_peak_blocks"] == \
        arena.pool.blocks_for(256)                        # 32 blocks
    assert pe_p.stats["prefill_kv_peak_blocks"] < \
        pe_d.stats["prefill_kv_peak_blocks"]


def test_kv_transfer_true_vs_padded_metering(full_stack):
    """Satellite: the PD transfer meter charges TRUE resident bytes, with
    the old padded figure reported alongside — a short prompt in a large
    dense cache no longer meters the padding."""
    cfg, lm, params = full_stack
    pe = PrefillEngine(lm, params, None, max_len=96, enable_chunked=False)
    de = DecodeEngine(lm, params, None, n_slots=2, max_len=96, paged=False)
    prompt = (5, 6, 7, 8, 9, 10, 11, 12)                  # 8 of 96 tokens
    cache, first, _ = pe.process(prompt)
    assert de.admit(0, cache, first, len(prompt))
    true_b = de.stats["kv_transfer_bytes"]
    padded_b = de.stats["kv_transfer_bytes_padded"]
    assert padded_b == de._dense_kv_nbytes
    # all-full-attention stack: true bytes are the 8 resident tokens' worth
    # (plus the bounded non-KV leaves — here just the position scalar) —
    # ~1/12th of the padded figure, not 1×
    bounded = padded_b - de._full_tok_nbytes * 96
    assert true_b == de._full_tok_nbytes * len(prompt) + bounded
    assert true_b * 10 < padded_b


def test_run_full_pad_bucket_floor(full_stack):
    """Satellite: the unchunked path buckets with the same lo=8 floor as
    the chunked path — a 9-token prompt pads to 16, not 32."""
    cfg, lm, params = full_stack
    pe = PrefillEngine(lm, params, None, max_len=96, enable_chunked=False)
    shapes = []
    orig = pe._fn
    pe._fn = lambda p, toks, tl, tb: (shapes.append(toks.shape),
                                      orig(p, toks, tl, tb))[1]
    pe.process(tuple(range(1, 10)))
    assert shapes == [(1, 16)]


def test_dense_store_entries_prefix_sized(full_stack):
    """Satellite: dense store entries hold prefix-length KV and weigh their
    REAL bytes — LRU under a byte cap can tell a short prefix from a long
    one (uniform max_len sizing could not)."""
    cfg, lm, params = full_stack
    pe = PrefillEngine(lm, params, None, max_len=96, chunk_tokens=16)
    short = tuple(np.random.default_rng(3).integers(0, cfg.vocab_size, 9))
    long_ = tuple(np.random.default_rng(4).integers(0, cfg.vocab_size, 64))
    pe.process(short)
    pe.process(long_)
    ents = {e.n: e for e in pe.store.entries.values()}
    assert set(ents) == {9, 64}
    assert 0 < ents[9].nbytes < ents[64].nbytes
    # full-attn KV is trimmed to the pow2 bucket of the prefix, so the
    # short entry weighs ~16/64ths of the long one, not 96/96
    assert ents[9].nbytes * 3 < ents[64].nbytes
    # resume from a trimmed entry still reproduces the reference stream
    ref = _greedy_ref(lm, params, long_ + (7, 8), 4)
    de = DecodeEngine(lm, params, None, n_slots=2, max_len=96, paged=False)
    cache, first, _ = pe.process(long_ + (7, 8))
    assert pe.stats["prefix_hits"] == 1
    assert de.admit(0, cache, first, len(long_) + 2)
    outs = [first]
    for _ in range(3):
        outs.append(de.step()[0])
    assert outs == ref


def test_store_byte_cap_evicts_lru(full_stack):
    """capacity_bytes caps the store by real resident bytes."""
    cfg, lm, params = full_stack
    pe = PrefillEngine(lm, params, None, max_len=96, chunk_tokens=16)
    pe.process(tuple(range(30, 94)))          # 64-token entry
    big = next(iter(pe.store.entries.values())).nbytes
    pe.store.capacity_bytes = int(big * 1.5)
    pe.process(tuple(range(200, 264)))        # second big entry → evict LRU
    assert len(pe.store.entries) == 1
    assert next(iter(pe.store.entries.values())).n == 64
    assert pe.store.size_bytes <= pe.store.capacity_bytes
