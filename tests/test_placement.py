"""OmniPlacement invariants (paper eq. 1-4) — property-based."""
import numpy as np
import pytest
pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.placement import (
    DynamicScheduler, SchedulerConfig, calculate_imbalance, plan_migration,
    static_expert_placement,
)
from repro.core.placement.static import determine_replicas, round_robin
from repro.models.moe import tables_from_placement


@settings(max_examples=25, deadline=None)
@given(E=st.sampled_from([8, 16, 60, 128]),
       ep=st.sampled_from([2, 4, 16]),
       budget=st.integers(0, 8),
       seed=st.integers(0, 10_000))
def test_static_placement_constraints(E, ep, budget, seed):
    rng = np.random.default_rng(seed)
    D = rng.lognormal(0, 1.0, (3, E))
    placements, s = static_expert_placement(D, ep=ep, budget=budget,
                                            max_slots=int(np.ceil(E / ep)) + 3)
    for l, p in enumerate(placements):
        # eq.1 availability: every expert on ≥ 1 device
        assert (p.sum(axis=0) >= 1).all()
        # eq.2 capacity: ≤ s_l slots per device
        assert (p.sum(axis=1) <= s[l]).all()
        # binary
        assert set(np.unique(p)).issubset({0, 1})


@settings(max_examples=25, deadline=None)
@given(E=st.sampled_from([16, 60, 128]), seed=st.integers(0, 10_000))
def test_placement_beats_round_robin(E, seed):
    """The optimized placement should (weakly) beat naive round-robin."""
    rng = np.random.default_rng(seed)
    ep = 8
    D = rng.lognormal(0, 1.2, (1, E))
    n_slots = int(np.ceil(E / ep)) + 2
    placements, _ = static_expert_placement(D, ep=ep, budget=2,
                                            max_slots=n_slots)
    b_opt = calculate_imbalance(placements[0], D[0])
    b_rr = calculate_imbalance(round_robin(E, ep, int(np.ceil(E / ep))), D[0])
    assert b_opt <= b_rr * 1.05


def test_determine_replicas_budget():
    loads = np.array([100.0, 10, 5, 1, 1, 1, 1, 1])
    counts = determine_replicas(loads, extra_slots=4, ep=4, n_slots=3)
    assert counts.sum() <= 12
    assert counts[0] >= 2                  # hottest expert replicated first
    assert (counts >= 1).all()


def test_tables_from_placement_invariants():
    placement = np.array([[1, 1, 0, 0], [0, 1, 1, 0], [0, 0, 0, 1]],
                         dtype=np.int8)
    t = tables_from_placement(placement, n_slots=2)
    n_rep = np.asarray(t["n_rep"])
    assert list(n_rep) == [1, 2, 1, 1]
    se = np.asarray(t["slot_expert"])
    # every replica entry points at a slot that actually hosts the expert
    rr, rs = np.asarray(t["rep_rank"]), np.asarray(t["rep_slot"])
    for e in range(4):
        for i in range(rr.shape[1]):
            assert se[rr[e, i], rs[e, i]] == e


def test_overfull_rank_raises():
    placement = np.ones((2, 5), dtype=np.int8)
    with pytest.raises(ValueError):
        tables_from_placement(placement, n_slots=2)


# ----------------------------------------------------------------------
def test_dynamic_scheduler_rebalances_on_shift():
    rng = np.random.default_rng(0)
    E, ep, L = 32, 4, 2
    n_slots = E // ep + 2
    sched = DynamicScheduler(
        ep=ep, n_experts=E, n_layers=L,
        cfg=SchedulerConfig(b_trigger=1.15, delta=0.02, budget=4,
                            max_slots=n_slots),
        placements=[round_robin(E, ep, E // ep) for _ in range(L)])
    flat = np.ones((L, E))
    for _ in range(3):
        sched.step(flat)
    assert sched.n_rebalances == 0         # balanced load: no churn
    skew = flat.copy()
    skew[:, :2] = 60.0                     # two hot experts
    plans = None
    for _ in range(6):
        p = sched.step(skew)
        plans = p or plans
    assert sched.n_rebalances >= 1
    assert plans is not None and any(pl.n_moves > 0 for pl in plans)
    assert sched.current_imbalance() < 2.0


def test_migration_plan_consistency():
    old = round_robin(16, 4, 4)
    rng = np.random.default_rng(1)
    D = rng.lognormal(0, 1.5, (1, 16))
    new, _ = static_expert_placement(D, ep=4, budget=2, max_slots=5,
                                     prev=[old])
    plan = plan_migration(old, new[0], n_slots=5)
    # every move lands the expert the new table claims
    for r, s, e in plan.moves:
        assert plan.new_slot_expert[r, s] == e
    # unchanged slots are not moved
    same = (plan.new_slot_expert == plan.old_slot_expert)
    moved = np.zeros_like(same)
    for r, s, _ in plan.moves:
        moved[r, s] = True
    assert not (same & moved).any()


def test_prediction_follows_trend():
    sched = DynamicScheduler(ep=4, n_experts=8, n_layers=1,
                             cfg=SchedulerConfig(window=8))
    for i in range(8):
        sched.step(np.full((1, 8), 1.0 + i))
    pred = sched.predict_future_activations()
    assert pred.mean() > sched._ema.mean()   # rising trend extrapolated up
