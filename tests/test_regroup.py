"""Param regrouping between stack periodizations (serving under a different
OmniAttn pattern than the params were built with) must preserve weights and
model outputs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import reduced_config
from repro.models import LM
from repro.models.stack import StackPlan, regroup_params, restack_params, unstack_params


def test_unstack_restack_roundtrip(mesh1):
    cfg = reduced_config("qwen2-1.5b").with_updates(n_layers=8)
    lm = LM.build(cfg, mesh1, pattern=[0] * 8)
    params = lm.init(jax.random.PRNGKey(0))
    layers = unstack_params(lm.plan, params["stack"])
    assert len(layers) == 8
    back = restack_params(lm.plan, layers)
    for a, b in zip(jax.tree.leaves(params["stack"]), jax.tree.leaves(back)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_regroup_preserves_layer_order(mesh1):
    """Same logits under a different periodization of the same weights."""
    cfg = reduced_config("qwen2-1.5b").with_updates(
        n_layers=8, compute_dtype="float32", param_dtype="float32")
    lm0 = LM.build(cfg, mesh1, pattern=[0] * 8)          # period 1 × 8
    lm1 = LM.build(cfg, mesh1, pattern=[1, 1, 0, 0] * 2)  # period 4 × 2
    assert lm0.plan != lm1.plan
    params = lm0.init(jax.random.PRNGKey(0))
    re = dict(params, stack=regroup_params(params["stack"], lm0.plan, lm1.plan))
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, 16), 0,
                              cfg.vocab_size)
    # short prompt (< sink+recent) → compressed and full caches agree,
    # so logits must match across periodizations
    _, l0, _ = lm0.prefill(params, {"tokens": toks}, max_len=24)
    _, l1, _ = lm1.prefill(re, {"tokens": toks}, max_len=24)
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l0), rtol=2e-4,
                               atol=2e-4)


def test_regroup_rejects_layer_mismatch(mesh1):
    cfg8 = reduced_config("qwen2-1.5b").with_updates(n_layers=8)
    cfg4 = reduced_config("qwen2-1.5b").with_updates(n_layers=4)
    lm8 = LM.build(cfg8, mesh1, pattern=[0] * 8)
    lm4 = LM.build(cfg4, mesh1, pattern=[0] * 4)
    params = lm8.init(jax.random.PRNGKey(0))
    with pytest.raises(ValueError):
        regroup_params(params["stack"], lm8.plan, lm4.plan)
