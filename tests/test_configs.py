"""Config registry: all 10 assigned architectures + periodization invariants."""
import numpy as np
import pytest

from repro.configs import ARCH_IDS, SHAPES, get_config, reduced_config

EXPECTED = {
    "qwen2-1.5b": dict(n_layers=28, d_model=1536, n_heads=12, n_kv_heads=2,
                       d_ff=8960, vocab_size=151936),
    "qwen3-32b": dict(n_layers=64, d_model=5120, n_heads=64, n_kv_heads=8,
                      d_ff=25600, vocab_size=151936),
    "gemma3-4b": dict(n_layers=34, d_model=2560, n_heads=8, n_kv_heads=4,
                      d_ff=10240, vocab_size=262144),
    "granite-34b": dict(n_layers=88, d_model=6144, n_heads=48, n_kv_heads=1,
                        d_ff=24576, vocab_size=49152),
    "jamba-1.5-large-398b": dict(n_layers=72, d_model=8192, n_heads=64,
                                 n_kv_heads=8, d_ff=24576, vocab_size=65536),
    "qwen2-moe-a2.7b": dict(n_layers=24, d_model=2048, n_heads=16,
                            n_kv_heads=16, d_ff=1408, vocab_size=151936),
    "qwen3-moe-235b-a22b": dict(n_layers=94, d_model=4096, n_heads=64,
                                n_kv_heads=4, d_ff=1536, vocab_size=151936),
    "mamba2-130m": dict(n_layers=24, d_model=768, vocab_size=50280),
    "phi-3-vision-4.2b": dict(n_layers=32, d_model=3072, n_heads=32,
                              n_kv_heads=32, d_ff=8192, vocab_size=32064),
    "hubert-xlarge": dict(n_layers=48, d_model=1280, n_heads=16,
                          n_kv_heads=16, d_ff=5120, vocab_size=504),
}


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_config_matches_assignment(arch):
    cfg = get_config(arch)
    for k, v in EXPECTED[arch].items():
        assert getattr(cfg, k) == v, (arch, k)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_periodize_reconstructs_stack(arch):
    cfg = get_config(arch)
    specs = cfg.layer_specs(cfg.default_compression_pattern())
    period, n_rep, rem = cfg.periodize(specs)
    assert list(period) * n_rep + list(rem) == specs
    assert len(period) * n_rep + len(rem) == cfg.n_layers


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_moe_experts_counts(arch):
    cfg = get_config(arch)
    if arch == "qwen2-moe-a2.7b":
        assert cfg.moe.n_experts == 60 and cfg.moe.top_k == 4
        assert cfg.moe.n_shared_experts == 4
    if arch == "qwen3-moe-235b-a22b":
        assert cfg.moe.n_experts == 128 and cfg.moe.top_k == 8
    if arch == "jamba-1.5-large-398b":
        assert cfg.moe.n_experts == 16 and cfg.moe.top_k == 2
        # 1:7 attention:mamba interleave
        specs = cfg.layer_specs()
        attn = sum(1 for s in specs if s.kind == "attn")
        assert attn == cfg.n_layers // 8


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_param_count_scale(arch):
    """n_params within 35% of the size implied by the arch name."""
    sizes = {"qwen2-1.5b": 1.5e9, "qwen3-32b": 32e9, "gemma3-4b": 4e9,
             "granite-34b": 34e9, "jamba-1.5-large-398b": 398e9,
             "qwen2-moe-a2.7b": 14e9,       # A2.7B = *active* 2.7B, total ~14B
             "qwen3-moe-235b-a22b": 235e9, "mamba2-130m": 130e6,
             "phi-3-vision-4.2b": 4.2e9, "hubert-xlarge": 1e9}
    n = get_config(arch).n_params()
    assert 0.65 * sizes[arch] <= n <= 1.5 * sizes[arch], n


def test_active_params_moe():
    cfg = get_config("qwen3-moe-235b-a22b")
    assert 15e9 < cfg.n_active_params() < 30e9   # A22B
    dense = get_config("qwen3-32b")
    assert dense.n_active_params() == dense.n_params()


def test_shapes_table():
    assert SHAPES["train_4k"].seq_len == 4096
    assert SHAPES["train_4k"].global_batch == 256
    assert SHAPES["prefill_32k"].global_batch == 32
    assert SHAPES["decode_32k"].global_batch == 128
    assert SHAPES["long_500k"].seq_len == 524288


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_reduced_config_small(arch):
    cfg = reduced_config(arch)
    assert cfg.n_layers <= 16 and cfg.d_model <= 128
    assert cfg.vocab_size <= 512
