"""Mesh parity: a tp=2, ep=4 server over 8 host devices must be
observationally identical to the 1-device server — bit-equal greedy token
streams (the placement layer's contract) with every serving invariant
(KVPool bookkeeping, zero-stale-summary, one host fetch per decode step)
holding on both meshes, through forced preemption, prefix snapshot/resume,
and a live OmniPlacement expert migration mid-decode.

Run with XLA_FLAGS=--xla_force_host_platform_device_count=8 (the CI
multi-device job does); skipped when fewer than 8 devices are visible.
"""
import jax
import numpy as np
import pytest

from repro.configs import reduced_config
from repro.core.proxy import OASConfig
from repro.core.placement import SchedulerConfig
from repro.models import LM
from repro.serving import DevicePlacement, Server, ServerConfig

pytestmark = pytest.mark.skipif(
    jax.device_count() < 8,
    reason="needs XLA_FLAGS=--xla_force_host_platform_device_count=8")

TP, EP = 2, 4


@pytest.fixture(scope="module")
def moe_setup():
    """One parameter set, authored on the 1-device mesh; the 8-device server
    receives it through DevicePlacement.transfer_params (expert slot tensors
    re-gathered from canonical rows for the wider EP layout)."""
    cfg = reduced_config("qwen2-moe-a2.7b").with_updates(
        compute_dtype="float32", param_dtype="float32")
    pl1 = DevicePlacement.local()
    lm1 = LM.build(cfg, pl1.ctx)
    params1 = lm1.init(jax.random.PRNGKey(0))
    return cfg, pl1, lm1, params1


def _requests(cfg, n=4, seed=11, max_tokens=8):
    rng = np.random.default_rng(seed)
    base = tuple(rng.integers(0, cfg.vocab_size, 12).tolist())
    reqs = []
    for i in range(n):
        if i % 2 == 0:  # shared prefix → exercises snapshot/resume
            p = base + tuple(rng.integers(0, cfg.vocab_size, 5 + i).tolist())
        else:
            p = tuple(rng.integers(0, cfg.vocab_size,
                                   int(rng.integers(8, 24))).tolist())
        reqs.append((p, max_tokens))
    return reqs


def _server_for(moe_setup, scfg, mesh8: bool):
    cfg, pl1, lm1, params1 = moe_setup
    if not mesh8:
        return Server(cfg, scfg, placement=pl1, params=params1)
    pl8 = DevicePlacement.build(tp=TP, ep=EP)
    lm8 = LM.build(cfg, pl8.ctx)
    params8 = pl8.transfer_params(lm1, params1, lm8)
    return Server(cfg, scfg, placement=pl8, params=params8)


def _run(srv, reqs):
    s = srv.run(reqs, max_wall_s=300)
    assert s["n_done"] == len(reqs)
    outs = {r.rid: tuple(r.output_tokens) for r in srv.metrics.done}
    for eng in srv.decodes:
        eng.pool.check_invariants()
        assert eng.stats["host_fetches"] == eng.stats["steps"]
    if srv.kv_arena is not None:
        srv.kv_arena.check_summaries()
        srv.kv_arena.pool.check_invariants()
    return s, outs


@pytest.mark.parametrize("block_size", [8, 16])
def test_greedy_bit_parity(moe_setup, block_size):
    """Same prompts, same weights → bit-equal greedy streams on the two
    meshes, across KV block sizes, with prefix reuse + chunked prefill on."""
    cfg = moe_setup[0]

    def scfg():
        return ServerConfig(n_prefill=1, n_decode=1, decode_slots=4,
                            max_len=96, kv_block_size=block_size,
                            chunk_tokens=16, enable_placement=False,
                            oas=OASConfig(defer_window=0.0))

    reqs = _requests(cfg)
    _, outs1 = _run(_server_for(moe_setup, scfg(), mesh8=False), reqs)
    _, outs8 = _run(_server_for(moe_setup, scfg(), mesh8=True), reqs)
    assert outs1 == outs8
    assert all(len(v) == 8 for v in outs8.values())


def test_parity_under_forced_preemption(moe_setup):
    """A starved KV pool forces preemption + re-admission mid-stream; the
    8-device mesh must recover to the same tokens as the 1-device mesh."""
    cfg = moe_setup[0]

    def scfg(kv_blocks):
        return ServerConfig(n_prefill=1, n_decode=1, decode_slots=4,
                            max_len=96, kv_block_size=8, kv_blocks=kv_blocks,
                            enable_placement=False,
                            oas=OASConfig(defer_window=0.0))

    rng = np.random.default_rng(23)
    reqs = [(tuple(rng.integers(0, cfg.vocab_size, 14).tolist()), 8)
            for _ in range(2)]
    _, outs_free = _run(_server_for(moe_setup, scfg(None), mesh8=False), reqs)
    s8, outs8 = _run(_server_for(moe_setup, scfg(5), mesh8=True), reqs)
    assert s8["decode_stats"][0]["preemptions"] >= 1
    assert outs8 == outs_free


def test_live_migration_parity_mid_decode(moe_setup):
    """An aggressive DynamicScheduler fires a real expert-weight migration
    while decode slots are live on the sharded mesh; the donated remap jit
    must preserve the greedy streams (vs. the never-migrating 1-device
    baseline) while the placement loop logs an imbalance drop."""
    cfg = moe_setup[0]

    def scfg(enable):
        pcfg = SchedulerConfig(b_trigger=1.01, delta=0.0, window=2,
                               ema_alpha=1.0, budget=0) if enable else None
        return ServerConfig(n_prefill=1, n_decode=1, decode_slots=4,
                            max_len=128, kv_block_size=8,
                            enable_placement=enable, placement_interval=2,
                            placement_cfg=pcfg,
                            oas=OASConfig(defer_window=0.0))

    reqs = _requests(cfg, n=4, seed=5, max_tokens=24)
    _, outs1 = _run(_server_for(moe_setup, scfg(False), mesh8=False), reqs)
    srv8 = _server_for(moe_setup, scfg(True), mesh8=True)
    s8, outs8 = _run(srv8, reqs)
    assert s8["n_migrations"] >= 1, \
        "scheduler never migrated — skew/trigger config no longer fires"
    assert outs8 == outs1
    for entry in s8["migration_log"]:
        assert entry["b_after"] < entry["b_before"]
