"""Pallas kernels vs ref.py oracles: shape/dtype sweeps (interpret=True)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.block_topk import block_topk_scores
from repro.kernels.flash_prefill import flash_prefill
from repro.kernels.moe_gmm import moe_gmm
from repro.kernels.paged_decode import paged_decode
from repro.kernels.paged_prefill import paged_prefill
from repro.kernels.sink_decode import sink_decode
from repro.kernels.spec_verify import spec_verify

TOL = {jnp.float32: dict(rtol=2e-5, atol=2e-5),
       jnp.bfloat16: dict(rtol=2e-2, atol=2e-2)}


@pytest.mark.parametrize("S", [64, 128, 256])
@pytest.mark.parametrize("h", [32, 64])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("kw", [dict(causal=True), dict(causal=False),
                                dict(causal=True, window=32),
                                dict(causal=True, window=32, sink=8)])
def test_flash_prefill_sweep(S, h, dtype, kw):
    rng = jax.random.PRNGKey(S + h)
    r = jax.random.split(rng, 3)
    BH = 3
    q = jax.random.normal(r[0], (BH, S, h), dtype)
    k = jax.random.normal(r[1], (BH, S, h), dtype)
    v = jax.random.normal(r[2], (BH, S, h), dtype)
    out = flash_prefill(q, k, v, block_q=64, block_k=64, interpret=True, **kw)
    want = ref.flash_prefill_ref(q, k, v, **kw)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), **TOL[dtype])


@pytest.mark.parametrize("W,bw", [(64, 16), (128, 64), (96, 32)])
@pytest.mark.parametrize("G", [1, 4])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_sink_decode_sweep(W, bw, G, dtype):
    rng = jax.random.PRNGKey(W + G)
    r = jax.random.split(rng, 4)
    B, K, h = 2, 2, 32
    q = jax.random.normal(r[0], (B, K, G, h), dtype)
    kc = jax.random.normal(r[1], (B, K, W, h), dtype)
    vc = jax.random.normal(r[2], (B, K, W, h), dtype)
    t = jnp.array([W // 3, W])
    out = sink_decode(q, kc, vc, t, block_w=bw, interpret=True)
    want = ref.sink_decode_ref(q, kc, vc, t)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), **TOL[dtype])


def test_sink_decode_occupancy_zero():
    """t=1 (single occupied slot) must equal attending to just slot 0."""
    rng = jax.random.PRNGKey(0)
    r = jax.random.split(rng, 3)
    q = jax.random.normal(r[0], (1, 1, 2, 16))
    kc = jax.random.normal(r[1], (1, 1, 32, 16))
    vc = jax.random.normal(r[2], (1, 1, 32, 16))
    out = sink_decode(q, kc, vc, jnp.array([1]), block_w=8, interpret=True)
    np.testing.assert_allclose(np.asarray(out[0, 0]),
                               np.asarray(vc[0, 0, 0][None].repeat(2, 0)),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("bs,nb", [(8, 6), (16, 4), (16, 1)])
@pytest.mark.parametrize("G", [1, 4])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_paged_decode_sweep(bs, nb, G, dtype):
    """Block-table gather + online softmax vs the linear-gather oracle,
    including partial tail blocks and per-sequence lens."""
    rng = jax.random.PRNGKey(bs * nb + G)
    r = jax.random.split(rng, 4)
    B, K, h, N = 3, 2, 32, 24
    q = jax.random.normal(r[0], (B, K, G, h), dtype)
    kp = jax.random.normal(r[1], (N, K, bs, h), dtype)
    vp = jax.random.normal(r[2], (N, K, bs, h), dtype)
    tables = jax.random.randint(r[3], (B, nb), 1, N)
    # lens: one token, a mid-block tail, and fully resident
    lens = jnp.array([1, max(nb * bs // 2 - 3, 1), nb * bs])
    out = paged_decode(q, kp, vp, tables, lens, interpret=True)
    want = ref.paged_decode_ref(q, kp, vp, tables, lens)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), **TOL[dtype])


def test_paged_decode_null_blocks_masked():
    """Table entries past the resident count point at the null block (id 0);
    its content must never leak into the output."""
    rng = jax.random.PRNGKey(1)
    r = jax.random.split(rng, 3)
    B, K, G, h, bs, N = 1, 1, 2, 16, 8, 6
    q = jax.random.normal(r[0], (B, K, G, h))
    kp = jax.random.normal(r[1], (N, K, bs, h))
    vp = jax.random.normal(r[2], (N, K, bs, h))
    kp = kp.at[0].set(1e4)          # poisoned null block
    vp = vp.at[0].set(1e4)
    tables = jnp.array([[3, 0, 0]])            # only block 0 logical resident
    lens = jnp.array([bs])
    out = paged_decode(q, kp, vp, tables, lens, interpret=True)
    want = ref.sink_decode_ref(q, kp[jnp.array([3])], vp[jnp.array([3])], lens)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-5, atol=1e-5)
    assert np.all(np.isfinite(np.asarray(out)))


def test_paged_vs_sink_decode_linear_tables():
    """With an identity block table the paged kernel must reproduce
    sink_decode exactly (same occupancy semantics)."""
    rng = jax.random.PRNGKey(2)
    r = jax.random.split(rng, 3)
    B, K, G, h, bs = 2, 2, 2, 32, 16
    nb = 4
    W = nb * bs
    q = jax.random.normal(r[0], (B, K, G, h))
    kc = jax.random.normal(r[1], (B, K, W, h))
    vc = jax.random.normal(r[2], (B, K, W, h))
    # arena: batch-major linear layout, identity tables per sequence
    kp = kc.reshape(B, K, nb, bs, h).transpose(0, 2, 1, 3, 4).reshape(
        B * nb, K, bs, h)
    vp = vc.reshape(B, K, nb, bs, h).transpose(0, 2, 1, 3, 4).reshape(
        B * nb, K, bs, h)
    tables = jnp.arange(B * nb, dtype=jnp.int32).reshape(B, nb)
    t = jnp.array([W // 3, W])
    out = paged_decode(q, kp, vp, tables, t, interpret=True)
    want = sink_decode(q, kc, vc, t, block_w=bs, interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("bs,S", [(8, 8), (16, 8), (8, 32)])
@pytest.mark.parametrize("G", [1, 4])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("kw", [dict(), dict(window=24),
                                dict(window=24, sink=8)])
def test_paged_prefill_sweep(bs, S, G, dtype, kw):
    """Chunked prefill over paged history vs the linear-gather oracle:
    resident-history masking (incl. mid-block off), causal in-chunk keys,
    padded chunk rows, and the sink+window sparse mask."""
    rng = jax.random.PRNGKey(bs + S * G)
    r = jax.random.split(rng, 6)
    B, K, h, N, nb = 2, 2, 32, 24, 5
    q = jax.random.normal(r[0], (B, K, S * G, h), dtype)
    kn = jax.random.normal(r[1], (B, K, S, h), dtype)
    vn = jax.random.normal(r[2], (B, K, S, h), dtype)
    kp = jax.random.normal(r[3], (N, K, bs, h), dtype)
    vp = jax.random.normal(r[4], (N, K, bs, h), dtype)
    tables = jax.random.randint(r[5], (B, nb), 1, N)
    # histories: empty (first chunk) and a mid-block boundary
    off = jnp.array([0, nb * bs // 2 - 3], jnp.int32)
    cl = jnp.array([S, max(S - 3, 1)], jnp.int32)
    out = paged_prefill(q, kn, vn, kp, vp, tables, off, cl,
                        interpret=True, **kw)
    want = ref.paged_prefill_ref(q, kn, vn, kp, vp, tables, off, cl, **kw)
    got = np.asarray(out, np.float32)
    exp = np.asarray(want, np.float32)
    # padded chunk rows (token index >= cl) are garbage by contract on both
    # sides — compare real rows only
    for b in range(B):
        real = int(cl[b]) * G
        np.testing.assert_allclose(got[b, :, :real], exp[b, :, :real],
                                   **TOL[dtype])


def test_paged_prefill_fallback_matches_ref():
    """models/attention.py jnp fallback (model layout) vs the kernel oracle
    (kv-head-major layout) on a GQA case with mid-block history."""
    from repro.models.attention import paged_prefill_attention
    rng = jax.random.PRNGKey(9)
    r = jax.random.split(rng, 6)
    B, S, K, G, h, bs, N, nb = 1, 8, 2, 3, 16, 8, 12, 4
    H = K * G
    q = jax.random.normal(r[0], (B, S, H, h))
    kn = jax.random.normal(r[1], (B, S, K, h))
    vn = jax.random.normal(r[2], (B, S, K, h))
    kp = jax.random.normal(r[3], (N, K, bs, h))
    vp = jax.random.normal(r[4], (N, K, bs, h))
    tables = jax.random.randint(r[5], (B, nb), 1, N)
    off, cl = jnp.array([13]), jnp.array([6])
    out = paged_prefill_attention(q, kn, vn, kp, vp, tables, off, cl)
    qf = q.reshape(B, S, K, G, h).transpose(0, 2, 1, 3, 4) \
        .reshape(B, K, S * G, h)
    want = ref.paged_prefill_ref(qf, kn.transpose(0, 2, 1, 3),
                                 vn.transpose(0, 2, 1, 3), kp, vp, tables,
                                 off, cl)
    want = want.reshape(B, K, S, G, h).transpose(0, 2, 1, 3, 4) \
        .reshape(B, S, H, h)
    np.testing.assert_allclose(np.asarray(out[:, :6]),
                               np.asarray(want[:, :6]), rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("bs,S", [(8, 4), (16, 5), (8, 2)])
@pytest.mark.parametrize("G", [1, 4])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_spec_verify_sweep(bs, S, G, dtype):
    """Batched speculative-verify window (S = k+1 rows per slot) vs the
    chunked-prefill oracle: per-slot history offsets covering empty,
    mid-block, and fully-resident histories; padded draft rows; causal
    in-window keys."""
    rng = jax.random.PRNGKey(bs * S + G)
    r = jax.random.split(rng, 6)
    B, K, h, N, nb = 3, 2, 32, 20, 4
    q = jax.random.normal(r[0], (B, K, S * G, h), dtype)
    kn = jax.random.normal(r[1], (B, K, S, h), dtype)
    vn = jax.random.normal(r[2], (B, K, S, h), dtype)
    kp = jax.random.normal(r[3], (N, K, bs, h), dtype)
    vp = jax.random.normal(r[4], (N, K, bs, h), dtype)
    tables = jax.random.randint(r[5], (B, nb), 1, N)
    off = jnp.array([0, bs + bs // 2 - 1, nb * bs], jnp.int32)
    cl = jnp.array([S, max(S - 2, 1), 1], jnp.int32)
    out = spec_verify(q, kn, vn, kp, vp, tables, off, cl, interpret=True)
    want = ref.spec_verify_ref(q, kn, vn, kp, vp, tables, off, cl)
    got = np.asarray(out, np.float32)
    exp = np.asarray(want, np.float32)
    # padded window rows (token index >= cl) are garbage by contract on
    # both sides — compare real rows only
    for b in range(B):
        real = int(cl[b]) * G
        np.testing.assert_allclose(got[b, :, :real], exp[b, :, :real],
                                   **TOL[dtype])


def test_spec_verify_null_blocks_masked():
    """Table entries at or past the residency point alias the null block
    (id 0); its poisoned content must never leak into verify outputs."""
    rng = jax.random.PRNGKey(4)
    r = jax.random.split(rng, 5)
    B, K, G, h, bs, N, S = 1, 1, 2, 16, 8, 6, 3
    q = jax.random.normal(r[0], (B, K, S * G, h))
    kn = jax.random.normal(r[1], (B, K, S, h))
    vn = jax.random.normal(r[2], (B, K, S, h))
    kp = jax.random.normal(r[3], (N, K, bs, h)).at[0].set(1e4)
    vp = jax.random.normal(r[4], (N, K, bs, h)).at[0].set(1e4)
    tables = jnp.array([[3, 0, 0]])             # 1 resident history block
    off, cl = jnp.array([bs]), jnp.array([S])
    out = spec_verify(q, kn, vn, kp, vp, tables, off, cl, interpret=True)
    want = ref.spec_verify_ref(q, kn, vn, kp, vp, tables, off, cl)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-5, atol=1e-5)
    assert np.all(np.isfinite(np.asarray(out)))


def test_spec_verify_adapter_matches_ref():
    """ops layout adapter (model [B,S,H,h] layout, GQA regroup) vs the
    kernel oracle on a mixed empty/mid-block history batch."""
    rng = jax.random.PRNGKey(21)
    r = jax.random.split(rng, 6)
    B, S, K, G, h, bs, N, nb = 2, 4, 2, 3, 16, 8, 12, 3
    H = K * G
    q = jax.random.normal(r[0], (B, S, H, h))
    kn = jax.random.normal(r[1], (B, S, K, h))
    vn = jax.random.normal(r[2], (B, S, K, h))
    kp = jax.random.normal(r[3], (N, K, bs, h))
    vp = jax.random.normal(r[4], (N, K, bs, h))
    tables = jax.random.randint(r[5], (B, nb), 1, N)
    off, cl = jnp.array([0, 13]), jnp.array([S, 3])
    got = ops.spec_verify_op(q, kn, vn, kp, vp, tables, off, cl)
    qf = q.reshape(B, S, K, G, h).transpose(0, 2, 1, 3, 4) \
        .reshape(B, K, S * G, h)
    want = ref.spec_verify_ref(qf, kn.transpose(0, 2, 1, 3),
                               vn.transpose(0, 2, 1, 3), kp, vp, tables,
                               off, cl)
    want = want.reshape(B, K, S, G, h).transpose(0, 2, 1, 3, 4) \
        .reshape(B, S, H, h)
    for b in range(B):
        real = int(cl[b])
        np.testing.assert_allclose(np.asarray(got[b, :real]),
                                   np.asarray(want[b, :real]),
                                   rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("bs,nb", [(8, 4), (16, 3), (8, 8)])
@pytest.mark.parametrize("G", [1, 3])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_block_topk_sweep(bs, nb, G, dtype):
    """block_topk scoring vs the jnp oracle across block sizes, GQA group
    widths and dtypes, with per-sequence lens covering a single block, a
    mid-block tail, and full residency."""
    rng = jax.random.PRNGKey(3 * bs + nb + G)
    r = jax.random.split(rng, 4)
    B, K, h, N = 3, 2, 32, 10
    q = jax.random.normal(r[0], (B, K, G, h), dtype)
    kmin = jax.random.normal(r[1], (N, K, h), jnp.float32)
    kmax = kmin + jax.nn.relu(jax.random.normal(r[2], (N, K, h)))
    tables = jax.random.randint(r[3], (B, nb), 1, N)
    lens = jnp.array([1, nb * bs - bs // 2, nb * bs])
    out = block_topk_scores(q, kmin, kmax, tables, lens, block_size=bs,
                            interpret=True)
    want = ref.block_topk_scores_ref(q, kmin, kmax, tables, lens,
                                     block_size=bs)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               **TOL[jnp.float32 if dtype == jnp.float32
                                     else jnp.bfloat16])


def test_block_topk_non_resident_masked():
    """A poisoned summary behind a non-resident table entry (the null-block
    alias) must never outrank a real block: its score is NEG_INF."""
    B, K, G, h, N, bs, nb = 1, 1, 1, 16, 6, 8, 3
    q = jnp.ones((B, K, G, h))
    kmin = jnp.zeros((N, K, h)).at[0].set(1e4)      # poisoned null block
    kmax = jnp.ones((N, K, h)).at[0].set(1e4)
    tables = jnp.array([[3, 0, 0]])                 # 1 resident block
    lens = jnp.array([5])
    out = np.asarray(block_topk_scores(q, kmin, kmax, tables, lens,
                                       block_size=bs, interpret=True))
    assert out[0, 0] == pytest.approx(h, rel=1e-5)  # Σ_c max(1·0, 1·1)
    assert (out[0, 1:] <= -1e29).all()


def test_block_topk_adapter_matches_fallback():
    """ops layout adapter (model [B,H,h] layout) ≡ the models/attention.py
    jnp fallback, GQA case."""
    from repro.models.attention import block_topk_scores as fb
    rng = jax.random.PRNGKey(17)
    r = jax.random.split(rng, 4)
    B, K, G, h, N, bs, nb = 2, 2, 2, 32, 8, 8, 4
    q = jax.random.normal(r[0], (B, K * G, h))
    kmin = jax.random.normal(r[1], (N, K, h))
    kmax = kmin + jax.nn.relu(jax.random.normal(r[2], (N, K, h)))
    tables = jax.random.randint(r[3], (B, nb), 1, N)
    lens = jnp.array([9, nb * bs])
    got = ops.block_topk_scores_op(q, kmin, kmax, tables, lens,
                                   block_size=bs)
    want = fb(q, kmin, kmax, tables, lens, block_size=bs)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("s,C,D,F", [(2, 32, 64, 48), (4, 64, 128, 96),
                                     (1, 16, 32, 32)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_moe_gmm_sweep(s, C, D, F, dtype):
    rng = jax.random.PRNGKey(s * C)
    r = jax.random.split(rng, 3)
    x = jax.random.normal(r[0], (s, C, D), dtype)
    w = jax.random.normal(r[1], (s, D, F), dtype)
    nv = jax.random.randint(r[2], (s,), 0, C + 1)
    out = moe_gmm(x, w, nv, block_c=16, block_f=16, block_d=32, interpret=True)
    want = ref.moe_gmm_ref(x, w, nv)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               rtol=3e-2 if dtype == jnp.bfloat16 else 1e-4,
                               atol=3e-2 if dtype == jnp.bfloat16 else 1e-4)


def test_moe_gmm_invalid_rows_masked():
    x = jnp.ones((1, 8, 16))
    w = jnp.ones((1, 16, 8))
    out = moe_gmm(x, w, jnp.array([3]), block_c=8, block_f=8, block_d=16,
                  interpret=True)
    assert float(out[0, 2].sum()) == 16 * 8    # valid row
    assert float(jnp.abs(out[0, 3:]).sum()) == 0.0


def test_ops_layout_adapters_match_model_reference():
    """ops adapters (GQA repeat + transpose) vs the model's dense math."""
    from tests.test_attention import dense_ref
    rng = jax.random.PRNGKey(5)
    r = jax.random.split(rng, 3)
    B, S, H, K, h = 2, 64, 4, 2, 32
    q = jax.random.normal(r[0], (B, S, H, h))
    k = jax.random.normal(r[1], (B, S, K, h))
    v = jax.random.normal(r[2], (B, S, K, h))
    out = ops.attention_prefill_op(q, k, v, causal=True, block_q=32, block_k=32)
    want = dense_ref(q, k, v, True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want, np.float32),
                               rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------- quant
def _quantize_pages(rng, pages, n_sealed):
    """f32 pages [N, K, bs, h] → (q int8, scale [N, K, h], tok [N, K, bs])
    in the QuantPlane arena format: the first `n_sealed` real blocks carry
    per-block per-channel seal scales (nonzero scale row ⟺ sealed), the
    rest the per-token provisional tail format (scale row zero)."""
    from repro.models import attention as attn
    N, K, bs, h = pages.shape
    sealed = jnp.arange(N) < n_sealed
    sc_full = jnp.abs(pages).max(axis=2) / 127.0            # [N, K, h]
    scale = jnp.where(sealed[:, None, None], sc_full, 0.0)
    qs = jnp.clip(jnp.round(pages / jnp.where(sc_full > 0, sc_full, 1.0)
                            [:, :, None, :]), -127, 127).astype(jnp.int8)
    qt, tok = attn.quant_tokens(pages.transpose(0, 2, 1, 3))  # [N,bs,K,...]
    qt = qt.transpose(0, 2, 1, 3)
    tok = jnp.where(sealed[:, None, None], 0.0, tok.transpose(0, 2, 1))
    q = jnp.where(sealed[:, None, None, None], qs, qt)
    return q, scale, tok


@pytest.mark.parametrize("bs,nb", [(8, 6), (16, 4)])
@pytest.mark.parametrize("G", [1, 4])
def test_paged_decode_quant_sweep(bs, nb, G):
    """Quantized-arena decode: the kernel's in-tile dequant (sealed
    per-channel rows + unsealed per-token scalars, mixed in one table)
    vs the linear-gather oracle's independent dequant."""
    rng = jax.random.PRNGKey(bs * nb + G + 101)
    r = jax.random.split(rng, 6)
    B, K, h, N = 3, 2, 32, 24
    q = jax.random.normal(r[0], (B, K, G, h))
    kp = jax.random.normal(r[1], (N, K, bs, h))
    vp = jax.random.normal(r[2], (N, K, bs, h))
    kq, ks, kt = _quantize_pages(r[3], kp, N // 2)
    vq, vs, vt = _quantize_pages(r[4], vp, N // 2)
    tables = jax.random.randint(r[5], (B, nb), 1, N)
    lens = jnp.array([1, max(nb * bs // 2 - 3, 1), nb * bs])
    out = paged_decode(q, kq, vq, tables, lens, k_scale=ks, k_tok=kt,
                       v_scale=vs, v_tok=vt, interpret=True)
    want = ref.paged_decode_ref(q, kq, vq, tables, lens, k_scale=ks,
                                k_tok=kt, v_scale=vs, v_tok=vt)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-5, atol=2e-5)
    # and the oracle itself vs the f32 kernel on materialized dequant
    # content — two independent dequant implementations agreeing
    kf = ref.dequant_pages_ref(kq, ks, kt)
    vf = ref.dequant_pages_ref(vq, vs, vt)
    f32 = paged_decode(q, kf, vf, tables, lens, interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(f32),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("bs,S", [(8, 8), (16, 8)])
@pytest.mark.parametrize("G", [1, 4])
def test_paged_prefill_quant_sweep(bs, S, G):
    """Quantized-arena chunked prefill: int8 HISTORY dequantized in-tile,
    f32 in-chunk keys untouched, vs the oracle — empty and mid-block
    history offsets."""
    rng = jax.random.PRNGKey(bs + S * G + 202)
    r = jax.random.split(rng, 8)
    B, K, h, N, nb = 2, 2, 32, 24, 5
    q = jax.random.normal(r[0], (B, K, S * G, h))
    kn = jax.random.normal(r[1], (B, K, S, h))
    vn = jax.random.normal(r[2], (B, K, S, h))
    kq, ks, kt = _quantize_pages(r[3], jax.random.normal(r[4], (N, K, bs, h)),
                                 N // 3)
    vq, vs, vt = _quantize_pages(r[5], jax.random.normal(r[6], (N, K, bs, h)),
                                 N // 3)
    tables = jax.random.randint(r[7], (B, nb), 1, N)
    off = jnp.array([0, nb * bs // 2 - 3], jnp.int32)
    cl = jnp.array([S, max(S - 3, 1)], jnp.int32)
    out = paged_prefill(q, kn, vn, kq, vq, tables, off, cl, k_scale=ks,
                        k_tok=kt, v_scale=vs, v_tok=vt, interpret=True)
    want = ref.paged_prefill_ref(q, kn, vn, kq, vq, tables, off, cl,
                                 k_scale=ks, k_tok=kt, v_scale=vs, v_tok=vt)
    got, exp = np.asarray(out), np.asarray(want)
    for b in range(B):
        real = int(cl[b]) * G
        np.testing.assert_allclose(got[b, :, :real], exp[b, :, :real],
                                   rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("bs,S", [(8, 4), (16, 5)])
@pytest.mark.parametrize("G", [1, 4])
def test_spec_verify_quant_sweep(bs, S, G):
    """Quantized-arena speculative verify: int8 history dequantized
    in-tile under per-slot offsets (empty / mid-block / fully resident),
    f32 in-window keys causal as before."""
    rng = jax.random.PRNGKey(bs * S + G + 303)
    r = jax.random.split(rng, 8)
    B, K, h, N, nb = 3, 2, 32, 20, 4
    q = jax.random.normal(r[0], (B, K, S * G, h))
    kn = jax.random.normal(r[1], (B, K, S, h))
    vn = jax.random.normal(r[2], (B, K, S, h))
    kq, ks, kt = _quantize_pages(r[3], jax.random.normal(r[4], (N, K, bs, h)),
                                 N // 2)
    vq, vs, vt = _quantize_pages(r[5], jax.random.normal(r[6], (N, K, bs, h)),
                                 N // 2)
    tables = jax.random.randint(r[7], (B, nb), 1, N)
    off = jnp.array([0, bs + bs // 2 - 1, nb * bs], jnp.int32)
    cl = jnp.array([S, max(S - 2, 1), 1], jnp.int32)
    out = spec_verify(q, kn, vn, kq, vq, tables, off, cl, k_scale=ks,
                      k_tok=kt, v_scale=vs, v_tok=vt, interpret=True)
    want = ref.spec_verify_ref(q, kn, vn, kq, vq, tables, off, cl,
                               k_scale=ks, k_tok=kt, v_scale=vs, v_tok=vt)
    got, exp = np.asarray(out), np.asarray(want)
    for b in range(B):
        real = int(cl[b]) * G
        np.testing.assert_allclose(got[b, :, :real], exp[b, :, :real],
                                   rtol=2e-5, atol=2e-5)


def test_block_topk_quant_summaries():
    """block_topk over a quantized arena: the summary plane is maintained
    over the DEQUANTIZED content (update_block_summaries takes the scale
    plane), so the untouched score kernel prices exactly what attention
    reads — scores over quant summaries must match the f32 kernel run on
    summaries of the materialized dequant content."""
    from repro.models import attention as attn
    rng = jax.random.PRNGKey(404)
    r = jax.random.split(rng, 4)
    B, K, G, h, bs, N, nb = 2, 2, 2, 32, 8, 16, 5
    kp = jax.random.normal(r[0], (N, K, bs, h))
    kq, ks, kt = _quantize_pages(r[1], kp, N // 2)
    q = jax.random.normal(r[2], (B, K, G, h))
    tables = jax.random.randint(r[3], (B, nb), 1, N)
    lens = jnp.array([nb * bs, 2 * bs], jnp.int32)
    zeros = jnp.zeros((N, K, h))
    kmin, kmax, _ = attn.update_block_summaries(
        zeros, zeros, zeros, kq, jnp.arange(N), k_scale=ks, k_tok=kt)
    kf = ref.dequant_pages_ref(kq, ks, kt)
    kmin_f, kmax_f, _ = attn.update_block_summaries(
        zeros, zeros, zeros, kf, jnp.arange(N))
    np.testing.assert_allclose(np.asarray(kmin), np.asarray(kmin_f),
                               rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(np.asarray(kmax), np.asarray(kmax_f),
                               rtol=1e-6, atol=1e-6)
    out = block_topk_scores(q, kmin, kmax, tables, lens, block_size=bs,
                            interpret=True)
    want = ref.block_topk_scores_ref(q, kmin_f, kmax_f, tables, lens,
                                     block_size=bs)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-5, atol=1e-5)
