"""OmniAttn online sparsity: block-summary metadata plane + query-aware
top-k block selection for paged decode.

Covers: selection semantics (forced keeps, per-slot degrade-to-exact,
logical-order compaction + lens arithmetic), greedy bit-equivalence of
full-budget sparse decode against the exact engines across block sizes ×
layer stacks (incl. snapshot+resume through the prefix store), the
zero-stale-summary invariant through admission handoff / preemption +
re-admission / partial-tail CoW, the pow2-bucketed resident-block count
(bounded step-jit retraces), controller validation, and the server-level
stats plumbing."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import reduced_config
from repro.configs.base import OmniAttnConfig
from repro.distributed.ctx import local_mesh_ctx
from repro.models import LM
from repro.models.attention import select_kv_blocks
from repro.serving import (DecodeEngine, KVArena, PrefillEngine,
                           SamplingParams, SparsityController)


@pytest.fixture(scope="module")
def full_stack():
    """Two full-attention layers (every KV block pool-managed)."""
    mesh = local_mesh_ctx()
    cfg = reduced_config("qwen2-1.5b").with_updates(
        compute_dtype="float32", param_dtype="float32", n_layers=2)
    lm = LM.build(cfg, mesh, pattern=[0, 0])
    return cfg, lm.mesh, lm.init(jax.random.PRNGKey(0))


@pytest.fixture(scope="module")
def mixed_stack():
    """Full + sliding-window + sink+recent-compressed attention layers:
    selection applies ONLY to the paged full layers; rings keep their
    bounded dense caches."""
    mesh = local_mesh_ctx()
    cfg = reduced_config("qwen2-1.5b").with_updates(
        compute_dtype="float32", param_dtype="float32", n_layers=4,
        local_per_global=1, local_window=16, prefill_sparse=True,
        omniattn=OmniAttnConfig(sink_tokens=8, recent_tokens=24))
    lm = LM.build(cfg, mesh, pattern=[0, 0, 0, 1])
    return cfg, lm.mesh, lm.init(jax.random.PRNGKey(1))


def _greedy_ref(lm, params, prompt, n, max_len=96):
    toks = jnp.asarray([list(prompt)], jnp.int32)
    cache, logits, _ = lm.prefill(params, {"tokens": toks}, max_len=max_len)
    out, pos = [], len(prompt)
    for i in range(n):
        nxt = int(jnp.argmax(logits[0]))
        out.append(nxt)
        if i == n - 1:
            break
        cache, logits, _ = lm.decode(params, cache, jnp.asarray([[nxt]]),
                                     jnp.int32(pos))
        pos += 1
    return out


def _drive(pe, de, prompts, hints, n_decode):
    outs = {}
    for rid, (p, hint) in enumerate(zip(prompts, hints)):
        pe.start(rid, p, prefix_hint=hint)
        recs = []
        while len(recs) == 0:
            recs = pe.step()
        (rec,) = recs
        assert de.admit(rid, rec.cache, rec.first_token, rec.prompt_len,
                        cached_tokens=rec.reused, prompt=p)
        outs[rid] = [rec.first_token]
    for _ in range(n_decode):
        for rid, t in de.step().items():
            outs[rid].append(t)
    return outs


# ======================================================================
def test_select_kv_blocks_semantics():
    """Forced keeps, compaction order, lens arithmetic, per-slot degrade."""
    bs, nb = 4, 8
    tables = jnp.arange(1, 17).reshape(2, nb)
    lens = jnp.asarray([30, 9])            # 8 resident blocks / 3 resident
    # score the middle blocks highest so the keeps have to be forced
    scores = jnp.asarray([[0., 9, 8, 7, 6, 5, 1, 0],
                          [0., 9, 8, 0, 0, 0, 0, 0]])
    tbl, ln, m, sel = select_kv_blocks(scores, tables, lens, block_size=bs,
                                       k_static=4, sink_blocks=1,
                                       recent_blocks=2)
    # row 0: keeps {0, 6, 7} + best-scored {1}; ascending logical order
    np.testing.assert_array_equal(np.asarray(tbl[0]), [1, 2, 7, 8])
    assert int(ln[0]) == 3 * bs + 2        # 3 full blocks + tail fill 2
    assert int(m[0]) == 4
    np.testing.assert_array_equal(np.asarray(sel[0]),
                                  [1, 1, 0, 0, 0, 0, 1, 1])
    # row 1: only 3 resident → degrade to exact (all kept, padded with 0)
    np.testing.assert_array_equal(np.asarray(tbl[1]), [9, 10, 11, 0])
    assert int(ln[1]) == 9 and int(m[1]) == 3

    # fractional budget: ceil(frac·n_res) per slot, floored at the keeps
    _, _, m2, _ = select_kv_blocks(scores, tables, lens, block_size=bs,
                                   k_static=6, frac=0.5, sink_blocks=1,
                                   recent_blocks=2)
    assert int(m2[0]) == 4                 # ceil(0.5·8)
    assert int(m2[1]) == 3                 # max(ceil(0.5·3), 3) ∩ resident


@pytest.mark.parametrize("block_size", [8, 16])
@pytest.mark.parametrize("stack", ["full", "mixed"])
def test_full_budget_sparse_bit_equivalence(block_size, stack, full_stack,
                                            mixed_stack):
    """Greedy bit-equivalence: online selection ACTIVE (budget below the
    bucketed table width, so scoring + compaction actually run) but
    covering every resident block — across block sizes × layer stacks,
    over shared-prefix prompts that exercise snapshot-at-boundary and
    store resume (partial-tail CoW included)."""
    cfg, mesh, params = full_stack if stack == "full" else mixed_stack
    # budget: one below the smallest possible bucketed table width, so the
    # k_static < nb branch is taken on every trace; prompts stay ≤ 5 blocks
    budget = {8: 7, 16: 5}[block_size]
    cfg_sp = cfg.with_updates(omniattn_topk_blocks=budget,
                              omniattn_topk_measure_mass=True)
    # plans must match so one params pytree serves both configs
    pattern = [0, 0] if stack == "full" else [0, 0, 0, 1]
    lm = LM.build(cfg, mesh, pattern=pattern)
    lm_sp = LM.build(cfg_sp, mesh, pattern=pattern)
    assert lm.plan == lm_sp.plan

    rng = np.random.default_rng(7 + block_size)
    base = tuple(rng.integers(0, cfg.vocab_size, 24))
    prompts = [base + tuple(rng.integers(0, cfg.vocab_size, 9)),
               base + tuple(rng.integers(0, cfg.vocab_size, 14)),
               tuple(rng.integers(0, cfg.vocab_size, 11))]
    hints = [24, 24, 0]
    refs = [_greedy_ref(lm, params, p, 7) for p in prompts]

    arena = KVArena.build(lm_sp, n_blocks=64, block_size=block_size)
    pe = PrefillEngine(lm_sp, params, None, max_len=96, chunk_tokens=8,
                       arena=arena)
    de = DecodeEngine(lm_sp, params, None, n_slots=4, max_len=96,
                      block_size=block_size, arena=arena)
    assert de.sparsity is not None
    sparse = _drive(pe, de, prompts, hints, 6)
    for rid in range(len(prompts)):
        assert sparse[rid] == refs[rid], f"request {rid}"
    v = de.take_sparsity_stats()
    # selection ran and kept everything (budget ≥ resident): the two
    # independent meters agree and the measured mass is exactly 1
    assert v is not None and v[0] > 0
    assert de.stats["blocks_attended"] == de.stats["blocks_scored"] > 0
    assert de.stats["attn_mass_n"] > 0
    assert de.stats["attn_mass_sum"] == pytest.approx(
        de.stats["attn_mass_n"], rel=1e-6)
    arena.pool.check_invariants(arena)     # zero-stale-summary included


def test_sparse_budget_reduces_attended_blocks(full_stack):
    """A sub-resident budget actually attends fewer blocks than it scores,
    and the compacted table still yields a usable stream (every step emits
    a token for the slot)."""
    cfg, mesh, params = full_stack
    cfg_sp = cfg.with_updates(omniattn_topk_blocks=4,
                              omniattn_topk_measure_mass=True)
    lm_sp = LM.build(cfg_sp, mesh, pattern=[0, 0])
    arena = KVArena.build(lm_sp, n_blocks=64, block_size=8)
    pe = PrefillEngine(lm_sp, params, None, max_len=96, chunk_tokens=16,
                       arena=arena)
    de = DecodeEngine(lm_sp, params, None, n_slots=2, max_len=96,
                      arena=arena)
    prompt = tuple(np.random.default_rng(3).integers(0, cfg.vocab_size, 60))
    pe.start(0, prompt)
    recs = []
    while not recs:
        recs = pe.step()
    assert de.admit(0, recs[0].cache, recs[0].first_token,
                    recs[0].prompt_len, prompt=prompt)
    toks = []
    for _ in range(5):
        toks.append(de.step()[0])
    assert len(toks) == 5
    de.take_sparsity_stats()
    # 60+ tokens resident = 8 blocks scored per step, 4 attended
    assert 0 < de.stats["blocks_attended"] < de.stats["blocks_scored"]
    assert 0 < SparsityController.mass_kept(de.stats) <= 1.0
    arena.pool.check_invariants(arena)


def test_step_jit_traces_once_per_block_bucket(full_stack):
    """Satellite: the resident-block count fed to the step jit is pow2-
    bucketed (lo=8 floor) — decoding across MANY block boundaries inside
    one bucket must not retrace; crossing a bucket boundary adds exactly
    one trace. Greedy outputs stay equal to the slot-dense engine."""
    cfg, mesh, params = full_stack
    lm = LM.build(cfg, mesh, pattern=[0, 0])
    bs, n_steps = 8, 80
    prompt = tuple(np.random.default_rng(5).integers(0, cfg.vocab_size, 30))
    ref = _greedy_ref(lm, params, prompt, n_steps + 1, max_len=512)

    arena = KVArena.build(lm, n_blocks=128, block_size=bs)
    pe = PrefillEngine(lm, params, None, max_len=512, chunk_tokens=16,
                       arena=arena)
    de = DecodeEngine(lm, params, None, n_slots=2, max_len=512, arena=arena)
    pe.start(0, prompt)
    recs = []
    while not recs:
        recs = pe.step()
    assert de.admit(0, recs[0].cache, recs[0].first_token,
                    recs[0].prompt_len, prompt=prompt)
    outs = [recs[0].first_token]
    for _ in range(n_steps):
        outs.append(de.step()[0])
    assert outs == ref
    # 30 → 111 resident tokens: blocks 4 → 14, i.e. ≥ 9 block-boundary
    # crossings but only two buckets (8, 16) — and so exactly two traces
    assert arena.pool.blocks_for(int(de.tokens_h[de.rid_slot[0]])) > 8
    assert de._step._cache_size() == 2, \
        f"step jit traced {de._step._cache_size()}× across 2 buckets"


def test_zero_stale_summary_invariant_lifecycle(full_stack):
    """The block-summary plane stays coherent through every ownership
    move: prefill chunk writes → store snapshot → zero-copy handoff →
    decode appends → resume borrower tail CoW → preemption → dense
    re-admission. check_invariants(arena) recomputes every block's
    summary from its content at each stage."""
    cfg, mesh, params = full_stack
    lm = LM.build(cfg, mesh, pattern=[0, 0])
    arena = KVArena.build(lm, n_blocks=16, block_size=8)
    pool = arena.pool
    pe = PrefillEngine(lm, params, None, max_len=96, chunk_tokens=8,
                       arena=arena)
    de = DecodeEngine(lm, params, None, n_slots=2, max_len=96, arena=arena)
    rng = np.random.default_rng(11)
    base = tuple(rng.integers(0, cfg.vocab_size, 20))
    p1 = base + tuple(rng.integers(0, cfg.vocab_size, 8))
    p2 = base + tuple(rng.integers(0, cfg.vocab_size, 11))

    pe.start(0, p1, prefix_hint=20)
    (r1,) = pe.step()
    pool.check_invariants(arena)           # chunk writes + snapshot
    assert de.admit(0, r1.cache, r1.first_token, len(p1), prompt=p1)
    pool.check_invariants(arena)           # zero-copy handoff
    de.step()
    pool.check_invariants(arena)           # decode append

    pe.start(1, p2, prefix_hint=20)
    (r2,) = pe.step()
    assert pe.stats["prefix_hits"] == 1    # resume: tail block CoW'd
    pool.check_invariants(arena)           # copy_block carried summaries
    assert de.admit(1, r2.cache, r2.first_token, len(p2), prompt=p2)
    de.step()
    pool.check_invariants(arena)

    # exhaust the pool so the next extend preempts request 1, then re-admit
    # its extracted dense cache (the dense-scatter recompute path)
    blocker = pool.allocate("blocker", pool.free_blocks * pool.block_size)
    assert blocker is not None and pool.free_blocks == 0
    steps = 0
    while not de.preempted and steps < 20:
        de.step()
        steps += 1
    assert de.preempted
    pool.check_invariants(arena)           # extraction left no stale blocks
    rid, cache_one, tok, pos = de.preempted.pop()
    pool.release("blocker")
    assert de.admit(rid, cache_one, tok, pos)
    de.step()
    pool.check_invariants(arena)           # dense re-admission recomputed


def test_check_summaries_detects_corruption(full_stack):
    """The invariant is not vacuous: poisoning one block's kmin must trip
    check_invariants(arena)."""
    cfg, mesh, params = full_stack
    lm = LM.build(cfg, mesh, pattern=[0, 0])
    arena = KVArena.build(lm, n_blocks=8, block_size=8)
    pe = PrefillEngine(lm, params, None, max_len=64, chunk_tokens=8,
                       arena=arena)
    pe.process(tuple(range(40, 60)))
    arena.pool.check_invariants(arena)
    for i, e in enumerate(arena.kv["period"]):
        if e is not None:
            e["kmin"] = e["kmin"].at[..., 2, :, :].add(1.0)
            break
    with pytest.raises(AssertionError):
        arena.pool.check_invariants(arena)


def test_sparsity_controller_validation(full_stack):
    cfg, mesh, params = full_stack
    lm = LM.build(cfg, mesh, pattern=[0, 0])
    assert SparsityController.from_model(cfg, lm.plan, 8, 12) is None
    c = SparsityController.from_model(
        cfg.with_updates(omniattn_topk_frac=0.5), lm.plan, 8, 12)
    assert c is not None and c.plan.n_sparse_layers == 2
    assert c.plan.budget_blocks == 6 and c.plan.frac == 0.5
    with pytest.raises(ValueError):
        SparsityController.from_model(
            cfg.with_updates(omniattn_topk_blocks=4, omniattn_topk_frac=0.5),
            lm.plan, 8, 12)
    with pytest.raises(ValueError):
        SparsityController.from_model(
            cfg.with_updates(omniattn_topk_frac=1.5), lm.plan, 8, 12)


def test_server_reports_sparsity_summary(full_stack):
    """Server-level plumbing: the run summary carries blocks_scored /
    blocks_attended / attn_mass_kept, selection costs zero extra host
    syncs, and greedy outputs match the exact server."""
    from repro.core.proxy import OASConfig
    from repro.serving import Server, ServerConfig

    cfg, mesh, params = full_stack
    scfg = ServerConfig(n_prefill=1, n_decode=1, decode_slots=2, max_len=96,
                        chunk_tokens=16, kv_blocks=48, kv_block_size=8,
                        oas=OASConfig(defer_window=0.0))
    rng = np.random.default_rng(23)
    reqs = [(tuple(rng.integers(0, cfg.vocab_size, 50)), 5),
            (tuple(rng.integers(0, cfg.vocab_size, 12)), 4)]

    exact = Server(cfg, scfg, pattern=[0, 0], params=params)
    s0 = exact.run(list(reqs))
    srv = Server(cfg.with_updates(omniattn_topk_blocks=4,
                                  omniattn_topk_measure_mass=True),
                 scfg, pattern=[0, 0], params=params)
    s1 = srv.run(list(reqs))
    assert s1["n_done"] == len(reqs)
    assert s1["blocks_attended"] < s1["blocks_scored"]
    assert 0.0 < s1["attn_mass_kept"] <= 1.0
    assert np.isnan(s0["attn_mass_kept"]) and s0["blocks_scored"] == 0
    ds = srv.decodes[0].stats
    assert ds["host_fetches"] == ds["steps"]

    # the STREAMING entry points see the stats too: the window drains at
    # the monitor cadence inside step(), not only in run()'s epilogue
    from dataclasses import replace
    srv2 = Server(cfg.with_updates(omniattn_topk_blocks=4,
                                   omniattn_topk_measure_mass=True),
                  replace(scfg, placement_interval=2),
                  pattern=[0, 0], params=params)
    for _ in srv2.generate([reqs[0][0]], SamplingParams(max_tokens=5)):
        pass
    assert srv2.metrics.blocks_scored > 0
    assert srv2.metrics.blocks_attended < srv2.metrics.blocks_scored