"""Serving engine + server e2e: continuous batching correctness, PD handoff,
fault injection, training resume (integration)."""
import subprocess
import sys
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import reduced_config
from repro.core.proxy import OASConfig
from repro.distributed.ctx import local_mesh_ctx
from repro.models import LM
from repro.serving import DecodeEngine, PrefillEngine, Server, ServerConfig
from repro.serving.kvpool import KVPool


@pytest.fixture(scope="module")
def small():
    mesh = local_mesh_ctx()
    cfg = reduced_config("qwen2-1.5b").with_updates(
        compute_dtype="float32", param_dtype="float32", n_layers=2)
    lm = LM.build(cfg, mesh, pattern=[0, 0])
    params = lm.init(jax.random.PRNGKey(0))
    return cfg, lm, params


def greedy_reference(lm, params, prompt, n):
    toks = jnp.asarray([list(prompt)], jnp.int32)
    cache, logits, _ = lm.prefill(params, {"tokens": toks}, max_len=96)
    out = []
    pos = len(prompt)
    for i in range(n):
        nxt = int(jnp.argmax(logits[0]))
        out.append(nxt)
        if i == n - 1:
            break
        cache, logits, _ = lm.decode(params, cache, jnp.asarray([[nxt]]),
                                     jnp.int32(pos))
        pos += 1
    return out


def test_batched_decode_matches_single_stream(small):
    """Two requests decoded TOGETHER in engine slots must produce the same
    greedy continuations as isolated reference decoding."""
    cfg, lm, params = small
    pe = PrefillEngine(lm, params, None, max_len=96)
    de = DecodeEngine(lm, params, None, n_slots=4, max_len=96)
    rng = np.random.default_rng(0)
    prompts = [tuple(rng.integers(0, cfg.vocab_size, 9)),
               tuple(rng.integers(0, cfg.vocab_size, 17))]
    refs = [greedy_reference(lm, params, p, 6) for p in prompts]
    outs = {i: [] for i in range(2)}
    for i, p in enumerate(prompts):
        cache, first, _ = pe.process(p)
        assert de.admit(i, cache, first, len(p))
        outs[i].append(first)
    for _ in range(5):
        toks = de.step()
        for rid, t in toks.items():
            outs[rid].append(t)
    for i in range(2):
        assert outs[i] == refs[i], f"request {i}"


def test_engine_slot_release_and_reuse(small):
    cfg, lm, params = small
    pe = PrefillEngine(lm, params, None, max_len=96)
    de = DecodeEngine(lm, params, None, n_slots=1, max_len=96)
    cache, first, _ = pe.process((1, 2, 3))
    assert de.admit(0, cache, first, 3)
    assert not de.has_capacity()
    assert not de.admit(1, cache, first, 3)
    de.step()
    de.release(0)
    assert de.has_capacity()
    assert de.admit(1, cache, first, 3)


def test_prefill_exact_cache_hit(small):
    cfg, lm, params = small
    pe = PrefillEngine(lm, params, None, max_len=96)
    p = (5, 6, 7, 8)
    pe.process(p)
    n0 = pe.stats["prefills"]
    pe.process(p)
    assert pe.stats["prefills"] == n0
    assert pe.stats["cache_hits"] == 1


def test_kvpool_admission():
    pool = KVPool(n_blocks=4, block_size=16)
    assert pool.allocate(1, 40)            # 3 blocks
    assert not pool.can_admit(40)          # only 1 left
    assert pool.allocate(2, 10)            # 1 block
    assert not pool.allocate(3, 1)
    pool.release(1)
    assert pool.allocate(3, 30)            # 2 blocks
    assert pool.utilization == 0.75


def test_server_end_to_end(small):
    cfg, _, _ = small
    scfg = ServerConfig(n_prefill=1, n_decode=1, decode_slots=4, max_len=96,
                        oas=OASConfig(defer_window=0.0))
    srv = Server(cfg, scfg)
    rng = np.random.default_rng(1)
    reqs = [(tuple(rng.integers(0, cfg.vocab_size, int(rng.integers(4, 12)))), 4)
            for _ in range(5)]
    s = srv.run(reqs, max_wall_s=120)
    assert s["n_done"] == 5
    assert s["qpm"] > 0
    assert all(np.isfinite(s[k]) for k in ("ttft_mean", "tpot_mean_ms"))


def test_server_moe_arch(small):
    cfg = reduced_config("qwen2-moe-a2.7b").with_updates(n_layers=2)
    scfg = ServerConfig(n_prefill=1, n_decode=1, decode_slots=2, max_len=64,
                        oas=OASConfig(defer_window=0.0))
    srv = Server(cfg, scfg)
    rng = np.random.default_rng(2)
    reqs = [(tuple(rng.integers(0, cfg.vocab_size, 6)), 3) for _ in range(2)]
    s = srv.run(reqs, max_wall_s=120)
    assert s["n_done"] == 2
