"""Serving engine + server e2e: continuous batching correctness, PD handoff,
fault injection, training resume (integration)."""
import subprocess
import sys
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import reduced_config
from repro.core.proxy import OASConfig
from repro.distributed.ctx import local_mesh_ctx
from repro.models import LM
from repro.serving import DecodeEngine, PrefillEngine, Server, ServerConfig
from repro.serving.kvpool import KVPool


@pytest.fixture(scope="module")
def small():
    mesh = local_mesh_ctx()
    cfg = reduced_config("qwen2-1.5b").with_updates(
        compute_dtype="float32", param_dtype="float32", n_layers=2)
    lm = LM.build(cfg, mesh, pattern=[0, 0])
    params = lm.init(jax.random.PRNGKey(0))
    return cfg, lm, params


def greedy_reference(lm, params, prompt, n):
    toks = jnp.asarray([list(prompt)], jnp.int32)
    cache, logits, _ = lm.prefill(params, {"tokens": toks}, max_len=96)
    out = []
    pos = len(prompt)
    for i in range(n):
        nxt = int(jnp.argmax(logits[0]))
        out.append(nxt)
        if i == n - 1:
            break
        cache, logits, _ = lm.decode(params, cache, jnp.asarray([[nxt]]),
                                     jnp.int32(pos))
        pos += 1
    return out


def test_batched_decode_matches_single_stream(small):
    """Two requests decoded TOGETHER in engine slots must produce the same
    greedy continuations as isolated reference decoding."""
    cfg, lm, params = small
    pe = PrefillEngine(lm, params, None, max_len=96)
    de = DecodeEngine(lm, params, None, n_slots=4, max_len=96)
    rng = np.random.default_rng(0)
    prompts = [tuple(rng.integers(0, cfg.vocab_size, 9)),
               tuple(rng.integers(0, cfg.vocab_size, 17))]
    refs = [greedy_reference(lm, params, p, 6) for p in prompts]
    outs = {i: [] for i in range(2)}
    for i, p in enumerate(prompts):
        cache, first, _ = pe.process(p)
        assert de.admit(i, cache, first, len(p))
        outs[i].append(first)
    for _ in range(5):
        toks = de.step()
        for rid, t in toks.items():
            outs[rid].append(t)
    for i in range(2):
        assert outs[i] == refs[i], f"request {i}"


def test_engine_slot_release_and_reuse(small):
    cfg, lm, params = small
    pe = PrefillEngine(lm, params, None, max_len=96)
    de = DecodeEngine(lm, params, None, n_slots=1, max_len=96)
    cache, first, _ = pe.process((1, 2, 3))
    assert de.admit(0, cache, first, 3)
    assert not de.has_capacity()
    assert not de.admit(1, cache, first, 3)
    de.step()
    de.release(0)
    assert de.has_capacity()
    assert de.admit(1, cache, first, 3)


def test_prefill_exact_cache_hit(small):
    cfg, lm, params = small
    pe = PrefillEngine(lm, params, None, max_len=96)
    p = (5, 6, 7, 8)
    pe.process(p)
    n0 = pe.stats["prefills"]
    pe.process(p)
    assert pe.stats["prefills"] == n0
    assert pe.stats["cache_hits"] == 1


def test_kvpool_admission():
    pool = KVPool(n_blocks=4, block_size=16)
    assert pool.allocate(1, 40)            # 3 blocks
    assert not pool.can_admit(40)          # only 1 left
    assert pool.allocate(2, 10)            # 1 block
    assert not pool.allocate(3, 1)
    pool.release(1)
    assert pool.allocate(3, 30)            # 2 blocks
    assert pool.utilization == 0.75


def test_chunked_prefill_matches_full(small):
    """Chunked engine prefill (threaded resume chunks) must match the
    blocking whole-prompt path: same first token, matching stored logits,
    and identical greedy continuation through the decode engine."""
    cfg, lm, params = small
    rng = np.random.default_rng(3)
    prompt = tuple(rng.integers(0, cfg.vocab_size, 37))
    ref = greedy_reference(lm, params, prompt, 6)

    pe_full = PrefillEngine(lm, params, None, max_len=96, enable_chunked=False)
    pe_chunk = PrefillEngine(lm, params, None, max_len=96, chunk_tokens=16)
    assert pe_chunk.chunked and not pe_full.chunked
    cache_f, first_f, _ = pe_full.process(prompt)
    cache_c, first_c, _ = pe_chunk.process(prompt)
    assert first_c == first_f == ref[0]
    assert pe_chunk.stats["chunks"] >= 3
    lf = pe_full.store.lookup(prompt)[2]
    lc = pe_chunk.store.lookup(prompt)[2]
    np.testing.assert_allclose(np.asarray(lf), np.asarray(lc),
                               rtol=1e-4, atol=1e-4)

    de = DecodeEngine(lm, params, None, n_slots=2, max_len=96)
    assert de.admit(0, cache_c, first_c, len(prompt))
    outs = [first_c]
    for _ in range(5):
        outs.append(de.step()[0])
    assert outs == ref


def test_prefix_reuse_suffix_only(small):
    """A prompt sharing an N-token prefix with a stored entry must prefill
    only the suffix (token counter) and produce logits matching the
    from-scratch path."""
    cfg, lm, params = small
    rng = np.random.default_rng(4)
    p1 = tuple(rng.integers(0, cfg.vocab_size, 24))
    suffix = tuple(rng.integers(0, cfg.vocab_size, 13))
    p2 = p1 + suffix

    pe = PrefillEngine(lm, params, None, max_len=96, chunk_tokens=16)
    pe.process(p1)
    assert pe.stats["tokens"] == len(p1)
    cache2, first2, _ = pe.process(p2)
    assert pe.stats["prefix_hits"] == 1
    assert pe.stats["reused_tokens"] == len(p1)
    assert pe.stats["tokens"] == len(p1) + len(suffix)   # suffix work only

    # from-scratch reference: logits and greedy continuation must agree
    scratch = PrefillEngine(lm, params, None, max_len=96, chunk_tokens=16)
    cache_ref, first_ref, _ = scratch.process(p2)
    assert first2 == first_ref
    l_re = pe.store.lookup(p2)[2]
    l_ref = scratch.store.lookup(p2)[2]
    np.testing.assert_allclose(np.asarray(l_re), np.asarray(l_ref),
                               rtol=1e-4, atol=1e-4)
    de = DecodeEngine(lm, params, None, n_slots=2, max_len=96)
    assert de.admit(0, cache2, first2, len(p2))
    assert de.admit(1, cache_ref, first_ref, len(p2))
    for _ in range(4):
        toks = de.step()
        assert toks[0] == toks[1]

    # exact re-submission: no new compute
    n_before = pe.stats["tokens"]
    pe.process(p2)
    assert pe.stats["tokens"] == n_before
    assert pe.stats["cache_hits"] == 1


def test_batched_admission_matches_references(small):
    """Three caches admitted in ONE donated insert call must decode exactly
    like isolated reference streams."""
    cfg, lm, params = small
    pe = PrefillEngine(lm, params, None, max_len=96)
    de = DecodeEngine(lm, params, None, n_slots=4, max_len=96)
    rng = np.random.default_rng(5)
    prompts = [tuple(rng.integers(0, cfg.vocab_size, n)) for n in (7, 12, 19)]
    refs = [greedy_reference(lm, params, p, 5) for p in prompts]
    items = []
    for i, p in enumerate(prompts):
        cache, first, _ = pe.process(p)
        items.append((i, cache, first, len(p), 0))
    granted = de.admit_batch(items)
    assert all(granted.values())
    outs = {i: [items[i][2]] for i in range(3)}
    for _ in range(4):
        for rid, t in de.step().items():
            outs[rid].append(t)
    for i in range(3):
        assert outs[i] == refs[i], f"request {i}"
    # O(1) release bookkeeping stays consistent
    de.release(1)
    assert 1 not in de.rid_slot and len(de.free) == 2
    assert sorted(de.slot_rid.values()) == [0, 2]


def test_decode_preemption_on_block_exhaustion(small):
    """pool.extend failure must preempt the request (slot + blocks freed,
    cache extracted) and re-admission must resume the exact token stream."""
    cfg, lm, params = small
    pe = PrefillEngine(lm, params, None, max_len=96)
    de = DecodeEngine(lm, params, None, n_slots=2, max_len=96,
                      kv_blocks=3)    # block_size=16 → 48 tokens total
    prompt = tuple(np.random.default_rng(6).integers(0, cfg.vocab_size, 14))
    ref = greedy_reference(lm, params, prompt, 8)
    cache, first, _ = pe.process(prompt)
    assert de.admit(0, cache, first, len(prompt))       # 1 block (15 tokens)
    assert de.admit(1, cache, first, len(prompt))       # 1 block
    outs = {0: [first], 1: [first]}
    # decoding grows both requests; at the 16-token crossing each needs a new
    # block — the pool (1 spare) can only serve one, the other preempts
    preempted = None
    for _ in range(8):
        for r, t in de.step().items():
            outs[r].append(t)
        if de.preempted:
            preempted = de.preempted.pop(0)
            break
    assert preempted is not None
    assert de.stats["preemptions"] == 1
    rid, cache_one, tok, pos = preempted
    assert rid not in de.rid_slot and len(de.free) == 1
    assert tok == outs[rid][-1] and pos == len(prompt) + len(outs[rid]) - 1
    # free the survivor's blocks, re-admit the preempted stream, and check
    # it continues the exact reference token sequence
    de.release(1 - rid)
    assert de.admit(rid, cache_one, tok, pos)
    while len(outs[rid]) < len(ref):
        outs[rid].append(de.step()[rid])
    assert outs[rid] == ref


def test_kvpool_denial_extend_release_readmit():
    pool = KVPool(n_blocks=3, block_size=16)
    assert pool.allocate(1, 30)            # 2 blocks
    assert not pool.allocate(2, 20)        # needs 2, only 1 free
    assert pool.allocate(2, 10)            # 1 block
    assert not pool.extend(1, 30, 35)      # crosses 32 → needs a 3rd block
    assert pool.extend(1, 30, 32)          # same block: free
    pool.release(2)
    assert pool.extend(1, 32, 35)          # now fits
    assert pool.free_blocks == 0
    pool.release(1)
    assert pool.free_blocks == 3
    assert pool.allocate(3, 48)            # release → readmit full pool
    # prefix-credited admission only charges the non-resident suffix
    pool.release(3)
    assert pool.allocate(4, 48, cached_tokens=32)
    assert pool.free_blocks == 2


def test_radix_payload_prefix_store(small):
    from repro.core.proxy.radix import RadixTree
    from repro.serving.kvpool import PrefixKVStore
    tree = RadixTree()
    store = PrefixKVStore(tree, capacity=2)
    store.put((1, 2, 3, 4), "c1", "l1")
    store.put((1, 2, 3, 4, 5, 6), "c2", "l2")
    n, c, l = store.lookup((1, 2, 3, 4, 5, 6, 7, 8))
    assert (n, c) == (6, "c2")
    n, c, _ = store.lookup((1, 2, 3, 4, 9))
    assert (n, c) == (4, "c1")
    assert store.lookup((2, 1))[0] == 0
    store.put((8, 8, 8), "c3", "l3")       # beyond cap=2: LRU evicts c2
    assert len(store.entries) == 2
    # c2's payload is still attached in the tree but stale — lookup must
    # skip it and fall back to the shallower live entry
    n, c, _ = store.lookup((1, 2, 3, 4, 5, 6))
    assert (n, c) == (4, "c1")


def test_moe_migration_preserves_outputs():
    """Swapping expert slots via _apply_migration (weights + tables) must not
    change model outputs."""
    from repro.core.placement.migration import MigrationPlan
    cfg = reduced_config("qwen2-moe-a2.7b").with_updates(
        n_layers=2, compute_dtype="float32", param_dtype="float32")
    scfg = ServerConfig(n_prefill=1, n_decode=1, decode_slots=2, max_len=64,
                        oas=OASConfig(defer_window=0.0))
    srv = Server(cfg, scfg)
    rng = np.random.default_rng(8)
    toks = jnp.asarray([rng.integers(0, cfg.vocab_size, 9)], jnp.int32)
    _, logits_before, _ = srv.lm.prefill(srv.params, {"tokens": toks},
                                         max_len=64, tables=srv.tables)
    old_se = np.asarray(srv.tables["slot_expert"]).copy()
    new_se = old_se.copy()
    new_se[0, 0], new_se[0, 1] = old_se[0, 1], old_se[0, 0]   # swap two slots
    srv._apply_migration(MigrationPlan(old_se, new_se, ((0, 0, 0),), 1))
    assert srv.n_migrations == 1
    _, logits_after, _ = srv.lm.prefill(srv.params, {"tokens": toks},
                                        max_len=64, tables=srv.tables)
    np.testing.assert_allclose(np.asarray(logits_before),
                               np.asarray(logits_after), rtol=2e-4, atol=2e-4)


def test_server_prefix_reuse_end_to_end(small):
    """Shared-prefix prompts through the whole server: snapshot-at-boundary
    plus resume must cut computed prefill tokens."""
    cfg, _, _ = small
    scfg = ServerConfig(n_prefill=1, n_decode=1, decode_slots=4, max_len=96,
                        chunk_tokens=16, prefill_tick_budget=64,
                        oas=OASConfig(defer_window=0.0))
    srv = Server(cfg, scfg, pattern=[0, 0])
    rng = np.random.default_rng(9)
    base = tuple(rng.integers(0, cfg.vocab_size, 24))
    reqs = [(base + tuple(rng.integers(0, cfg.vocab_size, 8)), 3)
            for _ in range(4)]
    s = srv.run(reqs, max_wall_s=120)
    ps = s["prefill_stats"][0]
    assert s["n_done"] == 4
    assert ps["prefix_hits"] >= 1
    assert ps["tokens"] + ps["reused_tokens"] >= 4 * 32
    assert ps["tokens"] < 4 * 32          # strictly less than recompute-all


def test_server_decode_instance_failure_recovers(small):
    """A decode-instance death mid-run loses KV for its requests; the proxy
    requeues them and the server must route them back through prefill and
    still finish every request."""
    import time as _t
    cfg, _, _ = small
    scfg = ServerConfig(n_prefill=1, n_decode=1, decode_slots=4, max_len=96,
                        oas=OASConfig(defer_window=0.0))
    srv = Server(cfg, scfg, pattern=[0, 0])
    rng = np.random.default_rng(11)
    reqs = [(tuple(rng.integers(0, cfg.vocab_size, 8)), 6) for _ in range(3)]
    t0 = _t.monotonic()
    for i, (p, m) in enumerate(reqs):
        srv.submit(i, p, m, t0)
    # run a few ticks so requests reach decode, then kill the instance
    for _ in range(3):
        srv._drain_actions(_t.monotonic())
        srv._prefill_round()
        srv._decode_round()
    requeued = srv.proxy.mark_unhealthy("decode", 0, _t.monotonic())
    assert requeued, "expected in-flight decode work to be requeued"
    srv.proxy.mark_healthy("decode", 0)
    while srv.proxy.inflight and _t.monotonic() - t0 < 120:
        srv._drain_actions(_t.monotonic())
        srv._prefill_round()
        srv._decode_round()
    s = srv.metrics.summary(_t.monotonic() - t0)
    assert s["n_done"] == 3
    for r in srv.metrics.done:
        assert len(r.output_tokens) == 6


def test_server_prefill_instance_fail_recover(small):
    """Fail + recover a prefill instance while its engine holds half-done
    chunked tasks: the re-dispatched requests must supersede the stale tasks
    (no duplicate first tokens, accounting balanced)."""
    import time as _t
    cfg, _, _ = small
    scfg = ServerConfig(decode_slots=4, max_len=96, chunk_tokens=8,
                        prefill_tick_budget=8, oas=OASConfig(defer_window=0.0))
    srv = Server(cfg, scfg, pattern=[0, 0])
    rng = np.random.default_rng(13)
    t0 = _t.monotonic()
    for i in range(2):
        srv.submit(i, tuple(rng.integers(0, cfg.vocab_size, 20)), 4, t0)
    srv._drain_actions(_t.monotonic())
    srv._prefill_round()              # partial progress only (tiny budget)
    srv.proxy.mark_unhealthy("prefill", 0, _t.monotonic())
    srv.proxy.mark_healthy("prefill", 0)
    while srv.proxy.inflight and _t.monotonic() - t0 < 120:
        srv._drain_actions(_t.monotonic())
        srv._prefill_round()
        srv._decode_round()
    s = srv.metrics.summary(_t.monotonic() - t0)
    assert s["n_done"] == 2
    assert all(len(r.output_tokens) == 4 for r in srv.metrics.done)
    assert srv.proxy.prefill[0].running == 0


def test_server_end_to_end(small):
    cfg, _, _ = small
    scfg = ServerConfig(n_prefill=1, n_decode=1, decode_slots=4, max_len=96,
                        oas=OASConfig(defer_window=0.0))
    srv = Server(cfg, scfg)
    rng = np.random.default_rng(1)
    reqs = [(tuple(rng.integers(0, cfg.vocab_size, int(rng.integers(4, 12)))), 4)
            for _ in range(5)]
    s = srv.run(reqs, max_wall_s=120)
    assert s["n_done"] == 5
    assert s["qpm"] > 0
    assert all(np.isfinite(s[k]) for k in ("ttft_mean", "tpot_mean_ms"))


def test_server_moe_arch(small):
    cfg = reduced_config("qwen2-moe-a2.7b").with_updates(n_layers=2)
    scfg = ServerConfig(n_prefill=1, n_decode=1, decode_slots=2, max_len=64,
                        oas=OASConfig(defer_window=0.0))
    srv = Server(cfg, scfg)
    rng = np.random.default_rng(2)
    reqs = [(tuple(rng.integers(0, cfg.vocab_size, 6)), 3) for _ in range(2)]
    s = srv.run(reqs, max_wall_s=120)
    assert s["n_done"] == 2
