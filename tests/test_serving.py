"""Serving engine + server e2e: continuous batching correctness, PD handoff,
fault injection, training resume (integration)."""
import subprocess
import sys
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import reduced_config
from repro.core.proxy import OASConfig
from repro.distributed.ctx import local_mesh_ctx
from repro.models import LM
from repro.serving import DecodeEngine, PrefillEngine, Server, ServerConfig
from repro.serving.kvpool import KVPool


@pytest.fixture(scope="module")
def small():
    mesh = local_mesh_ctx()
    cfg = reduced_config("qwen2-1.5b").with_updates(
        compute_dtype="float32", param_dtype="float32", n_layers=2)
    lm = LM.build(cfg, mesh, pattern=[0, 0])
    params = lm.init(jax.random.PRNGKey(0))
    return cfg, lm, params


def greedy_reference(lm, params, prompt, n):
    toks = jnp.asarray([list(prompt)], jnp.int32)
    cache, logits, _ = lm.prefill(params, {"tokens": toks}, max_len=96)
    out = []
    pos = len(prompt)
    for i in range(n):
        nxt = int(jnp.argmax(logits[0]))
        out.append(nxt)
        if i == n - 1:
            break
        cache, logits, _ = lm.decode(params, cache, jnp.asarray([[nxt]]),
                                     jnp.int32(pos))
        pos += 1
    return out


def test_batched_decode_matches_single_stream(small):
    """Two requests decoded TOGETHER in engine slots must produce the same
    greedy continuations as isolated reference decoding."""
    cfg, lm, params = small
    pe = PrefillEngine(lm, params, None, max_len=96)
    de = DecodeEngine(lm, params, None, n_slots=4, max_len=96)
    rng = np.random.default_rng(0)
    prompts = [tuple(rng.integers(0, cfg.vocab_size, 9)),
               tuple(rng.integers(0, cfg.vocab_size, 17))]
    refs = [greedy_reference(lm, params, p, 6) for p in prompts]
    outs = {i: [] for i in range(2)}
    for i, p in enumerate(prompts):
        cache, first, _ = pe.process(p)
        assert de.admit(i, cache, first, len(p))
        outs[i].append(first)
    for _ in range(5):
        toks = de.step()
        for rid, t in toks.items():
            outs[rid].append(t)
    for i in range(2):
        assert outs[i] == refs[i], f"request {i}"


def test_engine_slot_release_and_reuse(small):
    cfg, lm, params = small
    pe = PrefillEngine(lm, params, None, max_len=96)
    de = DecodeEngine(lm, params, None, n_slots=1, max_len=96)
    cache, first, _ = pe.process((1, 2, 3))
    assert de.admit(0, cache, first, 3)
    assert not de.has_capacity()
    assert not de.admit(1, cache, first, 3)
    de.step()
    de.release(0)
    assert de.has_capacity()
    assert de.admit(1, cache, first, 3)


def test_prefill_exact_cache_hit(small):
    cfg, lm, params = small
    pe = PrefillEngine(lm, params, None, max_len=96)
    p = (5, 6, 7, 8)
    pe.process(p)
    n0 = pe.stats["prefills"]
    pe.process(p)
    assert pe.stats["prefills"] == n0
    assert pe.stats["cache_hits"] == 1


def test_kvpool_admission():
    pool = KVPool(n_blocks=4, block_size=16)
    assert pool.allocate(1, 40) is not None     # 3 blocks
    assert not pool.can_admit(40)               # only 1 left
    assert pool.allocate(2, 10) is not None     # 1 block
    assert pool.allocate(3, 1) is None
    pool.release(1)
    assert pool.allocate(3, 30) is not None     # 2 blocks
    assert pool.utilization == 0.75
    pool.check_invariants()


def test_chunked_prefill_matches_full(small):
    """Chunked engine prefill (threaded resume chunks) must match the
    blocking whole-prompt path: same first token, matching stored logits,
    and identical greedy continuation through the decode engine."""
    cfg, lm, params = small
    rng = np.random.default_rng(3)
    prompt = tuple(rng.integers(0, cfg.vocab_size, 37))
    ref = greedy_reference(lm, params, prompt, 6)

    pe_full = PrefillEngine(lm, params, None, max_len=96, enable_chunked=False)
    pe_chunk = PrefillEngine(lm, params, None, max_len=96, chunk_tokens=16)
    assert pe_chunk.chunked and not pe_full.chunked
    cache_f, first_f, _ = pe_full.process(prompt)
    cache_c, first_c, _ = pe_chunk.process(prompt)
    assert first_c == first_f == ref[0]
    assert pe_chunk.stats["chunks"] >= 3
    lf = pe_full.store.lookup(prompt)[2]
    lc = pe_chunk.store.lookup(prompt)[2]
    np.testing.assert_allclose(np.asarray(lf), np.asarray(lc),
                               rtol=1e-4, atol=1e-4)

    de = DecodeEngine(lm, params, None, n_slots=2, max_len=96)
    assert de.admit(0, cache_c, first_c, len(prompt))
    outs = [first_c]
    for _ in range(5):
        outs.append(de.step()[0])
    assert outs == ref


def test_prefix_reuse_suffix_only(small):
    """A prompt sharing an N-token prefix with a stored entry must prefill
    only the suffix (token counter) and produce logits matching the
    from-scratch path."""
    cfg, lm, params = small
    rng = np.random.default_rng(4)
    p1 = tuple(rng.integers(0, cfg.vocab_size, 24))
    suffix = tuple(rng.integers(0, cfg.vocab_size, 13))
    p2 = p1 + suffix

    pe = PrefillEngine(lm, params, None, max_len=96, chunk_tokens=16)
    pe.process(p1)
    assert pe.stats["tokens"] == len(p1)
    cache2, first2, _ = pe.process(p2)
    assert pe.stats["prefix_hits"] == 1
    assert pe.stats["reused_tokens"] == len(p1)
    assert pe.stats["tokens"] == len(p1) + len(suffix)   # suffix work only

    # from-scratch reference: logits and greedy continuation must agree
    scratch = PrefillEngine(lm, params, None, max_len=96, chunk_tokens=16)
    cache_ref, first_ref, _ = scratch.process(p2)
    assert first2 == first_ref
    l_re = pe.store.lookup(p2)[2]
    l_ref = scratch.store.lookup(p2)[2]
    np.testing.assert_allclose(np.asarray(l_re), np.asarray(l_ref),
                               rtol=1e-4, atol=1e-4)
    de = DecodeEngine(lm, params, None, n_slots=2, max_len=96)
    assert de.admit(0, cache2, first2, len(p2))
    assert de.admit(1, cache_ref, first_ref, len(p2))
    for _ in range(4):
        toks = de.step()
        assert toks[0] == toks[1]

    # exact re-submission: no new compute
    n_before = pe.stats["tokens"]
    pe.process(p2)
    assert pe.stats["tokens"] == n_before
    assert pe.stats["cache_hits"] == 1


def test_batched_admission_matches_references(small):
    """Three caches admitted in ONE donated insert call must decode exactly
    like isolated reference streams."""
    cfg, lm, params = small
    pe = PrefillEngine(lm, params, None, max_len=96)
    de = DecodeEngine(lm, params, None, n_slots=4, max_len=96)
    rng = np.random.default_rng(5)
    prompts = [tuple(rng.integers(0, cfg.vocab_size, n)) for n in (7, 12, 19)]
    refs = [greedy_reference(lm, params, p, 5) for p in prompts]
    items = []
    for i, p in enumerate(prompts):
        cache, first, _ = pe.process(p)
        items.append((i, cache, first, len(p), 0))
    granted = de.admit_batch(items)
    assert all(granted.values())
    outs = {i: [items[i][2]] for i in range(3)}
    for _ in range(4):
        for rid, t in de.step().items():
            outs[rid].append(t)
    for i in range(3):
        assert outs[i] == refs[i], f"request {i}"
    # O(1) release bookkeeping stays consistent
    de.release(1)
    assert 1 not in de.rid_slot and len(de.free) == 2
    assert sorted(de.slot_rid.values()) == [0, 2]


def test_decode_preemption_on_block_exhaustion(small):
    """pool.extend failure must preempt the request (slot + blocks freed,
    cache extracted) and re-admission must resume the exact token stream."""
    cfg, lm, params = small
    pe = PrefillEngine(lm, params, None, max_len=96)
    de = DecodeEngine(lm, params, None, n_slots=2, max_len=96,
                      kv_blocks=3)    # block_size=16 → 48 tokens total
    prompt = tuple(np.random.default_rng(6).integers(0, cfg.vocab_size, 14))
    ref = greedy_reference(lm, params, prompt, 8)
    cache, first, _ = pe.process(prompt)
    assert de.admit(0, cache, first, len(prompt))       # 1 block (15 tokens)
    assert de.admit(1, cache, first, len(prompt))       # 1 block
    outs = {0: [first], 1: [first]}
    # decoding grows both requests; at the 16-token crossing each needs a new
    # block — the pool (1 spare) can only serve one, the other preempts
    preempted = None
    for _ in range(8):
        for r, t in de.step().items():
            outs[r].append(t)
        if de.preempted:
            preempted = de.preempted.pop(0)
            break
    assert preempted is not None
    assert de.stats["preemptions"] == 1
    rid, cache_one, tok, pos = preempted
    assert rid not in de.rid_slot and len(de.free) == 1
    assert tok == outs[rid][-1] and pos == len(prompt) + len(outs[rid]) - 1
    # free the survivor's blocks, re-admit the preempted stream, and check
    # it continues the exact reference token sequence
    de.release(1 - rid)
    assert de.admit(rid, cache_one, tok, pos)
    while len(outs[rid]) < len(ref):
        outs[rid].append(de.step()[rid])
    assert outs[rid] == ref


def test_kvpool_denial_extend_release_readmit():
    pool = KVPool(n_blocks=3, block_size=16)
    assert pool.allocate(1, 30) is not None     # 2 blocks
    assert pool.allocate(2, 20) is None         # needs 2, only 1 free
    assert pool.allocate(2, 10) is not None     # 1 block
    assert pool.extend(1, 30, 35) is None  # crosses 32 → needs a 3rd block
    assert pool.extend(1, 30, 32) == []    # same block: free
    pool.release(2)
    assert pool.extend(1, 32, 35)          # now fits
    assert pool.free_blocks == 0
    pool.release(1)
    assert pool.free_blocks == 3
    assert pool.allocate(3, 48) is not None     # release → readmit full pool
    # prefix-credited admission only charges the non-resident suffix
    pool.release(3)
    assert pool.allocate(4, 48, cached_tokens=32) is not None
    assert pool.free_blocks == 2
    pool.check_invariants()


def test_kvpool_partial_block_prefix_credit():
    """A cached prefix ending mid-block must only credit its FULL blocks:
    the partial tail block is the borrower's to allocate and copy (the
    pre-paging ceil arithmetic under-allocated by one block here)."""
    pool = KVPool(n_blocks=4, block_size=16)
    # 20 cached tokens = 1 full block + 4 tokens into the second: only ONE
    # block is shareable; admitting 40 tokens (3 blocks) must charge 2.
    assert pool.shareable_blocks(20) == 1
    t = pool.allocate(1, 40, cached_tokens=20)
    assert t is not None and pool.free_blocks == 4 - 2
    pool.release(1)
    assert pool.free_blocks == 4
    # physical sharing path: lender's full prefix block is mapped, borrower
    # owns the tail block privately, and release order cannot double-free
    pool = KVPool(n_blocks=5, block_size=16)
    lend = pool.allocate(10, 40)                # 3 blocks, rids 10/11 share
    assert lend is not None
    borrow = pool.allocate(11, 40, shared=lend[:1])
    assert borrow is not None
    assert borrow[0] == lend[0] and borrow[1] != lend[1]
    assert pool.refcount[lend[0]] == 2
    pool.release(10)                            # lender leaves first
    assert pool.refcount[lend[0]] == 1          # borrower still maps it
    pool.check_invariants()
    pool.release(11)
    assert pool.free_blocks == 5
    pool.check_invariants()


def test_kvpool_property_random_ops():
    """Randomized allocator property sweep: alloc/extend/share/release never
    double-free, never hand out a mapped block, and conserve the block
    population (checked after every op)."""
    rng = np.random.default_rng(0)
    pool = KVPool(n_blocks=24, block_size=8)
    live: dict[int, int] = {}           # rid → accounted tokens
    next_rid = 0
    for _ in range(1500):
        op = rng.integers(0, 3)
        if op == 0:                     # allocate, sometimes prefix-sharing
            n_tokens = int(rng.integers(1, 120))
            shared = None
            if live and rng.random() < 0.5:
                donor = int(rng.choice(list(live)))
                cached = int(rng.integers(0, min(live[donor], n_tokens) + 1))
                shared = pool.owned(donor)[:pool.shareable_blocks(cached)]
            t = pool.allocate(next_rid, n_tokens, shared=shared)
            if t is not None:
                assert len(t) == pool.blocks_for(n_tokens)
                live[next_rid] = n_tokens
            next_rid += 1
        elif op == 1 and live:          # extend
            rid = int(rng.choice(list(live)))
            grow = int(rng.integers(1, 20))
            if pool.extend(rid, live[rid], live[rid] + grow) is not None:
                live[rid] += grow
                assert len(pool.owned(rid)) == pool.blocks_for(live[rid])
        elif op == 2 and live:          # release
            rid = int(rng.choice(list(live)))
            pool.release(rid)
            del live[rid]
            pool.release(rid)           # double release must be a no-op
        pool.check_invariants()
    for rid in list(live):
        pool.release(rid)
    pool.check_invariants()
    assert pool.free_blocks == pool.n_blocks


def test_kvpool_property_hypothesis():
    """Same invariants driven by hypothesis (skipped where not installed)."""
    hyp = pytest.importorskip("hypothesis")
    st = pytest.importorskip("hypothesis.strategies")

    @hyp.given(st.lists(st.tuples(st.integers(0, 2), st.integers(0, 7),
                                  st.integers(1, 90)), max_size=60))
    @hyp.settings(deadline=None, max_examples=50)
    def run(ops):
        pool = KVPool(n_blocks=8, block_size=16)
        live: dict[int, int] = {}
        for kind, rid, n in ops:
            if kind == 0 and rid not in live:
                if pool.allocate(rid, n) is not None:
                    live[rid] = n
            elif kind == 1 and rid in live:
                if pool.extend(rid, live[rid], live[rid] + n) is not None:
                    live[rid] += n
            elif kind == 2:
                pool.release(rid)
                live.pop(rid, None)
            pool.check_invariants()
        for rid in list(live):
            pool.release(rid)
        assert pool.free_blocks == pool.n_blocks

    run()


def test_prefix_store_supersede_drops_old_entry():
    """Re-storing the same prompt must drop the superseded entry immediately
    instead of letting the dead (cache, logits) snapshot pin KV memory until
    LRU capacity eviction."""
    from repro.serving.kvpool import PrefixKVStore
    store = PrefixKVStore(capacity=8)
    store.put((1, 2, 3), "c1", "l1")
    assert len(store.entries) == 1
    store.put((1, 2, 3), "c2", "l2")
    assert len(store.entries) == 1            # old snapshot dropped eagerly
    assert store.lookup((1, 2, 3))[1:] == ("c2", "l2")
    # strict-prefix and unrelated entries are NOT superseded
    store.put((1, 2), "p", "lp")
    store.put((9, 9), "q", "lq")
    store.put((1, 2, 3), "c3", "l3")
    assert len(store.entries) == 3
    assert store.lookup((1, 2, 3))[1] == "c3"
    assert store.lookup((1, 2, 7))[1] == "p"
    assert store.lookup((9, 9))[1] == "q"


@pytest.mark.parametrize("block_size", [8, 16])
def test_paged_vs_dense_decode_equivalence(block_size):
    """Greedy outputs must be identical between the slot-dense and the
    physically paged decode paths, over a stack mixing full, sliding-window,
    and sink+recent-compressed attention layers."""
    from repro.configs.base import OmniAttnConfig
    mesh = local_mesh_ctx()
    cfg = reduced_config("qwen2-1.5b").with_updates(
        compute_dtype="float32", param_dtype="float32", n_layers=4,
        local_per_global=1, local_window=16,
        omniattn=OmniAttnConfig(sink_tokens=8, recent_tokens=24))
    lm = LM.build(cfg, mesh, pattern=[0, 0, 0, 1])
    specs = lm.plan.all_specs()
    assert any(s.window > 0 and not s.compressed for s in specs)
    assert any(s.compressed for s in specs)
    assert any(s.kind == "attn" and s.window == 0 and not s.compressed
               for s in specs)
    params = lm.init(jax.random.PRNGKey(1))
    pe = PrefillEngine(lm, params, None, max_len=96)
    rng = np.random.default_rng(7)
    prompts = [tuple(rng.integers(0, cfg.vocab_size, n)) for n in (9, 21, 33)]
    handoff = []
    for i, p in enumerate(prompts):
        cache, first, _ = pe.process(p)
        handoff.append((i, cache, first, len(p), 0, p))
    outs = {}
    for paged in (False, True):
        de = DecodeEngine(lm, params, None, n_slots=4, max_len=96,
                          paged=paged, block_size=block_size)
        granted = de.admit_batch(handoff)
        assert all(granted.values())
        o = {rid: [f] for rid, _, f, *_ in handoff}
        for _ in range(8):
            for rid, t in de.step().items():
                o[rid].append(t)
        outs[paged] = o
    assert outs[True] == outs[False]


def test_paged_prefix_sharing_maps_blocks(small):
    """A prefix-sharing admission must MAP the lender's full prefix blocks
    (refcount 2, no fresh allocation for them) and copy only from the
    partial tail block onward; the borrower must survive the lender's
    release and still decode the from-scratch greedy stream."""
    cfg, lm, params = small
    rng = np.random.default_rng(21)
    base = tuple(rng.integers(0, cfg.vocab_size, 32))     # 2 full blocks
    p1 = base + tuple(rng.integers(0, cfg.vocab_size, 8))
    p2 = base + tuple(rng.integers(0, cfg.vocab_size, 11))
    ref2 = greedy_reference(lm, params, p2, 7)

    pe = PrefillEngine(lm, params, None, max_len=96, chunk_tokens=16)
    de = DecodeEngine(lm, params, None, n_slots=4, max_len=96, block_size=16)
    c1, f1, _ = pe.process(p1)
    assert de.admit(0, c1, f1, len(p1), prompt=p1)
    fresh0 = de.stats["blocks_fresh"]
    c2, f2, _ = pe.process(p2)                 # radix-resumed at len(base)
    assert de.admit(1, c2, f2, len(p2), cached_tokens=len(base), prompt=p2)
    assert de.stats["blocks_shared"] == 2      # both full base blocks mapped
    t1, t2 = de.pool.owned(0), de.pool.owned(1)
    assert t2[:2] == t1[:2]                    # physically the same blocks
    assert de.pool.refcount[t1[0]] == de.pool.refcount[t1[1]] == 2
    assert de.stats["blocks_fresh"] - fresh0 == len(t2) - 2
    de.pool.check_invariants()

    outs = {1: [f2]}
    for _ in range(3):
        outs[1].append(de.step()[1])
    de.release(0)                              # lender leaves mid-stream
    assert de.pool.refcount[t1[0]] == 1        # borrower keeps the blocks
    while len(outs[1]) < len(ref2):
        outs[1].append(de.step()[1])
    assert outs[1] == ref2
    de.pool.check_invariants()


def test_paged_decode_past_max_len_no_crash(small):
    """A request decoding past max_len must not grow its block table past
    the row width (that used to IndexError); capacity pins at max_len, the
    overflow writes are dropped (null block — matching the dense path's OOB
    scatter drop), and the token stream stays dense-identical throughout."""
    cfg, lm, params = small
    pe = PrefillEngine(lm, params, None, max_len=32)
    outs = {}
    for paged in (False, True):
        de = DecodeEngine(lm, params, None, n_slots=2, max_len=32,
                          paged=paged)
        cache, first, _ = pe.process((1, 2, 3, 4, 5))
        assert de.admit(0, cache, first, 5)
        o = [first]
        for _ in range(32):                # runs well past 32-token capacity
            o.append(de.step()[0])
        assert int(de.tokens_h[de.rid_slot[0]]) == 32
        de.pool.check_invariants()
        outs[paged] = o
    assert outs[True] == outs[False]


def test_server_preemption_token_continuity(small):
    """Forced KV-exhaustion preemptions through the whole server must not
    drop or replay any sampled token: outputs are greedy-identical to an
    unconstrained run."""
    cfg, _, _ = small
    rng = np.random.default_rng(23)
    reqs = [(tuple(rng.integers(0, cfg.vocab_size, 14)), 8) for _ in range(2)]

    def run(kv_blocks):
        scfg = ServerConfig(n_prefill=1, n_decode=1, decode_slots=4,
                            max_len=96, kv_blocks=kv_blocks,
                            oas=OASConfig(defer_window=0.0))
        srv = Server(cfg, scfg, pattern=[0, 0])
        s = srv.run(reqs, max_wall_s=120)
        outs = {r.rid: tuple(r.output_tokens) for r in srv.metrics.done}
        return s, outs

    s_free, outs_free = run(None)              # unconstrained pool
    assert s_free["n_done"] == 2
    assert s_free["decode_stats"][0]["preemptions"] == 0
    s_tight, outs_tight = run(3)               # 3 blocks → forced preemption
    assert s_tight["n_done"] == 2
    assert s_tight["decode_stats"][0]["preemptions"] >= 1
    assert outs_tight == outs_free
    assert all(len(v) == 8 for v in outs_tight.values())


def test_radix_payload_prefix_store(small):
    from repro.core.proxy.radix import RadixTree
    from repro.serving.kvpool import PrefixKVStore
    tree = RadixTree()
    store = PrefixKVStore(tree, capacity=2)
    store.put((1, 2, 3, 4), "c1", "l1")
    store.put((1, 2, 3, 4, 5, 6), "c2", "l2")
    n, c, l = store.lookup((1, 2, 3, 4, 5, 6, 7, 8))
    assert (n, c) == (6, "c2")
    n, c, _ = store.lookup((1, 2, 3, 4, 9))
    assert (n, c) == (4, "c1")
    assert store.lookup((2, 1))[0] == 0
    store.put((8, 8, 8), "c3", "l3")       # beyond cap=2: LRU evicts c2
    assert len(store.entries) == 2
    # c2's payload is still attached in the tree but stale — lookup must
    # skip it and fall back to the shallower live entry
    n, c, _ = store.lookup((1, 2, 3, 4, 5, 6))
    assert (n, c) == (4, "c1")


def test_moe_migration_preserves_outputs():
    """Swapping expert slots via _apply_migration (weights + tables) must not
    change model outputs."""
    from repro.core.placement.migration import MigrationPlan
    cfg = reduced_config("qwen2-moe-a2.7b").with_updates(
        n_layers=2, compute_dtype="float32", param_dtype="float32")
    scfg = ServerConfig(n_prefill=1, n_decode=1, decode_slots=2, max_len=64,
                        oas=OASConfig(defer_window=0.0))
    srv = Server(cfg, scfg)
    rng = np.random.default_rng(8)
    toks = jnp.asarray([rng.integers(0, cfg.vocab_size, 9)], jnp.int32)
    _, logits_before, _ = srv.lm.prefill(srv.params, {"tokens": toks},
                                         max_len=64, tables=srv.tables)
    old_se = np.asarray(srv.tables["slot_expert"]).copy()
    new_se = old_se.copy()
    new_se[0, 0], new_se[0, 1] = old_se[0, 1], old_se[0, 0]   # swap two slots
    srv._apply_migration(MigrationPlan(old_se, new_se, ((0, 0, 0),), 1))
    assert srv.n_migrations == 1
    _, logits_after, _ = srv.lm.prefill(srv.params, {"tokens": toks},
                                        max_len=64, tables=srv.tables)
    np.testing.assert_allclose(np.asarray(logits_before),
                               np.asarray(logits_after), rtol=2e-4, atol=2e-4)


def test_server_prefix_reuse_end_to_end(small):
    """Shared-prefix prompts through the whole server: snapshot-at-boundary
    plus resume must cut computed prefill tokens."""
    cfg, _, _ = small
    scfg = ServerConfig(n_prefill=1, n_decode=1, decode_slots=4, max_len=96,
                        chunk_tokens=16, prefill_tick_budget=64,
                        oas=OASConfig(defer_window=0.0))
    srv = Server(cfg, scfg, pattern=[0, 0])
    rng = np.random.default_rng(9)
    base = tuple(rng.integers(0, cfg.vocab_size, 24))
    reqs = [(base + tuple(rng.integers(0, cfg.vocab_size, 8)), 3)
            for _ in range(4)]
    s = srv.run(reqs, max_wall_s=120)
    ps = s["prefill_stats"][0]
    assert s["n_done"] == 4
    assert ps["prefix_hits"] >= 1
    assert ps["tokens"] + ps["reused_tokens"] >= 4 * 32
    assert ps["tokens"] < 4 * 32          # strictly less than recompute-all


def test_server_decode_instance_failure_recovers(small):
    """A decode-instance death mid-run loses KV for its requests; the proxy
    requeues them and the server must route them back through prefill and
    still finish every request."""
    import time as _t
    cfg, _, _ = small
    scfg = ServerConfig(n_prefill=1, n_decode=1, decode_slots=4, max_len=96,
                        oas=OASConfig(defer_window=0.0))
    srv = Server(cfg, scfg, pattern=[0, 0])
    rng = np.random.default_rng(11)
    reqs = [(tuple(rng.integers(0, cfg.vocab_size, 8)), 6) for _ in range(3)]
    t0 = _t.monotonic()
    for i, (p, m) in enumerate(reqs):
        srv.submit(i, p, m, t0)
    # run a few ticks so requests reach decode, then kill the instance
    for _ in range(3):
        srv._drain_actions(_t.monotonic())
        srv._prefill_round()
        srv._decode_round()
    requeued = srv.proxy.mark_unhealthy("decode", 0, _t.monotonic())
    assert requeued, "expected in-flight decode work to be requeued"
    srv.proxy.mark_healthy("decode", 0)
    while srv.proxy.inflight and _t.monotonic() - t0 < 120:
        srv._drain_actions(_t.monotonic())
        srv._prefill_round()
        srv._decode_round()
    s = srv.metrics.summary(_t.monotonic() - t0)
    assert s["n_done"] == 3
    for r in srv.metrics.done:
        assert len(r.output_tokens) == 6


def test_server_prefill_instance_fail_recover(small):
    """Fail + recover a prefill instance while its engine holds half-done
    chunked tasks: the re-dispatched requests must supersede the stale tasks
    (no duplicate first tokens, accounting balanced)."""
    import time as _t
    cfg, _, _ = small
    scfg = ServerConfig(decode_slots=4, max_len=96, chunk_tokens=8,
                        prefill_tick_budget=8, oas=OASConfig(defer_window=0.0))
    srv = Server(cfg, scfg, pattern=[0, 0])
    rng = np.random.default_rng(13)
    t0 = _t.monotonic()
    for i in range(2):
        srv.submit(i, tuple(rng.integers(0, cfg.vocab_size, 20)), 4, t0)
    srv._drain_actions(_t.monotonic())
    srv._prefill_round()              # partial progress only (tiny budget)
    srv.proxy.mark_unhealthy("prefill", 0, _t.monotonic())
    srv.proxy.mark_healthy("prefill", 0)
    while srv.proxy.inflight and _t.monotonic() - t0 < 120:
        srv._drain_actions(_t.monotonic())
        srv._prefill_round()
        srv._decode_round()
    s = srv.metrics.summary(_t.monotonic() - t0)
    assert s["n_done"] == 2
    assert all(len(r.output_tokens) == 4 for r in srv.metrics.done)
    assert srv.proxy.prefill[0].running == 0


def test_server_end_to_end(small):
    cfg, _, _ = small
    scfg = ServerConfig(n_prefill=1, n_decode=1, decode_slots=4, max_len=96,
                        oas=OASConfig(defer_window=0.0))
    srv = Server(cfg, scfg)
    rng = np.random.default_rng(1)
    reqs = [(tuple(rng.integers(0, cfg.vocab_size, int(rng.integers(4, 12)))), 4)
            for _ in range(5)]
    s = srv.run(reqs, max_wall_s=120)
    assert s["n_done"] == 5
    assert s["qpm"] > 0
    assert all(np.isfinite(s[k]) for k in ("ttft_mean", "tpot_mean_ms"))


def test_server_moe_arch(small):
    cfg = reduced_config("qwen2-moe-a2.7b").with_updates(n_layers=2)
    scfg = ServerConfig(n_prefill=1, n_decode=1, decode_slots=2, max_len=64,
                        oas=OASConfig(defer_window=0.0))
    srv = Server(cfg, scfg)
    rng = np.random.default_rng(2)
    reqs = [(tuple(rng.integers(0, cfg.vocab_size, 6)), 3) for _ in range(2)]
    s = srv.run(reqs, max_wall_s=120)
    assert s["n_done"] == 2
