"""Per-arch REDUCED smoke tests (required): one forward/train step on CPU,
asserting output shapes + no NaNs; plus a decode step for decoder archs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, reduced_config
from repro.models import LM
from repro.training.data import DataConfig, make_batch
from repro.training.optim import adamw_init
from repro.training.trainer import make_train_step


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_step_smoke(arch, mesh1):
    cfg = reduced_config(arch)
    lm = LM.build(cfg, mesh1)
    params = lm.init(jax.random.PRNGKey(0))
    tables = lm.default_tables()
    dcfg = DataConfig(cfg.vocab_size, 64, 2)
    batch = make_batch(cfg, dcfg, 0)
    step = jax.jit(make_train_step(lm, lr=1e-3))
    opt = adamw_init(params, cfg.optimizer_dtype)
    new_params, _, metrics = step(params, opt, batch, tables)
    loss = float(metrics["loss"])
    assert np.isfinite(loss) and 0 < loss < 50
    # params actually moved
    moved = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(
        a.astype(jnp.float32) - b.astype(jnp.float32)))), params, new_params)
    assert max(jax.tree.leaves(moved)) > 0


@pytest.mark.parametrize("arch", [a for a in ARCH_IDS
                                  if not reduced_config(a).encoder_only])
def test_prefill_decode_smoke(arch, mesh1):
    cfg = reduced_config(arch)
    lm = LM.build(cfg, mesh1)
    params = lm.init(jax.random.PRNGKey(0))
    tables = lm.default_tables()
    B, S = 2, 32
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab_size)
    batch = {"tokens": toks}
    if cfg.family == "vlm":
        batch["patches"] = jnp.ones((B, cfg.num_patches, cfg.frontend_dim),
                                    jnp.float32)
    cache, logits, _ = lm.prefill(params, batch, max_len=S + 8, tables=tables)
    assert logits.shape == (B, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))
    pos = S + (cfg.num_patches if cfg.family == "vlm" else 0)
    cache, logits, _ = lm.decode(params, cache, toks[:, :1],
                                 jnp.int32(pos), tables=tables)
    assert logits.shape == (B, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))


def test_encoder_only_forward(mesh1):
    cfg = reduced_config("hubert-xlarge")
    lm = LM.build(cfg, mesh1)
    params = lm.init(jax.random.PRNGKey(0))
    B, S = 2, 32
    batch = {"frames": jnp.ones((B, S, cfg.frontend_dim), jnp.float32)}
    _, logits, _ = lm.prefill(params, batch, max_len=S)
    assert logits.shape == (B, S, cfg.vocab_size)   # per-frame logits
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))
