"""OmniAttn: fidelity properties + GA pattern search behavior."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.configs import get_config, reduced_config
from repro.core.omniattn import (
    GAConfig, PatternSearch, attention_fidelity, kv_bytes_for_pattern,
    sink_recent_indices,
)


def test_sink_recent_indices_shape():
    idx = sink_recent_indices(100, 8, 16)
    assert len(idx) == 24
    assert list(idx[:8]) == list(range(8))
    assert list(idx[-16:]) == list(range(84, 100))
    # degenerate: subset covers everything
    assert len(sink_recent_indices(10, 8, 16)) == 10


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 1000))
def test_fidelity_improves_with_budget(seed):
    """More retained tokens → attention output error weakly decreases."""
    rng = jax.random.PRNGKey(seed)
    r1, r2, r3 = jax.random.split(rng, 3)
    M, d = 128, 32
    q = jax.random.normal(r1, (4, d))
    k = jax.random.normal(r2, (M, d))
    v = jax.random.normal(r3, (M, d))
    errs = [attention_fidelity(q, k, v, 4, n)["rel_err"]
            for n in (8, 32, 124)]
    assert errs[2] <= errs[0] + 1e-6
    assert errs[2] < 1e-5                       # full coverage → exact


def test_fidelity_with_sink_concentration():
    """When attention mass sits on sinks+recents (the paper's premise), the
    approximation is good even at small budgets."""
    rng = jax.random.PRNGKey(0)
    r1, r2, r3 = jax.random.split(rng, 3)
    M, d = 256, 32
    k = jax.random.normal(r2, (M, d)) * 0.05    # flat keys...
    k = k.at[:4].add(2.0)                       # ...except strong sinks
    k = k.at[-32:].add(1.0)                     # and recent emphasis
    q = jax.random.normal(r1, (8, d)) + k[:4].mean(0) * 0.5
    v = jax.random.normal(r3, (M, d))
    out = attention_fidelity(q, k, v, 4, 32)
    assert out["attn_mass"] > 0.6
    assert out["rel_err"] < 0.35


def test_kv_bytes_pattern_monotone():
    cfg = get_config("qwen3-32b")
    zero = kv_bytes_for_pattern(cfg, np.zeros(cfg.n_layers, np.int64), 32768)
    full = kv_bytes_for_pattern(cfg, np.ones(cfg.n_layers, np.int64), 32768)
    half = kv_bytes_for_pattern(
        cfg, np.array([1, 0] * (cfg.n_layers // 2), np.int64), 32768)
    assert full < half < zero
    # compression only helps beyond the window
    W = cfg.omniattn.sink_tokens + cfg.omniattn.recent_tokens
    assert kv_bytes_for_pattern(cfg, np.ones(cfg.n_layers, np.int64), W) == \
        kv_bytes_for_pattern(cfg, np.zeros(cfg.n_layers, np.int64), W)


def test_ga_finds_feasible_compression():
    """Synthetic evaluator: accuracy drops with compressed-layer count; GA
    must find the largest feasible compression."""
    cfg = reduced_config("qwen3-32b").with_updates(n_layers=8)

    def evaluate(pattern):
        return 1.0 - 0.02 * pattern.sum()       # 2% penalty per layer

    ps = PatternSearch(cfg, evaluate, GAConfig(population=12, generations=12,
                                               accuracy_tau=0.9, seed=0),
                       seq_len=8192)
    out = ps.run()
    assert out["feasible"]
    n = out["pattern"].sum()
    assert 4 <= n <= 5          # τ=0.9 → at most 5 layers @ 2% each
    assert out["kv_gain"] > 0.3


def test_ga_respects_hard_accuracy():
    cfg = reduced_config("qwen2-1.5b").with_updates(n_layers=6)

    def evaluate(pattern):                      # any compression breaks it
        return 1.0 if pattern.sum() == 0 else 0.0

    ps = PatternSearch(cfg, evaluate, GAConfig(population=10, generations=8,
                                               accuracy_tau=0.99, seed=1))
    out = ps.run()
    assert out["pattern"].sum() == 0            # identity pattern wins


def test_ga_periodic_restriction():
    cfg = get_config("qwen3-32b")

    def evaluate(pattern):
        return 1.0 - 0.001 * pattern.sum()

    ps = PatternSearch(cfg, evaluate, GAConfig(population=8, generations=4,
                                               periodic=4, seed=2))
    out = ps.run()
    pat = out["pattern"]
    period = pat[:4]
    for i in range(0, len(pat) - 4, 4):
        assert (pat[i:i + 4] == period).all()
