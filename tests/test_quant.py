"""QuantPlane: int8 paged KV arenas with per-block scales (PR 10).

Covers the quantized-arena contract at every layer:

  · controller validation — bits≠8 and dense-KV requests raise; a stack
    with no full-attention layer degrades to None (quant off); the
    residency compression figure clears the ≥1.9x bar;
  · format purity — per-token provisional quantization and seal-on-full
    are pure functions of the written content, so any write grouping
    lands the same bytes (the bit-identity mechanism);
  · unseal-on-open — a freed sealed block reallocated WITHOUT scrubbing
    must have its stale per-channel scale cleared before the new owner's
    tokens land;
  · zero-stale-scales — `KVArena.check_summaries` passes at quiescent
    points across e2e serving, CoW prefix sharing, preemption round-trips
    and store adoption/resume;
  · behavior — quant-ON greedy outputs equal quant-OFF outputs on the
    test model, quant-OFF arenas carry no scale leaves (byte-identical
    trees), and dtype-true block accounting roughly halves bytes/block.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import reduced_config
from repro.core.proxy import OASConfig
from repro.distributed.ctx import local_mesh_ctx
from repro.models import LM
from repro.models import attention as attn
from repro.serving import (DecodeEngine, PrefillEngine, Server, ServerConfig,
                           SpecConfig)
from repro.serving.arena import KVArena
from repro.serving.quant import QuantConfig, QuantController


@pytest.fixture(scope="module")
def small():
    mesh = local_mesh_ctx()
    cfg = reduced_config("qwen2-1.5b").with_updates(
        compute_dtype="float32", param_dtype="float32", n_layers=2)
    lm = LM.build(cfg, mesh, pattern=[0, 0])
    params = lm.init(jax.random.PRNGKey(0))
    return cfg, lm, params


@pytest.fixture(scope="module", autouse=True)
def _release_compiled_executables():
    yield
    jax.clear_caches()


def _server(cfg, quant, **kw):
    scfg = ServerConfig(n_prefill=1, n_decode=1, decode_slots=4, max_len=96,
                        chunk_tokens=16, prefill_tick_budget=64,
                        oas=OASConfig(defer_window=0.0),
                        quant=QuantConfig() if quant else None, **kw)
    return Server(cfg, scfg, pattern=[0, 0])


def _outputs(srv):
    return {r.rid: tuple(r.output_tokens) for r in srv.metrics.done}


# ------------------------------------------------------------ controller
def test_controller_validation(small):
    cfg, lm, _ = small
    mk = lambda q, **kw: QuantController.from_model(
        cfg, lm.plan, q, 16, **kw)
    assert mk(None) is None
    with pytest.raises(ValueError, match="int8"):
        mk(QuantConfig(bits=4))
    with pytest.raises(ValueError, match="paged"):
        mk(QuantConfig(), paged_kv=False)
    ctl = mk(QuantConfig())
    assert ctl is not None
    assert ctl.plan.n_quant_layers == 2
    assert ctl.compression() > 1.9
    stats = QuantController.stats_keys()
    ctl.note(stats)
    assert stats["quant_block_bytes"] * 1.9 < stats["quant_block_bytes_f32"]


def test_controller_degrades_without_full_attention():
    """An all-ring stack has no paged full-attention arena to quantize:
    the controller must degrade to None (quant off), not raise."""
    mesh = local_mesh_ctx()
    cfg = reduced_config("qwen2-1.5b").with_updates(
        compute_dtype="float32", param_dtype="float32", n_layers=2)
    lm = LM.build(cfg, mesh, pattern=[1, 1])    # every layer ring-buffered
    assert QuantController.from_model(cfg, lm.plan, QuantConfig(), 16) is None


# ----------------------------------------------------------- format unit
def test_quant_tokens_grouping_independent():
    """Per-token quantization is a pure per-token function: quantizing a
    sequence whole or split at any boundary lands identical ints/scales —
    the mechanism that makes chunked prefill, decode appends and
    store-resume offsets bit-compatible."""
    x = jax.random.normal(jax.random.PRNGKey(3), (10, 2, 32))
    q, ts = attn.quant_tokens(x)
    for cut in (1, 4, 7):
        qa, ta = attn.quant_tokens(x[:cut])
        qb, tb = attn.quant_tokens(x[cut:])
        np.testing.assert_array_equal(np.asarray(q),
                                      np.concatenate([qa, qb]))
        np.testing.assert_array_equal(np.asarray(ts),
                                      np.concatenate([ta, tb]))
    # zero tokens: ts = 0, q = 0 (dequant multiplies by the stored zero)
    qz, tz = attn.quant_tokens(jnp.zeros((2, 1, 8)))
    assert not np.asarray(qz).any() and not np.asarray(tz).any()


def test_seal_blocks_pure_and_null_exempt():
    """Sealing re-quantizes the STORED (int8, tok) payload — a pure
    function of block content, independent of write grouping — and the
    null block 0 must never seal."""
    rng = jax.random.split(jax.random.PRNGKey(4), 2)
    N, K, bs, h = 5, 2, 8, 16
    x = jax.random.normal(rng[0], (N, bs, K, h))
    q, ts = attn.quant_tokens(x)
    pages = q.transpose(0, 2, 1, 3)
    tok = ts.transpose(0, 2, 1)
    scale = jnp.zeros((N, K, h))
    blocks = jnp.array([0, 2, 3])
    do = jnp.array([True, True, False])
    p1, s1, t1 = attn.seal_blocks(pages, scale, tok, blocks, do)
    # null block exempt: content/scales untouched
    np.testing.assert_array_equal(np.asarray(p1[0]), np.asarray(pages[0]))
    assert not np.asarray(s1[0]).any()
    # unsealed block untouched
    np.testing.assert_array_equal(np.asarray(p1[3]), np.asarray(pages[3]))
    assert not np.asarray(s1[3]).any()
    # sealed block: nonzero per-channel row, zeroed tok row, and the
    # re-quantized content stays within one per-channel grid step of the
    # per-token content it replaced
    assert np.asarray(s1[2]).all() and not np.asarray(t1[2]).any()
    pre = attn.dequant_pages(pages, scale, tok)
    post = attn.dequant_pages(p1, s1, t1)
    step = np.asarray(s1[2]).max()
    np.testing.assert_allclose(np.asarray(post[2]), np.asarray(pre[2]),
                               atol=step, rtol=0)
    # determinism: sealing the same stored payload again from the same
    # pre-seal state lands identical bytes (grouping independence)
    p1b, s1b, t1b = attn.seal_blocks(pages, scale, tok, blocks, do)
    np.testing.assert_array_equal(np.asarray(p1), np.asarray(p1b))
    np.testing.assert_array_equal(np.asarray(s1), np.asarray(s1b))


def test_unseal_on_open():
    """A reallocated (unscrubbed) sealed block must have its stale
    per-channel scale cleared when the new owner's offset-0 token lands —
    otherwise the dequant rule reads the previous owner's seal scale over
    per-token content."""
    rng = jax.random.split(jax.random.PRNGKey(5), 3)
    N, K, bs, h = 4, 2, 8, 16
    x = jax.random.normal(rng[0], (N, bs, K, h))
    q, ts = attn.quant_tokens(x)
    entry = {"k": q.transpose(0, 2, 1, 3), "v": q.transpose(0, 2, 1, 3),
             "ktok": ts.transpose(0, 2, 1), "vtok": ts.transpose(0, 2, 1),
             "kscale": jnp.zeros((N, K, h)), "vscale": jnp.zeros((N, K, h))}
    for n in ("kscale", "vscale"):
        entry[n] = entry[n].at[2].set(0.5)      # block 2: stale prior seal
    k_new = jax.random.normal(rng[1], (1, K, h))
    out = attn.quant_paged_cache_write(entry, k_new, k_new,
                                       jnp.array([2]), jnp.array([0]))
    assert not np.asarray(out["kscale"][2]).any(), "stale seal survived"
    got = attn.dequant_pages(out["k"], out["kscale"], out["ktok"])[2, :, 0]
    qe, te = attn.quant_tokens(k_new[0])
    np.testing.assert_allclose(np.asarray(got),
                               np.asarray(qe.astype(jnp.float32)
                                          * te[..., None]),
                               rtol=1e-6, atol=1e-6)


# ------------------------------------------------------------------ e2e
def test_server_quant_outputs_match_f32(small):
    """Greedy serving with int8 arenas: outputs equal the f32 run on the
    test model, the extended summary+scale scan passes quiescent, and the
    dtype-true block accounting roughly halves bytes per block."""
    cfg, _, _ = small
    rng = np.random.default_rng(11)
    reqs = [(tuple(rng.integers(0, cfg.vocab_size, 12)), 5) for _ in range(4)]
    s0 = _server(cfg, quant=False)
    s0.run(reqs, max_wall_s=300)
    s1 = _server(cfg, quant=True)
    sm = s1.run(reqs, max_wall_s=300)
    assert sm["n_done"] == 4
    assert _outputs(s0) == _outputs(s1)
    assert s1.kv_arena.quant and not s0.kv_arena.quant
    s1.kv_arena.check_summaries()
    ratio = s1.kv_arena.block_nbytes / s0.kv_arena.block_nbytes
    assert ratio < 0.55, f"quant block bytes ratio {ratio:.3f}"
    ds = sm["decode_stats"][0]
    assert ds["quant_layers"] == 2
    assert ds["quant_block_bytes"] * 1.9 < ds["quant_block_bytes_f32"]


def test_quant_off_tree_has_no_scale_leaves(small):
    """Quant-OFF arenas must be byte-identical to the pre-QuantPlane tree:
    no scale leaves, f32 payloads, structural `quant` property False."""
    cfg, lm, _ = small
    arena = KVArena.build(lm, 6)
    assert not arena.quant
    for part in ("period", "rem"):
        for e in arena.kv[part]:
            if e is None:
                continue
            assert "kscale" not in e and "ktok" not in e
            if "kmin" in e:
                assert e["k"].dtype == jnp.float32


def test_quant_prefix_sharing_and_pressure_bit_identical(small):
    """Shared-prefix workload under arena pressure with quant ON: CoW
    block sharing, store adoption/resume and tail copies all round-trip
    the scale plane — outputs bit-identical to quant-OFF, scan clean."""
    cfg, _, _ = small
    rng = np.random.default_rng(12)
    base = tuple(rng.integers(0, cfg.vocab_size, 24))
    reqs = [(base + tuple(rng.integers(0, cfg.vocab_size, 28)), 10)
            for _ in range(6)]
    s1 = _server(cfg, quant=True, kv_blocks=22)
    sm = s1.run(reqs, max_wall_s=300)
    assert sm["n_done"] == 6
    assert sm["prefill_stats"][0]["prefix_hits"] >= 1
    s1.kv_arena.check_summaries()
    s0 = _server(cfg, quant=False, kv_blocks=22)
    s0.run(reqs, max_wall_s=300)
    assert _outputs(s0) == _outputs(s1)


def test_quant_preemption_roundtrip_bit_identical(small):
    """Preempt → extract (dequantized dense + raw int8 sidecar) →
    re-admit (verbatim sidecar scatter) must resume the exact greedy
    stream; the scale plane survives the round-trip."""
    cfg, lm, params = small
    arena = KVArena.build(lm, 3, quant=True)
    pe = PrefillEngine(lm, params, None, max_len=96)
    de = DecodeEngine(lm, params, None, n_slots=2, max_len=96, arena=arena)
    prompt = tuple(np.random.default_rng(6).integers(0, cfg.vocab_size, 14))
    toks = jnp.asarray([list(prompt)], jnp.int32)
    cache_r, logits, _ = lm.prefill(params, {"tokens": toks}, max_len=96)
    ref, pos = [], len(prompt)
    for i in range(8):
        nxt = int(jnp.argmax(logits[0]))
        ref.append(nxt)
        if i == 7:
            break
        cache_r, logits, _ = lm.decode(params, cache_r,
                                       jnp.asarray([[nxt]]), jnp.int32(pos))
        pos += 1
    cache, first, _ = pe.process(prompt)
    assert de.admit(0, cache, first, len(prompt))
    assert de.admit(1, cache, first, len(prompt))
    outs = {0: [first], 1: [first]}
    preempted = None
    for _ in range(8):
        for r, t in de.step().items():
            outs[r].append(t)
        if de.preempted:
            preempted = de.preempted.pop(0)
            break
    assert preempted is not None and de.stats["preemptions"] == 1
    rid, cache_one, tok, pos = preempted
    leaves = sorted({k for part in ("period", "rem")
                     for e in cache_one.get("attn", cache_one)[part]
                     if isinstance(e, dict) for k in e})
    assert {"kq", "kscale", "ktok", "vq", "vscale", "vtok"} <= set(leaves), \
        f"extracted cache missing quant sidecar: {leaves}"
    de.release(1 - rid)
    assert de.admit(rid, cache_one, tok, pos)
    while len(outs[rid]) < len(ref):
        outs[rid].append(de.step()[rid])
    assert outs[rid] == ref
    arena.check_summaries()


def test_quant_spec_compose_bit_identical(small):
    """QuantPlane × SpecPlane: speculative decoding over int8 arenas
    (spec_verify's in-tile dequant + block/summary/scale rollback) must
    land the same greedy outputs as the plain f32 run."""
    cfg, _, _ = small
    rng = np.random.default_rng(13)
    gram = tuple(int(t) for t in rng.integers(0, cfg.vocab_size, 6))
    reqs = [(gram * 3, 10) for _ in range(2)] + \
        [(tuple(rng.integers(0, cfg.vocab_size, 18)), 10) for _ in range(2)]
    s0 = _server(cfg, quant=False)
    s0.run(reqs, max_wall_s=300)
    s1 = _server(cfg, quant=True, spec=SpecConfig(k=4))
    sm = s1.run(reqs, max_wall_s=300)
    assert sm["n_done"] == 4
    assert _outputs(s0) == _outputs(s1)
    s1.kv_arena.check_summaries()
