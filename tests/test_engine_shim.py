"""serving/engine.py is a back-compat shim over the per-phase modules —
assert it stays one (≤ 100 lines) and keeps re-exporting the same objects
the real modules define, so old `from repro.serving.engine import X` call
sites never drift from the split."""
import inspect

import repro.serving.arena as arena
import repro.serving.decode as decode
import repro.serving.engine as engine
import repro.serving.placement as placement
import repro.serving.prefill as prefill


def test_shim_stays_thin():
    src = inspect.getsource(engine)
    assert len(src.splitlines()) <= 100


def test_shim_reexports_are_identical_objects():
    homes = {
        "BlockHandoff": arena, "KVArena": arena,
        "blocks_to_dense_kv": arena, "dense_kv_to_blocks": arena,
        "kv_bytes": arena,
        "DecodeEngine": decode,
        "DevicePlacement": placement,
        "PrefillEngine": prefill, "PrefillResult": prefill,
        "PrefillTask": prefill,
    }
    assert set(engine.__all__) == set(homes)
    for name, mod in homes.items():
        assert getattr(engine, name) is getattr(mod, name), name


def test_shim_covers_module_public_surface():
    """Every public class/function defined in a per-phase module is reachable
    through the shim (private helpers exempt)."""
    for mod in (arena, decode, prefill):
        for name, obj in vars(mod).items():
            if name.startswith("_") or not (inspect.isclass(obj)
                                            or inspect.isfunction(obj)):
                continue
            if getattr(obj, "__module__", None) != mod.__name__:
                continue        # imported, not defined here
            assert getattr(engine, name, None) is obj, \
                f"{mod.__name__}.{name} missing from engine shim"
