"""MoE: shard_map EP vs dense oracle, router semantics, capacity behavior."""
from dataclasses import replace

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import reduced_config
from repro.models import moe as M


def _setup(cfg, mesh, T=48, cf=8.0, seed=0):
    cfg = replace(cfg, moe=replace(cfg.moe, capacity_factor=cf))
    E, Fe, D = cfg.moe.n_experts, cfg.moe.d_ff_expert, cfg.d_model
    s = M.default_slot_count(cfg, mesh.ep)
    tables = M.tables_from_placement(
        M.round_robin_placement(E, mesh.ep, s), s)
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    x = jax.random.normal(ks[0], (T, D), jnp.float32)
    rw = jax.random.normal(ks[1], (D, E), jnp.float32) * 0.1
    cw = [jax.random.normal(k, shp) * 0.05 for k, shp in
          zip(ks[2:], [(E, D, Fe), (E, D, Fe), (E, Fe, D)])]
    slots = [M.slots_from_canonical(c, tables["slot_expert"]) for c in cw]
    return cfg, x, rw, cw, slots, tables


def test_moe_matches_dense_oracle(mesh1):
    cfg = reduced_config("qwen3-moe-235b-a22b").with_updates(
        compute_dtype="float32", param_dtype="float32")
    cfg, x, rw, cw, slots, tables = _setup(cfg, mesh1)
    y, counts = M.moe_ffn(mesh1, cfg, x, rw, *slots, tables,
                          batch_part="data")
    want = M.moe_ffn_dense(cfg, x, rw, *cw)
    np.testing.assert_allclose(np.asarray(y), np.asarray(want),
                               rtol=3e-5, atol=3e-5)
    assert float(counts.sum()) == x.shape[0] * cfg.moe.top_k


def test_moe_shared_experts(mesh1):
    cfg = reduced_config("qwen2-moe-a2.7b").with_updates(
        compute_dtype="float32", param_dtype="float32")
    cfg, x, rw, cw, slots, tables = _setup(cfg, mesh1)
    Fe, D = cfg.moe.d_ff_expert, cfg.d_model
    Fsh = cfg.moe.n_shared_experts * Fe
    ks = jax.random.split(jax.random.PRNGKey(9), 3)
    shared = (jax.random.normal(ks[0], (D, Fsh)) * 0.05,
              jax.random.normal(ks[1], (D, Fsh)) * 0.05,
              jax.random.normal(ks[2], (Fsh, D)) * 0.05)
    y, _ = M.moe_ffn(mesh1, cfg, x, rw, *slots, tables, shared,
                     batch_part="data")
    want = M.moe_ffn_dense(cfg, x, rw, *cw, shared)
    np.testing.assert_allclose(np.asarray(y), np.asarray(want),
                               rtol=3e-5, atol=3e-5)


def test_router_norm_topk():
    cfg = reduced_config("qwen3-moe-235b-a22b")  # norm_topk_prob=True
    x = jax.random.normal(jax.random.PRNGKey(0), (16, cfg.d_model))
    rw = jax.random.normal(jax.random.PRNGKey(1), (cfg.d_model,
                                                   cfg.moe.n_experts))
    gates, idx, probs = M.router(cfg, x, rw)
    np.testing.assert_allclose(np.asarray(gates.sum(-1)), 1.0, rtol=1e-5)
    assert idx.shape == (16, cfg.moe.top_k)
    # indices are the true top-k of the softmax
    want_idx = np.argsort(-np.asarray(probs), axis=-1)[:, :cfg.moe.top_k]
    assert set(map(tuple, np.sort(np.asarray(idx), -1))) == \
        set(map(tuple, np.sort(want_idx, -1)))


def test_capacity_dropping_bounded(mesh1):
    """With tiny capacity the output degrades gracefully (no NaN/explosion)."""
    cfg = reduced_config("qwen3-moe-235b-a22b").with_updates(
        compute_dtype="float32", param_dtype="float32")
    cfg, x, rw, cw, slots, tables = _setup(cfg, mesh1, cf=0.25)
    y, counts = M.moe_ffn(mesh1, cfg, x, rw, *slots, tables,
                          batch_part="data")
    assert bool(jnp.all(jnp.isfinite(y)))
    # counts still reflect ROUTING (pre-drop)
    assert float(counts.sum()) == x.shape[0] * cfg.moe.top_k


def test_slots_from_canonical_empty_slots_zero():
    can = jnp.arange(4 * 2 * 3, dtype=jnp.float32).reshape(4, 2, 3) + 1
    se = np.array([[0, 1, -1], [2, 3, -1]])
    slots = M.slots_from_canonical(can, se)
    assert slots.shape == (2, 3, 2, 3)
    assert float(jnp.abs(slots[0, 2]).sum()) == 0.0
    np.testing.assert_allclose(np.asarray(slots[1, 0]), np.asarray(can[2]))


def test_moe_gradients_flow(mesh1):
    cfg = reduced_config("jamba-1.5-large-398b").with_updates(
        compute_dtype="float32", param_dtype="float32")
    cfg, x, rw, cw, slots, tables = _setup(cfg, mesh1, T=16)

    def loss(x, w1):
        y, _ = M.moe_ffn(mesh1, cfg, x, rw, w1, slots[1], slots[2], tables,
                         batch_part="data")
        return jnp.sum(y * y)

    gx, gw = jax.grad(loss, argnums=(0, 1))(x, slots[0])
    assert float(jnp.abs(gx).sum()) > 0
    assert float(jnp.abs(gw).sum()) > 0
    assert bool(jnp.all(jnp.isfinite(gx)))
