"""System-level numerical consistency: prefill/decode equivalence (the core
serving invariant), padded prefill, SSD vs naive recurrence, Pallas path."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, reduced_config
from repro.models import LM
from repro.models import ssd as S

DECODER_ARCHS = [a for a in ARCH_IDS if not reduced_config(a).encoder_only]


def _batchify(cfg, toks):
    b = {"tokens": toks}
    if cfg.family == "vlm":
        b["patches"] = jnp.ones((toks.shape[0], cfg.num_patches,
                                 cfg.frontend_dim), jnp.float32)
    return b


@pytest.mark.parametrize("arch", DECODER_ARCHS)
def test_prefill_then_decode_matches_full_prefill(arch, mesh1):
    cfg = reduced_config(arch).with_updates(compute_dtype="float32",
                                            param_dtype="float32")
    lm = LM.build(cfg, mesh1, pattern=[0] * cfg.n_layers)
    params = lm.init(jax.random.PRNGKey(0))
    tables = lm.default_tables()
    B, Stok = 2, 24
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, Stok), 0,
                              cfg.vocab_size)
    _, logits_full, _ = lm.prefill(params, _batchify(cfg, toks),
                                   max_len=48, tables=tables)
    cache, _, _ = lm.prefill(params, _batchify(cfg, toks[:, :-1]),
                             max_len=48, tables=tables)
    pos = Stok - 1 + (cfg.num_patches if cfg.family == "vlm" else 0)
    _, logits_dec, _ = lm.decode(params, cache, toks[:, -1:],
                                 jnp.int32(pos), tables=tables)
    np.testing.assert_allclose(np.asarray(logits_dec),
                               np.asarray(logits_full), rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("arch", ["qwen2-1.5b", "mamba2-130m", "gemma3-4b",
                                  "jamba-1.5-large-398b"])
def test_padded_prefill_equals_exact(arch, mesh1):
    cfg = reduced_config(arch).with_updates(compute_dtype="float32",
                                            param_dtype="float32")
    lm = LM.build(cfg, mesh1)
    params = lm.init(jax.random.PRNGKey(0))
    tables = lm.default_tables()
    S, Spad = 21, 32
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, Spad), 0,
                              cfg.vocab_size)
    ce, le, _ = lm.prefill(params, {"tokens": toks[:, :S]}, max_len=64,
                           tables=tables)
    cp, lp, _ = lm.prefill(params, {"tokens": toks}, max_len=64,
                           tables=tables, true_len=jnp.int32(S))
    np.testing.assert_allclose(np.asarray(lp), np.asarray(le), rtol=2e-4,
                               atol=2e-4)
    _, d1, _ = lm.decode(params, ce, toks[:, S:S + 1], jnp.int32(S),
                         tables=tables)
    _, d2, _ = lm.decode(params, cp, toks[:, S:S + 1], jnp.int32(S),
                         tables=tables)
    np.testing.assert_allclose(np.asarray(d2), np.asarray(d1), rtol=2e-4,
                               atol=2e-4)


def test_multi_token_greedy_continuation(mesh1):
    """8 decode steps == prefilling the whole greedy sequence."""
    cfg = reduced_config("qwen2-1.5b").with_updates(compute_dtype="float32",
                                                    param_dtype="float32")
    lm = LM.build(cfg, mesh1, pattern=[0] * cfg.n_layers)
    params = lm.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, 8), 0, cfg.vocab_size)
    cache, logits, _ = lm.prefill(params, {"tokens": toks}, max_len=24)
    seq = list(np.asarray(toks)[0])
    for t in range(8):
        nxt = int(jnp.argmax(logits[0]))
        seq.append(nxt)
        cache, logits, _ = lm.decode(params, cache,
                                     jnp.asarray([[nxt]]), jnp.int32(8 + t))
    # reference: prefill the WHOLE greedy sequence (16 tokens) — its last
    # logits predict position 16, matching the final decode step's output
    _, ref_logits, _ = lm.prefill(params,
                                  {"tokens": jnp.asarray([seq])},
                                  max_len=24)
    np.testing.assert_allclose(np.asarray(logits), np.asarray(ref_logits),
                               rtol=2e-3, atol=2e-3)


# ----------------------------------------------------------------------
def ssd_naive(x, dt, A, Bm, Cm):
    """Token-by-token recurrence oracle."""
    Bsz, S, H, P = x.shape
    N = Bm.shape[-1]
    state = jnp.zeros((Bsz, H, P, N))
    ys = []
    for t in range(S):
        y, state = S_decode(state, x[:, t], dt[:, t], A, Bm[:, t], Cm[:, t])
        ys.append(y)
    return jnp.stack(ys, 1)


def S_decode(state, x, dt, A, Bm, Cm):
    from repro.models.ssd import ssd_decode_step
    return ssd_decode_step(state, x, dt, A, Bm, Cm)


@pytest.mark.parametrize("chunk", [4, 8, 32])
def test_ssd_chunked_matches_naive_recurrence(chunk):
    rng = jax.random.PRNGKey(0)
    ks = jax.random.split(rng, 5)
    Bsz, Sq, H, P, N = 2, 32, 3, 8, 4
    x = jax.random.normal(ks[0], (Bsz, Sq, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (Bsz, Sq, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
    Bm = jax.random.normal(ks[3], (Bsz, Sq, N))
    Cm = jax.random.normal(ks[4], (Bsz, Sq, N))
    y, final = S.ssd_chunked(x, dt, A, Bm, Cm, chunk)
    y_ref = ssd_naive(x, dt, A, Bm, Cm)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=2e-4, atol=2e-4)


def test_ssd_state_continuation():
    """final state from chunked == continuing the recurrence."""
    rng = jax.random.PRNGKey(1)
    ks = jax.random.split(rng, 5)
    Bsz, Sq, H, P, N = 1, 16, 2, 4, 4
    x = jax.random.normal(ks[0], (Bsz, Sq, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (Bsz, Sq, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
    Bm = jax.random.normal(ks[3], (Bsz, Sq, N))
    Cm = jax.random.normal(ks[4], (Bsz, Sq, N))
    _, s_half = S.ssd_chunked(x[:, :8], dt[:, :8], A, Bm[:, :8], Cm[:, :8], 4)
    y2, s_full = S.ssd_chunked(x[:, 8:], dt[:, 8:], A, Bm[:, 8:], Cm[:, 8:],
                               4, initial_state=s_half)
    _, s_ref = S.ssd_chunked(x, dt, A, Bm, Cm, 4)
    np.testing.assert_allclose(np.asarray(s_full), np.asarray(s_ref),
                               rtol=2e-4, atol=2e-4)


def test_causal_conv_cache_roundtrip():
    rng = jax.random.PRNGKey(2)
    x = jax.random.normal(rng, (2, 12, 6))
    w = jax.random.normal(jax.random.PRNGKey(3), (4, 6))
    y_full, cache = S.causal_conv(x, w)
    y_a, cache_a = S.causal_conv(x[:, :7], w)
    y_b, _ = S.causal_conv(x[:, 7:], w, cache_a)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([y_a, y_b], 1)),
                               np.asarray(y_full), rtol=1e-5, atol=1e-5)


def test_pallas_path_matches_jnp(mesh1):
    cfg = reduced_config("qwen2-1.5b").with_updates(compute_dtype="float32",
                                                    param_dtype="float32")
    lmA = LM.build(cfg, mesh1)
    lmB = LM.build(cfg.with_updates(use_pallas=True), mesh1)
    params = lmA.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0,
                              cfg.vocab_size)
    cA, lA, _ = lmA.prefill(params, {"tokens": toks}, max_len=40)
    cB, lB, _ = lmB.prefill(params, {"tokens": toks}, max_len=40)
    np.testing.assert_allclose(np.asarray(lB), np.asarray(lA), rtol=2e-4,
                               atol=2e-4)
    _, dA, _ = lmA.decode(params, cA, toks[:, :1], jnp.int32(32))
    _, dB, _ = lmB.decode(params, cB, toks[:, :1], jnp.int32(32))
    np.testing.assert_allclose(np.asarray(dB), np.asarray(dA), rtol=2e-4,
                               atol=2e-4)
