"""Cluster simulator behavior + dry-run artifact validation + multi-device
distribution smoke (subprocess with forced host devices)."""
import glob
import json
import os
import subprocess
import sys
from pathlib import Path

import numpy as np
import pytest

from repro.sim import ClusterSim, SimConfig
from repro.sim.workload import WorkloadConfig, closed_loop_requests

REPO = Path(__file__).resolve().parents[1]


def _run(**kw):
    cfg = SimConfig(n_requests=250, concurrency=200,
                    workload=WorkloadConfig(seed=1), **kw)
    return ClusterSim(cfg).run()


@pytest.fixture(scope="module")
def sims():
    return {
        "full": _run(),
        "wo_placement": _run(use_placement=False),
        "wo_attn": _run(use_omniattn=False),
        "wo_all": _run(use_placement=False, use_omniattn=False,
                       use_proxy=False),
    }


def test_sim_completes_all(sims):
    for k, s in sims.items():
        assert s["n_done"] == 250, k


def test_ablation_ordering(sims):
    """Paper Table 2 ordering: full ≥ w/o attn > w/o placement ≥ w/o all."""
    assert sims["full"]["qpm"] >= sims["wo_attn"]["qpm"] * 0.98
    assert sims["wo_attn"]["qpm"] > sims["wo_placement"]["qpm"]
    assert sims["full"]["qpm"] > sims["wo_all"]["qpm"] * 1.15


def test_placement_reduces_imbalance(sims):
    assert sims["full"]["moe_imbalance_final"] < \
        sims["wo_placement"]["moe_imbalance_final"] - 0.3


def test_omniattn_reduces_tpot(sims):
    assert sims["full"]["tpot_mean_ms"] < sims["wo_attn"]["tpot_mean_ms"]


def test_workload_long_tail():
    reqs = closed_loop_requests(WorkloadConfig(seed=0), 4000)
    lin = np.array([r[0] for r in reqs])
    lout = np.array([r[1] for r in reqs])
    assert (lin + lout).max() <= 16384
    assert 2000 < lin.mean() < 5000
    assert lin.max() > 4 * lin.mean()        # pronounced tail


# ----------------------------------------------------------------------
RESULTS = REPO / "results" / "dryrun"


@pytest.mark.skipif(not RESULTS.exists(), reason="dry-run artifacts absent")
@pytest.mark.parametrize("mesh", ["pod_16x16", "multipod_2x16x16"])
def test_dryrun_matrix_green(mesh):
    recs = [json.loads(Path(f).read_text())
            for f in sorted(glob.glob(str(RESULTS / mesh / "*.json")))]
    base = [r for r in recs if not r.get("tag")]    # exclude §Perf variants
    assert len(base) == 40, "expected 40 baseline cells per mesh"
    bad = []
    for r in base:
        if r["status"] == "error":
            bad.append((r["arch"], r["shape"]))
        elif r["status"] == "ok":
            t = r["roofline"]["terms"]
            assert t["compute_s"] >= 0 and t["memory_s"] > 0
            assert r["flops_per_device"] > 0
    assert not bad, bad


@pytest.mark.skipif(not RESULTS.exists(), reason="dry-run artifacts absent")
def test_dryrun_skips_are_encoder_only():
    for mesh in ("pod_16x16", "multipod_2x16x16"):
        for f in glob.glob(str(RESULTS / mesh / "*.json")):
            r = json.loads(Path(f).read_text())
            if r["status"] == "skipped":
                assert r["arch"] == "hubert-xlarge"
                assert r["shape"] in ("decode_32k", "long_500k")


@pytest.mark.skipif(not RESULTS.exists(), reason="dry-run artifacts absent")
def test_perf_variants_improved_dominant_term():
    """§Perf: each hillclimb cell's best tagged variant beats its baseline
    on the dominant (memory) roofline term."""
    best = {("qwen3-moe-235b-a22b", "prefill_32k"): "A6_int8a2a",
            ("qwen2-1.5b", "train_4k"): "B3_bigchunk",
            ("gemma3-4b", "train_4k"): "C4_winskip"}
    for (arch, shape), tag in best.items():
        b = RESULTS / "pod_16x16" / f"{arch}__{shape}.json"
        v = RESULTS / "pod_16x16" / f"{arch}__{shape}__{tag}.json"
        if not (b.exists() and v.exists()):
            pytest.skip("hillclimb records absent")
        rb = json.loads(b.read_text())["roofline"]["terms"]
        rv = json.loads(v.read_text())["roofline"]["terms"]
        assert rv["memory_s"] < 0.6 * rb["memory_s"], (arch, shape)
        assert rv["collective_s"] < rb["collective_s"], (arch, shape)


# ----------------------------------------------------------------------
@pytest.mark.slow
def test_multi_device_moe_subprocess():
    """shard_map MoE vs dense oracle on an 8-device (2,2,2) pod/data/model
    mesh — run in a subprocess so the forced device count can't leak."""
    code = r"""
import jax, jax.numpy as jnp, numpy as np
from repro.distributed.ctx import MeshCtx
from repro.configs import reduced_config
from repro.models import moe as M
from dataclasses import replace
mesh = MeshCtx(jax.make_mesh((2,2,2), ('pod','data','model')))
cfg = reduced_config('qwen2-moe-a2.7b').with_updates(compute_dtype='float32', param_dtype='float32')
cfg = replace(cfg, moe=replace(cfg.moe, capacity_factor=8.0))
E, Fe, D = cfg.moe.n_experts, cfg.moe.d_ff_expert, cfg.d_model
s = M.default_slot_count(cfg, mesh.ep)
tables = M.tables_from_placement(M.round_robin_placement(E, mesh.ep, s), s)
ks = jax.random.split(jax.random.PRNGKey(0), 5)
x = jax.random.normal(ks[0], (64, D))
rw = jax.random.normal(ks[1], (D, E)) * 0.1
cw = [jax.random.normal(k, shp)*0.05 for k, shp in zip(ks[2:], [(E,D,Fe),(E,D,Fe),(E,Fe,D)])]
slots = [M.slots_from_canonical(c, tables['slot_expert']) for c in cw]
y, _ = jax.jit(lambda *a: M.moe_ffn(mesh, cfg, *a, batch_part=('pod','data')))(x, rw, *slots, tables)
ref = M.moe_ffn_dense(cfg, x, rw, *cw)
err = float(jnp.max(jnp.abs(y - ref)))
assert err < 1e-4, err
print('OK', err)
"""
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8",
               PYTHONPATH=str(REPO / "src"))
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=560)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "OK" in out.stdout


@pytest.mark.slow
def test_train_resume_after_preemption(tmp_path):
    """Integration drill: preempted training resumes from checkpoint."""
    env = dict(os.environ, PYTHONPATH=str(REPO / "src"))
    base = [sys.executable, "-m", "repro.launch.train", "--arch", "qwen2-1.5b",
            "--reduced", "--steps", "24", "--batch", "2", "--seq", "32",
            "--ckpt-dir", str(tmp_path), "--ckpt-every", "10"]
    first = subprocess.run(base + ["--preempt-at", "12"], env=env,
                           capture_output=True, text=True, timeout=560)
    assert first.returncode == 42, first.stderr[-1500:]
    second = subprocess.run(base, env=env, capture_output=True, text=True,
                            timeout=560)
    assert second.returncode == 0, second.stderr[-1500:]
    assert "resumed from step 10" in second.stdout
