"""Request-level serving API: SamplingParams, device-side batched sampling,
streaming step()/generate(), abort hygiene, and seeded determinism across
engine layouts and preemption."""
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import reduced_config
from repro.core.proxy import (OASConfig, Phase, RequestOutput, SamplingParams,
                              seed_key)
from repro.distributed.ctx import local_mesh_ctx
from repro.models import LM
from repro.serving import Server, ServerConfig
from repro.serving.sampling import sample_tokens


@pytest.fixture(scope="module")
def small():
    mesh = local_mesh_ctx()
    cfg = reduced_config("qwen2-1.5b").with_updates(
        compute_dtype="float32", param_dtype="float32", n_layers=2)
    lm = LM.build(cfg, mesh, pattern=[0, 0])
    params = lm.init(jax.random.PRNGKey(0))
    return cfg, lm, params


def make_server(cfg, **kw):
    defaults = dict(n_prefill=1, n_decode=1, decode_slots=4, max_len=96,
                    oas=OASConfig(defer_window=0.0))
    defaults.update(kw)
    return Server(cfg, ServerConfig(**defaults), pattern=[0, 0])


def drain(srv, rids, max_wall_s=120.0):
    """step() until every rid in `rids` finished; → {rid: output_tokens},
    {rid: finish_reason}, [all RequestOutput records]."""
    t0 = time.monotonic()
    live = set(rids)
    toks: dict[int, list] = {r: [] for r in rids}
    reasons: dict[int, str] = {}
    records = []
    while live and time.monotonic() - t0 < max_wall_s:
        for out in srv.step():
            records.append(out)
            if out.rid in toks:
                toks[out.rid].extend(out.new_tokens)
            if out.finished and out.rid in live:
                reasons[out.rid] = out.finish_reason
                live.discard(out.rid)
    assert not live, f"requests {live} did not finish"
    return {r: tuple(t) for r, t in toks.items()}, reasons, records


# ======================================================================
def test_sampling_params_validation():
    p = SamplingParams()
    assert p.greedy and p.temperature == 0.0 and p.stop_token_ids == ()
    q = SamplingParams(temperature=0.8, top_k=5, stop_token_ids=[3, np.int64(7)])
    assert not q.greedy and q.stop_token_ids == (3, 7)
    with pytest.raises(ValueError):
        SamplingParams(temperature=-1)
    with pytest.raises(ValueError):
        SamplingParams(top_p=0.0)
    with pytest.raises(ValueError):
        SamplingParams(max_tokens=0)


def test_empty_summary_keeps_full_key_set():
    """Zero completed requests (all aborted / wall expired) must still
    return every column consumers index unconditionally."""
    from repro.core.proxy import MetricsAggregator, Request
    m = MetricsAggregator()
    m.add_aborted(Request(0, (1, 2), 4, arrival=0.0))
    s = m.summary(1.0)
    assert s["n_done"] == 0 and s["n_aborted"] == 1
    for k in ("qpm", "ttft_mean", "tpot_mean_ms", "e2e_p99", "ott_tok_s",
              "n_stop", "n_length"):
        assert k in s


def test_seed_key_matches_prngkey():
    for s in (0, 5, 12345, 2**31 - 1):
        assert np.array_equal(seed_key(s), np.asarray(jax.random.PRNGKey(s)))


def test_sample_tokens_unit():
    """Pure-sampler semantics: greedy/top-k=1/tiny-top-p all reduce to
    argmax; filtered rows stay inside their candidate sets; draws are a
    pure function of (key, fold)."""
    rng = np.random.default_rng(0)
    logits = jnp.asarray(rng.normal(size=(5, 64)).astype(np.float32))
    temp = jnp.asarray([0.0, 1.0, 5.0, 0.9, 1.3], jnp.float32)
    tk = jnp.asarray([0, 8, 1, 0, 0], jnp.int32)
    tp = jnp.asarray([1.0, 1.0, 1.0, 1e-6, 0.7], jnp.float32)
    keys = jnp.asarray(np.stack([seed_key(i) for i in range(5)]))
    fold = jnp.full((5,), 17, jnp.int32)
    out = np.asarray(sample_tokens(logits, temp, tk, tp, keys, fold))
    am = np.argmax(np.asarray(logits), axis=-1)
    assert out[0] == am[0]                      # temperature 0 → greedy
    assert out[2] == am[2]                      # top_k=1 → greedy at any temp
    assert out[3] == am[3]                      # top_p→0 keeps only top-1
    assert out[1] in np.argsort(-np.asarray(logits)[1])[:8]   # top-k set
    # reproducible for identical inputs; varies with the fold position
    out2 = np.asarray(sample_tokens(logits, temp, tk, tp, keys, fold))
    assert np.array_equal(out, out2)
    seen = {tuple(np.asarray(sample_tokens(
        logits, temp, tk, tp, keys, jnp.full((5,), f, jnp.int32))))
        for f in range(18, 30)}
    assert len(seen) > 1


# ======================================================================
def test_generate_streaming_and_stop_tokens(small):
    """generate() streams per-step deltas whose concatenation is the full
    output; stop_token_ids terminate with finish_reason='stop' and the
    stream is a strict prefix of the unconstrained greedy stream."""
    cfg, _, _ = small
    srv = make_server(cfg)
    rng = np.random.default_rng(31)
    prompt = tuple(rng.integers(0, cfg.vocab_size, 11))

    outs = list(srv.generate(prompt, SamplingParams(max_tokens=6)))
    rid = outs[0].rid
    full = tuple(t for o in outs for t in o.new_tokens)
    assert len(full) == 6
    assert outs[-1].finished and outs[-1].finish_reason == "length"
    assert outs[-1].n_generated == 6

    # stopping on a token of the greedy stream truncates at its first
    # occurrence (inclusive), reason 'stop'
    stop = full[2]
    outs2 = list(srv.generate(prompt, SamplingParams(
        max_tokens=6, stop_token_ids=(stop,))))
    mine = [o for o in outs2 if o.rid != rid]
    toks2 = tuple(t for o in mine for t in o.new_tokens)
    assert toks2 == full[:full.index(stop) + 1]
    assert mine[-1].finish_reason == "stop"
    s = srv.metrics.summary(1.0)
    assert s["n_stop"] == 1 and s["n_length"] == 1 and s["n_aborted"] == 0


def test_eos_token_deprecated_default(small):
    """ServerConfig.eos_token still terminates requests that carry no
    stop_token_ids, and is overridden by per-request stop sets."""
    cfg, _, _ = small
    probe = make_server(cfg)
    rng = np.random.default_rng(33)
    prompt = tuple(rng.integers(0, cfg.vocab_size, 9))
    base = list(probe.generate(prompt, SamplingParams(max_tokens=5)))
    full = tuple(t for o in base for t in o.new_tokens)

    eos = int(full[1])
    srv = make_server(cfg, eos_token=eos)
    rid_a = srv.add_request(prompt, SamplingParams(max_tokens=5))
    # a per-request stop set that never fires overrides the global eos
    rid_b = srv.add_request(prompt, SamplingParams(
        max_tokens=5, stop_token_ids=(int(cfg.vocab_size) - 1,)))
    toks, reasons, _ = drain(srv, [rid_a, rid_b])
    assert toks[rid_a] == full[:full.index(eos) + 1]
    assert reasons[rid_a] == "stop"
    assert toks[rid_b] == full and reasons[rid_b] == "length"


def test_seeded_sampling_deterministic_across_layouts(small):
    """Same SamplingParams(seed=...) must yield identical token streams on
    the paged and slot-dense decode engines (the draw is a pure function of
    seed and position, and sampling runs in the fused device step)."""
    cfg, _, _ = small
    rng = np.random.default_rng(41)
    prompts = [tuple(rng.integers(0, cfg.vocab_size, n)) for n in (9, 14, 21)]
    # temperature 2: the random-init model is extremely peaked (top-1 prob
    # ≈ 0.99 at T=1), which would make "sampling" collapse to argmax
    params = [SamplingParams(temperature=2.0, top_k=50, top_p=0.95,
                             seed=100 + i, max_tokens=8)
              for i in range(3)]

    streams = {}
    for paged in (False, True):
        srv = make_server(cfg, paged_kv=paged)
        rids = [srv.add_request(p, sp) for p, sp in zip(prompts, params)]
        toks, reasons, _ = drain(srv, rids)
        assert all(r == "length" for r in reasons.values())
        streams[paged] = [toks[r] for r in rids]
        # device-side sampling: exactly one host fetch per decode step
        ds = srv.decodes[0].stats
        assert ds["host_fetches"] == ds["steps"]
        # released slots reset temp, so later all-greedy batches take the
        # argmax-only lax.cond branch
        assert np.all(np.asarray(srv.decodes[0].state["temp"]) == 0.0)
    assert streams[True] == streams[False]
    assert all(len(t) == 8 for t in streams[True])

    # sanity: the sampled streams are actually sampled, not greedy
    greedy_srv = make_server(cfg)
    grids = [greedy_srv.add_request(p, SamplingParams(max_tokens=8))
             for p in prompts]
    gtoks, _, _ = drain(greedy_srv, grids)
    assert [gtoks[r] for r in grids] != streams[True]


def test_seeded_sampling_preemption_continuity(small):
    """Forced KV-exhaustion preemption + resume must reproduce the exact
    seeded sampled stream (extends the PR 2 greedy preempt regression to
    stochastic decoding: the per-position fold makes the draw independent
    of when the request was evicted and re-admitted)."""
    cfg, _, _ = small
    rng = np.random.default_rng(43)
    reqs = [(tuple(rng.integers(0, cfg.vocab_size, 14)),
             SamplingParams(temperature=2.0, top_k=40, seed=7 + i,
                            max_tokens=8)) for i in range(2)]

    def run(kv_blocks):
        srv = make_server(cfg, kv_blocks=kv_blocks)
        s = srv.run(reqs, max_wall_s=120)
        outs = {r.rid: tuple(r.output_tokens) for r in srv.metrics.done}
        return s, outs

    s_free, outs_free = run(None)
    assert s_free["n_done"] == 2
    assert s_free["decode_stats"][0]["preemptions"] == 0
    s_tight, outs_tight = run(3)            # 3 blocks → forced preemption
    assert s_tight["n_done"] == 2
    assert s_tight["decode_stats"][0]["preemptions"] >= 1
    assert outs_tight == outs_free
    assert all(len(v) == 8 for v in outs_tight.values())


# ======================================================================
def _request_held_blocks(pool):
    """Pool keys held by REQUEST state (decode rids, prefill tasks, parked
    handoffs) — prefix-store snapshots are shared cache, not request state,
    and legitimately keep blocks refcounted under ("store", ...)."""
    return {k: v for k, v in pool.per_request.items()
            if not (isinstance(k, tuple) and k[0] == "store")}


def _assert_clean(srv, rid):
    """No trace of `rid` anywhere a request can hold state."""
    assert rid not in srv.proxy.inflight
    assert rid not in srv._pending_kv
    assert all(r.rid != rid for r in srv.proxy.pending)
    assert all(r.rid != rid for r in srv.proxy.decode_wait)
    for eng in srv.prefills:
        assert all(t.rid != rid for t in eng.queue)
        assert all(r.rid != rid for r in eng._ready)
        if eng.paged:
            assert ("prefill", rid) not in eng.arena.pool
    for eng in srv.decodes:
        assert rid not in eng.rid_slot
        assert rid not in eng.pool
        eng.pool.check_invariants()


def test_abort_all_phases_leaves_pool_clean(small):
    """Aborting in every reachable phase (queued, mid-chunked-prefill,
    pending-KV/decode-wait, decoding) releases all state and the surviving
    requests still finish."""
    cfg, _, _ = small
    srv = make_server(cfg, chunk_tokens=8, prefill_tick_budget=8)
    rng = np.random.default_rng(51)
    mk = lambda n: tuple(rng.integers(0, cfg.vocab_size, n))
    t0 = time.monotonic()

    # -- queued: aborted before any step() ever runs
    r_q = srv.add_request(mk(10), SamplingParams(max_tokens=4), now=t0)
    keep = srv.add_request(mk(10), SamplingParams(max_tokens=4), now=t0)
    assert srv.abort(r_q)
    _assert_clean(srv, r_q)

    # -- mid-chunked-prefill: 30-token prompt at 8 tokens/round needs
    # several rounds; abort while the engine holds a half-done task
    r_p = srv.add_request(mk(30), SamplingParams(max_tokens=4))
    task = None
    for _ in range(10):        # SRPT runs `keep`'s shorter prompt first
        srv.step()
        task = next((t for t in srv.prefills[0].queue if t.rid == r_p), None)
        if task is not None and task.cursor > 0:
            break
    assert task is not None and 0 < task.cursor < 30
    assert srv.abort(r_p)
    _assert_clean(srv, r_p)

    # -- pending-KV / decode-wait: step until the handoff exists
    r_kv = srv.add_request(mk(12), SamplingParams(max_tokens=4))
    for _ in range(40):
        if r_kv in srv._pending_kv:
            break
        srv.step()
    assert r_kv in srv._pending_kv
    assert srv.abort(r_kv)
    _assert_clean(srv, r_kv)

    # -- decoding: slot + pool blocks held
    r_d = srv.add_request(mk(12), SamplingParams(max_tokens=30))
    for _ in range(40):
        req = srv.proxy.inflight.get(r_d)
        if req is not None and req.phase == Phase.DECODE_RUNNING:
            break
        srv.step()
    assert srv.proxy.inflight[r_d].phase == Phase.DECODE_RUNNING
    assert r_d in srv.decodes[0].rid_slot
    assert srv.abort(r_d)
    _assert_clean(srv, r_d)
    outs = srv.step()
    assert any(o.rid == r_d and o.finished and o.finish_reason == "abort"
               for o in outs)

    # survivors unaffected (keep may have finished during the staging
    # loops above); all accounting returns to zero
    t0 = time.monotonic()
    while keep in srv.proxy.inflight and time.monotonic() - t0 < 60:
        srv.step()
    done = next(r for r in srv.metrics.done if r.rid == keep)
    assert len(done.output_tokens) == 4 and done.finish_reason == "length"
    assert srv.metrics.summary(1.0)["n_aborted"] == 4
    assert not srv._pending_kv
    for eng in srv.decodes:
        assert not eng.rid_slot
        # zero request-held blocks: only prefix-store snapshots (shared
        # cache, refcounted under their own keys) may keep blocks mapped
        assert not _request_held_blocks(eng.pool)
        eng.pool.check_invariants()
    assert srv.proxy.prefill[0].running == 0
    assert srv.proxy.prefill[0].queue_len == 0
    assert srv.proxy.decode[0].running == 0
    assert not srv.abort(99999)            # unknown rid → False, no crash


def test_abort_preempted_request(small):
    """Aborting a request parked in decode_wait with an extracted cache
    (KV-exhaustion preemption) releases everything; the survivor finishes
    and the pool returns to fully free."""
    cfg, _, _ = small
    srv = make_server(cfg, kv_blocks=3)     # block_size=16 → 48 tokens total
    rng = np.random.default_rng(53)
    prompts = [tuple(rng.integers(0, cfg.vocab_size, 14)) for _ in range(2)]
    rids = [srv.add_request(p, SamplingParams(max_tokens=12))
            for p in prompts]
    victim = None
    for _ in range(60):
        srv.step()
        if srv.decodes[0].stats["preemptions"] >= 1:
            pre = [r for r in rids if r in srv._pending_kv
                   and srv.proxy.inflight.get(r) is not None
                   and srv.proxy.inflight[r].phase == Phase.DECODE_WAIT]
            if pre:
                victim = pre[0]
                break
    assert victim is not None, "no preemption materialized"
    assert srv.abort(victim)
    _assert_clean(srv, victim)
    survivor = [r for r in rids if r != victim]
    _, reasons, _ = drain(srv, survivor)
    assert reasons[survivor[0]] == "length"
    done = next(r for r in srv.metrics.done if r.rid == survivor[0])
    assert len(done.output_tokens) == 12
    pool = srv.decodes[0].pool
    assert pool.free_blocks == pool.n_blocks
    s = srv.metrics.summary(1.0)
    assert s["n_aborted"] == 1 and s["n_done"] == 1


def test_kv_lost_restart_does_not_replay_deltas(small):
    """A decode-instance death reroutes its requests through prefill from
    scratch (output_tokens cleared); the regenerated prefix is identical
    (draws are positional) and must NOT be re-streamed: each request's
    concatenated RequestOutput deltas contain every token exactly once."""
    cfg, _, _ = small
    srv = make_server(cfg)
    rng = np.random.default_rng(59)
    rids = [srv.add_request(tuple(rng.integers(0, cfg.vocab_size, 8)),
                            SamplingParams(max_tokens=6)) for _ in range(3)]
    t0 = time.monotonic()
    deltas: dict[int, list] = {r: [] for r in rids}
    live = set(rids)
    killed = False
    while live and time.monotonic() - t0 < 120:
        for out in srv.step():
            deltas[out.rid].extend(out.new_tokens)
            if out.finished:
                live.discard(out.rid)
        if not killed and any(r in srv.decodes[0].rid_slot for r in rids):
            srv.proxy.mark_unhealthy("decode", 0, time.monotonic())
            srv.proxy.mark_healthy("decode", 0)
            killed = True
    assert killed and not live
    for r in rids:
        done = next(q for q in srv.metrics.done if q.rid == r)
        assert deltas[r] == done.output_tokens    # no replay, no gap
        assert len(deltas[r]) == 6


def test_run_sleeps_until_future_arrival(small):
    """With nothing in flight and a future arrival, run() must sleep
    instead of busy-spinning on time.monotonic()."""
    cfg, _, _ = small
    srv = make_server(cfg)
    rng = np.random.default_rng(55)
    reqs = [(tuple(rng.integers(0, cfg.vocab_size, 8)), 3)]
    s = srv.run(reqs, max_wall_s=30, arrivals=[0.25])
    assert s["n_done"] == 1
    assert s["idle_slept_s"] >= 0.2


def test_first_token_stop_never_admits_to_decode(small):
    """A request whose FIRST token is a stop token (or max_tokens=1) must
    retire at prefill — no decode admission, no KV handoff leak."""
    cfg, _, _ = small
    probe = make_server(cfg)
    rng = np.random.default_rng(57)
    prompt = tuple(rng.integers(0, cfg.vocab_size, 9))
    first = list(probe.generate(prompt, SamplingParams(max_tokens=1)))
    assert sum(len(o.new_tokens) for o in first) == 1
    assert first[-1].finish_reason == "length"

    srv = make_server(cfg)
    tok0 = first[-1].new_tokens[-1] if first[-1].new_tokens else \
        [t for o in first for t in o.new_tokens][0]
    rid = srv.add_request(prompt, SamplingParams(
        max_tokens=5, stop_token_ids=(int(tok0),)))
    toks, reasons, _ = drain(srv, [rid])
    assert toks[rid] == (tok0,) and reasons[rid] == "stop"
    assert srv.decodes[0].stats["admits"] == 0
    assert not srv._pending_kv
