"""Training substrate: loss decreases, grad-accum equivalence, optimizer."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import reduced_config
from repro.models import LM
from repro.training.data import DataConfig, make_batch, synth_tokens
from repro.training.optim import adamw_init, adamw_update
from repro.training.trainer import make_train_step


def test_loss_decreases(mesh1):
    cfg = reduced_config("qwen2-1.5b")
    lm = LM.build(cfg, mesh1)
    params = lm.init(jax.random.PRNGKey(0))
    opt = adamw_init(params, cfg.optimizer_dtype)
    step = jax.jit(make_train_step(lm, lr=1e-3))
    dcfg = DataConfig(cfg.vocab_size, 64, 4)
    losses = []
    for i in range(12):
        params, opt, m = step(params, opt, make_batch(cfg, dcfg, i), None)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 1.0


def test_grad_accum_equivalent(mesh1):
    cfg = reduced_config("qwen2-1.5b").with_updates(
        compute_dtype="float32", param_dtype="float32", remat=False)
    lm1 = LM.build(cfg, mesh1)
    lm2 = LM.build(cfg.with_updates(grad_accum=2), mesh1)
    params = lm1.init(jax.random.PRNGKey(0))
    opt = adamw_init(params, "float32")
    dcfg = DataConfig(cfg.vocab_size, 32, 4)
    batch = make_batch(cfg, dcfg, 0)
    p1, _, m1 = jax.jit(make_train_step(lm1))(params, opt, batch, None)
    p2, _, m2 = jax.jit(make_train_step(lm2))(params, opt, batch, None)
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]),
                               rtol=1e-5)
    # updates agree to fp32 tolerance (microbatch loss averaging reorders ops)
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-3,
                                   atol=2e-5)


def test_int8_grad_compression_trains(mesh1):
    cfg = reduced_config("qwen2-1.5b")
    lm = LM.build(cfg, mesh1)
    params = lm.init(jax.random.PRNGKey(0))
    opt = adamw_init(params, cfg.optimizer_dtype)
    step = jax.jit(make_train_step(lm, lr=1e-3, grad_compress_int8=True))
    dcfg = DataConfig(cfg.vocab_size, 32, 4)
    losses = []
    for i in range(8):
        params, opt, m = step(params, opt, make_batch(cfg, dcfg, i), None)
        losses.append(float(m["loss"]))
    assert np.isfinite(losses).all() and losses[-1] < losses[0]


def test_adamw_weight_decay_shrinks_params():
    params = {"w": jnp.ones((4, 4))}
    opt = adamw_init(params)
    grads = {"w": jnp.zeros((4, 4))}
    new, _, _ = adamw_update(grads, opt, params, lr=0.1, weight_decay=0.5,
                             grad_clip=0.0)
    assert float(new["w"].mean()) < 1.0


def test_grad_clip_bounds_update():
    params = {"w": jnp.ones((4,))}
    opt = adamw_init(params)
    grads = {"w": jnp.full((4,), 1e6)}
    _, _, gnorm = adamw_update(grads, opt, params, grad_clip=1.0)
    assert float(gnorm) > 1e5               # pre-clip norm reported


def test_data_pipeline_deterministic_restart():
    dcfg = DataConfig(512, 32, 4, seed=3)
    a = synth_tokens(dcfg, 17)
    b = synth_tokens(dcfg, 17)
    np.testing.assert_array_equal(a, b)
    assert not np.array_equal(synth_tokens(dcfg, 18), a)


def test_data_bigram_structure_learnable():
    dcfg = DataConfig(512, 256, 2, seed=0)
    t = synth_tokens(dcfg, 0)
    follow = (t[:, :-1] * 7 + 3) % 512
    frac = (t[:, 1:] == follow).mean()
    # the follow-chain is computed from the base sample, so replacements
    # dilute the observable rate to ~0.25 — still far above chance (1/512)
    assert frac > 0.2
