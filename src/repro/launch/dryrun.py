import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# ruff: noqa: E402  (the two lines above MUST precede any jax-importing module)
import argparse
import json
import re
import time
import traceback
from pathlib import Path

import jax
import numpy as np

from repro.configs import ARCH_IDS, SHAPES, get_config
from repro.distributed.ctx import MeshCtx
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import batch_specs, decode_specs, supports_shape
from repro.models.common import param_shapes, param_specs
from repro.models.lm import LM
from repro.models.moe import default_slot_count, round_robin_placement, tables_from_placement
from repro.training.optim import adamw_init, opt_specs
from repro.training.trainer import make_train_step

# TPU v5e hardware model (per chip) — see EXPERIMENTS.md §Roofline
PEAK_FLOPS = 197e12          # bf16
HBM_BW = 819e9               # bytes/s
LINK_BW = 50e9               # bytes/s per ICI link

_COLL_RE = re.compile(
    r"\b(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(")
_SHAPE_RE = re.compile(r"\b([a-z][a-z0-9]*)\[([0-9,]*)\]")

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def collective_bytes(hlo_text: str) -> dict:
    """Sum operand bytes of every collective op in the (per-device) HLO."""
    out = {k: 0 for k in ("all-gather", "all-reduce", "reduce-scatter",
                          "all-to-all", "collective-permute")}
    counts = {k: 0 for k in out}
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m or "-done(" in line:
            continue
        op = m.group(1)
        # operand shapes appear after the op name; result shape before '='
        after = line[m.end():]
        shapes = _SHAPE_RE.findall(after)
        if not shapes:            # fall back to the result shape
            shapes = _SHAPE_RE.findall(line[:m.start()])[:1]
        out[op] += sum(_shape_bytes(dt, dims) for dt, dims in shapes)
        counts[op] += 1
    out["total"] = sum(out.values())
    out["counts"] = counts
    return out


# ----------------------------------------------------------------------
def build_cell(arch: str, shape_name: str, mesh_ctx: MeshCtx,
               overrides: dict | None = None):
    """Returns (fn, arg_sds tuple, in_shardings tuple, meta)."""
    cfg = get_config(arch)
    if overrides:
        cfg = cfg.with_updates(**overrides)
    shape = SHAPES[shape_name]
    lm = LM.build(cfg, mesh_ctx)
    p_sds = lm.shapes()
    p_specs = lm.specs()

    tables_sds = tables_specs = None
    if cfg.moe.n_experts:
        s = default_slot_count(cfg, mesh_ctx.ep)
        t = tables_from_placement(
            round_robin_placement(cfg.moe.n_experts, mesh_ctx.ep, s), s)
        tables_sds = jax.tree.map(lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), t)
        tables_specs = lm.table_specs()

    sh = mesh_ctx.tree_shardings

    if shape.kind == "train":
        b_sds, b_specs = batch_specs(cfg, shape, mesh_ctx)
        opt_sds = jax.eval_shape(lambda: adamw_init(p_sds, cfg.optimizer_dtype))
        o_specs = opt_specs(p_specs)
        step = make_train_step(lm)

        def fn(params, opt, batch, tables):
            return step(params, opt, batch, tables)

        args = (p_sds, opt_sds, b_sds, tables_sds)
        shards = (sh(p_specs), sh(o_specs), sh(b_specs),
                  sh(tables_specs) if tables_specs else None)
    elif shape.kind == "prefill":
        b_sds, b_specs = batch_specs(cfg, shape, mesh_ctx)

        def fn(params, batch, tables):
            cache, logits, _aux = lm.prefill(params, batch,
                                             max_len=shape.seq_len, tables=tables)
            return cache, logits

        args = (p_sds, b_sds, tables_sds)
        shards = (sh(p_specs), sh(b_specs),
                  sh(tables_specs) if tables_specs else None)
    else:  # decode
        (tok, pos, cache_sds), (tok_sp, pos_sp, cache_sp) = \
            decode_specs(cfg, shape, mesh_ctx, lm)

        def fn(params, cache, token, positions, tables):
            new_cache, logits, _aux = lm.decode(params, cache, token, positions,
                                                tables=tables)
            return new_cache, logits

        args = (p_sds, cache_sds, tok, pos, tables_sds)
        shards = (sh(p_specs), sh(cache_sp), sh(tok_sp), sh(pos_sp),
                  sh(tables_specs) if tables_specs else None)

    meta = {
        "arch": arch, "shape": shape_name, "kind": shape.kind,
        "n_params": cfg.n_params(), "n_active_params": cfg.n_active_params(),
        "seq_len": shape.seq_len, "global_batch": shape.global_batch,
    }
    return fn, args, shards, meta


def model_flops(meta: dict) -> float:
    """6·N_active·tokens (train) / 2·N_active·tokens (inference)."""
    factor = 6.0 if meta["kind"] == "train" else 2.0
    tokens = meta["global_batch"] * (meta["seq_len"] if meta["kind"] != "decode" else 1)
    return factor * meta["n_active_params"] * tokens


def run_cell(arch: str, shape_name: str, multi_pod: bool, out_dir: Path,
             force: bool = False, overrides: dict | None = None,
             tag: str = "", mesh_shape: tuple | None = None) -> dict:
    if mesh_shape is not None:
        mesh_name = "pod_" + "x".join(str(d) for d in mesh_shape)
    else:
        mesh_name = "multipod_2x16x16" if multi_pod else "pod_16x16"
    suffix = f"__{tag}" if tag else ""
    out_path = out_dir / mesh_name / f"{arch}__{shape_name}{suffix}.json"
    if out_path.exists() and not force:
        prev = json.loads(out_path.read_text())
        if prev.get("status") != "error":      # always retry failed cells
            return prev
    out_path.parent.mkdir(parents=True, exist_ok=True)

    cfg = get_config(arch)
    ok, why = supports_shape(cfg, SHAPES[shape_name])
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
           "tag": tag, "overrides": overrides or {}}
    if not ok:
        rec.update(status="skipped", reason=why)
        out_path.write_text(json.dumps(rec, indent=1))
        return rec

    try:
        if mesh_shape is not None:   # elastic single-pod layouts (§Perf)
            devices = jax.devices()[:mesh_shape[0] * mesh_shape[1]]
            mesh = jax.make_mesh(mesh_shape, ("data", "model"),
                                 devices=devices)
        else:
            mesh = make_production_mesh(multi_pod=multi_pod)
        ctx = MeshCtx(mesh)
        chips = ctx.n_devices
        fn, args, shards, meta = build_cell(arch, shape_name, ctx, overrides)

        t0 = time.time()
        lowered = jax.jit(fn, in_shardings=shards).lower(*args)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        hlo = compiled.as_text()
        coll_xla = collective_bytes(hlo)

        # trip-count-aware walk (XLA cost_analysis counts while bodies ONCE —
        # see hlo_cost.py); XLA numbers kept as *_xla reference fields.
        from repro.launch.hlo_cost import analyze
        walked = analyze(hlo)
        coll = dict(walked.collective_bytes,
                    total=walked.total_collective_bytes,
                    counts=walked.collective_counts)
        flops_dev = float(walked.flops)
        bytes_dev = float(walked.bytes)
        mf = model_flops(meta)

        compute_t = flops_dev / PEAK_FLOPS
        memory_t = bytes_dev / HBM_BW
        coll_t = coll["total"] / LINK_BW
        terms = {"compute_s": compute_t, "memory_s": memory_t,
                 "collective_s": coll_t}
        dominant = max(terms, key=terms.get)

        rec.update(
            status="ok", chips=chips, **meta,
            t_lower_s=round(t_lower, 2), t_compile_s=round(t_compile, 2),
            flops_per_device=flops_dev, bytes_per_device=bytes_dev,
            collective_bytes_per_device=coll,
            xla_cost_reference={
                "flops": float(cost.get("flops", 0.0)),
                "bytes_accessed": float(cost.get("bytes accessed", 0.0)),
                "collective_bytes_once": coll_xla,
            },
            memory_analysis={
                "output_bytes": getattr(mem, "output_size_in_bytes", None),
                "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
                "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
                "generated_code_bytes": getattr(mem, "generated_code_size_in_bytes", None),
            },
            roofline={"terms": terms, "dominant": dominant},
            model_flops_total=mf,
            hlo_flops_total=flops_dev * chips,
            useful_flops_ratio=(mf / (flops_dev * chips)) if flops_dev else None,
        )
    except Exception as e:  # record failures — they are bugs to fix
        rec.update(status="error", error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc()[-4000:])
    out_path.write_text(json.dumps(rec, indent=1))
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", nargs="*", default=ARCH_IDS)
    ap.add_argument("--shape", nargs="*", default=list(SHAPES))
    ap.add_argument("--mesh", choices=["single", "multi", "both"], default="single")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--tag", default="", help="suffix for variant records")
    ap.add_argument("--mesh-shape", default=None,
                    help="custom single-pod data x model, e.g. 64x4")
    ap.add_argument("--set", nargs="*", default=[], dest="overrides",
                    help="config overrides key=value (perf hillclimb)")
    args = ap.parse_args()

    def _parse(v: str):
        if v in ("True", "true"):
            return True
        if v in ("False", "false"):
            return False
        try:
            return int(v)
        except ValueError:
            try:
                return float(v)
            except ValueError:
                return v
    overrides = {k: _parse(v) for k, v in
                 (item.split("=", 1) for item in args.overrides)}
    out = Path(args.out)
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]
    for arch in args.arch:
        for shape in args.shape:
            for mp in meshes:
                t0 = time.time()
                ms = None
                if args.mesh_shape:
                    ms = tuple(int(x) for x in args.mesh_shape.split("x"))
                rec = run_cell(arch, shape, mp, out, force=args.force,
                               overrides=overrides, tag=args.tag,
                               mesh_shape=ms)
                status = rec.get("status")
                extra = ""
                if status == "ok":
                    r = rec["roofline"]
                    extra = (f" dominant={r['dominant']}"
                             f" compute={r['terms']['compute_s']:.4f}s"
                             f" mem={r['terms']['memory_s']:.4f}s"
                             f" coll={r['terms']['collective_s']:.4f}s")
                elif status == "error":
                    extra = " " + rec.get("error", "")[:160]
                print(f"[{time.strftime('%H:%M:%S')}] {arch} × {shape} × "
                      f"{'multi' if mp else 'single'}: {status}{extra}"
                      f" ({time.time()-t0:.0f}s)", flush=True)


if __name__ == "__main__":
    main()
