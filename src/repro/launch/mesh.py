"""Production mesh builders (functions — importing this module never touches
jax device state)."""
from __future__ import annotations

import jax

from repro.distributed.ctx import MeshCtx


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = 1
    for s in shape:
        n *= s
    devices = jax.devices()[:n]   # single-pod mesh uses the first 256 of 512
    return jax.make_mesh(shape, axes, devices=devices)


def make_production_ctx(*, multi_pod: bool = False) -> MeshCtx:
    return MeshCtx(make_production_mesh(multi_pod=multi_pod))
