"""Production mesh builders (functions — importing this module never touches
jax device state).

The (data, model) axes double as the serving engine's parallel axes:
``data`` is the expert-parallel (EP) axis, ``model`` is tensor parallelism
(TP) — see repro.distributed.ctx.MeshCtx. Pass explicit ``tp``/``ep`` to
carve a serving mesh out of whatever devices the process sees (the launcher
exposes these as --tp/--ep); the default shapes are the paper's pod-scale
deployment footprints.
"""
from __future__ import annotations

from typing import Optional

import jax

from repro.distributed.ctx import MeshCtx


def make_production_mesh(*, multi_pod: bool = False,
                         tp: Optional[int] = None, ep: Optional[int] = None):
    if tp is not None or ep is not None:
        if multi_pod:
            raise ValueError("--tp/--ep sizing and multi_pod are exclusive")
        shape = (ep or 1, tp or 1)
        axes = ("data", "model")
    else:
        shape = (2, 16, 16) if multi_pod else (16, 16)
        axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = 1
    for s in shape:
        n *= s
    devices = jax.devices()
    if len(devices) < n:
        raise ValueError(
            f"mesh {dict(zip(axes, shape))} needs {n} devices, have "
            f"{len(devices)} (on CPU: XLA_FLAGS="
            f"--xla_force_host_platform_device_count={n})")
    return jax.make_mesh(shape, axes, devices=devices[:n])


def make_production_ctx(*, multi_pod: bool = False,
                        tp: Optional[int] = None,
                        ep: Optional[int] = None) -> MeshCtx:
    return MeshCtx(make_production_mesh(multi_pod=multi_pod, tp=tp, ep=ep))
