"""Trip-count-aware cost analysis over optimized HLO text.

XLA's built-in ``compiled.cost_analysis()`` counts each ``while`` body ONCE
(verified experimentally — see EXPERIMENTS.md §Dry-run), which undercounts
scanned layer stacks and grad-accumulation loops by orders of magnitude.
This walker re-derives:

  flops            — 2·M·N·K for every dot (recursing into fusions),
                     multiplied by enclosing while trip counts
                     (``backend_config known_trip_count``);
  bytes            — operand+result bytes at FUSION BOUNDARIES (inner fused
                     ops are free — closer to real HBM traffic than per-op);
  collective bytes — per collective op kind, operand bytes × trip counts.

Shapes of operands are resolved through a per-computation symbol table
(optimized HLO prints shapes only at definition sites).
"""
from __future__ import annotations

import json
import re
from dataclasses import dataclass, field

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "token": 0, "opaque": 0,
}

_SHAPE_RE = re.compile(r"\b([a-z][a-z0-9]+)\[([0-9,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*")
# opcode = first word directly followed by '(' after the type (type tokens
# are followed by '[' or ')' or ',', never '('; nested tuple parens are
# preceded by '(' or ', ', never by a word character)
_OPCODE_RE = re.compile(r"([a-z][a-z0-9\-]*)\(")
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(")
_TRIP_RE = re.compile(r'known_trip_count[\\"={:]+n[\\"]*:?[\\"]+(\d+)')
_CALL_RE = re.compile(r"(?:calls|to_apply|body)=%?([\w.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w.\-]+)")
_BODY_RE = re.compile(r"body=%?([\w.\-]+)")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")
_CDIM_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

# bytes are skipped for bookkeeping ops
_FREE_OPS = {"parameter", "constant", "tuple", "get-tuple-element", "bitcast",
             "copy", "copy-start", "copy-done", "after-all", "iota",
             "broadcast", "reshape"}


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES.get(dt, 4)
    return total


def _shape_dims(type_str: str):
    m = _SHAPE_RE.search(type_str)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",") if d]


@dataclass
class _Instr:
    name: str
    type_str: str
    opcode: str
    rest: str


def _parse(hlo: str) -> dict[str, list[_Instr]]:
    comps: dict[str, list[_Instr]] = {}
    cur = None
    for line in hlo.splitlines():
        if not line.strip() or line.startswith(("HloModule", "FileNames",
                                                "FunctionNames",
                                                "FileLocations",
                                                "StackFrames")):
            continue
        if not line.startswith((" ", "\t")):
            m = _COMP_RE.match(line.strip())
            if m and line.rstrip().endswith("{"):
                cur = m.group(1)
                comps[cur] = []
            continue
        if cur is None:
            continue
        m = _DEF_RE.match(line)
        if not m:
            continue
        tail = line[m.end():]
        mo = _OPCODE_RE.search(tail)
        if not mo:
            continue
        comps[cur].append(_Instr(m.group(1), tail[:mo.start()].strip(),
                                 mo.group(1), tail[mo.end():]))
    return comps


@dataclass
class CostResult:
    flops: float = 0.0
    bytes: float = 0.0
    collective_bytes: dict = field(default_factory=lambda: {
        k: 0.0 for k in COLLECTIVES})
    collective_counts: dict = field(default_factory=lambda: {
        k: 0 for k in COLLECTIVES})

    def scaled(self, k: float) -> "CostResult":
        return CostResult(self.flops * k, self.bytes * k,
                          {o: v * k for o, v in self.collective_bytes.items()},
                          {o: int(v * k) for o, v in
                           self.collective_counts.items()})

    def add(self, other: "CostResult"):
        self.flops += other.flops
        self.bytes += other.bytes
        for o in COLLECTIVES:
            self.collective_bytes[o] += other.collective_bytes[o]
            self.collective_counts[o] += other.collective_counts[o]

    @property
    def total_collective_bytes(self) -> float:
        return sum(self.collective_bytes.values())


def analyze(hlo: str) -> CostResult:
    comps = _parse(hlo)
    memo: dict[tuple, CostResult] = {}

    def comp_cost(name: str, count_bytes: bool) -> CostResult:
        key = (name, count_bytes)
        if key in memo:
            return memo[key]
        memo[key] = CostResult()          # break recursion defensively
        out = CostResult()
        instrs = comps.get(name, [])
        symtab = {i.name: i.type_str for i in instrs}
        for ins in instrs:
            op = ins.opcode
            # ---- flops
            if op == "dot":
                result = _shape_dims(ins.type_str)
                cd = _CDIM_RE.search(ins.rest)
                ops = _OPERAND_RE.findall(ins.rest)
                lhs_dims = _shape_dims(symtab.get(ops[0], "")) if ops else []
                k = 1
                if cd and lhs_dims:
                    for d in cd.group(1).split(","):
                        if d and int(d) < len(lhs_dims):
                            k *= lhs_dims[int(d)]
                n = 1
                for d in result:
                    n *= d
                out.flops += 2.0 * n * k
            # ---- control flow
            if op == "while":
                trips = 1
                mt = _TRIP_RE.search(ins.rest)
                if mt:
                    trips = int(mt.group(1))
                body = _BODY_RE.search(ins.rest)
                cond = _COND_RE.search(ins.rest)
                sub = CostResult()
                if body:
                    sub.add(comp_cost(body.group(1), count_bytes))
                if cond:
                    sub.add(comp_cost(cond.group(1), count_bytes))
                out.add(sub.scaled(trips))
                continue
            if op in ("fusion", "call", "map", "reduce", "reduce-window",
                      "scatter", "select-and-scatter", "sort", "conditional"):
                for called in _CALL_RE.findall(ins.rest):
                    # fusions: recurse for flops only — bytes are counted at
                    # the fusion boundary below
                    out.add(comp_cost(called, False))
            # ---- collectives
            for c in COLLECTIVES:
                if op == c or op == c + "-start":
                    opnds = _OPERAND_RE.findall(ins.rest.split(",")[0]
                                                if "(" not in ins.rest
                                                else ins.rest)
                    b = sum(_shape_bytes(symtab.get(o, "")) for o in
                            _OPERAND_RE.findall(ins.rest)
                            if o in symtab)
                    if b == 0:
                        b = _shape_bytes(ins.type_str)
                    out.collective_bytes[c] += b
                    out.collective_counts[c] += 1
                    break
            # ---- bytes at fusion boundary
            if count_bytes and op not in _FREE_OPS and \
                    not op.endswith("-done"):
                b = _shape_bytes(ins.type_str)
                for o in _OPERAND_RE.findall(ins.rest):
                    if o in symtab:
                        b += _shape_bytes(symtab[o])
                out.bytes += b
        memo[key] = out
        return out

    entry = None
    for line in hlo.splitlines():
        if line.startswith("ENTRY"):
            m = _COMP_RE.match(line[len("ENTRY"):].strip())
            if m:
                entry = m.group(1)
            break
    if entry is None:
        entry = next(iter(comps))
    return comp_cost(entry, True)
