"""ShapeDtypeStruct input stand-ins for every (arch × shape) cell — the
shannon/kernels pattern: weak-type-correct, shardable, no device allocation."""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig
from repro.distributed.ctx import MeshCtx
from repro.models.lm import LM
from repro.models.stack import cache_struct


def sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, jnp.dtype(dtype))


def supports_shape(cfg: ModelConfig, shape: ShapeConfig) -> tuple[bool, str]:
    if cfg.encoder_only and shape.kind == "decode":
        return False, "encoder-only arch has no decode step"
    return True, ""


def batch_specs(cfg: ModelConfig, shape: ShapeConfig, mesh: MeshCtx):
    """(batch ShapeDtypeStructs, batch PartitionSpecs) for a train/prefill cell."""
    B, S = shape.global_batch, shape.seq_len
    bp = mesh.batch_part(B)
    batch, specs = {}, {}
    if cfg.family == "audio":
        batch["frames"] = sds((B, S, cfg.frontend_dim), "bfloat16")
        specs["frames"] = P(bp, None, None)
    elif cfg.family == "vlm":
        Pn = cfg.num_patches
        batch["tokens"] = sds((B, S - Pn), "int32")
        specs["tokens"] = P(bp, None)
        batch["patches"] = sds((B, Pn, cfg.frontend_dim), "bfloat16")
        specs["patches"] = P(bp, None, None)
    else:
        batch["tokens"] = sds((B, S), "int32")
        specs["tokens"] = P(bp, None)
    if shape.kind == "train":
        batch["labels"] = sds((B, S), "int32")
        specs["labels"] = P(bp, None)
    return batch, specs


def decode_specs(cfg: ModelConfig, shape: ShapeConfig, mesh: MeshCtx, lm: LM):
    """(token, positions, cache) ShapeDtypeStructs + PartitionSpecs.

    Cache holds shape.seq_len-1 tokens; the lowered step writes token
    seq_len-1 and attends over the full window."""
    B, S = shape.global_batch, shape.seq_len
    bp = mesh.batch_part(B)
    cache_sds, cache_specs = cache_struct(cfg, mesh, lm.plan, B, S)
    token = sds((B, 1), "int32")
    positions = sds((), "int32")
    return (token, positions, cache_sds), (P(bp, None), P(), cache_specs)
