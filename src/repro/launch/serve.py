"""Serving launcher: --arch <id> through the full OmniInfer stack.

CPU-runnable with --reduced (real model, real engines); the same Server
object drives TPU-scale deployments with a production mesh. Per-request
decoding config rides on SamplingParams: --temperature > 0 switches the
whole batch from greedy to seeded device-side sampling.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-moe-a2.7b \
        --reduced --requests 8 --max-tokens 6 --temperature 0.8 --top-k 40
"""
from __future__ import annotations

import argparse
import json

import numpy as np

from repro.configs import get_config, reduced_config
from repro.core.proxy import OASConfig, SamplingParams
from repro.serving import Server, ServerConfig


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--tp", type=int, default=1,
                    help="tensor-parallel width (the mesh 'model' axis)")
    ap.add_argument("--ep", type=int, default=1,
                    help="expert-parallel width (the mesh 'data' axis)")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-tokens", type=int, default=6)
    ap.add_argument("--prefill", type=int, default=1)
    ap.add_argument("--decode", type=int, default=1)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=96)
    ap.add_argument("--no-proxy", action="store_true",
                    help="round-robin baseline (ablation)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--temperature", type=float, default=0.0,
                    help="0 → greedy (default); > 0 → seeded sampling")
    ap.add_argument("--top-k", type=int, default=0)
    ap.add_argument("--top-p", type=float, default=1.0)
    ap.add_argument("--stop-token", type=int, default=-1,
                    help="per-request stop token id (-1 → none)")
    args = ap.parse_args(argv)

    cfg = reduced_config(args.arch) if args.reduced else get_config(args.arch)
    oas = OASConfig(defer_window=0.0, cache_aware=not args.no_proxy,
                    lpt=not args.no_proxy, deferred=False)
    placement = None
    if args.tp > 1 or args.ep > 1:
        from repro.launch.mesh import make_production_ctx
        placement = make_production_ctx(tp=args.tp, ep=args.ep)
    srv = Server(cfg, ServerConfig(n_prefill=args.prefill,
                                   n_decode=args.decode,
                                   decode_slots=args.slots,
                                   max_len=args.max_len, oas=oas),
                 placement=placement)
    rng = np.random.default_rng(args.seed)
    shared = tuple(rng.integers(0, min(cfg.vocab_size, 500), 16).tolist())
    stop = (args.stop_token,) if args.stop_token >= 0 else ()
    reqs = []
    for i in range(args.requests):
        if i % 3 == 0:
            p = shared + tuple(rng.integers(0, 500, 4 + i).tolist())
        else:
            p = tuple(rng.integers(0, 500, int(rng.integers(8, 32))).tolist())
        reqs.append((p, SamplingParams(temperature=args.temperature,
                                       top_k=args.top_k, top_p=args.top_p,
                                       seed=args.seed + i,
                                       stop_token_ids=stop,
                                       max_tokens=args.max_tokens)))
    s = srv.run(reqs, max_wall_s=600)
    print(json.dumps({k: v for k, v in s.items()
                      if not isinstance(v, list)}, indent=1, default=float))
    return s


if __name__ == "__main__":
    main()
