"""Training launcher: --arch <id> + data pipeline + AdamW + checkpoint/resume.

Fault tolerance drill: `--preempt-at N` kills the process after step N
(simulated preemption); relaunching with the same --ckpt-dir resumes from the
latest committed checkpoint.
"""
from __future__ import annotations

import argparse
import sys
import time

import jax
import jax.numpy as jnp

from repro.checkpoint import CheckpointManager
from repro.configs import get_config, reduced_config
from repro.distributed.ctx import MeshCtx, local_mesh_ctx
from repro.models.lm import LM
from repro.training.data import DataConfig, make_batch
from repro.training.optim import adamw_init, opt_specs
from repro.training.trainer import make_train_step


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b")
    ap.add_argument("--reduced", action="store_true",
                    help="reduced config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--preempt-at", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg = reduced_config(args.arch) if args.reduced else get_config(args.arch)
    mesh = local_mesh_ctx()
    lm = LM.build(cfg, mesh)
    tables = lm.default_tables()
    step_fn = jax.jit(make_train_step(lm, lr=args.lr))

    mgr = CheckpointManager(args.ckpt_dir) if args.ckpt_dir else None
    start = 0
    if mgr is not None and mgr.latest_step() is not None:
        tmpl = {"params": lm.shapes(),
                "opt": jax.eval_shape(lambda: adamw_init(lm.shapes(),
                                                         cfg.optimizer_dtype))}
        state, start, _ = mgr.restore(template=tmpl)
        params, opt = state["params"], state["opt"]
        print(f"resumed from step {start}", flush=True)
    else:
        params = lm.init(jax.random.PRNGKey(0))
        opt = adamw_init(params, cfg.optimizer_dtype)

    dcfg = DataConfig(cfg.vocab_size, args.seq, args.batch)
    t0 = time.time()
    for step in range(start, args.steps):
        batch = make_batch(cfg, dcfg, step)
        params, opt, metrics = step_fn(params, opt, batch, tables)
        if step % args.log_every == 0 or step == args.steps - 1:
            print(f"step {step} loss {float(metrics['loss']):.4f} "
                  f"gnorm {float(metrics['grad_norm']):.3f} "
                  f"({time.time()-t0:.1f}s)", flush=True)
        if mgr is not None and (step + 1) % args.ckpt_every == 0:
            mgr.save(step + 1, {"params": params, "opt": opt})
        if args.preempt_at and step + 1 >= args.preempt_at:
            print(f"simulated preemption at step {step + 1}", flush=True)
            sys.exit(42)
    return float(metrics["loss"])


if __name__ == "__main__":
    main()
