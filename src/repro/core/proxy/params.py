"""Request-level serving API types: per-request decoding configuration and
the incremental output record.

These are pure-python (numpy only) so the proxy layer — which must stay
runtime-agnostic and importable without jax — can carry them on every
`Request`. The device-side fused sampler that consumes them lives in
`repro.serving.sampling`.

Determinism contract: the PRNG key for the token sampled after `n` context
tokens is `fold_in(seed_key(seed), n)`. Because the draw is a pure function
of (seed, position), the sampled stream is invariant to engine layout
(paged vs slot-dense), admission batching, and preemption/resume — the same
`SamplingParams(seed=...)` always yields the same tokens.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional

import numpy as np

FINISH_STOP = "stop"        # hit one of the request's stop_token_ids
FINISH_LENGTH = "length"    # generated max_tokens
FINISH_ABORT = "abort"      # cancelled via Server.abort(rid)
FINISH_ERROR = "error"      # retries exhausted (instance death / KV loss)
FINISH_TIMEOUT = "timeout"  # retired by the no-progress watchdog


class BackpressureError(RuntimeError):
    """Typed admission rejection (graceful load shedding): raised by
    Server.add_request/submit when a request could never be served (prompt
    larger than the whole KV pool) or when the admission backlog exceeds
    `ServerConfig.admission_queue_cap`. Shedding at the door replaces the
    livelock of a request deferring forever inside the engines; callers
    retry later or route elsewhere. Counted in `MetricsAggregator.n_shed`."""


@dataclass(frozen=True)
class SamplingParams:
    """Per-request decoding configuration (vLLM-style).

    temperature=0 (the default) is greedy argmax — bit-identical to the
    pre-sampling engines, so closed-batch callers keep their outputs.
    top_k <= 0 and top_p >= 1 disable the respective filters. seed=None
    derives the PRNG stream from the request id (still reproducible for a
    fixed rid assignment; pass an explicit seed for cross-run determinism).
    stop_token_ids=() falls back to the deprecated server-global
    `ServerConfig.eos_token`.
    """
    temperature: float = 0.0
    top_k: int = 0
    top_p: float = 1.0
    seed: Optional[int] = None
    stop_token_ids: tuple = ()
    max_tokens: int = 16

    def __post_init__(self):
        if self.temperature < 0:
            raise ValueError("temperature must be >= 0")
        if not 0 < self.top_p <= 1:
            raise ValueError("top_p must be in (0, 1]")
        if self.max_tokens < 1:
            raise ValueError("max_tokens must be >= 1")
        object.__setattr__(self, "top_k", int(self.top_k))
        object.__setattr__(self, "stop_token_ids",
                           tuple(int(t) for t in self.stop_token_ids))

    @property
    def greedy(self) -> bool:
        return self.temperature <= 0.0


GREEDY = SamplingParams()


@dataclass
class RequestOutput:
    """One request's delta for one `Server.step()`: the tokens generated
    this step (empty for an abort notification) and, on the final record,
    the finish reason."""
    rid: int
    new_tokens: tuple = ()
    finished: bool = False
    finish_reason: Optional[str] = None     # FINISH_STOP/LENGTH/ABORT/
                                            # ERROR/TIMEOUT
    n_generated: int = 0                    # total output tokens so far


def seed_key(seed: int) -> np.ndarray:
    """uint32[2] threefry base key for `seed` — numerically identical to
    `jax.random.PRNGKey(seed)` without a device round-trip (negative seeds
    wrap into the same 64-bit space)."""
    s = int(seed) & ((1 << 64) - 1)
    return np.array([s >> 32, s & 0xFFFFFFFF], np.uint32)


def device_row(params: Optional[SamplingParams], rid: int = 0) -> tuple:
    """(temperature, top_k, top_p, base_key) scalars for one slot of the
    engines' device-side parameter tensors."""
    p = params if params is not None else GREEDY
    seed = p.seed if p.seed is not None else rid
    return float(p.temperature), int(p.top_k), float(p.top_p), seed_key(seed)
