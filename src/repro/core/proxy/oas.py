"""OmniProxy: Omni Adaptive Scheduling (OAS) — paper §5.1.

A deterministic, runtime-agnostic scheduling layer driven by explicit
`tick(now)` calls (the Nginx event loop of the paper becomes an explicit
scheduler tick so the SAME policy code runs under the real in-process engine
and the discrete-event cluster simulator).

Policies:
  · Prefill: cache-informed load balancing — π_P(i) = Match_P(i) − α·ρ_P
    (eq. 8), Match from the per-instance radix tree, ρ_P = running requests +
    queued tokens (normalized);
  · Decode: Longest-Processing-Time-first on ℓ_i = T_prompt + T_max (eq. 9),
    dispatched to the least-loaded healthy decode instance;
  · Deferred submission & resorting: requests are held up to
    `defer_window` (bounded by the predicted upstream batch cycle — EWMA of
    instance batch time) so each tick dispatches a coherent, re-sorted group;
  · Straggler mitigation (beyond-paper, required at 1000+ nodes): EWMA batch
    time per instance; instances slower than `straggler_factor` × peer median
    are score-penalized, and prefills stuck longer than `timeout_factor` ×
    expected service time are re-dispatched elsewhere.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional

import numpy as np

from repro.core.proxy.lifecycle import Phase, Request
from repro.core.proxy.radix import RadixTree


@dataclass
class OASConfig:
    alpha: float = 0.5              # cache-affinity vs load trade-off (eq. 8)
    defer_window: float = 0.02      # max deferred-submission delay (s)
    ewma_beta: float = 0.2
    straggler_factor: float = 2.0
    straggler_penalty: float = 0.5
    timeout_factor: float = 10.0
    max_retries: int = 2
    retry_backoff_s: float = 0.0    # re-dispatch delay × n_retries (0 → off)
    lpt: bool = True                # decode LPT ordering (ablation switch)
    cache_aware: bool = True        # prefill APC-aware scoring (ablation)
    deferred: bool = True           # deferred submission (ablation)


@dataclass
class InstanceStats:
    iid: int
    kind: str                       # 'prefill' | 'decode'
    queue_len: int = 0
    running: int = 0
    queued_tokens: int = 0
    running_tokens: int = 0
    ewma_batch_time: float = 0.0
    completed: int = 0
    healthy: bool = True

    def load(self) -> float:
        """ρ_P: running requests + tokens, normalized (eq. 8)."""
        return (self.running + self.queue_len) + \
            (self.running_tokens + self.queued_tokens) / 4096.0

    def observe_batch_time(self, dt: float, beta: float):
        self.ewma_batch_time = dt if self.ewma_batch_time == 0 else \
            beta * dt + (1 - beta) * self.ewma_batch_time


class OmniProxy:
    def __init__(self, n_prefill: int, n_decode: int,
                 cfg: Optional[OASConfig] = None,
                 radix_capacity: int = 1 << 20):
        self.cfg = cfg or OASConfig()
        self.prefill = [InstanceStats(i, "prefill") for i in range(n_prefill)]
        self.decode = [InstanceStats(i, "decode") for i in range(n_decode)]
        self.trees = [RadixTree(radix_capacity) for _ in range(n_prefill)]
        self.pending: list[Request] = []          # deferred submission pool
        self.decode_wait: list[Request] = []
        self.inflight: dict[int, Request] = {}
        self._rr = 0                              # round-robin fallback state
        self.dispatch_log: list[dict] = []

    # ------------------------------------------------------------------
    def submit(self, req: Request, now: float):
        req.advance(Phase.TOKENIZE, now)
        req.advance(Phase.APC_MATCH, now)
        self.pending.append(req)
        self.inflight[req.rid] = req

    # ------------------------------------------------------------------
    def _prefill_score(self, req: Request, inst: InstanceStats) -> float:
        match = self.trees[inst.iid].match(req.tokens) if self.cfg.cache_aware else 0
        rho = inst.load()
        score = match / max(req.prompt_len, 1) - self.cfg.alpha * rho
        if self._is_straggler(inst, self.prefill):
            score -= self.cfg.straggler_penalty
        return score

    def _is_straggler(self, inst: InstanceStats, peers) -> bool:
        times = [p.ewma_batch_time for p in peers if p.ewma_batch_time > 0]
        if not times or inst.ewma_batch_time == 0:
            return False
        return inst.ewma_batch_time > self.cfg.straggler_factor * float(np.median(times))

    def _predicted_cycle(self) -> float:
        times = [p.ewma_batch_time for p in self.prefill if p.ewma_batch_time > 0]
        return float(np.median(times)) if times else 0.0

    # ------------------------------------------------------------------
    def tick(self, now: float) -> list[tuple[Request, InstanceStats, str]]:
        """Dispatch decisions for this tick: (request, instance, stage)."""
        actions: list[tuple[Request, InstanceStats, str]] = []

        # ---- deferred submission: release requests whose defer window
        # expired or who align with the predicted upstream batch cycle
        if self.cfg.deferred:
            cycle = min(self._predicted_cycle(), self.cfg.defer_window)
            ready = [r for r in self.pending if now - r.arrival >= cycle
                     and now >= r.not_before]
        else:
            ready = [r for r in self.pending if now >= r.not_before]

        # ---- resorting: coherent groups — short prompts first within the
        # released group keeps prefill batches uniform (reduces bubbles)
        ready.sort(key=lambda r: r.prompt_len)

        for req in ready:
            self.pending.remove(req)
            healthy = [p for p in self.prefill if p.healthy]
            if not healthy:
                req.advance(Phase.FAILED, now)
                continue
            if self.cfg.cache_aware:
                inst = max(healthy, key=lambda p: self._prefill_score(req, p))
            else:                                  # round-robin baseline (Nginx)
                inst = healthy[self._rr % len(healthy)]
                self._rr += 1
            req.prefix_match = self.trees[inst.iid].match(req.tokens, now)
            req.prefill_instance = inst.iid
            req.advance(Phase.PREFILL_SCHEDULED, now)
            inst.queue_len += 1
            inst.queued_tokens += req.prompt_len - req.prefix_match
            self.trees[inst.iid].insert(req.tokens, now)
            actions.append((req, inst, "prefill"))
            self.dispatch_log.append({"rid": req.rid, "stage": "prefill",
                                      "iid": inst.iid, "match": req.prefix_match})

        # ---- decode side: LPT over waiting requests
        wait = sorted(self.decode_wait,
                      key=lambda r: -r.effective_load if self.cfg.lpt else r.rid)
        for req in wait:
            healthy = [d for d in self.decode if d.healthy]
            if not healthy:
                break
            inst = min(healthy, key=lambda d: d.load() +
                       (self.cfg.straggler_penalty
                        if self._is_straggler(d, self.decode) else 0))
            self.decode_wait.remove(req)
            req.decode_instance = inst.iid
            req.advance(Phase.DECODE_SCHEDULED, now)
            inst.queue_len += 1
            inst.queued_tokens += req.max_tokens
            actions.append((req, inst, "decode"))
            self.dispatch_log.append({"rid": req.rid, "stage": "decode",
                                      "iid": inst.iid})
        return actions

    # ---- engine callbacks --------------------------------------------
    def on_prefill_start(self, req: Request, now: float):
        inst = self.prefill[req.prefill_instance]
        inst.queue_len -= 1
        inst.queued_tokens -= req.prompt_len - req.prefix_match
        inst.running += 1
        inst.running_tokens += req.prompt_len
        req.advance(Phase.PREFILL_RUNNING, now)

    def on_prefill_done(self, req: Request, now: float, batch_time: float = 0.0):
        inst = self.prefill[req.prefill_instance]
        inst.running -= 1
        inst.running_tokens -= req.prompt_len
        inst.completed += 1
        if batch_time > 0:
            inst.observe_batch_time(batch_time, self.cfg.ewma_beta)
        req.advance(Phase.DECODE_WAIT, now)
        self.decode_wait.append(req)

    def on_decode_start(self, req: Request, now: float):
        inst = self.decode[req.decode_instance]
        inst.queue_len -= 1
        inst.queued_tokens -= req.max_tokens
        inst.running += 1
        inst.running_tokens += req.effective_load
        req.advance(Phase.DECODE_RUNNING, now)

    def on_decode_requeue(self, req: Request, now: float):
        """Admission refused (no slot / no KV blocks): return the request to
        the decode wait pool, undoing the schedule-time accounting."""
        inst = self.decode[req.decode_instance]
        inst.queue_len -= 1
        inst.queued_tokens -= req.max_tokens
        req.decode_instance = None
        req.advance(Phase.DECODE_WAIT, now)
        self.decode_wait.append(req)

    def _reroute_to_prefill(self, req: Request, now: float) -> bool:
        """Shared recovery tail for every KV-loss path: clear placement,
        wipe the output buffer (draws are positional, so the regenerated
        prefix is bit-identical and the server's per-rid delivered counter
        suppresses re-streaming it) and re-enter the deferred-submission
        pool. Bounded by `max_retries`: a request whose KV keeps vanishing
        must not re-enter the prefill queue forever — exhausted retries
        advance to Phase.FAILED, which the server retires with
        finish_reason="error". retry_backoff_s > 0 delays the re-dispatch
        by backoff × n_retries (linear backoff)."""
        if req.n_retries >= self.cfg.max_retries:
            req.advance(Phase.FAILED, now)
            return False
        req.n_retries += 1
        req.prefill_instance = None
        req.decode_instance = None
        req.output_tokens.clear()
        if self.cfg.retry_backoff_s > 0:
            req.not_before = max(req.not_before,
                                 now + self.cfg.retry_backoff_s * req.n_retries)
        req.advance(Phase.APC_MATCH, now)
        self.pending.append(req)
        return True

    def on_decode_kv_lost(self, req: Request, now: float) -> bool:
        """Scheduled for decode but its KV vanished (e.g. decode-instance
        failure between admissions, a dropped handoff payload): undo the
        schedule accounting and route the request back through prefill from
        scratch — retry-capped (see _reroute_to_prefill). → re-dispatched?"""
        inst = self.decode[req.decode_instance]
        inst.queue_len -= 1
        inst.queued_tokens -= req.max_tokens
        req.decode_instance = None
        return self._reroute_to_prefill(req, now)

    def on_decode_restart(self, req: Request, now: float) -> bool:
        """A RUNNING decode request lost its KV (engine-detected loss,
        corruption quarantine): undo the running accounting and route back
        through prefill from scratch — retry-capped."""
        inst = self.decode[req.decode_instance]
        inst.running -= 1
        inst.running_tokens -= req.effective_load
        req.decode_instance = None
        return self._reroute_to_prefill(req, now)

    def on_prefill_restart(self, req: Request, now: float) -> bool:
        """An in-flight prefill lost its blocks (corruption quarantine —
        whole-instance death goes through mark_unhealthy): undo the phase
        accounting and re-dispatch — retry-capped."""
        if req.prefill_instance is not None:
            inst = self.prefill[req.prefill_instance]
            if req.phase == Phase.PREFILL_RUNNING:
                inst.running -= 1
                inst.running_tokens -= req.prompt_len
            elif req.phase == Phase.PREFILL_SCHEDULED:
                inst.queue_len -= 1
                inst.queued_tokens -= req.prompt_len - req.prefix_match
        return self._reroute_to_prefill(req, now)

    def on_handoff_lost(self, req: Request, now: float) -> bool:
        """A parked (prefill-done, not yet admitted) handoff lost its KV:
        prefill accounting is closed and decode accounting not yet opened —
        just leave the wait pool and reroute through prefill, retry-capped."""
        self.decode_wait = [r for r in self.decode_wait if r.rid != req.rid]
        return self._reroute_to_prefill(req, now)

    def on_decode_preempt(self, req: Request, now: float):
        """Running request evicted by the engine (KV block exhaustion):
        back to the wait pool for re-admission with its extracted cache."""
        inst = self.decode[req.decode_instance]
        inst.running -= 1
        inst.running_tokens -= req.effective_load
        req.decode_instance = None
        req.advance(Phase.DECODE_WAIT, now)
        self.decode_wait.append(req)

    def on_first_token(self, req: Request, now: float):
        if req.first_token_time is None:
            req.first_token_time = now

    def on_early_finish(self, req: Request, now: float):
        """Request finished at its FIRST token (stop token hit, or
        max_tokens == 1): it sits in decode_wait with no decode instance —
        retire it without ever admitting to decode."""
        self.decode_wait = [r for r in self.decode_wait if r.rid != req.rid]
        req.finish_time = now
        req.advance(Phase.DONE, now)
        self.inflight.pop(req.rid, None)

    def abort(self, rid: int, now: float) -> Optional[Request]:
        """Cancel a request wherever it lives, undoing any instance
        accounting its current phase holds. → the Request (finish_reason
        set to "abort"), or None if the rid is not in flight. The caller
        (server) releases engine-side state: prefill queue tasks,
        pending-KV handoffs, decode slots + KVPool blocks."""
        req = self.inflight.pop(rid, None)
        if req is None:
            return None
        if any(r.rid == rid for r in self.pending):
            self.pending = [r for r in self.pending if r.rid != rid]
        elif any(r.rid == rid for r in self.decode_wait):
            # prefill accounting already closed by on_prefill_done; decode
            # accounting not yet opened (or undone by requeue/preempt)
            self.decode_wait = [r for r in self.decode_wait if r.rid != rid]
        elif req.phase == Phase.PREFILL_RUNNING and \
                req.prefill_instance is not None:
            inst = self.prefill[req.prefill_instance]
            inst.running -= 1
            inst.running_tokens -= req.prompt_len
        elif req.phase == Phase.PREFILL_SCHEDULED and \
                req.prefill_instance is not None:
            inst = self.prefill[req.prefill_instance]
            inst.queue_len -= 1
            inst.queued_tokens -= req.prompt_len - req.prefix_match
        elif req.phase == Phase.DECODE_RUNNING and \
                req.decode_instance is not None:
            inst = self.decode[req.decode_instance]
            inst.running -= 1
            inst.running_tokens -= req.effective_load
        elif req.phase == Phase.DECODE_SCHEDULED and \
                req.decode_instance is not None:
            inst = self.decode[req.decode_instance]
            inst.queue_len -= 1
            inst.queued_tokens -= req.max_tokens
        req.finish_reason = "abort"
        req.finish_time = now
        req.advance(Phase.DONE, now)
        return req

    def on_decode_done(self, req: Request, now: float, batch_time: float = 0.0):
        inst = self.decode[req.decode_instance]
        inst.running -= 1
        inst.running_tokens -= req.effective_load
        inst.completed += 1
        if batch_time > 0:
            inst.observe_batch_time(batch_time, self.cfg.ewma_beta)
        req.finish_time = now
        req.advance(Phase.DONE, now)
        self.inflight.pop(req.rid, None)

    # ---- fault handling ----------------------------------------------
    def mark_unhealthy(self, kind: str, iid: int, now: float) -> list[Request]:
        """Instance failure: requeue its in-flight requests (fault tolerance)."""
        pool = self.prefill if kind == "prefill" else self.decode
        pool[iid].healthy = False
        requeued = []
        for req in list(self.inflight.values()):
            if kind == "prefill" and req.prefill_instance == iid and \
                    req.phase in (Phase.PREFILL_SCHEDULED, Phase.PREFILL_RUNNING):
                # accounting is zeroed wholesale below — only reroute here
                if self._reroute_to_prefill(req, now):
                    requeued.append(req)
            elif kind == "decode" and req.decode_instance == iid and \
                    req.phase in (Phase.DECODE_SCHEDULED, Phase.DECODE_RUNNING):
                if req.n_retries >= self.cfg.max_retries:
                    req.advance(Phase.FAILED, now)
                    continue
                req.n_retries += 1
                req.decode_instance = None
                req.advance(Phase.DECODE_WAIT, now)
                self.decode_wait.append(req)
                requeued.append(req)
        pool[iid].queue_len = 0
        pool[iid].running = 0
        pool[iid].queued_tokens = 0
        pool[iid].running_tokens = 0
        return requeued

    def mark_healthy(self, kind: str, iid: int):
        (self.prefill if kind == "prefill" else self.decode)[iid].healthy = True
