"""Serving metrics aggregation — the columns of paper Table 2:
TTFT / p99 TTFT / TPOT / p99 TPOT / QPM / E2E / p99 E2E / OTT / TTT."""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from repro.core.proxy.lifecycle import Request


@dataclass
class MetricsAggregator:
    done: list = field(default_factory=list)

    def add(self, req: Request):
        if req.finish_time is not None:
            self.done.append(req)

    def summary(self, wall_time: float) -> dict:
        if not self.done:
            return {"qpm": 0.0}
        ttft = np.array([r.ttft() for r in self.done if r.ttft() is not None])
        tpot = np.array([r.tpot() for r in self.done if r.tpot() is not None])
        e2e = np.array([r.e2e() for r in self.done])
        out_toks = sum(len(r.output_tokens) for r in self.done)
        tot_toks = out_toks + sum(r.prompt_len for r in self.done)
        wall = max(wall_time, 1e-9)
        pct = lambda a, p: float(np.percentile(a, p)) if len(a) else float("nan")
        return {
            "n_done": len(self.done),
            "qpm": 60.0 * len(self.done) / wall,
            "ttft_mean": float(ttft.mean()) if len(ttft) else float("nan"),
            "ttft_p99": pct(ttft, 99),
            "tpot_mean_ms": 1e3 * float(tpot.mean()) if len(tpot) else float("nan"),
            "tpot_p99_ms": 1e3 * pct(tpot, 99),
            "e2e_mean": float(e2e.mean()),
            "e2e_p99": pct(e2e, 99),
            "ott_tok_s": out_toks / wall,
            "ttt_tok_s": tot_toks / wall,
        }
