"""Serving metrics aggregation — the columns of paper Table 2:
TTFT / p99 TTFT / TPOT / p99 TPOT / QPM / E2E / p99 E2E / OTT / TTT."""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from repro.core.proxy.lifecycle import Request


@dataclass
class MetricsAggregator:
    done: list = field(default_factory=list)
    aborted: list = field(default_factory=list)
    # robustness plane (FaultPlane recovery machinery): requests retired
    # with finish_reason="error" (retries exhausted) / "timeout" (watchdog),
    # admissions shed at the door (BackpressureError), arena blocks pulled
    # from circulation by the summary-plane corruption scan, and the total
    # re-dispatch count — the columns that make robustness regressions
    # visible next to the latency figures.
    errors: list = field(default_factory=list)
    timeouts: list = field(default_factory=list)
    n_shed: int = 0
    blocks_quarantined: int = 0
    # PD transfer-cost model: true bytes = the KV payload actually resident
    # (prompt tokens), padded bytes = what a dense max_len handoff pytree
    # would meter. The old model reported only the padded figure — a
    # 64-token prompt in a max_len=2048 cache charged 32× its real bytes.
    kv_transfer_true_bytes: int = 0
    kv_transfer_padded_bytes: int = 0
    # OmniAttn online sparsity (layer-averaged engine figures): resident
    # blocks scored vs blocks actually attended per decode across the run,
    # and the exact attention mass the selected blocks captured (weighted
    # mean; only measured when the engine runs with topk_measure_mass).
    blocks_scored: int = 0
    blocks_attended: int = 0
    attn_mass_sum: float = 0.0
    attn_mass_n: float = 0.0
    # SpecPlane (model-free speculative decoding): draft tokens proposed vs
    # accepted by the batched verify, tokens emitted by verify steps, and
    # the verify-step count — the figures behind the `draft_acceptance` and
    # `tokens_per_verify` summary columns.
    spec_drafted: int = 0
    spec_accepted: int = 0
    spec_emitted: int = 0
    spec_verifies: int = 0

    def add(self, req: Request):
        if req.finish_time is not None:
            self.done.append(req)

    def add_aborted(self, req: Request):
        """Cancelled requests are tracked separately: they count in
        `n_aborted` but never pollute the latency distributions."""
        self.aborted.append(req)

    def add_error(self, req: Request):
        """Request retired after exhausting its retry budget."""
        self.errors.append(req)

    def add_timeout(self, req: Request):
        """Request retired by the no-progress watchdog."""
        self.timeouts.append(req)

    def note_shed(self, n: int = 1):
        """Admission rejected with BackpressureError (never entered
        the lifecycle, so there is no Request to keep)."""
        self.n_shed += n

    def note_quarantine(self, n: int = 1):
        """Arena blocks pulled from circulation by the corruption scan."""
        self.blocks_quarantined += n

    def note_kv_transfer(self, true_bytes: int, padded_bytes: int):
        """Record one admission round's KV handoff payload (both figures,
        so the padding distortion stays visible in summaries)."""
        self.kv_transfer_true_bytes += true_bytes
        self.kv_transfer_padded_bytes += padded_bytes

    def note_sparsity(self, scored: int, attended: int, mass_sum: float,
                      mass_n: float):
        """Record one decode engine's drained online-sparsity window
        (layer-averaged block counts + attention-mass accumulators)."""
        self.blocks_scored += int(scored)
        self.blocks_attended += int(attended)
        self.attn_mass_sum += mass_sum
        self.attn_mass_n += mass_n

    def note_spec(self, drafted, accepted, emitted, verifies):
        """Record one decode engine's drained speculation window
        ([drafted, accepted, emitted, verify steps])."""
        self.spec_drafted += int(round(float(drafted)))
        self.spec_accepted += int(round(float(accepted)))
        self.spec_emitted += int(round(float(emitted)))
        self.spec_verifies += int(round(float(verifies)))

    def _spec(self) -> dict:
        d, n = self.spec_drafted, self.spec_verifies
        return {"spec_drafted": d,
                "spec_accepted": self.spec_accepted,
                "spec_verifies": n,
                "draft_acceptance": (self.spec_accepted / d if d
                                     else float("nan")),
                "tokens_per_verify": (self.spec_emitted / n if n
                                      else float("nan"))}

    def _sparsity(self) -> dict:
        mass = (self.attn_mass_sum / self.attn_mass_n
                if self.attn_mass_n else float("nan"))
        return {"blocks_scored": self.blocks_scored,
                "blocks_attended": self.blocks_attended,
                "attn_mass_kept": mass}

    def _reasons(self) -> dict:
        n_stop = sum(1 for r in self.done if r.finish_reason == "stop")
        n_length = sum(1 for r in self.done if r.finish_reason == "length")
        return {"n_stop": n_stop, "n_length": n_length,
                "n_aborted": len(self.aborted)}

    def _robustness(self) -> dict:
        n_retries = sum(r.n_retries for pool in
                        (self.done, self.aborted, self.errors, self.timeouts)
                        for r in pool)
        return {"n_errors": len(self.errors),
                "n_timeouts": len(self.timeouts),
                "n_shed": self.n_shed,
                "n_retries": n_retries,
                "blocks_quarantined": self.blocks_quarantined}

    def summary(self, wall_time: float) -> dict:
        if not self.done:
            # zero-done is a normal state now (every request aborted, or the
            # wall clock expired): keep the full key set so consumers that
            # index n_done / latency columns unconditionally don't KeyError
            nan = float("nan")
            return {"n_done": 0, "qpm": 0.0, **self._reasons(),
                    **self._robustness(),
                    "ttft_mean": nan, "ttft_p99": nan,
                    "tpot_mean_ms": nan, "tpot_p99_ms": nan,
                    "e2e_mean": nan, "e2e_p99": nan,
                    "ott_tok_s": 0.0, "ttt_tok_s": 0.0,
                    "kv_transfer_true_bytes": self.kv_transfer_true_bytes,
                    "kv_transfer_padded_bytes": self.kv_transfer_padded_bytes,
                    **self._sparsity(), **self._spec()}
        ttft = np.array([r.ttft() for r in self.done if r.ttft() is not None])
        tpot = np.array([r.tpot() for r in self.done if r.tpot() is not None])
        e2e = np.array([r.e2e() for r in self.done])
        out_toks = sum(len(r.output_tokens) for r in self.done)
        tot_toks = out_toks + sum(r.prompt_len for r in self.done)
        wall = max(wall_time, 1e-9)
        pct = lambda a, p: float(np.percentile(a, p)) if len(a) else float("nan")
        return {
            "n_done": len(self.done),
            **self._reasons(),
            **self._robustness(),
            "qpm": 60.0 * len(self.done) / wall,
            "ttft_mean": float(ttft.mean()) if len(ttft) else float("nan"),
            "ttft_p99": pct(ttft, 99),
            "tpot_mean_ms": 1e3 * float(tpot.mean()) if len(tpot) else float("nan"),
            "tpot_p99_ms": 1e3 * pct(tpot, 99),
            "e2e_mean": float(e2e.mean()),
            "e2e_p99": pct(e2e, 99),
            "ott_tok_s": out_toks / wall,
            "ttt_tok_s": tot_toks / wall,
            "kv_transfer_true_bytes": self.kv_transfer_true_bytes,
            "kv_transfer_padded_bytes": self.kv_transfer_padded_bytes,
            **self._sparsity(), **self._spec(),
        }
