"""Unified request lifecycle — paper §5.1 (eight phases)."""
from __future__ import annotations

import enum
from dataclasses import dataclass, field
from typing import Optional


class Phase(enum.IntEnum):
    TOKENIZE = 0
    APC_MATCH = 1
    PREFILL_WAIT = 2
    PREFILL_SCHEDULED = 3
    PREFILL_RUNNING = 4
    DECODE_WAIT = 5
    DECODE_SCHEDULED = 6
    DECODE_RUNNING = 7
    DONE = 8
    FAILED = 9


@dataclass
class Request:
    rid: int
    tokens: tuple                      # prompt token ids
    max_tokens: int                    # generation budget (T_max)
    arrival: float = 0.0
    phase: Phase = Phase.TOKENIZE
    phase_times: dict = field(default_factory=dict)
    prefix_match: int = 0              # Match_P(i) on the chosen instance
    prefill_instance: Optional[int] = None
    decode_instance: Optional[int] = None
    output_tokens: list = field(default_factory=list)
    first_token_time: Optional[float] = None
    finish_time: Optional[float] = None
    n_retries: int = 0                 # straggler/failure re-dispatches
    not_before: float = 0.0            # retry backoff: earliest re-dispatch
    sampling: Optional[object] = None  # SamplingParams (None → greedy legacy)
    finish_reason: Optional[str] = None   # "stop" | "length" | "abort" |
                                          # "error" | "timeout"

    def advance(self, phase: Phase, now: float):
        self.phase = phase
        self.phase_times[phase.name] = now

    @property
    def prompt_len(self) -> int:
        return len(self.tokens)

    @property
    def effective_load(self) -> int:
        """ℓ_i = T_prompt + T_max (paper eq. 9) — LPT key for decode."""
        return self.prompt_len + self.max_tokens

    # ---- derived metrics --------------------------------------------
    def ttft(self) -> Optional[float]:
        if self.first_token_time is None:
            return None
        return self.first_token_time - self.arrival

    def tpot(self) -> Optional[float]:
        if self.finish_time is None or self.first_token_time is None:
            return None
        n = max(len(self.output_tokens) - 1, 1)
        return (self.finish_time - self.first_token_time) / n

    def e2e(self) -> Optional[float]:
        if self.finish_time is None:
            return None
        return self.finish_time - self.arrival
