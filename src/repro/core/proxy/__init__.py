from repro.core.proxy.radix import RadixTree
from repro.core.proxy.lifecycle import Phase, Request
from repro.core.proxy.oas import InstanceStats, OASConfig, OmniProxy
from repro.core.proxy.metrics import MetricsAggregator
from repro.core.proxy.params import (GREEDY, BackpressureError, RequestOutput,
                                     SamplingParams, device_row, seed_key)

__all__ = ["RadixTree", "Phase", "Request", "InstanceStats", "OASConfig",
           "OmniProxy", "MetricsAggregator", "SamplingParams",
           "RequestOutput", "BackpressureError", "GREEDY", "device_row",
           "seed_key"]
