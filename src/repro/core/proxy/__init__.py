from repro.core.proxy.radix import RadixTree
from repro.core.proxy.lifecycle import Phase, Request
from repro.core.proxy.oas import InstanceStats, OASConfig, OmniProxy
from repro.core.proxy.metrics import MetricsAggregator

__all__ = ["RadixTree", "Phase", "Request", "InstanceStats", "OASConfig",
           "OmniProxy", "MetricsAggregator"]
