"""Radix tree for Automatic-Prefix-Cache (APC) matching — paper §5.1.

Token-sequence radix tree with path compression, LRU eviction by token count.
One tree per prefill instance mirrors that instance's KV block cache, so
Match_P(i) (eq. 8) = longest cached prefix on instance P.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional


@dataclass
class _Node:
    edge: tuple = ()                      # compressed token run from parent
    children: dict = field(default_factory=dict)   # first-token → _Node
    last_access: float = 0.0
    n_tokens_here: int = 0                # tokens stored on this edge
    payload: object = None                # engine-side KV handle at this depth


def _common_prefix(a: tuple, b: tuple) -> int:
    n = min(len(a), len(b))
    i = 0
    while i < n and a[i] == b[i]:
        i += 1
    return i


class RadixTree:
    def __init__(self, capacity_tokens: int = 1 << 20):
        self.root = _Node()
        self.capacity = capacity_tokens
        self.total_tokens = 0
        self._clock = 0.0

    # ------------------------------------------------------------------
    def match(self, tokens, now: Optional[float] = None) -> int:
        """Longest cached prefix length (touches nodes for LRU)."""
        self._clock = now if now is not None else self._clock + 1e-9
        tokens = tuple(tokens)
        node, matched = self.root, 0
        while True:
            node.last_access = self._clock
            rest = tokens[matched:]
            if not rest or rest[0] not in node.children:
                return matched
            child = node.children[rest[0]]
            cp = _common_prefix(child.edge, rest)
            matched += cp
            if cp < len(child.edge):
                child.last_access = self._clock
                return matched
            node = child

    def insert(self, tokens, now: Optional[float] = None) -> int:
        """Insert a sequence; returns newly-added token count."""
        self._clock = now if now is not None else self._clock + 1e-9
        tokens = tuple(tokens)
        node, matched, added = self.root, 0, 0
        while matched < len(tokens):
            node.last_access = self._clock
            rest = tokens[matched:]
            child = node.children.get(rest[0])
            if child is None:
                new = _Node(edge=rest, last_access=self._clock,
                            n_tokens_here=len(rest))
                node.children[rest[0]] = new
                added += len(rest)
                matched = len(tokens)
                break
            cp = _common_prefix(child.edge, rest)
            if cp == len(child.edge):
                matched += cp
                node = child
                continue
            # split the edge at cp
            mid = _Node(edge=child.edge[:cp], last_access=self._clock,
                        n_tokens_here=cp)
            child.edge = child.edge[cp:]
            child.n_tokens_here = len(child.edge)
            mid.children[child.edge[0]] = child
            node.children[rest[0]] = mid
            matched += cp
            node = mid
        self.total_tokens += added
        if self.total_tokens > self.capacity:
            self._evict()
        return added

    # ------------------------------------------------------------------
    # Payload handles: the serving engine marks prefixes whose KV is
    # resident in its store, so Match_P scoring (eq. 8) and the engine agree
    # on what a prefix hit is actually worth.
    def attach(self, tokens, payload, now: Optional[float] = None) -> bool:
        """Insert `tokens` and attach a payload handle at its exact boundary;
        → True if attached. insert() splits edges at every divergence point —
        including the strict-prefix case — so the walk below consumes whole
        edges and ends on a node at exactly len(tokens), UNLESS insert's own
        LRU eviction removed part of the just-inserted path (prompt longer
        than the tree capacity): then we report False instead of attaching."""
        self.insert(tokens, now)
        tokens = tuple(tokens)
        if not tokens:
            self.root.payload = payload
            return True
        node, matched = self.root, 0
        while matched < len(tokens):
            child = node.children.get(tokens[matched])
            if child is None:
                return False          # evicted mid-path: no boundary node
            node = child
            matched += len(node.edge)
        if matched != len(tokens):
            return False
        node.payload = payload
        return True

    def detach(self, tokens, payload=None) -> bool:
        """Clear the payload handle at exactly the `tokens` boundary; when
        `payload` is given, clear only if it still matches (a superseding
        attach may have replaced it). → True if a handle was cleared.
        Dropped store entries call this so stale handles don't linger on
        the matched path until eviction."""
        tokens = tuple(tokens)
        node, matched = self.root, 0
        while matched < len(tokens):
            child = node.children.get(tokens[matched])
            if child is None:
                return False
            cp = _common_prefix(child.edge, tokens[matched:])
            if cp < len(child.edge):
                return False
            node = child
            matched += cp
        if node.payload is None or \
                (payload is not None and node.payload != payload):
            return False
        node.payload = None
        return True

    def payload_prefixes(self, tokens, now: Optional[float] = None) -> list:
        """All (depth, payload) pairs on the matched path of `tokens`,
        shallow → deep. Handles may be stale (evicted store entries):
        callers must validate against their own store."""
        self._clock = now if now is not None else self._clock + 1e-9
        tokens = tuple(tokens)
        node, matched, found = self.root, 0, []
        while True:
            node.last_access = self._clock
            rest = tokens[matched:]
            if not rest or rest[0] not in node.children:
                return found
            child = node.children[rest[0]]
            cp = _common_prefix(child.edge, rest)
            matched += cp
            if cp < len(child.edge):
                return found
            if child.payload is not None:
                found.append((matched, child.payload))
            node = child

    # ------------------------------------------------------------------
    # Speculative drafting (SpecPlane): read-only n-gram continuation.
    def continuation(self, tokens, k: int) -> list:
        """Up to `k` tokens the tree stores immediately AFTER the exact
        sequence `tokens` — the prompt-lookup draft for model-free
        speculation. READ-ONLY: no LRU touch, no clock advance, so drafting
        never perturbs eviction order (spec on/off must not change which
        prefixes stay cached). Returns [] unless the whole of `tokens`
        is present; at branch points the walk descends into the
        most-recently-accessed child (ties broken by smallest token) —
        a deterministic 'most recent continuation wins' policy."""
        if k <= 0:
            return []
        tokens = tuple(tokens)
        node, matched = self.root, 0
        out: list = []
        while matched < len(tokens):
            rest = tokens[matched:]
            child = node.children.get(rest[0])
            if child is None:
                return []
            cp = _common_prefix(child.edge, rest)
            matched += cp
            if cp < len(child.edge):
                if matched < len(tokens):
                    return []          # diverged mid-edge: no exact match
                out.extend(child.edge[cp:cp + k])   # ends inside this edge
            node = child
        while len(out) < k and node.children:
            tok = min(node.children, key=lambda t:
                      (-node.children[t].last_access, t))
            child = node.children[tok]
            take = min(k - len(out), len(child.edge))
            out.extend(child.edge[:take])
            if take < len(child.edge):
                break
            node = child
        return out

    # ------------------------------------------------------------------
    def _evict(self):
        """Evict least-recently-used leaves until under capacity."""
        while self.total_tokens > self.capacity:
            leaf, parent, key = self._lru_leaf()
            if leaf is None:
                return
            self.total_tokens -= leaf.n_tokens_here
            del parent.children[key]

    def _lru_leaf(self):
        best = (None, None, None, float("inf"))
        stack = [(self.root, None, None)]
        while stack:
            node, parent, key = stack.pop()
            if not node.children and parent is not None:
                if node.last_access < best[3]:
                    best = (node, parent, key, node.last_access)
            for k, c in node.children.items():
                stack.append((c, node, k))
        return best[0], best[1], best[2]

    def size_tokens(self) -> int:
        return self.total_tokens
