"""OmniInfer core: the paper's three contributions.

  placement/ — OmniPlacement: load-aware MoE expert placement (Alg. 1 & 2)
  omniattn/  — OmniAttn: sink+recent KV compression + GA pattern search
  proxy/     — OmniProxy: disaggregation-aware global scheduling (OAS)
"""
