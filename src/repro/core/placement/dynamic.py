"""OmniPlacement — Dynamic Expert Scheduler (paper Algorithm 2).

Near-real-time closed loop:
  · UpdateActivationWindow: weighted-moving-average expert load from the
    activation counts emitted by the MoE layer (models/moe.py aux output);
  · trigger rebalancing when B_current > B_trigger;
  · PredictFutureActivations: linear trend extrapolation over the window;
  · re-run the static algorithm; accept only if simulated improvement > Δ;
  · plan a pipelined, non-blocking migration (migration.py) and atomically
    swap placement tables once weights have landed.

Pure-Python control plane: runs on the host beside the serving engine (the
paper runs it on a separate monitoring stream); all device work is the weight
gather in migration.apply (a separate jit program XLA overlaps with serving).
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field
from typing import Callable, Optional

import numpy as np

from repro.core.placement.static import calculate_imbalance, static_expert_placement
from repro.core.placement.migration import MigrationPlan, plan_migration


@dataclass
class SchedulerConfig:
    b_trigger: float = 1.3        # imbalance trigger threshold B_trigger
    delta: float = 0.05           # required improvement margin Δ
    window: int = 16              # activation sliding-window length
    ema_alpha: float = 0.3        # weighted moving average factor
    budget: int = 0               # extra slot rows across layers (M)
    max_slots: Optional[int] = None
    predict_horizon: float = 1.0  # trend extrapolation steps


@dataclass
class DynamicScheduler:
    ep: int
    n_experts: int
    n_layers: int
    cfg: SchedulerConfig = field(default_factory=SchedulerConfig)
    placements: Optional[list[np.ndarray]] = None

    def __post_init__(self):
        self._window: deque[np.ndarray] = deque(maxlen=self.cfg.window)
        self._ema: Optional[np.ndarray] = None
        self.n_rebalances = 0
        self.n_checks = 0
        self.history: list[dict] = []

    # ------------------------------------------------------------------
    def update_activation_window(self, counts: np.ndarray) -> np.ndarray:
        """counts [L, E] activation counts from the last interval."""
        counts = np.asarray(counts, dtype=np.float64)
        self._window.append(counts)
        if self._ema is None:
            self._ema = counts.copy()
        else:
            a = self.cfg.ema_alpha
            self._ema = a * counts + (1 - a) * self._ema
        return self._ema

    def predict_future_activations(self) -> np.ndarray:
        """Linear trend over the window, clipped at 0 (paper's
        PredictFutureActivations)."""
        if len(self._window) < 2:
            return self._ema.copy()
        recent = np.mean([self._window[i] for i in range(len(self._window) // 2,
                                                         len(self._window))], axis=0)
        older = np.mean([self._window[i] for i in range(len(self._window) // 2)],
                        axis=0)
        trend = (recent - older) / max(len(self._window) / 2, 1)
        return np.maximum(self._ema + self.cfg.predict_horizon *
                          trend * len(self._window) / 2, 0.0)

    def current_imbalance(self) -> float:
        if self._ema is None or self.placements is None:
            return 1.0
        return float(np.mean([calculate_imbalance(self.placements[l], self._ema[l])
                              for l in range(self.n_layers)]))

    # ------------------------------------------------------------------
    def step(self, counts: np.ndarray) -> Optional[list[MigrationPlan]]:
        """One monitoring tick. Returns migration plans if a rebalance was
        accepted, else None (paper Algorithm 2 lines 4-14)."""
        self.n_checks += 1
        self.update_activation_window(counts)
        if self.placements is None:
            return None
        b_current = self.current_imbalance()
        if b_current <= self.cfg.b_trigger:
            self.history.append({"b": b_current, "rebalanced": False})
            return None
        d_pred = self.predict_future_activations()
        cand, _ = static_expert_placement(
            d_pred, self.ep, self.cfg.budget, prev=self.placements,
            max_slots=self.cfg.max_slots)
        b_sim = float(np.mean([calculate_imbalance(cand[l], d_pred[l])
                               for l in range(self.n_layers)]))
        if b_sim < b_current - self.cfg.delta:
            plans = [plan_migration(self.placements[l], cand[l],
                                    self.cfg.max_slots or
                                    _slots_of(cand[l]))
                     for l in range(self.n_layers)]
            self.placements = cand
            self.n_rebalances += 1
            self.history.append({"b": b_current, "b_sim": b_sim, "rebalanced": True})
            return plans
        self.history.append({"b": b_current, "b_sim": b_sim, "rebalanced": False})
        return None


def _slots_of(placement: np.ndarray) -> int:
    return int(placement.sum(axis=1).max())
