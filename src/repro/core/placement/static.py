"""OmniPlacement — Static Expert Placement (paper Algorithm 1).

Placement tensor P ∈ {0,1}^{L×R×E} subject to
  availability: Σ_r P[l,r,e] ≥ 1             (eq. 1)
  capacity:     Σ_e P[l,r,e] ≤ s_l           (eq. 2)
minimizing the per-layer load-imbalance ratio
  B(l,P,D) = max_r load_r / mean_r load_r    (eq. 4)
given the expert-load matrix D ∈ R^{L×E} (eq. 3 aggregates loads per device).

Components (paper §4.1):
  AllocateBudgetByImbalance — distribute the global redundancy budget M across
    layers proportional to their observed imbalance;
  DetermineReplicas — heap-greedy replica counts for the hottest experts;
  GeneratePlacement — greedy least-loaded device assignment + topology-aware
    remapping (minimize inter-device moves w.r.t. a previous placement);
  CalculateImbalance — eq. 4.
"""
from __future__ import annotations

import heapq
from typing import Optional

import numpy as np


def calculate_imbalance(placement: np.ndarray, loads: np.ndarray) -> float:
    """placement [R, E] binary; loads [E]. Replicated experts split their load
    evenly across replicas (balanced replica selection — see models/moe.py)."""
    n_rep = np.maximum(placement.sum(axis=0), 1)          # [E]
    per_replica = loads / n_rep
    device_load = placement @ per_replica                 # [R]
    mean = device_load.mean()
    if mean <= 0:
        return 1.0
    return float(device_load.max() / mean)


def allocate_budget_by_imbalance(D: np.ndarray, n_slots_base: int, budget: int,
                                 ep: int) -> np.ndarray:
    """Distribute `budget` extra slot-rows (one per layer unit of s_l beyond
    ceil(E/R)) to layers ∝ their imbalance under the unreplicated layout."""
    L, E = D.shape
    base = np.full(L, n_slots_base, dtype=np.int64)
    if budget <= 0:
        return base
    imb = np.zeros(L)
    rr = round_robin(E, ep, n_slots_base)
    for l in range(L):
        imb[l] = calculate_imbalance(rr, D[l]) - 1.0
    imb = np.maximum(imb, 1e-6)
    share = imb / imb.sum()
    extra = np.floor(share * budget).astype(np.int64)
    # hand out remaining units to the most imbalanced layers
    rem = budget - int(extra.sum())
    order = np.argsort(-imb)
    for i in range(rem):
        extra[order[i % L]] += 1
    return base + extra


def round_robin(E: int, ep: int, n_slots: int) -> np.ndarray:
    p = np.zeros((ep, E), dtype=np.int8)
    for e in range(E):
        p[(e // n_slots) % ep, e] = 1
    return p


def determine_replicas(loads: np.ndarray, extra_slots: int, ep: int,
                       n_slots: int) -> np.ndarray:
    """Heap-greedy replica counts [E]: repeatedly replicate the expert whose
    per-replica load is currently highest, until the slot budget (ep*n_slots)
    is used. Every expert gets ≥ 1 replica."""
    E = loads.shape[0]
    total_slots = ep * n_slots
    counts = np.ones(E, dtype=np.int64)
    free = total_slots - E
    if free < 0:
        raise ValueError(f"{total_slots} slots < {E} experts")
    heap = [(-loads[e], e) for e in range(E)]
    heapq.heapify(heap)
    for _ in range(min(free, extra_slots)):
        _, e = heapq.heappop(heap)
        counts[e] += 1
        heapq.heappush(heap, (-loads[e] / (counts[e] + 1.0), e))
    return counts


def generate_placement(counts: np.ndarray, loads: np.ndarray, ep: int,
                       n_slots: int,
                       prev: Optional[np.ndarray] = None) -> np.ndarray:
    """Greedy least-loaded assignment of expert replicas to devices, then a
    topology-aware remap: permute device rows to maximize overlap with `prev`
    (minimizes weight migration traffic — the TPU analogue of the paper's
    inter-device communication remapping)."""
    E = counts.shape[0]
    per_rep = loads / np.maximum(counts, 1)
    # place replicas of heavy experts first
    order = np.argsort(-per_rep)
    device_load = np.zeros(ep)
    device_used = np.zeros(ep, dtype=np.int64)
    placement = np.zeros((ep, E), dtype=np.int8)
    for e in order:
        for _ in range(int(counts[e])):
            # least-loaded device that has a free slot and doesn't already
            # host this expert
            cand = [(device_load[r], r) for r in range(ep)
                    if device_used[r] < n_slots and placement[r, e] == 0]
            if not cand:      # all devices host it already or are full
                break
            _, r = min(cand)
            placement[r, e] = 1
            device_used[r] += 1
            device_load[r] += per_rep[e]
    if prev is not None:
        placement = _remap_to_prev(placement, prev)
    return placement


def _remap_to_prev(placement: np.ndarray, prev: np.ndarray) -> np.ndarray:
    """Greedy row permutation maximizing per-device overlap with prev."""
    ep = placement.shape[0]
    overlap = placement.astype(np.int32) @ prev.astype(np.int32).T   # [new_r, old_r]
    out = np.zeros_like(placement)
    used_new, used_old = set(), set()
    pairs = sorted(((overlap[i, j], i, j) for i in range(ep) for j in range(ep)),
                   reverse=True)
    assign = {}
    for _, i, j in pairs:
        if i in used_new or j in used_old:
            continue
        assign[j] = i
        used_new.add(i)
        used_old.add(j)
        if len(assign) == ep:
            break
    for old_r, new_i in assign.items():
        out[old_r] = placement[new_i]
    return out


def static_expert_placement(D: np.ndarray, ep: int, budget: int,
                            n_slots_base: Optional[int] = None,
                            prev: Optional[list[np.ndarray]] = None,
                            max_slots: Optional[int] = None):
    """Paper Algorithm 1. D [L, E] load matrix; budget M = total extra slot
    rows across layers. Returns (placements list of [R,E], n_slots [L])."""
    L, E = D.shape
    if n_slots_base is None:
        n_slots_base = int(np.ceil(E / ep))
    s = allocate_budget_by_imbalance(D, n_slots_base, budget, ep)
    if max_slots is not None:
        s = np.minimum(s, max_slots)
    placements = []
    for l in range(L):
        best, best_b = None, np.inf
        # iterate redundancy levels k = 0..(s_l - base): extra replica rows
        for k in range(int(s[l]) - n_slots_base + 1):
            n_slots_l = n_slots_base + k
            extra = n_slots_l * ep - E
            counts = determine_replicas(D[l], extra, ep, n_slots_l)
            cand = generate_placement(counts, D[l], ep, n_slots_l,
                                      prev[l] if prev is not None else None)
            b = calculate_imbalance(cand, D[l])
            if b < best_b:
                best, best_b = cand, b
        placements.append(best)
    return placements, s
