"""Pipelined expert-weight migration (paper §4.1 'Pipelined Expert Weight and
Placement Updates').

On Ascend the paper moves weights over a dedicated HCCL stream; the TPU/JAX
adaptation builds the new slot tensor with a separate jit'd gather program
(XLA async dispatch overlaps it with serving steps — the engine keeps decoding
on the old tables until `apply` returns), then atomically swaps the placement
tables. `bytes_moved` quantifies migration traffic for the simulator.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.models.moe import tables_from_placement


@dataclass(frozen=True)
class MigrationPlan:
    old_slot_expert: np.ndarray   # [R, s]
    new_slot_expert: np.ndarray   # [R, s]
    moves: tuple                  # ((rank, slot, expert), ...) slots that change
    bytes_moved_per_param: int    # number of expert-rows fetched

    @property
    def n_moves(self) -> int:
        return len(self.moves)


def plan_migration(old_placement: np.ndarray, new_placement: np.ndarray,
                   n_slots: int) -> MigrationPlan:
    old_t = tables_from_placement(old_placement, n_slots)
    new_t = tables_from_placement(new_placement, n_slots)
    old_se = np.asarray(old_t["slot_expert"])
    new_se = np.asarray(new_t["slot_expert"])
    moves = []
    for r in range(new_se.shape[0]):
        for s in range(new_se.shape[1]):
            if new_se[r, s] != old_se[r, s] and new_se[r, s] >= 0:
                moves.append((r, s, int(new_se[r, s])))
    return MigrationPlan(old_se, new_se, tuple(moves), len(moves))


def apply_migration(plan: MigrationPlan, canonical_weights: dict, slots: dict,
                    slots_from_canonical):
    """Rebuild slot weights for the new layout. canonical_weights: dict of
    [E, ...] arrays; slots: dict of [R, s, ...]. Returns (new_slots, tables).

    In production only the changed (rank, slot) rows move (plan.moves); here we
    regather the slot tensor — XLA turns this into a gather whose cost the
    simulator models from plan.n_moves.
    """
    new_tables = tables_from_placement_from_slots(plan.new_slot_expert)
    new_slots = {k: slots_from_canonical(v, plan.new_slot_expert)
                 for k, v in canonical_weights.items()}
    return new_slots, new_tables


def tables_from_placement_from_slots(slot_expert: np.ndarray) -> dict:
    """Rebuild replica lookup tables directly from a slot_expert map,
    preserving the given slot assignment. (Round-tripping through a binary
    placement would re-pack experts in ascending order and silently undo any
    slot permutation the weights were migrated to.)"""
    import jax.numpy as jnp
    slot_expert = np.asarray(slot_expert)
    R, s = slot_expert.shape
    E = int(slot_expert.max()) + 1
    reps: list[list[tuple[int, int]]] = [[] for _ in range(E)]
    for r in range(R):
        for i in range(s):
            e = int(slot_expert[r, i])
            if e >= 0:
                reps[e].append((r, i))
    max_rep = max(1, max(len(x) for x in reps))
    rep_rank = np.zeros((E, max_rep), dtype=np.int32)
    rep_slot = np.zeros((E, max_rep), dtype=np.int32)
    n_rep = np.zeros((E,), dtype=np.int32)
    for e, lst in enumerate(reps):
        if not lst:
            raise ValueError(f"expert {e} unplaced")
        n_rep[e] = len(lst)
        for i in range(max_rep):
            r, sl = lst[i % len(lst)]
            rep_rank[e, i] = r
            rep_slot[e, i] = sl
    return dict(rep_rank=jnp.asarray(rep_rank), rep_slot=jnp.asarray(rep_slot),
                n_rep=jnp.asarray(n_rep),
                slot_expert=jnp.asarray(slot_expert.astype(np.int32)))
