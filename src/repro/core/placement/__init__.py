from repro.core.placement.static import (
    allocate_budget_by_imbalance,
    calculate_imbalance,
    determine_replicas,
    generate_placement,
    static_expert_placement,
)
from repro.core.placement.dynamic import DynamicScheduler, SchedulerConfig
from repro.core.placement.migration import MigrationPlan, plan_migration

__all__ = [
    "allocate_budget_by_imbalance", "calculate_imbalance", "determine_replicas",
    "generate_placement", "static_expert_placement", "DynamicScheduler",
    "SchedulerConfig", "MigrationPlan", "plan_migration",
]
