"""OmniAttn pattern search (paper §4.2).

Layer-wise compression pattern p ∈ {0,1}^L discovered by a genetic algorithm
at inference-only cost:

    min_p latency(p)   s.t.   accuracy(p) ≥ τ          (paper eq. 7)

Fitness: patterns meeting the accuracy budget are ranked by compression gain
(KV bytes saved → latency proxy); infeasible patterns are ranked below every
feasible one by their accuracy shortfall. Selection = tournament, crossover =
uniform, mutation = per-gene flip. Early stop when a pattern exceeds τ at the
target compression.

`periodic` restricts the search space to period-`q` patterns (the scan-stack
compile-cost constraint for the big dry-run archs — see DESIGN.md); the
engine-scale search runs unrestricted.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Optional

import numpy as np

from repro.configs.base import ModelConfig


def kv_bytes_for_pattern(cfg: ModelConfig, pattern: np.ndarray, seq_len: int,
                         bytes_per_el: int = 2) -> int:
    """Total KV bytes per sequence under pattern p (1 = compressed)."""
    total = 0
    specs = cfg.layer_specs(list(int(x) for x in pattern))
    for s in specs:
        if s.kind != "attn":
            continue
        if s.compressed:
            W = cfg.omniattn.sink_tokens + cfg.omniattn.recent_tokens
        elif s.window > 0:
            W = min(s.window, seq_len)
        else:
            W = seq_len
        total += 2 * min(W, seq_len) * cfg.n_kv_heads * cfg.head_dim * bytes_per_el
    return total


@dataclass
class GAConfig:
    population: int = 24
    generations: int = 20
    tournament: int = 3
    crossover_rate: float = 0.8
    mutation_rate: float = 0.08
    accuracy_tau: float = 0.99    # relative to uncompressed accuracy
    seed: int = 0
    periodic: Optional[int] = None  # restrict to period-q patterns
    early_stop_patience: int = 5


@dataclass
class PatternSearch:
    cfg: ModelConfig
    evaluate: Callable[[np.ndarray], float]   # pattern → accuracy ∈ [0,1]
    ga: GAConfig
    seq_len: int = 4096

    def _expand(self, genes: np.ndarray) -> np.ndarray:
        """genes (period-q or full-length) → full per-layer pattern, zeroing
        non-candidate layers (mamba / local-window)."""
        L = self.cfg.n_layers
        if self.ga.periodic:
            pat = np.tile(genes, (L + len(genes) - 1) // len(genes))[:L]
        else:
            pat = genes.copy()
        specs = self.cfg.layer_specs()
        for i, s in enumerate(specs):
            if s.kind != "attn" or s.window > 0:
                pat[i] = 0
        return pat

    def _gene_len(self) -> int:
        return self.ga.periodic or self.cfg.n_layers

    def fitness(self, genes: np.ndarray, base_acc: float) -> tuple[float, dict]:
        pat = self._expand(genes)
        key = pat.tobytes()
        if not hasattr(self, "_cache"):
            self._cache = {}
        if key not in self._cache:                 # evaluations are expensive
            self._cache[key] = self.evaluate(pat)  # (one jit compile each)
        acc = self._cache[key]
        full = kv_bytes_for_pattern(self.cfg, np.zeros_like(pat), self.seq_len)
        kv = kv_bytes_for_pattern(self.cfg, pat, self.seq_len)
        gain = 1.0 - kv / max(full, 1)
        feasible = acc >= self.ga.accuracy_tau * base_acc
        score = gain if feasible else -1.0 + acc / max(base_acc, 1e-9)
        return score, {"acc": acc, "kv_gain": gain, "feasible": feasible,
                       "pattern": pat}

    # ------------------------------------------------------------------
    def run(self) -> dict:
        rng = np.random.default_rng(self.ga.seed)
        n = self._gene_len()
        base_acc = self.evaluate(self._expand(np.zeros(n, dtype=np.int64)))
        pop = (rng.random((self.ga.population, n)) < 0.5).astype(np.int64)
        pop[0] = 0                                  # keep the identity pattern
        best, best_info, best_score = None, None, -np.inf
        stale = 0
        log = []
        for gen in range(self.ga.generations):
            scored = []
            for ind in pop:
                s, info = self.fitness(ind, base_acc)
                scored.append((s, ind, info))
            scored.sort(key=lambda t: -t[0])
            if scored[0][0] > best_score + 1e-12:
                best_score, best, best_info = scored[0][0], scored[0][1].copy(), scored[0][2]
                stale = 0
            else:
                stale += 1
            log.append({"gen": gen, "best_score": float(best_score),
                        "best_acc": float(best_info["acc"]),
                        "kv_gain": float(best_info["kv_gain"])})
            if stale >= self.ga.early_stop_patience:
                break
            # --- evolve
            new_pop = [scored[0][1].copy()]         # elitism
            while len(new_pop) < self.ga.population:
                a = self._tournament(scored, rng)
                b = self._tournament(scored, rng)
                child = a.copy()
                if rng.random() < self.ga.crossover_rate:
                    m = rng.random(n) < 0.5
                    child = np.where(m, a, b)
                flip = rng.random(n) < self.ga.mutation_rate
                child = np.where(flip, 1 - child, child)
                new_pop.append(child.astype(np.int64))
            pop = np.stack(new_pop)
        return {"pattern": best_info["pattern"], "genes": best,
                "accuracy": best_info["acc"], "base_accuracy": base_acc,
                "kv_gain": best_info["kv_gain"], "feasible": best_info["feasible"],
                "log": log}

    def _tournament(self, scored, rng):
        idx = rng.integers(0, len(scored), size=self.ga.tournament)
        return max((scored[i] for i in idx), key=lambda t: t[0])[1]
