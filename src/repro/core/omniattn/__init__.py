from repro.core.omniattn.search import GAConfig, PatternSearch, kv_bytes_for_pattern
from repro.core.omniattn.fidelity import (attention_fidelity,
                                          block_subset_indices,
                                          sink_recent_indices)

__all__ = ["GAConfig", "PatternSearch", "kv_bytes_for_pattern",
           "attention_fidelity", "sink_recent_indices",
           "block_subset_indices"]
