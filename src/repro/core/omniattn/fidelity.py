"""Attention-output fidelity under KV sparsification (paper eq. 5-6).

Measures || softmax(QK_M^T/√d) V_M  −  softmax(QK^T/√d) V || for a token
subset M — the quantity OmniAttn's approximation bounds. M defaults to the
static sink ∪ recent pattern (eq. 6); an arbitrary `indices` subset scores
any sparsification, in particular the blocks picked by the ONLINE top-k
selection (`block_subset_indices` maps selected block ids to token
indices). Used by bench_accuracy.py (Table 3 proxy, incl. the
`attn_mass_kept` figure for top-k-selected blocks) and hypothesis tests.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def sink_recent_indices(M: int, n_sink: int, n_recent: int) -> np.ndarray:
    """Token index subset per eq. 6: first n_sink + last n_recent of M."""
    n_sink = min(n_sink, M)
    n_recent = min(n_recent, M - n_sink)
    return np.concatenate([np.arange(n_sink), np.arange(M - n_recent, M)])


def block_subset_indices(M: int, blocks, block_size: int) -> np.ndarray:
    """Token index subset covered by the given KV block ids (logical block
    j spans tokens [j·bs, (j+1)·bs) ∩ [0, M)) — the online top-k
    selection's M, in eq. 5-6 terms."""
    out = [np.arange(b * block_size, min((b + 1) * block_size, M))
           for b in sorted(int(b) for b in blocks)]
    return (np.concatenate(out) if out
            else np.zeros((0,), np.int64))


def attention_fidelity(q, k, v, n_sink: int = 0, n_recent: int = 0, *,
                       indices=None):
    """q [Nq, d]; k, v [M, d]. Scores the token subset `indices` (or the
    eq. 6 sink∪recent subset built from n_sink/n_recent when omitted).
    Returns dict with the relative L2 output error and the total attention
    mass the subset captures."""
    M, d = k.shape
    idx = (np.asarray(indices, np.int64) if indices is not None
           else sink_recent_indices(M, n_sink, n_recent))
    scale = d ** -0.5
    s_full = (q @ k.T) * scale
    p_full = jax.nn.softmax(s_full, axis=-1)
    out_full = p_full @ v
    s_sub = (q @ k[idx].T) * scale
    p_sub = jax.nn.softmax(s_sub, axis=-1)
    out_sub = p_sub @ v[idx]
    rel = jnp.linalg.norm(out_sub - out_full) / jnp.maximum(
        jnp.linalg.norm(out_full), 1e-9)
    mass = p_full[:, idx].sum(-1).mean()
    return {"rel_err": float(rel), "attn_mass": float(mass)}
