"""Attention-output fidelity under sink+recent compression (paper eq. 5-6).

Measures || softmax(QK_M^T/√d) V_M  −  softmax(QK^T/√d) V || for the token
subset M = sinks ∪ recents — the quantity OmniAttn's approximation bounds.
Used by bench_accuracy.py (Table 3 proxy) and hypothesis tests.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def sink_recent_indices(M: int, n_sink: int, n_recent: int) -> np.ndarray:
    """Token index subset per eq. 6: first n_sink + last n_recent of M."""
    n_sink = min(n_sink, M)
    n_recent = min(n_recent, M - n_sink)
    return np.concatenate([np.arange(n_sink), np.arange(M - n_recent, M)])


def attention_fidelity(q, k, v, n_sink: int, n_recent: int):
    """q [Nq, d]; k, v [M, d]. Returns dict with relative L2 error and the
    total attention mass captured by the selected subset."""
    M, d = k.shape
    idx = sink_recent_indices(M, n_sink, n_recent)
    scale = d ** -0.5
    s_full = (q @ k.T) * scale
    p_full = jax.nn.softmax(s_full, axis=-1)
    out_full = p_full @ v
    s_sub = (q @ k[idx].T) * scale
    p_sub = jax.nn.softmax(s_sub, axis=-1)
    out_sub = p_sub @ v[idx]
    rel = jnp.linalg.norm(out_sub - out_full) / jnp.maximum(
        jnp.linalg.norm(out_full), 1e-9)
    mass = p_full[:, idx].sum(-1).mean()
    return {"rel_err": float(rel), "attn_mass": float(mass)}
