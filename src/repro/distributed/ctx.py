"""Mesh context: axis naming, PartitionSpec helpers, sharding constraints.

Axis convention (see DESIGN.md):
  pod   — inter-pod data parallelism (multi-pod mesh only)
  data  — intra-pod data parallelism; ALSO the expert-parallel (EP) axis
  model — tensor parallelism (heads / d_ff / vocab / expert-FFN width)
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import cached_property
from typing import Any, Optional, Sequence

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


@dataclass(frozen=True)
class MeshCtx:
    mesh: Mesh

    # ------------------------------------------------------------------
    @cached_property
    def axis_names(self) -> tuple[str, ...]:
        return tuple(self.mesh.axis_names)

    @cached_property
    def has_pod(self) -> bool:
        return "pod" in self.axis_names

    @cached_property
    def batch_axes(self) -> tuple[str, ...]:
        return ("pod", "data") if self.has_pod else ("data",)

    def size(self, name: str) -> int:
        return self.mesh.shape[name] if name in self.axis_names else 1

    @cached_property
    def dp(self) -> int:
        return int(np.prod([self.size(a) for a in self.batch_axes]))

    @cached_property
    def ep(self) -> int:          # expert-parallel ranks (data axis)
        return self.size("data")

    @cached_property
    def tp(self) -> int:
        return self.size("model")

    @cached_property
    def n_devices(self) -> int:
        return int(np.prod(list(self.mesh.shape.values())))

    # ------------------------------------------------------------------
    def spec(self, *parts: Any) -> P:
        return P(*parts)

    def sharding(self, spec: P) -> NamedSharding:
        return NamedSharding(self.mesh, spec)

    def batch_part(self, dim_size: int):
        """Mesh axes to shard a batch-like dim over; None if not divisible."""
        axes = []
        rem = dim_size
        for a in self.batch_axes:
            if rem % self.size(a) == 0:
                axes.append(a)
                rem //= self.size(a)
        if not axes:
            return None
        return tuple(axes) if len(axes) > 1 else axes[0]

    def model_part(self, dim_size: int, allow_pad: bool = True):
        """'model' if the dim can shard over TP (padding allowed)."""
        if self.tp == 1:
            return None
        if dim_size % self.tp == 0 or (allow_pad and dim_size > 1):
            return "model"
        return None

    def part_if(self, name, dim_size: int):
        """Axis name(s) if dim_size divides evenly, else None (pjit inputs
        require exact divisibility, unlike internal sharding constraints)."""
        if name is None:
            return None
        names = (name,) if isinstance(name, str) else tuple(name)
        total = 1
        for n in names:
            total *= self.size(n)
        return name if total > 0 and dim_size % total == 0 else None

    def sanitize_spec(self, spec: P, shape: tuple) -> P:
        parts = list(spec) + [None] * (len(shape) - len(spec))
        return P(*(self.part_if(p, d) for p, d in zip(parts, shape)))

    def constrain(self, x, spec: P):
        if self.n_devices == 1:
            return x
        return jax.lax.with_sharding_constraint(x, self.sharding(spec))

    def tree_shardings(self, spec_tree):
        return jax.tree.map(self.sharding, spec_tree,
                            is_leaf=lambda s: isinstance(s, P))


def local_mesh_ctx(axes: Sequence[str] = ("data", "model")) -> MeshCtx:
    """1-device mesh for smoke tests / single-host runs."""
    shape = tuple(1 for _ in axes)
    return MeshCtx(jax.make_mesh(shape, tuple(axes)))
