from repro.distributed.ctx import MeshCtx, local_mesh_ctx

__all__ = ["MeshCtx", "local_mesh_ctx"]
