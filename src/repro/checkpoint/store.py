"""Fault-tolerant checkpointing: sharded chunk files + manifest, atomic
rename commit, zstd-compressed msgpack, elastic restore onto any mesh.

Layout of one checkpoint:
  <dir>/step_000123/
    manifest.json            # leaf index: path → (file, shape, dtype)  (last)
    chunk_00000.msgpack.zst  # {leaf_key: raw bytes}, ≤ chunk_mb each

Crash safety: everything is written into `step_X.tmp/` and committed with a
single atomic rename to `step_X/`; a crash mid-write leaves only a .tmp
directory which restore ignores and cleanup removes. On a real multi-host pod
each host writes its own chunk files (addressable shards) and host 0 commits
the manifest — the same protocol, parameterized by process_index.

Elastic restore: leaves are stored unsharded (host gathers); `restore` places
them onto the *current* mesh with the *current* specs via jax.device_put, so
a job can restart on a different mesh shape (elastic scaling).
"""
from __future__ import annotations

import json
import os
import shutil
from dataclasses import dataclass
from pathlib import Path
from typing import Any, Optional

import jax
import jax.numpy as jnp
import msgpack
import numpy as np

try:
    import zstandard as zstd
except ImportError:          # container without zstandard: zlib fallback with
    import zlib              # the same 2-method surface (format not portable
                             # across the two codecs; checkpoints are local)

    class _ZlibCompressor:
        def __init__(self, level=3):
            self._level = level

        def compress(self, data):
            return zlib.compress(data, self._level)

    class _ZlibDecompressor:
        def decompress(self, data, max_output_size=0):
            return zlib.decompress(data)

    class zstd:  # type: ignore[no-redef]
        ZstdCompressor = _ZlibCompressor
        ZstdDecompressor = _ZlibDecompressor


def _flatten(tree) -> dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        flat[key] = leaf
    return flat


def save_checkpoint(directory, step: int, tree, *, chunk_mb: int = 256,
                    process_index: int = 0, extra: Optional[dict] = None):
    """Atomic sharded save. Returns the committed path."""
    directory = Path(directory)
    final = directory / f"step_{step:08d}"
    tmp = directory / f"step_{step:08d}.tmp"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)

    flat = _flatten(tree)
    manifest = {"step": step, "leaves": {}, "extra": extra or {}}
    comp = zstd.ZstdCompressor(level=3)
    chunk, chunk_bytes, chunk_id = {}, 0, 0

    def flush():
        nonlocal chunk, chunk_bytes, chunk_id
        if not chunk:
            return
        fn = f"chunk_p{process_index}_{chunk_id:05d}.msgpack.zst"
        with open(tmp / fn, "wb") as f:
            f.write(comp.compress(msgpack.packb(chunk, use_bin_type=True)))
        chunk, chunk_bytes = {}, 0
        chunk_id += 1

    for key, leaf in flat.items():
        arr = np.asarray(leaf)
        fn = f"chunk_p{process_index}_{chunk_id:05d}.msgpack.zst"
        manifest["leaves"][key] = {"file": fn, "shape": list(arr.shape),
                                   "dtype": str(arr.dtype)}
        chunk[key] = arr.tobytes()
        chunk_bytes += arr.nbytes
        if chunk_bytes >= chunk_mb << 20:
            flush()
    flush()

    with open(tmp / "manifest.json", "w") as f:
        json.dump(manifest, f)
    if final.exists():
        shutil.rmtree(final)
    os.rename(tmp, final)         # atomic commit
    return final


def load_checkpoint(directory, step: Optional[int] = None, *,
                    template=None, shardings=None):
    """Restore (tree, step, extra). With `template` (pytree) the stored flat
    leaves are unflattened into its structure; `shardings` (same structure)
    places each leaf onto the current mesh (elastic restore)."""
    directory = Path(directory)
    if step is None:
        steps = sorted(int(p.name.split("_")[1]) for p in directory.iterdir()
                       if p.is_dir() and p.name.startswith("step_")
                       and not p.name.endswith(".tmp"))
        if not steps:
            raise FileNotFoundError(f"no checkpoints in {directory}")
        step = steps[-1]
    ckpt = directory / f"step_{step:08d}"
    manifest = json.loads((ckpt / "manifest.json").read_text())
    decomp = zstd.ZstdDecompressor()
    cache: dict[str, dict] = {}

    def read_leaf(key):
        info = manifest["leaves"][key]
        if info["file"] not in cache:
            raw = (ckpt / info["file"]).read_bytes()
            cache[info["file"]] = msgpack.unpackb(decomp.decompress(raw),
                                                  raw=False)
        buf = cache[info["file"]][key]
        return np.frombuffer(buf, dtype=info["dtype"]).reshape(info["shape"])

    if template is None:
        flat = {k: read_leaf(k) for k in manifest["leaves"]}
        return flat, step, manifest["extra"]

    flat_t = _flatten(template)
    missing = set(flat_t) - set(manifest["leaves"])
    if missing:
        raise KeyError(f"checkpoint missing leaves: {sorted(missing)[:5]} ...")
    leaves_by_key = {k: read_leaf(k) for k in flat_t}
    shard_flat = _flatten(shardings) if shardings is not None else {}
    out_leaves = []
    paths, treedef = jax.tree_util.tree_flatten_with_path(template)
    for path, tmpl in paths:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        arr = leaves_by_key[key]
        want = jnp.dtype(tmpl.dtype) if hasattr(tmpl, "dtype") else None
        val = arr.astype(want) if want is not None and arr.dtype != want else arr
        if key in shard_flat and shard_flat[key] is not None:
            val = jax.device_put(val, shard_flat[key])
        else:
            val = jnp.asarray(val)
        out_leaves.append(val)
    return jax.tree_util.tree_unflatten(treedef, out_leaves), step, manifest["extra"]


@dataclass
class CheckpointManager:
    """Keep-last-N rotation + resume + crash-garbage cleanup."""
    directory: Path
    keep: int = 3

    def __post_init__(self):
        self.directory = Path(self.directory)
        self.directory.mkdir(parents=True, exist_ok=True)
        for p in self.directory.glob("*.tmp"):    # crashed writes
            shutil.rmtree(p, ignore_errors=True)

    def save(self, step: int, tree, extra: Optional[dict] = None):
        path = save_checkpoint(self.directory, step, tree, extra=extra)
        ckpts = sorted(p for p in self.directory.iterdir()
                       if p.is_dir() and not p.name.endswith(".tmp"))
        for old in ckpts[:-self.keep]:
            shutil.rmtree(old, ignore_errors=True)
        return path

    def latest_step(self) -> Optional[int]:
        steps = [int(p.name.split("_")[1]) for p in self.directory.iterdir()
                 if p.is_dir() and p.name.startswith("step_")
                 and not p.name.endswith(".tmp")]
        return max(steps) if steps else None

    def restore(self, template=None, shardings=None):
        return load_checkpoint(self.directory, template=template,
                               shardings=shardings)
