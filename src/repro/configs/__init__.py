"""Architecture registry: ``get_config(arch_id)`` + reduced smoke variants."""
from __future__ import annotations

from dataclasses import replace

from repro.configs.base import SHAPES, LayerSpec, ModelConfig, MoEConfig, ShapeConfig, SSMConfig

_MODULES = {
    "qwen2-1.5b": "qwen2_1_5b",
    "qwen3-32b": "qwen3_32b",
    "gemma3-4b": "gemma3_4b",
    "granite-34b": "granite_34b",
    "jamba-1.5-large-398b": "jamba_1_5_large",
    "qwen2-moe-a2.7b": "qwen2_moe_a2_7b",
    "qwen3-moe-235b-a22b": "qwen3_moe_235b",
    "mamba2-130m": "mamba2_130m",
    "phi-3-vision-4.2b": "phi3_vision",
    "hubert-xlarge": "hubert_xlarge",
}

ARCH_IDS = list(_MODULES)


def get_config(arch_id: str) -> ModelConfig:
    if arch_id not in _MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; known: {ARCH_IDS}")
    import importlib

    mod = importlib.import_module(f"repro.configs.{_MODULES[arch_id]}")
    return mod.CONFIG


def reduced_config(arch_id: str) -> ModelConfig:
    """Tiny same-family variant for CPU smoke tests: few layers (but ≥ one
    full period of the layer pattern), narrow width, small vocab."""
    cfg = get_config(arch_id)
    period = max(cfg.attn_period, cfg.local_per_global + 1, cfg.moe.moe_every, 1)
    n_layers = max(2 * period, 2)
    kw = dict(
        n_layers=n_layers,
        d_model=128,
        d_ff=256 if cfg.d_ff else 0,
        vocab_size=512,
        attn_q_chunk=64,
        attn_kv_chunk=64,
        moe_token_chunk=256,
        fsdp=False,
        remat=False,
        grad_accum=1,
        optimizer_dtype="float32",
    )
    if cfg.n_heads:
        kw.update(n_heads=4, n_kv_heads=min(cfg.n_kv_heads, 2) or 1, head_dim=32)
    if cfg.moe.n_experts:
        kw["moe"] = replace(cfg.moe, n_experts=8, top_k=min(cfg.moe.top_k, 2),
                            d_ff_expert=64,
                            n_shared_experts=min(cfg.moe.n_shared_experts, 1))
    if cfg.family in ("ssm", "hybrid"):
        kw["ssm"] = replace(cfg.ssm, d_state=16, head_dim=16, chunk=32)
    if cfg.frontend_dim:
        kw["frontend_dim"] = 64
    if cfg.num_patches:
        kw["num_patches"] = 8
    if cfg.local_per_global:
        kw["local_window"] = 32
    kw["omniattn"] = replace(cfg.omniattn, sink_tokens=4, recent_tokens=16)
    return replace(cfg, **kw)


__all__ = [
    "ARCH_IDS", "SHAPES", "LayerSpec", "ModelConfig", "MoEConfig", "SSMConfig",
    "ShapeConfig", "get_config", "reduced_config",
]
