"""granite-34b — dense llama-arch code model, MQA (kv=1). [arXiv:2405.04324; hf]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="granite-34b",
    family="dense",
    n_layers=88,
    d_model=6144,
    n_heads=48,
    n_kv_heads=1,
    head_dim=128,
    d_ff=24576,
    vocab_size=49152,
    rope_theta=1e5,
    fsdp=True,
    grad_accum=8,
)
