"""qwen3-moe-235b-a22b — 128 routed experts top-8, qk_norm.
[hf:Qwen/Qwen3-30B-A3B family; hf]"""
from repro.configs.base import MoEConfig, ModelConfig

CONFIG = ModelConfig(
    arch_id="qwen3-moe-235b-a22b",
    family="moe",
    n_layers=94,
    d_model=4096,
    n_heads=64,
    n_kv_heads=4,
    head_dim=128,
    d_ff=1536,
    vocab_size=151936,
    qk_norm=True,
    rope_theta=1e6,
    moe=MoEConfig(n_experts=128, top_k=8, d_ff_expert=1536, moe_every=1,
                  norm_topk_prob=True, redundant_slots=1),
    fsdp=True,
    grad_accum=8,
)
