"""phi-3-vision-4.2b — phi3-mini backbone + CLIP frontend (stub).
[hf:microsoft/Phi-3-vision-128k-instruct; hf]

The modality frontend is a STUB: input_specs() provides precomputed patch
embeddings [B, num_patches, frontend_dim] that are linearly projected and
prepended to the token embeddings (prefix-LM style).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="phi-3-vision-4.2b",
    family="vlm",
    n_layers=32,
    d_model=3072,
    n_heads=32,
    n_kv_heads=32,
    head_dim=96,
    d_ff=8192,
    vocab_size=32064,
    rope_theta=1e4,
    frontend_dim=1024,    # CLIP-L/14 hidden size
    num_patches=256,
)
