"""Model/run configuration dataclasses shared by every architecture.

A config fully describes one architecture from the assigned pool. The layer
stack is expressed as a *periodic* sequence of ``LayerSpec``s (period length ×
repeat count + remainder) so that the model code can ``lax.scan`` over repeats
while unrolling only one period — compile time stays O(period), not O(L).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field, replace
from typing import Optional


@dataclass(frozen=True)
class LayerSpec:
    """Static description of one layer position inside the period."""

    kind: str = "attn"          # 'attn' | 'mamba'
    window: int = 0             # attention window; 0 = full (causal or bidir)
    use_moe: bool = False       # MoE FFN instead of dense FFN
    compressed: bool = False    # OmniAttn layer-wise sink+recent compression


@dataclass(frozen=True)
class OmniAttnConfig:
    """Sink+recent KV compression (OmniAttn)."""

    sink_tokens: int = 128
    recent_tokens: int = 4096
    # default layer pattern period: compress `compress_per_period` of every
    # `pattern_period` layers. The GA search (core/omniattn) can override.
    pattern_period: int = 4
    compress_per_period: int = 3

    # --- online (dynamic) sparsity: query-aware top-k KV-block selection
    # for paged decode over full-attention layers. Per-block key summaries
    # (per-kv-head mean + min/max channel bounds) live next to the block
    # arenas; each decode step scores resident blocks with a Quest-style
    # upper bound and attends only a per-slot budget of them (sink + most
    # recent blocks always kept). Budget: `topk_blocks` absolute, or
    # `topk_frac` of each slot's RESIDENT block count (ceil); both 0 → off.
    # Selection degrades to exact attention when the budget covers every
    # resident block. `topk_measure_mass` additionally computes the exact
    # attention mass captured by the selected blocks (a full-score pass —
    # diagnostics/benchmarks only, not the production hot path).
    topk_blocks: int = 0
    topk_frac: float = 0.0
    topk_sink_blocks: int = 1
    topk_recent_blocks: int = 2
    topk_measure_mass: bool = False


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int = 0
    top_k: int = 0
    n_shared_experts: int = 0
    d_ff_expert: int = 0
    moe_every: int = 1            # MoE FFN on every k-th layer
    norm_topk_prob: bool = True
    capacity_factor: float = 2.0
    # OmniPlacement redundancy: extra slots per EP rank beyond ceil(E/R).
    redundant_slots: int = 1


@dataclass(frozen=True)
class SSMConfig:
    d_state: int = 128
    head_dim: int = 64
    expand: int = 2
    conv_width: int = 4
    chunk: int = 256              # SSD chunk length


@dataclass(frozen=True)
class ModelConfig:
    arch_id: str = "unnamed"
    family: str = "dense"         # dense|moe|hybrid|ssm|vlm|audio
    n_layers: int = 2
    d_model: int = 256
    n_heads: int = 4
    n_kv_heads: int = 4
    head_dim: int = 64
    d_ff: int = 1024
    vocab_size: int = 32000

    # attention details
    qkv_bias: bool = False
    qk_norm: bool = False
    rope_theta: float = 1e6
    causal: bool = True           # False → encoder-only (hubert)

    # local:global window pattern (gemma3): `local_per_global` local layers
    # (sliding window `local_window`) followed by one global layer.
    local_per_global: int = 0
    local_window: int = 1024

    moe: MoEConfig = field(default_factory=MoEConfig)
    ssm: SSMConfig = field(default_factory=SSMConfig)
    omniattn: OmniAttnConfig = field(default_factory=OmniAttnConfig)

    # hybrid (jamba): one attention layer per `attn_period` layers, at offset
    # `attn_offset`; remaining layers are mamba.
    attn_period: int = 0
    attn_offset: int = 4

    # modality frontend stubs
    frontend_dim: int = 0         # >0 → inputs include precomputed embeddings
    num_patches: int = 0          # vlm: patch embeddings prepended to tokens
    encoder_only: bool = False

    rms_eps: float = 1e-6
    tie_embeddings: bool = False

    # numerics
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"
    optimizer_dtype: str = "float32"   # AdamW m/v dtype (bf16 for ≥300B archs)

    # execution knobs (perf-tunable; see EXPERIMENTS.md §Perf)
    attn_q_chunk: int = 2048
    attn_kv_chunk: int = 1024
    attn_skip_masked_chunks: bool = False  # statically skip fully-masked
                                           # causal KV blocks (halves flops)
    attn_fp32_scores: bool = True          # False → bf16 score/prob traffic
    attn_qseq_out_constraint: bool = False # pin q-seq sharding on attn output
    prefill_sparse: bool = False           # OmniAttn sink+window prefill math
    moe_token_chunk: int = 8192   # chunked MoE dispatch to bound a2a buffers
    moe_dispatch_int8: bool = False        # quantize dispatch/combine a2a
    remat: bool = True            # activation checkpointing in train_step
    remat_policy: str = "nothing"          # nothing | dots
    grad_accum: int = 1           # microbatch accumulation steps in train_step
    fsdp: bool = False            # shard params/opt-state over data axis too
    use_pallas: bool = False      # Pallas kernels (TPU target; interpret on CPU)

    # ------------------------------------------------------------------
    def layer_specs(self, pattern: Optional[list[int]] = None) -> list[LayerSpec]:
        """Full per-layer spec list. ``pattern[l]=1`` → OmniAttn-compressed."""
        specs = []
        for l in range(self.n_layers):
            kind = "attn"
            if self.attn_period > 0:
                kind = "attn" if (l % self.attn_period) == self.attn_offset else "mamba"
            elif self.family == "ssm":
                kind = "mamba"
            window = 0
            if kind == "attn" and self.local_per_global > 0:
                # 5 local : 1 global → positions 0..4 local, 5 global (mod 6)
                period = self.local_per_global + 1
                if (l % period) != self.local_per_global:
                    window = self.local_window
            use_moe = (
                self.moe.n_experts > 0 and (l % self.moe.moe_every) == (self.moe.moe_every - 1)
            )
            compressed = bool(pattern[l]) if pattern is not None else False
            if kind != "attn":
                compressed = False
            specs.append(LayerSpec(kind=kind, window=window, use_moe=use_moe,
                                   compressed=compressed))
        return specs

    def default_compression_pattern(self) -> list[int]:
        """Paper-faithful periodic default: compress `compress_per_period` of
        every `pattern_period` attention layers (GA can refine)."""
        oa = self.omniattn
        pat = []
        specs = self.layer_specs()
        ai = 0
        for s in specs:
            # only full-context attention layers are candidates: local-window
            # layers already have bounded caches, mamba layers have none.
            if s.kind != "attn" or s.window > 0:
                pat.append(0)
                continue
            pat.append(1 if (ai % oa.pattern_period) < oa.compress_per_period else 0)
            ai += 1
        return pat

    # ------------------------------------------------------------------
    def periodize(self, specs: list[LayerSpec]) -> tuple[list[LayerSpec], int, list[LayerSpec]]:
        """Find (period_specs, n_repeats, remainder_specs) with the smallest
        period so the stack scans over repeats and unrolls one period."""
        L = len(specs)
        for p in range(1, L + 1):
            period = specs[:p]
            n_rep = L // p
            if all(specs[i] == period[i % p] for i in range(n_rep * p)):
                rem = specs[n_rep * p:]
                # only worthwhile if we actually repeat; degenerate case p=L
                if n_rep >= 1:
                    return period, n_rep, rem
        return specs, 1, []

    def n_params(self) -> int:
        """Parameter count (for 6ND model FLOPs and memory budgeting)."""
        D, hd = self.d_model, self.head_dim
        n = self.vocab_size * D * (1 if self.tie_embeddings else 2)
        for s in self.layer_specs():
            if s.kind == "attn":
                n += D * hd * (self.n_heads + 2 * self.n_kv_heads)  # wq wk wv
                n += self.n_heads * hd * D                          # wo
                if self.qkv_bias:
                    n += hd * (self.n_heads + 2 * self.n_kv_heads)
            else:
                ssm = self.ssm
                d_in = ssm.expand * D
                n_h = d_in // ssm.head_dim
                n += D * (2 * d_in + 2 * ssm.d_state + n_h)  # in_proj(z,x) B C dt
                n += d_in * ssm.conv_width + n_h * 2          # conv, A, D
                n += d_in * D                                  # out_proj
            if s.use_moe:
                m = self.moe
                n += D * m.n_experts                           # router
                n += m.n_experts * 3 * D * m.d_ff_expert
                n += m.n_shared_experts * 3 * D * m.d_ff_expert
            else:
                n += 3 * D * self.d_ff
            n += 2 * D                                         # norms
        n += D                                                 # final norm
        return n

    def n_active_params(self) -> int:
        """Active params per token (MoE top-k) for 6·N_active·D model FLOPs."""
        if self.moe.n_experts == 0:
            return self.n_params()
        m = self.moe
        total = self.n_params()
        specs = self.layer_specs()
        n_moe_layers = sum(1 for s in specs if s.use_moe)
        all_expert = n_moe_layers * m.n_experts * 3 * self.d_model * m.d_ff_expert
        active_expert = n_moe_layers * m.top_k * 3 * self.d_model * m.d_ff_expert
        return total - all_expert + active_expert

    def with_updates(self, **kw) -> "ModelConfig":
        nested = {}
        for key in ("moe", "ssm", "omniattn"):
            sub = {k[len(key) + 1:]: kw.pop(k) for k in list(kw)
                   if k.startswith(key + "_") and k[len(key) + 1:] in
                   {f.name for f in dataclasses.fields(getattr(self, key).__class__)}}
            if sub:
                nested[key] = replace(getattr(self, key), **sub)
        return replace(self, **kw, **nested)


# ----------------------------------------------------------------------
# Input shape sets (assigned): every LM arch gets all four; encoder-only
# archs skip decode shapes (handled in launch/dryrun.py).
@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # 'train' | 'prefill' | 'decode'


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}
