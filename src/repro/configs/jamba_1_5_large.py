"""jamba-1.5-large-398b — hybrid Mamba+attention (1:7), MoE 16e top-2.
[arXiv:2403.19887; hf]"""
from repro.configs.base import MoEConfig, ModelConfig, OmniAttnConfig, SSMConfig

CONFIG = ModelConfig(
    arch_id="jamba-1.5-large-398b",
    family="hybrid",
    n_layers=72,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=24576,
    vocab_size=65536,
    rope_theta=1e6,
    attn_period=8,       # one attention layer per 8 (1:7 attn:mamba)
    attn_offset=4,
    moe=MoEConfig(n_experts=16, top_k=2, d_ff_expert=24576, moe_every=2,
                  capacity_factor=2.0, redundant_slots=1),
    ssm=SSMConfig(d_state=128, head_dim=64, expand=2),
    # compress every attention layer (1/8 of the stack): keeps the 8-layer
    # hybrid pattern periodic; SSM layers carry long-range state anyway.
    omniattn=OmniAttnConfig(pattern_period=1, compress_per_period=1),
    fsdp=True,
    grad_accum=8,
    optimizer_dtype="bfloat16",   # 398B: fp32 m/v would not fit v5e-256
)
