"""qwen3-32b — dense, GQA kv=8, qk_norm. [hf:Qwen/Qwen3-8B family; hf]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="qwen3-32b",
    family="dense",
    n_layers=64,
    d_model=5120,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=25600,
    vocab_size=151936,
    qk_norm=True,
    rope_theta=1e6,
    fsdp=True,
    grad_accum=8,
)
