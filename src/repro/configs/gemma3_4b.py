"""gemma3-4b — dense, GQA kv=4, 5:1 local:global, 128k ctx.
[hf:google/gemma-3-1b-pt scaled; unverified]"""
from repro.configs.base import ModelConfig, OmniAttnConfig

CONFIG = ModelConfig(
    arch_id="gemma3-4b",
    family="dense",
    n_layers=34,
    d_model=2560,
    n_heads=8,
    n_kv_heads=4,
    head_dim=256,
    d_ff=10240,
    vocab_size=262144,
    qk_norm=True,
    rope_theta=1e6,
    local_per_global=5,
    local_window=1024,
    tie_embeddings=True,
    grad_accum=4,
    # compress every global layer (keeps the 6-layer pattern periodic; the GA
    # search can retain full globals at small scale — see DESIGN.md)
    omniattn=OmniAttnConfig(pattern_period=1, compress_per_period=1),
)
