"""mamba2-130m — attention-free SSD (state-space duality). [arXiv:2405.21060]"""
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    arch_id="mamba2-130m",
    family="ssm",
    n_layers=24,
    d_model=768,
    n_heads=0,
    n_kv_heads=0,
    head_dim=0,
    d_ff=0,               # mamba block subsumes the FFN (no separate MLP)
    vocab_size=50280,
    ssm=SSMConfig(d_state=128, head_dim=64, expand=2, conv_width=4, chunk=256),
    tie_embeddings=True,
)
