"""qwen2-moe-a2.7b — 60 routed experts top-4 + 4 shared (fine-grained).
[hf:Qwen/Qwen1.5-MoE-A2.7B; hf]"""
from repro.configs.base import MoEConfig, ModelConfig

CONFIG = ModelConfig(
    arch_id="qwen2-moe-a2.7b",
    family="moe",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    head_dim=128,
    d_ff=1408,
    vocab_size=151936,
    qkv_bias=True,
    rope_theta=1e6,
    grad_accum=2,
    moe=MoEConfig(n_experts=60, top_k=4, n_shared_experts=4, d_ff_expert=1408,
                  moe_every=1, norm_topk_prob=False, redundant_slots=0),
    # 60 experts on a 16-way EP axis → ceil(60/16)=4 slots/rank, 4 redundant
    # slots absorbed by OmniPlacement replicas of the hottest experts.
)
