"""hubert-xlarge — encoder-only audio transformer (w2v2 arch). [arXiv:2106.07447]

Encoder-only: no decode step (decode_32k / long_500k shapes are skipped — see
DESIGN.md). The conv feature extractor is a STUB: input_specs() provides
precomputed frame embeddings [B, S, frontend_dim]. Training objective is
masked-frame classification over the 504-unit codebook.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="hubert-xlarge",
    family="audio",
    n_layers=48,
    d_model=1280,
    n_heads=16,
    n_kv_heads=16,
    head_dim=80,
    d_ff=5120,
    vocab_size=504,
    causal=False,
    encoder_only=True,
    frontend_dim=512,
    fsdp=True,
)
