"""Expert-parallel MoE with OmniPlacement slot redundancy.

Layout (see DESIGN.md):
  · experts live in per-rank *slots* on the `data` mesh axis (EP), with each
    expert's FFN width TP-sharded over `model`;
  · slot weights  w1/w3 [R, s, D, Fe]  w2 [R, s, Fe, D]  sharded
    P('data', None, None, 'model') / P('data', None, 'model', None);
  · a *placement* maps experts → (rank, slot) replicas. Redundant slots host
    replicas of hot experts (OmniPlacement); replica choice is a deterministic
    round-robin over (token, choice), which balances replicas in expectation
    without any extra communication;
  · dispatch: bucket tokens per (rank, slot), all_to_all over `data`, grouped
    batched matmul over local slots (exact grouped FLOPs — no one-hot blowup),
    all_to_all back, weighted scatter-add combine, psum over `model`.

Token dispatch is chunked (cfg.moe_token_chunk) to bound the a2a buffers at
long sequence lengths.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.distributed.ctx import MeshCtx


def _shard_map(f, *, mesh, in_specs, out_specs):
    """Version-portable shard_map: jax>=0.6 exposes jax.shard_map
    (check_vma); older releases ship jax.experimental.shard_map (check_rep)."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False)
    from jax.experimental.shard_map import shard_map
    return shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                     check_rep=False)


# ----------------------------------------------------------------------
# Placement tables (pytree of arrays — swapped atomically at migration time).
def tables_from_placement(placement: np.ndarray, n_slots: int) -> dict:
    """placement: binary [R, E] (this layer) — build replica lookup tables.

    Slot assignment: each rank hosts its experts in ascending expert order.
    Returns dict of int32 arrays:
      rep_rank [E, max_rep], rep_slot [E, max_rep], n_rep [E],
      slot_expert [R, s] (-1 = empty slot).
    """
    R, E = placement.shape
    slot_expert = -np.ones((R, n_slots), dtype=np.int32)
    reps: list[list[tuple[int, int]]] = [[] for _ in range(E)]
    for r in range(R):
        hosted = np.nonzero(placement[r])[0]
        if len(hosted) > n_slots:
            raise ValueError(f"rank {r} hosts {len(hosted)} experts > {n_slots} slots")
        for i, e in enumerate(hosted):
            slot_expert[r, i] = e
            reps[int(e)].append((r, i))
    max_rep = max(1, max(len(x) for x in reps))
    rep_rank = np.zeros((E, max_rep), dtype=np.int32)
    rep_slot = np.zeros((E, max_rep), dtype=np.int32)
    n_rep = np.zeros((E,), dtype=np.int32)
    for e, lst in enumerate(reps):
        if not lst:
            raise ValueError(f"expert {e} unplaced")
        n_rep[e] = len(lst)
        for i in range(max_rep):
            r, sl = lst[i % len(lst)]
            rep_rank[e, i] = r
            rep_slot[e, i] = sl
    return dict(rep_rank=jnp.asarray(rep_rank), rep_slot=jnp.asarray(rep_slot),
                n_rep=jnp.asarray(n_rep), slot_expert=jnp.asarray(slot_expert))


def round_robin_placement(n_experts: int, ep: int, n_slots: int) -> np.ndarray:
    """Trivial (training / baseline) placement: expert e → rank e // s."""
    placement = np.zeros((ep, n_experts), dtype=np.int8)
    for e in range(n_experts):
        placement[(e // n_slots) % ep, e] = 1
    return placement


def default_slot_count(cfg: ModelConfig, ep: int) -> int:
    base = math.ceil(cfg.moe.n_experts / ep)
    return base + cfg.moe.redundant_slots


def table_specs() -> dict:
    return dict(rep_rank=P(None, None), rep_slot=P(None, None),
                n_rep=P(None), slot_expert=P(None, None))


# ----------------------------------------------------------------------
def router(cfg: ModelConfig, x, router_w):
    """x [T, D] → (gates [T,k] f32, experts [T,k] i32, probs [T,E] f32)."""
    logits = (x.astype(jnp.float32) @ router_w.astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    vals, idx = jax.lax.top_k(probs, cfg.moe.top_k)
    if cfg.moe.norm_topk_prob:
        vals = vals / jnp.maximum(vals.sum(-1, keepdims=True), 1e-9)
    return vals, idx, probs


def _bucket_capacity(tc: int, k: int, ep: int, s: int, cf: float) -> int:
    c = math.ceil(tc * k * cf / (ep * s))
    return max(8, ((c + 7) // 8) * 8)


# ----------------------------------------------------------------------
def moe_ffn(mesh: MeshCtx, cfg: ModelConfig, x, router_w, w1, w3, w2,
            tables: dict, shared: Optional[tuple] = None, batch_part="data",
            token_mask=None):
    """x [T, D] (T sharded over batch axes, replicated over model).

    Returns (y [T, D], expert_counts [E] f32) — counts feed OmniPlacement's
    activation window. token_mask [T] (optional) weights the counts so
    invalid rows (inactive decode slots, padded prefill tail) don't pollute
    the activation signal; the outputs of masked rows are unaffected
    (callers already ignore them).
    """
    ep, s = w1.shape[0], w1.shape[1]
    k = cfg.moe.top_k
    E = cfg.moe.n_experts
    T, D = x.shape

    in_specs = (
        P(batch_part, None),                      # x
        P(None, None),                            # router_w
        P("data", None, None, "model"),           # w1
        P("data", None, None, "model"),           # w3
        P("data", None, "model", None),           # w2
        {k2: v for k2, v in table_specs().items()},
    )
    shared_specs = ()
    if shared is not None:
        shared_specs = ((P(None, "model"), P(None, "model"), P("model", None)),)
        in_specs = in_specs + shared_specs
    if token_mask is not None:
        in_specs = in_specs + (P(batch_part),)
    out_specs = (P(batch_part, None), P(None))

    T_loc = T // mesh.dp if batch_part is not None else T
    tc = min(cfg.moe_token_chunk, T_loc)
    while T_loc % tc:
        tc //= 2
    n_chunks = T_loc // tc
    Cb = _bucket_capacity(tc, k, ep, s, cfg.moe.capacity_factor)
    a = tc * k

    def body(x_loc, rw, w1_l, w3_l, w2_l, tbl, *extra):
        extra = list(extra)
        mask_l = extra.pop() if token_mask is not None else None
        shared_l = tuple(extra)
        w1_l, w3_l, w2_l = w1_l[0], w3_l[0], w2_l[0]   # [s, D, Fe_loc] ...
        gates, eidx, _ = router(cfg, x_loc, rw)        # [T_loc,k]
        cw = (jnp.repeat(mask_l.astype(jnp.float32), k)
              if mask_l is not None else 1.0)
        counts = jnp.zeros((E,), jnp.float32).at[eidx.reshape(-1)].add(cw)

        # replica choice: deterministic round-robin over (token, choice)
        tok_pos = jnp.arange(T_loc)[:, None] * k + jnp.arange(k)[None, :]
        rr = tok_pos % jnp.maximum(tbl["n_rep"][eidx], 1)
        drank = tbl["rep_rank"][eidx, rr]              # [T_loc,k]
        dslot = tbl["rep_slot"][eidx, rr]

        def a2a(x):
            if mesh.ep == 1:
                return x
            if not cfg.moe_dispatch_int8:
                return jax.lax.all_to_all(x, "data", 0, 0, tiled=True)
            # int8-quantized transport (per-row max-abs scales) — halves the
            # EP all-to-all bytes; dequantized on arrival. §Perf A6.
            scale = jnp.max(jnp.abs(x), axis=-1, keepdims=True) / 127.0
            scale = jnp.maximum(scale, 1e-9)
            q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
            q = jax.lax.all_to_all(q, "data", 0, 0, tiled=True)
            scale = jax.lax.all_to_all(scale.astype(jnp.float32), "data", 0,
                                       0, tiled=True)
            return (q.astype(x.dtype) * scale.astype(x.dtype))

        def chunk_step(_, inp):
            xk, gk, drk, dsk = inp                     # [tc,D],[tc,k],[tc,k],[tc,k]
            key = (drk * s + dsk).reshape(a)           # [a]
            gate_f = gk.reshape(a)
            src = jnp.repeat(jnp.arange(tc), k)
            onehot = (key[:, None] == jnp.arange(ep * s)[None, :]).astype(jnp.int32)
            pos = (jnp.cumsum(onehot, axis=0) * onehot).sum(-1) - 1   # [a]
            valid = pos < Cb
            dr, ds = key // s, key % s
            send = jnp.zeros((ep, s, Cb, D), xk.dtype)
            send = send.at[dr, ds, pos].set(xk[src], mode="drop")
            recv = a2a(send)
            xe = recv.transpose(1, 0, 2, 3).reshape(s, ep * Cb, D)
            h = jax.nn.silu(jnp.einsum("sed,sdf->sef", xe, w1_l))
            h = h * jnp.einsum("sed,sdf->sef", xe, w3_l)
            oe = jnp.einsum("sef,sfd->sed", h, w2_l)
            back = oe.reshape(s, ep, Cb, D).transpose(1, 0, 2, 3)
            ret = a2a(back)
            res = ret.at[dr, ds, pos].get(mode="fill", fill_value=0.0)  # [a,D]
            wgt = (gate_f * valid).astype(res.dtype)[:, None]
            yk = jnp.zeros((tc, D), res.dtype).at[src].add(res * wgt)
            return 0, yk

        xs = (x_loc.reshape(n_chunks, tc, D), gates.reshape(n_chunks, tc, k),
              drank.reshape(n_chunks, tc, k), dslot.reshape(n_chunks, tc, k))
        _, y = jax.lax.scan(chunk_step, 0, xs)
        y = y.reshape(T_loc, D)

        if shared_l:
            sw1, sw3, sw2 = shared_l[0]
            y = y + (jax.nn.silu(x_loc @ sw1) * (x_loc @ sw3)) @ sw2

        if mesh.tp > 1:
            y = jax.lax.psum(y, "model")
        # sum counts over the axes tokens are actually sharded on
        bp = batch_part if batch_part is not None else ()
        bp = (bp,) if isinstance(bp, str) else tuple(bp)
        axes = tuple(ax for ax in bp if mesh.size(ax) > 1)
        if axes:
            counts = jax.lax.psum(counts, axes)
        return y, counts

    args = (x, router_w, w1, w3, w2, tables) + \
        ((shared,) if shared is not None else ()) + \
        ((token_mask,) if token_mask is not None else ())
    return _shard_map(body, mesh=mesh.mesh, in_specs=in_specs,
                      out_specs=out_specs)(*args)


# ----------------------------------------------------------------------
# Dense oracle (tests / reference): canonical expert weights [E, D, Fe].
def moe_ffn_dense(cfg: ModelConfig, x, router_w, ew1, ew3, ew2, shared=None):
    gates, eidx, _ = router(cfg, x, router_w)
    E = cfg.moe.n_experts
    gmat = jnp.zeros((x.shape[0], E), jnp.float32)
    gmat = gmat.at[jnp.arange(x.shape[0])[:, None], eidx].add(gates)
    y = jnp.zeros_like(x, dtype=jnp.float32)
    for e in range(E):
        h = jax.nn.silu(x @ ew1[e]) * (x @ ew3[e])
        y = y + gmat[:, e:e + 1] * (h @ ew2[e]).astype(jnp.float32)
    if shared is not None:
        sw1, sw3, sw2 = shared
        y = y + ((jax.nn.silu(x @ sw1) * (x @ sw3)) @ sw2).astype(jnp.float32)
    return y.astype(x.dtype)


def slots_from_canonical(canonical, slot_expert):
    """canonical [E, ...] + slot_expert [R, s] → slot weights [R, s, ...]."""
    se = jnp.asarray(slot_expert)
    w = canonical[jnp.clip(se, 0, canonical.shape[0] - 1)]
    mask = (se >= 0).astype(w.dtype)
    return w * mask.reshape(se.shape + (1,) * (w.ndim - 2))
