"""Mamba-2 SSD (state-space duality) block — chunked prefill + O(1) decode.

Follows the SSD chunked algorithm of Dao & Gu (arXiv:2405.21060): intra-chunk
quadratic ("attention-like") term + inter-chunk linear recurrence carried by a
scan over chunks. Pure jnp einsums (TPU MXU-friendly); the Pallas variant of
the intra-chunk matmul lives in kernels/ (optional).

Shapes: x [B, S, Hm, Pm], dt [B, S, Hm], B/C mats [B, S, N] (single group).
State [B, Hm, Pm, N].
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def segsum(a):
    """log-decay lower-triangular matrix: out[..., i, j] = sum_{k=j+1..i} a[...,k]
    for i >= j, -inf otherwise. a: [..., Q]."""
    Q = a.shape[-1]
    cs = jnp.cumsum(a, axis=-1)
    d = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((Q, Q), dtype=bool))
    return jnp.where(mask, d, -jnp.inf)


def ssd_chunked(x, dt, A, Bm, Cm, chunk: int, initial_state=None):
    """Returns (y [B,S,Hm,Pm], final_state [B,Hm,Pm,N])."""
    Bsz, S, Hm, Pm = x.shape
    N = Bm.shape[-1]
    Q = min(chunk, S)
    while S % Q:
        Q //= 2
    C = S // Q

    f32 = jnp.float32
    xq = x.reshape(Bsz, C, Q, Hm, Pm).astype(f32)
    dtq = dt.reshape(Bsz, C, Q, Hm).astype(f32)
    Bq = Bm.reshape(Bsz, C, Q, N).astype(f32)
    Cq = Cm.reshape(Bsz, C, Q, N).astype(f32)

    dA = dtq * A.astype(f32)[None, None, None, :]          # [B,C,Q,Hm]
    dA_cs = jnp.cumsum(dA, axis=2)                          # inclusive cumsum

    # ---- intra-chunk (quadratic) term
    L = jnp.exp(segsum(jnp.moveaxis(dA, 2, 3)))             # [B,C,Hm,Q,Q]
    # scores[b,c,h,l,s] = C_l·B_s * L * dt_s
    G = jnp.einsum("bcln,bcsn->bcls", Cq, Bq)               # [B,C,Q,Q]
    M = G[:, :, None] * L * jnp.moveaxis(dtq, 2, 3)[:, :, :, None, :]
    y_diag = jnp.einsum("bchls,bcshp->bclhp", M, xq)

    # ---- chunk states: S_c = sum_s exp(dA_end - dA_cs_s) * dt_s * B_s ⊗ x_s
    decay_states = jnp.exp(dA_cs[:, :, -1:, :] - dA_cs)     # [B,C,Q,Hm]
    states = jnp.einsum("bcsn,bcsh,bcshp->bchpn", Bq, decay_states * dtq, xq)

    # ---- inter-chunk recurrence over C chunks
    chunk_decay = jnp.exp(dA_cs[:, :, -1, :])               # [B,C,Hm]
    if initial_state is None:
        s0 = jnp.zeros((Bsz, Hm, Pm, N), dtype=f32)
    else:
        s0 = initial_state.astype(f32)

    def step(s_prev, inp):
        st, dec = inp                                        # [B,Hm,Pm,N], [B,Hm]
        s_new = s_prev * dec[:, :, None, None] + st
        return s_new, s_prev

    final, s_prevs = jax.lax.scan(
        step, s0, (jnp.moveaxis(states, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)))
    s_prevs = jnp.moveaxis(s_prevs, 0, 1)                    # [B,C,Hm,Pm,N]

    # ---- inter-chunk output: y_off = C_l · (exp(dA_cs_l) * S_prev)
    state_decay = jnp.exp(dA_cs)                             # [B,C,Q,Hm]
    y_off = jnp.einsum("bcln,bchpn,bclh->bclhp", Cq, s_prevs, state_decay)

    y = (y_diag + y_off).reshape(Bsz, S, Hm, Pm)
    return y.astype(x.dtype), final


def ssd_decode_step(state, x, dt, A, Bm, Cm):
    """One-token state update. x [B,Hm,Pm], dt [B,Hm], Bm/Cm [B,N].
    Returns (y [B,Hm,Pm], new_state [B,Hm,Pm,N])."""
    f32 = jnp.float32
    x32, dt32 = x.astype(f32), dt.astype(f32)
    dA = jnp.exp(dt32 * A.astype(f32)[None, :])              # [B,Hm]
    upd = jnp.einsum("bn,bh,bhp->bhpn", Bm.astype(f32), dt32, x32)
    new_state = state.astype(f32) * dA[:, :, None, None] + upd
    y = jnp.einsum("bn,bhpn->bhp", Cm.astype(f32), new_state)
    return y.astype(x.dtype), new_state


def causal_conv(x, w, cache=None):
    """Causal depthwise conv, width cw. x [B,S,Cd], w [cw,Cd].
    cache [B, cw-1, Cd] of previous inputs (decode) or None (prefill).
    Returns (y [B,S,Cd], new_cache [B, cw-1, Cd])."""
    cw = w.shape[0]
    if cache is None:
        xp = jnp.pad(x, ((0, 0), (cw - 1, 0), (0, 0)))
    else:
        xp = jnp.concatenate([cache.astype(x.dtype), x], axis=1)
    y = sum(xp[:, i:i + x.shape[1]] * w[i][None, None, :] for i in range(cw))
    new_cache = xp[:, xp.shape[1] - (cw - 1):]
    return y, new_cache
