"""Shared model primitives: norms, rope, init schema, losses."""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


# ----------------------------------------------------------------------
# Parameter schema: shape + sharding spec + init scale, so init_params and
# param_specs are generated from one source of truth.
@dataclass(frozen=True)
class ParamDef:
    shape: tuple[int, ...]
    spec: P
    scale: float = 0.02           # normal std; 0.0 → zeros; 1.0 & ndim==1 → ones
    dtype: str = "bfloat16"
    ones: bool = False


def init_params(defs, rng):
    flat, treedef = jax.tree.flatten(defs, is_leaf=lambda d: isinstance(d, ParamDef))
    keys = jax.random.split(rng, len(flat))
    vals = []
    for d, k in zip(flat, keys):
        if d.ones:
            v = jnp.ones(d.shape, dtype=d.dtype)
        elif d.scale == 0.0:
            v = jnp.zeros(d.shape, dtype=d.dtype)
        else:
            v = (jax.random.normal(k, d.shape, dtype=jnp.float32) * d.scale).astype(d.dtype)
        vals.append(v)
    return jax.tree.unflatten(treedef, vals)


def param_specs(defs):
    return jax.tree.map(lambda d: d.spec, defs,
                        is_leaf=lambda d: isinstance(d, ParamDef))


def param_shapes(defs):
    return jax.tree.map(lambda d: jax.ShapeDtypeStruct(d.shape, jnp.dtype(d.dtype)),
                        defs, is_leaf=lambda d: isinstance(d, ParamDef))


def stack_defs(defs, n: int):
    """Add a leading scan-repeat axis of size n to every ParamDef."""
    return jax.tree.map(
        lambda d: ParamDef((n,) + d.shape, P(*((None,) + tuple(d.spec))), d.scale,
                           d.dtype, d.ones),
        defs, is_leaf=lambda d: isinstance(d, ParamDef))


# ----------------------------------------------------------------------
def rms_norm(x, scale, eps: float = 1e-6):
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    out = x32 * jax.lax.rsqrt(var + eps)
    return (out * scale.astype(jnp.float32)).astype(x.dtype)


def swiglu(x, w1, w3, w2):
    h = jax.nn.silu(x @ w1) * (x @ w3)
    return h @ w2


# ----------------------------------------------------------------------
def rope_freqs(head_dim: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x, positions, theta: float):
    """x: [B, S, ..., head_dim]. positions: scalar, [S], or [B, S] absolute."""
    h = x.shape[-1]
    freqs = rope_freqs(h, theta)                     # [h/2]
    pos = jnp.asarray(positions)
    if pos.ndim == 0:
        pos = pos[None]                              # [1] — one seq position
    ang = pos.astype(jnp.float32)[..., None] * freqs  # [S, h/2] or [B, S, h/2]
    if ang.ndim == 2:
        ang = ang[None]                              # [1, S, h/2]
    for _ in range(x.ndim - 3):                      # insert head dims
        ang = jnp.expand_dims(ang, axis=2)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ----------------------------------------------------------------------
def cross_entropy(logits, labels, mask=None):
    """Mean token cross-entropy; logits may be vocab-sharded (XLA reduces)."""
    logits = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = lse - ll
    if mask is None:
        return jnp.mean(nll)
    mask = mask.astype(jnp.float32)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
