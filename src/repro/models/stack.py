"""Layer-stack engine: periodized scan over heterogeneous layers.

The per-layer spec sequence (attention/mamba × windowed × MoE × compressed) is
decomposed into (period, n_repeats, remainder) — see ModelConfig.periodize —
so compile time is O(period + remainder) while the stack scans over repeats.

Params pytree:
  {'period': (pos0_params, pos1_params, ...),   # leaves stacked [n_rep, ...]
   'rem':    (params, ...)}                      # unstacked
Caches mirror the same structure plus a scalar position.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import LayerSpec, ModelConfig
from repro.distributed.ctx import MeshCtx
from repro.models import attention as attn_mod
from repro.models import moe as moe_mod
from repro.models import ssd as ssd_mod
from repro.models.common import ParamDef, rms_norm, stack_defs


# ======================================================================
@dataclass(frozen=True)
class StackPlan:
    period: tuple[LayerSpec, ...]
    n_rep: int
    rem: tuple[LayerSpec, ...]

    @staticmethod
    def from_config(cfg: ModelConfig, pattern: Optional[list[int]] = None) -> "StackPlan":
        if pattern is None:
            pattern = cfg.default_compression_pattern()
        specs = cfg.layer_specs(pattern)
        period, n_rep, rem = cfg.periodize(specs)
        return StackPlan(tuple(period), n_rep, tuple(rem))

    @property
    def n_layers(self) -> int:
        return len(self.period) * self.n_rep + len(self.rem)

    def all_specs(self) -> list[LayerSpec]:
        return list(self.period) * self.n_rep + list(self.rem)


def cache_window(cfg: ModelConfig, spec: LayerSpec) -> tuple[int, int]:
    """(sink, recent) for this layer's KV cache; (0,0) → full cache."""
    if spec.kind != "attn":
        return (0, 0)
    if spec.compressed:
        return (cfg.omniattn.sink_tokens, cfg.omniattn.recent_tokens)
    if spec.window > 0:
        return (0, spec.window)
    return (0, 0)


# ======================================================================
# Parameter schemas
def _fs(cfg):      # FSDP axis for the "replicated big" param dim
    return "data" if cfg.fsdp else None


def attn_defs(cfg: ModelConfig) -> dict:
    D, H, K, h = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    fs, dt = _fs(cfg), cfg.param_dtype
    d = {
        "ln_attn": ParamDef((D,), P(None), dtype=dt, ones=True),
        "wq": ParamDef((D, H * h), P(fs, "model"), dtype=dt),
        "wk": ParamDef((D, K * h), P(fs, "model"), dtype=dt),
        "wv": ParamDef((D, K * h), P(fs, "model"), dtype=dt),
        "wo": ParamDef((H * h, D), P("model", fs), dtype=dt),
    }
    if cfg.qkv_bias:
        d["bq"] = ParamDef((H * h,), P("model"), 0.0, dtype=dt)
        d["bk"] = ParamDef((K * h,), P("model"), 0.0, dtype=dt)
        d["bv"] = ParamDef((K * h,), P("model"), 0.0, dtype=dt)
    if cfg.qk_norm:
        d["q_norm"] = ParamDef((h,), P(None), dtype=dt, ones=True)
        d["k_norm"] = ParamDef((h,), P(None), dtype=dt, ones=True)
    return d


def mamba_defs(cfg: ModelConfig) -> dict:
    D, ssm = cfg.d_model, cfg.ssm
    d_in = ssm.expand * D
    nh = d_in // ssm.head_dim
    N, cw = ssm.d_state, ssm.conv_width
    fs, dt = _fs(cfg), cfg.param_dtype
    return {
        "ln_attn": ParamDef((D,), P(None), dtype=dt, ones=True),
        "w_z": ParamDef((D, d_in), P(fs, "model"), dtype=dt),
        "w_x": ParamDef((D, d_in), P(fs, "model"), dtype=dt),
        "w_bc": ParamDef((D, 2 * N), P(fs, None), dtype=dt),
        "w_dt": ParamDef((D, nh), P(fs, "model"), dtype=dt),
        "dt_bias": ParamDef((nh,), P("model"), 0.0, dtype=dt),
        "conv_x": ParamDef((cw, d_in), P(None, "model"), dtype=dt),
        "conv_bc": ParamDef((cw, 2 * N), P(None, None), dtype=dt),
        "A_log": ParamDef((nh,), P("model"), dtype=dt, ones=True),
        "D_skip": ParamDef((nh,), P("model"), dtype=dt, ones=True),
        "ssm_norm": ParamDef((d_in,), P("model"), dtype=dt, ones=True),
        "out_proj": ParamDef((d_in, D), P("model", fs), dtype=dt),
    }


def ffn_defs(cfg: ModelConfig) -> dict:
    D, F = cfg.d_model, cfg.d_ff
    fs, dt = _fs(cfg), cfg.param_dtype
    return {
        "ln_mlp": ParamDef((D,), P(None), dtype=dt, ones=True),
        "w1": ParamDef((D, F), P(fs, "model"), dtype=dt),
        "w3": ParamDef((D, F), P(fs, "model"), dtype=dt),
        "w2": ParamDef((F, D), P("model", fs), dtype=dt),
    }


def moe_defs(cfg: ModelConfig, mesh: MeshCtx) -> dict:
    D, m = cfg.d_model, cfg.moe
    ep = mesh.ep
    s = moe_mod.default_slot_count(cfg, ep)
    dt = cfg.param_dtype
    d = {
        "ln_mlp": ParamDef((D,), P(None), dtype=dt, ones=True),
        "router": ParamDef((D, m.n_experts), P(None, None), dtype="float32"),
        "moe_w1": ParamDef((ep, s, D, m.d_ff_expert), P("data", None, None, "model"), dtype=dt),
        "moe_w3": ParamDef((ep, s, D, m.d_ff_expert), P("data", None, None, "model"), dtype=dt),
        "moe_w2": ParamDef((ep, s, m.d_ff_expert, D), P("data", None, "model", None), dtype=dt),
    }
    if m.n_shared_experts:
        Fsh = m.n_shared_experts * m.d_ff_expert
        d["shared_w1"] = ParamDef((D, Fsh), P(_fs(cfg), "model"), dtype=dt)
        d["shared_w3"] = ParamDef((D, Fsh), P(_fs(cfg), "model"), dtype=dt)
        d["shared_w2"] = ParamDef((Fsh, D), P("model", _fs(cfg)), dtype=dt)
    return d


def layer_defs(cfg: ModelConfig, mesh: MeshCtx, spec: LayerSpec) -> dict:
    d = attn_defs(cfg) if spec.kind == "attn" else mamba_defs(cfg)
    if spec.use_moe:
        d.update(moe_defs(cfg, mesh))
    elif cfg.d_ff > 0:
        d.update(ffn_defs(cfg))
    return d


def stack_param_defs(cfg: ModelConfig, mesh: MeshCtx, plan: StackPlan) -> dict:
    period = tuple(stack_defs(layer_defs(cfg, mesh, s), plan.n_rep) for s in plan.period)
    rem = tuple(layer_defs(cfg, mesh, s) for s in plan.rem)
    return {"period": period, "rem": rem}


# ======================================================================
# Cache schemas (ShapeDtypeStruct + PartitionSpec builders for the dry-run
# and for real allocation in the serving engine).
def layer_cache_shape(cfg: ModelConfig, mesh: MeshCtx, spec: LayerSpec, B: int,
                      max_len: int) -> dict:
    """Returns {name: (shape, spec)} for one layer's decode cache."""
    bp = mesh.batch_part(B)
    if spec.kind == "attn":
        sink, recent = cache_window(cfg, spec)
        W = (sink + recent) if (sink or recent) else max_len
        K, h = cfg.n_kv_heads, cfg.head_dim
        strat = attn_mod.decode_strategy(K, mesh.tp)
        w_part = mesh.part_if("model", W) if strat == "wseq" else None
        kv_part = "model" if strat == "kv" else None
        sp = P(bp, w_part, kv_part, None)
        return {"k": ((B, W, K, h), sp), "v": ((B, W, K, h), sp)}
    ssm = cfg.ssm
    d_in = ssm.expand * cfg.d_model
    nh = d_in // ssm.head_dim
    return {
        "state": ((B, nh, ssm.head_dim, ssm.d_state),
                  P(bp, mesh.part_if("model", nh), None, None)),
        "conv_x": ((B, ssm.conv_width - 1, d_in),
                   P(bp, None, mesh.part_if("model", d_in))),
        "conv_bc": ((B, ssm.conv_width - 1, 2 * ssm.d_state), P(bp, None, None)),
    }


def cache_struct(cfg: ModelConfig, mesh: MeshCtx, plan: StackPlan, B: int,
                 max_len: int, dtype=None):
    """(ShapeDtypeStruct pytree, PartitionSpec pytree) for the full cache."""
    dtype = dtype or jnp.dtype(cfg.compute_dtype)
    def one(spec: LayerSpec, stacked: bool):
        shapes = layer_cache_shape(cfg, mesh, spec, B, max_len)
        sds, sps = {}, {}
        for name, (shp, sp) in shapes.items():
            dt = jnp.float32 if name == "state" else dtype
            if stacked:
                shp = (plan.n_rep,) + shp
                sp = P(*((None,) + tuple(sp)))
            sds[name] = jax.ShapeDtypeStruct(shp, dt)
            sps[name] = sp
        return sds, sps
    period = [one(s, True) for s in plan.period]
    rem = [one(s, False) for s in plan.rem]
    sds = {"period": tuple(p[0] for p in period), "rem": tuple(r[0] for r in rem),
           "pos": jax.ShapeDtypeStruct((), jnp.int32)}
    sps = {"period": tuple(p[1] for p in period), "rem": tuple(r[1] for r in rem),
           "pos": P()}
    return sds, sps


def _alloc_placed(mesh, sds, sps):
    """zeros for every ShapeDtypeStruct leaf, laid out on the mesh per its
    PartitionSpec (single-device meshes skip the device_put)."""
    zeros = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), sds)
    if mesh.n_devices == 1:
        return zeros
    return jax.device_put(zeros, mesh.tree_shardings(sps))


def alloc_cache(cfg, mesh, plan, B, max_len, dtype=None):
    sds, sps = cache_struct(cfg, mesh, plan, B, max_len, dtype)
    return _alloc_placed(mesh, sds, sps)


# ----------------------------------------------------------------------
# Physically paged decode caches: attention KV lives in a global per-layer
# block arena [N, K, block_size, h] indexed through per-slot block tables.
def ring_block_count(sink: int, recent: int, block_size: int) -> int:
    """Blocks backing one slot's sink+recent ring (ceil, last may be partial)."""
    return -(-(sink + recent) // block_size)


def layer_cache_shape_paged(cfg: ModelConfig, mesh: MeshCtx, spec: LayerSpec,
                            n_slots: int, max_len: int, n_arena_blocks: int,
                            block_size: int) -> dict:
    """{name: (shape, spec)} for one layer's paged decode cache.

    Full-attention layers share the pool-managed arena (`n_arena_blocks`
    includes the reserved null block 0); ring layers (windowed / sink+recent
    compressed) have fixed per-slot capacity, so each slot statically owns a
    contiguous run of ring blocks. Non-attention layers keep their per-slot
    dense state (it does not grow with context).
    """
    if spec.kind != "attn":
        return layer_cache_shape(cfg, mesh, spec, n_slots, max_len)
    sink, recent = cache_window(cfg, spec)
    K, h = cfg.n_kv_heads, cfg.head_dim
    if sink or recent:
        N = n_slots * ring_block_count(sink, recent, block_size)
    else:
        N = n_arena_blocks
    sp = P(None, attn_mod.arena_kv_part(K, mesh.tp), None, None)
    return {"k": ((N, K, block_size, h), sp),
            "v": ((N, K, block_size, h), sp)}


def paged_cache_struct(cfg: ModelConfig, mesh: MeshCtx, plan: StackPlan,
                       n_slots: int, max_len: int, n_arena_blocks: int,
                       block_size: int, dtype=None):
    """(ShapeDtypeStruct pytree, PartitionSpec pytree) for the paged cache."""
    dtype = dtype or jnp.dtype(cfg.compute_dtype)
    def one(spec: LayerSpec, stacked: bool):
        shapes = layer_cache_shape_paged(cfg, mesh, spec, n_slots, max_len,
                                         n_arena_blocks, block_size)
        sds, sps = {}, {}
        for name, (shp, sp) in shapes.items():
            dt = jnp.float32 if name == "state" else dtype
            if stacked:
                shp = (plan.n_rep,) + shp
                sp = P(*((None,) + tuple(sp)))
            sds[name] = jax.ShapeDtypeStruct(shp, dt)
            sps[name] = sp
        return sds, sps
    period = [one(s, True) for s in plan.period]
    rem = [one(s, False) for s in plan.rem]
    sds = {"period": tuple(p[0] for p in period), "rem": tuple(r[0] for r in rem),
           "pos": jax.ShapeDtypeStruct((), jnp.int32)}
    sps = {"period": tuple(p[1] for p in period), "rem": tuple(r[1] for r in rem),
           "pos": P()}
    return sds, sps


def alloc_paged_cache(cfg, mesh, plan, n_slots, max_len, n_arena_blocks,
                      block_size, dtype=None):
    sds, sps = paged_cache_struct(cfg, mesh, plan, n_slots, max_len,
                                  n_arena_blocks, block_size, dtype)
    return _alloc_placed(mesh, sds, sps)


# ----------------------------------------------------------------------
# Arena/private cache split: with paged *prefill*, the pool-managed
# full-attention block arenas are SHARED between the prefill and decode
# engines (zero-copy admission is a block-table transfer), while everything
# bounded — ring KV, mamba state, per-slot scalars — stays engine-private.
# A cache pytree handed to a jit is composed as (private ∪ arena) and split
# back after the (donated) call; positions with no entry hold None.
def full_attn_layer(cfg: ModelConfig, spec: LayerSpec) -> bool:
    """True for attention layers whose KV grows with context (full cache:
    no ring) — exactly the layers whose KV lives in pool-backed arenas."""
    return spec.kind == "attn" and cache_window(cfg, spec) == (0, 0)


def _drop_entries(cfg, plan, tree, drop_full: bool):
    """None out period/rem entries on one side of the full-attn split."""
    per = tuple(None if full_attn_layer(cfg, s) == drop_full else
                tree["period"][i] for i, s in enumerate(plan.period))
    rem = tuple(None if full_attn_layer(cfg, s) == drop_full else
                tree["rem"][i] for i, s in enumerate(plan.rem))
    out = {"period": per, "rem": rem}
    if "pos" in tree:
        out["pos"] = tree["pos"]
    return out


def alloc_arena_kv(cfg, mesh, plan, n_arena_blocks, block_size, dtype=None,
                   quant: bool = False):
    """Allocate only the shared full-attention arenas:
    {"period": (entry|None, ...), "rem": (...)} with entry
    {"k","v": [n_rep?, n_arena_blocks, K, bs, h],
     "kmin","kmax","kmean": [n_rep?, n_arena_blocks, K, h] float32}
    (`n_arena_blocks` includes the reserved null block 0). The summary
    leaves are the per-block key metadata plane for online top-k block
    selection, maintained by the same donated jits that write KV — every
    arena K write recomputes the touched blocks' summaries, so a quiescent
    arena never holds a stale summary (KVArena.check_summaries asserts
    exactly that). kmin/kmax feed the Quest-style upper-bound score
    (kernels/block_topk.py); kmean is the block-center estimate (the
    mean-score ablation in bench_accuracy and diagnostics — not on the
    decode scoring path).

    With `quant` (QuantPlane, serving/quant.py) the k/v payloads are int8
    and each entry carries the scale plane: per-block PER-CHANNEL seal
    scales {"kscale","vscale": [n_rep?, N, K, h] f32} (nonzero row ⟺ block
    sealed) plus per-token scalar scales {"ktok","vtok": [n_rep?, N, K, bs]
    f32} for unsealed tail content — maintained by the same donated jits
    that write KV, so zero-stale-scale rides the summary invariant."""
    dtype = dtype or jnp.dtype(cfg.compute_dtype)
    if quant:
        dtype = jnp.int8
    K, h = cfg.n_kv_heads, cfg.head_dim
    kv_part = attn_mod.arena_kv_part(K, mesh.tp)

    def one(spec, stacked):
        if not full_attn_layer(cfg, spec):
            return None, None
        shp = (n_arena_blocks, K, block_size, h)
        sshp = (n_arena_blocks, K, h)
        tshp = (n_arena_blocks, K, block_size)
        lead = ()
        if stacked:
            shp = (plan.n_rep,) + shp
            sshp = (plan.n_rep,) + sshp
            tshp = (plan.n_rep,) + tshp
            lead = (None,)
        kv_sp = P(*lead, None, kv_part, None, None)
        sm_sp = P(*lead, None, kv_part, None)
        entry = {"k": jnp.zeros(shp, dtype), "v": jnp.zeros(shp, dtype),
                 "kmin": jnp.zeros(sshp, jnp.float32),
                 "kmax": jnp.zeros(sshp, jnp.float32),
                 "kmean": jnp.zeros(sshp, jnp.float32)}
        sps = {"k": kv_sp, "v": kv_sp,
               "kmin": sm_sp, "kmax": sm_sp, "kmean": sm_sp}
        if quant:
            entry.update(kscale=jnp.zeros(sshp, jnp.float32),
                         vscale=jnp.zeros(sshp, jnp.float32),
                         ktok=jnp.zeros(tshp, jnp.float32),
                         vtok=jnp.zeros(tshp, jnp.float32))
            sps.update(kscale=sm_sp, vscale=sm_sp,
                       ktok=sm_sp, vtok=sm_sp)
        return entry, sps

    period = [one(s, True) for s in plan.period]
    rem = [one(s, False) for s in plan.rem]
    arena = {"period": tuple(p[0] for p in period),
             "rem": tuple(r[0] for r in rem)}
    sps = {"period": tuple(p[1] for p in period),
           "rem": tuple(r[1] for r in rem)}
    if mesh.n_devices == 1:
        return arena
    return jax.device_put(arena, mesh.tree_shardings(sps))


def topk_block_budget(oa, nb: int) -> Optional[int]:
    """Static (per-trace) top-k block budget against a width-`nb` block
    table, or None when online sparsity is off (both budget knobs 0).
    Absolute `topk_blocks` wins over `topk_frac` (which resolves per slot
    in-trace against the RESIDENT block count — this static figure is its
    ceiling, ceil(frac·nb)). Floored at the forced keeps and capped at nb;
    a budget == nb means the (bucketed) table already fits the budget and
    the caller skips selection entirely — exact attention."""
    if oa.topk_blocks <= 0 and oa.topk_frac <= 0:
        return None
    k = oa.topk_blocks if oa.topk_blocks > 0 else \
        int(math.ceil(oa.topk_frac * nb))
    k = max(k, max(oa.topk_sink_blocks, 0) + max(oa.topk_recent_blocks, 1), 1)
    return min(k, nb)


def alloc_prefill_private_cache(cfg, mesh, plan, max_len, dtype=None):
    """B=1 dense task cache WITHOUT full-attention layers (their KV lives
    in the shared arena): ring KV (bounded by sink+recent), mamba state,
    and the position scalar. This is what a paged prefill task pins per
    layer instead of a [1, max_len, K, h] dense cache."""
    return _drop_entries(cfg, plan,
                         alloc_cache(cfg, mesh, plan, 1, max_len, dtype),
                         drop_full=True)


def alloc_paged_private_cache(cfg, mesh, plan, n_slots, max_len, block_size,
                              dtype=None):
    """Decode-engine private side of the paged cache: per-slot ring arenas
    and non-attention state; full-attention entries are None (shared
    arena). n_arena_blocks=1 below is a placeholder — those entries are
    dropped."""
    return _drop_entries(cfg, plan,
                         alloc_paged_cache(cfg, mesh, plan, n_slots, max_len,
                                           1, block_size, dtype),
                         drop_full=True)


def merge_arena_cache(cfg, plan, private, arena_kv):
    """(private ∪ arena) → the full cache pytree a jit body expects."""
    per = tuple(arena_kv["period"][i] if full_attn_layer(cfg, s)
                else private["period"][i] for i, s in enumerate(plan.period))
    rem = tuple(arena_kv["rem"][i] if full_attn_layer(cfg, s)
                else private["rem"][i] for i, s in enumerate(plan.rem))
    return {"period": per, "rem": rem, "pos": private["pos"]}


def split_arena_cache(cfg, plan, cache):
    """Inverse of merge_arena_cache → (private, arena_kv)."""
    return (_drop_entries(cfg, plan, cache, drop_full=True),
            _drop_entries(cfg, plan, {k: cache[k] for k in ("period", "rem")},
                          drop_full=False))


# ======================================================================
def unstack_params(plan: StackPlan, params: dict) -> list[dict]:
    """Stack params → flat per-layer list (layer order)."""
    layers = []
    for r in range(plan.n_rep):
        for i in range(len(plan.period)):
            layers.append(jax.tree.map(lambda x: x[r], params["period"][i]))
    layers.extend(params["rem"])
    return layers


def restack_params(plan: StackPlan, layers: list[dict]) -> dict:
    """Flat per-layer list → stack params for `plan`."""
    p = len(plan.period)
    period = []
    for i in range(p):
        entries = [layers[r * p + i] for r in range(plan.n_rep)]
        period.append(jax.tree.map(lambda *xs: jnp.stack(xs), *entries))
    rem = tuple(layers[plan.n_rep * p:])
    return {"period": tuple(period), "rem": rem}


def regroup_params(params: dict, plan_from: StackPlan, plan_to: StackPlan) -> dict:
    """Convert stack params between periodizations (e.g. to serve a model
    under a different OmniAttn pattern than it was built with). Weights are
    pattern-independent; only the scan grouping changes."""
    if plan_from == plan_to:
        return params
    if plan_from.n_layers != plan_to.n_layers:
        raise ValueError("layer count mismatch")
    return restack_params(plan_to, unstack_params(plan_from, params))


# ======================================================================
# Layer application
def attn_sublayer(cfg: ModelConfig, mesh: MeshCtx, p: dict, x, *, spec: LayerSpec,
                  mode: str, positions, cache, max_len: int, batch_part,
                  true_len=None, attend_limit: int = 0, block_tables=None,
                  token_mask=None):
    """→ (x, new_cache | None, sparsity_aux | None). sparsity_aux is a [4]
    f32 vector [blocks_scored, blocks_attended, mass_sum, mass_n] emitted
    by paged-decode full-attention layers when online top-k selection is
    configured (cfg.omniattn.topk_*), weighted by `token_mask` (live
    slots)."""
    B = x.shape[0]
    H, K, h = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    cd = jnp.dtype(cfg.compute_dtype)
    hid = rms_norm(x, p["ln_attn"], cfg.rms_eps).astype(cd)
    q = hid @ p["wq"]
    k = hid @ p["wk"]
    v = hid @ p["wv"]
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    S = x.shape[1]
    q = q.reshape(B, S, H, h)
    k = k.reshape(B, S, K, h)
    v = v.reshape(B, S, K, h)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.rms_eps)
        k = rms_norm(k, p["k_norm"], cfg.rms_eps)
    q = attn_mod.apply_rope(q, positions, cfg.rope_theta)
    k = attn_mod.apply_rope(k, positions, cfg.rope_theta)
    sink, recent = cache_window(cfg, spec)

    use_pallas = cfg.use_pallas and mesh.tp == 1
    new_cache = None
    sp_aux = None
    if (mode == "prefill" and cache is not None and block_tables is not None
            and not (sink or recent)):
        # paged chunked prefill (B=1 task): the prompt's resident history
        # lives in pool-allocated arena blocks reached through the task's
        # block table (cache leaves ARE the arenas); the chunk's K/V is
        # scattered straight into its own blocks — no dense [1, max_len]
        # cache ever exists for this layer. Ring layers (sink or recent)
        # stay on the dense per-task path below: their capacity is bounded
        # by the window, not max_len.
        cl = S if true_len is None else true_len
        pos0 = jnp.asarray(positions, jnp.int32)[0]
        # QuantPlane: int8 arenas carry the scale plane — history reads
        # dequantize in-tile, writes quantize per-token + seal-on-full
        quant = "kscale" in cache
        qkw = dict(k_scale=cache["kscale"], k_tok=cache["ktok"],
                   v_scale=cache["vscale"], v_tok=cache["vtok"]) \
            if quant else {}
        if use_pallas:
            from repro.kernels import ops as kops
            out = kops.attention_paged_prefill_op(
                q, k, v, cache["k"], cache["v"], block_tables, pos0, cl,
                **qkw)
        else:
            out = attn_mod.paged_prefill_attention(
                q, k, v, cache["k"], cache["v"], block_tables, pos0, cl,
                **qkw)
        if quant:
            new_cache = attn_mod.quant_paged_prefill_write(
                cache, k, v, block_tables, pos0, cl)
        else:
            kc, vc = attn_mod.paged_prefill_write(cache["k"], cache["v"],
                                                  k, v, block_tables, pos0,
                                                  cl)
            new_cache = {"k": kc, "v": vc}
        y = out.reshape(B, S, H * h)
        if "kmin" in cache:
            # block-summary metadata plane: the chunk's writes touched the
            # blocks its token positions map to (padded tail rows alias the
            # null block, whose re-summary is harmless) — recompute those
            # blocks' summaries from the updated arena in the same jit
            bs_a = cache["k"].shape[-2]
            nb_t = block_tables.shape[1]
            ppos = pos0 + jnp.arange(S)
            wblk = jnp.where(jnp.arange(S) < jnp.asarray(cl, jnp.int32),
                             block_tables[0, jnp.clip(ppos // bs_a, 0,
                                                      nb_t - 1)], 0)
            kmn, kmx, kme = attn_mod.update_block_summaries(
                cache["kmin"], cache["kmax"], cache["kmean"],
                new_cache["k"], wblk,
                k_scale=new_cache.get("kscale"),
                k_tok=new_cache.get("ktok"))
            new_cache.update(kmin=kmn, kmax=kmx, kmean=kme)
    elif mode == "prefill" and cache is not None:
        # continuation chunk (chunked prefill / radix prefix-KV resume):
        # attend resident cache tokens + causal in-chunk keys, then scatter
        # the chunk into the cache. true_len here is chunk-local.
        mask_window = mask_sink = 0
        if spec.window > 0:
            mask_window = spec.window
        elif spec.compressed and cfg.prefill_sparse:
            mask_window, mask_sink = recent, sink
        out, kc, vc = attn_mod.prefill_resume_attention(
            q, k, v, cache["k"], cache["v"], positions,
            chunk_len=(S if true_len is None else true_len),
            sink=sink, recent=recent,
            mask_window=mask_window, mask_sink=mask_sink,
            attend_limit=attend_limit)
        y = out.reshape(B, S, H * h)
        new_cache = {"k": kc, "v": vc}
    elif mode == "decode" and block_tables is not None:
        # physically paged decode: the cache leaves are block arenas
        # [N, K, bs, h]; full layers map logical position blocks through the
        # per-slot table, ring layers statically own a contiguous block run.
        pos = jnp.asarray(positions)
        t = pos[:, 0] if pos.ndim == 2 else (pos[0] if pos.ndim == 1 else pos)
        t = jnp.broadcast_to(jnp.asarray(t, jnp.int32), (B,))
        bs = cache["k"].shape[2]
        bidx = jnp.arange(B, dtype=jnp.int32)
        if sink or recent:
            W = sink + recent
            bpw = ring_block_count(sink, recent, bs)
            slot = attn_mod.ring_slot(t, sink, recent)
            blk = bidx * bpw + slot // bs
            off = slot % bs
            tbl = bidx[:, None] * bpw + jnp.arange(bpw, dtype=jnp.int32)[None, :]
            lens = jnp.minimum(t + 1, W)
        else:
            # past the table's logical capacity the write is redirected to
            # the null block (the dense per-request path drops OOB writes)
            nb = block_tables.shape[1]
            blk = jnp.where(t < nb * bs,
                            block_tables[bidx, jnp.minimum(t // bs, nb - 1)],
                            0)
            off = t % bs
            tbl = block_tables
            lens = jnp.minimum(t + 1, nb * bs)
        quant = "kscale" in cache
        if quant and not (sink or recent):
            # QuantPlane append: per-token int8 quantize + scale-plane
            # maintenance (unseal-on-open / seal-on-full) in one helper
            new_cache = attn_mod.quant_paged_cache_write(
                cache, k[:, 0], v[:, 0], blk, off)
            kc, vc = new_cache["k"], new_cache["v"]
        else:
            kc, vc = attn_mod.paged_cache_write(cache["k"], cache["v"],
                                                k[:, 0], v[:, 0], blk, off)
            new_cache = {"k": kc, "v": vc}
        qkw = dict(k_scale=new_cache["kscale"], k_tok=new_cache["ktok"],
                   v_scale=new_cache["vscale"], v_tok=new_cache["vtok"]) \
            if quant and not (sink or recent) else {}
        if not (sink or recent) and "kmin" in cache:
            # summaries ride the same write: the appended token lands in
            # `blk` (freed slots alias the null block) — recompute those
            # blocks BEFORE scoring so the tail bound covers the new key
            kmn, kmx, kme = attn_mod.update_block_summaries(
                cache["kmin"], cache["kmax"], cache["kmean"], kc, blk,
                k_scale=new_cache.get("kscale"),
                k_tok=new_cache.get("ktok"))
            new_cache.update(kmin=kmn, kmax=kmx, kmean=kme)
            oa = cfg.omniattn
            k_static = topk_block_budget(oa, tbl.shape[1])
            if k_static is not None:
                act = (token_mask.astype(jnp.float32) if token_mask
                       is not None else jnp.ones((B,), jnp.float32))
                n_res = (lens + bs - 1) // bs
                scored = (act * n_res).sum()
                if k_static < tbl.shape[1]:
                    # online top-k: score resident blocks with the Quest
                    # upper bound and attend a compacted table — blocks
                    # outside the budget are never gathered downstream
                    if use_pallas:
                        from repro.kernels import ops as kops
                        scores = kops.block_topk_scores_op(
                            q[:, 0], kmn, kmx, tbl, lens, block_size=bs)
                    else:
                        scores = attn_mod.block_topk_scores(
                            q[:, 0], kmn, kmx, tbl, lens, block_size=bs)
                    tbl_s, lens_s, m, selected = attn_mod.select_kv_blocks(
                        scores, tbl, lens, block_size=bs, k_static=k_static,
                        frac=0.0 if oa.topk_blocks > 0 else oa.topk_frac,
                        sink_blocks=max(oa.topk_sink_blocks, 0),
                        recent_blocks=max(oa.topk_recent_blocks, 1))
                    if oa.topk_measure_mass:
                        mass = attn_mod.selected_attention_mass(
                            q[:, 0], kc, tbl, lens, selected,
                            k_scale=new_cache.get("kscale"),
                            k_tok=new_cache.get("ktok"))
                        mass_sum, mass_n = (act * mass).sum(), act.sum()
                    else:
                        mass_sum = mass_n = jnp.float32(0)
                    sp_aux = jnp.stack([scored, (act * m).sum(),
                                        mass_sum, mass_n])
                    tbl, lens = tbl_s, lens_s
                else:
                    # budget covers the whole (bucketed) table: exact
                    # attention; still report so the stats stay comparable
                    mn = act.sum() if oa.topk_measure_mass else jnp.float32(0)
                    sp_aux = jnp.stack([scored, scored, mn, mn])
        if use_pallas:
            from repro.kernels import ops as kops
            out = kops.attention_paged_decode_op(q[:, 0], kc, vc, tbl, lens,
                                                 **qkw)
        else:
            out = attn_mod.paged_decode_attention(q[:, 0], kc, vc, tbl, lens,
                                                  **qkw)
        y = out.reshape(B, 1, H * h)
    elif mode == "verify":
        # speculative verify: READ-ONLY attention of each slot's draft
        # window [B, S] (S = k+1) against its paged history. No K/V write
        # happens here — the window's rope'd keys are STAGED as this
        # layer's "new cache" and committed post-acceptance by
        # stack_verify_commit, so a rejected draft row leaves blocks and
        # block summaries untouched (rollback = the write never landing).
        pos2 = jnp.asarray(positions, jnp.int32)        # [B, S]
        if sink or recent:
            # gather the slot's frozen ring blocks into a dense [B, W]
            # view (slot b statically owns blocks [b·bpw, (b+1)·bpw))
            bs_a = cache["k"].shape[2]
            bpw = ring_block_count(sink, recent, bs_a)
            W = sink + recent
            kr = jnp.moveaxis(cache["k"].reshape(B, bpw, K, bs_a, h), 2, 3) \
                .reshape(B, bpw * bs_a, K, h)[:, :W]
            vr = jnp.moveaxis(cache["v"].reshape(B, bpw, K, bs_a, h), 2, 3) \
                .reshape(B, bpw * bs_a, K, h)[:, :W]
            out = attn_mod.spec_verify_ring_attention(
                q, k, v, kr, vr, pos2, sink=sink, recent=recent)
        else:
            t = pos2[:, 0]
            qkw = dict(k_scale=cache["kscale"], k_tok=cache["ktok"],
                       v_scale=cache["vscale"], v_tok=cache["vtok"]) \
                if "kscale" in cache else {}
            if use_pallas:
                from repro.kernels import ops as kops
                out = kops.spec_verify_op(q, k, v, cache["k"], cache["v"],
                                          block_tables, t,
                                          jnp.full_like(t, S), **qkw)
            else:
                out = attn_mod.paged_prefill_attention(
                    q, k, v, cache["k"], cache["v"], block_tables, t, S,
                    **qkw)
        y = out.reshape(B, S, H * h)
        new_cache = {"k": k, "v": v}
    elif mode == "decode":
        pos = jnp.asarray(positions)
        t = pos[:, 0] if pos.ndim == 2 else (pos[0] if pos.ndim == 1 else pos)
        kc, vc = attn_mod.cache_write(cache["k"], cache["v"], k[:, 0], v[:, 0], t,
                                      sink=sink, recent=recent)
        if use_pallas:
            from repro.kernels import ops as kops
            out = kops.attention_decode_op(q[:, 0], kc, vc, t + 1)
        else:
            strat = attn_mod.decode_strategy(K, mesh.tp)
            out = attn_mod.decode_attention(q[:, 0], kc, vc, t + 1, mesh=mesh,
                                            strategy=strat, batch_part=batch_part)
        y = out.reshape(B, 1, H * h)
        new_cache = {"k": kc, "v": vc}
    elif use_pallas:
        from repro.kernels import ops as kops
        window = spec.window
        use_sink = 0
        if spec.compressed and cfg.prefill_sparse:
            window, use_sink = recent, sink
        out = kops.attention_prefill_op(q, k, v, causal=cfg.causal,
                                        window=window, sink=use_sink)
        y = out.reshape(B, S, H * h)
        if mode == "prefill":
            if sink or recent:
                kc, vc = attn_mod.compress_prefill_kv(k, v, sink=sink,
                                                      recent=recent,
                                                      true_len=true_len)
            else:
                pad = max_len - S
                kc = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
                vc = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
            new_cache = {"k": kc, "v": vc}
    else:
        window = spec.window
        use_sink = 0
        if spec.compressed and cfg.prefill_sparse:
            window, use_sink = recent, sink
        out = attn_mod.chunked_attention(
            q, k, v, causal=cfg.causal, window=window, sink=use_sink,
            q_chunk=cfg.attn_q_chunk, kv_chunk=cfg.attn_kv_chunk, mesh=mesh,
            strategy=attn_mod.prefill_strategy(H, K, mesh.tp),
            batch_part=batch_part,
            skip_masked_chunks=cfg.attn_skip_masked_chunks,
            fp32_scores=cfg.attn_fp32_scores,
            qseq_out_constraint=cfg.attn_qseq_out_constraint)
        y = out.reshape(B, S, H * h)
        if mode == "prefill":
            if sink or recent:
                kc, vc = attn_mod.compress_prefill_kv(k, v, sink=sink,
                                                      recent=recent,
                                                      true_len=true_len)
            else:
                pad = max_len - S
                kc = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
                vc = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
            new_cache = {"k": kc, "v": vc}
    y = (y @ p["wo"]).astype(x.dtype)
    return x + y, new_cache, sp_aux


def mamba_sublayer(cfg: ModelConfig, mesh: MeshCtx, p: dict, x, *, mode: str,
                   cache, batch_part, true_len=None):
    if mode == "verify":
        # backstop: SpecController refuses hybrid/SSM stacks upfront — a
        # rejected draft would need the pre-window recurrent state back,
        # and SSM state has no block/summary plane to roll back through
        raise NotImplementedError(
            "speculative verify has no multi-token SSM rollback path")
    B, S, D = x.shape
    ssm = cfg.ssm
    d_in = ssm.expand * D
    nh = d_in // ssm.head_dim
    N = ssm.d_state
    cd = jnp.dtype(cfg.compute_dtype)
    hid = rms_norm(x, p["ln_attn"], cfg.rms_eps).astype(cd)
    z = hid @ p["w_z"]
    xin = hid @ p["w_x"]
    bc = hid @ p["w_bc"]
    dt_raw = hid @ p["w_dt"]
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(p["A_log"].astype(jnp.float32))

    cx_cache = cache["conv_x"] if cache is not None else None
    cbc_cache = cache["conv_bc"] if cache is not None else None
    xin_pre, bc_pre = xin, bc               # pre-conv (cache rows live here)
    xin, new_cx = ssd_mod.causal_conv(xin, p["conv_x"], cx_cache)
    bc, new_cbc = ssd_mod.causal_conv(bc, p["conv_bc"], cbc_cache)
    xin = jax.nn.silu(xin)
    bc = jax.nn.silu(bc)
    if true_len is not None and mode != "decode":
        # right-padded prefill: dt=0 beyond true_len freezes the SSD state
        # (decay exp(0)=1, update 0); x masked for the D_skip term.
        live = (jnp.arange(S) < true_len)
        dt = dt * live[None, :, None]
        xin = xin * live[None, :, None].astype(xin.dtype)
        # conv caches hold the last conv_width-1 REAL pre-conv inputs; for a
        # continuation chunk they may straddle the chunk boundary, so slice
        # from (old cache ‖ chunk) instead of a zero-padded chunk.
        cw = ssm.conv_width
        if cx_cache is not None:
            pad_x = jnp.concatenate([cx_cache.astype(xin_pre.dtype), xin_pre],
                                    axis=1)
            pad_bc = jnp.concatenate([cbc_cache.astype(bc_pre.dtype), bc_pre],
                                     axis=1)
        else:
            pad_x = jnp.pad(xin_pre, ((0, 0), (cw - 1, 0), (0, 0)))
            pad_bc = jnp.pad(bc_pre, ((0, 0), (cw - 1, 0), (0, 0)))
        new_cx = jax.lax.dynamic_slice_in_dim(pad_x, true_len, cw - 1, axis=1)
        new_cbc = jax.lax.dynamic_slice_in_dim(pad_bc, true_len, cw - 1, axis=1)
    Bm, Cm = bc[..., :N], bc[..., N:]

    xh = xin.reshape(B, S, nh, ssm.head_dim)
    xh = mesh.constrain(xh, P(batch_part, None, "model", None))
    if mode == "decode":
        y1, new_state = ssd_mod.ssd_decode_step(cache["state"], xh[:, 0], dt[:, 0],
                                                A, Bm[:, 0], Cm[:, 0])
        y = y1[:, None]
    else:
        init = cache["state"] if cache is not None else None
        y, new_state = ssd_mod.ssd_chunked(xh, dt, A, Bm, Cm, ssm.chunk, init)
    y = y + xh.astype(y.dtype) * p["D_skip"].astype(y.dtype)[None, None, :, None]
    y = y.reshape(B, S, d_in)
    y = rms_norm(y * jax.nn.silu(z.astype(y.dtype)), p["ssm_norm"], cfg.rms_eps)
    out = (y.astype(cd) @ p["out_proj"]).astype(x.dtype)
    if mesh.tp > 1:
        out = mesh.constrain(out, P(batch_part, None, None))
    new_cache = None
    if mode in ("decode", "prefill"):
        new_cache = {"state": new_state.astype(jnp.float32), "conv_x": new_cx,
                     "conv_bc": new_cbc}
    return x + out, new_cache


def ffn_sublayer(cfg: ModelConfig, mesh: MeshCtx, p: dict, x, *, spec: LayerSpec,
                 batch_part, token_mask=None):
    """Returns (x, moe_counts or None)."""
    B, S, D = x.shape
    cd = jnp.dtype(cfg.compute_dtype)
    if not spec.use_moe and cfg.d_ff == 0:
        return x, None
    hid = rms_norm(x, p["ln_mlp"], cfg.rms_eps).astype(cd)
    if spec.use_moe:
        flat = hid.reshape(B * S, D)
        shared = None
        if cfg.moe.n_shared_experts:
            shared = (p["shared_w1"], p["shared_w3"], p["shared_w2"])
        tables = p["_tables"]
        y, counts = moe_mod.moe_ffn(mesh, cfg, flat, p["router"], p["moe_w1"],
                                    p["moe_w3"], p["moe_w2"], tables, shared,
                                    batch_part=batch_part,
                                    token_mask=token_mask)
        y = y.reshape(B, S, D)
        return x + y.astype(x.dtype), counts
    h1 = jax.nn.silu(hid @ p["w1"]) * (hid @ p["w3"])
    y = (h1 @ p["w2"]).astype(x.dtype)
    y = mesh.constrain(y, P(batch_part, None, None))
    return x + y, None


def apply_layer(cfg, mesh, spec: LayerSpec, p: dict, x, *, mode, positions,
                cache, max_len, batch_part, true_len=None, attend_limit=0,
                token_mask=None, block_tables=None):
    sp = None
    if spec.kind == "attn":
        x, nc, sp = attn_sublayer(cfg, mesh, p, x, spec=spec, mode=mode,
                                  positions=positions, cache=cache,
                                  max_len=max_len, batch_part=batch_part,
                                  true_len=true_len,
                                  attend_limit=attend_limit,
                                  block_tables=block_tables,
                                  token_mask=token_mask)
    else:
        x, nc = mamba_sublayer(cfg, mesh, p, x, mode=mode, cache=cache,
                               batch_part=batch_part, true_len=true_len)
    x, counts = ffn_sublayer(cfg, mesh, p, x, spec=spec, batch_part=batch_part,
                             token_mask=token_mask)
    x = mesh.constrain(x, P(batch_part, None, None))
    return x, nc, counts, sp


# ======================================================================
def stack_apply(cfg: ModelConfig, mesh: MeshCtx, plan: StackPlan, params: dict,
                x, *, mode: str, positions, caches=None, max_len: int = 0,
                batch_part=None, tables=None, true_len=None,
                attend_limit: int = 0, token_mask=None, block_tables=None):
    """Run the full layer stack.

    tables: MoE placement tables dict (injected into layer params as '_tables').
    block_tables: [B, nb] physical KV block ids (decode over paged caches —
    every attention layer's cache leaves must then be block arenas).
    Returns (x, new_caches | None, aux dict with per-layer MoE counts).
    """
    def with_tables(p):
        if tables is not None and any(k.startswith("moe_") for k in p):
            p = dict(p)
            p["_tables"] = tables
        return p

    has_cache = caches is not None
    period_caches = caches["period"] if has_cache else tuple(None for _ in plan.period)

    def body(carry, xs):
        h = carry
        p_slices = xs[0]
        c_slices = xs[1] if has_cache else tuple(None for _ in plan.period)
        new_cs, counts, sps = [], [], []
        for i, spec in enumerate(plan.period):
            h, nc, cnt, sp = apply_layer(cfg, mesh, spec,
                                         with_tables(p_slices[i]), h,
                                         mode=mode, positions=positions,
                                         cache=c_slices[i], max_len=max_len,
                                         batch_part=batch_part,
                                         true_len=true_len,
                                         attend_limit=attend_limit,
                                         token_mask=token_mask,
                                         block_tables=block_tables)
            if nc is not None:
                new_cs.append(nc)
            if cnt is not None:
                counts.append(cnt)
            if sp is not None:
                sps.append(sp)
        return h, (tuple(new_cs), tuple(counts), tuple(sps))

    if cfg.remat and mode == "train":
        policy = (jax.checkpoint_policies.dots_saveable
                  if cfg.remat_policy == "dots"
                  else jax.checkpoint_policies.nothing_saveable)
        body = jax.checkpoint(body, policy=policy)

    xs = (params["period"], period_caches) if has_cache else (params["period"],)
    if plan.n_rep > 0 and plan.period:
        x, (new_period_caches, period_counts, period_sparsity) = \
            jax.lax.scan(body, x, xs)
    else:
        new_period_caches, period_counts, period_sparsity = (), (), ()

    new_rem_caches, rem_counts, rem_sparsity = [], [], []
    rem_caches = caches["rem"] if has_cache else tuple(None for _ in plan.rem)
    for i, spec in enumerate(plan.rem):
        x, nc, cnt, sp = apply_layer(cfg, mesh, spec,
                                     with_tables(params["rem"][i]), x,
                                     mode=mode, positions=positions,
                                     cache=rem_caches[i], max_len=max_len,
                                     batch_part=batch_part, true_len=true_len,
                                     attend_limit=attend_limit,
                                     token_mask=token_mask,
                                     block_tables=block_tables)
        if nc is not None:
            new_rem_caches.append(nc)
        if cnt is not None:
            rem_counts.append(cnt)
        if sp is not None:
            rem_sparsity.append(sp)

    new_caches = None
    if mode in ("prefill", "decode"):
        new_pos = jnp.max(jnp.asarray(positions)) + 1
        new_caches = {"period": new_period_caches, "rem": tuple(new_rem_caches),
                      "pos": jnp.asarray(new_pos, jnp.int32)}
    elif mode == "verify":
        # STAGED (not yet written) rope'd window K/V per attention layer —
        # period entries arrive scan-stacked [n_rep, B, S, K, h]. The caller
        # decides acceptance, then lands only the accepted prefix via
        # stack_verify_commit; until then the real caches are untouched.
        new_caches = {"period": new_period_caches,
                      "rem": tuple(new_rem_caches)}
    aux = {"period_counts": period_counts, "rem_counts": tuple(rem_counts),
           # per-layer online-sparsity vectors [blocks_scored,
           # blocks_attended, mass_sum, mass_n] — period entries arrive
           # scan-stacked [n_rep, 4]; empty tuples when sparsity is off
           "period_sparsity": period_sparsity,
           "rem_sparsity": tuple(rem_sparsity)}
    return x, new_caches, aux


# ======================================================================
def stack_verify_commit(cfg: ModelConfig, plan: StackPlan, caches, staged,
                        positions, n_write, block_tables):
    """Land a speculative verify window's ACCEPTED prefix in the paged caches.

    caches: the paged cache pytree the verify forward read (untouched by
    it); staged: stack_apply(mode="verify")'s second return — each
    attention layer's rope'd window K/V; positions [B] window start (the
    pre-verify slot cursor); n_write [B] rows to land per slot — the
    CONSUMED input tokens (current token + accepted drafts; 0 for idle
    slots); block_tables [B, nb].

    Window row i of slot b lands at absolute position positions[b] + i iff
    i < n_write[b]. Full-attention layers redirect rejected/idle/overflow
    rows to the null block and recompute the touched blocks' summaries in
    the same jit (duplicate + null ids are harmless re-reductions), so the
    zero-stale-summary invariant holds at the jit boundary — a rollback is
    simply a write that never happened. Ring layers have no null block:
    rejected rows write back their target slot's current content
    (gather-then-where), bit-exact no-ops. Distinct window rows always map
    to distinct ring slots because S ≤ recent (`chunked_prefill_support`
    caps the draft window). Returns the updated cache pytree; "pos"
    advances to the furthest committed cursor like a decode step's cache.
    """
    positions = jnp.asarray(positions, jnp.int32)
    n_write = jnp.asarray(n_write, jnp.int32)
    B = positions.shape[0]
    entries = list(staged["period"]) + list(staged["rem"])
    S = entries[0]["k"].shape[-3]
    pos2 = positions[:, None] + jnp.arange(S, dtype=jnp.int32)[None]
    valid = jnp.arange(S, dtype=jnp.int32)[None] < n_write[:, None]
    bidx = jnp.arange(B, dtype=jnp.int32)

    def commit_full(entry, stg):
        bs = entry["k"].shape[-2]
        nb = block_tables.shape[1]
        blk = jnp.where(valid & (pos2 < nb * bs),
                        block_tables[bidx[:, None],
                                     jnp.minimum(pos2 // bs, nb - 1)], 0)
        off = pos2 % bs
        if "kscale" in entry:
            # QuantPlane commit: the staged f32 window quantizes per-token
            # on landing; rejected rows arrive null-redirected, so rollback
            # stays "the write never happened" for payload AND scale plane
            out = dict(entry)
            out.update(attn_mod.quant_paged_cache_write_tokens(
                entry, stg["k"], stg["v"], blk, off))
        else:
            kc, vc = attn_mod.paged_cache_write_tokens(
                entry["k"], entry["v"], stg["k"], stg["v"], blk, off)
            out = dict(entry, k=kc, v=vc)
        if "kmin" in entry:
            kmn, kmx, kme = attn_mod.update_block_summaries(
                entry["kmin"], entry["kmax"], entry["kmean"], out["k"],
                blk.reshape(-1), k_scale=out.get("kscale"),
                k_tok=out.get("ktok"))
            out.update(kmin=kmn, kmax=kmx, kmean=kme)
        return out

    def commit_ring(entry, stg, sink, recent):
        bs = entry["k"].shape[-2]
        bpw = ring_block_count(sink, recent, bs)
        slot = attn_mod.ring_slot(pos2, sink, recent)
        blk = bidx[:, None] * bpw + slot // bs
        off = slot % bs
        kc, vc = attn_mod.paged_cache_write_tokens_masked(
            entry["k"], entry["v"], stg["k"], stg["v"], blk, off, valid)
        return dict(entry, k=kc, v=vc)

    def commit(spec, entry, stg, stacked):
        sink, recent = cache_window(cfg, spec)
        if sink or recent:
            fn = lambda e, s: commit_ring(e, s, sink, recent)
        else:
            fn = commit_full
        return jax.vmap(fn)(entry, stg) if stacked else fn(entry, stg)

    per = tuple(commit(s, caches["period"][i], staged["period"][i], True)
                for i, s in enumerate(plan.period))
    rem = tuple(commit(s, caches["rem"][i], staged["rem"][i], False)
                for i, s in enumerate(plan.rem))
    new_pos = jnp.max(positions + n_write).astype(jnp.int32)
    return {"period": per, "rem": rem, "pos": new_pos}
