from repro.models.lm import LM
from repro.models.stack import StackPlan, alloc_cache, cache_struct

__all__ = ["LM", "StackPlan", "alloc_cache", "cache_struct"]
