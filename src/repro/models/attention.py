"""Attention: chunked (flash-style) prefill + cached decode.

Layouts:
  q        [B, S, H, h]       (H = n_heads)
  k, v     [B, S, K, h]       (K = n_kv_heads, G = H//K)
  cache    [B, W, K, h]       per layer; W = allocated window

Sharding strategies (chosen per arch by the caller — see DESIGN.md):
  prefill: 'heads' → shard H over `model` (repeat-kv full-head layout)
           'qseq'  → shard q-chunk seq over `model` (few-head archs)
  decode:  'kv'    → shard K over `model` (K ≥ TP)
           'wseq'  → shard cache W over `model` (flash-decoding style; XLA
                     inserts the LSE-combining all-reduce in the softmax)
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.distributed.ctx import MeshCtx
from repro.models.common import apply_rope  # re-export for layer code

NEG_INF = -1e30


def prefill_strategy(n_heads: int, n_kv: int, tp: int) -> str:
    return "heads" if n_heads % tp == 0 else "qseq"


def decode_strategy(n_kv: int, tp: int) -> str:
    return "kv" if n_kv % tp == 0 else "wseq"


def arena_kv_part(n_kv: int, tp: int):
    """Mesh axis (or None) the KV-head dim of paged KV arenas and their
    block summaries shards over. Blocks stay replicated along the block
    dim — any rank can serve any block-table row — so TP only splits the
    head dim, and only under the 'kv' decode strategy."""
    return "model" if tp > 1 and decode_strategy(n_kv, tp) == "kv" else None


# ----------------------------------------------------------------------
def chunked_attention(
    q, k, v, *,
    causal: bool,
    window: int = 0,
    sink: int = 0,
    q_offset: int = 0,
    q_chunk: int = 2048,
    kv_chunk: int = 1024,
    mesh: Optional[MeshCtx] = None,
    strategy: str = "heads",
    batch_part=None,
    skip_masked_chunks: bool = False,
    fp32_scores: bool = True,
    qseq_out_constraint: bool = False,
):
    """Blockwise attention with online softmax; O(q_chunk·kv_chunk) live scores.

    window > 0 → sliding-window (local) attention of that width.
    skip_masked_chunks → unroll q chunks in Python and statically slice the KV
    range each q chunk can see (halves causal FLOPs; §Perf lever).
    """
    B, S, H, h = q.shape
    Skv = k.shape[1]
    K = k.shape[2]
    G = H // K
    scale = h ** -0.5

    qc = min(q_chunk, S)
    while S % qc:
        qc //= 2
    kc = min(kv_chunk, Skv)
    while Skv % kc:
        kc //= 2
    n_q, n_kv = S // qc, Skv // kc

    if strategy == "heads" and mesh is not None and mesh.tp > 1:
        # full-head layout: repeat KV → shard H over model
        k = jnp.repeat(k, G, axis=2)
        v = jnp.repeat(v, G, axis=2)
        K_eff, G_eff = H, 1
        head_spec = "model"
    else:
        K_eff, G_eff = K, G
        head_spec = None

    kq = k.reshape(B, n_kv, kc, K_eff, h)
    vq = v.reshape(B, n_kv, kc, K_eff, h)
    qr = q.reshape(B, n_q, qc, K_eff, G_eff, h)

    def one_q_chunk(args):
        qi, q_blk = args                      # q_blk [B, qc, K_eff, G_eff, h]
        if mesh is not None:
            if strategy == "qseq":
                q_blk = mesh.constrain(q_blk, P(batch_part, "model", None, None, None))
            elif head_spec:
                q_blk = mesh.constrain(q_blk, P(batch_part, None, head_spec, None, None))
        q_pos = q_offset + qi * qc + jnp.arange(qc)

        sd = jnp.float32 if fp32_scores else q.dtype

        def kv_step(carry, inp):
            m, l, acc = carry
            kj, k_blk, v_blk = inp            # [B, kc, K_eff, h]
            k_pos = kj * kc + jnp.arange(kc)
            s = jnp.einsum("bqkgh,bckh->bkgqc", q_blk.astype(sd),
                           k_blk.astype(sd)) * scale
            mask = jnp.ones((qc, kc), dtype=bool)
            if causal:
                mask &= k_pos[None, :] <= q_pos[:, None]
            if window > 0:
                in_win = (q_pos[:, None] - k_pos[None, :]) < window
                if sink > 0:          # sink+window sparse prefill (OmniAttn)
                    in_win |= k_pos[None, :] < sink
                mask &= in_win
            s = jnp.where(mask[None, None, None], s,
                          jnp.asarray(NEG_INF, s.dtype))
            m_new = jnp.maximum(m, s.max(axis=-1).astype(jnp.float32))
            p = jnp.exp(s.astype(jnp.float32) - m_new[..., None]).astype(sd) \
                if fp32_scores else jnp.exp(s - m_new[..., None].astype(sd))
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(axis=-1).astype(jnp.float32)
            pv = jnp.einsum("bkgqc,bckh->bkgqh", p.astype(v_blk.dtype), v_blk)
            acc_new = acc * corr[..., None].astype(acc.dtype) + pv
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, K_eff, G_eff, qc), NEG_INF, dtype=jnp.float32)
        l0 = jnp.zeros((B, K_eff, G_eff, qc), dtype=jnp.float32)
        a0 = jnp.zeros((B, K_eff, G_eff, qc, h), dtype=v.dtype)

        if skip_masked_chunks and causal:
            # statically bound the visible kv blocks for this q chunk:
            # causal upper bound, sliding-window lower bound, sink blocks
            # (OmniAttn sparse prefill: compute ∝ window, not S)
            q_lo = q_offset + int(qi) * qc
            q_hi = q_offset + (int(qi) + 1) * qc - 1
            hi = min(n_kv, (q_hi + kc) // kc)
            if window > 0:
                lo = max(0, (q_lo - window + 1) // kc)
                vis = set(range(lo, hi))
                if sink > 0:
                    vis |= set(range(0, min((sink + kc - 1) // kc, n_kv)))
            else:
                vis = set(range(hi))
            carry = (m0, l0, a0)
            for j in sorted(vis):
                carry, _ = kv_step(carry, (jnp.asarray(j), kq[:, j], vq[:, j]))
            m, l, acc = carry
        else:
            (m, l, acc), _ = jax.lax.scan(
                kv_step, (m0, l0, a0),
                (jnp.arange(n_kv), jnp.moveaxis(kq, 1, 0), jnp.moveaxis(vq, 1, 0)))
        out = acc / jnp.maximum(l, 1e-30)[..., None].astype(acc.dtype)
        out = jnp.moveaxis(out, 3, 1)          # [B, qc, K_eff, G_eff, h]
        return out.reshape(B, qc, K_eff * G_eff, h)

    if skip_masked_chunks and causal:
        outs = [one_q_chunk((i, qr[:, i])) for i in range(n_q)]
        out = jnp.stack(outs, axis=1)
    else:
        out = jax.lax.map(one_q_chunk, (jnp.arange(n_q), jnp.moveaxis(qr, 1, 0)))
        out = jnp.moveaxis(out, 0, 1)         # [B, n_q, qc, H, h]
    out = out.reshape(B, S, H, h)
    if mesh is not None and strategy == "qseq" and qseq_out_constraint:
        # pin the q-sequence sharding on the merged output so SPMD reshards
        # once at the wo matmul instead of inventing 6-D transposes
        # (cuts collectives ~12% but costs compute — §Perf C1: net refuted,
        # kept as an opt-in knob)
        out = mesh.constrain(out, P(batch_part, "model", None, None))
    return out


# ----------------------------------------------------------------------
def decode_attention(
    q, k_cache, v_cache, t, *,
    mesh: Optional[MeshCtx] = None,
    strategy: str = "kv",
    batch_part=None,
):
    """Single-token attention over a cache. q [B, H, h]; caches [B, W, K, h];
    t = number of tokens written (all cache slots with idx < min(t, W) valid —
    ring layout guarantees slots [0, min(t,W)) are occupied)."""
    B, H, h = q.shape
    W, K = k_cache.shape[1], k_cache.shape[2]
    G = H // K
    scale = h ** -0.5

    if mesh is not None:
        w_part = "model" if strategy == "wseq" else None
        kv_part = "model" if strategy == "kv" else None
        cache_spec = P(batch_part, w_part, kv_part, None)
        k_cache = mesh.constrain(k_cache, cache_spec)
        v_cache = mesh.constrain(v_cache, cache_spec)

    qg = q.reshape(B, K, G, h).astype(jnp.float32)
    s = jnp.einsum("bkgh,bwkh->bkgw", qg, k_cache.astype(jnp.float32)) * scale
    t = jnp.asarray(t)
    lim = jnp.minimum(t, W)
    if lim.ndim:                      # per-request positions [B]
        lim = lim[:, None, None, None]
    valid = jnp.arange(W)[None, None, None, :] < lim
    s = jnp.where(valid, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgw,bwkh->bkgh", p.astype(v_cache.dtype), v_cache)
    return out.reshape(B, H, h)


# ----------------------------------------------------------------------
# QuantPlane: int8 arena payloads + the f32 scale plane.
#
# Sealed (full) blocks store K/V as int8 with per-block, PER-CHANNEL f32
# scales [N, K, h] (kscale/vscale); the partial tail block's tokens carry
# per-token, per-kv-head SCALAR scales [N, K, bs] (ktok/vtok), assigned
# once when the token is appended. The per-token scale is a pure function
# of the single token and the per-channel seal scale a pure function of
# the block's stored int payload, so the arena bytes are independent of
# how writes were grouped into chunks/windows — the bit-identity contracts
# (chunked prefill vs store-resume vs verify commits vs fault replay) ride
# on exactly this grouping independence. Convention: a nonzero kscale row
# marks the block sealed; dequantization is the single elementwise rule
# `q * where(scale != 0, scale, tok)` which is exact in every edge case
# (zero channels of sealed blocks have q == 0, scrubbed blocks dequantize
# to 0) and needs no residency context.


def quant_tokens(x):
    """Per-token provisional int8 quantization (the unsealed tail format).

    x [..., h] → (q int8 [..., h], ts f32 [...]): ts = absmax(token)/127
    per (token, kv head); q = round(x/ts) clipped to ±127. Zero tokens get
    ts = 0 with q = 0 (the dequant rule multiplies by the stored 0)."""
    x = jnp.asarray(x, jnp.float32)
    ts = jnp.abs(x).max(axis=-1) / 127.0
    safe = jnp.where(ts > 0, ts, 1.0)
    q = jnp.clip(jnp.round(x / safe[..., None]), -127, 127).astype(jnp.int8)
    return q, ts


def quant_effective_scale(scale, tok):
    """Elementwise dequant scale [..., bs, h] from the per-channel seal
    plane `scale` [..., h] and the per-token plane `tok` [..., bs]: sealed
    blocks (nonzero scale row) use the channel scale, unsealed content the
    token scalar."""
    return jnp.where(scale[..., None, :] != 0, scale[..., None, :],
                     tok[..., None])


def dequant_pages(pages, scale, tok):
    """int8 payload [..., bs, h] → f32 content, via the elementwise rule."""
    return pages.astype(jnp.float32) * quant_effective_scale(scale, tok)


def dequant_gather(pages, scale, tok, tables):
    """Gather tabled blocks and dequantize → linear [B, nb·bs, K, h] f32
    (the quant twin of the `k_pages[tables]` gathers below)."""
    B, nb = tables.shape
    K, bs, h = pages.shape[-3:]
    g = dequant_pages(pages[tables], scale[tables], tok[tables])
    return g.transpose(0, 1, 3, 2, 4).reshape(B, nb * bs, K, h)


def seal_blocks(pages, scale, tok, blocks, do_seal, *, stacked=False):
    """Seal freshly-filled arena blocks: re-quantize each block's stored
    per-token payload with per-block, per-channel scales and zero its
    per-token row. pages int8 [n_rep?, N, K, bs, h]; scale [n_rep?, N, K, h];
    tok [n_rep?, N, K, bs]; blocks [M] physical ids; do_seal [M] bool.

    Non-sealing rows are redirected to the null block 0 and write back its
    gathered content unchanged (whole-block scatters must keep real-block
    targets unique — the duplicate-scatter determinism rule); the null
    block itself is never sealed. Sealing is a pure function of the stored
    (int8, per-token scale) payload, so it lands the same bytes no matter
    which write grouping filled the block."""
    blocks = jnp.asarray(blocks, jnp.int32)
    do_seal = jnp.asarray(do_seal, bool) & (blocks != 0)
    tgt = jnp.where(do_seal, blocks, 0)
    ix = (slice(None), tgt) if stacked else tgt
    praw = pages[ix]                               # [R?, M, K, bs, h] int8
    ts = tok[ix]                                   # [R?, M, K, bs]
    deq = praw.astype(jnp.float32) * ts[..., None]
    sc = jnp.abs(deq).max(axis=-2) / 127.0         # [R?, M, K, h]
    safe = jnp.where(sc > 0, sc, 1.0)
    q2 = jnp.clip(jnp.round(deq / safe[..., None, :]), -127, 127) \
        .astype(jnp.int8)
    lead = (1,) if stacked else ()
    m5 = do_seal.reshape(lead + (-1, 1, 1, 1))
    m4 = do_seal.reshape(lead + (-1, 1, 1))
    return (pages.at[ix].set(jnp.where(m5, q2, praw)),
            scale.at[ix].set(jnp.where(m4, sc, scale[ix])),
            tok.at[ix].set(jnp.where(m4, 0.0, ts)))


def quant_paged_cache_write(entry, k_new, v_new, blk, off):
    """Decode append into an int8 arena entry (the quant twin of
    `paged_cache_write` + the scale-plane maintenance it implies).

    entry holds {"k","v"} int8 arenas plus {"kscale","vscale","ktok",
    "vtok"}; k_new/v_new [B, K, h] f32; blk/off [B]. Three scatters in
    order: (1) UNSEAL any block receiving its in-block offset-0 token —
    clearing the per-channel scale a prior owner may have sealed in
    (reallocated blocks are not scrubbed; without this the dequant rule
    would read the stale seal scale over the new owner's per-token
    payload); (2) write the per-token quantized payload + its scale;
    (3) SEAL blocks whose last slot (off == bs-1) just landed. Returns the
    six updated quant leaves."""
    bs = entry["k"].shape[-2]
    K = entry["k"].shape[-3]
    kq, kts = quant_tokens(k_new)
    vq, vts = quant_tokens(v_new)
    ub = jnp.where(off == 0, blk, 0)
    ksc = entry["kscale"].at[ub].set(0.0)
    vsc = entry["vscale"].at[ub].set(0.0)
    kp, vp = paged_cache_write(entry["k"], entry["v"], kq, vq, blk, off)
    ki = jnp.arange(K)[None, :]
    ktk = entry["ktok"].at[blk[:, None], ki, off[:, None]].set(kts)
    vtk = entry["vtok"].at[blk[:, None], ki, off[:, None]].set(vts)
    do_seal = off == bs - 1
    kp, ksc, ktk = seal_blocks(kp, ksc, ktk, blk, do_seal)
    vp, vsc, vtk = seal_blocks(vp, vsc, vtk, blk, do_seal)
    return {"k": kp, "v": vp, "kscale": ksc, "vscale": vsc,
            "ktok": ktk, "vtok": vtk}


def quant_paged_prefill_write(entry, k_new, v_new, tables, off, chunk_len):
    """Chunk scatter into an int8 arena entry (quant twin of
    `paged_prefill_write`): per-token quantize the chunk [1, S, K, h],
    unseal blocks the chunk opens (first token at in-block offset 0), land
    payload + per-token scales, then seal every block whose last slot the
    chunk covered. Padded tail rows are redirected to the null block."""
    B, S, K, h = k_new.shape
    bs = entry["k"].shape[-2]
    nb = tables.shape[1]
    pos = jnp.asarray(off, jnp.int32) + jnp.arange(S)
    valid = jnp.arange(S) < jnp.asarray(chunk_len, jnp.int32)
    blk = jnp.where(valid, tables[0, jnp.clip(pos // bs, 0, nb - 1)], 0)
    offi = pos % bs
    kq, kts = quant_tokens(k_new[0])               # [S, K, h], [S, K]
    vq, vts = quant_tokens(v_new[0])
    ub = jnp.where(valid & (offi == 0), blk, 0)
    ksc = entry["kscale"].at[ub].set(0.0)
    vsc = entry["vscale"].at[ub].set(0.0)
    ki = jnp.arange(K)[None, :]
    kp = entry["k"].at[blk[:, None], ki, offi[:, None]].set(kq)
    vp = entry["v"].at[blk[:, None], ki, offi[:, None]].set(vq)
    ktk = entry["ktok"].at[blk[:, None], ki, offi[:, None]].set(kts)
    vtk = entry["vtok"].at[blk[:, None], ki, offi[:, None]].set(vts)
    do_seal = valid & (offi == bs - 1)
    kp, ksc, ktk = seal_blocks(kp, ksc, ktk, blk, do_seal)
    vp, vsc, vtk = seal_blocks(vp, vsc, vtk, blk, do_seal)
    return {"k": kp, "v": vp, "kscale": ksc, "vscale": vsc,
            "ktok": ktk, "vtok": vtk}


def quant_paged_cache_write_tokens(entry, k_new, v_new, blk, off):
    """Per-sequence token-WINDOW scatter into an int8 arena entry (quant
    twin of `paged_cache_write_tokens` — the speculative-verify commit).
    blk/off [B, S]; rejected/idle rows arrive already redirected to the
    null block, so rollback stays the absence of a write; unseal/seal
    follow the same offset-0 / offset-(bs-1) rules as the append path."""
    B, S, K, h = k_new.shape
    bs = entry["k"].shape[-2]
    kq, kts = quant_tokens(k_new)                  # [B, S, K, h], [B, S, K]
    vq, vts = quant_tokens(v_new)
    ub = jnp.where(off == 0, blk, 0).reshape(-1)
    ksc = entry["kscale"].at[ub].set(0.0)
    vsc = entry["vscale"].at[ub].set(0.0)
    ki = jnp.arange(K)[None, None, :]
    kp = entry["k"].at[blk[:, :, None], ki, off[:, :, None]].set(kq)
    vp = entry["v"].at[blk[:, :, None], ki, off[:, :, None]].set(vq)
    ktk = entry["ktok"].at[blk[:, :, None], ki, off[:, :, None]].set(kts)
    vtk = entry["vtok"].at[blk[:, :, None], ki, off[:, :, None]].set(vts)
    flat_b = blk.reshape(-1)
    do_seal = (off == bs - 1).reshape(-1)
    kp, ksc, ktk = seal_blocks(kp, ksc, ktk, flat_b, do_seal)
    vp, vsc, vtk = seal_blocks(vp, vsc, vtk, flat_b, do_seal)
    return {"k": kp, "v": vp, "kscale": ksc, "vscale": vsc,
            "ktok": ktk, "vtok": vtk}


# ----------------------------------------------------------------------
def paged_decode_attention(q, k_pages, v_pages, tables, lens, *,
                           k_scale=None, k_tok=None, v_scale=None,
                           v_tok=None):
    """Single-token attention over physically paged KV (pure-jnp path).

    q [B, H, h]; arenas [N, K, bs, h] (kv-head-major blocks); tables [B, nb]
    physical block ids; lens [B] = resident logical slots (t+1 once the
    current token's K/V is written; min(t+1, W) for ring layers). Gathers
    the tabled blocks into a linear [B, nb·bs, K, h] view (non-resident
    entries alias the null block and are masked by `lens`) and reuses the
    dense masked-softmax decode math. With the scale-plane kwargs the
    arenas are int8 and each gathered tile is dequantized in-register
    (quant_effective_scale) — no dequantized arena copy exists outside the
    gathered view. The Pallas kernel additionally skips compute for blocks
    past `lens` — this fallback pays the full gather.
    """
    B = q.shape[0]
    nb = tables.shape[1]
    bs, h = k_pages.shape[2], k_pages.shape[3]
    K = k_pages.shape[1]
    if k_scale is not None:
        k_lin = dequant_gather(k_pages, k_scale, k_tok, tables)
        v_lin = dequant_gather(v_pages, v_scale, v_tok, tables)
    else:
        k_lin = k_pages[tables].transpose(0, 1, 3, 2, 4) \
            .reshape(B, nb * bs, K, h)
        v_lin = v_pages[tables].transpose(0, 1, 3, 2, 4) \
            .reshape(B, nb * bs, K, h)
    return decode_attention(q, k_lin, v_lin, lens)


def paged_prefill_attention(q, k_new, v_new, k_pages, v_pages, tables, off,
                            chunk_len, *, mask_window: int = 0,
                            mask_sink: int = 0, k_scale=None, k_tok=None,
                            v_scale=None, v_tok=None):
    """Chunked-prefill attention over paged history (pure-jnp path).

    q [B,S,H,h] is one prompt chunk at absolute positions off + arange(S)
    (only the first chunk_len rows real); k_new/v_new [B,S,K,h] are its
    keys; the prompt's history (tokens < off) lives in arena blocks
    [N,K,bs,h] mapped by tables [B,nb]. Queries attend resident history
    slots plus causal in-chunk keys, optionally under the sink+window
    sparse mask (mask_window=0 → dense causal). Non-resident table entries
    alias the null block and are masked by off. Quantized arenas (the
    scale-plane kwargs) dequantize only the gathered HISTORY tiles — the
    chunk's in-flight k_new/v_new stay f32. The Pallas kernel
    (kernels/paged_prefill.py) additionally skips compute for blocks past
    the residency — this fallback pays the full gather.
    """
    B, S, H, h = q.shape
    K = k_new.shape[2]
    G = H // K
    nb = tables.shape[1]
    bs = k_pages.shape[2]
    L = nb * bs
    f32 = jnp.float32
    off = jnp.broadcast_to(jnp.asarray(off, jnp.int32), (B,))
    cl = jnp.broadcast_to(jnp.asarray(chunk_len, jnp.int32), (B,))
    if k_scale is not None:
        k_hist = dequant_gather(k_pages, k_scale, k_tok, tables)
        v_hist = dequant_gather(v_pages, v_scale, v_tok, tables)
    else:
        k_hist = k_pages[tables].transpose(0, 1, 3, 2, 4).reshape(B, L, K, h)
        v_hist = v_pages[tables].transpose(0, 1, 3, 2, 4).reshape(B, L, K, h)
    pos = off[:, None] + jnp.arange(S)[None]                 # [B, S] q pos
    tok = jnp.concatenate(
        [jnp.broadcast_to(jnp.arange(L)[None], (B, L)), pos], axis=1)
    res = jnp.concatenate([jnp.arange(L)[None] < off[:, None],
                           jnp.arange(S)[None] < cl[:, None]], axis=1)

    ok = tok[:, None, :] <= pos[:, :, None]
    if mask_window > 0:
        win = (pos[:, :, None] - tok[:, None, :]) < mask_window
        if mask_sink > 0:
            win |= (tok < mask_sink)[:, None, :]
        ok &= win
    mask = res[:, None, :] & ok                              # [B, S, L+S]

    qg = q.reshape(B, S, K, G, h).astype(f32)
    k_all = jnp.concatenate([k_hist, k_new], axis=1).astype(f32)
    v_all = jnp.concatenate([v_hist, v_new], axis=1).astype(f32)
    s = jnp.einsum("bskgh,btkh->bskgt", qg, k_all) * (h ** -0.5)
    s = jnp.where(mask[:, :, None, None, :], s, jnp.asarray(NEG_INF, f32))
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bskgt,btkh->bskgh", p, v_all)
    return out.reshape(B, S, H, h).astype(q.dtype)


def paged_prefill_write(k_pages, v_pages, k_new, v_new, tables, off,
                        chunk_len):
    """Scatter one chunk's K/V [B,S,K,h] (B == 1) into arena blocks.

    Chunk token i lands at absolute position off + i → physical block
    tables[0, (off+i)//bs] at in-block offset (off+i) % bs; padded tail
    rows (i >= chunk_len) are redirected to the null block 0, where
    clobbering is harmless (null contents are masked everywhere).
    """
    B, S, K, h = k_new.shape
    bs = k_pages.shape[2]
    nb = tables.shape[1]
    pos = jnp.asarray(off, jnp.int32) + jnp.arange(S)
    valid = jnp.arange(S) < jnp.asarray(chunk_len, jnp.int32)
    blk = jnp.where(valid, tables[0, jnp.clip(pos // bs, 0, nb - 1)], 0)
    offi = pos % bs
    ki = jnp.arange(K)[None, :]
    k_pages = k_pages.at[blk[:, None], ki, offi[:, None]].set(
        k_new[0].astype(k_pages.dtype))
    v_pages = v_pages.at[blk[:, None], ki, offi[:, None]].set(
        v_new[0].astype(v_pages.dtype))
    return k_pages, v_pages


def update_block_summaries(kmin, kmax, kmean, k_pages, blocks, *,
                           stacked=False, k_scale=None, k_tok=None):
    """Recompute the per-block key summaries for the (just-written) blocks.

    kmin/kmax/kmean [N, K, h] float32 side arrays of a [N, K, bs, h] key
    arena (with a leading n_rep axis on both when `stacked` — the scan-
    stacked period arenas); blocks [M] physical block ids — duplicates are
    fine (every duplicate recomputes the same value from the same updated
    arena content, so scatter order does not matter). Summaries are exact
    whole-block reductions: unwritten slots hold zeros, which only widen
    the [kmin, kmax] interval, so the Quest upper bound stays valid for
    partially filled blocks (and the null block 0, a frequent redirect
    target, is harmlessly re-summarized). Quantized arenas pass the scale
    plane (k_scale/k_tok): summaries reduce the DEQUANTIZED content, so
    kmin/kmax keep bounding exactly what attention will read and the Quest
    bound stays valid with zero quant-specific scoring code. This is the
    ONLY reduction implementing the summary semantics — every write site
    (prefill chunk, decode append, dense-scatter admission) must go
    through it so the zero-stale-summary (and zero-stale-scale) invariant
    cannot diverge between paths.
    """
    blocks = jnp.asarray(blocks, jnp.int32)
    if stacked:
        k = k_pages[:, blocks].astype(jnp.float32)       # [R, M, K, bs, h]
        if k_scale is not None:
            k = k * quant_effective_scale(k_scale[:, blocks],
                                          k_tok[:, blocks])
        ix = (slice(None), blocks)
    else:
        k = k_pages[blocks].astype(jnp.float32)          # [M, K, bs, h]
        if k_scale is not None:
            k = k * quant_effective_scale(k_scale[blocks], k_tok[blocks])
        ix = blocks
    return (kmin.at[ix].set(k.min(axis=-2)),
            kmax.at[ix].set(k.max(axis=-2)),
            kmean.at[ix].set(k.mean(axis=-2)))


def block_topk_scores(q, kmin, kmax, tables, lens, *, block_size):
    """Quest-style upper-bound block scores (pure-jnp path).

    q [B, H, h]; kmin/kmax [N, K, h]; tables [B, nb] physical block ids;
    lens [B] resident logical slots → scores [B, nb] f32: the channel-wise
    upper bound on any key dot-product inside the block, maxed over (kv
    head, query head); NEG_INF for blocks whose logical slot range starts
    at or past lens (their table entries alias the null block). The Pallas
    kernel (kernels/block_topk.py) DMAs only the tabled [K, h] summary
    tiles — this fallback pays the full gather.
    """
    B, H, h = q.shape
    K = kmin.shape[1]
    G = H // K
    nb = tables.shape[1]
    lo = kmin[tables].astype(jnp.float32)                # [B, nb, K, h]
    hi = kmax[tables].astype(jnp.float32)
    qg = q.reshape(B, K, G, h).astype(jnp.float32)[:, None]
    ub = jnp.maximum(qg * lo[:, :, :, None, :],
                     qg * hi[:, :, :, None, :]).sum(-1)  # [B, nb, K, G]
    s = ub.max(axis=(2, 3))
    resident = (jnp.arange(nb)[None] * block_size) < lens[:, None]
    return jnp.where(resident, s, NEG_INF)


def select_kv_blocks(scores, tables, lens, *, block_size, k_static,
                     frac=0.0, sink_blocks=1, recent_blocks=2):
    """Per-slot top-k block selection → a COMPACTED block table.

    scores [B, nb] upper-bound block scores (NEG_INF past residency);
    tables [B, nb]; lens [B] resident logical slots. Selects up to
    `k_static` resident blocks per slot — sink blocks (logical j <
    sink_blocks) and the most recent `recent_blocks` (always including the
    partial tail) are force-kept, the rest ranked by score. With `frac > 0`
    the per-slot budget is ceil(frac · resident_blocks) (floored at the
    keeps), so the budget tracks each slot's own context; `frac == 0` uses
    the absolute `k_static`. Budgets ≥ the resident count degrade to exact
    attention: every resident block is kept in logical order and the
    output equals the input table bit-for-bit.

    Selected blocks land in the compacted table in LOGICAL ORDER (ascending
    sort), so all entries but the last are full blocks and the tail keeps
    its partial fill — `new_lens = (m-1)·bs + tail_fill` makes the
    unmodified ``paged_decode`` occupancy masking correct on the compacted
    view. Unused entries point at the null block 0.

    Returns (new_tables [B, k_static], new_lens [B], m [B] selected block
    counts, selected [B, nb] bool mask over the ORIGINAL logical blocks).
    """
    B, nb = tables.shape
    lens = jnp.asarray(lens, jnp.int32)
    n_res = (lens + block_size - 1) // block_size        # [B] ≥ 1 in decode
    j = jnp.arange(nb)
    resident = j[None] < n_res[:, None]
    keep = resident & ((j[None] < sink_blocks)
                       | (j[None] >= (n_res - recent_blocks)[:, None]))
    adj = jnp.where(keep, jnp.inf, jnp.where(resident, scores, -jnp.inf))
    _, idx = jax.lax.top_k(adj, k_static)                # [B, k_static]
    if frac > 0:
        k_b = jnp.ceil(frac * n_res).astype(jnp.int32)
        k_b = jnp.maximum(k_b, sink_blocks + recent_blocks)
    else:
        k_b = jnp.full_like(n_res, k_static)
    k_b = jnp.minimum(k_b, n_res)                        # degrade: keep all
    sel = (jnp.arange(k_static)[None] < k_b[:, None]) \
        & jnp.take_along_axis(resident, idx, 1)
    sidx = jnp.sort(jnp.where(sel, idx, nb), axis=1)     # ascending, pad→nb
    gat = jnp.take_along_axis(tables, jnp.minimum(sidx, nb - 1), 1)
    new_tables = jnp.where(sidx < nb, gat, 0)
    m = sel.sum(axis=1)
    tail_fill = lens - (n_res - 1) * block_size
    new_lens = jnp.maximum(m - 1, 0) * block_size + tail_fill
    selected = jnp.zeros((B, nb), bool) \
        .at[jnp.arange(B)[:, None], idx].set(sel)        # idx rows distinct
    return new_tables, new_lens, m, selected


def selected_attention_mass(q, k_pages, tables, lens, selected, *,
                            k_scale=None, k_tok=None):
    """Exact attention mass the selected blocks capture, per slot.

    q [B, H, h]; k_pages [N, K, bs, h]; tables/selected [B, nb] over the
    ORIGINAL logical blocks; lens [B] resident slots. Computes the full
    resident softmax (the dense-fallback gather — this is a diagnostics
    pass, gated by `omniattn.topk_measure_mass`) and sums the probability
    landing in selected blocks, averaged over heads → [B] in [0, 1].
    Quantized arenas pass the key scale plane so the mass is measured over
    the content attention actually reads.
    """
    B, H, h = q.shape
    K, bs = k_pages.shape[1], k_pages.shape[2]
    G = H // K
    nb = tables.shape[1]
    if k_scale is not None:
        k_lin = dequant_gather(k_pages, k_scale, k_tok, tables)
    else:
        k_lin = k_pages[tables].transpose(0, 1, 3, 2, 4) \
            .reshape(B, nb * bs, K, h).astype(jnp.float32)
    qg = q.reshape(B, K, G, h).astype(jnp.float32)
    s = jnp.einsum("bkgh,bwkh->bkgw", qg, k_lin) * (h ** -0.5)
    valid = jnp.arange(nb * bs)[None] < lens[:, None]
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    slot_sel = jnp.repeat(selected, bs, axis=1)          # [B, nb*bs]
    return (p * slot_sel[:, None, None, :]).sum(-1).mean(axis=(1, 2))


def paged_cache_write(k_pages, v_pages, k_new, v_new, blk, off):
    """Write one token's K/V per sequence into arena blocks.

    arenas [N, K, bs, h]; k_new/v_new [B, K, h]; blk/off [B] physical block
    id and in-block offset. Distinct live sequences always target distinct
    blocks (append-only block ownership); freed slots are redirected to the
    null block by the caller, where duplicate writes are harmless.
    """
    K = k_pages.shape[1]
    ki = jnp.arange(K)[None, :]
    k_pages = k_pages.at[blk[:, None], ki, off[:, None]].set(
        k_new.astype(k_pages.dtype))
    v_pages = v_pages.at[blk[:, None], ki, off[:, None]].set(
        v_new.astype(v_pages.dtype))
    return k_pages, v_pages


def paged_cache_write_tokens(k_pages, v_pages, k_new, v_new, blk, off):
    """Write a per-sequence token WINDOW into arena blocks.

    arenas [N, K, bs, h]; k_new/v_new [B, S, K, h] (S window rows per
    sequence); blk/off [B, S] physical block id and in-block offset per row.
    The speculative-verify commit: the caller redirects rejected/padded rows
    to the null block 0, so only the accepted prefix ever lands in a real
    block — rollback is the absence of a write, never an undo. Distinct live
    sequences own distinct blocks and a window's rows occupy distinct
    (block, offset) slots, so scatter order is irrelevant outside null.
    """
    K = k_pages.shape[1]
    ki = jnp.arange(K)[None, None, :]
    k_pages = k_pages.at[blk[:, :, None], ki, off[:, :, None]].set(
        k_new.astype(k_pages.dtype))
    v_pages = v_pages.at[blk[:, :, None], ki, off[:, :, None]].set(
        v_new.astype(v_pages.dtype))
    return k_pages, v_pages


def paged_cache_write_tokens_masked(k_pages, v_pages, k_new, v_new, blk, off,
                                    write):
    """`paged_cache_write_tokens` for arenas WITHOUT a null block (the ring
    arenas): rows with write[b,s] False write back the slot's CURRENT
    content (gather-then-where, the `prefill_resume_attention` idiom), so a
    rejected draft row is a bit-exact no-op on its target slot. Callers must
    keep each sequence's masked-in rows on distinct (blk, off) slots."""
    K = k_pages.shape[1]
    ki = jnp.arange(K)[None, None, :]
    bi = blk[:, :, None]
    oi = off[:, :, None]
    cur_k = k_pages[bi, ki, oi]                          # [B, S, K, h]
    cur_v = v_pages[bi, ki, oi]
    wm = write[:, :, None, None]
    k_wr = jnp.where(wm, k_new.astype(k_pages.dtype), cur_k)
    v_wr = jnp.where(wm, v_new.astype(v_pages.dtype), cur_v)
    k_pages = k_pages.at[bi, ki, oi].set(k_wr)
    v_pages = v_pages.at[bi, ki, oi].set(v_wr)
    return k_pages, v_pages


def ring_slot(t, sink: int, recent: int):
    """Cache slot for the token written at absolute position t (sink+ring)."""
    W = sink + recent
    return jnp.where(t < W, t, sink + (t - sink) % recent)


def cache_write(k_cache, v_cache, k_new, v_new, t, *, sink: int = 0, recent: int = 0):
    """Write one token's K/V at position t (scalar, or [B] per-request).
    Full cache when sink==recent==0 (slot=t), else sink+recent ring layout."""
    t = jnp.asarray(t)
    if sink or recent:
        idx = ring_slot(t, sink, recent)
    else:
        idx = t
    if t.ndim:                        # per-request write positions
        b = jnp.arange(k_cache.shape[0])
        k_cache = k_cache.at[b, idx].set(k_new)
        v_cache = v_cache.at[b, idx].set(v_new)
        return k_cache, v_cache
    k_cache = jax.lax.dynamic_update_slice_in_dim(k_cache, k_new[:, None], idx, axis=1)
    v_cache = jax.lax.dynamic_update_slice_in_dim(v_cache, v_new[:, None], idx, axis=1)
    return k_cache, v_cache


def resident_token_positions(W: int, off, *, sink: int, recent: int):
    """Token position resident at each cache slot after `off` tokens written.

    Full cache (sink==recent==0): slot j holds token j iff j < off. Ring
    layout (cache_write): slots < sink are immutable sink tokens; ring slot
    j ≥ sink hosts the residue class {j, j+recent, j+2·recent, ...} and the
    resident token is the largest class member < off.

    Returns (tok_pos [W] int32, resident [W] bool).
    """
    j = jnp.arange(W, dtype=jnp.int32)
    off = jnp.asarray(off, jnp.int32)
    if sink or recent:
        wraps = jnp.maximum((off - 1 - j) // recent, 0)
        tok = jnp.where(j < sink, j, j + wraps * recent)
    else:
        tok = j
    return tok, tok < off


def spec_verify_ring_attention(q, k_new, v_new, k_cache, v_cache, positions,
                               *, sink: int, recent: int):
    """Read-only speculative-verify attention over a ring (sink+recent) cache.

    q [B,S,H,h] is each slot's draft window at absolute positions [B,S]
    (row i of slot b at positions[b, 0] + i); k_new/v_new [B,S,K,h] are the
    window's rope'd keys; caches [B,W,K,h] hold the FROZEN ring history —
    tokens < positions[:, 0], each ring slot its residue class's largest
    member below the window. Nothing is written: the accepted prefix is
    committed afterwards by `paged_cache_write_tokens_masked`.

    Mask equivalence with baseline ring decode: single-token decode writes
    position t into its ring slot and attends every occupied slot, so the
    resident set at t is exactly { tok : t - tok < recent } ∪ sink — the
    ring's physical eviction IS the window. A verify query row at position
    p = off + i must therefore drop any frozen token with p - tok ≥ recent:
    its evicting class member tok + recent lies in [off, p], i.e. it is an
    in-window key this row attends instead. In-window keys themselves are
    always within the window (i < S ≤ recent — callers keep the draft
    window no longer than the smallest ring, see `chunked_prefill_support`)
    so they take only the causal mask. Padded draft rows need no masking:
    a padded key j is attended only by query rows i ≥ j, which are
    themselves padding whose outputs the acceptance rule never reads.
    """
    B, S, H, h = q.shape
    K = k_new.shape[2]
    G = H // K
    W = k_cache.shape[1]
    scale = h ** -0.5
    f32 = jnp.float32
    pos = jnp.asarray(positions, jnp.int32)              # [B, S]
    off = pos[:, 0]
    # per-slot resident map (the [B]-batched resident_token_positions)
    j = jnp.arange(W, dtype=jnp.int32)[None]             # [1, W]
    if sink or recent:
        wraps = jnp.maximum((off[:, None] - 1 - j) // recent, 0)
        tok = jnp.where(j < sink, j, j + wraps * recent)
    else:
        tok = jnp.broadcast_to(j, (B, W))
    res = tok < off[:, None]                             # [B, W]

    def allowed(p, t):
        ok = t <= p
        if recent > 0:
            ok &= ((p - t) < recent) | (t < sink)
        return ok

    m_old = res[:, None, :] & allowed(pos[:, :, None], tok[:, None, :])
    m_new = allowed(pos[:, :, None], pos[:, None, :])    # causal in-window
    qg = q.reshape(B, S, K, G, h).astype(f32)
    s_old = jnp.einsum("bskgh,bwkh->bskgw", qg,
                       k_cache.astype(f32)) * scale
    s_old = jnp.where(m_old[:, :, None, None, :], s_old,
                      jnp.asarray(NEG_INF, f32))
    s_new = jnp.einsum("bskgh,bukh->bskgu", qg, k_new.astype(f32)) * scale
    s_new = jnp.where(m_new[:, :, None, None, :], s_new,
                      jnp.asarray(NEG_INF, f32))
    p_att = jax.nn.softmax(jnp.concatenate([s_old, s_new], axis=-1), axis=-1)
    v_all = jnp.concatenate([v_cache.astype(f32), v_new.astype(f32)], axis=1)
    out = jnp.einsum("bskgw,bwkh->bskgh", p_att, v_all)
    return out.reshape(B, S, H, h).astype(q.dtype)


def prefill_resume_attention(q, k_new, v_new, k_cache, v_cache, positions, *,
                             chunk_len, sink: int, recent: int,
                             mask_window: int = 0, mask_sink: int = 0,
                             attend_limit: int = 0):
    """Exact continuation-prefill attention for one chunk.

    q [B,S,H,h], k_new/v_new [B,S,K,h] at absolute `positions` [S]
    (= off + arange(S)); caches [B,W,K,h] hold tokens < off. Queries attend
    resident cache tokens plus causal in-chunk keys, optionally under a
    sink+window sparsity mask (mask_window=0 → dense causal). Only the first
    `chunk_len` chunk rows are real: padded tail queries produce garbage
    outputs (callers must ignore them) and padded keys are neither attended
    nor written. The chunk is scattered into the cache at linear slots when
    sink==recent==0, else at ring slots — callers must keep S ≤ recent for
    ring caches so in-chunk slots stay distinct.

    attend_limit (static, full layout only): a known upper bound on off —
    scores are computed against cache[:, :attend_limit] instead of the whole
    allocation, so early chunks pay O(prefix), not O(max_len).

    Returns (out [B,S,H,h], k_cache', v_cache').
    """
    B, S, H, h = q.shape
    K = k_new.shape[2]
    G = H // K
    k_att, v_att = k_cache, v_cache
    if attend_limit and not (sink or recent):
        lim = min(attend_limit, k_cache.shape[1])
        k_att, v_att = k_cache[:, :lim], v_cache[:, :lim]
    W = k_att.shape[1]
    scale = h ** -0.5
    f32 = jnp.float32
    pos = jnp.asarray(positions, jnp.int32)
    off = pos[0]
    cl = jnp.asarray(chunk_len, jnp.int32)
    valid_q = jnp.arange(S) < cl

    def allowed(p, t):
        ok = t <= p
        if mask_window > 0:
            ok &= ((p - t) < mask_window) | (t < mask_sink)
        return ok

    tok_old, res_old = resident_token_positions(W, off, sink=sink, recent=recent)
    qg = q.reshape(B, S, K, G, h).astype(f32)
    s_old = jnp.einsum("bskgh,bwkh->bskgw", qg, k_att.astype(f32)) * scale
    m_old = res_old[None, :] & allowed(pos[:, None], tok_old[None, :])
    s_old = jnp.where(m_old[None, :, None, None, :], s_old,
                      jnp.asarray(NEG_INF, f32))
    s_new = jnp.einsum("bskgh,bukh->bskgu", qg, k_new.astype(f32)) * scale
    m_new = allowed(pos[:, None], pos[None, :]) & valid_q[None, :]
    s_new = jnp.where(m_new[None, :, None, None, :], s_new,
                      jnp.asarray(NEG_INF, f32))

    p_att = jax.nn.softmax(jnp.concatenate([s_old, s_new], axis=-1), axis=-1)
    v_all = jnp.concatenate([v_att.astype(f32), v_new.astype(f32)], axis=1)
    out = jnp.einsum("bskgw,bwkh->bskgh", p_att, v_all)
    out = out.reshape(B, S, H, h).astype(q.dtype)

    slots = ring_slot(pos, sink, recent) if (sink or recent) else pos
    safe = jnp.clip(slots, 0, k_cache.shape[1] - 1)
    vq = valid_q[None, :, None, None]
    k_wr = jnp.where(vq, k_new.astype(k_cache.dtype),
                     jnp.take(k_cache, safe, axis=1))
    v_wr = jnp.where(vq, v_new.astype(v_cache.dtype),
                     jnp.take(v_cache, safe, axis=1))
    k_cache = k_cache.at[:, slots].set(k_wr, mode="drop")
    v_cache = v_cache.at[:, slots].set(v_wr, mode="drop")
    return out, k_cache, v_cache


def compress_prefill_kv(k, v, *, sink: int, recent: int, true_len=None):
    """Build a sink+recent ring cache from full prefill K/V [B, S, K, h].

    Ring layout: token i (i ≥ sink) lives at slot sink + (i - sink) % recent,
    so after a prefill of `true_len` tokens the ring holds the latest token of
    each residue class. true_len (traced scalar) supports right-padded
    prefill; defaults to S.
    """
    B, S, K, h = k.shape
    W = sink + recent
    if true_len is None and S <= W:
        pad = W - S
        kc = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        vc = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        return kc, vc
    tl = jnp.asarray(S if true_len is None else true_len, jnp.int32)
    j = jnp.arange(recent)
    base = sink + j
    n_wraps = jnp.maximum((tl - 1 - base) // recent, 0)
    p = jnp.clip(base + n_wraps * recent, 0, S - 1)       # token at ring slot j
    valid = (base < tl).astype(k.dtype)[None, :, None, None]
    ring_k = jnp.take(k, p, axis=1) * valid
    ring_v = jnp.take(v, p, axis=1) * valid
    sink_n = min(sink, S)
    sink_k = k[:, :sink_n]
    sink_v = v[:, :sink_n]
    if sink_n < sink:
        sink_k = jnp.pad(sink_k, ((0, 0), (0, sink - sink_n), (0, 0), (0, 0)))
        sink_v = jnp.pad(sink_v, ((0, 0), (0, sink - sink_n), (0, 0), (0, 0)))
    return (jnp.concatenate([sink_k, ring_k], axis=1),
            jnp.concatenate([sink_v, ring_v], axis=1))
