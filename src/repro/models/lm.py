"""Top-level model: embeddings, modality frontends (stubs), head, losses,
and the three lowered entry points (train_loss / prefill / decode)."""
from __future__ import annotations

from dataclasses import dataclass
from functools import cached_property
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.distributed.ctx import MeshCtx
from repro.models import moe as moe_mod
from repro.models import stack as stack_mod
from repro.models.common import ParamDef, cross_entropy, init_params, param_shapes, param_specs, rms_norm


@dataclass(frozen=True)
class LM:
    cfg: ModelConfig
    mesh: MeshCtx
    plan: stack_mod.StackPlan

    @staticmethod
    def build(cfg: ModelConfig, mesh: MeshCtx, pattern: Optional[list[int]] = None) -> "LM":
        return LM(cfg, mesh, stack_mod.StackPlan.from_config(cfg, pattern))

    # ------------------------------------------------------------------
    @cached_property
    def param_defs(self) -> dict:
        cfg = self.cfg
        fs = "data" if cfg.fsdp else None
        dt = cfg.param_dtype
        d = {"stack": stack_mod.stack_param_defs(cfg, self.mesh, self.plan),
             "final_norm": ParamDef((cfg.d_model,), P(None), dtype=dt, ones=True),
             "embed": ParamDef((cfg.vocab_size, cfg.d_model), P("model", fs),
                               scale=cfg.d_model ** -0.5, dtype=dt)}
        if not cfg.tie_embeddings:
            d["head"] = ParamDef((cfg.d_model, cfg.vocab_size), P(fs, "model"), dtype=dt)
        if cfg.frontend_dim:
            d["frontend"] = ParamDef((cfg.frontend_dim, cfg.d_model), P(None, None), dtype=dt)
        # pjit input shardings must divide exactly: drop non-divisible axes
        # (e.g. vocab 50280 or 504 on a 16-way model axis → replicate).
        d = jax.tree.map(
            lambda pd: ParamDef(pd.shape, self.mesh.sanitize_spec(pd.spec, pd.shape),
                                pd.scale, pd.dtype, pd.ones),
            d, is_leaf=lambda v: isinstance(v, ParamDef))
        return d

    def init(self, rng) -> dict:
        """Fresh params, laid out per `shardings()` on multi-device meshes
        (attention heads over `model`, MoE expert slots over `data`) so
        every downstream jit sees the canonical placement from step one."""
        params = init_params(self.param_defs, rng)
        if self.mesh.n_devices > 1:
            params = jax.device_put(params, self.shardings())
        return params

    def specs(self) -> dict:
        return param_specs(self.param_defs)

    def shapes(self) -> dict:
        return param_shapes(self.param_defs)

    def shardings(self):
        return self.mesh.tree_shardings(self.specs())

    # ------------------------------------------------------------------
    def default_tables(self) -> Optional[dict]:
        cfg = self.cfg
        if cfg.moe.n_experts == 0:
            return None
        s = moe_mod.default_slot_count(cfg, self.mesh.ep)
        placement = moe_mod.round_robin_placement(cfg.moe.n_experts, self.mesh.ep, s)
        return moe_mod.tables_from_placement(placement, s)

    def table_specs(self) -> Optional[dict]:
        if self.cfg.moe.n_experts == 0:
            return None
        return moe_mod.table_specs()

    # ------------------------------------------------------------------
    def _embed_inputs(self, params, batch, batch_part):
        cfg = self.cfg
        cd = jnp.dtype(cfg.compute_dtype)
        if cfg.family == "audio":
            x = batch["frames"].astype(cd) @ params["frontend"]
        elif cfg.family == "vlm":
            tok = jnp.take(params["embed"], batch["tokens"], axis=0)
            patch = batch["patches"].astype(cd) @ params["frontend"]
            x = jnp.concatenate([patch, tok], axis=1)
        else:
            x = jnp.take(params["embed"], batch["tokens"], axis=0)
        x = x.astype(cd)
        return self.mesh.constrain(x, P(batch_part, None, None))

    def _logits(self, params, x):
        cfg = self.cfg
        x = rms_norm(x, params["final_norm"], cfg.rms_eps)
        cd = jnp.dtype(cfg.compute_dtype)
        if cfg.tie_embeddings:
            logits = jnp.einsum("...d,vd->...v", x.astype(cd), params["embed"])
        else:
            logits = x.astype(cd) @ params["head"]
        return logits

    # ------------------------------------------------------------------
    def train_loss(self, params, batch, tables=None):
        """batch: tokens/frames/patches + labels [B,S] (+ optional mask).
        Returns (loss, aux)."""
        cfg = self.cfg
        B = batch["labels"].shape[0]
        bp = self.mesh.batch_part(B)
        x = self._embed_inputs(params, batch, bp)
        S = x.shape[1]
        positions = jnp.arange(S)
        x, _, aux = stack_mod.stack_apply(
            cfg, self.mesh, self.plan, params["stack"], x, mode="train",
            positions=positions, batch_part=bp, tables=tables)
        logits = self._logits(params, x)
        loss = cross_entropy(logits, batch["labels"], batch.get("mask"))
        return loss, aux

    def prefill(self, params, batch, *, max_len: int, tables=None,
                true_len=None):
        """Returns (cache, last_logits [B, V]). true_len (traced scalar)
        supports right-padded prompts: the cache and last-token logits are
        computed as if the sequence were true_len long."""
        cfg = self.cfg
        key = "frames" if cfg.family == "audio" else "tokens"
        B = batch[key].shape[0]
        bp = self.mesh.batch_part(B)
        x = self._embed_inputs(params, batch, bp)
        S = x.shape[1]
        positions = jnp.arange(S)
        mode = "train" if cfg.encoder_only else "prefill"
        x, cache, aux = stack_mod.stack_apply(
            cfg, self.mesh, self.plan, params["stack"], x, mode=mode,
            positions=positions, max_len=max_len, batch_part=bp, tables=tables,
            true_len=true_len)
        if cfg.encoder_only:
            return None, self._logits(params, x), aux
        if true_len is None:
            last = x[:, -1]
        else:
            last = jax.lax.dynamic_index_in_dim(x, true_len - 1, axis=1,
                                                keepdims=False)
        logits = self._logits(params, last)
        if true_len is not None and cache is not None:
            cache["pos"] = jnp.asarray(true_len, jnp.int32)
        return cache, logits, aux

    def prefill_resume(self, params, batch, cache, *, max_len: int,
                       tables=None, chunk_len=None, attend_limit: int = 0,
                       block_tables=None):
        """Continue prefill from an existing cache (chunked prefill / radix
        prefix-KV reuse). batch['tokens'] [B,S] is the next chunk, occupying
        absolute positions cache['pos'] + arange(S); chunk_len (traced scalar)
        marks the real rows of a right-padded final chunk. Returns
        (cache, logits-of-last-real-token [B,V], aux). A prefill from scratch
        is the degenerate case: a zero cache with pos=0 (alloc_cache).
        block_tables [1, nb] (optional) selects the physically paged prefill
        path: full-attention cache leaves are block arenas, the chunk's KV
        is written straight into the tabled blocks."""
        cfg = self.cfg
        tokens = batch["tokens"]
        B, S = tokens.shape
        bp = self.mesh.batch_part(B)
        cd = jnp.dtype(cfg.compute_dtype)
        x = jnp.take(params["embed"], tokens, axis=0).astype(cd)
        x = self.mesh.constrain(x, P(bp, None, None))
        off = jnp.asarray(cache["pos"], jnp.int32)
        positions = off + jnp.arange(S)
        cl = jnp.asarray(S if chunk_len is None else chunk_len, jnp.int32)
        x, new_cache, aux = stack_mod.stack_apply(
            cfg, self.mesh, self.plan, params["stack"], x, mode="prefill",
            positions=positions, caches=cache, max_len=max_len,
            batch_part=bp, tables=tables, true_len=cl,
            attend_limit=attend_limit, block_tables=block_tables)
        last = jax.lax.dynamic_index_in_dim(x, cl - 1, axis=1, keepdims=False)
        logits = self._logits(params, last)
        new_cache["pos"] = off + cl
        return new_cache, logits, aux

    @cached_property
    def chunked_prefill_support(self) -> tuple:
        """(supported, max_chunk_tokens). Chunked prefill is exact only when
        every attention layer's prefill mask needs no evicted keys: full
        layers always qualify; windowed layers ride their window ring;
        compressed (OmniAttn) layers qualify only under cfg.prefill_sparse
        (dense-prefill compressed layers attend tokens the ring has dropped).
        Ring scatter-writes additionally bound the chunk to the smallest
        ring so in-chunk slots stay distinct."""
        cfg = self.cfg
        if cfg.encoder_only or cfg.family in ("vlm", "audio"):
            return False, 0
        limit = 1 << 30
        for spec in self.plan.all_specs():
            if spec.kind != "attn":
                continue
            if spec.compressed and not cfg.prefill_sparse:
                return False, 0
            sink, recent = stack_mod.cache_window(cfg, spec)
            if sink or recent:
                limit = min(limit, recent)
        return True, limit

    def decode(self, params, cache, token, positions, tables=None,
               token_mask=None, block_tables=None):
        """token [B,1] int32; positions scalar or [B,1]. → (cache, logits [B,V]).
        token_mask [B] (optional) marks live rows — it weights the MoE
        activation counts AND the online-sparsity stats (inactive slots in
        a slot-dense batch would otherwise pollute both signals).
        block_tables [B, nb] (optional) selects the physically paged decode
        path: attention cache leaves are block arenas and reads gather only
        resident blocks — and, with cfg.omniattn.topk_* set, only the
        query-selected top-k of them (aux carries the per-layer
        period_sparsity/rem_sparsity stat vectors; see serving/sparsity.py)."""
        cfg = self.cfg
        B = token.shape[0]
        bp = self.mesh.batch_part(B)
        cd = jnp.dtype(cfg.compute_dtype)
        x = jnp.take(params["embed"], token, axis=0).astype(cd)
        x = self.mesh.constrain(x, P(bp, None, None))
        x, new_cache, aux = stack_mod.stack_apply(
            cfg, self.mesh, self.plan, params["stack"], x, mode="decode",
            positions=jnp.asarray(positions), caches=cache, batch_part=bp,
            tables=tables, token_mask=token_mask, block_tables=block_tables)
        logits = self._logits(params, x[:, 0])
        return new_cache, logits, aux

    def verify(self, params, cache, tokens, positions, tables=None,
               token_mask=None, block_tables=None):
        """Speculative multi-token verify: a READ-ONLY forward over each
        slot's draft window. tokens [B, S] = [current input token,
        draft_1..draft_{S-1}] per row; positions [B] = each slot's next
        write position (the same cursor the single-token decode step
        holds). Runs the stack over all S window positions against the
        paged caches WITHOUT writing any K/V — each attention layer stages
        its rope'd window keys instead — and returns (logits [B, S, V],
        staged, aux). Greedy-prefix acceptance and the masked commit
        (`verify_commit`) happen in the caller's jit, so a rejected draft
        never touches a block or its summary. token_mask [B] marks live
        slots; it is broadcast across the window for the MoE counters
        (moe_ffn's flat [B·S] row mask)."""
        cfg = self.cfg
        B, S = tokens.shape
        bp = self.mesh.batch_part(B)
        cd = jnp.dtype(cfg.compute_dtype)
        x = jnp.take(params["embed"], tokens, axis=0).astype(cd)
        x = self.mesh.constrain(x, P(bp, None, None))
        pos2 = jnp.asarray(positions, jnp.int32)[:, None] + \
            jnp.arange(S, dtype=jnp.int32)[None]
        tm = None if token_mask is None else jnp.repeat(token_mask, S)
        x, staged, aux = stack_mod.stack_apply(
            cfg, self.mesh, self.plan, params["stack"], x, mode="verify",
            positions=pos2, caches=cache, batch_part=bp, tables=tables,
            token_mask=tm, block_tables=block_tables)
        return self._logits(params, x), staged, aux

    def verify_commit(self, cache, staged, positions, n_write, block_tables):
        """Land the accepted prefix of a `verify` window — n_write [B] rows
        per slot — in the paged caches; see stack_verify_commit."""
        return stack_mod.stack_verify_commit(
            self.cfg, self.plan, cache, staged, positions, n_write,
            block_tables)
