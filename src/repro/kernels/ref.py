"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def flash_prefill_ref(q, k, v, *, causal=True, window=0, sink=0):
    """q/k/v [BH, S, h] → [BH, S, h]; dense softmax attention."""
    BH, S, h = q.shape
    s = jnp.einsum("bqh,bkh->bqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * (h ** -0.5)
    q_pos = jnp.arange(S)[:, None]
    k_pos = jnp.arange(S)[None, :]
    mask = jnp.ones((S, S), bool)
    if causal:
        mask &= k_pos <= q_pos
    if window > 0:
        in_win = (q_pos - k_pos) < window
        if sink > 0:
            in_win |= k_pos < sink
        mask &= in_win
    s = jnp.where(mask[None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkh->bqh", p, v.astype(jnp.float32)).astype(q.dtype)


def sink_decode_ref(q, k_cache, v_cache, t):
    """q [B,K,G,h]; caches [B,K,W,h]; t [B] → [B,K,G,h]."""
    B, K, G, h = q.shape
    W = k_cache.shape[2]
    s = jnp.einsum("bkgh,bkwh->bkgw", q.astype(jnp.float32),
                   k_cache.astype(jnp.float32)) * (h ** -0.5)
    occ = jnp.arange(W)[None, None, None, :] < t[:, None, None, None]
    s = jnp.where(occ, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bkgw,bkwh->bkgh", p,
                      v_cache.astype(jnp.float32)).astype(q.dtype)


def dequant_pages_ref(pages, scale, tok):
    """QuantPlane dequant oracle: int8 payload [..., K, bs, h] × the scale
    plane (per-block per-channel `scale` [..., K, h] for sealed blocks —
    nonzero row ⟺ sealed — or per-token scalar `tok` [..., K, bs] for
    unsealed tail content) → f32. The single elementwise rule every kernel
    tile implements: q * where(scale != 0, scale, tok)."""
    s = jnp.where(scale[..., None, :] != 0, scale[..., None, :],
                  tok[..., None])
    return pages.astype(jnp.float32) * s


def _maybe_dequant_gathered(pages_g, scale, tok, tables):
    if scale is None:
        return pages_g
    return dequant_pages_ref(pages_g, scale[tables], tok[tables])


def paged_decode_ref(q, k_pages, v_pages, tables, lens, *, k_scale=None,
                     k_tok=None, v_scale=None, v_tok=None):
    """q [B,K,G,h]; pages [N,K,bs,h]; tables [B,nb]; lens [B] → [B,K,G,h].
    Gather the pages into a linear [B,K,nb*bs,h] cache, then masked softmax
    attention over the first `lens` logical slots. Quantized arenas pass
    the scale plane (k_scale/v_scale [N,K,h], k_tok/v_tok [N,K,bs]); the
    gathered blocks dequantize through `dequant_pages_ref`."""
    B, K, G, h = q.shape
    nb = tables.shape[1]
    bs = k_pages.shape[2]
    kg = _maybe_dequant_gathered(k_pages[tables], k_scale, k_tok, tables)
    vg = _maybe_dequant_gathered(v_pages[tables], v_scale, v_tok, tables)
    k_lin = jnp.moveaxis(kg, 2, 1).reshape(B, K, nb * bs, h)
    v_lin = jnp.moveaxis(vg, 2, 1).reshape(B, K, nb * bs, h)
    return sink_decode_ref(q, k_lin, v_lin, lens)


def paged_prefill_ref(q, k_new, v_new, k_pages, v_pages, tables, off,
                      chunk_len, *, window=0, sink=0, k_scale=None,
                      k_tok=None, v_scale=None, v_tok=None):
    """q [B,K,S*G,h] (row r = chunk token r//G); k_new/v_new [B,K,S,h];
    pages [N,K,bs,h]; tables [B,nb]; off/chunk_len [B] → [B,K,S*G,h].
    Dense reference: gather the tabled history blocks into a linear cache,
    concatenate the chunk keys, and run one masked softmax — resident
    history (slot < off), valid chunk rows (< chunk_len), causal, and the
    optional sink+window sparse mask."""
    B, K, SG, h = q.shape
    S = k_new.shape[2]
    G = SG // S
    nb = tables.shape[1]
    bs = k_pages.shape[2]
    off = jnp.broadcast_to(jnp.asarray(off, jnp.int32), (B,))
    cl = jnp.broadcast_to(jnp.asarray(chunk_len, jnp.int32), (B,))
    kg = _maybe_dequant_gathered(k_pages[tables], k_scale, k_tok, tables)
    vg = _maybe_dequant_gathered(v_pages[tables], v_scale, v_tok, tables)
    k_hist = jnp.moveaxis(kg, 2, 1).reshape(B, K, nb * bs, h)
    v_hist = jnp.moveaxis(vg, 2, 1).reshape(B, K, nb * bs, h)
    k_all = jnp.concatenate([k_hist, k_new], axis=2).astype(jnp.float32)
    v_all = jnp.concatenate([v_hist, v_new], axis=2).astype(jnp.float32)
    tok_h = jnp.broadcast_to(jnp.arange(nb * bs)[None], (B, nb * bs))
    tok_c = off[:, None] + jnp.arange(S)[None]
    tok = jnp.concatenate([tok_h, tok_c], axis=1)            # [B, L+S]
    res = jnp.concatenate([tok_h < off[:, None],
                           jnp.arange(S)[None] < cl[:, None]], axis=1)
    p_row = off[:, None] + (jnp.arange(SG) // G)[None]       # [B, SG]
    ok = tok[:, None, :] <= p_row[:, :, None]
    if window > 0:
        win = (p_row[:, :, None] - tok[:, None, :]) < window
        if sink > 0:
            win |= (tok < sink)[:, None, :]
        ok &= win
    mask = res[:, None, :] & ok                              # [B, SG, L+S]
    s = jnp.einsum("bkrh,bkth->bkrt", q.astype(jnp.float32),
                   k_all) * (h ** -0.5)
    s = jnp.where(mask[:, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bkrt,bkth->bkrh", p, v_all).astype(q.dtype)


def spec_verify_ref(q, k_new, v_new, k_pages, v_pages, tables, off, n_tok,
                    *, k_scale=None, k_tok=None, v_scale=None, v_tok=None):
    """Speculative-verify oracle. q [B,K,S*G,h] (row r = window token r//G);
    k_new/v_new [B,K,S,h] the draft window's rope'd keys; pages [N,K,bs,h];
    tables [B,nb]; off [B] per-slot history length; n_tok [B] real window
    rows (draft_len+1) → [B,K,S*G,h]. Mathematically the verify step IS a
    batched causal chunked-prefill read — every slot attends its resident
    history plus its own window under the causal mask — so the oracle is
    `paged_prefill_ref` with per-row offsets and no sparse window. Kept as a
    named oracle so the verify kernel's contract (read-only, causal-only,
    per-row off/cl) is pinned independently of prefill's evolution."""
    return paged_prefill_ref(q, k_new, v_new, k_pages, v_pages, tables,
                             off, n_tok, window=0, sink=0, k_scale=k_scale,
                             k_tok=k_tok, v_scale=v_scale, v_tok=v_tok)


def block_topk_scores_ref(q, kmin, kmax, tables, lens, *, block_size):
    """q [B,K,G,h]; kmin/kmax [N,K,h] per-block key channel bounds;
    tables [B,nb]; lens [B] resident logical slots → scores [B,nb] f32.
    Quest-style upper bound: score(b,j) = max over (K,G) heads of
    Σ_c max(q_c·kmin_c, q_c·kmax_c) for the tabled block; NEG_INF once the
    block's logical slot range starts at or past lens."""
    B, K, G, h = q.shape
    nb = tables.shape[1]
    lo = kmin[tables].astype(jnp.float32)                # [B, nb, K, h]
    hi = kmax[tables].astype(jnp.float32)
    qg = q.astype(jnp.float32)[:, None]                  # [B, 1, K, G, h]
    ub = jnp.maximum(qg * lo[:, :, :, None, :],
                     qg * hi[:, :, :, None, :]).sum(-1)  # [B, nb, K, G]
    s = ub.max(axis=(2, 3))
    resident = (jnp.arange(nb)[None] * block_size) < lens[:, None]
    return jnp.where(resident, s, NEG_INF)


def moe_gmm_ref(x, w, n_valid):
    """x [s,C,D] @ w [s,D,F] with valid-row masking → [s,C,F]."""
    C = x.shape[1]
    mask = jnp.arange(C)[None, :, None] < n_valid[:, None, None]
    xm = jnp.where(mask, x.astype(jnp.float32), 0.0)
    return jnp.einsum("scd,sdf->scf", xm,
                      w.astype(jnp.float32)).astype(x.dtype)
