"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def flash_prefill_ref(q, k, v, *, causal=True, window=0, sink=0):
    """q/k/v [BH, S, h] → [BH, S, h]; dense softmax attention."""
    BH, S, h = q.shape
    s = jnp.einsum("bqh,bkh->bqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * (h ** -0.5)
    q_pos = jnp.arange(S)[:, None]
    k_pos = jnp.arange(S)[None, :]
    mask = jnp.ones((S, S), bool)
    if causal:
        mask &= k_pos <= q_pos
    if window > 0:
        in_win = (q_pos - k_pos) < window
        if sink > 0:
            in_win |= k_pos < sink
        mask &= in_win
    s = jnp.where(mask[None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkh->bqh", p, v.astype(jnp.float32)).astype(q.dtype)


def sink_decode_ref(q, k_cache, v_cache, t):
    """q [B,K,G,h]; caches [B,K,W,h]; t [B] → [B,K,G,h]."""
    B, K, G, h = q.shape
    W = k_cache.shape[2]
    s = jnp.einsum("bkgh,bkwh->bkgw", q.astype(jnp.float32),
                   k_cache.astype(jnp.float32)) * (h ** -0.5)
    occ = jnp.arange(W)[None, None, None, :] < t[:, None, None, None]
    s = jnp.where(occ, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bkgw,bkwh->bkgh", p,
                      v_cache.astype(jnp.float32)).astype(q.dtype)


def paged_decode_ref(q, k_pages, v_pages, tables, lens):
    """q [B,K,G,h]; pages [N,K,bs,h]; tables [B,nb]; lens [B] → [B,K,G,h].
    Gather the pages into a linear [B,K,nb*bs,h] cache, then masked softmax
    attention over the first `lens` logical slots."""
    B, K, G, h = q.shape
    nb = tables.shape[1]
    bs = k_pages.shape[2]
    k_lin = jnp.moveaxis(k_pages[tables], 2, 1).reshape(B, K, nb * bs, h)
    v_lin = jnp.moveaxis(v_pages[tables], 2, 1).reshape(B, K, nb * bs, h)
    return sink_decode_ref(q, k_lin, v_lin, lens)


def moe_gmm_ref(x, w, n_valid):
    """x [s,C,D] @ w [s,D,F] with valid-row masking → [s,C,F]."""
    C = x.shape[1]
    mask = jnp.arange(C)[None, :, None] < n_valid[:, None, None]
    xm = jnp.where(mask, x.astype(jnp.float32), 0.0)
    return jnp.einsum("scd,sdf->scf", xm,
                      w.astype(jnp.float32)).astype(x.dtype)
