"""Chunked-prefill attention over physically paged history KV (TPU Pallas).

The prefill-side sibling of ``paged_decode``: one query *chunk* of a prompt
(S tokens at absolute positions ``off .. off+S``, of which only the first
``cl`` rows are real) attends to

  1. the prompt's **resident history** — tokens ``< off`` living in
     non-contiguous fixed-size blocks of the global per-layer arena
     ``[n_blocks, K, block_size, h]`` (kv-head-major), reached through the
     task's scalar-prefetched block table so the BlockSpec index map drives
     the DMA gather directly, and
  2. the chunk's own keys, under the causal in-chunk mask.

Online softmax accumulates across history blocks and the in-chunk step in
VMEM scratch, so the kernel never materializes the full score row. The
OmniAttn sink+window sparse mask (eq. 6's token subset) is fused into both
score blocks: a key at absolute position t is visible to the query at
position p iff ``t <= p`` and (when ``window > 0``)
``p - t < window or t < sink`` — full-attention layers pass window=sink=0.

Chunk K/V is *not* written here: the engine scatters it into the arena
blocks in the same jit (``models/attention.py::paged_prefill_write``), the
same split as the decode path (kernel reads, jnp scatter writes).

Grid: (B, K, n_hist_blocks + 1) with the last dimension sequential; block
j < nb is history block j (compute skipped entirely once ``j*bs >= off`` —
table entries past the resident region point at the reserved null block 0,
whose DMA fetch is masked out), and j == nb is the in-chunk step. GQA is
native: the q block carries all G = H/K query rows of one kv group per
chunk token (row r of the [S*G, h] q tile is chunk token r // G).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax>=0.7 renamed TPUCompilerParams -> CompilerParams; support both.
_CompilerParams = getattr(pltpu, "CompilerParams", None) or \
    getattr(pltpu, "TPUCompilerParams")

NEG_INF = -1e30


def _kernel(tbl_ref, meta_ref, q_ref, kn_ref, vn_ref, kp_ref, vp_ref, *rest,
            scale: float, block_size: int, n_blocks: int, S: int, G: int,
            window: int, sink: int, quant: bool):
    # QuantPlane: int8 history tiles dequantize in VMEM against their seal
    # scales [h] (nonzero ⟺ sealed) or per-token tail scales [bs]; the
    # chunk's own k_new/v_new stay f32 — only HISTORY lives in the arena.
    if quant:
        ks_ref, kt_ref, vs_ref, vt_ref, o_ref, acc_ref, m_ref, l_ref = rest
    else:
        o_ref, acc_ref, m_ref, l_ref = rest
    b = pl.program_id(0)
    j = pl.program_id(2)
    off = meta_ref[b, 0]
    cl = meta_ref[b, 1]
    SG = S * G
    # query row r is chunk token r // G at absolute position off + r // G
    p_row = off + jax.lax.broadcasted_iota(jnp.int32, (SG, 1), 0)[:, 0] // G

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    def _allowed(p, t):
        ok = t <= p
        if window > 0:
            win = (p - t) < window
            if sink > 0:
                win |= t < sink
            ok &= win
        return ok

    def _accumulate(s, mask):
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        m_ref[...] = m_new
        l_ref[...] = l_ref[...] * corr + p.sum(axis=1)
        return p, corr

    # history block j: logical slots [j*bs, (j+1)*bs) hold tokens at those
    # absolute positions; skip compute once the block starts past the
    # resident region (its tabled entry is the null block)
    @pl.when(jnp.logical_and(j < n_blocks, j * block_size < off))
    def _history():
        q = q_ref[...].astype(jnp.float32)              # [SG, h]
        k = kp_ref[...].astype(jnp.float32)             # [bs, h]
        if quant:
            ks = ks_ref[...].astype(jnp.float32)        # [h]
            kt = kt_ref[...].astype(jnp.float32)        # [bs]
            k = k * jnp.where(ks[None, :] != 0, ks[None, :], kt[:, None])
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale
        tok = j * block_size + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = (tok < off) & _allowed(p_row[:, None], tok)
        p, corr = _accumulate(s, mask)
        v = vp_ref[...].astype(jnp.float32)
        if quant:
            vs = vs_ref[...].astype(jnp.float32)
            vt = vt_ref[...].astype(jnp.float32)
            v = v * jnp.where(vs[None, :] != 0, vs[None, :], vt[:, None])
        acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot(p, v)

    # in-chunk step: causal attention over the chunk's own (real) keys
    @pl.when(j == n_blocks)
    def _chunk():
        q = q_ref[...].astype(jnp.float32)              # [SG, h]
        k = kn_ref[...].astype(jnp.float32)             # [S, h]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale
        u = jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        t = off + u
        mask = (u < cl) & _allowed(p_row[:, None], t)
        p, corr = _accumulate(s, mask)
        v = vn_ref[...].astype(jnp.float32)
        acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot(p, v)
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[...] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("window", "sink", "interpret"))
def paged_prefill(q, k_new, v_new, k_pages, v_pages, tables, off, chunk_len,
                  *, window: int = 0, sink: int = 0, k_scale=None, k_tok=None,
                  v_scale=None, v_tok=None, interpret: bool = False):
    """q [B, K, S*G, h] (row r = chunk token r//G); k_new/v_new [B, K, S, h];
    arenas [N, K, bs, h]; tables [B, nb] physical block ids; off/chunk_len
    [B] (history length, real chunk rows) → o [B, K, S*G, h].

    Quantized arenas (QuantPlane) pass int8 pages plus the scale plane
    (k_scale/v_scale [N, K, h] seal scales, k_tok/v_tok [N, K, bs] per-token
    tail scales); history tiles dequantize in VMEM — k_new/v_new stay f32."""
    B, K, SG, h = q.shape
    S = k_new.shape[2]
    G = SG // S
    bs = k_pages.shape[2]
    nb = tables.shape[1]
    scale = h ** -0.5
    quant = k_scale is not None
    meta = jnp.stack([jnp.broadcast_to(jnp.asarray(off, jnp.int32), (B,)),
                      jnp.broadcast_to(jnp.asarray(chunk_len, jnp.int32),
                                       (B,))], axis=1)
    kernel = functools.partial(_kernel, scale=scale, block_size=bs,
                               n_blocks=nb, S=S, G=G, window=window, sink=sink,
                               quant=quant)
    in_specs = [
        pl.BlockSpec((None, None, SG, h),
                     lambda b, kh, j, tbl, meta: (b, kh, 0, 0)),
        pl.BlockSpec((None, None, S, h),
                     lambda b, kh, j, tbl, meta: (b, kh, 0, 0)),
        pl.BlockSpec((None, None, S, h),
                     lambda b, kh, j, tbl, meta: (b, kh, 0, 0)),
        # the j == nb (in-chunk) step still fetches a tabled block; the
        # clamped entry is never read by compute
        pl.BlockSpec((None, None, bs, h),
                     lambda b, kh, j, tbl, meta:
                     (tbl[b, jnp.minimum(j, tbl.shape[1] - 1)], kh, 0, 0)),
        pl.BlockSpec((None, None, bs, h),
                     lambda b, kh, j, tbl, meta:
                     (tbl[b, jnp.minimum(j, tbl.shape[1] - 1)], kh, 0, 0)),
    ]
    operands = [q, k_new, v_new, k_pages, v_pages]
    if quant:
        sc_spec = pl.BlockSpec(
            (None, None, h),
            lambda b, kh, j, tbl, meta:
            (tbl[b, jnp.minimum(j, tbl.shape[1] - 1)], kh, 0))
        tk_spec = pl.BlockSpec(
            (None, None, bs),
            lambda b, kh, j, tbl, meta:
            (tbl[b, jnp.minimum(j, tbl.shape[1] - 1)], kh, 0))
        in_specs += [sc_spec, tk_spec, sc_spec, tk_spec]
        operands += [k_scale, k_tok, v_scale, v_tok]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,      # tables, meta
        grid=(B, K, nb + 1),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((None, None, SG, h),
                               lambda b, kh, j, tbl, meta: (b, kh, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((SG, h), jnp.float32),
            pltpu.VMEM((SG,), jnp.float32),
            pltpu.VMEM((SG,), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, K, SG, h), q.dtype),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(tables.astype(jnp.int32), meta, *operands)
