"""Flash-attention prefill kernel (TPU Pallas) with sink+window sparse masks.

TPU adaptation of the OmniAttn prefill path: blockwise online-softmax
attention tiled for VMEM (q blocks × kv blocks in the grid, fp32 accumulators
in VMEM scratch), with the sink+sliding-window mask fused into the score
block — the compute-side realization of eq. 6's token subset M.

Layouts: q/k/v/o are [BH, S, h] (batch×head flattened; GQA callers repeat KV
heads — see ops.py). Grid: (BH, n_q_blocks, n_kv_blocks); the kv dimension is
'arbitrary' (sequential) so scratch accumulates across kv steps.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax>=0.7 renamed TPUCompilerParams -> CompilerParams; support both.
_CompilerParams = getattr(pltpu, "CompilerParams", None) or \
    getattr(pltpu, "TPUCompilerParams")

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
            scale: float, causal: bool, window: int, sink: int,
            block_q: int, block_k: int, n_kv: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q_pos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
    k_pos = ki * block_k + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
    mask = jnp.ones((block_q, block_k), dtype=jnp.bool_)
    if causal:
        mask &= k_pos <= q_pos
    if window > 0:
        in_win = (q_pos - k_pos) < window
        if sink > 0:
            in_win |= k_pos < sink
        mask &= in_win

    q = q_ref[...].astype(jnp.float32)
    k = k_ref[...].astype(jnp.float32)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=1))
    p = jnp.exp(s - m_new[:, None])
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + p.sum(axis=1)
    m_ref[...] = m_new
    v = v_ref[...].astype(jnp.float32)
    acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot(p, v)

    @pl.when(ki == n_kv - 1)
    def _final():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[...] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "window", "sink",
                                             "block_q", "block_k", "interpret"))
def flash_prefill(q, k, v, *, causal: bool = True, window: int = 0,
                  sink: int = 0, block_q: int = 512, block_k: int = 512,
                  interpret: bool = False):
    """q/k/v: [BH, S, h] → o [BH, S, h]."""
    BH, S, h = q.shape
    block_q = min(block_q, S)
    block_k = min(block_k, S)
    while S % block_q:
        block_q //= 2
    while S % block_k:
        block_k //= 2
    n_q, n_kv = S // block_q, S // block_k
    scale = h ** -0.5
    kernel = functools.partial(_kernel, scale=scale, causal=causal,
                               window=window, sink=sink, block_q=block_q,
                               block_k=block_k, n_kv=n_kv)
    return pl.pallas_call(
        kernel,
        grid=(BH, n_q, n_kv),
        in_specs=[
            pl.BlockSpec((None, block_q, h), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((None, block_k, h), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((None, block_k, h), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((None, block_q, h), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, S, h), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, h), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(q, k, v)
