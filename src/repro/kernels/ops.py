"""jit'd public wrappers: backend dispatch (interpret=True on CPU — the
kernels TARGET TPU; interpret mode executes the kernel body for validation)
+ layout adapters matching the model stack's tensor shapes."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.block_topk import block_topk_scores
from repro.kernels.flash_prefill import flash_prefill
from repro.kernels.moe_gmm import moe_gmm
from repro.kernels.paged_decode import paged_decode
from repro.kernels.paged_prefill import paged_prefill
from repro.kernels.sink_decode import sink_decode
from repro.kernels.spec_verify import spec_verify


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def attention_prefill_op(q, k, v, *, causal=True, window=0, sink=0,
                         block_q=512, block_k=512):
    """Model-stack layout adapter: q [B,S,H,h], k/v [B,S,K,h] → [B,S,H,h].
    KV heads are repeated to full heads (TPU flash layout)."""
    B, S, H, h = q.shape
    K = k.shape[2]
    G = H // K
    if G > 1:
        k = jnp.repeat(k, G, axis=2)
        v = jnp.repeat(v, G, axis=2)
    qf = q.transpose(0, 2, 1, 3).reshape(B * H, S, h)
    kf = k.transpose(0, 2, 1, 3).reshape(B * H, S, h)
    vf = v.transpose(0, 2, 1, 3).reshape(B * H, S, h)
    o = flash_prefill(qf, kf, vf, causal=causal, window=window, sink=sink,
                      block_q=block_q, block_k=block_k, interpret=_interpret())
    return o.reshape(B, H, S, h).transpose(0, 2, 1, 3)


def attention_decode_op(q, k_cache, v_cache, t, *, block_w=512):
    """q [B,H,h]; caches [B,W,K,h]; t scalar or [B] → [B,H,h]."""
    B, H, h = q.shape
    W, K = k_cache.shape[1], k_cache.shape[2]
    G = H // K
    qg = q.reshape(B, K, G, h)
    kc = k_cache.transpose(0, 2, 1, 3)        # [B,K,W,h]
    vc = v_cache.transpose(0, 2, 1, 3)
    t = jnp.broadcast_to(jnp.asarray(t, jnp.int32), (B,))
    o = sink_decode(qg, kc, vc, t, block_w=block_w, interpret=_interpret())
    return o.reshape(B, H, h)


def attention_paged_decode_op(q, k_pages, v_pages, tables, lens, *,
                              k_scale=None, k_tok=None, v_scale=None,
                              v_tok=None):
    """q [B,H,h]; arenas [N,K,bs,h]; tables [B,nb] physical block ids;
    lens [B] resident logical slots → [B,H,h]. Quantized arenas (QuantPlane)
    pass int8 pages plus k_scale/v_scale [N,K,h] and k_tok/v_tok [N,K,bs];
    the kernel dequantizes per tile."""
    B, H, h = q.shape
    K = k_pages.shape[1]
    G = H // K
    o = paged_decode(q.reshape(B, K, G, h), k_pages, v_pages, tables, lens,
                     k_scale=k_scale, k_tok=k_tok, v_scale=v_scale,
                     v_tok=v_tok, interpret=_interpret())
    return o.reshape(B, H, h)


def block_topk_scores_op(q, kmin, kmax, tables, lens, *, block_size):
    """q [B,H,h]; kmin/kmax [N,K,h] per-block key channel bounds; tables
    [B,nb]; lens [B] resident logical slots → upper-bound block scores
    [B,nb] f32 (NEG_INF past the residency)."""
    B, H, h = q.shape
    K = kmin.shape[1]
    G = H // K
    return block_topk_scores(q.reshape(B, K, G, h), kmin, kmax, tables, lens,
                             block_size=block_size, interpret=_interpret())


def attention_paged_prefill_op(q, k_new, v_new, k_pages, v_pages, tables,
                               off, chunk_len, *, window=0, sink=0,
                               k_scale=None, k_tok=None, v_scale=None,
                               v_tok=None):
    """Chunked prefill over paged history. q [B,S,H,h]; k_new/v_new
    [B,S,K,h]; arenas [N,K,bs,h]; tables [B,nb]; off/chunk_len scalars or
    [B] → [B,S,H,h]. Rows are regrouped per kv head (row r = chunk token
    r//G, the kernel's GQA layout)."""
    B, S, H, h = q.shape
    K = k_new.shape[2]
    G = H // K
    qf = q.reshape(B, S, K, G, h).transpose(0, 2, 1, 3, 4) \
        .reshape(B, K, S * G, h)
    kf = k_new.transpose(0, 2, 1, 3)
    vf = v_new.transpose(0, 2, 1, 3)
    o = paged_prefill(qf, kf, vf, k_pages, v_pages, tables, off, chunk_len,
                      window=window, sink=sink, k_scale=k_scale, k_tok=k_tok,
                      v_scale=v_scale, v_tok=v_tok, interpret=_interpret())
    return o.reshape(B, K, S, G, h).transpose(0, 2, 1, 3, 4) \
        .reshape(B, S, H, h)


def spec_verify_op(q, k_new, v_new, k_pages, v_pages, tables, off, n_tok, *,
                   k_scale=None, k_tok=None, v_scale=None, v_tok=None):
    """Batched multi-token speculative verify over paged history (read-only).
    q [B,S,H,h] — S = k+1 window rows per slot; k_new/v_new [B,S,K,h] the
    window's rope'd keys (NOT yet in any block); arenas [N,K,bs,h]; tables
    [B,nb]; off [B] per-slot resident-history length; n_tok [B] real window
    rows → [B,S,H,h]. Same GQA regroup as the chunked-prefill adapter."""
    B, S, H, h = q.shape
    K = k_new.shape[2]
    G = H // K
    qf = q.reshape(B, S, K, G, h).transpose(0, 2, 1, 3, 4) \
        .reshape(B, K, S * G, h)
    kf = k_new.transpose(0, 2, 1, 3)
    vf = v_new.transpose(0, 2, 1, 3)
    o = spec_verify(qf, kf, vf, k_pages, v_pages, tables, off, n_tok,
                    k_scale=k_scale, k_tok=k_tok, v_scale=v_scale,
                    v_tok=v_tok, interpret=_interpret())
    return o.reshape(B, K, S, G, h).transpose(0, 2, 1, 3, 4) \
        .reshape(B, S, H, h)


def moe_gmm_op(x, w, n_valid, **kw):
    return moe_gmm(x, w, n_valid, interpret=_interpret(), **kw)
