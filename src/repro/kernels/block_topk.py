"""Quest-style per-block upper-bound scoring for online KV sparsity (TPU
Pallas).

The scoring half of OmniAttn's dynamic sparsity: every resident KV block of
a paged full-attention layer carries per-kv-head channel bounds of its keys
(``kmin``/``kmax`` ``[N, K, h]`` side arrays maintained next to the
``[N, K, bs, h]`` arenas by the same jits that write KV). For a decode query
``q`` the score of block ``n`` is the channel-wise upper bound on any key
dot-product inside the block,

    score(n) = max_{k-head, q-head} Σ_c max(q_c · kmin[n]_c, q_c · kmax[n]_c)

— an upper bound on ``max_t q · key_t`` for every key resident in the block
(unwritten slots hold zeros, which only widen the [kmin, kmax] interval, so
the bound stays valid for partially filled blocks). The per-slot block table
is a scalar-prefetch operand so the BlockSpec index map DMAs exactly the
summaries of tabled blocks — one [K, h] tile per block, a ``1/block_size``
fraction of the KV bytes the full attention read would move.

Blocks whose logical slot range starts at or beyond ``lens[b]`` (the
resident occupancy, same convention as ``paged_decode``) score ``NEG_INF``
so downstream top-k selection never picks a non-resident (null-aliased)
table entry.

Grid: (B, nb) with the block dimension sequential; scores accumulate in a
VMEM scratch row written out on the last block step. Selection itself
(top-k + forced sink/recent keeps + table compaction) is cheap [B, nb]
index arithmetic and stays in jnp — see
``models/attention.py::select_kv_blocks``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax>=0.7 renamed TPUCompilerParams -> CompilerParams; support both.
_CompilerParams = getattr(pltpu, "CompilerParams", None) or \
    getattr(pltpu, "TPUCompilerParams")

NEG_INF = -1e30


def _kernel(tbl_ref, lens_ref, q_ref, kmin_ref, kmax_ref, o_ref, s_ref, *,
            block_size: int, n_blocks: int):
    b = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        s_ref[...] = jnp.full_like(s_ref, NEG_INF)

    # resident blocks only: block j covers logical slots [j*bs, (j+1)*bs);
    # entries past the occupancy alias the null block and must never outrank
    # a real one
    @pl.when(j * block_size < lens_ref[b])
    def _score():
        q = q_ref[...].astype(jnp.float32)              # [K, G, h]
        lo = kmin_ref[...].astype(jnp.float32)          # [K, h]
        hi = kmax_ref[...].astype(jnp.float32)
        ub = jnp.maximum(q * lo[:, None, :], q * hi[:, None, :]).sum(-1)
        s_ref[j] = jnp.max(ub)                          # max over (K, G)

    @pl.when(j == n_blocks - 1)
    def _final():
        o_ref[...] = s_ref[...]


@functools.partial(jax.jit, static_argnames=("block_size", "interpret"))
def block_topk_scores(q, kmin, kmax, tables, lens, *, block_size: int,
                      interpret: bool = False):
    """q [B, K, G, h]; kmin/kmax [N, K, h]; tables [B, nb]; lens [B] resident
    logical slots (block j resident iff j*block_size < lens[b]) →
    scores [B, nb] float32."""
    B, K, G, h = q.shape
    nb = tables.shape[1]
    kernel = functools.partial(_kernel, block_size=block_size, n_blocks=nb)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,      # tables, lens
        grid=(B, nb),
        in_specs=[
            pl.BlockSpec((None, K, G, h),
                         lambda b, j, tbl, lens: (b, 0, 0, 0)),
            pl.BlockSpec((None, K, h),
                         lambda b, j, tbl, lens: (tbl[b, j], 0, 0)),
            pl.BlockSpec((None, K, h),
                         lambda b, j, tbl, lens: (tbl[b, j], 0, 0)),
        ],
        out_specs=pl.BlockSpec((None, nb), lambda b, j, tbl, lens: (b, 0)),
        scratch_shapes=[pltpu.VMEM((nb,), jnp.float32)],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, nb), jnp.float32),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(tables.astype(jnp.int32), lens.astype(jnp.int32), q, kmin, kmax)
