"""Decode-step attention over a (sink‖ring) compressed KV cache (TPU Pallas).

The OmniAttn decode hot path: one query token per sequence attends over the
W = sink+recent compressed cache with an occupancy mask (slots < min(t, W)).
GQA is handled natively: the q block carries all G=H/K heads of one kv group,
so the cache block is read ONCE per group (the bandwidth win that motivates
grouped layouts on TPU).

Layouts: q [B, K, G, h]; k/v caches [B, K, W, h] (kv-head-major so the W×h
cache block for one (batch, kv-head) is contiguous); t [B] occupancy.
Grid: (B, K, n_w_blocks) with W sequential.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax>=0.7 renamed TPUCompilerParams -> CompilerParams; support both.
_CompilerParams = getattr(pltpu, "CompilerParams", None) or \
    getattr(pltpu, "TPUCompilerParams")

NEG_INF = -1e30


def _kernel(t_ref, q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
            scale: float, block_w: int, n_w: int):
    wi = pl.program_id(2)
    b = pl.program_id(0)

    @pl.when(wi == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[...].astype(jnp.float32)              # [G, h]
    k = k_ref[...].astype(jnp.float32)              # [block_w, h]
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale  # [G, bw]
    slot = wi * block_w + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    occupied = slot < t_ref[b]
    s = jnp.where(occupied, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=1))
    p = jnp.exp(s - m_new[:, None])
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + p.sum(axis=1)
    m_ref[...] = m_new
    v = v_ref[...].astype(jnp.float32)
    acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot(p, v)

    @pl.when(wi == n_w - 1)
    def _final():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[...] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_w", "interpret"))
def sink_decode(q, k_cache, v_cache, t, *, block_w: int = 512,
                interpret: bool = False):
    """q [B, K, G, h]; caches [B, K, W, h]; t [B] → o [B, K, G, h]."""
    B, K, G, h = q.shape
    W = k_cache.shape[2]
    block_w = min(block_w, W)
    while W % block_w:
        block_w //= 2
    n_w = W // block_w
    scale = h ** -0.5
    kernel = functools.partial(_kernel, scale=scale, block_w=block_w, n_w=n_w)
    return pl.pallas_call(
        kernel,
        grid=(B, K, n_w),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),   # t: scalar occupancy
            pl.BlockSpec((None, None, G, h), lambda b, kh, w: (b, kh, 0, 0)),
            pl.BlockSpec((None, None, block_w, h), lambda b, kh, w: (b, kh, w, 0)),
            pl.BlockSpec((None, None, block_w, h), lambda b, kh, w: (b, kh, w, 0)),
        ],
        out_specs=pl.BlockSpec((None, None, G, h), lambda b, kh, w: (b, kh, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, K, G, h), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((G, h), jnp.float32),
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G,), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(t.astype(jnp.int32), q, k_cache, v_cache)
