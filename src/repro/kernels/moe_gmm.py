"""Slot-batched grouped matmul (TPU Pallas) — the MoE expert-FFN hot op.

After OmniPlacement dispatch, each device holds its slot buffer
x [s, C, D] and slot weights w [s, D, F] (see models/moe.py); the expert
compute is a batched matmul with per-slot row validity n_valid [s] (tokens
beyond a slot's fill count are capacity padding and must not pollute the MXU
accumulation — they're masked at load).

Grid: (s, C/block_c, F/block_f, D/block_d) with the D dimension sequential
(accumulated in VMEM scratch).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax>=0.7 renamed TPUCompilerParams -> CompilerParams; support both.
_CompilerParams = getattr(pltpu, "CompilerParams", None) or \
    getattr(pltpu, "TPUCompilerParams")


def _kernel(nv_ref, x_ref, w_ref, o_ref, acc_ref, *, block_c: int,
            block_d: int, n_d: int):
    s = pl.program_id(0)
    ci = pl.program_id(1)
    di = pl.program_id(3)

    @pl.when(di == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[...].astype(jnp.float32)              # [block_c, block_d]
    row = ci * block_c + jax.lax.broadcasted_iota(jnp.int32, x.shape, 0)
    x = jnp.where(row < nv_ref[s], x, 0.0)
    w = w_ref[...].astype(jnp.float32)              # [block_d, block_f]
    acc_ref[...] += jax.lax.dot(x, w)

    @pl.when(di == n_d - 1)
    def _final():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_c", "block_f", "block_d",
                                             "interpret"))
def moe_gmm(x, w, n_valid, *, block_c: int = 256, block_f: int = 256,
            block_d: int = 256, interpret: bool = False):
    """x [s, C, D] @ w [s, D, F] with per-slot valid-row masks → [s, C, F]."""
    S, C, D = x.shape
    F = w.shape[2]
    block_c = min(block_c, C)
    block_f = min(block_f, F)
    block_d = min(block_d, D)
    while C % block_c:
        block_c //= 2
    while F % block_f:
        block_f //= 2
    while D % block_d:
        block_d //= 2
    n_d = D // block_d
    kernel = functools.partial(_kernel, block_c=block_c, block_d=block_d,
                               n_d=n_d)
    return pl.pallas_call(
        kernel,
        grid=(S, C // block_c, F // block_f, n_d),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),   # n_valid
            pl.BlockSpec((None, block_c, block_d), lambda s, c, f, d: (s, c, d)),
            pl.BlockSpec((None, block_d, block_f), lambda s, c, f, d: (s, d, f)),
        ],
        out_specs=pl.BlockSpec((None, block_c, block_f),
                               lambda s, c, f, d: (s, c, f)),
        out_shape=jax.ShapeDtypeStruct((S, C, F), x.dtype),
        scratch_shapes=[pltpu.VMEM((block_c, block_f), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(n_valid.astype(jnp.int32), x, w)
