"""Batched speculative-verify attention over physically paged KV (Pallas).

The verify-side sibling of ``paged_prefill``: every decode slot presents a
tiny draft window of S = k+1 tokens — its current input token followed by k
speculated continuations — at absolute positions ``off_b .. off_b + k``, and
attends

  1. the slot's **resident history** — tokens ``< off_b`` living in
     non-contiguous fixed-size arena blocks ``[n_blocks, K, bs, h]``
     reached through the slot's scalar-prefetched block-table row, and
  2. the window's own keys under the causal in-chunk mask,

producing the logits the greedy-prefix acceptance rule consumes. The regime
differs from chunked prefill in two ways that shape the kernel: the batch is
the full slot dimension (B = n_slots, every row with its OWN history offset
``off_b`` and real-row count ``cl_b`` = draft_len+1 — prefill runs one task
at a time with scalar offsets), and S is tiny (k+1, single-digit), so the
whole [S·G, h] query tile of one kv group rides each grid step. Masking is
causal-only: verify serves full-attention layers (ring layers take the
read-only jnp resume path — their window is enforced by ring eviction, which
the verify mask mirrors in ``spec_verify_ring_attention``).

STRICTLY READ-ONLY: no K/V is written here. The engine commits the accepted
prefix AFTER the in-jit acceptance via the masked scatter
(``stack_verify_commit``) — rejected draft rows never touch a block, which
is what makes rollback a non-event for the block-summary plane.

Grid: (B, K, n_hist_blocks + 1), last dimension sequential; j < nb is
history block j (compute skipped once ``j*bs >= off_b`` — table entries past
the residency point at the reserved null block 0), j == nb is the in-window
step. GQA is native: q row r is window token r // G.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax>=0.7 renamed TPUCompilerParams -> CompilerParams; support both.
_CompilerParams = getattr(pltpu, "CompilerParams", None) or \
    getattr(pltpu, "TPUCompilerParams")

NEG_INF = -1e30


def _kernel(tbl_ref, meta_ref, q_ref, kn_ref, vn_ref, kp_ref, vp_ref, *rest,
            scale: float, block_size: int, n_blocks: int, S: int, G: int,
            quant: bool):
    # QuantPlane: int8 history tiles dequantize in VMEM against their seal
    # scales [h] (nonzero ⟺ sealed) or per-token tail scales [bs]; the
    # window's own k_new/v_new stay f32 (not yet committed to any block).
    if quant:
        ks_ref, kt_ref, vs_ref, vt_ref, o_ref, acc_ref, m_ref, l_ref = rest
    else:
        o_ref, acc_ref, m_ref, l_ref = rest
    b = pl.program_id(0)
    j = pl.program_id(2)
    off = meta_ref[b, 0]          # this slot's resident-history length
    cl = meta_ref[b, 1]           # this slot's real window rows (draft_len+1)
    SG = S * G
    # query row r is window token r // G at absolute position off + r // G
    p_row = off + jax.lax.broadcasted_iota(jnp.int32, (SG, 1), 0)[:, 0] // G

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    def _accumulate(s, mask):
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        m_ref[...] = m_new
        l_ref[...] = l_ref[...] * corr + p.sum(axis=1)
        return p, corr

    # history block j: logical slots [j*bs, (j+1)*bs) hold tokens at those
    # absolute positions; skip compute once the block starts past this
    # slot's residency (its tabled entry is the null block)
    @pl.when(jnp.logical_and(j < n_blocks, j * block_size < off))
    def _history():
        q = q_ref[...].astype(jnp.float32)              # [SG, h]
        k = kp_ref[...].astype(jnp.float32)             # [bs, h]
        if quant:
            ks = ks_ref[...].astype(jnp.float32)        # [h]
            kt = kt_ref[...].astype(jnp.float32)        # [bs]
            k = k * jnp.where(ks[None, :] != 0, ks[None, :], kt[:, None])
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale
        tok = j * block_size + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = (tok < off) & (tok <= p_row[:, None])
        p, corr = _accumulate(s, mask)
        v = vp_ref[...].astype(jnp.float32)
        if quant:
            vs = vs_ref[...].astype(jnp.float32)
            vt = vt_ref[...].astype(jnp.float32)
            v = v * jnp.where(vs[None, :] != 0, vs[None, :], vt[:, None])
        acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot(p, v)

    # in-window step: causal attention over the window's real keys (padded
    # draft rows past cl are masked as keys; their queries emit garbage the
    # acceptance rule never reads)
    @pl.when(j == n_blocks)
    def _window():
        q = q_ref[...].astype(jnp.float32)              # [SG, h]
        k = kn_ref[...].astype(jnp.float32)             # [S, h]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale
        u = jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = (u < cl) & ((off + u) <= p_row[:, None])
        p, corr = _accumulate(s, mask)
        v = vn_ref[...].astype(jnp.float32)
        acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot(p, v)
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[...] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def spec_verify(q, k_new, v_new, k_pages, v_pages, tables, off, n_tok,
                *, k_scale=None, k_tok=None, v_scale=None, v_tok=None,
                interpret: bool = False):
    """q [B, K, S*G, h] (row r = window token r//G); k_new/v_new [B, K, S, h];
    arenas [N, K, bs, h]; tables [B, nb] physical block ids; off [B] per-slot
    history length, n_tok [B] real window rows → o [B, K, S*G, h].

    Quantized arenas (QuantPlane) pass int8 pages plus the scale plane
    (k_scale/v_scale [N, K, h] seal scales, k_tok/v_tok [N, K, bs] per-token
    tail scales); history tiles dequantize in VMEM — the draft window's
    k_new/v_new stay f32."""
    B, K, SG, h = q.shape
    S = k_new.shape[2]
    G = SG // S
    bs = k_pages.shape[2]
    nb = tables.shape[1]
    scale = h ** -0.5
    quant = k_scale is not None
    meta = jnp.stack([jnp.broadcast_to(jnp.asarray(off, jnp.int32), (B,)),
                      jnp.broadcast_to(jnp.asarray(n_tok, jnp.int32), (B,))],
                     axis=1)
    kernel = functools.partial(_kernel, scale=scale, block_size=bs,
                               n_blocks=nb, S=S, G=G, quant=quant)
    in_specs = [
        pl.BlockSpec((None, None, SG, h),
                     lambda b, kh, j, tbl, meta: (b, kh, 0, 0)),
        pl.BlockSpec((None, None, S, h),
                     lambda b, kh, j, tbl, meta: (b, kh, 0, 0)),
        pl.BlockSpec((None, None, S, h),
                     lambda b, kh, j, tbl, meta: (b, kh, 0, 0)),
        # the j == nb (in-window) step still fetches a tabled block; the
        # clamped entry is never read by compute
        pl.BlockSpec((None, None, bs, h),
                     lambda b, kh, j, tbl, meta:
                     (tbl[b, jnp.minimum(j, tbl.shape[1] - 1)], kh, 0, 0)),
        pl.BlockSpec((None, None, bs, h),
                     lambda b, kh, j, tbl, meta:
                     (tbl[b, jnp.minimum(j, tbl.shape[1] - 1)], kh, 0, 0)),
    ]
    operands = [q, k_new, v_new, k_pages, v_pages]
    if quant:
        sc_spec = pl.BlockSpec(
            (None, None, h),
            lambda b, kh, j, tbl, meta:
            (tbl[b, jnp.minimum(j, tbl.shape[1] - 1)], kh, 0))
        tk_spec = pl.BlockSpec(
            (None, None, bs),
            lambda b, kh, j, tbl, meta:
            (tbl[b, jnp.minimum(j, tbl.shape[1] - 1)], kh, 0))
        in_specs += [sc_spec, tk_spec, sc_spec, tk_spec]
        operands += [k_scale, k_tok, v_scale, v_tok]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,      # tables, meta
        grid=(B, K, nb + 1),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((None, None, SG, h),
                               lambda b, kh, j, tbl, meta: (b, kh, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((SG, h), jnp.float32),
            pltpu.VMEM((SG,), jnp.float32),
            pltpu.VMEM((SG,), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, K, SG, h), q.dtype),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(tables.astype(jnp.int32), meta, *operands)
