"""Decode-step attention over physically paged KV (TPU Pallas).

PagedAttention for the decode hot path: each sequence's KV lives in
non-contiguous fixed-size blocks of a global per-layer arena
``[n_blocks, K, block_size, h]`` (kv-head-major so the block_size×h tile for
one (block, kv-head) is contiguous). A per-sequence block table maps logical
block j → physical arena block; the table is a scalar-prefetch operand so the
BlockSpec index map can drive the DMA gather directly — no host-side gather.

The occupancy operand `lens [B]` is the number of logical slots resident for
each sequence: t+1 once the current token's K/V is written for full-attention
layers, min(t+1, sink+recent) for ring (sliding-window / OmniAttn sink+recent
compressed) layers — the ring mapping lives in the caller; this kernel only
sees logical slot space, which makes one kernel serve full, windowed and
compressed layers. Compute for blocks whose logical range starts at or
beyond `lens` is skipped (the resident-blocks-only win; their block-spec
DMA still fetches the tabled entry, which the engine points at the null
block); the tail block is masked per-slot.

GQA is native: the q block carries all G=H/K heads of one kv group, so each
cache block is read once per group. Grid: (B, K, n_blocks_per_seq) with the
block dimension sequential (online softmax accumulates in VMEM scratch).

Table entries past a sequence's resident count should point at a reserved
null block (id 0 by convention in the serving engine): the DMA still touches
it, but the compute guard masks it out.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax>=0.7 renamed TPUCompilerParams -> CompilerParams; support both.
_CompilerParams = getattr(pltpu, "CompilerParams", None) or \
    getattr(pltpu, "TPUCompilerParams")

NEG_INF = -1e30


def _kernel(tbl_ref, lens_ref, q_ref, k_ref, v_ref, *rest,
            scale: float, block_size: int, n_blocks: int, quant: bool):
    # QuantPlane variant: int8 payload tiles ride with their per-block
    # per-channel seal scales [h] and per-token tail scales [bs]; the
    # dequant happens HERE, in the VMEM tile, on the f32 copy feeding the
    # MXU — no dequantized block ever exists in HBM.
    if quant:
        ks_ref, kt_ref, vs_ref, vt_ref, o_ref, acc_ref, m_ref, l_ref = rest
    else:
        o_ref, acc_ref, m_ref, l_ref = rest
    b = pl.program_id(0)
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    # Skip compute for blocks entirely past the resident region. The logical
    # slot range of block j is [j*bs, (j+1)*bs); lens >= 1 always (the block
    # holding the current token is resident), so block 0 is never skipped and
    # m/l carry at least one finite score into the final normalization.
    @pl.when(j * block_size < lens_ref[b])
    def _compute():
        q = q_ref[...].astype(jnp.float32)              # [G, h]
        k = k_ref[...].astype(jnp.float32)              # [bs, h]
        if quant:
            ks = ks_ref[...].astype(jnp.float32)        # [h]
            kt = kt_ref[...].astype(jnp.float32)        # [bs]
            k = k * jnp.where(ks[None, :] != 0, ks[None, :], kt[:, None])
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale
        slot = j * block_size + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(slot < lens_ref[b], s, NEG_INF)

        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + p.sum(axis=1)
        m_ref[...] = m_new
        v = v_ref[...].astype(jnp.float32)
        if quant:
            vs = vs_ref[...].astype(jnp.float32)
            vt = vt_ref[...].astype(jnp.float32)
            v = v * jnp.where(vs[None, :] != 0, vs[None, :], vt[:, None])
        acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot(p, v)

    @pl.when(j == n_blocks - 1)
    def _final():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[...] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_decode(q, k_pages, v_pages, tables, lens, *, k_scale=None,
                 k_tok=None, v_scale=None, v_tok=None,
                 interpret: bool = False):
    """q [B, K, G, h]; pages [N, K, bs, h]; tables [B, nb] int32 (physical
    block ids); lens [B] resident logical slots → o [B, K, G, h].

    Quantized arenas (QuantPlane) pass int8 pages plus the scale plane:
    k_scale/v_scale [N, K, h] per-block per-channel seal scales (nonzero
    row ⟺ sealed block) and k_tok/v_tok [N, K, bs] per-token scalar scales
    for the unsealed tail — the same block-table index maps DMA the scale
    tiles alongside their payload and the tile dequantizes in VMEM."""
    B, K, G, h = q.shape
    bs = k_pages.shape[2]
    nb = tables.shape[1]
    scale = h ** -0.5
    quant = k_scale is not None
    kernel = functools.partial(_kernel, scale=scale, block_size=bs,
                               n_blocks=nb, quant=quant)
    in_specs = [
        pl.BlockSpec((None, None, G, h),
                     lambda b, kh, j, tbl, lens: (b, kh, 0, 0)),
        pl.BlockSpec((None, None, bs, h),
                     lambda b, kh, j, tbl, lens: (tbl[b, j], kh, 0, 0)),
        pl.BlockSpec((None, None, bs, h),
                     lambda b, kh, j, tbl, lens: (tbl[b, j], kh, 0, 0)),
    ]
    operands = [q, k_pages, v_pages]
    if quant:
        sc_spec = pl.BlockSpec((None, None, h),
                               lambda b, kh, j, tbl, lens: (tbl[b, j], kh, 0))
        tk_spec = pl.BlockSpec((None, None, bs),
                               lambda b, kh, j, tbl, lens: (tbl[b, j], kh, 0))
        in_specs += [sc_spec, tk_spec, sc_spec, tk_spec]
        operands += [k_scale, k_tok, v_scale, v_tok]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,      # tables, lens
        grid=(B, K, nb),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((None, None, G, h),
                               lambda b, kh, j, tbl, lens: (b, kh, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((G, h), jnp.float32),
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G,), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, K, G, h), q.dtype),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(tables.astype(jnp.int32), lens.astype(jnp.int32), *operands)
