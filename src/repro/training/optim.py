"""AdamW in pure JAX (no optax): m/v moments follow the parameter sharding,
with configurable moment dtype (bf16 for ≥300B archs — see configs)."""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp


def adamw_init(params, dtype="float32"):
    dt = jnp.dtype(dtype)
    zeros = lambda p: jnp.zeros(p.shape, dt)
    return {"m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params),
            "step": jnp.zeros((), jnp.int32)}


def opt_specs(param_spec_tree):
    """Optimizer-state PartitionSpecs mirror the parameter specs."""
    from jax.sharding import PartitionSpec as P
    return {"m": param_spec_tree, "v": param_spec_tree, "step": P()}


def adamw_update(grads, opt, params, *, lr=3e-4, b1=0.9, b2=0.95, eps=1e-8,
                 weight_decay=0.1, grad_clip=1.0):
    step = opt["step"] + 1
    if grad_clip:
        gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                             for g in jax.tree.leaves(grads)))
        scale = jnp.minimum(1.0, grad_clip / jnp.maximum(gnorm, 1e-9))
    else:
        gnorm = jnp.zeros(())
        scale = 1.0

    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(g, m, v, p):
        g32 = g.astype(jnp.float32) * scale
        m32 = b1 * m.astype(jnp.float32) + (1 - b1) * g32
        v32 = b2 * v.astype(jnp.float32) + (1 - b2) * jnp.square(g32)
        mh = m32 / bc1
        vh = v32 / bc2
        p32 = p.astype(jnp.float32)
        new_p = p32 - lr * (mh / (jnp.sqrt(vh) + eps) + weight_decay * p32)
        return new_p.astype(p.dtype), m32.astype(m.dtype), v32.astype(v.dtype)

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(opt["m"])
    flat_v = jax.tree.leaves(opt["v"])
    res = [upd(g, m, v, p) for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
    new_params = jax.tree.unflatten(treedef, [r[0] for r in res])
    new_m = jax.tree.unflatten(treedef, [r[1] for r in res])
    new_v = jax.tree.unflatten(treedef, [r[2] for r in res])
    return new_params, {"m": new_m, "v": new_v, "step": step}, gnorm
