from repro.training.optim import adamw_init, adamw_update, opt_specs
from repro.training.trainer import TrainState, make_train_step

__all__ = ["adamw_init", "adamw_update", "opt_specs", "TrainState", "make_train_step"]
