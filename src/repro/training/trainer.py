"""train_step: value_and_grad + microbatch accumulation + AdamW.

Gradient cross-replica reduction is inserted by XLA (params replicated over
batch axes → grad contraction psums); microbatching (cfg.grad_accum) bounds
activation memory at long sequence lengths; gradients accumulate in fp32.
An optional int8 gradient-compression path (quantize per-leaf with max-abs
scales before accumulation) trades accuracy for all-reduce bytes — a
large-scale knob measured in the roofline hillclimb.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.models.lm import LM
from repro.training.optim import adamw_init, adamw_update


@dataclass
class TrainState:
    params: Any
    opt: Any
    step: int = 0


def _split_micro(batch, accum):
    def f(x):
        b = x.shape[0]
        return x.reshape((accum, b // accum) + x.shape[1:])
    return jax.tree.map(f, batch)


def make_train_step(lm: LM, *, lr: float = 3e-4, weight_decay: float = 0.1,
                    grad_compress_int8: bool = False):
    cfg = lm.cfg

    def loss_fn(params, batch, tables):
        loss, _aux = lm.train_loss(params, batch, tables=tables)
        return loss

    def quantize(g):
        scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-9) / 127.0
        q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
        return q.astype(jnp.float32) * scale

    def train_step(params, opt, batch, tables=None):
        accum = cfg.grad_accum
        if accum > 1:
            micro = _split_micro(batch, accum)

            def body(carry, mb):
                acc, lsum = carry
                loss, grads = jax.value_and_grad(loss_fn)(params, mb, tables)
                if grad_compress_int8:
                    grads = jax.tree.map(quantize, grads)
                acc = jax.tree.map(lambda a, g: a + g.astype(jnp.float32), acc, grads)
                return (acc, lsum + loss), None

            zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (gsum, lsum), _ = jax.lax.scan(body, (zeros, 0.0), micro)
            grads = jax.tree.map(lambda g: (g / accum), gsum)
            loss = lsum / accum
        else:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch, tables)
            if grad_compress_int8:
                grads = jax.tree.map(quantize, grads)
        new_params, new_opt, gnorm = adamw_update(
            grads, opt, params, lr=lr, weight_decay=weight_decay)
        return new_params, new_opt, {"loss": loss, "grad_norm": gnorm}

    return train_step


def init_state(lm: LM, rng) -> TrainState:
    params = lm.init(rng)
    opt = adamw_init(params, lm.cfg.optimizer_dtype)
    return TrainState(params, opt, 0)
