"""Synthetic data pipeline: deterministic, shardable, restartable.

A stateless index→batch map (seeded hash), so the pipeline position is fully
described by the step counter — restart-safe by construction (the checkpoint
stores only `step`). Sequences follow a Zipf unigram distribution with
Markov-ish bigram structure so the LM loss actually decreases (needed by the
train-100M example and the OmniAttn accuracy benches).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Iterator

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig


@dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    zipf_a: float = 1.2
    copy_dist: int = 0        # >0 → long-range copy dependency at this offset
    copy_prob: float = 0.3


def _batch_rng(seed: int, step: int) -> np.random.Generator:
    return np.random.default_rng(np.random.SeedSequence([seed, step]))


def synth_tokens(cfg: DataConfig, step: int) -> np.ndarray:
    """[B, S+1] token stream with learnable structure: bigram transitions and
    (optionally) long-range copies t[i] = t[i - copy_dist] — the retrieval
    dependency that OmniAttn's window compression can break (Table 3 proxy)."""
    rng = _batch_rng(cfg.seed, step)
    B, S, V = cfg.global_batch, cfg.seq_len, cfg.vocab_size
    # zipf unigrams clipped into the vocab
    base = rng.zipf(cfg.zipf_a, size=(B, S + 1)).astype(np.int64) % V
    # bigram structure: with p=0.5 the next token is f(prev) = (prev*7+3)%V
    follow = (base * 7 + 3) % V
    coin = rng.random((B, S + 1)) < 0.5
    out = base.copy()
    out[:, 1:] = np.where(coin[:, 1:], follow[:, :-1], base[:, 1:])
    if cfg.copy_dist > 0 and S + 1 > cfg.copy_dist:
        d = cfg.copy_dist
        cp = rng.random((B, S + 1)) < cfg.copy_prob
        cp[:, :d + 1] = False
        bs, ps = np.nonzero(cp)
        out[bs, ps - 1] = 0              # marker announces the copy
        out[bs, ps] = out[bs, ps - d]    # t[i] = t[i - d]
    return out


def make_batch(model_cfg: ModelConfig, data_cfg: DataConfig, step: int) -> dict:
    toks = synth_tokens(data_cfg, step)
    if model_cfg.family == "audio":
        rng = _batch_rng(data_cfg.seed + 1, step)
        frames = rng.standard_normal(
            (data_cfg.global_batch, data_cfg.seq_len,
             model_cfg.frontend_dim)).astype(np.float32)
        return {"frames": jnp.asarray(frames),
                "labels": jnp.asarray(toks[:, :-1] % model_cfg.vocab_size)}
    if model_cfg.family == "vlm":
        Pn = model_cfg.num_patches
        rng = _batch_rng(data_cfg.seed + 2, step)
        patches = rng.standard_normal(
            (data_cfg.global_batch, Pn, model_cfg.frontend_dim)).astype(np.float32)
        tokens = toks[:, :data_cfg.seq_len - Pn]
        labels = np.concatenate(
            [np.zeros((data_cfg.global_batch, Pn), np.int64),
             toks[:, 1:data_cfg.seq_len - Pn + 1]], axis=1)
        mask = np.concatenate(
            [np.zeros((data_cfg.global_batch, Pn), np.float32),
             np.ones((data_cfg.global_batch, data_cfg.seq_len - Pn), np.float32)],
            axis=1)
        return {"tokens": jnp.asarray(tokens), "patches": jnp.asarray(patches),
                "labels": jnp.asarray(labels), "mask": jnp.asarray(mask)}
    return {"tokens": jnp.asarray(toks[:, :-1]),
            "labels": jnp.asarray(toks[:, 1:])}


def batches(model_cfg: ModelConfig, data_cfg: DataConfig,
            start_step: int = 0) -> Iterator[tuple[int, dict]]:
    step = start_step
    while True:
        yield step, make_batch(model_cfg, data_cfg, step)
        step += 1
