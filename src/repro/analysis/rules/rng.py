"""seeded-rng-only: randomness in serving/ and core/ flows from a seed.

FaultPlane's recovery contract and the mesh-parity tests both depend on
bit-reproducible schedules: a fault schedule, sampler, or dispatch
tiebreak that draws from wall-clock time or an unseeded generator cannot
be replayed, so the chaos soak loses its oracle. In `serving/` and
`core/` this rule flags

  · `time.time()` — wall-clock entropy (time.monotonic / perf_counter for
    *measuring* durations stay fine),
  · the stdlib `random` module's global functions (`random.random()`,
    `random.randint(...)` ...) — `random.Random(seed)` instances are fine,
  · legacy `np.random.*` globals (`np.random.rand`, `np.random.seed`, ...)
    and `np.random.default_rng()` called without a seed — only
    `np.random.default_rng(seed)` (any explicit argument) passes.

jax.random needs no rule: it cannot be called without an explicit key.
"""
from __future__ import annotations

import ast

from repro.analysis.diagnostics import Diagnostic
from repro.analysis.lint import LintContext, dotted_name, import_aliases
from repro.analysis.rules import register

RULE = "seeded-rng-only"
SCOPES = ("serving", "core")


def _check_file(sf) -> list[Diagnostic]:
    aliases = import_aliases(sf.tree, {"numpy": "numpy", "time": "time",
                                       "random": "random"})
    np_names = {n for n, t in aliases.items() if t == "numpy"}
    time_mods = {n for n, t in aliases.items() if t == "time" and n == "time"}
    # names imported *from* random, e.g. `from random import randint`
    random_funcs = {n for n, t in aliases.items()
                    if t == "random" and n not in ("random", "Random")}
    diags = []
    for node in ast.walk(sf.tree):
        if not isinstance(node, ast.Call):
            continue
        name = dotted_name(node.func)
        if name is None:
            if isinstance(node.func, ast.Name) \
                    and node.func.id in random_funcs:
                name = f"random.{node.func.id}"
            else:
                continue
        parts = name.split(".")
        if name == "time.time" and parts[0] in time_mods:
            diags.append(Diagnostic(
                RULE, sf.path, node.lineno,
                "time.time() is wall-clock entropy — schedules must be "
                "seed-derived (time.monotonic for duration measurement "
                "is fine)"))
        elif parts[0] == "random" and len(parts) == 2 \
                and parts[1] != "Random":
            diags.append(Diagnostic(
                RULE, sf.path, node.lineno,
                f"global `{name}()` draws from unseeded process state; "
                "use np.random.default_rng(seed) or random.Random(seed)"))
        elif len(parts) == 3 and parts[0] in np_names \
                and parts[1] == "random":
            fn = parts[2]
            if fn == "default_rng":
                if not node.args and not node.keywords:
                    diags.append(Diagnostic(
                        RULE, sf.path, node.lineno,
                        "np.random.default_rng() without a seed is "
                        "OS-entropy seeded — pass the component's "
                        "explicit seed"))
            elif fn != "Generator":
                diags.append(Diagnostic(
                    RULE, sf.path, node.lineno,
                    f"legacy np.random.{fn} uses the unseeded global "
                    "state; use np.random.default_rng(seed)"))
    return diags


@register(RULE)
def seeded_rng_only(ctx: LintContext) -> list[Diagnostic]:
    diags = []
    for scope in SCOPES:
        for sf in ctx.in_dir(scope):
            diags.extend(_check_file(sf))
    return diags
