"""no-host-sync-in-impl: jitted bodies never pull values to the host.

The serving stack's `host_fetches == steps` contract means every decode
step costs exactly one device->host fetch, made by the *engine glue* after
the jit returns. A host sync **inside** a jitted body — `int()`/`float()`
on a traced value, `.item()`, `np.asarray`, `jax.device_get`,
`.block_until_ready()` — either fails at trace time in the best case or
(via concretization during warmup paths) silently serializes the hot loop
in the worst.

"Jitted bodies" are found three ways: functions named `_*_impl` (the
serving impl convention), functions passed to a `donate_jit(...)` /
`jit(...)` construction call in the same module, and functions carrying a
`@jax.jit` / `@functools.partial(jax.jit, ...)` decorator (the kernels
convention). Trace-time host values stay allowed: `int(x.shape[0])`,
`len(xs)`, arithmetic on constants, and anything derived only from
static_argnums/static_argnames parameters.
"""
from __future__ import annotations

import ast
import re

from repro.analysis.diagnostics import Diagnostic
from repro.analysis.lint import LintContext, call_root_name, import_aliases
from repro.analysis.rules import register

RULE = "no-host-sync-in-impl"
IMPL_RE = re.compile(r"^_\w*_impl$")
SHAPE_ATTRS = {"shape", "ndim", "size", "dtype"}


def _is_jit_func(func: ast.AST) -> bool:
    return (isinstance(func, ast.Attribute)
            and func.attr in ("jit", "donate_jit")) or \
           (isinstance(func, ast.Name) and func.id == "jit")


def _static_arg_positions(call: ast.Call):
    for kw in call.keywords:
        if kw.arg == "static_argnums":
            node = kw.value
            elts = node.elts if isinstance(node, (ast.Tuple, ast.List)) \
                else [node]
            return tuple(e.value for e in elts
                         if isinstance(e, ast.Constant)
                         and isinstance(e.value, int))
    return ()


def _static_arg_names(call: ast.Call):
    for kw in call.keywords:
        if kw.arg == "static_argnames":
            node = kw.value
            elts = node.elts if isinstance(node, (ast.Tuple, ast.List)) \
                else [node]
            return tuple(e.value for e in elts
                         if isinstance(e, ast.Constant)
                         and isinstance(e.value, str))
    return ()


def jitted_functions(sf):
    """{fn_name: (static_positions, bound, static_names)} for every
    function this module jits by construction call or decorator."""
    out: dict[str, tuple] = {}
    for node in ast.walk(sf.tree):
        if isinstance(node, ast.Call) and _is_jit_func(node.func) \
                and node.args:
            tgt = node.args[0]
            if isinstance(tgt, ast.Attribute):  # pl.donate_jit(self._f_impl)
                out[tgt.attr] = (_static_arg_positions(node), True,
                                 _static_arg_names(node))
            elif isinstance(tgt, ast.Name):     # donate_jit(remap, ...)
                out[tgt.id] = (_static_arg_positions(node), False,
                               _static_arg_names(node))
        elif isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            for dec in node.decorator_list:
                call = dec if isinstance(dec, ast.Call) else None
                tgt = call.func if call else dec
                is_jit = _is_jit_func(tgt) or (
                    call and any(_is_jit_func(a) for a in call.args))
                if is_jit:
                    out[node.name] = ((_static_arg_positions(call),
                                       False, _static_arg_names(call))
                                      if call else ((), False, ()))
    return out


def _static_params(fn: ast.FunctionDef, reg) -> set:
    """Parameter names bound to static_argnums/static_argnames — Python
    values at trace time, free to host-convert."""
    if reg is None:
        return set()
    positions, bound, names = reg
    params = [a.arg for a in fn.args.posonlyargs + fn.args.args]
    out = set(names)
    for p in positions:
        idx = p + 1 if bound and params[:1] == ["self"] else p
        if 0 <= idx < len(params):
            out.add(params[idx])
    return out


def _host_safe(node: ast.AST, static_names: set) -> bool:
    """True if the expression is a trace-time Python value: constants,
    shapes/dtypes/len of anything, statics, and arithmetic thereof."""
    if isinstance(node, ast.Constant):
        return True
    if isinstance(node, ast.Name):
        return node.id in static_names
    if isinstance(node, ast.Attribute):
        return node.attr in SHAPE_ATTRS
    if isinstance(node, ast.Subscript):
        return _host_safe(node.value, static_names) \
            and _host_safe(node.slice, static_names)
    if isinstance(node, ast.Call):
        return isinstance(node.func, ast.Name) and node.func.id == "len"
    if isinstance(node, (ast.BinOp, ast.UnaryOp, ast.BoolOp, ast.Compare,
                         ast.Tuple, ast.List, ast.IfExp, ast.Slice)):
        return all(_host_safe(c, static_names)
                   for c in ast.iter_child_nodes(node)
                   if not isinstance(c, (ast.operator, ast.unaryop,
                                         ast.boolop, ast.cmpop,
                                         ast.expr_context)))
    return False


@register(RULE)
def no_host_sync_in_impl(ctx: LintContext) -> list[Diagnostic]:
    diags = []
    for path in sorted(ctx.files):
        sf = ctx.files[path]
        jitted = jitted_functions(sf)
        np_aliases = {n for n, t in import_aliases(
            sf.tree, {"numpy": "numpy"}).items() if t == "numpy"}
        jax_aliases = {n for n, t in import_aliases(
            sf.tree, {"jax": "jax"}).items() if t == "jax"}
        seen = set()
        for fn in ast.walk(sf.tree):
            if not isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            reg = jitted.get(fn.name)
            if reg is None and not IMPL_RE.match(fn.name):
                continue
            statics = _static_params(fn, reg)
            for node in ast.walk(fn):
                if not isinstance(node, ast.Call):
                    continue
                key, msg = None, None
                f = node.func
                if isinstance(f, ast.Attribute):
                    if f.attr == "item":
                        msg = ".item() forces a device->host transfer " \
                              "inside a jitted body"
                    elif f.attr == "block_until_ready":
                        msg = ".block_until_ready() inside a jitted body " \
                              "serializes the hot loop"
                    elif f.attr == "device_get" \
                            and call_root_name(f) in jax_aliases:
                        msg = "jax.device_get inside a jitted body is a " \
                              "host sync"
                    elif f.attr in ("asarray", "array") \
                            and call_root_name(f) in np_aliases \
                            and not all(_host_safe(a, statics)
                                        for a in node.args):
                        msg = f"np.{f.attr} on a traced value " \
                              "concretizes it on the host"
                elif isinstance(f, ast.Name) and f.id in ("int", "float") \
                        and node.args \
                        and not all(_host_safe(a, statics)
                                    for a in node.args):
                    msg = f"{f.id}() on a traced value is a host sync; " \
                          "keep the value on-device (or thread it via " \
                          "static_argnums if it is a Python scalar)"
                if msg:
                    key = (node.lineno, msg)
                    if key not in seen:
                        seen.add(key)
                        diags.append(Diagnostic(
                            RULE, sf.path, node.lineno,
                            f"in jitted body `{fn.name}`: {msg}"))
    return diags
