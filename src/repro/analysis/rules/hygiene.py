"""repo-hygiene: no build artifacts in the index, .gitignore stays armed.

Fails CI the moment a bytecode/cache artifact gets committed: any tracked
path containing `__pycache__`, `*.pyc`, `.pytest_cache`, `*.egg-info`,
`.ipynb_checkpoints` or `.DS_Store` is flagged, and `.gitignore` must
carry the `__pycache__/` and `*.pyc` patterns so the artifacts never show
up as untracked noise in the first place. Working-tree-only cache dirs
(e.g. a local `tests/__pycache__/`) are fine — only the git index counts.

The tracked-file list and .gitignore text are injectable on the
LintContext for tests; by default they come from `git ls-files` at the
repo root (silently skipped when git/the index is unavailable, e.g. a
source tarball).
"""
from __future__ import annotations

import re
import subprocess

from repro.analysis.diagnostics import Diagnostic
from repro.analysis.lint import LintContext
from repro.analysis.rules import register

RULE = "repo-hygiene"
ARTIFACT_RE = re.compile(
    r"(^|/)__pycache__(/|$)|\.pyc$|(^|/)\.pytest_cache(/|$)"
    r"|\.egg-info(/|$)|(^|/)\.ipynb_checkpoints(/|$)|(^|/)\.DS_Store$")
REQUIRED_IGNORES = ("__pycache__/", "*.pyc")


def _tracked_files(ctx: LintContext):
    if ctx.tracked_files is not None:
        return ctx.tracked_files
    try:
        out = subprocess.run(
            ["git", "-C", str(ctx.root), "ls-files"],
            capture_output=True, text=True, timeout=30)
    except (OSError, subprocess.TimeoutExpired):
        return None
    if out.returncode != 0:
        return None
    return out.stdout.splitlines()


def _gitignore(ctx: LintContext):
    if ctx.gitignore_text is not None:
        return ctx.gitignore_text
    p = ctx.root / ".gitignore"
    return p.read_text() if p.exists() else ""


@register(RULE)
def repo_hygiene(ctx: LintContext) -> list[Diagnostic]:
    diags = []
    tracked = _tracked_files(ctx)
    if tracked is None:
        return diags  # no git index to audit (tarball checkout)
    for path in tracked:
        if ARTIFACT_RE.search(path):
            diags.append(Diagnostic(
                RULE, path, 1,
                "build artifact tracked in git — `git rm --cached` it; "
                ".gitignore should be keeping it out"))
    ignore_lines = {ln.strip() for ln in _gitignore(ctx).splitlines()}
    for pat in REQUIRED_IGNORES:
        if pat not in ignore_lines:
            diags.append(Diagnostic(
                RULE, ".gitignore", 1,
                f"missing `{pat}` pattern — bytecode artifacts would "
                "show up as untracked noise and eventually get committed"))
    return diags
