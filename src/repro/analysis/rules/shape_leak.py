"""no-shape-leak: static_argnums never see raw data-dependent shapes.

The serving jits bound retraces by pow2-bucketing every shape-like Python
value before it reaches a `static_argnums` slot (`_bucket` in
serving/arena.py and friends). Feeding a static slot a raw
`.shape`-derived value — `self._resume(..., toks.shape[1])` — silently
reintroduces one recompile per distinct length and defeats the bucketing
that keeps warmup bounded.

The rule pairs the two halves up per module: pass 1 records every
`<placement>.donate_jit(fn, static_argnums=...)` / `jax.jit(...)`
construction assigned to a name; pass 2 checks each call through that
name and flags static-position arguments whose expression mentions
`.shape` / `.ndim` / `.size` outside a bucketing call
(`_bucket(x.shape[0])`, `_pow2_floor(...)`, `next_pow2(...)` are the
sanctioned spellings).
"""
from __future__ import annotations

import ast

from repro.analysis.diagnostics import Diagnostic
from repro.analysis.lint import LintContext
from repro.analysis.rules import register
from repro.analysis.rules.host_sync import (_is_jit_func,
                                            _static_arg_positions)

RULE = "no-shape-leak"
SHAPE_ATTRS = {"shape", "ndim", "size"}
BUCKET_FNS = {"_bucket", "bucket", "_pow2_floor", "pow2_floor", "next_pow2",
              "_next_pow2"}


def _jit_bindings(tree) -> dict[str, tuple]:
    """{bound name: static positions} for `self._f = pl.donate_jit(...,
    static_argnums=...)` style assignments (bare-name targets too)."""
    out = {}
    for node in ast.walk(tree):
        if not isinstance(node, ast.Assign) or len(node.targets) != 1:
            continue
        call = node.value
        if not (isinstance(call, ast.Call) and _is_jit_func(call.func)):
            continue
        positions = _static_arg_positions(call)
        if not positions:
            continue
        tgt = node.targets[0]
        if isinstance(tgt, ast.Attribute):
            out[tgt.attr] = positions
        elif isinstance(tgt, ast.Name):
            out[tgt.id] = positions
    return out


def _raw_shape_use(node: ast.AST) -> bool:
    """Does this expression read .shape/.ndim/.size outside a bucketing
    call?"""
    if isinstance(node, ast.Call):
        fn = node.func
        name = fn.attr if isinstance(fn, ast.Attribute) else \
            fn.id if isinstance(fn, ast.Name) else None
        if name in BUCKET_FNS:
            return False  # bucketed: pow2-bounded by construction
    if isinstance(node, ast.Attribute) and node.attr in SHAPE_ATTRS:
        return True
    return any(_raw_shape_use(c) for c in ast.iter_child_nodes(node))


@register(RULE)
def no_shape_leak(ctx: LintContext) -> list[Diagnostic]:
    diags = []
    for path in sorted(ctx.files):
        sf = ctx.files[path]
        bindings = _jit_bindings(sf.tree)
        if not bindings:
            continue
        for node in ast.walk(sf.tree):
            if not isinstance(node, ast.Call):
                continue
            fn = node.func
            name = fn.attr if isinstance(fn, ast.Attribute) else \
                fn.id if isinstance(fn, ast.Name) else None
            positions = bindings.get(name)
            if not positions:
                continue
            for pos in positions:
                if pos < len(node.args) and _raw_shape_use(node.args[pos]):
                    diags.append(Diagnostic(
                        RULE, sf.path, node.lineno,
                        f"static arg {pos} of `{name}` is fed a raw "
                        ".shape-derived value — every distinct shape "
                        "retraces; bucket it first (_bucket / "
                        "_pow2_floor) so retraces stay O(log n)"))
    return diags
