"""proxy-jax-free: the OmniProxy never sees jax.

The PD-disaggregation contract (docs/serving.md §OmniProxy) keeps
`core/proxy/` a pure-Python/numpy control plane: dispatch math, radix
trees, request lifecycle and metrics must be runnable on a frontend host
with no accelerator runtime. This rule flags

  · any direct `import jax` / `import jax.numpy` (or `from jax...`) in a
    module under core/proxy/, and
  · any intra-repo import whose transitive closure reaches a module that
    imports jax — so a "harmless" `from repro.serving.x import helper`
    cannot smuggle the dependency in.

Function-local (lazy) jax imports count too: the proxy has no business
importing jax even lazily.
"""
from __future__ import annotations

import ast
from typing import Optional

from repro.analysis.diagnostics import Diagnostic
from repro.analysis.lint import LintContext, SourceFile
from repro.analysis.rules import register

RULE = "proxy-jax-free"
PROXY_PREFIX = "repro.core.proxy"


def _jax_import_line(sf: SourceFile) -> Optional[int]:
    """First line importing jax (any spelling), or None."""
    for node in ast.walk(sf.tree):
        if isinstance(node, ast.Import):
            for a in node.names:
                if a.name == "jax" or a.name.startswith("jax."):
                    return node.lineno
        elif isinstance(node, ast.ImportFrom):
            m = node.module or ""
            if m == "jax" or m.startswith("jax."):
                return node.lineno
    return None


def _intra_repo_imports(sf: SourceFile) -> list[tuple[str, int]]:
    """(imported repro.* module, lineno) pairs, relative imports resolved."""
    pkg = sf.module if sf.path.endswith("__init__.py") \
        else sf.module.rsplit(".", 1)[0]
    out = []
    for node in ast.walk(sf.tree):
        if isinstance(node, ast.Import):
            for a in node.names:
                if a.name == "repro" or a.name.startswith("repro."):
                    out.append((a.name, node.lineno))
        elif isinstance(node, ast.ImportFrom):
            if node.level:  # relative: resolve against the package
                base = pkg.split(".")
                if node.level > 1:
                    base = base[: -(node.level - 1)]
                mod = ".".join(base + ([node.module] if node.module else []))
            else:
                mod = node.module or ""
            if mod == "repro" or mod.startswith("repro."):
                out.append((mod, node.lineno))
                # `from repro.x import y` may name submodules, not attrs
                for a in node.names:
                    out.append((f"{mod}.{a.name}", node.lineno))
    return out


def _resolve(ctx: LintContext, modname: str) -> Optional[SourceFile]:
    sf = ctx.module_file(modname)
    if sf is None and "." in modname:  # attr import: try the parent module
        sf = ctx.module_file(modname.rsplit(".", 1)[0])
    return sf


@register(RULE)
def proxy_jax_free(ctx: LintContext) -> list[Diagnostic]:
    diags = []
    # memoized "does this module reach jax" over the intra-repo import graph
    reaches: dict[str, Optional[list[str]]] = {}

    def chain_to_jax(modname: str, stack: tuple) -> Optional[list[str]]:
        if modname in reaches:
            return reaches[modname]
        if modname in stack:  # import cycle: break, no new info
            return None
        sf = _resolve(ctx, modname)
        if sf is None:
            reaches[modname] = None
            return None
        if _jax_import_line(sf) is not None:
            reaches[modname] = [sf.module]
            return reaches[modname]
        reaches[modname] = None  # provisional (cycle safety)
        for dep, _ in _intra_repo_imports(sf):
            sub = chain_to_jax(dep, stack + (modname,))
            if sub:
                reaches[modname] = [sf.module] + sub
                return reaches[modname]
        return None

    for sf in ctx.in_dir("core/proxy"):
        line = _jax_import_line(sf)
        if line is not None:
            diags.append(Diagnostic(
                RULE, sf.path, line,
                "OmniProxy modules must stay jax-free (the proxy is a "
                "pure-host control plane); move device work behind the "
                "serving engines"))
        seen = set()
        for dep, lineno in _intra_repo_imports(sf):
            if dep.startswith(PROXY_PREFIX):
                continue  # proxy-internal imports are vetted by this walk
            chain = chain_to_jax(dep, (sf.module,))
            if chain and (lineno, tuple(chain)) not in seen:
                seen.add((lineno, tuple(chain)))
                diags.append(Diagnostic(
                    RULE, sf.path, lineno,
                    f"transitive jax dependency: {sf.module} -> "
                    + " -> ".join(chain)
                    + " (imports jax); the proxy must not depend on "
                    "device-side modules"))
    return diags
