"""Pluggable rule registry for the contract linter.

A rule is a function `rule(ctx: LintContext) -> list[Diagnostic]`
registered under its kebab-case id. Importing this package populates
`RULES`; `repro.analysis.lint.run_rules` consumes it. Adding a rule =
adding a module here with a `@register("my-rule")` function plus a
catalog entry in docs/analysis.md.
"""
from __future__ import annotations

from typing import Callable

RULES: dict[str, Callable] = {}


def register(name: str):
    def deco(fn):
        RULES[name] = fn
        fn.rule_name = name
        return fn
    return deco


# importing the rule modules registers them (must come after register())
from repro.analysis.rules import host_sync    # noqa: E402,F401
from repro.analysis.rules import hygiene      # noqa: E402,F401
from repro.analysis.rules import jit_choke    # noqa: E402,F401
from repro.analysis.rules import proxy_imports  # noqa: E402,F401
from repro.analysis.rules import rng          # noqa: E402,F401
from repro.analysis.rules import shape_leak   # noqa: E402,F401
