"""donate-jit-choke-point: serving jits are built in exactly one place.

Every hot-loop jit in `serving/` must be constructed through
`DevicePlacement.donate_jit` (serving/placement.py) — that choke point
pins out-shardings so donated arena/state layouts are a fixed point, wires
donation, and registers the jit in the HotLoopRegistry the jaxpr auditor
walks. A bare `jax.jit(...)` (or `pl.jit`, `from jax import jit`, a
`@jax.jit` decorator) anywhere else in serving/ bypasses all three, so any
`.jit` spelling outside placement.py is flagged.
"""
from __future__ import annotations

import ast

from repro.analysis.diagnostics import Diagnostic
from repro.analysis.lint import LintContext, import_aliases
from repro.analysis.rules import register

RULE = "donate-jit-choke-point"
CHOKE_POINT = "src/repro/serving/placement.py"


def _jit_uses(sf) -> list[int]:
    """Line numbers of every `<x>.jit(...)` call, bare `jit(...)` call
    where `jit` was imported from jax, and `@...jit` decorator."""
    jit_names = {name for name, _ in import_aliases(
        sf.tree, {"jax": "jax"}).items() if name == "jit"}
    lines = []

    def is_jit(func: ast.AST) -> bool:
        if isinstance(func, ast.Attribute) and func.attr == "jit":
            return True
        if isinstance(func, ast.Name) and func.id in jit_names:
            return True
        return False

    for node in ast.walk(sf.tree):
        if isinstance(node, ast.Call) and is_jit(node.func):
            lines.append(node.lineno)
        elif isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            for dec in node.decorator_list:
                tgt = dec.func if isinstance(dec, ast.Call) else dec
                if is_jit(tgt):
                    lines.append(dec.lineno)
                elif isinstance(dec, ast.Call):  # functools.partial(jax.jit)
                    if any(is_jit(a) for a in dec.args):
                        lines.append(dec.lineno)
    return lines


@register(RULE)
def donate_jit_choke_point(ctx: LintContext) -> list[Diagnostic]:
    diags = []
    for sf in ctx.in_dir("serving"):
        if sf.path == CHOKE_POINT:
            continue
        for line in _jit_uses(sf):
            diags.append(Diagnostic(
                RULE, sf.path, line,
                "bare jit construction in serving/ — route through "
                "DevicePlacement.donate_jit so out-shardings are pinned, "
                "donation is wired, and the jit lands in the "
                "HotLoopRegistry"))
    return diags
