"""ContractGuard layer 2 — the jaxpr / lowering hot-loop auditor.

Layer 1 reads source; this layer reads what jax actually built. Every jit
constructed through `DevicePlacement.donate_jit` lands in the placement's
`HotLoopRegistry` as a `HotLoopEntry` that captures abstract argument
signatures (shape/dtype/sharding) at its first real call. Post-warmup —
after a live `Server` has stepped real requests through the hot loops —
`audit_placement` re-traces and re-lowers each called entry from those
signatures (never touching live donated buffers) and asserts four
contracts on the artifact:

  · **purity** — no callback / debug / infeed / outfeed primitives
    anywhere in the jaxpr (a `jax.debug.print` left in a hot loop is a
    per-step host round-trip);
  · **no f64** — no `convert_element_type` to float64/complex128 and no
    f64-valued intermediate (serving runs with x64 disabled; an f64 leak
    would double KV bandwidth the moment that flag flips);
  · **donation** — for entries built with `donate_argnums`, input→output
    buffer aliasing is actually present in the lowered module
    (`tf.aliasing_output`); a dtype/shape mismatch silently turns a
    donated in-place update into a full copy per step;
  · **out-shardings** — on a multi-device mesh, the compiled executable's
    output shardings are exactly the placement's own spec tree for that
    entry, so donated layouts are a fixed point and the arg-sharding jit
    cache never churns;
  · **quant-upcast** — when a hot loop takes int8 arena payload leaves
    (QuantPlane), no floating-point eqn output may materialize a
    full-arena-sized twin of one: dequantization is licensed only on
    GATHERED views (a handful of tabled blocks), so a float tensor with
    an int8 leaf's full [N, K, bs, h] trailing shape means the whole
    quantized arena was silently upcast to f32 in HBM — exactly the copy
    the in-tile dequant contract exists to forbid.

Entries that were registered but never called (e.g. `_extract` when no
preemption happened during warmup) are reported as skipped, not failed —
pass `require_called=True` to turn those into findings instead.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import jax
import numpy as np

from repro.serving.placement import DevicePlacement, HotLoopEntry

BANNED_SUBSTR = ("callback",)
BANNED_EXACT = {"infeed", "outfeed"}
BANNED_PREFIX = ("debug",)
F64_DTYPES = (np.dtype("float64"), np.dtype("complex128"))


@dataclass
class AuditFinding:
    entry: str
    check: str          # purity | f64 | donation | out-shardings | trace
    detail: str

    def format(self) -> str:
        return f"[{self.check}] {self.entry}: {self.detail}"


@dataclass
class AuditReport:
    audited: list = field(default_factory=list)   # entry names traced
    skipped: list = field(default_factory=list)   # registered, never called
    findings: list = field(default_factory=list)
    checks: dict = field(default_factory=dict)    # check -> times performed

    def ok(self) -> bool:
        return not self.findings

    def format(self) -> str:
        lines = [f.format() for f in self.findings]
        lines.append(
            f"jaxpr audit: {len(self.audited)} hot loop(s) audited "
            f"({', '.join(self.audited)}), {len(self.skipped)} skipped, "
            f"{len(self.findings)} finding(s); checks: "
            + ", ".join(f"{k}={v}" for k, v in sorted(self.checks.items())))
        return "\n".join(lines)

    def _count(self, check: str) -> None:
        self.checks[check] = self.checks.get(check, 0) + 1


# ---------------------------------------------------------------------------
# jaxpr walking
# ---------------------------------------------------------------------------

def _subjaxprs(params: dict):
    for v in params.values():
        vs = v if isinstance(v, (list, tuple)) else (v,)
        for x in vs:
            if isinstance(x, jax.core.ClosedJaxpr):
                yield x.jaxpr
            elif isinstance(x, jax.core.Jaxpr):
                yield x


def iter_eqns(jaxpr):
    """Every eqn in a (closed) jaxpr, recursing into scan/cond/pjit/...
    sub-jaxprs carried in eqn params."""
    if isinstance(jaxpr, jax.core.ClosedJaxpr):
        jaxpr = jaxpr.jaxpr
    stack = [jaxpr]
    while stack:
        j = stack.pop()
        for eqn in j.eqns:
            yield eqn
            stack.extend(_subjaxprs(eqn.params))


def _entry_jaxpr(entry: HotLoopEntry):
    """Re-trace the raw fn from the captured abstract signature (kwargs
    remapped to a positional tail so static_argnums keep their indices)."""
    args = tuple(entry.abstract_args)
    kwargs = dict(entry.abstract_kwargs or {})
    if not kwargs:
        return jax.make_jaxpr(entry.fn,
                              static_argnums=entry.static_argnums)(*args)
    names = sorted(kwargs)
    n = len(args)

    def positional(*a):
        return entry.fn(*a[:n], **dict(zip(names, a[n:])))

    call = args + tuple(kwargs[k] for k in names)
    return jax.make_jaxpr(positional,
                          static_argnums=entry.static_argnums)(*call)


# ---------------------------------------------------------------------------
# the four checks
# ---------------------------------------------------------------------------

def _check_purity(entry, jaxpr, report):
    report._count("purity")
    for eqn in iter_eqns(jaxpr):
        name = eqn.primitive.name
        if (name in BANNED_EXACT or name.startswith(BANNED_PREFIX)
                or any(s in name for s in BANNED_SUBSTR)):
            report.findings.append(AuditFinding(
                entry.name, "purity",
                f"banned primitive `{name}` in the hot loop — host "
                f"round-trip per step"))


def _np_dtype(dt):
    """np.dtype or None for jax extended dtypes (prng keys etc.) and
    dtype-less avals (np.dtype(None) would default to float64!)."""
    if dt is None:
        return None
    try:
        return np.dtype(dt)
    except TypeError:
        return None


def _check_f64(entry, jaxpr, report):
    report._count("f64")
    for eqn in iter_eqns(jaxpr):
        if eqn.primitive.name == "convert_element_type":
            dt = _np_dtype(eqn.params.get("new_dtype"))
            if dt is not None and dt in F64_DTYPES:
                report.findings.append(AuditFinding(
                    entry.name, "f64",
                    f"convert_element_type to {dt} in the hot loop"))
        for v in eqn.outvars:
            aval = getattr(v, "aval", None)
            dt = _np_dtype(getattr(aval, "dtype", None)) \
                if aval is not None else None
            # NB: np.dtype(...) == None is True in numpy — guard explicitly
            if dt is not None and dt in F64_DTYPES:
                report.findings.append(AuditFinding(
                    entry.name, "f64",
                    f"f64 intermediate produced by `{eqn.primitive.name}`"))


FLOAT_DTYPES = (np.dtype("float32"), np.dtype("bfloat16"),
                np.dtype("float16"))


def _check_quant_upcast(entry, jaxpr, report):
    """No silent dequantized arena copy: collect the trailing-4 shapes
    [N, K, bs, h] of every int8 input leaf with ndim >= 4 (quantized
    arena payloads — the stacked [R, N, K, bs, h] leaves share the same
    trailing signature), then flag any float eqn output carrying one.
    Gathered per-block views ([M << N, K, bs, h] with M the tabled block
    count) don't collide — M never equals the pool-wide N in a hot loop."""
    jx = jaxpr.jaxpr if isinstance(jaxpr, jax.core.ClosedJaxpr) else jaxpr
    sigs = set()
    for v in jx.invars:
        aval = getattr(v, "aval", None)
        dt = _np_dtype(getattr(aval, "dtype", None)) \
            if aval is not None else None
        shp = getattr(aval, "shape", None)
        if dt == np.dtype("int8") and shp is not None and len(shp) >= 4:
            sigs.add(tuple(shp[-4:]))
    if not sigs:
        return
    report._count("quant-upcast")
    for eqn in iter_eqns(jaxpr):
        for v in eqn.outvars:
            aval = getattr(v, "aval", None)
            dt = _np_dtype(getattr(aval, "dtype", None)) \
                if aval is not None else None
            shp = getattr(aval, "shape", None)
            if (dt in FLOAT_DTYPES and shp is not None and len(shp) >= 4
                    and tuple(shp[-4:]) in sigs):
                report.findings.append(AuditFinding(
                    entry.name, "quant-upcast",
                    f"`{eqn.primitive.name}` materializes a {dt} tensor "
                    f"{tuple(shp)} with a quantized arena leaf's full "
                    f"block shape — the int8 arena was upcast to float in "
                    f"HBM instead of dequantized in-tile"))


def _check_donation(entry, lowered, report):
    if not entry.donate_argnums:
        return
    report._count("donation")
    text = lowered.as_text()
    n_alias = text.count("tf.aliasing_output")
    if n_alias == 0:
        report.findings.append(AuditFinding(
            entry.name, "donation",
            f"donate_argnums={entry.donate_argnums} but the lowered "
            f"module has no input-output aliasing — donation was dropped "
            f"(shape/dtype mismatch between donated input and outputs?) "
            f"and every step pays a full copy"))


def _check_out_shardings(entry, lowered, report):
    """Compiled output shardings must equal the placement's own spec tree
    — only meaningful on a multi-device mesh (the 1-device choke point
    drops the pin by design)."""
    pl = entry.placement
    if entry.out_specs is None or pl.n_devices == 1:
        return
    report._count("out-shardings")
    compiled = lowered.compile()
    is_shard = lambda x: isinstance(x, jax.sharding.Sharding)  # noqa: E731
    actual = jax.tree.leaves(compiled.output_shardings, is_leaf=is_shard)
    expected = jax.tree.leaves(pl.tree_shardings(entry.out_specs),
                               is_leaf=is_shard)
    out_shapes = jax.tree.leaves(jax.eval_shape(
        lambda *a, **k: entry.fn(*a, **k),
        *entry.abstract_args, **(entry.abstract_kwargs or {})))
    if not (len(actual) == len(expected) == len(out_shapes)):
        report.findings.append(AuditFinding(
            entry.name, "out-shardings",
            f"spec tree shape mismatch: {len(expected)} pinned specs vs "
            f"{len(actual)} compiled outputs"))
        return
    for i, (act, exp, shp) in enumerate(zip(actual, expected, out_shapes)):
        ndim = len(shp.shape)
        eq = act.is_equivalent_to(exp, ndim) \
            if hasattr(act, "is_equivalent_to") else act == exp
        if not eq:
            report.findings.append(AuditFinding(
                entry.name, "out-shardings",
                f"output {i}: compiled sharding {act} != pinned "
                f"{exp.spec} — donated layout is not a fixed point"))


# ---------------------------------------------------------------------------
# entry points
# ---------------------------------------------------------------------------

def audit_entry(entry: HotLoopEntry, report: AuditReport) -> None:
    try:
        jaxpr = _entry_jaxpr(entry)
        lowered = entry.lower()
    except Exception as e:  # re-trace must never crash the audit silently
        report.findings.append(AuditFinding(
            entry.name, "trace", f"re-trace/lower failed: {e!r}"))
        return
    _check_purity(entry, jaxpr, report)
    _check_f64(entry, jaxpr, report)
    _check_quant_upcast(entry, jaxpr, report)
    _check_donation(entry, lowered, report)
    _check_out_shardings(entry, lowered, report)
    report.audited.append(entry.name)


def audit_placement(placement: DevicePlacement, *,
                    require_called: bool = False) -> AuditReport:
    """Audit every hot loop registered on (and called through) this
    placement. Call after warmup — entries capture their abstract arg
    signature at first call."""
    report = AuditReport()
    for entry in placement.hot_loops.entries:
        if entry.abstract_args is None:
            if require_called:
                report.findings.append(AuditFinding(
                    entry.name, "trace",
                    "registered but never called during warmup"))
            else:
                report.skipped.append(entry.name)
            continue
        audit_entry(entry, report)
    return report


def audit_server(server, *, require_called: bool = False) -> AuditReport:
    return audit_placement(server.placement, require_called=require_called)
