"""CLI: `python -m repro.analysis [--strict] [--rule ID ...]`.

Exit 0 when the tree is contract-clean. Non-strict mode fails only on
unwaived diagnostics; `--strict` (what CI and the bench preamble run)
additionally fails on stale waivers and waivers missing a justification,
so the waiver set can never rot. Waived diagnostics are always echoed
with their justification.
"""
from __future__ import annotations

import argparse
import sys

from repro.analysis.lint import run_lint
from repro.analysis.rules import RULES


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="ContractGuard AST contract linter (layer 1). The "
                    "jaxpr hot-loop audit (layer 2) needs a live server: "
                    "run `pytest tests/test_analysis.py -m jaxpr_audit`.")
    ap.add_argument("--strict", action="store_true",
                    help="also fail on stale/unjustified waivers")
    ap.add_argument("--rule", action="append", metavar="ID",
                    help="run only this rule id (repeatable)")
    ap.add_argument("--list-rules", action="store_true",
                    help="print the rule catalog and exit")
    args = ap.parse_args(argv)

    if args.list_rules:
        for name in sorted(RULES):
            doc = (RULES[name].__doc__ or
                   sys.modules[RULES[name].__module__].__doc__ or "")
            head = doc.strip().splitlines()[0] if doc.strip() else ""
            print(f"{name:24s} {head}")
        return 0

    rules = None
    if args.rule:
        unknown = set(args.rule) - set(RULES)
        if unknown:
            ap.error(f"unknown rule(s): {', '.join(sorted(unknown))} "
                     f"(see --list-rules)")
        rules = {r: RULES[r] for r in args.rule}

    report = run_lint(rules=rules)
    print(report.format(strict=args.strict))
    return 0 if report.ok(strict=args.strict) else 1


if __name__ == "__main__":
    sys.exit(main())
