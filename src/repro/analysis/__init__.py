"""ContractGuard — static analysis for the serving stack's invariants.

Two layers (see docs/analysis.md):

  · `repro.analysis.lint` — AST contract linter over src/repro with
    pluggable rules (`repro.analysis.rules`) and inline waivers; run as
    `python -m repro.analysis [--strict]`.
  · `repro.analysis.jaxpr_audit` — post-warmup auditor over the
    `HotLoopRegistry` that `DevicePlacement.donate_jit` populates: traces
    every registered hot-loop jit and asserts on the jaxpr/lowering
    (no callbacks, no f64, donation wired, out-shardings pinned).
"""
from repro.analysis.diagnostics import Diagnostic, Report
from repro.analysis.lint import run_lint

__all__ = ["Diagnostic", "Report", "run_lint", "contract_gate"]


def contract_gate() -> None:
    """Assert-gated preamble for benchmarks: refuse to run on a tree that
    fails the contract lint (cheap — pure AST, no jax import)."""
    report = run_lint()
    assert report.ok(strict=True), \
        "contract lint failed — fix or waive before benchmarking:\n" \
        + report.format(strict=True)
