"""ContractGuard layer 1 — the AST contract linter.

Walks every Python file under `src/repro/`, parses it once, and runs the
pluggable rule set from `repro.analysis.rules` over the shared
`LintContext`. Rules are pure functions `rule(ctx) -> [Diagnostic]`; the
engine owns file discovery, waiver application (see diagnostics.py) and
report assembly. `run_lint(files=...)` accepts an in-memory
{relpath: source} mapping so the test suite can lint fixture snippets
through the exact same pipeline CI runs.

The rules encode the serving stack's architectural invariants (see
docs/analysis.md for the catalog): the OmniProxy stays jax-free, every
serving hot-loop jit routes through `DevicePlacement.donate_jit`, jitted
bodies never host-sync, rng flows from explicit seeds, static_argnums are
never fed raw `.shape`-dependent values, and no build artifacts are ever
tracked.
"""
from __future__ import annotations

import ast
from dataclasses import dataclass, field
from pathlib import Path
from typing import Callable, Optional

from repro.analysis.diagnostics import Diagnostic, Report, scan_waivers

# repo root = parents[3] of this file (src/repro/analysis/lint.py)
REPO_ROOT = Path(__file__).resolve().parents[3]
SRC_PREFIX = "src/repro"


@dataclass
class SourceFile:
    path: str                 # repo-relative posix path
    source: str
    tree: ast.AST
    lines: list[str]

    @property
    def module(self) -> str:
        """src/repro/serving/decode.py -> repro.serving.decode"""
        p = self.path
        if p.startswith("src/"):
            p = p[len("src/"):]
        if p.endswith("/__init__.py"):
            p = p[: -len("/__init__.py")]
        elif p.endswith(".py"):
            p = p[:-3]
        return p.replace("/", ".")


@dataclass
class LintContext:
    root: Path
    files: dict[str, SourceFile]
    # overridable for tests; None -> rules that need them ask git / disk
    tracked_files: Optional[list[str]] = None
    gitignore_text: Optional[str] = None
    _by_module: dict = field(default_factory=dict)

    def __post_init__(self):
        self._by_module = {sf.module: sf for sf in self.files.values()}

    def module_file(self, modname: str) -> Optional[SourceFile]:
        """Resolve `repro.x.y` to its SourceFile (package __init__ counts)."""
        return self._by_module.get(modname)

    def in_dir(self, prefix: str):
        """All files under a src/repro-relative dir, e.g. 'serving'."""
        full = f"{SRC_PREFIX}/{prefix.rstrip('/')}/"
        return [sf for p, sf in sorted(self.files.items())
                if p.startswith(full)]


def _parse(path: str, source: str) -> Optional[SourceFile]:
    try:
        tree = ast.parse(source, filename=path)
    except SyntaxError:
        return None
    return SourceFile(path, source, tree, source.splitlines())


def build_context(root: Optional[Path] = None,
                  files: Optional[dict[str, str]] = None,
                  **kw) -> LintContext:
    root = Path(root) if root is not None else REPO_ROOT
    srcs: dict[str, SourceFile] = {}
    if files is not None:
        for relpath, source in files.items():
            sf = _parse(relpath, source)
            if sf is not None:
                srcs[relpath] = sf
    else:
        for f in sorted((root / SRC_PREFIX).rglob("*.py")):
            rel = f.relative_to(root).as_posix()
            sf = _parse(rel, f.read_text())
            if sf is not None:
                srcs[rel] = sf
    return LintContext(root, srcs, **kw)


def run_rules(ctx: LintContext,
              rules: Optional[dict[str, Callable]] = None) -> Report:
    from repro.analysis.rules import RULES
    rules = RULES if rules is None else rules
    report = Report()
    for name in sorted(rules):
        for d in rules[name](ctx):
            report.diagnostics.append(d)
    for sf in ctx.files.values():
        report.waivers.extend(scan_waivers(sf.path, sf.lines))
    report.apply_waivers()
    return report


def run_lint(root: Optional[Path] = None,
             files: Optional[dict[str, str]] = None,
             rules: Optional[dict[str, Callable]] = None,
             **kw) -> Report:
    """One-call entry: build the context, run every rule, apply waivers."""
    return run_rules(build_context(root, files, **kw), rules)


# ---------------------------------------------------------------------------
# shared AST helpers used by several rules
# ---------------------------------------------------------------------------

def import_aliases(tree: ast.AST, targets: dict[str, str]) -> dict[str, str]:
    """Map local names to the canonical module they alias.

    targets: {canonical: canonical} filter, e.g. {"jax": "jax",
    "jax.experimental.pallas": "pallas", "numpy": "numpy"}. Returns
    {local_name: tag} for every `import X as Y` / `from X import Y` whose
    source module matches a target (by exact name or dotted prefix).
    """
    out: dict[str, str] = {}

    def match(modname: str) -> Optional[str]:
        for canon, tag in targets.items():
            if modname == canon or modname.startswith(canon + "."):
                return tag
        return None

    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for a in node.names:
                tag = match(a.name)
                if tag:
                    out[(a.asname or a.name.split(".")[0])] = tag
        elif isinstance(node, ast.ImportFrom) and node.module:
            tag = match(node.module)
            if tag:
                for a in node.names:
                    out[a.asname or a.name] = tag
    return out


def call_root_name(func: ast.AST) -> Optional[str]:
    """`np.random.default_rng` -> 'np'; `int` -> 'int'."""
    while isinstance(func, ast.Attribute):
        func = func.value
    return func.id if isinstance(func, ast.Name) else None


def dotted_name(func: ast.AST) -> Optional[str]:
    """`np.random.default_rng` -> 'np.random.default_rng' (None if the
    chain bottoms out in anything but a Name)."""
    parts = []
    while isinstance(func, ast.Attribute):
        parts.append(func.attr)
        func = func.value
    if not isinstance(func, ast.Name):
        return None
    parts.append(func.id)
    return ".".join(reversed(parts))
