"""Diagnostics + inline-waiver syntax for the contract linter.

A rule emits `Diagnostic`s with a (path, line) anchor. A diagnostic can be
waived **narrowly** — one rule, one line — with an inline comment on the
flagged line or the line directly above it:

    x = int(flags)  # contract: waive <rule-id> -- flags is a trace-time
                    # Python int threaded through static_argnums

(with `<rule-id>` e.g. `no-host-sync-in-impl`). The justification after
`--` is mandatory: a waiver without one is itself
reported (`waiver-missing-justification`), and a waiver comment that never
matches a diagnostic is reported as stale (`stale-waiver`) so waivers
cannot outlive the violation they excuse. Waived diagnostics are echoed in
the report together with their justification — a waiver hides nothing, it
just downgrades the exit code.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Optional

WAIVER_RE = re.compile(
    r"#\s*contract:\s*waive\s+(?P<rule>[a-z0-9-]+)"
    r"(?:\s*--\s*(?P<why>.*\S))?\s*$")

# internal rule ids used for waiver bookkeeping problems
WAIVER_STALE = "stale-waiver"
WAIVER_NO_WHY = "waiver-missing-justification"


@dataclass
class Diagnostic:
    rule: str
    path: str          # repo-relative, posix separators
    line: int          # 1-indexed
    msg: str
    waived: bool = False
    justification: str = ""

    def format(self) -> str:
        tag = "WAIVED" if self.waived else "ERROR"
        s = f"{self.path}:{self.line}: [{self.rule}] {tag}: {self.msg}"
        if self.waived:
            s += f"\n    waiver: {self.justification or '(no justification)'}"
        return s


@dataclass
class Waiver:
    rule: str
    path: str
    line: int                   # line the waiver comment sits on
    justification: str
    used: bool = False

    def covers(self, d: Diagnostic) -> bool:
        # a waiver covers its own line and the line below it (comment-above
        # style); it never reaches further
        return (d.rule == self.rule and d.path == self.path
                and d.line in (self.line, self.line + 1))


def scan_waivers(path: str, lines: list[str]) -> list[Waiver]:
    out = []
    for i, text in enumerate(lines, start=1):
        m = WAIVER_RE.search(text)
        if m:
            out.append(Waiver(m.group("rule"), path, i,
                              (m.group("why") or "").strip()))
    return out


@dataclass
class Report:
    diagnostics: list[Diagnostic] = field(default_factory=list)
    waivers: list[Waiver] = field(default_factory=list)

    def apply_waivers(self) -> None:
        for d in self.diagnostics:
            for w in self.waivers:
                if w.covers(d):
                    d.waived, d.justification, w.used = True, w.justification, True
                    break

    def waiver_problems(self) -> list[Diagnostic]:
        """Strict-mode extras: stale waivers and missing justifications."""
        probs = []
        for w in self.waivers:
            if not w.used:
                probs.append(Diagnostic(
                    WAIVER_STALE, w.path, w.line,
                    f"waiver for '{w.rule}' matches no diagnostic — "
                    f"remove it (the violation it excused is gone)"))
            elif not w.justification:
                probs.append(Diagnostic(
                    WAIVER_NO_WHY, w.path, w.line,
                    f"waiver for '{w.rule}' has no justification — "
                    f"append `-- <why this is sound>`"))
        return probs

    def errors(self, strict: bool = False) -> list[Diagnostic]:
        errs = [d for d in self.diagnostics if not d.waived]
        if strict:
            errs += self.waiver_problems()
        return errs

    def waived(self) -> list[Diagnostic]:
        return [d for d in self.diagnostics if d.waived]

    def format(self, strict: bool = False) -> str:
        chunks = []
        errs = self.errors(strict)
        for d in sorted(errs, key=lambda d: (d.path, d.line, d.rule)):
            chunks.append(d.format())
        for d in sorted(self.waived(), key=lambda d: (d.path, d.line)):
            chunks.append(d.format())
        n_w = len(self.waived())
        chunks.append(f"contract lint: {len(errs)} error(s), "
                      f"{n_w} waived diagnostic(s)")
        return "\n".join(chunks)

    def ok(self, strict: bool = False) -> bool:
        return not self.errors(strict)
