"""Discrete-event cluster simulator for paper Tables 1-2.

Runs the REAL OmniProxy (core/proxy) against simulated Ascend-910C prefill /
decode instances under a closed-loop workload. Component effects:

  OmniPlacement → per-step MoE imbalance multiplier B(t). Without placement,
    B(t) follows drifting zipf expert loads (sampled trajectory from
    core/placement's imbalance calculator under round-robin placement); with
    placement, the DynamicScheduler rebalances the same trajectory and the
    achieved B(t) is used. Same algorithm code as production.
  OmniAttn → KV bytes ratio (kv_bytes_for_pattern on the DeepSeek-like stack)
    scales decode-step KV reads AND raises the HBM-capacity sequence cap.
  OmniProxy → the actual scheduling policies (APC-aware prefill dispatch,
    LPT decode, deferred submission). Disabling reverts to Nginx round-robin.

Time advances on a heap of events; decode instances emit one token per step
for all resident sequences (continuous batching).
"""
from __future__ import annotations

import heapq
import itertools
from dataclasses import dataclass, field
from typing import Optional

import numpy as np

from repro.core.placement.dynamic import DynamicScheduler, SchedulerConfig
from repro.core.placement.static import calculate_imbalance, round_robin
from repro.core.proxy import MetricsAggregator, OASConfig, OmniProxy, Request
from repro.sim.hardware import AscendNodeModel, DeepSeekR1Model
from repro.sim.workload import WorkloadConfig, closed_loop_requests


@dataclass
class SimConfig:
    n_prefill: int = 6            # xP in xPyD
    n_decode: int = 1             # yD
    decode_dies: int = 64         # D32 = 64 dies (4 nodes)
    prefill_dies: int = 16        # P8 → one node TP16
    batch_per_die: int = 40
    concurrency: Optional[int] = None   # default: system batch × 1.2
    n_requests: int = 1500
    use_placement: bool = True
    use_omniattn: bool = True
    use_proxy: bool = True
    attn_window: int = 4224       # sink+recent: OmniAttn caps effective ctx
    placement_interval: float = 2.0     # scheduler tick period (s)
    seed: int = 0
    workload: WorkloadConfig = field(default_factory=WorkloadConfig)
    node: AscendNodeModel = field(default_factory=AscendNodeModel)
    model: DeepSeekR1Model = field(default_factory=DeepSeekR1Model)
    max_sim_s: float = 3600.0


class _ExpertLoadProcess:
    """Drifting zipf expert-load trajectory shared by both arms (placement
    on/off) so the comparison is paired."""

    def __init__(self, cfg: SimConfig):
        self.rng = np.random.default_rng(cfg.seed + 7)
        m = cfg.model
        self.n_layers = 8                 # representative MoE layers tracked
        self.E = m.n_experts
        self.ep = 16
        # moderately skewed expert popularity (hot experts ≈ 6-10× median,
        # matching published DeepSeek routing statistics) with slow drift
        self.loads = self.rng.lognormal(0.0, 0.8, (self.n_layers, self.E))
        self.slots = self.E // self.ep + 1

    def step(self):
        """Random-walk drift + occasional hot-spot shift."""
        drift = self.rng.lognormal(0, 0.08, self.loads.shape)
        self.loads = self.loads * drift
        if self.rng.random() < 0.10:      # workload shift: new hot experts
            l = self.rng.integers(0, self.n_layers)
            hot = self.rng.integers(0, self.E, 3)
            self.loads[l, hot] *= self.rng.uniform(1.5, 3.0)
        self.loads *= self.E / self.loads.sum(axis=1, keepdims=True)
        return self.loads.copy()


class ClusterSim:
    def __init__(self, cfg: SimConfig):
        self.cfg = cfg
        oas = OASConfig() if cfg.use_proxy else \
            OASConfig(cache_aware=False, lpt=False, deferred=False)
        self.proxy = OmniProxy(cfg.n_prefill, cfg.n_decode, oas)
        self.metrics = MetricsAggregator()
        # decode capacity: slots per instance bounded by HBM KV capacity
        avg_ctx = cfg.workload.mean_in + cfg.workload.mean_out / 2
        kv_cap_ratio = (min(cfg.attn_window, avg_ctx) / avg_ctx
                        if cfg.use_omniattn else 1.0)
        cap = cfg.model.kv_hbm_capacity_seqs(cfg.node, avg_ctx,
                                             cfg.decode_dies, kv_cap_ratio)
        self.slots_per_instance = min(cfg.batch_per_die, cap) * cfg.decode_dies
        # expert-load process + optional dynamic scheduler
        self.loadproc = _ExpertLoadProcess(cfg)
        self.placement_sched = None
        if cfg.use_placement:
            self.placement_sched = DynamicScheduler(
                ep=self.loadproc.ep, n_experts=self.loadproc.E,
                n_layers=self.loadproc.n_layers,
                cfg=SchedulerConfig(budget=self.loadproc.n_layers * 2,
                                    max_slots=self.loadproc.slots + 2,
                                    b_trigger=1.15, delta=0.02),
                placements=[round_robin(self.loadproc.E, self.loadproc.ep,
                                        self.loadproc.slots)
                            for _ in range(self.loadproc.n_layers)])
        self.moe_B = self._imbalance_now(init=True)
        self.migration_count = 0

        # simulated instance state (speed factor models real-cluster
        # stragglers: transient 1.5-2.5× slowdowns the proxy must route around)
        self._straggle_rng = np.random.default_rng(cfg.seed + 99)
        self.prefill_speed = np.ones(cfg.n_prefill)
        self.prefill_busy_until = [0.0] * cfg.n_prefill
        self.decode_active: list[dict] = [dict() for _ in range(cfg.n_decode)]
        self.decode_queue: list[list] = [[] for _ in range(cfg.n_decode)]
        self._step_scheduled = [False] * cfg.n_decode
        self._events: list = []
        self._eid = itertools.count()
        self._done_count = 0
        self._rid = itertools.count()
        self._req_meta: dict[int, tuple] = {}

    # ------------------------------------------------------------------
    def _imbalance_now(self, init=False) -> float:
        loads = self.loadproc.loads if init else self.loadproc.step()
        if self.placement_sched is not None:
            self.placement_sched.step(loads)
            return self.placement_sched.current_imbalance()
        rr = round_robin(self.loadproc.E, self.loadproc.ep, self.loadproc.slots)
        return float(np.mean([calculate_imbalance(rr, loads[l])
                              for l in range(self.loadproc.n_layers)]))

    def _push(self, t, kind, payload=None):
        heapq.heappush(self._events, (t, next(self._eid), kind, payload))

    # ------------------------------------------------------------------
    def run(self) -> dict:
        cfg = self.cfg
        reqs = closed_loop_requests(cfg.workload, cfg.n_requests)
        conc = cfg.concurrency or int(self.slots_per_instance *
                                      cfg.n_decode * 1.05)
        self._backlog = list(reversed(reqs))
        now = 0.0
        for _ in range(min(conc, len(self._backlog))):
            self._inject(now)
        self._push(cfg.placement_interval, "placement_tick")
        self._push(0.0, "proxy_tick")

        while self._events and now < cfg.max_sim_s:
            now, _, kind, payload = heapq.heappop(self._events)
            if kind == "proxy_tick":
                self._handle_proxy_tick(now)
                if self.proxy.inflight or self._backlog:
                    self._push(now + 0.005, "proxy_tick")
            elif kind == "prefill_done":
                self._handle_prefill_done(now, payload)
            elif kind == "decode_step":
                self._handle_decode_step(now, payload)
            elif kind == "placement_tick":
                self.moe_B = self._imbalance_now()
                # straggler process: each tick, instances may enter/leave a
                # degraded state (e.g. host contention, link flaps)
                r = self._straggle_rng
                for i in range(self.cfg.n_prefill):
                    if self.prefill_speed[i] == 1.0 and r.random() < 0.10:
                        self.prefill_speed[i] = r.uniform(1.6, 2.6)
                    elif self.prefill_speed[i] > 1.0 and r.random() < 0.4:
                        self.prefill_speed[i] = 1.0
                if self.placement_sched and self.placement_sched.history and \
                        self.placement_sched.history[-1].get("rebalanced"):
                    self.migration_count += 1
                self._push(now + cfg.placement_interval, "placement_tick")
            if not self.proxy.inflight and not self._backlog:
                break
        summary = self.metrics.summary(now)
        # steady-state QPM: completions between the 20th and 80th percentile
        # finish times (excludes warmup fill and long-tail drain)
        fins = sorted(r.finish_time for r in self.metrics.done)
        if len(fins) >= 20:
            i0, i1 = int(0.2 * len(fins)), int(0.8 * len(fins))
            span = max(fins[i1] - fins[i0], 1e-9)
            summary["qpm"] = 60.0 * (i1 - i0) / span
        summary.update(wall_s=now, moe_imbalance_final=self.moe_B,
                       migrations=self.migration_count,
                       slots_per_instance=self.slots_per_instance,
                       rebalances=(self.placement_sched.n_rebalances
                                   if self.placement_sched else 0))
        return summary

    # ------------------------------------------------------------------
    def _inject(self, now):
        if not self._backlog:
            return
        lin, lout, group = self._backlog.pop()
        rid = next(self._rid)
        # token-id stand-in: group prefix ids make the radix tree see real
        # shared prefixes without materializing full token arrays
        if group >= 0:
            pfx = min(self.cfg.workload.prefix_len, lin)
            tokens = tuple([(group << 20) | i for i in range(pfx)]) + \
                tuple([(rid << 22) | i for i in range(lin - pfx)])
        else:
            tokens = tuple([(rid << 22) | i for i in range(lin)])
        req = Request(rid, tokens, lout, arrival=now)
        self._req_meta[rid] = (lin, lout)
        self.proxy.submit(req, now)

    def _handle_proxy_tick(self, now):
        for req, inst, stage in self.proxy.tick(now):
            if stage == "prefill":
                iid = inst.iid
                new_tokens = req.prompt_len - req.prefix_match
                t_service = self.cfg.model.prefill_time(
                    max(new_tokens, 64), self.cfg.node, self.cfg.prefill_dies,
                    self.moe_B) * self.prefill_speed[iid]
                start = max(now, self.prefill_busy_until[iid])
                self.prefill_busy_until[iid] = start + t_service
                self._push(start + t_service, "prefill_done",
                           (req.rid, t_service))
            else:
                iid = inst.iid
                self.decode_queue[iid].append(req.rid)
                if not self._step_scheduled[iid]:
                    self._step_scheduled[iid] = True
                    self._push(now, "decode_step", iid)

    def _handle_prefill_done(self, now, payload):
        rid, t_service = payload
        req = self.proxy.inflight.get(rid)
        if req is None:
            return
        self.proxy.on_prefill_start(req, now - t_service)
        # KV transfer P→D before the decode queue sees it
        eff_len = min(req.prompt_len, self.cfg.attn_window) \
            if self.cfg.use_omniattn else req.prompt_len
        kv_bytes = eff_len * self.cfg.model.kv_bytes_per_token
        t_xfer = kv_bytes / self.cfg.node.interconnect_bw
        self.proxy.on_prefill_done(req, now + t_xfer, batch_time=t_service)
        self.proxy.on_first_token(req, now + t_xfer)
        req.output_tokens.append(0)

    def _handle_decode_step(self, now, iid):
        self._step_scheduled[iid] = False
        active = self.decode_active[iid]
        # admit from queue up to slot cap
        while self.decode_queue[iid] and len(active) < self.slots_per_instance:
            rid = self.decode_queue[iid].pop(0)
            req = self.proxy.inflight.get(rid)
            if req is None:
                continue
            self.proxy.on_decode_start(req, now)
            active[rid] = 0
        if not active:
            if self.proxy.inflight or self._backlog:
                self._step_scheduled[iid] = True
                self._push(now + 0.005, "decode_step", iid)
            return
        bpd = max(len(active) / self.cfg.decode_dies, 0.25)
        ctxs = np.array([self._req_meta[r][0] + active[r] for r in active],
                        dtype=float)
        if self.cfg.use_omniattn:   # compressed layers cap effective context
            ctxs = np.minimum(ctxs, self.cfg.attn_window)
        t_step = self.cfg.model.decode_step_time(
            bpd, float(ctxs.mean()), self.cfg.node, self.cfg.decode_dies,
            moe_imbalance=self.moe_B)
        done_rids = []
        for rid in list(active):
            active[rid] += 1
            req = self.proxy.inflight.get(rid)
            if req is None:
                done_rids.append(rid)
                continue
            req.output_tokens.append(0)
            if active[rid] >= self._req_meta[rid][1]:
                done_rids.append(rid)
        for rid in done_rids:
            req = self.proxy.inflight.get(rid)
            active.pop(rid, None)
            if req is not None:
                self.proxy.on_decode_done(req, now + t_step, batch_time=t_step)
                self.metrics.add(req)
                self._done_count += 1
                self._inject(now + t_step)   # closed loop
        self._step_scheduled[iid] = True
        self._push(now + t_step, "decode_step", iid)
