"""Synthetic workload matching the paper §6.1: long-tail lognormal lengths,
mean input ≈3500, mean output ≈1000, input+output capped at 16k, a fraction of
requests sharing long prefixes (system prompts → APC hits), closed-loop fixed
concurrency."""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np


@dataclass
class WorkloadConfig:
    mean_in: float = 3500.0
    mean_out: float = 1000.0
    sigma_in: float = 0.9         # lognormal shape → pronounced long tail
    sigma_out: float = 1.0
    cap_total: int = 16384
    shared_prefix_frac: float = 0.35
    n_prefix_groups: int = 8
    prefix_len: int = 1024
    seed: int = 0


def _lognormal(rng, mean, sigma, n):
    mu = np.log(mean) - sigma ** 2 / 2
    return np.maximum(rng.lognormal(mu, sigma, n).astype(np.int64), 16)


def closed_loop_requests(cfg: WorkloadConfig, n: int):
    """[(prompt_tokens_tuple_or_len, out_len, prefix_group)] — the simulator
    uses lengths + group ids; the real engine uses token tuples."""
    rng = np.random.default_rng(cfg.seed)
    lin = _lognormal(rng, cfg.mean_in, cfg.sigma_in, n)
    lout = _lognormal(rng, cfg.mean_out, cfg.sigma_out, n)
    total = lin + lout
    over = total > cfg.cap_total
    scale = np.where(over, cfg.cap_total / total, 1.0)
    lin = np.maximum((lin * scale).astype(np.int64), 16)
    lout = np.maximum((lout * scale).astype(np.int64), 16)
    groups = np.where(rng.random(n) < cfg.shared_prefix_frac,
                      rng.integers(0, cfg.n_prefix_groups, n), -1)
    return [(int(lin[i]), int(lout[i]), int(groups[i])) for i in range(n)]


def request_tokens(rng: np.random.Generator, lin: int, group: int,
                   prefix_len: int, vocab: int = 50000) -> tuple:
    """Materialize token ids (real engine): shared prefix per group."""
    if group >= 0:
        g = np.random.default_rng(group + 12345)
        prefix = g.integers(0, vocab, min(prefix_len, lin)).tolist()
        rest = rng.integers(0, vocab, max(lin - len(prefix), 0)).tolist()
        return tuple(prefix + rest)
    return tuple(rng.integers(0, vocab, lin).tolist())
