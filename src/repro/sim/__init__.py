from repro.sim.cluster import ClusterSim, SimConfig
from repro.sim.hardware import AscendNodeModel, DeepSeekR1Model
from repro.sim.workload import WorkloadConfig, closed_loop_requests

__all__ = ["ClusterSim", "SimConfig", "AscendNodeModel", "DeepSeekR1Model",
           "WorkloadConfig", "closed_loop_requests"]
