"""Hardware + model cost models for the cluster simulator.

The simulator reproduces paper Tables 1-2 (Ascend 910C, DeepSeek-R1 INT8).
Constants marked CALIBRATED are fit so the baseline (w/o all) lands near the
paper's 404 QPM / 75 ms TPOT at 6P8-1D32, then held fixed across every other
configuration — the table trends are then *predictions* of the model, not fits.
"""
from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class AscendNodeModel:
    dies_per_node: int = 16           # 8 × 910C, 2 dies each
    die_flops: float = 176e12         # INT8-effective per die
    die_hbm_bw: float = 1.6e12        # bytes/s
    die_hbm_gb: float = 64.0
    interconnect_bw: float = 56e9     # inter-node, bytes/s (per die share)
    mfu_prefill: float = 0.26         # CALIBRATED achievable fraction
    mfu_decode: float = 0.18


@dataclass(frozen=True)
class DeepSeekR1Model:
    n_params: float = 671e9
    n_active: float = 37e9
    bytes_per_param: float = 1.0      # INT8
    n_layers: int = 61
    kv_bytes_per_token: float = 70e3  # MLA compressed KV (c_kv 512 + rope 64)
    moe_layers: int = 58
    n_experts: int = 256
    top_k: int = 8

    def prefill_time(self, n_tokens: int, node: AscendNodeModel,
                     tp_dies: int, moe_imbalance: float = 1.0) -> float:
        """Compute-bound prefill on one TP16 instance."""
        flops = 2.0 * self.n_active * n_tokens
        eff = node.die_flops * node.mfu_prefill * tp_dies
        return flops / eff * moe_imbalance

    # decode kernel efficiency knobs (CALIBRATED once at 6P8-1D32 baseline)
    attn_bw_eff: float = 0.08         # paged-KV gather achieves ~8% of HBM bw
    step_overhead_s: float = 0.004    # launch/sync/sampling per step

    def decode_step_time(self, batch_per_die: float, avg_ctx_eff: float,
                         node: AscendNodeModel, dp_dies: int,
                         moe_imbalance: float = 1.0) -> float:
        """One token for `batch_per_die` seqs on each die of a decode instance.

        t_attn: KV gather, bandwidth-bound at attn_bw_eff × HBM (OmniAttn caps
          avg_ctx_eff at the sink+recent window for compressed layers);
        t_ffn: max(expert compute, per-die expert weight read), scaled by the
          OmniPlacement imbalance ratio B (slowest device gates the step);
        t_comm: MoE all-to-all dispatch+combine over the interconnect.
        """
        kv_bytes = batch_per_die * avg_ctx_eff * self.kv_bytes_per_token
        t_attn = kv_bytes / (node.die_hbm_bw * self.attn_bw_eff)
        weight_bytes = self.n_params * self.bytes_per_param / dp_dies
        t_ffn = max(2.0 * self.n_active * batch_per_die /
                    (node.die_flops * node.mfu_decode),
                    weight_bytes / node.die_hbm_bw) * moe_imbalance
        a2a_bytes = batch_per_die * self.moe_layers * self.top_k * 7168 * 2 * 2
        t_comm = a2a_bytes / node.interconnect_bw
        return t_attn + t_ffn + t_comm + self.step_overhead_s

    def kv_hbm_capacity_seqs(self, node: AscendNodeModel, avg_ctx: float,
                             dp_dies: int, kv_ratio: float = 1.0,
                             weight_frac: float = 0.45) -> int:
        """Max resident sequences per die given HBM after weights."""
        free = node.die_hbm_gb * 1e9 * (1 - weight_frac)
        per_seq = avg_ctx * self.kv_bytes_per_token * kv_ratio
        return max(int(free / per_seq), 1)
