"""Device-side batched sampling: one fused temperature/top-k/top-p
categorical draw for a whole decode batch (or a batch of finished prefills).

The function is pure and trace-friendly: `DecodeEngine` calls it inside its
donated step jit (per-slot parameter tensors + per-slot PRNG base keys live
in the device-side slot state), and `PrefillEngine` jits it once over the
stacked last-token logits of every prompt finished in an engine round — in
both cases sampling adds zero host syncs beyond the single per-step token
fetch the engines already pay.

Greedy rows (temperature <= 0) take a `where` branch around the categorical
machinery and return `argmax(logits)` computed exactly as the pre-sampling
engines did, so greedy streams stay bit-identical.

Per-row PRNG keys are folded with the row's context length
(`fold_in(base_key, n_context)`), making each draw a pure function of
(seed, position): the sampled stream is invariant to engine layout (paged
vs slot-dense), admission batch composition, and preemption/resume.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def sample_tokens(logits, temperature, top_k, top_p, keys, fold):
    """logits [n, V] (any float dtype; filtered/compared in float32),
    temperature [n] f32, top_k [n] i32 (<= 0 disables), top_p [n] f32
    (>= 1 disables), keys [n, 2] uint32 base PRNG keys, fold [n] i32 context
    lengths at this sample point. → sampled token ids [n] i32.
    """
    logits = logits.astype(jnp.float32)
    n, V = logits.shape
    greedy_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    is_greedy = temperature <= 0.0

    def _sampled():
        scaled = logits / jnp.maximum(temperature, 1e-6)[:, None]
        # one descending sort serves both filters
        order = jnp.argsort(-scaled, axis=-1)
        ranked = jnp.take_along_axis(scaled, order, axis=-1)
        # top-k: threshold at the k-th ranked logit (boundary ties are kept —
        # standard top-k semantics, and the tie set is sampled proportionally)
        k = jnp.where(top_k > 0, jnp.clip(top_k, 1, V), V).astype(jnp.int32)
        kth = jnp.take_along_axis(ranked, (k - 1)[:, None], axis=-1)
        keep = scaled >= kth
        # top-p: keep ranks whose EXCLUSIVE cumulative probability is < p, so
        # the top-1 token always survives and the mass kept first crosses p
        probs = jax.nn.softmax(ranked, axis=-1)
        excl = jnp.cumsum(probs, axis=-1) - probs
        keep_ranked = excl < top_p[:, None]
        rows = jnp.arange(n)[:, None]
        keep2 = keep & jnp.zeros_like(keep).at[rows, order].set(keep_ranked)
        masked = jnp.where(keep2, scaled, -jnp.inf)
        step_keys = jax.vmap(jax.random.fold_in)(keys, fold)
        sampled = jax.vmap(jax.random.categorical)(step_keys,
                                                   masked).astype(jnp.int32)
        return jnp.where(is_greedy, greedy_tok, sampled)

    # all-greedy batches (the serving default) skip the O(V log V) sort /
    # softmax / categorical machinery entirely — argmax is the whole step
    return jax.lax.cond(jnp.all(is_greedy), lambda: greedy_tok, _sampled)
