"""PD-disaggregated continuous-batching server: OmniProxy + engines, wall-clock.

The end-to-end driver for deliverable (b): serves a real (small) model with
batched requests through the full paper stack — APC-aware prefill dispatch
with radix-backed partial-prefix KV reuse, chunked prefill interleaved with
decode rounds (the prefill_tick_budget knob arbitrates the TTFT/TPOT
trade-off per tick), LPT decode scheduling with batched admission, deferred
submission, sink+recent compressed caches, and (for MoE configs)
OmniPlacement live expert-load monitoring with pipelined weight migration.

Request-level API (vLLM-style): `add_request(prompt, SamplingParams) → rid`
registers an open-loop request with its own temperature/top-k/top-p/seed/
stop-token configuration; `step()` advances every engine one round and
returns per-request `RequestOutput` deltas (new tokens + finish_reason in
{stop, length, abort}); `abort(rid)` cancels a request wherever it lives
(proxy pools, prefill queues, pending KV handoffs, decode slots + KVPool
blocks); `generate(prompts, params)` is a streaming iterator over the same
primitives. `run()` — the closed-batch entry the benchmarks use — is a thin
loop over add_request/step, so greedy outputs are unchanged.

Request lifecycle: proxy tick (eq. 8 dispatch) → chunked prefill (shortest-
remaining-first across queued prompts, resumed at radix prefix boundaries) →
KV handoff (batched donated insert) → continuous-batch decode (device-side
slot state incl. per-slot sampling params + PRNG keys; KVPool-preempted
requests re-enter decode_wait with their extracted cache). See
docs/serving.md.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.placement import DynamicScheduler, SchedulerConfig
from repro.core.placement.migration import tables_from_placement_from_slots
from repro.core.proxy import (BackpressureError, MetricsAggregator, OASConfig,
                              OmniProxy, Phase, Request, RequestOutput,
                              SamplingParams)
from repro.models import moe as moe_mod
from repro.models.lm import LM
from repro.serving.arena import BlockHandoff, KVArena
from repro.serving.decode import DecodeEngine
from repro.serving.placement import DevicePlacement
from repro.serving.prefill import PrefillEngine
from repro.serving.quant import QuantConfig, QuantController
from repro.serving.spec import SpecConfig


@dataclass
class ServerConfig:
    n_prefill: int = 1
    n_decode: int = 1
    decode_slots: int = 8
    max_len: int = 256
    oas: OASConfig = field(default_factory=OASConfig)
    chunked_prefill: bool = True      # chunk + interleave prefill with decode
    chunk_tokens: int = 64            # prefill chunk size (jit bucket ceiling)
    prefill_tick_budget: int = 128    # prefill tokens per tick: ↑TTFT-biased,
                                      # ↓TPOT-biased (the paper's P/D knob)
    prefix_reuse: bool = True         # radix partial-prefix KV resume
    prefix_cache_cap: int = 32        # stored prefixes per prefill instance
    prefix_cache_cap_bytes: Optional[int] = None   # byte cap (real sizes)
    kv_blocks: Optional[int] = None   # decode KVPool size override
    paged_kv: bool = True             # physically paged decode KV arenas
    kv_block_size: int = 16           # tokens per KV block
    enable_placement: bool = True     # OmniPlacement dynamic scheduler
    placement_interval: int = 16      # decode steps between monitor ticks
    eos_token: int = -1               # DEPRECATED: server-global stop token,
                                      # used only when a request's
                                      # SamplingParams.stop_token_ids is
                                      # empty (-1 → run to max_tokens)
    idle_sleep_s: float = 0.01        # max per-iteration sleep while run()
                                      # waits for a future arrival
    spec: Optional[SpecConfig] = None  # model-free speculative decoding
                                       # (SpecPlane; None → off, no change)
    quant: Optional[QuantConfig] = None  # int8 paged KV arenas (QuantPlane;
                                         # None → off, f32 arenas unchanged)
    # ---- FaultPlane recovery knobs (None → off, no behavior change) ----
    watchdog_steps: Optional[int] = None    # retire a request whose progress
                                            # marker is unchanged for N steps
                                            # with finish_reason="timeout"
    watchdog_wall_s: Optional[float] = None  # same, wall-clock bound
    admission_queue_cap: Optional[int] = None  # shed (BackpressureError) when
                                               # the admission backlog exceeds
                                               # this many waiting requests
    placement_cfg: Optional[SchedulerConfig] = None  # OmniPlacement scheduler
                                               # override (None → defaults
                                               # with budget=0, table-width
                                               # max_slots)


class Server:
    def __init__(self, cfg: ModelConfig, scfg: ServerConfig,
                 mesh=None, rng=None,
                 pattern: Optional[list] = None, params=None, faults=None,
                 placement: Optional[DevicePlacement] = None):
        self.cfg, self.scfg = cfg, scfg
        # FaultPlane (serving/faults.py): seeded deterministic fault
        # injection, fired at the top of every step() before any engine work
        self.faults = faults
        # every engine is constructed through the explicit device-placement
        # layer; `mesh` (a MeshCtx) survives as the back-compat spelling
        self.placement = DevicePlacement.of(
            placement if placement is not None else mesh)
        self.mesh = self.placement.ctx
        self.lm = LM.build(cfg, self.mesh, pattern=pattern)
        self.params = self.placement.place_params(self.lm, params) \
            if params is not None else \
            self.lm.init(rng if rng is not None else jax.random.PRNGKey(0))
        self.tables = self.placement.replicate(self.lm.default_tables())
        self.proxy = OmniProxy(scfg.n_prefill, scfg.n_decode, scfg.oas)
        self.metrics = MetricsAggregator()
        # one shared paged-KV runtime for every co-located engine: prefill
        # writes chunk KV straight into its arenas, decode extends them, and
        # admission hands over block tables — zero-copy. The default pool
        # grants every decode slot max_len capacity plus one prompt of
        # prefill headroom per prefill instance; prefix-store snapshots
        # share the pool and are reclaimed (LRU) under pressure.
        self.kv_arena = None
        # QuantPlane: validate the knobs against this stack (raises on
        # quant-over-dense-KV; degrades to None when no full-attention
        # layer exists to quantize) BEFORE any arena is allocated
        self.quant_ctl = QuantController.from_model(
            cfg, self.lm.plan, scfg.quant, scfg.kv_block_size,
            paged_kv=scfg.paged_kv)
        if scfg.paged_kv:
            max_blocks = -(-scfg.max_len // scfg.kv_block_size)
            n_blocks = scfg.kv_blocks if scfg.kv_blocks is not None else \
                (scfg.n_decode * scfg.decode_slots + scfg.n_prefill) \
                * max_blocks
            self.kv_arena = KVArena.build(self.lm, n_blocks,
                                          scfg.kv_block_size,
                                          placement=self.placement,
                                          quant=self.quant_ctl is not None)
        self.prefills = [
            PrefillEngine(self.lm, self.params, self.tables, scfg.max_len,
                          chunk_tokens=scfg.chunk_tokens,
                          enable_chunked=scfg.chunked_prefill,
                          allow_partial_reuse=scfg.prefix_reuse,
                          cache_cap=scfg.prefix_cache_cap,
                          cache_cap_bytes=scfg.prefix_cache_cap_bytes,
                          tree=self.proxy.trees[i],
                          arena=self.kv_arena,
                          placement=self.placement)
            for i in range(scfg.n_prefill)]
        self.decodes = [DecodeEngine(self.lm, self.params, self.tables,
                                     scfg.decode_slots, scfg.max_len,
                                     kv_blocks=scfg.kv_blocks,
                                     paged=scfg.paged_kv,
                                     block_size=scfg.kv_block_size,
                                     arena=self.kv_arena,
                                     placement=self.placement,
                                     spec=scfg.spec,
                                     spec_radix=self.proxy.trees[0]
                                     if self.proxy.trees else None)
                        for _ in range(scfg.n_decode)]
        if self.quant_ctl is not None:
            # static residency figures next to the per-step counters — the
            # benches read these from decode_stats like every other plane
            for eng in self.decodes:
                eng.stats.update(QuantController.stats_keys())
                self.quant_ctl.note(eng.stats)
        # rid → (cache B=1, next_token, pos, cached_tokens, prompt, params)
        # awaiting admission (prompt drives prefix-block sharing in the
        # paged pool; params land in the slot's device-side sampling state)
        self._pending_kv: dict[int, tuple] = {}
        self._step_count = 0
        self.n_migrations = 0
        # streaming-output plumbing: per-step token deltas, finish records,
        # and out-of-band events (aborts), flushed by step()
        self._next_rid = 0
        self._fresh: dict[int, list[int]] = {}
        self._emitted: dict[int, int] = {}          # rid → tokens delivered
        self._finish_info: dict[int, tuple] = {}    # rid → (reason, total)
        self._events: list[RequestOutput] = []
        self._idle_slept_s = 0.0
        # watchdog state: rid → (progress marker, step seen, wall seen)
        self._wd: dict[int, tuple] = {}
        self.n_handoffs_swept = 0
        self.placement_sched = None
        if scfg.enable_placement and cfg.moe.n_experts:
            s = int(self.tables["slot_expert"].shape[1])
            placement = moe_mod.round_robin_placement(cfg.moe.n_experts,
                                                      self.mesh.ep, s)
            # the engine applies ONE placement table across layers, so the
            # monitor runs on layer-summed counts (n_layers=1 collapse)
            pcfg = scfg.placement_cfg
            if pcfg is None:
                pcfg = SchedulerConfig(budget=0, max_slots=s)
            self.placement_sched = DynamicScheduler(
                ep=self.mesh.ep, n_experts=cfg.moe.n_experts, n_layers=1,
                cfg=pcfg, placements=[placement])
        self.migration_log: list[dict] = []
        self._remap_stack = None        # lazily-built donated remap jit

    # ---- request-level API -------------------------------------------
    def add_request(self, prompt: tuple,
                    params: Optional[SamplingParams] = None,
                    now: Optional[float] = None) -> int:
        """Register an open-loop request under its own SamplingParams;
        → rid. Tokens stream back through step() / generate()."""
        now = time.monotonic() if now is None else now
        params = params if params is not None else SamplingParams()
        rid = self._next_rid
        while rid in self.proxy.inflight:       # never collide with a live
            rid += 1                            # caller-chosen submit() rid
        return self._submit(rid, tuple(prompt), params, now)

    def submit(self, rid: int, prompt: tuple, max_tokens: int, now: float):
        """Legacy closed-batch entry: caller-chosen rid, greedy decoding,
        server-global eos_token. Prefer add_request()."""
        self._submit(rid, tuple(prompt),
                     SamplingParams(max_tokens=max_tokens), now)

    def _submit(self, rid: int, prompt: tuple, params: SamplingParams,
                now: float) -> int:
        self._admission_check(prompt)
        self.proxy.submit(Request(rid, prompt, params.max_tokens,
                                  arrival=now, sampling=params), now)
        self._next_rid = max(self._next_rid, rid + 1)
        return rid

    def _admission_check(self, prompt: tuple):
        """Graceful load shedding: reject at the door — with a typed
        BackpressureError the caller can act on — instead of admitting a
        request that would defer inside the engines forever (livelock).
        Two gates: a prompt no sequence of releases could ever make fit
        (larger than every non-quarantined block), and a bounded admission
        backlog (`admission_queue_cap`, None → unbounded)."""
        if self.kv_arena is not None:
            pool = self.kv_arena.pool
            usable = pool.n_blocks - len(pool.quarantined)
            need = pool.blocks_for(len(prompt))
            if need > usable:
                self.metrics.note_shed()
                raise BackpressureError(
                    f"prompt needs {need} KV blocks but the pool has only "
                    f"{usable} usable ({len(pool.quarantined)} quarantined)")
        cap = self.scfg.admission_queue_cap
        if cap is not None:
            backlog = (len(self.proxy.pending) + len(self.proxy.decode_wait)
                       + len(self._pending_kv)
                       + sum(len(e.queue) for e in self.prefills))
            if backlog >= cap:
                self.metrics.note_shed()
                raise BackpressureError(
                    f"admission backlog {backlog} >= cap {cap}")

    def step(self, now: Optional[float] = None) -> list[RequestOutput]:
        """Advance the whole server one round (proxy tick → prefill round →
        decode round) and return per-request deltas: every token generated
        this step, plus finish records (finish_reason in {stop, length})
        and abort notifications."""
        now = time.monotonic() if now is None else now
        if self.faults is not None:
            # fire scheduled faults (and run their recovery) BEFORE this
            # step's engine rounds: no token is ever computed from corrupt
            # or lost KV, which is what makes completed outputs bit-identical
            # to the fault-free run
            self.faults.on_step(self, self._step_count, now)
        if self.kv_arena is not None:
            self._sweep_orphan_handoffs()
        self._drain_actions(now)
        self._sweep_failed(now)
        self._prefill_round()
        self._decode_round()
        self._watchdog(now)
        return self._flush_outputs()

    def abort(self, rid: int, now: Optional[float] = None) -> bool:
        """Cancel a request wherever it lives: proxy pools, prefill queues,
        pending KV handoffs, decode slots + KVPool blocks. → True if the
        rid was in flight. The next step() (or this call's generate()
        consumer) sees a RequestOutput(finished, finish_reason="abort")."""
        now = time.monotonic() if now is None else now
        req = self.proxy.abort(rid, now)
        if req is None:
            return False
        kv = self._pending_kv.pop(rid, None)
        if kv is not None:
            self._release_handoff(kv[0])
        for eng in self.prefills:
            eng.abort(rid)
        for eng in self.decodes:
            eng.release(rid)                    # no-op where not resident
        self._fresh.pop(rid, None)
        self._finish_info.pop(rid, None)
        n_out = max(len(req.output_tokens), self._emitted.pop(rid, 0))
        self.metrics.add_aborted(req)
        self._events.append(RequestOutput(rid, (), True, "abort", n_out))
        return True

    def generate(self, prompts, params=None,
                 max_wall_s: float = 300.0) -> Iterator[RequestOutput]:
        """Streaming front door: submit one prompt (tuple of ints) or a
        list of prompts — `params` a single SamplingParams, a matching
        list, or None (greedy) — then drive step() and yield every
        RequestOutput as it materializes until all submitted requests
        finish. Yields include any other in-flight requests' outputs (the
        caller drives the shared engine loop)."""
        single = bool(prompts) and isinstance(prompts[0], (int, np.integer))
        plist = [tuple(prompts)] if single else [tuple(p) for p in prompts]
        if params is None or isinstance(params, SamplingParams):
            pparams = [params] * len(plist)
        else:
            pparams = list(params)
            if len(pparams) != len(plist):
                raise ValueError(f"{len(plist)} prompts but "
                                 f"{len(pparams)} SamplingParams")
        t0 = time.monotonic()
        live = {self.add_request(p, sp, now=t0)
                for p, sp in zip(plist, pparams)}
        while live and time.monotonic() - t0 < max_wall_s:
            for out in self.step():
                if out.finished:
                    live.discard(out.rid)
                yield out

    # ---- internals ---------------------------------------------------
    def _release_handoff(self, cache) -> None:
        """Free the arena blocks a zero-copy handoff still owns. Every
        request exit path that drops a cache-bearing record before decode
        admission (abort, early finish, stale re-dispatch result, drained
        _pending_kv) MUST route through here — a missed release leaks
        shared-arena blocks permanently."""
        if isinstance(cache, BlockHandoff):
            self.kv_arena.pool.release(cache.key)

    # ---- FaultPlane recovery machinery -------------------------------
    def _retire_faulted(self, rid: int, reason: str, now: float):
        """Retire a request the recovery machinery gave up on (`"error"`:
        retries exhausted, `"timeout"`: watchdog): release every engine/pool
        resource it holds and emit a terminal RequestOutput. Reuses
        proxy.abort for the accounting unwind — a Phase.FAILED request
        matches no accounting branch by construction."""
        req = self.proxy.abort(rid, now)
        if req is None:
            return
        req.finish_reason = reason
        kv = self._pending_kv.pop(rid, None)
        if kv is not None:
            self._release_handoff(kv[0])
        for eng in self.prefills:
            eng.abort(rid)
        for eng in self.decodes:
            eng.release(rid)
        self._fresh.pop(rid, None)
        self._finish_info.pop(rid, None)
        self._wd.pop(rid, None)
        n_out = max(len(req.output_tokens), self._emitted.pop(rid, 0))
        if reason == "timeout":
            self.metrics.add_timeout(req)
        else:
            self.metrics.add_error(req)
        self._events.append(RequestOutput(rid, (), True, reason, n_out))

    def _sweep_failed(self, now: float):
        """Retire every Phase.FAILED request with finish_reason="error".
        Retry-cap exhaustion (and the no-healthy-instance tick path) only
        advances the phase — without this sweep a FAILED request would sit
        in proxy.inflight forever and run()/generate() would never return
        (the pre-FaultPlane livelock)."""
        for rid in [r.rid for r in list(self.proxy.inflight.values())
                    if r.phase == Phase.FAILED]:
            self._retire_faulted(rid, "error", now)

    def _watchdog(self, now: float):
        """Retire requests whose progress marker has not changed for
        `watchdog_steps` server steps or `watchdog_wall_s` seconds with
        finish_reason="timeout". The marker collapses DECODE_WAIT and
        DECODE_SCHEDULED into one class — admission-requeue ping-pong is
        not progress and must not reset the timer — while prefill cursor
        advance, new output tokens, and a granted retry each re-earn the
        full window."""
        ws, ww = self.scfg.watchdog_steps, self.scfg.watchdog_wall_s
        if ws is None and ww is None:
            return
        live = set()
        for rid, req in list(self.proxy.inflight.items()):
            live.add(rid)
            phase_class = (Phase.DECODE_WAIT if req.phase in
                           (Phase.DECODE_WAIT, Phase.DECODE_SCHEDULED)
                           else req.phase)
            cursor = 0
            for eng in self.prefills:
                for t in eng.queue:
                    if t.rid == rid:
                        cursor = max(cursor, t.cursor)
            marker = (phase_class, cursor, len(req.output_tokens),
                      req.n_retries)
            prev = self._wd.get(rid)
            if prev is None or prev[0] != marker:
                self._wd[rid] = (marker, self._step_count, now)
                continue
            _, step0, t0 = prev
            if (ws is not None and self._step_count - step0 >= ws) or \
                    (ww is not None and now - t0 >= ww):
                self._retire_faulted(rid, "timeout", now)
                live.discard(rid)
        for rid in [r for r in self._wd if r not in live]:
            del self._wd[rid]

    def _sweep_orphan_handoffs(self):
        """Leak backstop for the `("handoff", i)` rename stage: a handoff
        key in the pool referenced by neither a parked `_pending_kv` record
        nor an engine's undelivered-result cache belongs to nobody — no
        code path will ever admit or release it. Dead-instance drops and
        injected handoff faults land here; released blocks return to the
        free list and the sweep is counted (`n_handoffs_swept`)."""
        pool = self.kv_arena.pool
        refs = {kv[0].key for kv in self._pending_kv.values()
                if isinstance(kv[0], BlockHandoff)}
        for eng in self.prefills:
            for r in eng._ready:
                if isinstance(r.cache, BlockHandoff):
                    refs.add(r.cache.key)
        for key in list(pool.per_request):
            if isinstance(key, tuple) and len(key) == 2 \
                    and key[0] == "handoff" and key not in refs:
                pool.release(key)
                self.n_handoffs_swept += 1

    def recover_corruption(self, now: Optional[float] = None) -> list:
        """Summary-plane corruption recovery: scan the arena for blocks
        whose stored key summaries disagree with their content, then (1)
        drop prefix-store entries built on them, (2) drop parked handoffs
        and (3) in-flight prefill work touching them (rerouting those
        requests retry-capped), (4) restart resident decode requests mapping
        them, and (5) quarantine + scrub the now-unmapped blocks so they
        leave circulation with a coherent (all-zero) summary. → condemned
        block ids. Restarted requests regenerate bit-identical prefixes
        (positional draws) and the delivered counter suppresses re-streaming."""
        if self.kv_arena is None:
            return []
        now = time.monotonic() if now is None else now
        bad = self.kv_arena.find_corrupt_blocks()
        if not bad:
            return []
        badset = set(bad)
        pool = self.kv_arena.pool
        # an already-orphaned handoff key may map a condemned block — sweep
        # first so the holder scan below sees only live owners
        self._sweep_orphan_handoffs()
        for eng in self.prefills:
            eng.store.drop_containing(badset)
        for rid in list(self._pending_kv):
            kv = self._pending_kv[rid]
            if isinstance(kv[0], BlockHandoff) and badset & set(kv[0].blocks):
                self._pending_kv.pop(rid)
                self._release_handoff(kv[0])
                req = self.proxy.inflight.get(rid)
                if req is not None:
                    self.proxy.on_handoff_lost(req, now)
        for eng in self.prefills:
            hit = {r.rid for r in eng._ready
                   if isinstance(r.cache, BlockHandoff)
                   and badset & set(r.cache.blocks)}
            hit |= {t.rid for t in eng.queue
                    if badset & set(pool.owned(("prefill", t.rid)))}
            for rid in hit:
                eng.abort(rid)
                req = self.proxy.inflight.get(rid)
                if req is not None:
                    self.proxy.on_prefill_restart(req, now)
        for eng in self.decodes:
            for rid in list(eng.rid_slot):
                if badset & set(pool.owned(rid)):
                    eng.release(rid)
                    req = self.proxy.inflight.get(rid)
                    if req is not None and req.phase == Phase.DECODE_RUNNING:
                        self.proxy.on_decode_restart(req, now)
        self._sweep_failed(now)
        for b in bad:
            pool.quarantine(b)
            assert b not in pool.refcount, \
                f"corrupt block {b} still mapped after recovery"
            self.kv_arena.scrub_block(b)
        self.metrics.note_quarantine(len(bad))
        return bad

    # ---- fault-injection entry points (FaultPlane hooks) -------------
    def inject_instance_failure(self, kind: str, iid: int,
                                now: Optional[float] = None):
        """Kill one engine instance: the proxy reroutes its in-flight
        requests (retry-capped) and the next step's engine rounds release
        its slots / queued tasks / undelivered results."""
        now = time.monotonic() if now is None else now
        self.proxy.mark_unhealthy(kind, iid, now)

    def revive_instance(self, kind: str, iid: int):
        self.proxy.mark_healthy(kind, iid)

    def inject_kv_lost(self, rid: int, now: Optional[float] = None):
        """Lose one resident decode request's KV: its slots/blocks are
        released and the request reroutes through prefill, retry-capped."""
        now = time.monotonic() if now is None else now
        req = self.proxy.inflight.get(rid)
        for eng in self.decodes:
            eng.release(rid)
        if req is not None and req.phase == Phase.DECODE_RUNNING:
            self.proxy.on_decode_restart(req, now)

    def inject_handoff_drop(self, rid: int) -> bool:
        """Drop a parked handoff WITHOUT releasing its pool key — models a
        payload lost mid-rename. The orphan-handoff sweep reclaims the
        blocks; the request recovers via the kv-lost path at dispatch."""
        return self._pending_kv.pop(rid, None) is not None

    def _stop_tokens(self, req: Request) -> tuple:
        sp = req.sampling
        if sp is not None and sp.stop_token_ids:
            return sp.stop_token_ids
        # deprecated server-global default
        return (self.scfg.eos_token,) if self.scfg.eos_token >= 0 else ()

    def _note_token(self, req: Request, tok: int) -> Optional[str]:
        """Record one generated token; → finish reason or None. A request
        rerouted through on_decode_kv_lost regenerates from scratch — the
        draws are positional, so the replayed prefix is identical and the
        per-rid delivered counter suppresses re-streaming it."""
        req.output_tokens.append(tok)
        n = len(req.output_tokens)
        if n > self._emitted.get(req.rid, 0):
            self._fresh.setdefault(req.rid, []).append(tok)
            self._emitted[req.rid] = n
        if tok in self._stop_tokens(req):
            return "stop"
        if n >= req.max_tokens:
            return "length"
        return None

    def _record_finish(self, req: Request, reason: str):
        req.finish_reason = reason
        self._finish_info[req.rid] = (reason, len(req.output_tokens))
        self._emitted.pop(req.rid, None)
        self.metrics.add(req)

    def _flush_outputs(self) -> list[RequestOutput]:
        outs = []
        for rid, toks in self._fresh.items():
            reason, total = self._finish_info.pop(rid, (None, None))
            if total is None:
                total = self._emitted.get(rid, len(toks))
            outs.append(RequestOutput(rid, tuple(toks), reason is not None,
                                      reason, total))
        self._fresh.clear()
        self._finish_info.clear()
        outs.extend(self._events)
        self._events = []
        return outs

    def _drain_actions(self, now: float):
        admissions: dict[int, list[Request]] = {}
        for req, inst, stage in self.proxy.tick(now):
            if stage == "prefill":
                self.proxy.on_prefill_start(req, time.monotonic())
                self.prefills[inst.iid].start(req.rid, req.tokens,
                                              prefix_hint=req.prefix_match,
                                              params=req.sampling)
            else:
                admissions.setdefault(inst.iid, []).append(req)
        for iid, reqs in admissions.items():
            eng = self.decodes[iid]
            tnow = time.monotonic()
            items, live = [], []
            for r in reqs:
                kv = self._pending_kv.pop(r.rid, None)
                if kv is None:   # KV died with a failed decode instance
                    self.proxy.on_decode_kv_lost(r, tnow)
                    continue
                items.append((r.rid,) + kv)
                live.append(r)
            t0 = eng.stats["kv_transfer_bytes"]
            p0 = eng.stats["kv_transfer_bytes_padded"]
            granted = eng.admit_batch(items)
            self.metrics.note_kv_transfer(
                eng.stats["kv_transfer_bytes"] - t0,
                eng.stats["kv_transfer_bytes_padded"] - p0)
            for req, item in zip(live, items):
                if granted[req.rid]:
                    self.proxy.on_decode_start(req, tnow)
                else:
                    self._pending_kv[req.rid] = item[1:]
                    self.proxy.on_decode_requeue(req, tnow)

    def _prefill_round(self):
        budget = self.scfg.prefill_tick_budget
        for iid, eng in enumerate(self.prefills):
            if not self.proxy.prefill[iid].healthy:
                # died mid-queue: proxy re-dispatches; abort() also frees
                # the tasks' pool blocks (a bare queue.clear would leak
                # prefill-phase block reservations)
                for t in list(eng.queue):
                    eng.abort(t.rid)
                # undelivered results die with the instance too: their
                # ("handoff", i) blocks would otherwise leak (the sweep is
                # the backstop; this is the prompt release)
                eng.drop_results()
                continue
            if not eng.has_work():
                continue
            for rec in eng.step(budget):
                req = self.proxy.inflight.get(rec.rid)
                tnow = time.monotonic()
                if req is None or req.prefill_instance != iid:
                    # stale result for a re-dispatched rid
                    self._release_handoff(rec.cache)
                    continue
                self.proxy.on_prefill_done(req, tnow, batch_time=rec.elapsed_s)
                # the first token materialized inside the engine round, not
                # when this bookkeeping runs
                self.proxy.on_first_token(req, rec.t_done or tnow)
                reason = self._note_token(req, rec.first_token)
                if reason:
                    # stop token / max_tokens=1 at the FIRST token: retire
                    # without ever admitting to decode (the never-admitted
                    # handoff's arena blocks are released here)
                    self._release_handoff(rec.cache)
                    self.proxy.on_early_finish(req, tnow)
                    self._record_finish(req, reason)
                else:
                    self._pending_kv[req.rid] = (rec.cache, rec.first_token,
                                                 rec.prompt_len, rec.reused,
                                                 req.tokens, req.sampling)

    def _decode_round(self):
        for iid, eng in enumerate(self.decodes):
            if not self.proxy.decode[iid].healthy:
                for rid in list(eng.rid_slot):   # died: slots are garbage,
                    eng.release(rid)             # proxy re-routes the reqs
                eng.preempted.clear()
                continue
            toks = eng.step()
            now = time.monotonic()
            finished = set()
            for rid, tok in toks.items():
                req = self.proxy.inflight.get(rid)
                if req is None or req.decode_instance != iid:
                    eng.release(rid)             # done or re-routed elsewhere
                    finished.add(rid)
                    continue
                # a speculating engine emits a LIST per slot (≥ 1 token per
                # verify step); note each in order and stop at the first
                # finish reason — tokens past a mid-window stop are never
                # recorded or streamed, exactly as if decoded one at a time
                seq = tok if isinstance(tok, (list, tuple)) else (tok,)
                reason = None
                for t in seq:
                    reason = self._note_token(req, t)
                    if reason:
                        break
                if reason:
                    finished.add(rid)
                    eng.release(rid)
                    self.proxy.on_decode_done(req, now,
                                              batch_time=eng.stats["busy_s"] /
                                              max(eng.stats["steps"], 1))
                    self._record_finish(req, reason)
            for rid, cache_one, tok, pos in eng.preempted:
                req = self.proxy.inflight.get(rid)
                if rid in finished or req is None:
                    continue
                self._pending_kv[rid] = (cache_one, tok, pos, 0, req.tokens,
                                         req.sampling)
                self.proxy.on_decode_preempt(req, now)
            eng.preempted.clear()
        self._step_count += 1
        self._maybe_placement_tick()
        self._maybe_sparsity_tick()

    def _maybe_sparsity_tick(self):
        """Drain the decode engines' device-side online-sparsity windows
        into the metrics at the monitor cadence (like the MoE activation
        window), so the STREAMING entry points (add_request/step/generate)
        report blocks_scored / blocks_attended / attn_mass_kept too — not
        just the closed-batch run() epilogue. One host sync per interval
        per sparse engine; no-op when online sparsity is off."""
        if self._step_count % max(self.scfg.placement_interval, 1) != 0:
            return
        for eng in self.decodes:
            if eng.sparsity is not None:
                sp = eng.take_sparsity_stats()
                if sp is not None:
                    self.metrics.note_sparsity(*sp)
            if eng.spec_ctl is not None:
                v = eng.take_spec_stats()
                if v is not None:
                    self.metrics.note_spec(*v)

    # ---- OmniPlacement closed loop -----------------------------------
    def _maybe_placement_tick(self):
        """One monitor tick per interval on counts aggregated across every
        decode engine (the scheduler's activation window is time-indexed)."""
        if (self.placement_sched is None or
                self._step_count % max(self.scfg.placement_interval, 1) != 0):
            return
        counts = None
        for eng in self.decodes:
            c = eng.take_moe_counts()           # fetch + reset the window
            if c is not None:
                counts = c if counts is None else counts + c
        if counts is None:
            return
        plans = self.placement_sched.step(counts.sum(axis=0, keepdims=True))
        if plans:
            self._apply_migration(plans[0])

    def _apply_migration(self, plan):
        """Rebuild MoE slot weights + tables for a new placement. The stack
        remap runs as one donated jit through the placement layer: expert
        rows gather from the canonical copy (rep_rank/rep_slot of the OLD
        tables) and scatter into the NEW slot layout, with out-shardings
        pinned to the stack's own specs so migration never perturbs the
        P("data", ..., "model") expert layout mid-stream. Compiled once;
        every later migration reuses it (rr/rs/new_se are traced args)."""
        if self._remap_stack is None:
            def remap(stack, rr, rs, new_se):
                def layer(p, stacked):
                    if "moe_w1" not in p:
                        return p
                    p = dict(p)
                    for k in ("moe_w1", "moe_w3", "moe_w2"):
                        if stacked:  # [n_rep, R, s, ...] — canonical rows
                            canon = p[k][:, rr, rs]
                            p[k] = jax.vmap(
                                lambda c: moe_mod.slots_from_canonical(
                                    c, new_se))(canon)
                        else:
                            p[k] = moe_mod.slots_from_canonical(
                                p[k][rr, rs], new_se)
                    return p
                return {"period": tuple(layer(p, True)
                                        for p in stack["period"]),
                        "rem": tuple(layer(p, False)
                                     for p in stack["rem"])}
            self._remap_stack = self.placement.donate_jit(
                remap, donate_argnums=(0,),
                out_specs=self.lm.specs()["stack"])

        old = self.tables
        rr = jnp.asarray(np.asarray(old["rep_rank"])[:, 0])
        rs = jnp.asarray(np.asarray(old["rep_slot"])[:, 0])
        new_se = np.asarray(plan.new_slot_expert)
        self.params["stack"] = self._remap_stack(
            self.params["stack"], rr, rs, jnp.asarray(new_se))
        self.tables = self.placement.replicate(
            tables_from_placement_from_slots(new_se))
        for eng in self.prefills + self.decodes:
            eng.tables = self.tables
        self.n_migrations += 1
        hist = self.placement_sched.history[-1] \
            if self.placement_sched.history else {}
        self.migration_log.append({
            "step": self._step_count,
            "b_before": float(hist.get("b", 0.0)),
            "b_after": float(hist.get("b_sim", 0.0))})

    # ------------------------------------------------------------------
    def audit_hot_loops(self, require_called: bool = False):
        """ContractGuard layer-2 entry point (see docs/analysis.md): jaxpr/
        lowering audit over every hot-loop jit this server's placement
        registered through donate_jit. Call post-warmup — entries capture
        their abstract argument signatures at first real call, and the
        audit re-traces from those (no live buffers touched). Returns an
        `AuditReport`; `report.ok()` is the pass/fail bit."""
        from repro.analysis.jaxpr_audit import audit_placement
        return audit_placement(self.placement,
                               require_called=require_called)

    # ------------------------------------------------------------------
    def run(self, requests: list, max_wall_s: float = 300.0,
            arrivals: Optional[list[float]] = None):
        """Closed-batch driver over the streaming primitives.
        requests: [(prompt_tokens, max_tokens:int)] or
        [(prompt_tokens, SamplingParams)]; arrivals: per-request offsets
        from t=0 (None → all at t=0, closed-loop pressure). Returns the
        metrics summary. Greedy int-budget items reproduce the pre-API
        outputs bit-exactly."""
        t_start = time.monotonic()
        todo = sorted(
            ((0.0 if arrivals is None else arrivals[i], i, p, spec)
             for i, (p, spec) in enumerate(requests)))
        k = 0
        while k < len(todo) or self.proxy.inflight:
            now = time.monotonic()
            if now - t_start >= max_wall_s:
                break
            while k < len(todo) and now - t_start >= todo[k][0]:
                _, i, prompt, spec = todo[k]
                params = spec if isinstance(spec, SamplingParams) else \
                    SamplingParams(max_tokens=int(spec))
                try:
                    self._submit(i, tuple(prompt), params, now)
                except BackpressureError:
                    pass        # shed (counted in metrics.n_shed)
                k += 1
            if not self.proxy.inflight and k < len(todo):
                # nothing in flight and the next arrival is in the future:
                # sleep instead of busy-spinning on time.monotonic()
                wait = (t_start + todo[k][0]) - time.monotonic()
                if wait > 0:
                    nap = min(wait, self.scfg.idle_sleep_s)
                    time.sleep(nap)
                    self._idle_slept_s += nap
                    continue
            self.step(now)
        wall = time.monotonic() - t_start
        for eng in self.decodes:
            # drain the device-side online-sparsity window (no-op when off)
            # so the summary reports blocks_scored / blocks_attended /
            # attn_mass_kept next to the wall-clock columns
            sp = eng.take_sparsity_stats()
            if sp is not None:
                self.metrics.note_sparsity(*sp)
            # same for the speculation window (no-op when spec is off)
            v = eng.take_spec_stats()
            if v is not None:
                self.metrics.note_spec(*v)
        summary = self.metrics.summary(wall)
        summary["wall_s"] = wall
        summary["n_migrations"] = self.n_migrations
        summary["migration_log"] = list(self.migration_log)
        summary["idle_slept_s"] = self._idle_slept_s
        summary["n_handoffs_swept"] = self.n_handoffs_swept
        if self.faults is not None:
            summary["faults_injected"] = dict(self.faults.injected)
        summary["prefill_stats"] = [e.stats for e in self.prefills]
        summary["decode_stats"] = [e.stats for e in self.decodes]
        return summary
