"""PD-disaggregated continuous-batching server: OmniProxy + engines, wall-clock.

The end-to-end driver for deliverable (b): serves a real (small) model with
batched requests through the full paper stack — APC-aware prefill dispatch
with radix-backed partial-prefix KV reuse, chunked prefill interleaved with
decode rounds (the prefill_tick_budget knob arbitrates the TTFT/TPOT
trade-off per tick), LPT decode scheduling with batched admission, deferred
submission, sink+recent compressed caches, and (for MoE configs)
OmniPlacement live expert-load monitoring with pipelined weight migration.

Request-level API (vLLM-style): `add_request(prompt, SamplingParams) → rid`
registers an open-loop request with its own temperature/top-k/top-p/seed/
stop-token configuration; `step()` advances every engine one round and
returns per-request `RequestOutput` deltas (new tokens + finish_reason in
{stop, length, abort}); `abort(rid)` cancels a request wherever it lives
(proxy pools, prefill queues, pending KV handoffs, decode slots + KVPool
blocks); `generate(prompts, params)` is a streaming iterator over the same
primitives. `run()` — the closed-batch entry the benchmarks use — is a thin
loop over add_request/step, so greedy outputs are unchanged.

Request lifecycle: proxy tick (eq. 8 dispatch) → chunked prefill (shortest-
remaining-first across queued prompts, resumed at radix prefix boundaries) →
KV handoff (batched donated insert) → continuous-batch decode (device-side
slot state incl. per-slot sampling params + PRNG keys; KVPool-preempted
requests re-enter decode_wait with their extracted cache). See
docs/serving.md.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Iterator, Optional

import jax
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.placement import DynamicScheduler, SchedulerConfig
from repro.core.placement.migration import tables_from_placement_from_slots
from repro.core.proxy import (MetricsAggregator, OASConfig, OmniProxy,
                              Request, RequestOutput, SamplingParams)
from repro.distributed.ctx import MeshCtx, local_mesh_ctx
from repro.models import moe as moe_mod
from repro.models.lm import LM
from repro.serving.engine import (BlockHandoff, DecodeEngine, KVArena,
                                  PrefillEngine)


@dataclass
class ServerConfig:
    n_prefill: int = 1
    n_decode: int = 1
    decode_slots: int = 8
    max_len: int = 256
    oas: OASConfig = field(default_factory=OASConfig)
    chunked_prefill: bool = True      # chunk + interleave prefill with decode
    chunk_tokens: int = 64            # prefill chunk size (jit bucket ceiling)
    prefill_tick_budget: int = 128    # prefill tokens per tick: ↑TTFT-biased,
                                      # ↓TPOT-biased (the paper's P/D knob)
    prefix_reuse: bool = True         # radix partial-prefix KV resume
    prefix_cache_cap: int = 32        # stored prefixes per prefill instance
    prefix_cache_cap_bytes: Optional[int] = None   # byte cap (real sizes)
    kv_blocks: Optional[int] = None   # decode KVPool size override
    paged_kv: bool = True             # physically paged decode KV arenas
    kv_block_size: int = 16           # tokens per KV block
    enable_placement: bool = True     # OmniPlacement dynamic scheduler
    placement_interval: int = 16      # decode steps between monitor ticks
    eos_token: int = -1               # DEPRECATED: server-global stop token,
                                      # used only when a request's
                                      # SamplingParams.stop_token_ids is
                                      # empty (-1 → run to max_tokens)
    idle_sleep_s: float = 0.01        # max per-iteration sleep while run()
                                      # waits for a future arrival


class Server:
    def __init__(self, cfg: ModelConfig, scfg: ServerConfig,
                 mesh: Optional[MeshCtx] = None, rng=None,
                 pattern: Optional[list] = None, params=None):
        self.cfg, self.scfg = cfg, scfg
        self.mesh = mesh or local_mesh_ctx()
        self.lm = LM.build(cfg, self.mesh, pattern=pattern)
        self.params = params if params is not None else \
            self.lm.init(rng if rng is not None else jax.random.PRNGKey(0))
        self.tables = self.lm.default_tables()
        self.proxy = OmniProxy(scfg.n_prefill, scfg.n_decode, scfg.oas)
        self.metrics = MetricsAggregator()
        # one shared paged-KV runtime for every co-located engine: prefill
        # writes chunk KV straight into its arenas, decode extends them, and
        # admission hands over block tables — zero-copy. The default pool
        # grants every decode slot max_len capacity plus one prompt of
        # prefill headroom per prefill instance; prefix-store snapshots
        # share the pool and are reclaimed (LRU) under pressure.
        self.kv_arena = None
        if scfg.paged_kv:
            max_blocks = -(-scfg.max_len // scfg.kv_block_size)
            n_blocks = scfg.kv_blocks if scfg.kv_blocks is not None else \
                (scfg.n_decode * scfg.decode_slots + scfg.n_prefill) \
                * max_blocks
            self.kv_arena = KVArena.build(self.lm, n_blocks,
                                          scfg.kv_block_size)
        self.prefills = [
            PrefillEngine(self.lm, self.params, self.tables, scfg.max_len,
                          chunk_tokens=scfg.chunk_tokens,
                          enable_chunked=scfg.chunked_prefill,
                          allow_partial_reuse=scfg.prefix_reuse,
                          cache_cap=scfg.prefix_cache_cap,
                          cache_cap_bytes=scfg.prefix_cache_cap_bytes,
                          tree=self.proxy.trees[i],
                          arena=self.kv_arena)
            for i in range(scfg.n_prefill)]
        self.decodes = [DecodeEngine(self.lm, self.params, self.tables,
                                     scfg.decode_slots, scfg.max_len,
                                     kv_blocks=scfg.kv_blocks,
                                     paged=scfg.paged_kv,
                                     block_size=scfg.kv_block_size,
                                     arena=self.kv_arena)
                        for _ in range(scfg.n_decode)]
        # rid → (cache B=1, next_token, pos, cached_tokens, prompt, params)
        # awaiting admission (prompt drives prefix-block sharing in the
        # paged pool; params land in the slot's device-side sampling state)
        self._pending_kv: dict[int, tuple] = {}
        self._step_count = 0
        self.n_migrations = 0
        # streaming-output plumbing: per-step token deltas, finish records,
        # and out-of-band events (aborts), flushed by step()
        self._next_rid = 0
        self._fresh: dict[int, list[int]] = {}
        self._emitted: dict[int, int] = {}          # rid → tokens delivered
        self._finish_info: dict[int, tuple] = {}    # rid → (reason, total)
        self._events: list[RequestOutput] = []
        self._idle_slept_s = 0.0
        self.placement_sched = None
        if scfg.enable_placement and cfg.moe.n_experts:
            s = int(self.tables["slot_expert"].shape[1])
            placement = moe_mod.round_robin_placement(cfg.moe.n_experts,
                                                      self.mesh.ep, s)
            # the engine applies ONE placement table across layers, so the
            # monitor runs on layer-summed counts (n_layers=1 collapse)
            self.placement_sched = DynamicScheduler(
                ep=self.mesh.ep, n_experts=cfg.moe.n_experts, n_layers=1,
                cfg=SchedulerConfig(budget=0, max_slots=s),
                placements=[placement])

    # ---- request-level API -------------------------------------------
    def add_request(self, prompt: tuple,
                    params: Optional[SamplingParams] = None,
                    now: Optional[float] = None) -> int:
        """Register an open-loop request under its own SamplingParams;
        → rid. Tokens stream back through step() / generate()."""
        now = time.monotonic() if now is None else now
        params = params if params is not None else SamplingParams()
        rid = self._next_rid
        while rid in self.proxy.inflight:       # never collide with a live
            rid += 1                            # caller-chosen submit() rid
        return self._submit(rid, tuple(prompt), params, now)

    def submit(self, rid: int, prompt: tuple, max_tokens: int, now: float):
        """Legacy closed-batch entry: caller-chosen rid, greedy decoding,
        server-global eos_token. Prefer add_request()."""
        self._submit(rid, tuple(prompt),
                     SamplingParams(max_tokens=max_tokens), now)

    def _submit(self, rid: int, prompt: tuple, params: SamplingParams,
                now: float) -> int:
        self.proxy.submit(Request(rid, prompt, params.max_tokens,
                                  arrival=now, sampling=params), now)
        self._next_rid = max(self._next_rid, rid + 1)
        return rid

    def step(self, now: Optional[float] = None) -> list[RequestOutput]:
        """Advance the whole server one round (proxy tick → prefill round →
        decode round) and return per-request deltas: every token generated
        this step, plus finish records (finish_reason in {stop, length})
        and abort notifications."""
        now = time.monotonic() if now is None else now
        self._drain_actions(now)
        self._prefill_round()
        self._decode_round()
        return self._flush_outputs()

    def abort(self, rid: int, now: Optional[float] = None) -> bool:
        """Cancel a request wherever it lives: proxy pools, prefill queues,
        pending KV handoffs, decode slots + KVPool blocks. → True if the
        rid was in flight. The next step() (or this call's generate()
        consumer) sees a RequestOutput(finished, finish_reason="abort")."""
        now = time.monotonic() if now is None else now
        req = self.proxy.abort(rid, now)
        if req is None:
            return False
        kv = self._pending_kv.pop(rid, None)
        if kv is not None:
            self._release_handoff(kv[0])
        for eng in self.prefills:
            eng.abort(rid)
        for eng in self.decodes:
            eng.release(rid)                    # no-op where not resident
        self._fresh.pop(rid, None)
        self._finish_info.pop(rid, None)
        n_out = max(len(req.output_tokens), self._emitted.pop(rid, 0))
        self.metrics.add_aborted(req)
        self._events.append(RequestOutput(rid, (), True, "abort", n_out))
        return True

    def generate(self, prompts, params=None,
                 max_wall_s: float = 300.0) -> Iterator[RequestOutput]:
        """Streaming front door: submit one prompt (tuple of ints) or a
        list of prompts — `params` a single SamplingParams, a matching
        list, or None (greedy) — then drive step() and yield every
        RequestOutput as it materializes until all submitted requests
        finish. Yields include any other in-flight requests' outputs (the
        caller drives the shared engine loop)."""
        single = bool(prompts) and isinstance(prompts[0], (int, np.integer))
        plist = [tuple(prompts)] if single else [tuple(p) for p in prompts]
        if params is None or isinstance(params, SamplingParams):
            pparams = [params] * len(plist)
        else:
            pparams = list(params)
            if len(pparams) != len(plist):
                raise ValueError(f"{len(plist)} prompts but "
                                 f"{len(pparams)} SamplingParams")
        t0 = time.monotonic()
        live = {self.add_request(p, sp, now=t0)
                for p, sp in zip(plist, pparams)}
        while live and time.monotonic() - t0 < max_wall_s:
            for out in self.step():
                if out.finished:
                    live.discard(out.rid)
                yield out

    # ---- internals ---------------------------------------------------
    def _release_handoff(self, cache) -> None:
        """Free the arena blocks a zero-copy handoff still owns. Every
        request exit path that drops a cache-bearing record before decode
        admission (abort, early finish, stale re-dispatch result, drained
        _pending_kv) MUST route through here — a missed release leaks
        shared-arena blocks permanently."""
        if isinstance(cache, BlockHandoff):
            self.kv_arena.pool.release(cache.key)

    def _stop_tokens(self, req: Request) -> tuple:
        sp = req.sampling
        if sp is not None and sp.stop_token_ids:
            return sp.stop_token_ids
        # deprecated server-global default
        return (self.scfg.eos_token,) if self.scfg.eos_token >= 0 else ()

    def _note_token(self, req: Request, tok: int) -> Optional[str]:
        """Record one generated token; → finish reason or None. A request
        rerouted through on_decode_kv_lost regenerates from scratch — the
        draws are positional, so the replayed prefix is identical and the
        per-rid delivered counter suppresses re-streaming it."""
        req.output_tokens.append(tok)
        n = len(req.output_tokens)
        if n > self._emitted.get(req.rid, 0):
            self._fresh.setdefault(req.rid, []).append(tok)
            self._emitted[req.rid] = n
        if tok in self._stop_tokens(req):
            return "stop"
        if n >= req.max_tokens:
            return "length"
        return None

    def _record_finish(self, req: Request, reason: str):
        req.finish_reason = reason
        self._finish_info[req.rid] = (reason, len(req.output_tokens))
        self._emitted.pop(req.rid, None)
        self.metrics.add(req)

    def _flush_outputs(self) -> list[RequestOutput]:
        outs = []
        for rid, toks in self._fresh.items():
            reason, total = self._finish_info.pop(rid, (None, None))
            if total is None:
                total = self._emitted.get(rid, len(toks))
            outs.append(RequestOutput(rid, tuple(toks), reason is not None,
                                      reason, total))
        self._fresh.clear()
        self._finish_info.clear()
        outs.extend(self._events)
        self._events = []
        return outs

    def _drain_actions(self, now: float):
        admissions: dict[int, list[Request]] = {}
        for req, inst, stage in self.proxy.tick(now):
            if stage == "prefill":
                self.proxy.on_prefill_start(req, time.monotonic())
                self.prefills[inst.iid].start(req.rid, req.tokens,
                                              prefix_hint=req.prefix_match,
                                              params=req.sampling)
            else:
                admissions.setdefault(inst.iid, []).append(req)
        for iid, reqs in admissions.items():
            eng = self.decodes[iid]
            tnow = time.monotonic()
            items, live = [], []
            for r in reqs:
                kv = self._pending_kv.pop(r.rid, None)
                if kv is None:   # KV died with a failed decode instance
                    self.proxy.on_decode_kv_lost(r, tnow)
                    continue
                items.append((r.rid,) + kv)
                live.append(r)
            t0 = eng.stats["kv_transfer_bytes"]
            p0 = eng.stats["kv_transfer_bytes_padded"]
            granted = eng.admit_batch(items)
            self.metrics.note_kv_transfer(
                eng.stats["kv_transfer_bytes"] - t0,
                eng.stats["kv_transfer_bytes_padded"] - p0)
            for req, item in zip(live, items):
                if granted[req.rid]:
                    self.proxy.on_decode_start(req, tnow)
                else:
                    self._pending_kv[req.rid] = item[1:]
                    self.proxy.on_decode_requeue(req, tnow)

    def _prefill_round(self):
        budget = self.scfg.prefill_tick_budget
        for iid, eng in enumerate(self.prefills):
            if not self.proxy.prefill[iid].healthy:
                # died mid-queue: proxy re-dispatches; abort() also frees
                # the tasks' pool blocks (a bare queue.clear would leak
                # prefill-phase block reservations)
                for t in list(eng.queue):
                    eng.abort(t.rid)
                continue
            if not eng.has_work():
                continue
            for rec in eng.step(budget):
                req = self.proxy.inflight.get(rec.rid)
                tnow = time.monotonic()
                if req is None or req.prefill_instance != iid:
                    # stale result for a re-dispatched rid
                    self._release_handoff(rec.cache)
                    continue
                self.proxy.on_prefill_done(req, tnow, batch_time=rec.elapsed_s)
                # the first token materialized inside the engine round, not
                # when this bookkeeping runs
                self.proxy.on_first_token(req, rec.t_done or tnow)
                reason = self._note_token(req, rec.first_token)
                if reason:
                    # stop token / max_tokens=1 at the FIRST token: retire
                    # without ever admitting to decode (the never-admitted
                    # handoff's arena blocks are released here)
                    self._release_handoff(rec.cache)
                    self.proxy.on_early_finish(req, tnow)
                    self._record_finish(req, reason)
                else:
                    self._pending_kv[req.rid] = (rec.cache, rec.first_token,
                                                 rec.prompt_len, rec.reused,
                                                 req.tokens, req.sampling)

    def _decode_round(self):
        for iid, eng in enumerate(self.decodes):
            if not self.proxy.decode[iid].healthy:
                for rid in list(eng.rid_slot):   # died: slots are garbage,
                    eng.release(rid)             # proxy re-routes the reqs
                eng.preempted.clear()
                continue
            toks = eng.step()
            now = time.monotonic()
            finished = set()
            for rid, tok in toks.items():
                req = self.proxy.inflight.get(rid)
                if req is None or req.decode_instance != iid:
                    eng.release(rid)             # done or re-routed elsewhere
                    finished.add(rid)
                    continue
                reason = self._note_token(req, tok)
                if reason:
                    finished.add(rid)
                    eng.release(rid)
                    self.proxy.on_decode_done(req, now,
                                              batch_time=eng.stats["busy_s"] /
                                              max(eng.stats["steps"], 1))
                    self._record_finish(req, reason)
            for rid, cache_one, tok, pos in eng.preempted:
                req = self.proxy.inflight.get(rid)
                if rid in finished or req is None:
                    continue
                self._pending_kv[rid] = (cache_one, tok, pos, 0, req.tokens,
                                         req.sampling)
                self.proxy.on_decode_preempt(req, now)
            eng.preempted.clear()
        self._step_count += 1
        self._maybe_placement_tick()
        self._maybe_sparsity_tick()

    def _maybe_sparsity_tick(self):
        """Drain the decode engines' device-side online-sparsity windows
        into the metrics at the monitor cadence (like the MoE activation
        window), so the STREAMING entry points (add_request/step/generate)
        report blocks_scored / blocks_attended / attn_mass_kept too — not
        just the closed-batch run() epilogue. One host sync per interval
        per sparse engine; no-op when online sparsity is off."""
        if self._step_count % max(self.scfg.placement_interval, 1) != 0:
            return
        for eng in self.decodes:
            if eng.sparsity is not None:
                sp = eng.take_sparsity_stats()
                if sp is not None:
                    self.metrics.note_sparsity(*sp)

    # ---- OmniPlacement closed loop -----------------------------------
    def _maybe_placement_tick(self):
        """One monitor tick per interval on counts aggregated across every
        decode engine (the scheduler's activation window is time-indexed)."""
        if (self.placement_sched is None or
                self._step_count % max(self.scfg.placement_interval, 1) != 0):
            return
        counts = None
        for eng in self.decodes:
            c = eng.take_moe_counts()           # fetch + reset the window
            if c is not None:
                counts = c if counts is None else counts + c
        if counts is None:
            return
        plans = self.placement_sched.step(counts.sum(axis=0, keepdims=True))
        if plans:
            self._apply_migration(plans[0])

    def _apply_migration(self, plan):
        """Rebuild MoE slot weights + tables for a new placement (the jit'd
        gather XLA overlaps with serving; tables swap atomically after)."""
        old = self.tables
        rr = np.asarray(old["rep_rank"])[:, 0]
        rs = np.asarray(old["rep_slot"])[:, 0]
        new_se = plan.new_slot_expert

        def remap_layer(p, stacked):
            if "moe_w1" not in p:
                return p
            p = dict(p)
            for k in ("moe_w1", "moe_w3", "moe_w2"):
                if stacked:     # [n_rep, R, s, ...] — gather canonical rows
                    canon = p[k][:, rr, rs]
                    p[k] = jax.vmap(
                        lambda c: moe_mod.slots_from_canonical(c, new_se))(canon)
                else:
                    p[k] = moe_mod.slots_from_canonical(p[k][rr, rs], new_se)
            return p

        stack = self.params["stack"]
        self.params["stack"] = {
            "period": tuple(remap_layer(p, True) for p in stack["period"]),
            "rem": tuple(remap_layer(p, False) for p in stack["rem"])}
        self.tables = tables_from_placement_from_slots(np.asarray(new_se))
        for eng in self.prefills + self.decodes:
            eng.tables = self.tables
        self.n_migrations += 1

    # ------------------------------------------------------------------
    def run(self, requests: list, max_wall_s: float = 300.0,
            arrivals: Optional[list[float]] = None):
        """Closed-batch driver over the streaming primitives.
        requests: [(prompt_tokens, max_tokens:int)] or
        [(prompt_tokens, SamplingParams)]; arrivals: per-request offsets
        from t=0 (None → all at t=0, closed-loop pressure). Returns the
        metrics summary. Greedy int-budget items reproduce the pre-API
        outputs bit-exactly."""
        t_start = time.monotonic()
        todo = sorted(
            ((0.0 if arrivals is None else arrivals[i], i, p, spec)
             for i, (p, spec) in enumerate(requests)))
        k = 0
        while k < len(todo) or self.proxy.inflight:
            now = time.monotonic()
            if now - t_start >= max_wall_s:
                break
            while k < len(todo) and now - t_start >= todo[k][0]:
                _, i, prompt, spec = todo[k]
                params = spec if isinstance(spec, SamplingParams) else \
                    SamplingParams(max_tokens=int(spec))
                self._submit(i, tuple(prompt), params, now)
                k += 1
            if not self.proxy.inflight and k < len(todo):
                # nothing in flight and the next arrival is in the future:
                # sleep instead of busy-spinning on time.monotonic()
                wait = (t_start + todo[k][0]) - time.monotonic()
                if wait > 0:
                    nap = min(wait, self.scfg.idle_sleep_s)
                    time.sleep(nap)
                    self._idle_slept_s += nap
                    continue
            self.step(now)
        wall = time.monotonic() - t_start
        for eng in self.decodes:
            # drain the device-side online-sparsity window (no-op when off)
            # so the summary reports blocks_scored / blocks_attended /
            # attn_mass_kept next to the wall-clock columns
            sp = eng.take_sparsity_stats()
            if sp is not None:
                self.metrics.note_sparsity(*sp)
        summary = self.metrics.summary(wall)
        summary["wall_s"] = wall
        summary["n_migrations"] = self.n_migrations
        summary["idle_slept_s"] = self._idle_slept_s
        summary["prefill_stats"] = [e.stats for e in self.prefills]
        summary["decode_stats"] = [e.stats for e in self.decodes]
        return summary
