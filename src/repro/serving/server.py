"""PD-disaggregated server: OmniProxy + prefill/decode engines, wall-clock.

The end-to-end driver for deliverable (b): serves a real (small) model with
batched requests through the full paper stack — APC-aware prefill dispatch,
LPT decode scheduling, deferred submission, sink+recent compressed caches,
and (for MoE configs) OmniPlacement with live expert-load monitoring and
placement migration.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Optional

import jax
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.placement import DynamicScheduler, SchedulerConfig
from repro.core.proxy import MetricsAggregator, OASConfig, OmniProxy, Phase, Request
from repro.distributed.ctx import MeshCtx, local_mesh_ctx
from repro.models.lm import LM
from repro.models.moe import slots_from_canonical, tables_from_placement
from repro.serving.engine import DecodeEngine, PrefillEngine


@dataclass
class ServerConfig:
    n_prefill: int = 1
    n_decode: int = 1
    decode_slots: int = 8
    max_len: int = 256
    oas: OASConfig = field(default_factory=OASConfig)
    enable_placement: bool = True     # OmniPlacement dynamic scheduler
    placement_interval: int = 16      # decode steps between monitor ticks
    eos_token: int = -1               # -1 → run to max_tokens


class Server:
    def __init__(self, cfg: ModelConfig, scfg: ServerConfig,
                 mesh: Optional[MeshCtx] = None, rng=None,
                 pattern: Optional[list] = None, params=None):
        self.cfg, self.scfg = cfg, scfg
        self.mesh = mesh or local_mesh_ctx()
        self.lm = LM.build(cfg, self.mesh, pattern=pattern)
        self.params = params if params is not None else \
            self.lm.init(rng if rng is not None else jax.random.PRNGKey(0))
        self.tables = self.lm.default_tables()
        self.proxy = OmniProxy(scfg.n_prefill, scfg.n_decode, scfg.oas)
        self.metrics = MetricsAggregator()
        self.prefills = [PrefillEngine(self.lm, self.params, self.tables,
                                       scfg.max_len)
                         for _ in range(scfg.n_prefill)]
        self.decodes = [DecodeEngine(self.lm, self.params, self.tables,
                                     scfg.decode_slots, scfg.max_len)
                        for _ in range(scfg.n_decode)]
        self._pending_kv: dict[int, tuple] = {}
        self._step_count = 0
        self.placement_sched = None
        if scfg.enable_placement and cfg.moe.n_experts:
            n_moe_layers = sum(1 for s in self.lm.plan.all_specs() if s.use_moe)
            self.placement_sched = DynamicScheduler(
                ep=self.mesh.ep, n_experts=cfg.moe.n_experts,
                n_layers=n_moe_layers,
                cfg=SchedulerConfig(budget=0, max_slots=int(
                    self.tables["slot_expert"].shape[1])),
                placements=None)

    # ------------------------------------------------------------------
    def submit(self, rid: int, prompt: tuple, max_tokens: int, now: float):
        self.proxy.submit(Request(rid, tuple(prompt), max_tokens, arrival=now),
                          now)

    def _drain_actions(self, now: float):
        for req, inst, stage in self.proxy.tick(now):
            if stage == "prefill":
                eng = self.prefills[inst.iid]
                self.proxy.on_prefill_start(req, time.monotonic())
                cache, first, dt = eng.process(req.tokens)
                tnow = time.monotonic()
                self.proxy.on_prefill_done(req, tnow, batch_time=dt)
                self.proxy.on_first_token(req, tnow)
                req.output_tokens.append(first)
                self._pending_kv[req.rid] = (cache, first)
            else:  # decode admission
                eng = self.decodes[inst.iid]
                cache, first = self._pending_kv.pop(req.rid)
                ok = eng.admit(req.rid, cache, first, req.prompt_len)
                if not ok:
                    self.proxy.decode_wait.append(req)   # retry next tick
                    self._pending_kv[req.rid] = (cache, first)
                    continue
                self.proxy.on_decode_start(req, time.monotonic())

    def _decode_round(self):
        for iid, eng in enumerate(self.decodes):
            toks = eng.step()
            now = time.monotonic()
            for rid, tok in toks.items():
                req = self.proxy.inflight.get(rid)
                if req is None:
                    eng.release(rid)
                    continue
                req.output_tokens.append(tok)
                done = (len(req.output_tokens) >= req.max_tokens or
                        tok == self.scfg.eos_token)
                if done:
                    eng.release(rid)
                    self.proxy.on_decode_done(req, now,
                                              batch_time=eng.stats["busy_s"] /
                                              max(eng.stats["steps"], 1))
                    self.metrics.add(req)
            if eng.stats["moe_counts"] is not None and self.placement_sched:
                pass  # counts wired via bench harness (aux plumbed offline)
        self._step_count += 1

    # ------------------------------------------------------------------
    def run(self, requests: list[tuple[tuple, int]], max_wall_s: float = 300.0):
        """requests: [(prompt_tokens, max_tokens)] all submitted at t=0
        (closed-loop pressure). Returns metrics summary."""
        t_start = time.monotonic()
        for i, (prompt, mt) in enumerate(requests):
            self.submit(i, prompt, mt, t_start)
        while self.proxy.inflight and time.monotonic() - t_start < max_wall_s:
            now = time.monotonic()
            self._drain_actions(now)
            self._decode_round()
        wall = time.monotonic() - t_start
        summary = self.metrics.summary(wall)
        summary["wall_s"] = wall
        summary["prefill_stats"] = [e.stats for e in self.prefills]
        summary["decode_stats"] = [e.stats for e in self.decodes]
        return summary
