"""PD-disaggregated continuous-batching server: OmniProxy + engines, wall-clock.

The end-to-end driver for deliverable (b): serves a real (small) model with
batched requests through the full paper stack — APC-aware prefill dispatch
with radix-backed partial-prefix KV reuse, chunked prefill interleaved with
decode rounds (the prefill_tick_budget knob arbitrates the TTFT/TPOT
trade-off per tick), LPT decode scheduling with batched admission, deferred
submission, sink+recent compressed caches, and (for MoE configs)
OmniPlacement live expert-load monitoring with pipelined weight migration.

Request lifecycle: proxy tick (eq. 8 dispatch) → chunked prefill (shortest-
remaining-first across queued prompts, resumed at radix prefix boundaries) →
KV handoff (batched donated insert) → continuous-batch decode (device-side
slot state; KVPool-preempted requests re-enter decode_wait with their
extracted cache). See docs/serving.md.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Optional

import jax
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.placement import DynamicScheduler, SchedulerConfig
from repro.core.placement.migration import tables_from_placement_from_slots
from repro.core.proxy import MetricsAggregator, OASConfig, OmniProxy, Phase, Request
from repro.distributed.ctx import MeshCtx, local_mesh_ctx
from repro.models import moe as moe_mod
from repro.models.lm import LM
from repro.serving.engine import DecodeEngine, PrefillEngine


@dataclass
class ServerConfig:
    n_prefill: int = 1
    n_decode: int = 1
    decode_slots: int = 8
    max_len: int = 256
    oas: OASConfig = field(default_factory=OASConfig)
    chunked_prefill: bool = True      # chunk + interleave prefill with decode
    chunk_tokens: int = 64            # prefill chunk size (jit bucket ceiling)
    prefill_tick_budget: int = 128    # prefill tokens per tick: ↑TTFT-biased,
                                      # ↓TPOT-biased (the paper's P/D knob)
    prefix_reuse: bool = True         # radix partial-prefix KV resume
    prefix_cache_cap: int = 32        # stored prefixes per prefill instance
    kv_blocks: Optional[int] = None   # decode KVPool size override
    paged_kv: bool = True             # physically paged decode KV arenas
    kv_block_size: int = 16           # tokens per KV block
    enable_placement: bool = True     # OmniPlacement dynamic scheduler
    placement_interval: int = 16      # decode steps between monitor ticks
    eos_token: int = -1               # -1 → run to max_tokens


class Server:
    def __init__(self, cfg: ModelConfig, scfg: ServerConfig,
                 mesh: Optional[MeshCtx] = None, rng=None,
                 pattern: Optional[list] = None, params=None):
        self.cfg, self.scfg = cfg, scfg
        self.mesh = mesh or local_mesh_ctx()
        self.lm = LM.build(cfg, self.mesh, pattern=pattern)
        self.params = params if params is not None else \
            self.lm.init(rng if rng is not None else jax.random.PRNGKey(0))
        self.tables = self.lm.default_tables()
        self.proxy = OmniProxy(scfg.n_prefill, scfg.n_decode, scfg.oas)
        self.metrics = MetricsAggregator()
        self.prefills = [
            PrefillEngine(self.lm, self.params, self.tables, scfg.max_len,
                          chunk_tokens=scfg.chunk_tokens,
                          enable_chunked=scfg.chunked_prefill,
                          allow_partial_reuse=scfg.prefix_reuse,
                          cache_cap=scfg.prefix_cache_cap,
                          tree=self.proxy.trees[i])
            for i in range(scfg.n_prefill)]
        self.decodes = [DecodeEngine(self.lm, self.params, self.tables,
                                     scfg.decode_slots, scfg.max_len,
                                     kv_blocks=scfg.kv_blocks,
                                     paged=scfg.paged_kv,
                                     block_size=scfg.kv_block_size)
                        for _ in range(scfg.n_decode)]
        # rid → (cache B=1, next_token, pos, cached_tokens, prompt) awaiting
        # admission (prompt drives prefix-block sharing in the paged pool)
        self._pending_kv: dict[int, tuple] = {}
        self._step_count = 0
        self.n_migrations = 0
        self.placement_sched = None
        if scfg.enable_placement and cfg.moe.n_experts:
            s = int(self.tables["slot_expert"].shape[1])
            placement = moe_mod.round_robin_placement(cfg.moe.n_experts,
                                                      self.mesh.ep, s)
            # the engine applies ONE placement table across layers, so the
            # monitor runs on layer-summed counts (n_layers=1 collapse)
            self.placement_sched = DynamicScheduler(
                ep=self.mesh.ep, n_experts=cfg.moe.n_experts, n_layers=1,
                cfg=SchedulerConfig(budget=0, max_slots=s),
                placements=[placement])

    # ------------------------------------------------------------------
    def submit(self, rid: int, prompt: tuple, max_tokens: int, now: float):
        self.proxy.submit(Request(rid, tuple(prompt), max_tokens, arrival=now),
                          now)

    def _drain_actions(self, now: float):
        admissions: dict[int, list[Request]] = {}
        for req, inst, stage in self.proxy.tick(now):
            if stage == "prefill":
                self.proxy.on_prefill_start(req, time.monotonic())
                self.prefills[inst.iid].start(req.rid, req.tokens,
                                              prefix_hint=req.prefix_match)
            else:
                admissions.setdefault(inst.iid, []).append(req)
        for iid, reqs in admissions.items():
            eng = self.decodes[iid]
            tnow = time.monotonic()
            items, live = [], []
            for r in reqs:
                kv = self._pending_kv.pop(r.rid, None)
                if kv is None:   # KV died with a failed decode instance
                    self.proxy.on_decode_kv_lost(r, tnow)
                    continue
                items.append((r.rid,) + kv)
                live.append(r)
            granted = eng.admit_batch(items)
            for req, item in zip(live, items):
                if granted[req.rid]:
                    self.proxy.on_decode_start(req, tnow)
                else:
                    self._pending_kv[req.rid] = item[1:]
                    self.proxy.on_decode_requeue(req, tnow)

    def _prefill_round(self):
        budget = self.scfg.prefill_tick_budget
        for iid, eng in enumerate(self.prefills):
            if not self.proxy.prefill[iid].healthy:
                eng.queue.clear()      # died mid-queue: proxy re-dispatches
                continue
            if not eng.has_work():
                continue
            for rec in eng.step(budget):
                req = self.proxy.inflight.get(rec.rid)
                tnow = time.monotonic()
                if req is None or req.prefill_instance != iid:
                    continue           # stale result for a re-dispatched rid
                self.proxy.on_prefill_done(req, tnow, batch_time=rec.elapsed_s)
                # the first token materialized inside the engine round, not
                # when this bookkeeping runs
                self.proxy.on_first_token(req, rec.t_done or tnow)
                req.output_tokens.append(rec.first_token)
                self._pending_kv[req.rid] = (rec.cache, rec.first_token,
                                             rec.prompt_len, rec.reused,
                                             req.tokens)

    def _decode_round(self):
        for iid, eng in enumerate(self.decodes):
            if not self.proxy.decode[iid].healthy:
                for rid in list(eng.rid_slot):   # died: slots are garbage,
                    eng.release(rid)             # proxy re-routes the reqs
                eng.preempted.clear()
                continue
            toks = eng.step()
            now = time.monotonic()
            finished = set()
            for rid, tok in toks.items():
                req = self.proxy.inflight.get(rid)
                if req is None or req.decode_instance != iid:
                    eng.release(rid)             # done or re-routed elsewhere
                    finished.add(rid)
                    continue
                req.output_tokens.append(tok)
                done = (len(req.output_tokens) >= req.max_tokens or
                        tok == self.scfg.eos_token)
                if done:
                    finished.add(rid)
                    eng.release(rid)
                    self.proxy.on_decode_done(req, now,
                                              batch_time=eng.stats["busy_s"] /
                                              max(eng.stats["steps"], 1))
                    self.metrics.add(req)
            for rid, cache_one, tok, pos in eng.preempted:
                req = self.proxy.inflight.get(rid)
                if rid in finished or req is None:
                    continue
                self._pending_kv[rid] = (cache_one, tok, pos, 0, req.tokens)
                self.proxy.on_decode_preempt(req, now)
            eng.preempted.clear()
        self._step_count += 1
        self._maybe_placement_tick()

    # ---- OmniPlacement closed loop -----------------------------------
    def _maybe_placement_tick(self):
        """One monitor tick per interval on counts aggregated across every
        decode engine (the scheduler's activation window is time-indexed)."""
        if (self.placement_sched is None or
                self._step_count % max(self.scfg.placement_interval, 1) != 0):
            return
        counts = None
        for eng in self.decodes:
            c = eng.take_moe_counts()           # fetch + reset the window
            if c is not None:
                counts = c if counts is None else counts + c
        if counts is None:
            return
        plans = self.placement_sched.step(counts.sum(axis=0, keepdims=True))
        if plans:
            self._apply_migration(plans[0])

    def _apply_migration(self, plan):
        """Rebuild MoE slot weights + tables for a new placement (the jit'd
        gather XLA overlaps with serving; tables swap atomically after)."""
        old = self.tables
        rr = np.asarray(old["rep_rank"])[:, 0]
        rs = np.asarray(old["rep_slot"])[:, 0]
        new_se = plan.new_slot_expert

        def remap_layer(p, stacked):
            if "moe_w1" not in p:
                return p
            p = dict(p)
            for k in ("moe_w1", "moe_w3", "moe_w2"):
                if stacked:     # [n_rep, R, s, ...] — gather canonical rows
                    canon = p[k][:, rr, rs]
                    p[k] = jax.vmap(
                        lambda c: moe_mod.slots_from_canonical(c, new_se))(canon)
                else:
                    p[k] = moe_mod.slots_from_canonical(p[k][rr, rs], new_se)
            return p

        stack = self.params["stack"]
        self.params["stack"] = {
            "period": tuple(remap_layer(p, True) for p in stack["period"]),
            "rem": tuple(remap_layer(p, False) for p in stack["rem"])}
        self.tables = tables_from_placement_from_slots(np.asarray(new_se))
        for eng in self.prefills + self.decodes:
            eng.tables = self.tables
        self.n_migrations += 1

    # ------------------------------------------------------------------
    def run(self, requests: list[tuple[tuple, int]], max_wall_s: float = 300.0,
            arrivals: Optional[list[float]] = None):
        """requests: [(prompt_tokens, max_tokens)]; arrivals: per-request
        offsets from t=0 (None → all at t=0, closed-loop pressure).
        Returns metrics summary."""
        t_start = time.monotonic()
        todo = sorted(
            ((0.0 if arrivals is None else arrivals[i], i, p, mt)
             for i, (p, mt) in enumerate(requests)))
        k = 0
        while k < len(todo) or self.proxy.inflight:
            now = time.monotonic()
            if now - t_start >= max_wall_s:
                break
            while k < len(todo) and now - t_start >= todo[k][0]:
                _, i, prompt, mt = todo[k]
                self.submit(i, prompt, mt, now)
                k += 1
            self._drain_actions(now)
            self._prefill_round()
            self._decode_round()
        wall = time.monotonic() - t_start
        summary = self.metrics.summary(wall)
        summary["wall_s"] = wall
        summary["n_migrations"] = self.n_migrations
        summary["prefill_stats"] = [e.stats for e in self.prefills]
        summary["decode_stats"] = [e.stats for e in self.decodes]
        return summary
