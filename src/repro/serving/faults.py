"""FaultPlane: seeded deterministic fault injection for the serving stack.

A `FaultPlane` is built from a `FaultConfig` (seed + per-kind fault counts
over a step horizon) and handed to `Server(faults=...)`. The server calls
`on_step(server, step, now)` at the TOP of every step — before any engine
round — so each fault's recovery (instance reroute, corruption quarantine,
handoff sweep) completes before the next token is computed. That ordering is
what upholds the headline contract: under any fault schedule, every
completed request's output is bit-identical to the fault-free run, because
no token is ever produced from lost or corrupt KV and restarted requests
regenerate their prefix from positional draws.

Injectable faults (all drawn from one `np.random.default_rng(seed)` stream,
so a (seed, workload) pair replays the exact same schedule):

  · kill_prefill / kill_decode — mark an instance unhealthy for a drawn
    number of steps, then revive it. The plane never kills the LAST healthy
    instance of a kind (the proxy would fail every pending request — a
    cluster-loss scenario, not a recoverable fault).
  · kv_corrupt — add a nonzero offset to one mapped arena block's keys
    WITHOUT updating its summary plane, then immediately run
    `server.recover_corruption()`: the `summary != reduce(content)` scan is
    the detection mechanism under test (value corruption is invisible to
    the key-summary plane and out of scope).
  · kv_lost — release a resident decode request's KV out from under it
    (models decode-node HBM loss); the request reroutes through prefill.
  · handoff_drop — drop a parked prefill→decode handoff without releasing
    its pool key (models a payload lost mid-rename); the orphan-handoff
    sweep reclaims the blocks and the request recovers at dispatch.
  · alloc_fail — arm the pool to fail its next N real allocations (models
    transient HBM pressure); engines take their defer/preempt paths.
  · straggler — inflate one instance's EWMA batch time so the proxy's
    straggler penalty reroutes around it (scheduling-plane only).

Faults whose precondition is absent at fire time (nothing resident to
corrupt, no parked handoff, no killable instance) are counted in `skipped`
rather than silently dropped, so chaos harnesses can assert on what
actually fired.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field
from typing import Optional

import jax.numpy as jnp
import numpy as np

from repro.serving.arena import BlockHandoff, KVArena

FAULT_KINDS = ("kill_prefill", "kill_decode", "kv_corrupt", "kv_lost",
               "handoff_drop", "alloc_fail", "straggler")


def corrupt_block(arena: KVArena, b: int, offset: float = 1.0):
    """Add `offset` to block `b`'s KEYS in every full-attention layer arena
    without touching the summary plane — the canonical detectable
    corruption: `kmin/kmax` no longer equal a fresh reduction of the block's
    content, so `KVArena.find_corrupt_blocks()` condemns it. Quantized
    (int8) arenas perturb the PAYLOAD ints by a clipped integer delta
    (≥ 1 step, so the change survives the grid and is never rounded away);
    the summaries bound the dequantized content, so the same
    `summary != reduce(dequant(content))` scan detects it."""
    def blk(x, stacked):
        if x.dtype == jnp.int8:
            delta = jnp.int16(max(1, round(abs(offset))))
            bumped = jnp.clip(x[:, b].astype(jnp.int16) + delta
                              if stacked else
                              x[b].astype(jnp.int16) + delta,
                              -127, 127).astype(jnp.int8)
            return x.at[:, b].set(bumped) if stacked else x.at[b].set(bumped)
        return x.at[:, b].add(offset) if stacked else x.at[b].add(offset)
    kv = arena.kv
    per = tuple(e if e is None or "kmin" not in e else
                {**e, "k": blk(e["k"], True)} for e in kv["period"])
    rem = tuple(e if e is None or "kmin" not in e else
                {**e, "k": blk(e["k"], False)} for e in kv["rem"])
    arena.kv = {"period": per, "rem": rem}


@dataclass(frozen=True)
class FaultSpec:
    step: int                   # server step the fault fires at
    kind: str                   # one of FAULT_KINDS
    arg: Optional[int] = None   # kind-specific (down steps / burst size)


@dataclass
class FaultConfig:
    seed: int = 0
    horizon: int = 120          # faults are scheduled in [warmup, horizon)
    warmup_steps: int = 2       # let the first dispatches land before chaos
    n_kill_prefill: int = 1
    n_kill_decode: int = 1
    n_kv_corrupt: int = 2
    n_kv_lost: int = 2
    n_handoff_drop: int = 2
    n_alloc_fail: int = 2
    n_straggler: int = 1
    kill_down_steps: tuple = (2, 8)     # inclusive range of downtime draws
    alloc_fail_burst: tuple = (1, 3)    # inclusive range of burst sizes
    straggler_slowdown: float = 4.0     # EWMA inflation factor


class FaultPlane:
    """Deterministic fault scheduler: builds the full (step, kind, arg)
    schedule up front from the config's rng stream, then fires due specs at
    each `on_step`. Target choices (which instance / block / rid) draw from
    the same stream at fire time — still deterministic for a fixed workload,
    since the server itself is deterministic between faults."""

    def __init__(self, cfg: Optional[FaultConfig] = None):
        self.cfg = cfg or FaultConfig()
        self.rng = np.random.default_rng(self.cfg.seed)
        self.injected = {k: 0 for k in FAULT_KINDS}
        self.skipped = {k: 0 for k in FAULT_KINDS}
        self._revive: list = []     # (due_step, kind, iid)
        self.schedule = deque(self._build())

    def _build(self) -> list:
        c, rng = self.cfg, self.rng
        lo, hi = c.warmup_steps, max(c.horizon, c.warmup_steps + 1)

        def at(n):
            return [int(s) for s in rng.integers(lo, hi, size=n)]
        specs = []
        for s in at(c.n_kill_prefill):
            specs.append(FaultSpec(s, "kill_prefill", int(rng.integers(
                c.kill_down_steps[0], c.kill_down_steps[1] + 1))))
        for s in at(c.n_kill_decode):
            specs.append(FaultSpec(s, "kill_decode", int(rng.integers(
                c.kill_down_steps[0], c.kill_down_steps[1] + 1))))
        for s in at(c.n_kv_corrupt):
            specs.append(FaultSpec(s, "kv_corrupt"))
        for s in at(c.n_kv_lost):
            specs.append(FaultSpec(s, "kv_lost"))
        for s in at(c.n_handoff_drop):
            specs.append(FaultSpec(s, "handoff_drop"))
        for s in at(c.n_alloc_fail):
            specs.append(FaultSpec(s, "alloc_fail", int(rng.integers(
                c.alloc_fail_burst[0], c.alloc_fail_burst[1] + 1))))
        for s in at(c.n_straggler):
            specs.append(FaultSpec(s, "straggler"))
        return sorted(specs, key=lambda f: (f.step, f.kind))

    def _pick(self, seq):
        seq = list(seq)
        return seq[int(self.rng.integers(len(seq)))] if seq else None

    # ------------------------------------------------------------------
    def on_step(self, server, step: int, now: float):
        """Fire every fault scheduled at or before `step` and process due
        instance revivals. Called by Server.step() before engine rounds."""
        due_revives = [r for r in self._revive if r[0] <= step]
        for due, kind, iid in due_revives:
            server.revive_instance(kind, iid)
            self._revive.remove((due, kind, iid))
        while self.schedule and self.schedule[0].step <= step:
            self._fire(server, self.schedule.popleft(), step, now)

    def _fire(self, server, spec: FaultSpec, step: int, now: float):
        kind = spec.kind
        if kind in ("kill_prefill", "kill_decode"):
            ekind = "prefill" if kind == "kill_prefill" else "decode"
            stats = server.proxy.prefill if ekind == "prefill" \
                else server.proxy.decode
            healthy = [s.iid for s in stats if s.healthy]
            if len(healthy) <= 1:       # never kill the last healthy one
                self.skipped[kind] += 1
                return
            iid = self._pick(healthy)
            server.inject_instance_failure(ekind, iid, now)
            self._revive.append((step + max(spec.arg or 1, 1), ekind, iid))
        elif kind == "kv_corrupt":
            arena_kv = server.kv_arena.kv if server.kv_arena else {}
            has_summaries = any(
                e is not None and "kmin" in e
                for part in ("period", "rem") for e in arena_kv.get(part, ()))
            if not has_summaries:   # no summary plane → corruption would be
                self.skipped[kind] += 1   # undetectable; don't inject it
                return
            pool = server.kv_arena.pool
            cands = [b for b in sorted(pool.refcount)
                     if b not in pool.quarantined]
            if not cands:
                self.skipped[kind] += 1
                return
            b = self._pick(cands)
            corrupt_block(server.kv_arena, b,
                          offset=0.5 + float(self.rng.random()))
            got = server.recover_corruption(now)
            assert b in got, f"corrupted block {b} not detected"
        elif kind == "kv_lost":
            resident = sorted({r for eng in server.decodes
                               for r in eng.rid_slot})
            if not resident:
                self.skipped[kind] += 1
                return
            server.inject_kv_lost(self._pick(resident), now)
        elif kind == "handoff_drop":
            parked = sorted(r for r, kv in server._pending_kv.items()
                            if isinstance(kv[0], BlockHandoff))
            if not parked:
                self.skipped[kind] += 1
                return
            server.inject_handoff_drop(self._pick(parked))
        elif kind == "alloc_fail":
            if server.kv_arena is None:
                self.skipped[kind] += 1
                return
            server.kv_arena.pool.inject_alloc_failures += \
                max(spec.arg or 1, 1)
        elif kind == "straggler":
            stats = self._pick(server.proxy.prefill + server.proxy.decode)
            if stats is None:
                self.skipped[kind] += 1
                return
            stats.ewma_batch_time = max(stats.ewma_batch_time, 1e-3) \
                * self.cfg.straggler_slowdown
        self.injected[kind] += 1
