"""Continuous-batch decode engine (the D side of PD disaggregation).

DecodeEngine admits pending caches in one donated jit call per batch, keeps
slot state (pos / cur_tok / active) device-side so the hot step has a single
[n_slots] host fetch (the sampled tokens), and masks inactive slots. With
paged=True (default) attention KV lives in physically paged per-layer
arenas; the decode step reads only resident blocks through per-slot block
tables, and a step that cannot grow its allocation preempts the request
(cache gathered back out of the arenas for re-admission) after LRU store
reclaim fails, instead of over-committing HBM. See docs/serving.md.

Built through a `DevicePlacement`: the hot step jit and both admission jits
route through its donate_jit choke point with the composed (private ∪
arena) cache specs and the replicated slot-state specs pinned as
out-shardings, so on a TP/EP mesh the donated state keeps its layout call
to call and the jit argument cache never churns.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.core.proxy.params import SamplingParams, device_row
from repro.models import attention as attn_mod
from repro.models.lm import LM
from repro.models.stack import (alloc_cache, alloc_paged_private_cache,
                                cache_struct, cache_window, full_attn_layer,
                                merge_arena_cache, ring_block_count,
                                split_arena_cache)
from repro.serving.arena import (BlockHandoff, KVArena, _bucket,
                                 blocks_to_dense_kv, dense_kv_to_blocks,
                                 kv_bytes)
from repro.serving.kvpool import KVPool
from repro.serving.placement import DevicePlacement
from repro.serving.sampling import sample_tokens
from repro.serving.sparsity import SparsityController
from repro.serving.spec import SpecConfig, SpecController
from repro.serving.stats import drain_accumulator


# ======================================================================
@dataclass
class DecodeEngine:
    """Continuous-batch decode engine.

    paged=True (default): attention KV lives in physically paged per-layer
    arenas. Admission allocates real blocks from the KVPool and scatters the
    incoming B=1 dense cache into them (prefix-sharing admissions map the
    lender's full prefix blocks instead of writing them — only the partial
    tail block and the suffix are copied); each decode step writes the new
    token's K/V through the per-slot block table and attends over resident
    blocks only; preemption extracts the dense cache back out of the arenas
    and releases the blocks (refcounted — shared blocks survive until their
    last mapper leaves). paged=False preserves the slot-dense layout with
    accounting-only admission control.
    """
    lm: LM
    params: dict
    tables: Optional[dict]
    n_slots: int
    max_len: int
    hbm_budget_bytes: int = 1 << 34
    kv_blocks: Optional[int] = None   # explicit pool size (tests/benchmarks)
    paged: bool = True                # physically paged attention KV
    block_size: int = 16
    arena: Optional[KVArena] = None   # shared arena (co-located prefill)
    placement: Optional[DevicePlacement] = None
    spec: Optional[SpecConfig] = None   # model-free speculative decoding
    spec_radix: Optional[object] = None  # proxy RadixTree for draft lookup
    stats: dict = field(default_factory=lambda: {
        "steps": 0, "tokens": 0, "busy_s": 0.0, "kv_transfer_bytes": 0,
        "kv_transfer_bytes_padded": 0, "handoff_copy_bytes": 0,
        "admits": 0, "preemptions": 0, "moe_counts": None,
        "blocks_touched": 0, "blocks_shared": 0, "blocks_fresh": 0,
        "host_fetches": 0})

    def __post_init__(self):
        cfg = self.lm.cfg
        if self.placement is None:
            self.placement = (self.arena.placement if self.arena is not None
                              else DevicePlacement.of(self.lm.mesh))
        pl = self.placement
        if self.paged:
            if self.arena is None:
                if self.kv_blocks is None:
                    # capacity parity with the dense layout: every slot can
                    # run to max_len; the pool turns that into admission
                    # flexibility
                    self.kv_blocks = self.n_slots * \
                        -(-self.max_len // self.block_size)
                self.arena = KVArena.build(self.lm, self.kv_blocks,
                                           self.block_size, placement=pl)
            self.block_size = self.arena.block_size
            self.kv_blocks = self.arena.pool.n_blocks
        self.max_blocks = -(-self.max_len // self.block_size)
        self.sparsity = None
        if self.paged:
            # engine-private side only: per-slot ring arenas + non-attention
            # state; the full-attention arenas live in the (possibly shared)
            # KVArena and are composed in around every jit call
            self.cache = alloc_paged_private_cache(
                cfg, self.lm.mesh, self.lm.plan, self.n_slots, self.max_len,
                self.block_size)
            self.tables_h = np.zeros((self.n_slots, self.max_blocks), np.int32)
            self._tbl_dev = jnp.asarray(self.tables_h)
            self._tbl_bucket = self.max_blocks
            self._tbl_dirty = False
            # online top-k block selection (OmniAttn dynamic sparsity):
            # resolved once from cfg.omniattn — the step jit reads the same
            # config, so controller and trace always agree
            self.sparsity = SparsityController.from_model(
                cfg, self.lm.plan, self.block_size, self.max_blocks)
            if self.sparsity is not None:
                self.stats.update(SparsityController.stats_keys())
        else:
            self.cache = alloc_cache(cfg, self.lm.mesh, self.lm.plan,
                                     self.n_slots, self.max_len)
            if self.kv_blocks is None:
                per_slot = kv_bytes(self.cache) // max(self.n_slots, 1)
                budget = max(self.hbm_budget_bytes // max(per_slot, 1),
                             self.n_slots) * 4
                # the accounting pool only needs to never constrain below the
                # slot-dense physical capacity — don't materialize a free
                # list for the raw HBM-budget block count (~1e5 ids)
                self.kv_blocks = min(budget,
                                     self.n_slots * self.max_blocks * 4)
        self.pool = self.arena.pool if self.paged else \
            KVPool(n_blocks=self.kv_blocks, block_size=self.block_size)
        # model-free speculative decoding (SpecPlane): drafting state lives
        # host-side in the controller; the batched verify runs as ONE extra
        # donated jit over [n_slots, k+1] window positions
        self.spec_ctl = SpecController.from_model(
            self.lm, self.spec, sparsity=self.sparsity, radix=self.spec_radix)
        if self.spec_ctl is not None:
            if not self.paged:
                raise ValueError("speculative decoding requires paged "
                                 "attention KV (block/summary rollback is "
                                 "defined on the paged plane)")
            self.stats.update(SpecController.stats_keys())
        # PD transfer-cost metering constants: a B=1 dense handoff cache is
        # `_dense_kv_nbytes` regardless of prompt length (the padded figure
        # the old meter charged); the TRUE payload is the bounded leaves
        # plus `_full_tok_nbytes` per resident token of full-attention KV.
        it = jnp.dtype(cfg.compute_dtype).itemsize
        n_full = sum(1 for sp in self.lm.plan.all_specs()
                     if full_attn_layer(cfg, sp))
        self._full_tok_nbytes = 2 * cfg.n_kv_heads * cfg.head_dim * it * n_full
        sds, _ = cache_struct(cfg, self.lm.mesh, self.lm.plan, 1, self.max_len)
        self._dense_kv_nbytes = sum(
            int(np.prod(s.shape)) * jnp.dtype(s.dtype).itemsize
            for s in jax.tree.leaves(sds))
        self.free = list(range(self.n_slots))
        self.slot_rid: dict[int, int] = {}
        self.rid_slot: dict[int, int] = {}
        self._prompts: dict[int, tuple] = {}   # live rid → prompt (sharing)
        # device-resident slot state threaded (donated) through the step jit;
        # host mirrors updated from values we already know — no device sync.
        # Per-slot sampling parameters + PRNG base keys live here too, so
        # the fused step samples the whole batch without any host traffic
        # (temp <= 0 rows take the greedy argmax branch).
        self.state = {"pos": jnp.zeros(self.n_slots, jnp.int32),
                      "tok": jnp.zeros(self.n_slots, jnp.int32),
                      "active": jnp.zeros(self.n_slots, bool),
                      "temp": jnp.zeros(self.n_slots, jnp.float32),
                      "top_k": jnp.zeros(self.n_slots, jnp.int32),
                      "top_p": jnp.ones(self.n_slots, jnp.float32),
                      "key": jnp.zeros((self.n_slots, 2), jnp.uint32)}
        n_moe = sum(1 for sp in self.lm.plan.all_specs() if sp.use_moe)
        if n_moe and cfg.moe.n_experts:
            # expert activation counts accumulate device-side too — fetched
            # (and reset) only at placement ticks via take_moe_counts()
            self.state["moe_counts"] = jnp.zeros((n_moe, cfg.moe.n_experts),
                                                 jnp.float32)
        if self.sparsity is not None:
            # online-sparsity window [blocks_scored, blocks_attended,
            # mass_sum, mass_n], layer-summed — accumulates device-side in
            # the step jit, drained only via take_sparsity_stats()
            self.state["sparsity"] = jnp.zeros(4, jnp.float32)
        if self.spec_ctl is not None:
            # speculation window [drafted, accepted, emitted, verifies] —
            # accumulates inside the verify jit, drained only via
            # take_spec_stats(), so host_fetches == steps survives spec
            self.state["spec"] = jnp.zeros(4, jnp.float32)
        self.state = pl.replicate(self.state)
        self.pos_h = np.zeros(self.n_slots, np.int64)      # next write position
        self.tok_h = np.zeros(self.n_slots, np.int64)      # current input token
        self.tokens_h = np.zeros(self.n_slots, np.int64)   # pool-accounted tokens
        self.preempted: list[tuple] = []   # (rid, cache_one, next_tok, pos)
        # pinned out-shardings: the composed cache keeps its arena/private
        # layout and the slot state stays replicated across every donated
        # call — the layout fixed point of the hot loop
        state_sp = pl.slot_state_specs(self.state)
        if self.paged:
            private_sp, merged_sp = pl.paged_cache_specs(
                cfg, self.lm.plan, self.n_slots, self.max_len,
                self.block_size, quant=self.arena.quant)
            self._insert = pl.donate_jit(self._insert_paged_impl,
                                         donate_argnums=(0, 1),
                                         out_specs=(merged_sp, state_sp))
            self._insert_handle = pl.donate_jit(
                self._insert_handle_impl, donate_argnums=(0, 1),
                out_specs=(merged_sp, state_sp))
            self._extract = pl.donate_jit(self._extract_paged_impl)
            step_cache_sp = merged_sp
        else:
            dense_sp = pl.dense_cache_specs(cfg, self.lm.plan, self.n_slots,
                                            self.max_len)
            self._insert = pl.donate_jit(self._insert_impl,
                                         donate_argnums=(0, 1),
                                         out_specs=(dense_sp, state_sp))
            self._extract = pl.donate_jit(self._extract_impl)
            step_cache_sp = dense_sp
        self._step = pl.donate_jit(self._step_impl, donate_argnums=(1, 2),
                                   out_specs=(step_cache_sp, state_sp, P()))
        self._verify = None
        if self.spec_ctl is not None:
            self._verify = pl.donate_jit(
                self._verify_impl, donate_argnums=(1, 2),
                out_specs=(step_cache_sp, state_sp, P()))
        self.greedy_h = np.zeros(self.n_slots, bool)   # slot temp <= 0

    # ---- arena compose/split -----------------------------------------
    # Paged jit calls take (private ∪ arena) and write the donated arena
    # leaves back, so the prefill engine sharing this arena never reads a
    # buffer this engine invalidated (execution is sequential in-process).
    def _full_cache(self):
        if not self.paged:
            return self.cache
        return merge_arena_cache(self.lm.cfg, self.lm.plan, self.cache,
                                 self.arena.kv)

    def _store_cache(self, cache):
        if not self.paged:
            self.cache = cache
            return
        self.cache, self.arena.kv = split_arena_cache(self.lm.cfg,
                                                      self.lm.plan, cache)

    def _true_kv_nbytes(self, n_tokens: int) -> int:
        """REAL bytes of a request's KV payload at `n_tokens` resident
        tokens: bounded leaves (ring KV, mamba state) plus per-token
        full-attention KV — the transfer-cost figure that does NOT meter
        max_len padding (a 64-token prompt in a max_len=2048 cache used to
        charge 32× its real bytes)."""
        bounded = self._dense_kv_nbytes - self._full_tok_nbytes * self.max_len
        return bounded + self._full_tok_nbytes * min(n_tokens, self.max_len)

    # ---- paged layout helpers (trace-level) --------------------------
    def _attn_classes(self):
        """[(spec, (sink, recent)) for period entries], same for rem."""
        cfg = self.lm.cfg
        per = [(s, cache_window(cfg, s)) for s in self.lm.plan.period]
        rem = [(s, cache_window(cfg, s)) for s in self.lm.plan.rem]
        return per, rem

    def _insert_attn_paged(self, win, entry, one, slot, wtbl, stacked):
        """Scatter one request's dense per-layer KV into arena blocks.
        Full layers write through `wtbl` (shared prefix entries redirected to
        the null block — mapped, not copied); ring layers overwrite the
        slot's statically owned block run. Full-layer writes recompute the
        written blocks' key summaries in the same jit, so dense→paged
        (re-)admission never leaves a stale summary (shared prefix entries
        redirect to the null block — the lender's summaries stand)."""
        sink, recent = win
        bs = self.block_size
        if not (sink or recent) and "kscale" in entry:
            return self._insert_attn_quant(entry, one, wtbl, stacked)
        out = dict(entry)
        for name in ("k", "v"):
            a = entry[name]
            o = one[name][:, 0] if stacked else one[name][0]   # [(R,) L, K, h]
            if sink or recent:
                bpw = ring_block_count(sink, recent, bs)
                blocks = dense_kv_to_blocks(o, bpw, bs).astype(a.dtype)
                start = (0, slot * bpw, 0, 0, 0) if stacked else \
                    (slot * bpw, 0, 0, 0)
                a = jax.lax.dynamic_update_slice(a, blocks, start)
            else:
                blocks = dense_kv_to_blocks(o, self.max_blocks,
                                            bs).astype(a.dtype)
                a = a.at[:, wtbl].set(blocks) if stacked else \
                    a.at[wtbl].set(blocks)
            out[name] = a
        if wtbl is not None and "kmin" in entry:
            out["kmin"], out["kmax"], out["kmean"] = \
                attn_mod.update_block_summaries(
                    entry["kmin"], entry["kmax"], entry["kmean"], out["k"],
                    wtbl, stacked=stacked)
        return out

    def _insert_attn_quant(self, entry, one, wtbl, stacked):
        """Dense-scatter admission into a QUANTIZED full-attention arena.

        Preemption round-trips bit-exactly: an extracted cache carries the
        raw int8 payload + scale-plane sidecar ("kq"/"kscale"/"ktok", v
        likewise) next to its dequantized dense view, and re-admission
        scatters those ints VERBATIM — float requantization is not exactly
        idempotent, the sidecar is. A fresh dense f32 cache (no sidecar)
        takes the per-token provisional quantization — the same pure
        per-token function every write path uses, so a later seal of these
        blocks lands the identical bits a prefill-filled block would.
        Summaries recompute over the DEQUANTIZED content in the same jit
        (zero-stale-scale rides zero-stale-summary); shared-prefix entries
        are already redirected to the null block in `wtbl`, so a lender's
        payload, scales and summaries all stand untouched."""
        out = dict(entry)
        ix = (slice(None), wtbl) if stacked else wtbl
        if "kq" in one:
            for name, qn, sn, tn in (("k", "kq", "kscale", "ktok"),
                                     ("v", "vq", "vscale", "vtok")):
                oq = one[qn][:, 0] if stacked else one[qn][0]
                osc = one[sn][:, 0] if stacked else one[sn][0]
                otk = one[tn][:, 0] if stacked else one[tn][0]
                out[name] = out[name].at[ix].set(oq)
                out[sn] = out[sn].at[ix].set(osc)
                out[tn] = out[tn].at[ix].set(otk)
        else:
            bs = self.block_size
            for name, sn, tn in (("k", "kscale", "ktok"),
                                 ("v", "vscale", "vtok")):
                o = one[name][:, 0] if stacked else one[name][0]
                q, ts = attn_mod.quant_tokens(o)       # [(R,) L, K, h] / [..K]
                blocks = dense_kv_to_blocks(q, self.max_blocks, bs)
                tsb = dense_kv_to_blocks(ts[..., None], self.max_blocks,
                                         bs)[..., 0]   # [(R,) nb, K, bs]
                out[name] = out[name].at[ix].set(blocks)
                out[sn] = out[sn].at[ix].set(0.0)      # all rewritten: unseal
                out[tn] = out[tn].at[ix].set(tsb)
        out["kmin"], out["kmax"], out["kmean"] = \
            attn_mod.update_block_summaries(
                entry["kmin"], entry["kmax"], entry["kmean"], out["k"],
                wtbl, stacked=stacked, k_scale=out["kscale"],
                k_tok=out["ktok"])
        return out

    def _extract_attn_paged(self, win, entry, slot, tbl, stacked):
        """Gather one slot's dense per-layer KV back out of the arenas.

        Quantized arenas return the DEQUANTIZED f32 dense view under the
        usual "k"/"v" names (the interchange format every generic consumer
        reads) plus the raw sidecar leaves ("kq"/"kscale"/"ktok", v
        likewise, in block-major layout) that `_insert_attn_quant` scatters
        back verbatim on re-admission — the int8 payload and its scale
        plane survive a preempt/resume round trip bit-exactly."""
        sink, recent = win
        bs = self.block_size
        quant = "kscale" in entry
        out = {}
        for name in ("k", "v"):
            a = entry[name]
            K, h = a.shape[-3], a.shape[-1]
            if sink or recent:
                W = sink + recent
                bpw = ring_block_count(sink, recent, bs)
                if stacked:
                    blocks = jax.lax.dynamic_slice(
                        a, (0, slot * bpw, 0, 0, 0),
                        (a.shape[0], bpw, K, bs, h))
                else:
                    blocks = jax.lax.dynamic_slice(
                        a, (slot * bpw, 0, 0, 0), (bpw, K, bs, h))
                x = blocks_to_dense_kv(blocks, W)
            else:
                blocks = a[:, tbl] if stacked else a[tbl]
                if quant:
                    sn, tn = ("kscale", "ktok") if name == "k" else \
                        ("vscale", "vtok")
                    sc = entry[sn][:, tbl] if stacked else entry[sn][tbl]
                    tk = entry[tn][:, tbl] if stacked else entry[tn][tbl]
                    for raw, lv in ((blocks, name[0] + "q"), (sc, sn),
                                    (tk, tn)):
                        out[lv] = raw[:, None] if stacked else raw[None]
                    blocks = attn_mod.dequant_pages(blocks, sc, tk)
                x = blocks_to_dense_kv(blocks, self.max_len)
            out[name] = x[:, None] if stacked else x[None]
        return out

    # ---- jit bodies --------------------------------------------------
    def _slot_state(self, state, slots, toks, poss, samp):
        """Write the admitted slots' scalar state + sampling rows."""
        temps, tks, tps, keys = samp
        state = dict(state)
        state.update(pos=state["pos"].at[slots].set(poss),
                     tok=state["tok"].at[slots].set(toks),
                     active=state["active"].at[slots].set(True),
                     temp=state["temp"].at[slots].set(temps),
                     top_k=state["top_k"].at[slots].set(tks),
                     top_p=state["top_p"].at[slots].set(tps),
                     key=state["key"].at[slots].set(keys))
        return state

    def _insert_impl(self, cache_all, state, caches, slots, toks, poss, samp):
        """Admit len(caches) B=1 caches into `slots` in one call."""
        per, rem = cache_all["period"], cache_all["rem"]
        for j in range(len(caches)):
            s = slots[j]
            per = jax.tree.map(lambda a, o, s=s: a.at[:, s].set(o[:, 0]),
                               per, caches[j]["period"])
            rem = jax.tree.map(lambda a, o, s=s: a.at[s].set(o[0]),
                               rem, caches[j]["rem"])
        state = self._slot_state(state, slots, toks, poss, samp)
        return {"period": per, "rem": rem, "pos": cache_all["pos"]}, state

    def _insert_paged_impl(self, cache_all, state, caches, slots, toks, poss,
                           samp, tbls, shns):
        """Paged admission: scatter each B=1 dense cache into arena blocks
        through its table row (tbls [n, max_blocks]); the first shns[j]
        entries are prefix blocks mapped from a lender and must not be
        written (redirected to the null block). Non-attention layer state
        stays per-slot."""
        per_cls, rem_cls = self._attn_classes()
        per = list(cache_all["period"])
        rem = list(cache_all["rem"])
        nb_iota = jnp.arange(self.max_blocks)
        for j in range(len(caches)):
            s = slots[j]
            wtbl = jnp.where(nb_iota < shns[j], 0, tbls[j])
            for i, (spec, win) in enumerate(per_cls):
                one = caches[j]["period"][i]
                if spec.kind == "attn":
                    per[i] = self._insert_attn_paged(win, per[i], one, s,
                                                     wtbl, stacked=True)
                else:
                    per[i] = jax.tree.map(
                        lambda a, o, s=s: a.at[:, s].set(o[:, 0]),
                        per[i], one)
            for i, (spec, win) in enumerate(rem_cls):
                one = caches[j]["rem"][i]
                if spec.kind == "attn":
                    rem[i] = self._insert_attn_paged(win, rem[i], one, s,
                                                     wtbl, stacked=False)
                else:
                    rem[i] = jax.tree.map(
                        lambda a, o, s=s: a.at[s].set(o[0]), rem[i], one)
        state = self._slot_state(state, slots, toks, poss, samp)
        return {"period": tuple(per), "rem": tuple(rem),
                "pos": cache_all["pos"]}, state

    def _insert_handle_impl(self, cache_all, state, privs, slots, toks, poss,
                            samp):
        """Zero-copy (block-handoff) admission: the full-attention KV is
        ALREADY in the arena blocks named by each request's table — only
        the bounded private leaves (ring KV scattered into the slot's
        static ring run, mamba state, scalars) are written. The dense
        scatter of `_insert_paged_impl` survives as the compat path."""
        per_cls, rem_cls = self._attn_classes()
        per = list(cache_all["period"])
        rem = list(cache_all["rem"])
        for j in range(len(privs)):
            s = slots[j]
            for i, (spec, win) in enumerate(per_cls):
                one = privs[j]["period"][i]
                if one is None:
                    continue                    # full-attn: lives in arena
                if spec.kind == "attn":
                    per[i] = self._insert_attn_paged(win, per[i], one, s,
                                                     None, stacked=True)
                else:
                    per[i] = jax.tree.map(
                        lambda a, o, s=s: a.at[:, s].set(o[:, 0]),
                        per[i], one)
            for i, (spec, win) in enumerate(rem_cls):
                one = privs[j]["rem"][i]
                if one is None:
                    continue
                if spec.kind == "attn":
                    rem[i] = self._insert_attn_paged(win, rem[i], one, s,
                                                     None, stacked=False)
                else:
                    rem[i] = jax.tree.map(
                        lambda a, o, s=s: a.at[s].set(o[0]), rem[i], one)
        state = self._slot_state(state, slots, toks, poss, samp)
        return {"period": tuple(per), "rem": tuple(rem),
                "pos": cache_all["pos"]}, state

    def _step_impl(self, params, cache, state, tables, block_tbl):
        new_cache, logits, aux = self.lm.decode(
            params, cache, state["tok"][:, None], state["pos"][:, None],
            tables=tables, token_mask=state["active"], block_tables=block_tbl)
        # fused per-slot sampling: the token following pos sees pos+1 context
        # tokens — folding that into the slot's base key makes the draw a
        # pure function of (seed, position), so preempt/resume and paged vs
        # dense layouts reproduce the same stream. Greedy slots (temp <= 0)
        # reduce to the old argmax bit-exactly.
        nxt = sample_tokens(logits, state["temp"], state["top_k"],
                            state["top_p"], state["key"], state["pos"] + 1)
        act = state["active"]
        new_state = dict(state)
        new_state.update(pos=state["pos"] + act.astype(jnp.int32),
                         tok=jnp.where(act, nxt, state["tok"]))
        if "moe_counts" in state:
            cnts = ([c.reshape(-1, c.shape[-1]) for c in aux["period_counts"]]
                    + [c[None] for c in aux["rem_counts"]])
            new_state["moe_counts"] = (state["moe_counts"] +
                                       jnp.concatenate(cnts, axis=0))
        if "sparsity" in state:
            # per-layer [4] vectors (period entries scan-stacked [n_rep, 4])
            vecs = [a.sum(0) for a in aux.get("period_sparsity", ())] \
                + list(aux.get("rem_sparsity", ()))
            if vecs:
                new_state["sparsity"] = state["sparsity"] + sum(vecs)
        return new_cache, new_state, nxt

    def _verify_impl(self, params, cache, state, tables, block_tbl, drafts,
                     draft_len):
        """Batched speculative verify: feed every slot's window
        [current token, draft_1..draft_k] through a READ-ONLY forward,
        accept the longest prefix matching the model's own greedy argmax,
        and land exactly the accepted rows' K/V with a masked commit —
        rejected draft positions never touch a block or its summary, so
        rollback is the write never happening. Position 0 reproduces the
        baseline step bit-exactly (greedy slots reduce to the same argmax;
        sampled slots draw with the same (key, pos+1) fold), which is what
        makes the emitted greedy stream identical to non-speculative decode
        under ANY draft source. → (cache, state, packed [B, k+2]) where
        packed[:, :k+1] are the emitted tokens and packed[:, -1] the
        per-slot emit count — ONE host fetch for the whole window."""
        B, k = drafts.shape
        act = state["active"]
        toks = jnp.concatenate([state["tok"][:, None], drafts], axis=1)
        logits, staged, aux = self.lm.verify(
            params, cache, toks, state["pos"], tables=tables,
            token_mask=act, block_tables=block_tbl)
        greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        nxt0 = sample_tokens(logits[:, 0], state["temp"], state["top_k"],
                             state["top_p"], state["key"], state["pos"] + 1)
        is_greedy = state["temp"] <= 0.0
        dmask = jnp.arange(k)[None, :] < draft_len[:, None]
        match = (drafts == greedy[:, :k]) & dmask & is_greedy[:, None]
        # accepted prefix length: draft_i is right iff it equals the greedy
        # continuation given positions < t+i — all of which were themselves
        # accepted (cumprod), exactly the sequential decode induction
        a = jnp.cumprod(match.astype(jnp.int32), axis=1).sum(axis=1)
        n_emit = jnp.where(act, a + 1, 0)
        emit = jnp.concatenate([nxt0[:, None], greedy[:, 1:]], axis=1)
        new_tok = jnp.where(act, emit[jnp.arange(B), a], state["tok"])
        new_cache = self.lm.verify_commit(cache, staged, state["pos"],
                                          n_emit, block_tbl)
        new_state = dict(state)
        new_state.update(pos=state["pos"] + n_emit, tok=new_tok)
        if "moe_counts" in state:
            cnts = ([c.reshape(-1, c.shape[-1]) for c in aux["period_counts"]]
                    + [c[None] for c in aux["rem_counts"]])
            new_state["moe_counts"] = (state["moe_counts"] +
                                       jnp.concatenate(cnts, axis=0))
        actf = act.astype(jnp.float32)
        new_state["spec"] = state["spec"] + jnp.stack(
            [(actf * draft_len).sum(), (actf * a).sum(),
             n_emit.sum().astype(jnp.float32), jnp.ones((), jnp.float32)])
        packed = jnp.concatenate([emit, n_emit[:, None]], axis=1)
        return new_cache, new_state, packed

    def _extract_impl(self, cache_all, slot):
        """Pull one slot back out as a B=1 cache (preemption path)."""
        per = jax.tree.map(
            lambda a: jax.lax.dynamic_slice_in_dim(a, slot, 1, axis=1),
            cache_all["period"])
        rem = jax.tree.map(
            lambda a: jax.lax.dynamic_slice_in_dim(a, slot, 1, axis=0),
            cache_all["rem"])
        return {"period": per, "rem": rem, "pos": cache_all["pos"]}

    def _extract_paged_impl(self, cache_all, slot, tbl):
        """Pull one slot's KV out of the arenas as a dense B=1 cache
        (preemption / re-admission interchange format)."""
        per_cls, rem_cls = self._attn_classes()
        per, rem = [], []
        for i, (spec, win) in enumerate(per_cls):
            e = cache_all["period"][i]
            if spec.kind == "attn":
                per.append(self._extract_attn_paged(win, e, slot, tbl,
                                                    stacked=True))
            else:
                per.append(jax.tree.map(
                    lambda a: jax.lax.dynamic_slice_in_dim(a, slot, 1, axis=1),
                    e))
        for i, (spec, win) in enumerate(rem_cls):
            e = cache_all["rem"][i]
            if spec.kind == "attn":
                rem.append(self._extract_attn_paged(win, e, slot, tbl,
                                                    stacked=False))
            else:
                rem.append(jax.tree.map(
                    lambda a: jax.lax.dynamic_slice_in_dim(a, slot, 1, axis=0),
                    e))
        return {"period": tuple(per), "rem": tuple(rem),
                "pos": cache_all["pos"]}

    # ------------------------------------------------------------------
    def _refresh_tables(self):
        """Device block-table refresh, with the resident-block count fed to
        the step jit pow2-BUCKETED (lo=8 floor, the prefill chunk-bucket
        convention): the jit traces once per bucket instead of once per
        block-boundary crossing as contexts grow, and short-context steps
        hand the kernels a narrow table — the paged_decode grid (and its
        per-block DMAs) scales with the bucket, not max_len. Every live
        slot's resident blocks fit the bucket by construction; stale rows
        of freed slots are clamped to the null block by the write guard."""
        cur = 1
        for slot in self.slot_rid:
            cur = max(cur, self.pool.blocks_for(int(self.tokens_h[slot])))
        nb = min(_bucket(cur, lo=8), self.max_blocks)
        if self._tbl_dirty or nb != self._tbl_bucket:
            self._tbl_dev = jnp.asarray(self.tables_h[:, :nb])
            self._tbl_bucket = nb
            self._tbl_dirty = False

    def take_sparsity_stats(self):
        """Fetch + reset the device-side online-sparsity window and fold it
        into stats (blocks_scored / blocks_attended / attn_mass_*, layer-
        averaged — see serving/sparsity.py). → the layer-averaged [4] np
        vector, or None when online sparsity is off. The only host sync for
        these counters — call at monitor ticks / run end, not per step."""
        v = drain_accumulator(self.state, "sparsity")
        if v is None:
            return None
        self.sparsity.note(self.stats, v)
        L = max(self.sparsity.plan.n_sparse_layers, 1)
        return v / L

    def take_spec_stats(self):
        """Fetch + reset the device-side speculation window ([drafted,
        accepted, emitted, verify steps], see SpecController.stats_keys)
        and fold it into stats. → the raw [4] np vector, or None when
        speculation is off. The only host sync for the spec counters —
        call at monitor ticks / run end, not per step."""
        v = drain_accumulator(self.state, "spec")
        if v is None:
            return None
        SpecController.note(self.stats, v)
        return v

    def has_capacity(self) -> bool:
        return len(self.free) > 0

    def _find_shared(self, prompt, cached: int) -> list[int]:
        """Physical prefix blocks to map for an admission whose first
        `cached` tokens are radix-cached: a live request whose prompt shares
        that prefix lends its FULL prefix blocks (floor — the partial tail
        block is always privately copied by the borrower). Returns [] when
        no lender is resident (the credit is then not taken: PR 1 credited
        blocks that were not physically anywhere)."""
        shn = self.pool.shareable_blocks(cached)
        if shn <= 0 or prompt is None:
            return []
        prompt = tuple(prompt)
        for rid, ptoks in self._prompts.items():
            if (ptoks is not None and len(ptoks) >= cached
                    and tuple(ptoks[:cached]) == prompt[:cached]):
                blocks = self.pool.owned(rid)
                if len(blocks) >= shn:
                    return blocks[:shn]
        return []

    def _admit_handle(self, rid: int, hb: BlockHandoff, pos: int) -> bool:
        """Zero-copy admission: rename the handoff's pool ownership to the
        decode rid, extend capacity for the next token, and point the
        slot's table row at the (already written) blocks. Fails clean —
        ownership is handed back so the server can requeue the handle."""
        self.pool.transfer(hb.key, rid)
        grown = self.pool.extend(rid, pos, pos + 1)
        if grown is None:
            self.arena.reclaim(1)
            grown = self.pool.extend(rid, pos, pos + 1)
        if grown is None:
            self.pool.transfer(rid, hb.key)
            return False
        self.stats["blocks_fresh"] += len(grown)
        return True

    def admit_batch(self, items: list[tuple]) -> dict[int, bool]:
        """items: (rid, cache_one, next_token, pos, cached_tokens[, prompt
        [, sampling_params]]). `cache_one` is either a B=1 dense cache (the
        scatter compat path, also used for preemption re-admission) or a
        `BlockHandoff` (paged prefill: ownership of the already-written
        arena blocks transfers to the decode rid — zero KV copy). Inserts
        every admissible item in ONE donated jit call per kind;
        → {rid: admitted}. With paged KV and a dense cache, `prompt`
        enables prefix-sharing admission: full blocks of the cached prefix
        are mapped from a live lender instead of copied. `sampling_params`
        (SamplingParams, None → greedy) lands in the slot's device-side
        parameter tensors."""
        out: dict[int, bool] = {}
        batch, hbatch = [], []
        for item in items:
            rid, cache_one, tok, pos, cached = item[:5]
            prompt = item[5] if len(item) > 5 else None
            sparams = item[6] if len(item) > 6 else None
            handoff = isinstance(cache_one, BlockHandoff)
            if not self.free:
                out[rid] = False
                continue
            if handoff:
                if not self.paged:
                    raise ValueError("BlockHandoff admission needs paged KV")
                if not self._admit_handle(rid, cache_one, pos):
                    out[rid] = False
                    continue
                slot = self.free.pop()
                tbl = self.pool.owned(rid)
                row = np.zeros(self.max_blocks, np.int32)
                row[:len(tbl)] = tbl
                self.tables_h[slot] = row
                shn = 0
            elif self.paged:
                shared = self._find_shared(prompt, cached)
                tbl = self.pool.allocate(rid, pos + 1, shared=shared)
                if tbl is None:
                    self.arena.reclaim(self.pool.blocks_for(pos + 1)
                                       - len(shared))
                    tbl = self.pool.allocate(rid, pos + 1, shared=shared)
                if tbl is None:
                    out[rid] = False
                    continue
                self.stats["blocks_shared"] += len(shared)
                self.stats["blocks_fresh"] += len(tbl) - len(shared)
                slot = self.free.pop()
                row = np.zeros(self.max_blocks, np.int32)
                row[:len(tbl)] = tbl
                self.tables_h[slot] = row
                shn = len(shared)
            else:
                if self.pool.allocate(rid, pos + 1,
                                      cached_tokens=cached) is None:
                    out[rid] = False
                    continue
                slot = self.free.pop()
                row, shn = None, 0
            self.slot_rid[slot] = rid
            self.rid_slot[rid] = slot
            self._prompts[rid] = tuple(prompt) if prompt is not None else None
            self.pos_h[slot] = pos
            self.tok_h[slot] = tok
            self.tokens_h[slot] = pos + 1
            # transfer-cost model: TRUE payload bytes (resident tokens, not
            # the max_len allocation) next to the padded figure the old
            # meter charged; handoff_copy_bytes is the full-attention KV
            # physically copied at admission — 0 on the zero-copy path, the
            # whole max_len scatter on the dense compat path
            self.stats["kv_transfer_bytes"] += self._true_kv_nbytes(pos)
            self.stats["kv_transfer_bytes_padded"] += self._dense_kv_nbytes
            if not handoff:
                self.stats["handoff_copy_bytes"] += \
                    self._full_tok_nbytes * self.max_len
            self.stats["admits"] += 1
            drow = device_row(sparams, rid)
            rec = (slot, cache_one.private if handoff else cache_one, tok,
                   pos, row, shn, drow)
            (hbatch if handoff else batch).append(rec)
            # host mirror of the greedy predicate: the draft gather skips
            # sampled slots without touching device state
            self.greedy_h[slot] = float(drow[0]) <= 0.0
            if self.spec_ctl is not None:
                self.spec_ctl.on_admit(rid, prompt, tok)
            out[rid] = True

        # pad to a pow2 batch by repeating the last insert (idempotent:
        # same slot, same values) — bounds jit retraces to log2(n_slots)
        def _prep(b):
            while len(b) & (len(b) - 1):
                b.append(b[-1])
            slots = jnp.asarray([x[0] for x in b], jnp.int32)
            toks = jnp.asarray([x[2] for x in b], jnp.int32)
            poss = jnp.asarray([x[3] for x in b], jnp.int32)
            caches = tuple(x[1] for x in b)
            samp = (jnp.asarray([x[6][0] for x in b], jnp.float32),
                    jnp.asarray([x[6][1] for x in b], jnp.int32),
                    jnp.asarray([x[6][2] for x in b], jnp.float32),
                    jnp.asarray(np.stack([x[6][3] for x in b])))
            return slots, toks, poss, caches, samp

        if batch:
            slots, toks, poss, caches, samp = _prep(batch)
            if self.paged:
                tbls = jnp.asarray(np.stack([b[4] for b in batch]), jnp.int32)
                shns = jnp.asarray([b[5] for b in batch], jnp.int32)
                cache, self.state = self._insert(
                    self._full_cache(), self.state, caches, slots, toks,
                    poss, samp, tbls, shns)
                self._store_cache(cache)
            else:
                self.cache, self.state = self._insert(
                    self.cache, self.state, caches, slots, toks, poss, samp)
        if hbatch:
            slots, toks, poss, privs, samp = _prep(hbatch)
            cache, self.state = self._insert_handle(
                self._full_cache(), self.state, privs, slots, toks, poss,
                samp)
            self._store_cache(cache)
        if self.paged and (batch or hbatch):
            self._tbl_dirty = True       # next step() re-buckets + uploads
        return out

    def admit(self, rid: int, cache_one, first_token: int, prompt_len: int,
              cached_tokens: int = 0, prompt: Optional[tuple] = None,
              params: Optional[SamplingParams] = None) -> bool:
        return self.admit_batch([(rid, cache_one, first_token, prompt_len,
                                  cached_tokens, prompt, params)])[rid]

    # ------------------------------------------------------------------
    def step(self):
        """One batched decode step. Without speculation: {rid: next_token}
        for active slots (unchanged contract). With speculation enabled
        ({rid: [tokens]}, ≥ 1 each): draft up to k candidates per greedy
        slot and run the batched verify window instead of the single-token
        step — still exactly one device→host fetch. Requests whose block
        allocation cannot grow are preempted into self.preempted (cache
        extracted for later re-admission)."""
        if not self.slot_rid:
            return {}
        if self.spec_ctl is None:
            return self._step_base()
        drafts_h, dlen_h = self._gather_drafts()
        if not dlen_h.any():
            # nothing to speculate this step: ride the plain single-token
            # jit (cheaper than a k+1 window of guaranteed-empty drafts)
            out = {rid: [t] for rid, t in self._step_base().items()}
            for rid, ts in out.items():
                self.spec_ctl.on_tokens(rid, ts)
            return out
        return self._step_spec(drafts_h, dlen_h)

    def _gather_drafts(self):
        """Host-side draft gather → (drafts [n_slots, k] i32, dlen
        [n_slots] i32). Sampled slots and slots at the max_len capacity
        wall are skipped (their row rides the verify window as a plain
        single-token step); draft length is clamped so every candidate
        write position stays below max_len."""
        k = self.spec_ctl.k
        drafts = np.zeros((self.n_slots, k), np.int32)
        dlen = np.zeros(self.n_slots, np.int32)
        for slot, rid in self.slot_rid.items():
            if not self.greedy_h[slot]:
                continue
            room = self.max_len - int(self.tokens_h[slot])
            if room <= 0:
                continue
            d = self.spec_ctl.draft(rid)[:room]
            if not d:
                continue
            drafts[slot, :len(d)] = d
            dlen[slot] = len(d)
        return drafts, dlen

    def _step_spec(self, drafts_h, dlen_h):
        """One speculative verify step → {rid: [tokens]}."""
        t0 = time.monotonic()
        # pre-extend each drafting slot's allocation to cover its window's
        # write positions; a slot that cannot grow (even after reclaim)
        # degrades to a plain single-token row — never preempt here, the
        # baseline row still fits the blocks it already owns
        touched = 0
        for slot, rid in self.slot_rid.items():
            cur = int(self.tokens_h[slot])
            touched += self.pool.blocks_for(cur)
            d = int(dlen_h[slot])
            want = min(cur + d, self.max_len)
            if d <= 0 or want <= cur:
                continue
            nb_used = self.pool.blocks_for(cur)
            grown = self.pool.extend(rid, cur, want)
            if grown is None and self.arena.reclaim(
                    max(self.pool.blocks_for(want) - nb_used, 1)):
                grown = self.pool.extend(rid, cur, want)
            if grown is None:
                drafts_h[slot] = 0
                dlen_h[slot] = 0
                continue
            for b in grown:
                self.tables_h[slot, nb_used] = b
                nb_used += 1
            if grown:
                self._tbl_dirty = True
                self.stats["blocks_fresh"] += len(grown)
            self.tokens_h[slot] = want
        self.stats["blocks_touched"] += touched
        self._refresh_tables()
        cache, self.state, packed = self._verify(
            self.params, self._full_cache(), self.state, self.tables,
            self._tbl_dev, jnp.asarray(drafts_h), jnp.asarray(dlen_h))
        self._store_cache(cache)
        packed_np = np.asarray(packed)     # the single per-step host fetch
        self.stats["host_fetches"] += 1
        out = {}
        ntok = 0
        for slot, rid in list(self.slot_rid.items()):
            n = int(packed_np[slot, -1])
            toks = [int(t) for t in packed_np[slot, :n]]
            out[rid] = toks
            ntok += n
            self.pos_h[slot] += n
            if n:
                self.tok_h[slot] = toks[-1]
            covered = int(self.tokens_h[slot])
            new_tokens = min(int(self.pos_h[slot]) + 1, self.max_len)
            if new_tokens > covered:
                # full accept: the next input token needs one position past
                # the pre-extended window — same grow path as the baseline
                nb_used = self.pool.blocks_for(covered)
                grown = self.pool.extend(rid, covered, new_tokens)
                if grown is None and self.arena.reclaim(1):
                    grown = self.pool.extend(rid, covered, new_tokens)
                if grown is None:
                    self.stats["preemptions"] += 1
                    self.preempted.append(self._preempt(rid))
                    continue
                for b in grown:
                    self.tables_h[slot, nb_used] = b
                    nb_used += 1
                if grown:
                    self._tbl_dirty = True
                    self.stats["blocks_fresh"] += len(grown)
            elif new_tokens < covered:
                # rejected tail: hand the over-extended blocks back and
                # zero their table entries. The masked commit never wrote
                # them (rejected rows land in the null block), so the
                # released blocks carry no new content and no summary goes
                # stale — this IS the rollback.
                dropped = self.pool.shrink(rid, covered, new_tokens)
                if dropped:
                    nb_new = self.pool.blocks_for(new_tokens)
                    self.tables_h[slot, nb_new:nb_new + len(dropped)] = 0
                    self._tbl_dirty = True
            self.tokens_h[slot] = new_tokens
            self.spec_ctl.on_tokens(rid, toks)
        dt = time.monotonic() - t0
        self.stats["steps"] += 1
        self.stats["tokens"] += ntok
        self.stats["busy_s"] += dt
        return out

    def _step_base(self) -> dict[int, int]:
        """The non-speculative single-token step → {rid: next_token}."""
        t0 = time.monotonic()
        if self.paged:
            self._refresh_tables()
        cache, self.state, nxt = self._step(
            self.params, self._full_cache(), self.state, self.tables,
            self._tbl_dev if self.paged else None)
        self._store_cache(cache)
        next_np = np.asarray(nxt)          # the single per-step host fetch
        self.stats["host_fetches"] += 1
        out = {}
        for slot, rid in list(self.slot_rid.items()):
            tok = int(next_np[slot])
            out[rid] = tok
            self.pos_h[slot] += 1
            self.tok_h[slot] = tok
            # work-based read metric: full-attention blocks gathered for this
            # slot this step (the dense layout always touches max_blocks)
            self.stats["blocks_touched"] += (
                self.pool.blocks_for(int(self.tokens_h[slot]))
                if self.paged else self.max_blocks)
            # capacity is capped at max_len: a request decoding past it keeps
            # emitting (its writes are dropped — null block for paged, OOB
            # scatter drop for dense) but never grows its allocation —
            # growing would index past the table row
            cur = int(self.tokens_h[slot])
            new_tokens = min(cur + 1, self.max_len)
            nb_used = self.pool.blocks_for(cur)
            grown = self.pool.extend(rid, cur, new_tokens)
            if grown is None and self.paged:
                # before preempting, reclaim shared cache state (LRU prefix
                # store entries) — evicting a snapshot is always cheaper
                # than extracting and re-prefilling a live request
                if self.arena.reclaim(1):
                    grown = self.pool.extend(rid, cur, new_tokens)
            if grown is None:
                # the sampled token is already in `out` (delivered once); the
                # preemption record carries it as the resume input so it is
                # neither dropped nor replayed on re-admission
                self.stats["preemptions"] += 1
                self.preempted.append(self._preempt(rid))
                continue
            if grown and self.paged:
                for b in grown:
                    self.tables_h[slot, nb_used] = b
                    nb_used += 1
                self._tbl_dirty = True
                self.stats["blocks_fresh"] += len(grown)
            self.tokens_h[slot] = new_tokens
        dt = time.monotonic() - t0
        self.stats["steps"] += 1
        self.stats["tokens"] += len(out)
        self.stats["busy_s"] += dt
        return out

    def take_moe_counts(self):
        """Fetch + reset the device-side expert activation window ([L_moe, E]
        np array, or None for non-MoE models). The only host sync for counts
        — call it at monitor ticks, not per step."""
        out = drain_accumulator(self.state, "moe_counts")
        if out is None:
            return None
        self.stats["moe_counts"] = out          # last fetched window (stats)
        return out

    def _preempt(self, rid: int) -> tuple:
        slot = self.rid_slot[rid]
        if self.paged:
            cache_one = self._extract(self._full_cache(), jnp.int32(slot),
                                      jnp.asarray(self.tables_h[slot]))
        else:
            cache_one = self._extract(self.cache, jnp.int32(slot))
        rec = (rid, cache_one, int(self.tok_h[slot]), int(self.pos_h[slot]))
        self._free_slot(rid, slot)
        return rec

    def _free_slot(self, rid: int, slot: int):
        del self.slot_rid[slot]
        del self.rid_slot[rid]
        self._prompts.pop(rid, None)
        if self.spec_ctl is not None:
            self.spec_ctl.on_release(rid)
        self.state["active"] = self.state["active"].at[slot].set(False)
        # a stale temp > 0 on a freed slot would permanently defeat the
        # all-greedy fast path in sample_tokens (jnp.all over every slot)
        self.state["temp"] = self.state["temp"].at[slot].set(0.0)
        self.free.append(slot)
        self.pool.release(rid)
        if self.paged:
            # the freed slot keeps decoding garbage until reused: its writes
            # must land in the null block, not in blocks the pool may hand to
            # another request
            self.tables_h[slot] = 0
            self._tbl_dirty = True

    def release(self, rid: int):
        slot = self.rid_slot.get(rid)
        if slot is not None:
            self._free_slot(rid, slot)
