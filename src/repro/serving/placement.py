"""DevicePlacement — the explicit device-placement layer every serving
engine is constructed through.

One object owns everything the serving stack needs to know about devices:

  · the `MeshCtx` (axis convention: `data` = expert parallelism / EP,
    `model` = tensor parallelism / TP — see distributed/ctx.py). No other
    serving module imports MeshCtx; engines ask this layer instead.
  · per-leaf `NamedSharding` specs for the three state families the engines
    allocate — paged KV arenas (KV heads sharded over `model` when the
    decode strategy is 'kv'), per-slot decode state (replicated), and model
    parameters (the LM's sanitized ParamDef specs: attention heads over
    `model`, MoE expert slots over `data`, expert FFN width over `model`);
  · `donate_jit`, the single choke point every donated serving jit routes
    through: it pins out-shardings where the caller provides a spec tree so
    arena/state layouts are a fixed point of the hot jits (donation reuses
    the input buffers, and the argument-sharding jit cache never churns),
    and degrades to a plain `jax.jit` on a 1-device mesh.

`build(tp=, ep=)` is the serving-facing constructor: a (ep, tp) mesh over
the first ep*tp local devices. On CPU, XLA_FLAGS=
--xla_force_host_platform_device_count=8 provides the devices — the mesh-
parity tests run tp=2, ep=4 that way; greedy outputs must be bit-identical
to the 1-device mesh (see tests/test_mesh_parity.py).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from functools import cached_property
from typing import Any, Callable, Optional

import jax
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.distributed.ctx import MeshCtx, local_mesh_ctx
from repro.models import attention as attn_mod
from repro.models import moe as moe_mod
from repro.models import stack as stack_mod


@dataclass
class HotLoopEntry:
    """One jit constructed through `donate_jit`, as the jaxpr auditor
    (repro/analysis/jaxpr_audit.py) sees it: the raw fn + the jit +
    everything the choke point decided (donation, statics, pinned
    out-specs), plus abstract argument signatures captured at first call
    so the auditor can re-trace/lower without touching live (donated)
    buffers. The entry IS the callable the engines hold — forwarding adds
    one attribute check per call."""
    name: str
    fn: Callable
    jit_fn: Callable
    donate_argnums: tuple
    static_argnums: tuple
    out_specs: Any
    placement: "DevicePlacement"
    abstract_args: Optional[tuple] = None
    abstract_kwargs: Optional[dict] = None
    calls: int = 0

    def _abstract(self, tree):
        def one(x):
            if isinstance(x, jax.Array):
                # keep the sharding only for committed arrays (device_put
                # through the placement); uncommitted host-built args were
                # free to follow the computation at the real call, so
                # pinning their observed device would make the re-lower
                # reject the mix of single-device and mesh-sharded args
                sh = x.sharding if getattr(x, "_committed", False) else None
                return jax.ShapeDtypeStruct(x.shape, x.dtype, sharding=sh)
            return x  # static/weak Python values pass through verbatim
        return jax.tree.map(one, tree,
                            is_leaf=lambda x: isinstance(x, jax.Array))

    def __call__(self, *args, **kwargs):
        if self.abstract_args is None:
            # capture BEFORE the call: donated inputs are dead after it
            self.abstract_args = self._abstract(args)
            self.abstract_kwargs = self._abstract(kwargs)
        self.calls += 1
        return self.jit_fn(*args, **kwargs)

    def __getattr__(self, name):
        # delegate jit introspection (_cache_size, clear_cache, ...) so the
        # wrapper is a drop-in for the jax.jit object it fronts
        if name == "jit_fn":
            raise AttributeError(name)
        return getattr(self.jit_fn, name)

    def lower(self):
        """Lower from the captured abstract signature (first real call's
        shapes/dtypes/shardings). Raises if the jit was never called."""
        if self.abstract_args is None:
            raise RuntimeError(f"hot loop '{self.name}' was never called; "
                               f"warm the server before auditing")
        return self.jit_fn.lower(*self.abstract_args,
                                 **self.abstract_kwargs)


@dataclass
class HotLoopRegistry:
    entries: list[HotLoopEntry] = field(default_factory=list)

    def add(self, entry: HotLoopEntry) -> HotLoopEntry:
        self.entries.append(entry)
        return entry

    def names(self) -> list[str]:
        return [e.name for e in self.entries]

    def called(self) -> list[HotLoopEntry]:
        return [e for e in self.entries if e.abstract_args is not None]


@dataclass(frozen=True)
class DevicePlacement:
    ctx: MeshCtx

    # ---- constructors -------------------------------------------------
    @staticmethod
    def local() -> "DevicePlacement":
        return DevicePlacement(local_mesh_ctx())

    @staticmethod
    def build(tp: int = 1, ep: int = 1, devices=None) -> "DevicePlacement":
        """(ep, tp) mesh over the first ep*tp devices: `data` is the
        EP/data-parallel axis, `model` the TP axis."""
        devices = list(jax.devices() if devices is None else devices)
        n = ep * tp
        if len(devices) < n:
            raise ValueError(
                f"tp={tp}, ep={ep} needs {n} devices but only "
                f"{len(devices)} are visible (CPU: set XLA_FLAGS="
                f"--xla_force_host_platform_device_count={n})")
        mesh = jax.make_mesh((ep, tp), ("data", "model"),
                             devices=devices[:n])
        return DevicePlacement(MeshCtx(mesh))

    @staticmethod
    def of(obj) -> "DevicePlacement":
        """Coerce None (→ local 1-device), a MeshCtx, or a DevicePlacement."""
        if obj is None:
            return DevicePlacement.local()
        if isinstance(obj, DevicePlacement):
            return obj
        if isinstance(obj, MeshCtx):
            return DevicePlacement(obj)
        raise TypeError(f"cannot build a DevicePlacement from {type(obj)!r}")

    # ---- mesh facts ---------------------------------------------------
    @cached_property
    def tp(self) -> int:
        return self.ctx.tp

    @cached_property
    def ep(self) -> int:
        return self.ctx.ep

    @cached_property
    def n_devices(self) -> int:
        return self.ctx.n_devices

    def sharding(self, spec: P):
        return self.ctx.sharding(spec)

    def tree_shardings(self, spec_tree):
        return self.ctx.tree_shardings(spec_tree)

    # ---- per-leaf placement specs ------------------------------------
    def arena_specs(self, cfg, plan, quant: bool = False) -> dict:
        """PartitionSpec tree matching alloc_arena_kv: KV + summary planes,
        KV heads sharded over `model` under the 'kv' decode strategy.
        Quantized arenas (QuantPlane) add the scale plane — per-block
        per-channel seal scales kscale/vscale [*, N, K, h] and per-token
        scalar scales ktok/vtok [*, N, K, bs] — which shard exactly like
        the summaries (KV-head dim over `model`, blocks replicated)."""
        kv_part = attn_mod.arena_kv_part(cfg.n_kv_heads, self.tp)

        def one(spec, stacked):
            if not stack_mod.full_attn_layer(cfg, spec):
                return None
            lead = (None,) if stacked else ()
            kv = P(*lead, None, kv_part, None, None)
            sm = P(*lead, None, kv_part, None)
            sps = {"k": kv, "v": kv, "kmin": sm, "kmax": sm, "kmean": sm}
            if quant:
                sps.update(kscale=sm, vscale=sm, ktok=sm, vtok=sm)
            return sps

        return {"period": tuple(one(s, True) for s in plan.period),
                "rem": tuple(one(s, False) for s in plan.rem)}

    def paged_cache_specs(self, cfg, plan, n_slots, max_len, block_size,
                          quant: bool = False):
        """(private_specs, merged_specs) for the paged decode cache: the
        engine-private side (ring arenas + non-attention state) and the
        composed (private ∪ arena) tree the hot jits thread."""
        _, sps = stack_mod.paged_cache_struct(cfg, self.ctx, plan, n_slots,
                                              max_len, 1, block_size)
        private = stack_mod._drop_entries(cfg, plan, sps, drop_full=True)
        merged = stack_mod.merge_arena_cache(cfg, plan, private,
                                             self.arena_specs(cfg, plan,
                                                              quant=quant))
        return private, merged

    def dense_cache_specs(self, cfg, plan, B, max_len):
        _, sps = stack_mod.cache_struct(cfg, self.ctx, plan, B, max_len)
        return sps

    def slot_state_specs(self, state: dict) -> dict:
        """Decode slot state ([n_slots] scalars, sampling rows, counter
        accumulators) is replicated: every rank sees every slot."""
        return jax.tree.map(lambda _: P(), state)

    def param_specs(self, lm) -> dict:
        return lm.specs()

    # ---- placement (device_put) --------------------------------------
    def place(self, tree, spec_tree):
        """device_put every leaf onto its NamedSharding (no-op on one
        device — uncommitted host arrays behave identically there)."""
        if self.n_devices == 1:
            return tree
        return jax.device_put(tree, self.tree_shardings(spec_tree))

    def replicate(self, tree):
        if self.n_devices == 1:
            return tree
        return jax.device_put(tree, self.sharding(P()))

    def place_params(self, lm, params):
        return self.place(params, lm.specs())

    # ---- the jit choke point -----------------------------------------
    @cached_property
    def hot_loops(self) -> HotLoopRegistry:
        """Every jit built through donate_jit, for the ContractGuard jaxpr
        auditor (one registry per placement — i.e. per server)."""
        return HotLoopRegistry()

    def donate_jit(self, fn, *, donate_argnums=(), static_argnums=(),
                   out_specs=None, name=None):
        """Every donated serving jit is built here. `out_specs` (optional
        PartitionSpec pytree matching the outputs) pins out-shardings so
        donated state keeps its layout call-to-call; on a 1-device mesh the
        pin is dropped and this is a plain jax.jit. The constructed jit is
        registered in `hot_loops` (wrapped in a HotLoopEntry that captures
        abstract arg signatures at first call) so the jaxpr auditor can
        later re-trace it and assert the donation/sharding/purity
        contracts actually lowered."""
        kw = {}
        if out_specs is not None and self.n_devices > 1:
            kw["out_shardings"] = self.tree_shardings(out_specs)
        jit_fn = jax.jit(fn, donate_argnums=donate_argnums,
                         static_argnums=static_argnums, **kw)
        return self.hot_loops.add(HotLoopEntry(
            name=name or getattr(fn, "__qualname__", repr(fn)),
            fn=fn, jit_fn=jit_fn,
            donate_argnums=tuple(donate_argnums),
            static_argnums=tuple(static_argnums),
            out_specs=out_specs, placement=self))

    # ---- cross-mesh parameter transfer -------------------------------
    def transfer_params(self, lm_src, params, lm_dst):
        """Re-lay-out `params` built for lm_src's mesh so lm_dst can serve
        them, and place them on this mesh. Only the MoE slot tensors are
        layout-dependent (w1/w3/w2 [R, s, D, Fe] with R = source EP): the
        canonical per-expert rows are gathered through the source replica
        tables and re-slotted for the destination placement, so a tp=2,ep=4
        server decodes with bit-identical expert weights to the 1-device
        server it mirrors (the mesh-parity contract)."""
        cfg = lm_dst.cfg
        if cfg.moe.n_experts == 0:
            return self.place_params(lm_dst, params)
        src_t = lm_src.default_tables()
        dst_t = lm_dst.default_tables()
        rr = np.asarray(src_t["rep_rank"])[:, 0]
        rs = np.asarray(src_t["rep_slot"])[:, 0]
        dst_se = np.asarray(dst_t["slot_expert"])

        def remap_layer(p, stacked):
            if "moe_w1" not in p:
                return p
            p = dict(p)
            for k in ("moe_w1", "moe_w3", "moe_w2"):
                if stacked:
                    canon = p[k][:, rr, rs]
                    p[k] = jax.vmap(lambda c: moe_mod.slots_from_canonical(
                        c, dst_se))(canon)
                else:
                    p[k] = moe_mod.slots_from_canonical(p[k][rr, rs], dst_se)
            return p

        stack = params["stack"]
        params = dict(params)
        params["stack"] = {
            "period": tuple(remap_layer(p, True) for p in stack["period"]),
            "rem": tuple(remap_layer(p, False) for p in stack["rem"])}
        return self.place_params(lm_dst, params)
