"""QuantPlane controller: int8 paged-KV arenas with per-block scales.

The residency half of the paper's KV program: full-attention KV blocks
store int8 payloads in the shared arenas, roughly HALVING the bytes a
resident token pins — which the KVPool's dtype-true ``block_nbytes``
accounting turns directly into ~2x admissible concurrency at a fixed HBM
budget. The numerics live next to the summary plane:

  * **sealed** blocks (every slot written) carry f32 per-block,
    per-channel scales ``kscale/vscale [N, K, h]`` — a nonzero scale row
    IS the sealed marker;
  * the **unsealed tail** carries f32 per-token scalar scales
    ``ktok/vtok [N, K, bs]`` from the provisional per-token quantization
    every write path applies (``models/attention.py::quant_tokens``);
  * dequantization happens inside the kernel tiles (``paged_decode``,
    ``paged_prefill`` history, ``spec_verify``) via the one elementwise
    rule ``q * where(scale != 0, scale, tok)`` — no dequantized block is
    ever materialized in HBM;
  * the scale plane is maintained by the SAME donated jits that maintain
    kmin/kmax, so zero-stale-scale rides the zero-stale-summary
    invariant (``KVArena.check_summaries`` checks both).

This module is the policy owner in the ``SparsityController`` /
``SpecController`` mold: it validates the knobs against the model/server
geometry, degrades to None (quant off, zero behavior change) when the
stack has no full-attention paged layer to quantize, and owns the static
residency figures the benches report (bytes per block quantized vs f32).
Quant itself is structural at runtime — engines and jits branch on the
presence of the ``kscale`` leaf, never on a config object — so a
quant-OFF server's traced programs are byte-identical to a tree without
this module.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.stack import StackPlan, full_attn_layer


# ======================================================================
@dataclass(frozen=True)
class QuantConfig:
    """Knobs for QuantPlane (int8 paged full-attention KV).

    bits: payload width. Only 8 is implemented (the arena leaf is int8
    and the kernels' dequant rule assumes the 127-step grid); any other
    value is a validation error, not a silent fallback.
    """
    bits: int = 8


@dataclass(frozen=True)
class QuantPlan:
    """Resolved quantized-arena geometry for one serving stack."""
    bits: int
    n_quant_layers: int         # full-attention layers whose arenas quantize
    payload_bytes_f32: int      # per (block, layer): k+v payload at f32
    payload_bytes_int8: int     # per (block, layer): k+v payload at int8
    scale_bytes: int            # per (block, layer): the whole scale plane


class QuantController:
    """Per-server owner of the int8-arena policy + residency figures."""

    def __init__(self, plan: QuantPlan):
        self.plan = plan

    # ---- construction -------------------------------------------------
    @staticmethod
    def from_model(cfg: ModelConfig, plan: StackPlan,
                   qcfg: Optional[QuantConfig], block_size: int, *,
                   paged_kv: bool = True) -> Optional["QuantController"]:
        """→ a controller when `qcfg` asks for quantized arenas and the
        stack has at least one paged full-attention layer, else None
        (quant off — including the graceful degrade when every layer is
        ring/mamba and there is simply no arena to quantize). Raises on
        configurations that cannot mean what they say: a non-int8 width,
        or quant requested on a dense (non-paged) KV server — the scale
        plane is defined on arena blocks, there is nothing to attach it
        to in the slot-dense layout."""
        if qcfg is None:
            return None
        if qcfg.bits != 8:
            raise ValueError(f"QuantConfig.bits {qcfg.bits} unsupported "
                             "(int8 arenas only)")
        if not paged_kv:
            raise ValueError("QuantPlane requires paged KV arenas "
                             "(paged_kv=True); per-block scales are "
                             "meaningless in the dense slot layout")
        n_quant = sum(1 for s in plan.all_specs() if full_attn_layer(cfg, s))
        if n_quant == 0:
            return None                 # nothing to quantize: degrade to off
        K, h, bs = cfg.n_kv_heads, cfg.head_dim, block_size
        it = jnp.dtype(cfg.compute_dtype).itemsize
        return QuantController(QuantPlan(
            bits=8, n_quant_layers=n_quant,
            payload_bytes_f32=2 * K * bs * h * it,
            payload_bytes_int8=2 * K * bs * h,
            # kscale/vscale [K, h] + ktok/vtok [K, bs], all f32
            scale_bytes=2 * (K * h + K * bs) * 4))

    # ---- stats contract ----------------------------------------------
    @staticmethod
    def stats_keys() -> dict:
        """Engine-stats schema this controller maintains. Static residency
        figures (not per-step counters): bytes one arena block pins across
        the quantized layers, quantized vs the f32 baseline — the numbers
        `bench_serving`'s resident_bytes/admissible_slots columns are
        built from."""
        return {"quant_layers": 0, "quant_block_bytes": 0,
                "quant_block_bytes_f32": 0}

    def note(self, stats: dict) -> None:
        p = self.plan
        stats["quant_layers"] = p.n_quant_layers
        stats["quant_block_bytes"] = \
            (p.payload_bytes_int8 + p.scale_bytes) * p.n_quant_layers
        stats["quant_block_bytes_f32"] = p.payload_bytes_f32 * p.n_quant_layers

    def compression(self) -> float:
        """Bytes-true residency win per full-attention block: f32 payload
        over (int8 payload + the whole scale plane). > 1.9 for every
        realistic (bs, h); → 2 as bs·h grows."""
        p = self.plan
        return p.payload_bytes_f32 / (p.payload_bytes_int8 + p.scale_bytes)
