"""OmniAttn online-sparsity controller: budgets, validation, and stats.

The dynamic half of OmniAttn. The *static* half (core/omniattn/search.py)
fixes a layer-wise sink+recent compression pattern offline; this module
governs the *online*, query-aware half built on the paged-KV plane: every
resident full-attention KV block carries key summaries (per-kv-head mean +
min/max channel bounds, maintained by the same donated jits that write KV —
see ``models/stack.py::alloc_arena_kv``), each decode step scores resident
blocks with a Quest-style upper bound (``kernels/block_topk.py``) and
attends only a per-slot budget of them through a compacted block table
(``models/attention.py::select_kv_blocks``) — non-selected blocks are never
gathered.

The controller maps ``ModelConfig.omniattn`` budget knobs (absolute
``topk_blocks`` or per-slot ``topk_frac`` of the resident block count) onto
the engine's paged geometry, validates them, and owns the stats contract:
the step jit accumulates a device-side ``[4]`` vector per sparse layer
(``blocks_scored``, ``blocks_attended``, ``mass_sum``, ``mass_n``);
``DecodeEngine.take_sparsity_stats`` drains it through ``note`` into the
engine stats dict (layer-averaged, so the figures are comparable to the
host-side per-slot ``blocks_touched`` metric), and the server feeds the
totals to ``MetricsAggregator.note_sparsity``. Selection degrades to exact
attention whenever the budget covers a slot's resident blocks — a server
with ``budget ≥ max_blocks`` is greedy bit-identical to the exact paged
engine.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

from repro.configs.base import ModelConfig
from repro.models.stack import StackPlan, full_attn_layer, topk_block_budget


@dataclass(frozen=True)
class SparsityPlan:
    """Resolved online-sparsity geometry for one paged decode engine."""
    budget_blocks: int          # static budget vs the full-width table
    frac: float                 # per-slot fractional budget (0 → absolute)
    sink_blocks: int            # logical blocks always kept from the front
    recent_blocks: int          # logical blocks always kept from the tail
    measure_mass: bool          # compute exact attn_mass_kept (diagnostics)
    n_sparse_layers: int        # full-attention layers under selection


class SparsityController:
    """Per-engine owner of the online top-k selection policy + stats."""

    def __init__(self, plan: SparsityPlan):
        self.plan = plan

    # ---- construction -------------------------------------------------
    @staticmethod
    def from_model(cfg: ModelConfig, plan: StackPlan, block_size: int,
                   max_blocks: int) -> Optional["SparsityController"]:
        """→ a controller when cfg.omniattn configures online sparsity and
        the stack has at least one paged full-attention layer, else None.
        Raises on nonsensical budgets (a budget that cannot even hold the
        forced keeps would silently keep everything)."""
        oa = cfg.omniattn
        budget = topk_block_budget(oa, max_blocks)
        if budget is None:
            return None
        n_sparse = sum(1 for s in plan.all_specs() if full_attn_layer(cfg, s))
        if n_sparse == 0:
            return None
        if oa.topk_blocks > 0 and oa.topk_frac > 0:
            raise ValueError("set omniattn.topk_blocks OR topk_frac, not both")
        if oa.topk_frac > 1.0:
            raise ValueError(f"omniattn.topk_frac {oa.topk_frac} > 1")
        sink = max(oa.topk_sink_blocks, 0)
        recent = max(oa.topk_recent_blocks, 1)   # the tail block MUST stay
        return SparsityController(SparsityPlan(
            budget_blocks=budget,
            frac=0.0 if oa.topk_blocks > 0 else oa.topk_frac,
            sink_blocks=sink, recent_blocks=recent,
            measure_mass=oa.topk_measure_mass, n_sparse_layers=n_sparse))

    # ---- stats contract ----------------------------------------------
    @staticmethod
    def stats_keys() -> dict:
        """Engine-stats schema this controller maintains (benches reset
        these between warmup and measurement)."""
        return {"blocks_scored": 0, "blocks_attended": 0,
                "attn_mass_sum": 0.0, "attn_mass_n": 0.0}

    def note(self, stats: dict, vec) -> None:
        """Fold one drained device accumulator (layer-summed [4] float
        vector) into an engine stats dict. Block counts are divided by the
        sparse layer count so they read in the same per-slot-step units as
        the host-side `blocks_touched` column."""
        L = max(self.plan.n_sparse_layers, 1)
        stats["blocks_scored"] += int(round(float(vec[0]) / L))
        stats["blocks_attended"] += int(round(float(vec[1]) / L))
        stats["attn_mass_sum"] += float(vec[2]) / L
        stats["attn_mass_n"] += float(vec[3]) / L

    @staticmethod
    def mass_kept(stats: dict) -> float:
        """Mean exact attention mass captured by selected blocks across
        every (layer, slot, step) selection — NaN when mass measurement is
        off or no selection ran."""
        n = stats.get("attn_mass_n", 0.0)
        return stats.get("attn_mass_sum", 0.0) / n if n else float("nan")
