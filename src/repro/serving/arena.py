"""Shared paged-KV arena runtime + block/dense interchange + PD handoff.

KVArena owns the per-layer full-attention block arenas and their allocator
(KVPool), shared by EVERY paged engine of one host. Prefill writes chunk KV
straight into the arenas through per-task block tables, decode reads/extends
them through per-slot tables, and admission is a zero-copy block-table
transfer (BlockHandoff: pool ownership renames from the handoff key to the
decode rid). Engines follow a compose/split discipline: a jit call takes
(private ∪ arena) and writes the donated arena leaves back here, so
sequential engines never hold stale buffers.

Every arena jit is built through the owning `DevicePlacement`'s donate_jit
choke point with the arena's PartitionSpec tree pinned as out-shardings —
on a TP mesh the KV-head dim stays sharded over `model` through every
copy/scrub, and the donated buffers are reused in place.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.lm import LM
from repro.models.stack import alloc_arena_kv
from repro.serving.kvpool import KVPool
from repro.serving.placement import DevicePlacement


def _bucket(n: int, lo: int = 32) -> int:
    b = lo
    while b < n:
        b *= 2
    return b


def _pow2_floor(n: int) -> int:
    b = 1
    while b * 2 <= n:
        b *= 2
    return b


def kv_bytes(cache) -> int:
    return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(cache))


def dense_kv_to_blocks(x, n_blocks: int, block_size: int):
    """[..., L, K, h] (dense token-major KV) → [..., n_blocks, K, bs, h]
    (kv-head-major arena blocks); the tail is zero-padded to block_size."""
    L, K, h = x.shape[-3:]
    pad = n_blocks * block_size - L
    if pad:
        x = jnp.pad(x, [(0, 0)] * (x.ndim - 3) + [(0, pad), (0, 0), (0, 0)])
    x = x.reshape(x.shape[:-3] + (n_blocks, block_size, K, h))
    return jnp.moveaxis(x, -3, -2)


def blocks_to_dense_kv(x, L: int):
    """Inverse of dense_kv_to_blocks: [..., nb, K, bs, h] → [..., L, K, h]."""
    x = jnp.moveaxis(x, -2, -3)
    nb, bs, K, h = x.shape[-4:]
    return x.reshape(x.shape[:-4] + (nb * bs, K, h))[..., :L, :, :]


# ======================================================================
@dataclass
class KVArena:
    """Shared physically-paged KV runtime: the per-layer full-attention
    block arenas plus their allocator, shared by EVERY paged engine of one
    host. Prefill writes chunk KV straight into the arenas through
    per-task block tables, decode reads/extends them through per-slot
    tables, and admission is a zero-copy block-table transfer. Engines
    follow a compose/split discipline: a jit call takes (private ∪ arena)
    and writes the donated arena leaves back here, so sequential engines
    never hold stale buffers.

    `reclaimers` are backpressure callbacks (prefix stores registering
    `evict_for_blocks`): when an allocation cannot be served, the caller
    asks the arena to reclaim before deferring/preempting."""
    lm: LM
    pool: KVPool
    kv: dict                 # alloc_arena_kv leaves [n_rep?, N, K, bs, h]
    block_size: int
    reclaimers: list = field(default_factory=list)
    placement: Optional[DevicePlacement] = None

    @staticmethod
    def build(lm: LM, n_blocks: int, block_size: int = 16,
              placement: Optional[DevicePlacement] = None,
              quant: bool = False) -> "KVArena":
        pool = KVPool(n_blocks=n_blocks, block_size=block_size)
        # +1: arena block 0 is the reserved null block (never allocated)
        kv = alloc_arena_kv(lm.cfg, lm.mesh, lm.plan, n_blocks + 1,
                            block_size, quant=quant)
        return KVArena(lm, pool, kv, block_size, placement=placement)

    @property
    def quant(self) -> bool:
        """Structural quant detection: an arena is quantized iff its
        entries carry the scale plane (no config threading — quant-OFF
        trees are byte-identical to pre-QuantPlane trees)."""
        return any(e is not None and "kscale" in e
                   for e in self.kv["period"] + self.kv["rem"])

    def __post_init__(self):
        if self.placement is None:
            self.placement = DevicePlacement.of(self.lm.mesh)
        leaves = jax.tree.leaves(self.kv)
        n = self.pool.n_blocks + 1
        # bytes one arena block pins across every full-attention layer —
        # dtype-true, so int8 quant arenas report ~half the f32 figure and
        # the pool's byte-based admission sizing doubles
        self.block_nbytes = sum(x.size // n * x.dtype.itemsize
                                for x in leaves)
        specs = self.placement.arena_specs(self.lm.cfg, self.lm.plan,
                                           quant=self.quant)
        self._copy = self.placement.donate_jit(
            self._copy_impl, donate_argnums=(0,), out_specs=specs)
        self._scrub = self.placement.donate_jit(
            self._scrub_impl, donate_argnums=(0,), out_specs=specs)

    def _copy_impl(self, kv, src, dst):
        # every arena leaf — KV [n_rep?, N, K, bs, h] AND the block-summary
        # plane [n_rep?, N, K, h] — carries the block axis at position 1
        # (stacked period entries) or 0 (rem), so the copy is structural,
        # not ndim-dispatched
        def blk(x, stacked):
            if stacked:
                return x.at[:, dst].set(x[:, src])
            return x.at[dst].set(x[src])
        per = tuple(None if e is None else
                    {k: blk(v, True) for k, v in e.items()}
                    for e in kv["period"])
        rem = tuple(None if e is None else
                    {k: blk(v, False) for k, v in e.items()}
                    for e in kv["rem"])
        return {"period": per, "rem": rem}

    def copy_block(self, src: int, dst: int):
        """Device-copy one physical block across every layer arena (the
        partial-tail copy-on-write for prefix-store resume borrowers).
        The block-summary plane rides along: a copied block's content is
        bit-identical to its source, so copying the summary IS the
        invalidate-and-recompute — the zero-stale-summary invariant holds
        through CoW without touching the keys."""
        if jax.tree.leaves(self.kv):
            self.kv = self._copy(self.kv, jnp.int32(src), jnp.int32(dst))

    def _scrub_impl(self, kv, b):
        # zero every leaf of one block — content AND summary plane — so a
        # quarantined block satisfies summary == reduce(content) forever
        def blk(x, stacked):
            if stacked:
                return x.at[:, b].set(0)
            return x.at[b].set(0)
        per = tuple(None if e is None else
                    {k: blk(v, True) for k, v in e.items()}
                    for e in kv["period"])
        rem = tuple(None if e is None else
                    {k: blk(v, False) for k, v in e.items()}
                    for e in kv["rem"])
        return {"period": per, "rem": rem}

    def scrub_block(self, b: int):
        """Zero one physical block across every layer arena (corruption
        quarantine: the block leaves circulation, and zeroed content with a
        zeroed summary keeps `check_summaries` green — all-zero keys reduce
        to all-zero min/max/mean)."""
        if jax.tree.leaves(self.kv):
            self.kv = self._scrub(self.kv, jnp.int32(b))

    @staticmethod
    def _dense_k(entry) -> np.ndarray:
        """Host f32 view of one entry's key content — dequantized through
        the stored scale plane for quant entries, so every scan/check below
        reasons about exactly what attention reads. The numpy multiply is
        bit-identical to the jit-side dequant (one f32 product per element),
        so exact-equality summary checks remain exact under quant."""
        k = np.asarray(entry["k"], np.float32)
        if "kscale" in entry:
            sc = np.asarray(entry["kscale"], np.float32)[..., None, :]
            tk = np.asarray(entry["ktok"], np.float32)[..., None]
            k = k * np.where(sc != 0, sc, tk)
        return k

    def find_corrupt_blocks(self) -> list:
        """Summary-plane corruption scan: block ids whose stored key
        summaries disagree with a fresh reduction of the block's key
        content. A fault (bit-flip, lost write, partial DMA) that mutates K
        without going through a summary-maintaining write path trips this —
        the detection half of the FaultPlane corruption story; on quant
        arenas the reduction runs over the DEQUANTIZED payload, so a
        perturbed int8 byte or scale entry shifts the recomputed min/max
        away from the stored summary exactly as an f32 flip would. Host
        scan (fetches the key arenas); call at recovery points, not per
        step."""
        n = self.pool.n_blocks + 1
        bad = np.zeros(n, bool)

        def one(entry, stacked):
            if entry is None or "kmin" not in entry:
                return
            k = self._dense_k(entry)
            mism = (np.asarray(entry["kmin"], np.float32) != k.min(axis=-2)) \
                | (np.asarray(entry["kmax"], np.float32) != k.max(axis=-2))
            # reduce every axis except the block axis
            ax = 1 if stacked else 0
            red = tuple(i for i in range(mism.ndim) if i != ax)
            np.logical_or(bad, mism.any(axis=red), out=bad)
        for e in self.kv["period"]:
            one(e, True)
        for e in self.kv["rem"]:
            one(e, False)
        return [int(b) for b in np.nonzero(bad)[0]]

    def check_summaries(self):
        """Zero-stale-summary invariant: for EVERY arena block of every
        full-attention layer, the stored per-block key summaries equal a
        fresh reduction of the block's key content. Holds at any quiescent
        point because every path that writes arena K recomputes the touched
        blocks' summaries in the same jit (prefill chunk writes, decode
        appends, dense-scatter admission) and copy_block copies content and
        summary together. Quant arenas extend the check to the scale plane
        (zero-stale-scales): summaries must match the dequantized content,
        scales must be finite and non-negative, and a sealed block's
        per-token row must be zeroed (seal-on-full zeroes it; the null
        block 0, a duplicate-scatter redirect target, is exempt from the
        seal/tail exclusivity — its content is masked everywhere).
        Test/diagnostic helper — fetches the arenas."""
        def one(entry):
            if entry is None or "kmin" not in entry:
                return
            k = self._dense_k(entry)
            np.testing.assert_array_equal(np.asarray(entry["kmin"]),
                                          k.min(axis=-2),
                                          err_msg="stale kmin summary")
            np.testing.assert_array_equal(np.asarray(entry["kmax"]),
                                          k.max(axis=-2),
                                          err_msg="stale kmax summary")
            np.testing.assert_allclose(np.asarray(entry["kmean"]),
                                       k.mean(axis=-2), rtol=1e-5, atol=1e-6,
                                       err_msg="stale kmean summary")
            if "kscale" not in entry:
                return
            for sck, tkk in (("kscale", "ktok"), ("vscale", "vtok")):
                sc = np.asarray(entry[sck], np.float32)
                tk = np.asarray(entry[tkk], np.float32)
                assert np.all(np.isfinite(sc)) and np.all(sc >= 0), \
                    f"invalid {sck} seal scales"
                assert np.all(np.isfinite(tk)) and np.all(tk >= 0), \
                    f"invalid {tkk} per-token scales"
                # sealed ⟹ per-token row zeroed (block axis is 1 for
                # stacked period entries, 0 for rem; null block exempt)
                sealed = (sc != 0).any(axis=-1)              # [..., N, K]
                ax = sc.ndim - 3
                nulls = np.zeros(sc.shape[ax], bool)
                nulls[0] = True
                sealed &= ~nulls.reshape((1,) * ax + (-1, 1))
                assert not (sealed[..., None] & (tk != 0)).any(), \
                    f"sealed block retains nonzero {tkk} row"
        for e in self.kv["period"]:
            one(e)
        for e in self.kv["rem"]:
            one(e)

    def reclaim(self, n_blocks: int) -> int:
        """Free up to `n_blocks` pool blocks by evicting shared cache
        state (LRU prefix-store entries first). → blocks actually freed."""
        freed = 0
        for cb in self.reclaimers:
            if freed >= n_blocks:
                break
            freed += cb(n_blocks - freed)
        return freed


@dataclass
class BlockHandoff:
    """Zero-copy PD handoff record: a finished prefill's pool-owned block
    table plus the bounded private leaves (ring KV, mamba state, position).
    Admission transfers pool ownership from `key` to the decode rid — no
    full-attention KV byte is copied (`handoff_copy_bytes == 0`); the
    dense-pytree handoff survives as the paged=False / cross-arena compat
    path."""
    key: tuple                         # pool ownership key ("handoff", i)
    blocks: tuple                      # physical block ids, logical order
    private: dict                      # B=1 cache without full-attn entries
    pos: int                           # resident tokens
