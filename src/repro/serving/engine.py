"""In-process serving engines (CPU-real, small models): batched decode with
slot-dense caches + per-request positions, single-request prefill with KV
handoff — the execution layer under OmniProxy.

PD disaggregation: PrefillEngine produces a B=1 cache pytree; DecodeEngine
admits it into a free slot of its slot-dense cache (the "KV transfer" — an
array copy in-process; bytes are metered for the transfer-cost model).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models.lm import LM
from repro.models.stack import alloc_cache
from repro.serving.kvpool import KVPool


def _bucket(n: int, lo: int = 32) -> int:
    b = lo
    while b < n:
        b *= 2
    return b


def kv_bytes(cache) -> int:
    return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(cache))


@dataclass
class PrefillEngine:
    lm: LM
    params: dict
    tables: Optional[dict]
    max_len: int
    cache_exact: dict = field(default_factory=dict)   # full-prompt APC reuse
    cache_cap: int = 32
    stats: dict = field(default_factory=lambda: {"prefills": 0, "cache_hits": 0,
                                                 "tokens": 0, "busy_s": 0.0})

    def __post_init__(self):
        self._fn = jax.jit(self._prefill, static_argnames=())

    def _prefill(self, params, tokens, true_len, tables):
        batch = {"tokens": tokens}
        cache, logits, _ = self.lm.prefill(params, batch, max_len=self.max_len,
                                           tables=tables, true_len=true_len)
        return cache, logits

    def process(self, prompt: tuple) -> tuple:
        """→ (cache B=1, first_token:int, elapsed_s). Exact-prefix APC reuse.
        Prompts are right-padded to pow2 buckets (one compile per bucket);
        true_len keeps the cache/logits exact."""
        t0 = time.monotonic()
        key = tuple(prompt)
        if key in self.cache_exact:
            self.stats["cache_hits"] += 1
            cache, logits = self.cache_exact[key]
        else:
            S = len(prompt)
            pad = min(_bucket(S), self.max_len) - S
            toks = jnp.asarray([list(prompt) + [0] * pad], jnp.int32)
            cache, logits = self._fn(self.params, toks, jnp.int32(S),
                                     self.tables)
            if len(self.cache_exact) < self.cache_cap:
                self.cache_exact[key] = (cache, logits)
            self.stats["prefills"] += 1
            self.stats["tokens"] += S
        first = int(jnp.argmax(logits[0]))
        dt = time.monotonic() - t0
        self.stats["busy_s"] += dt
        return cache, first, dt


@dataclass
class DecodeEngine:
    lm: LM
    params: dict
    tables: Optional[dict]
    n_slots: int
    max_len: int
    hbm_budget_bytes: int = 1 << 34
    stats: dict = field(default_factory=lambda: {
        "steps": 0, "tokens": 0, "busy_s": 0.0, "kv_transfer_bytes": 0,
        "moe_counts": None})

    def __post_init__(self):
        cfg = self.lm.cfg
        self.cache = alloc_cache(cfg, self.lm.mesh, self.lm.plan, self.n_slots,
                                 self.max_len)
        per_slot = kv_bytes(self.cache) // max(self.n_slots, 1)
        self.pool = KVPool(n_blocks=max(self.hbm_budget_bytes // max(per_slot, 1),
                                        self.n_slots) * 4, block_size=16)
        self.free = list(range(self.n_slots))
        self.slot_rid: dict[int, int] = {}
        self.pos = np.zeros(self.n_slots, np.int32)
        self.cur_tok = np.zeros(self.n_slots, np.int32)
        self.active = np.zeros(self.n_slots, bool)
        self._insert = jax.jit(self._insert_impl, donate_argnums=(0,))
        self._step = jax.jit(self._step_impl, donate_argnums=(1,))

    # ------------------------------------------------------------------
    def _insert_impl(self, cache_all, cache_one, slot):
        def ins2(a, o):
            # period/rem cache leaves: [n_rep, B, ...] ← [n_rep, 1, ...]
            return a.at[:, slot].set(o[:, 0])
        new = {"period": jax.tree.map(ins2, cache_all["period"], cache_one["period"]),
               "rem": jax.tree.map(ins2, cache_all["rem"], cache_one["rem"]),
               "pos": cache_all["pos"]}
        return new

    def _step_impl(self, params, cache, tokens, positions, tables):
        new_cache, logits, _ = self.lm.decode(params, cache, tokens, positions,
                                              tables=tables)
        next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return new_cache, next_tok

    # ------------------------------------------------------------------
    def has_capacity(self) -> bool:
        return len(self.free) > 0

    def admit(self, rid: int, cache_one, first_token: int, prompt_len: int) -> bool:
        if not self.free:
            return False
        if not self.pool.allocate(rid, prompt_len + 1):
            return False
        slot = self.free.pop()
        self.cache = self._insert(self.cache, cache_one, slot)
        self.stats["kv_transfer_bytes"] += kv_bytes(cache_one)
        self.slot_rid[slot] = rid
        self.pos[slot] = prompt_len
        self.cur_tok[slot] = first_token
        self.active[slot] = True
        return True

    def step(self) -> dict[int, int]:
        """One batched decode step → {rid: next_token} for active slots."""
        if not self.slot_rid:
            return {}
        t0 = time.monotonic()
        toks = jnp.asarray(self.cur_tok[:, None])
        pos = jnp.asarray(self.pos[:, None])
        self.cache, next_tok = self._step(self.params, self.cache, toks, pos,
                                          self.tables)
        next_np = np.asarray(next_tok)
        out = {}
        for slot, rid in list(self.slot_rid.items()):
            out[rid] = int(next_np[slot])
            self.pool.extend(rid, int(self.pos[slot]) + 1, int(self.pos[slot]) + 2)
            self.pos[slot] += 1
            self.cur_tok[slot] = next_np[slot]
        dt = time.monotonic() - t0
        self.stats["steps"] += 1
        self.stats["tokens"] += len(out)
        self.stats["busy_s"] += dt
        return out

    def release(self, rid: int):
        for slot, r in list(self.slot_rid.items()):
            if r == rid:
                del self.slot_rid[slot]
                self.active[slot] = False
                self.free.append(slot)
                self.pool.release(rid)
                return
