"""Back-compat shim: the monolithic engine module split into per-phase
modules constructed over the explicit device-placement layer.

  serving/placement.py — DevicePlacement (MeshCtx owner, per-leaf sharding
                         specs, the donate_jit choke point)
  serving/arena.py     — KVArena, BlockHandoff, block/dense interchange
  serving/prefill.py   — PrefillEngine, PrefillTask, PrefillResult
  serving/decode.py    — DecodeEngine

Every public name keeps resolving from here; new code should import from
the per-phase modules directly. tests/test_engine_shim.py asserts this
module stays a ≤100-line re-export surface in sync with the real modules.
"""
from repro.serving.arena import (BlockHandoff, KVArena, _bucket, _pow2_floor,
                                 blocks_to_dense_kv, dense_kv_to_blocks,
                                 kv_bytes)
from repro.serving.decode import DecodeEngine
from repro.serving.placement import DevicePlacement
from repro.serving.prefill import PrefillEngine, PrefillResult, PrefillTask

__all__ = [
    "BlockHandoff",
    "DecodeEngine",
    "DevicePlacement",
    "KVArena",
    "PrefillEngine",
    "PrefillResult",
    "PrefillTask",
    "blocks_to_dense_kv",
    "dense_kv_to_blocks",
    "kv_bytes",
]
