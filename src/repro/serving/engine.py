"""In-process serving engines (CPU-real, small models) — the execution layer
under OmniProxy, built for continuous batching over a shared paged-KV arena.

PrefillEngine processes prompts in fixed-size token chunks (jit'd once per
chunk bucket, cache threaded between chunks through LM.prefill_resume) and
schedules queued prompts shortest-remaining-first at chunk granularity, so a
short prompt never sits behind a long in-flight prefill. With a KVArena the
prefill phase is itself PAGED: each chunk reserves real KVPool blocks and
writes its KV straight into the per-layer block arenas through a per-task
block table (kernels/paged_prefill.py / paged_prefill_attention), so an
in-flight prompt pins blocks ∝ its length — never a dense max_len cache —
and a reservation the pool cannot serve DEFERS the task (backpressure)
instead of over-committing HBM. Completed prefixes land in a radix-backed
PrefixKVStore as refcounted block lists sized by real bytes: a later prompt
sharing an N-token prefix maps the entry's full blocks (copying only the
partial tail) and resumes prefill at token N.

DecodeEngine admits pending caches in one donated jit call per batch, keeps
slot state (pos / cur_tok / active) device-side so the hot step has a single
[n_slots] host fetch (the sampled tokens), and masks inactive slots. With
paged=True (default) attention KV lives in physically paged per-layer
arenas; the decode step reads only resident blocks through per-slot block
tables, and a step that cannot grow its allocation preempts the request
(cache gathered back out of the arenas for re-admission) after LRU store
reclaim fails, instead of over-committing HBM. See docs/serving.md.

PD handoff: with a shared arena, admission is a ZERO-COPY block-table
transfer (BlockHandoff: pool ownership renames from the handoff key to the
decode rid; only bounded ring/mamba leaves are inserted). The B=1 dense
cache pytree survives as the paged=False / preemption-re-admission compat
format, scattered into arena blocks (prefix-sharing admissions MAP a live
lender's full prefix blocks instead of copying). The transfer-cost model
meters TRUE resident bytes next to the legacy padded figure.
"""
from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.proxy.params import GREEDY, SamplingParams, device_row
from repro.core.proxy.radix import RadixTree
from repro.models import attention as attn_mod
from repro.models.lm import LM
from repro.models.stack import (alloc_arena_kv, alloc_cache,
                                alloc_paged_private_cache,
                                alloc_prefill_private_cache, cache_struct,
                                cache_window, full_attn_layer,
                                merge_arena_cache, ring_block_count,
                                split_arena_cache)
from repro.serving.kvpool import KVPool, PrefixKVStore, _pytree_bytes
from repro.serving.sampling import sample_tokens
from repro.serving.sparsity import SparsityController


def _bucket(n: int, lo: int = 32) -> int:
    b = lo
    while b < n:
        b *= 2
    return b


def _pow2_floor(n: int) -> int:
    b = 1
    while b * 2 <= n:
        b *= 2
    return b


def kv_bytes(cache) -> int:
    return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(cache))


def dense_kv_to_blocks(x, n_blocks: int, block_size: int):
    """[..., L, K, h] (dense token-major KV) → [..., n_blocks, K, bs, h]
    (kv-head-major arena blocks); the tail is zero-padded to block_size."""
    L, K, h = x.shape[-3:]
    pad = n_blocks * block_size - L
    if pad:
        x = jnp.pad(x, [(0, 0)] * (x.ndim - 3) + [(0, pad), (0, 0), (0, 0)])
    x = x.reshape(x.shape[:-3] + (n_blocks, block_size, K, h))
    return jnp.moveaxis(x, -3, -2)


def blocks_to_dense_kv(x, L: int):
    """Inverse of dense_kv_to_blocks: [..., nb, K, bs, h] → [..., L, K, h]."""
    x = jnp.moveaxis(x, -2, -3)
    nb, bs, K, h = x.shape[-4:]
    return x.reshape(x.shape[:-4] + (nb * bs, K, h))[..., :L, :, :]


# ======================================================================
@dataclass
class KVArena:
    """Shared physically-paged KV runtime: the per-layer full-attention
    block arenas plus their allocator, shared by EVERY paged engine of one
    host. Prefill writes chunk KV straight into the arenas through
    per-task block tables, decode reads/extends them through per-slot
    tables, and admission is a zero-copy block-table transfer. Engines
    follow a compose/split discipline: a jit call takes (private ∪ arena)
    and writes the donated arena leaves back here, so sequential engines
    never hold stale buffers.

    `reclaimers` are backpressure callbacks (prefix stores registering
    `evict_for_blocks`): when an allocation cannot be served, the caller
    asks the arena to reclaim before deferring/preempting."""
    lm: LM
    pool: KVPool
    kv: dict                 # alloc_arena_kv leaves [n_rep?, N, K, bs, h]
    block_size: int
    reclaimers: list = field(default_factory=list)

    @staticmethod
    def build(lm: LM, n_blocks: int, block_size: int = 16) -> "KVArena":
        pool = KVPool(n_blocks=n_blocks, block_size=block_size)
        # +1: arena block 0 is the reserved null block (never allocated)
        kv = alloc_arena_kv(lm.cfg, lm.mesh, lm.plan, n_blocks + 1,
                            block_size)
        return KVArena(lm, pool, kv, block_size)

    def __post_init__(self):
        leaves = jax.tree.leaves(self.kv)
        n = self.pool.n_blocks + 1
        # bytes one arena block pins across every full-attention layer
        self.block_nbytes = sum(x.size // n * x.dtype.itemsize
                                for x in leaves)
        self._copy = jax.jit(self._copy_impl, donate_argnums=(0,))
        self._scrub = jax.jit(self._scrub_impl, donate_argnums=(0,))

    def _copy_impl(self, kv, src, dst):
        # every arena leaf — KV [n_rep?, N, K, bs, h] AND the block-summary
        # plane [n_rep?, N, K, h] — carries the block axis at position 1
        # (stacked period entries) or 0 (rem), so the copy is structural,
        # not ndim-dispatched
        def blk(x, stacked):
            if stacked:
                return x.at[:, dst].set(x[:, src])
            return x.at[dst].set(x[src])
        per = tuple(None if e is None else
                    {k: blk(v, True) for k, v in e.items()}
                    for e in kv["period"])
        rem = tuple(None if e is None else
                    {k: blk(v, False) for k, v in e.items()}
                    for e in kv["rem"])
        return {"period": per, "rem": rem}

    def copy_block(self, src: int, dst: int):
        """Device-copy one physical block across every layer arena (the
        partial-tail copy-on-write for prefix-store resume borrowers).
        The block-summary plane rides along: a copied block's content is
        bit-identical to its source, so copying the summary IS the
        invalidate-and-recompute — the zero-stale-summary invariant holds
        through CoW without touching the keys."""
        if jax.tree.leaves(self.kv):
            self.kv = self._copy(self.kv, jnp.int32(src), jnp.int32(dst))

    def _scrub_impl(self, kv, b):
        # zero every leaf of one block — content AND summary plane — so a
        # quarantined block satisfies summary == reduce(content) forever
        def blk(x, stacked):
            if stacked:
                return x.at[:, b].set(0)
            return x.at[b].set(0)
        per = tuple(None if e is None else
                    {k: blk(v, True) for k, v in e.items()}
                    for e in kv["period"])
        rem = tuple(None if e is None else
                    {k: blk(v, False) for k, v in e.items()}
                    for e in kv["rem"])
        return {"period": per, "rem": rem}

    def scrub_block(self, b: int):
        """Zero one physical block across every layer arena (corruption
        quarantine: the block leaves circulation, and zeroed content with a
        zeroed summary keeps `check_summaries` green — all-zero keys reduce
        to all-zero min/max/mean)."""
        if jax.tree.leaves(self.kv):
            self.kv = self._scrub(self.kv, jnp.int32(b))

    def find_corrupt_blocks(self) -> list:
        """Summary-plane corruption scan: block ids whose stored key
        summaries disagree with a fresh reduction of the block's key
        content. A fault (bit-flip, lost write, partial DMA) that mutates K
        without going through a summary-maintaining write path trips this —
        the detection half of the FaultPlane corruption story. Host scan
        (fetches the key arenas); call at recovery points, not per step."""
        n = self.pool.n_blocks + 1
        bad = np.zeros(n, bool)

        def one(entry, stacked):
            if entry is None or "kmin" not in entry:
                return
            k = np.asarray(entry["k"], np.float32)
            mism = (np.asarray(entry["kmin"], np.float32) != k.min(axis=-2)) \
                | (np.asarray(entry["kmax"], np.float32) != k.max(axis=-2))
            # reduce every axis except the block axis
            ax = 1 if stacked else 0
            red = tuple(i for i in range(mism.ndim) if i != ax)
            np.logical_or(bad, mism.any(axis=red), out=bad)
        for e in self.kv["period"]:
            one(e, True)
        for e in self.kv["rem"]:
            one(e, False)
        return [int(b) for b in np.nonzero(bad)[0]]

    def check_summaries(self):
        """Zero-stale-summary invariant: for EVERY arena block of every
        full-attention layer, the stored per-block key summaries equal a
        fresh reduction of the block's key content. Holds at any quiescent
        point because every path that writes arena K recomputes the touched
        blocks' summaries in the same jit (prefill chunk writes, decode
        appends, dense-scatter admission) and copy_block copies content and
        summary together. Test/diagnostic helper — fetches the arenas."""
        def one(entry):
            if entry is None or "kmin" not in entry:
                return
            k = np.asarray(entry["k"], np.float32)
            np.testing.assert_array_equal(np.asarray(entry["kmin"]),
                                          k.min(axis=-2),
                                          err_msg="stale kmin summary")
            np.testing.assert_array_equal(np.asarray(entry["kmax"]),
                                          k.max(axis=-2),
                                          err_msg="stale kmax summary")
            np.testing.assert_allclose(np.asarray(entry["kmean"]),
                                       k.mean(axis=-2), rtol=1e-5, atol=1e-6,
                                       err_msg="stale kmean summary")
        for e in self.kv["period"]:
            one(e)
        for e in self.kv["rem"]:
            one(e)

    def reclaim(self, n_blocks: int) -> int:
        """Free up to `n_blocks` pool blocks by evicting shared cache
        state (LRU prefix-store entries first). → blocks actually freed."""
        freed = 0
        for cb in self.reclaimers:
            if freed >= n_blocks:
                break
            freed += cb(n_blocks - freed)
        return freed


@dataclass
class BlockHandoff:
    """Zero-copy PD handoff record: a finished prefill's pool-owned block
    table plus the bounded private leaves (ring KV, mamba state, position).
    Admission transfers pool ownership from `key` to the decode rid — no
    full-attention KV byte is copied (`handoff_copy_bytes == 0`); the
    dense-pytree handoff survives as the paged=False / cross-arena compat
    path."""
    key: tuple                         # pool ownership key ("handoff", i)
    blocks: tuple                      # physical block ids, logical order
    private: dict                      # B=1 cache without full-attn entries
    pos: int                           # resident tokens


# ======================================================================
@dataclass
class PrefillTask:
    rid: int
    prompt: tuple
    cache: object = None              # threaded B=1 cache (None until started)
    logits: object = None             # last-token logits of the latest chunk
    cursor: int = 0                   # tokens resident (incl. reused prefix)
    reused: int = 0                   # prefix tokens resumed from the store
    snap: int = 0                     # snapshot boundary (shared-prefix hint)
    params: SamplingParams = GREEDY   # first-token decoding config
    t_start: float = 0.0
    compute_s: float = 0.0            # pure prefill compute (excl. queue wait)
    handoff: object = None            # BlockHandoff once finished (paged)

    @property
    def remaining(self) -> int:
        return len(self.prompt) - self.cursor


@dataclass
class PrefillResult:
    rid: int
    cache: object
    first_token: int
    prompt_len: int
    reused: int
    elapsed_s: float                  # prefill compute time (EWMA batch time)
    t_done: float = 0.0               # wall time the first token materialized


@dataclass
class PrefillEngine:
    _next_handoff_id = 0              # shared-pool-unique handoff keys
    lm: LM
    params: dict
    tables: Optional[dict]
    max_len: int
    chunk_tokens: int = 64            # target chunk size (TTFT/TPOT knob)
    enable_chunked: bool = True
    allow_partial_reuse: bool = True
    cache_cap: int = 32               # PrefixKVStore entries
    cache_cap_bytes: Optional[int] = None   # PrefixKVStore byte cap (LRU)
    tree: Optional[RadixTree] = None  # share the proxy's per-instance tree
    arena: Optional[KVArena] = None   # shared paged-KV runtime → paged mode
    block_size: int = 16              # accounting granularity (dense mode)
    stats: dict = field(default_factory=lambda: {
        "prefills": 0, "cache_hits": 0, "prefix_hits": 0, "reused_tokens": 0,
        "tokens": 0, "chunks": 0, "busy_s": 0.0, "host_fetches": 0,
        "blocks_mapped": 0, "prefill_kv_peak_blocks": 0, "defers": 0})

    def __post_init__(self):
        self._fn = jax.jit(self._prefill)
        self._resume = jax.jit(self._resume_impl, donate_argnums=(2,),
                               static_argnums=(5,))
        self._first = jax.jit(self._first_impl)
        self.queue: deque[PrefillTask] = deque()
        self._ready: list[PrefillResult] = []
        sup, limit = self.lm.chunked_prefill_support
        self.chunk = _pow2_floor(max(min(self.chunk_tokens, limit), 1))
        self.chunked = bool(self.enable_chunked and sup and self.chunk >= 8)
        # paged prefill rides the chunked machinery (blocks grow per chunk);
        # with chunking unsupported the engine falls back to dense prefill
        # and the decode engine's dense-scatter admission compat path
        self.paged = bool(self.arena is not None and self.chunked)
        if self.paged:
            self.block_size = self.arena.block_size
            self._resume_paged = jax.jit(self._resume_paged_impl,
                                         donate_argnums=(2,))
        self.store = PrefixKVStore(
            self.tree, self.cache_cap,
            pool=self.arena.pool if self.paged else None,
            capacity_bytes=self.cache_cap_bytes)
        if self.paged:
            self.arena.reclaimers.append(self.store.evict_for_blocks)

    # ---- jit bodies --------------------------------------------------
    def _prefill(self, params, tokens, true_len, tables):
        cache, logits, _ = self.lm.prefill(params, {"tokens": tokens},
                                           max_len=self.max_len, tables=tables,
                                           true_len=true_len)
        return cache, logits

    def _resume_impl(self, params, tokens, cache, chunk_len, tables,
                     attend_limit):
        cache, logits, _ = self.lm.prefill_resume(
            params, {"tokens": tokens}, cache, max_len=self.max_len,
            tables=tables, chunk_len=chunk_len, attend_limit=attend_limit)
        return cache, logits

    def _resume_paged_impl(self, params, tokens, cache, chunk_len, tables,
                           tbl_row):
        """One paged chunk: full-attention cache leaves are the shared
        arenas, the chunk's KV is written straight into the tabled blocks
        (no dense max_len cache exists anywhere on this path)."""
        cache, logits, _ = self.lm.prefill_resume(
            params, {"tokens": tokens}, cache, max_len=self.max_len,
            tables=tables, chunk_len=chunk_len, block_tables=tbl_row)
        return cache, logits

    def _first_impl(self, logits_tuple, temp, tk, tp, keys, fold):
        """Fused first-token sampling over the stacked last-token logits of
        a batch of finished prefills (pow2-padded)."""
        logits = jnp.concatenate(logits_tuple, axis=0)
        return sample_tokens(logits, temp, tk, tp, keys, fold)

    # ---- paged-KV helpers --------------------------------------------
    @staticmethod
    def _pf_key(rid: int) -> tuple:
        return ("prefill", rid)

    def _resize_full_attn(self, cache, length: int, copy_rest: bool = False):
        """Slice or zero-pad the full-attention KV leaves of a dense B=1
        cache to `length` tokens (the prefix-store sizing fix: stored
        prefixes pin prefix-length KV, not a max_len allocation). Ring /
        mamba leaves are untouched (bounded) unless copy_rest — then they
        are jnp.copy'd so the snapshot survives chunk-to-chunk donation."""
        cfg, plan = self.lm.cfg, self.lm.plan

        def one(spec, entry, stacked):
            if entry is None:
                return None
            if not full_attn_layer(cfg, spec):
                return jax.tree.map(jnp.copy, entry) if copy_rest else entry
            ax = 2 if stacked else 1

            def f(x):
                W = x.shape[ax]
                if W > length:
                    idx = [slice(None)] * x.ndim
                    idx[ax] = slice(0, length)
                    return x[tuple(idx)]
                if W < length:
                    pad = [(0, 0)] * x.ndim
                    pad[ax] = (0, length - W)
                    return jnp.pad(x, pad)
                return jnp.copy(x) if copy_rest else x
            return {kk: f(vv) for kk, vv in entry.items()}

        return {"period": tuple(one(s, cache["period"][i], True)
                                for i, s in enumerate(plan.period)),
                "rem": tuple(one(s, cache["rem"][i], False)
                             for i, s in enumerate(plan.rem)),
                "pos": jnp.copy(cache["pos"]) if copy_rest else cache["pos"]}

    def _grow_blocks(self, task: PrefillTask, cl: int) -> bool:
        """Reserve pool blocks for the next `cl` chunk tokens. On
        exhaustion, reclaim shared cache (LRU store entries) and retry;
        still short → False (the caller defers this task — backpressure
        instead of HBM over-commit)."""
        pool, key = self.arena.pool, self._pf_key(task.rid)
        target = task.cursor + cl

        def attempt():
            if key in pool:
                return pool.extend(key, task.cursor, target)
            return pool.allocate(key, target)

        got = attempt()
        if got is None:
            held = len(pool.owned(key)) if key in pool else 0
            need = pool.blocks_for(target) - held - pool.free_blocks
            self.arena.reclaim(max(need, 1))
            got = attempt()
        return got is not None

    def _table_row(self, rid: int) -> jnp.ndarray:
        nb = -(-self.max_len // self.block_size)
        row = np.zeros((1, nb), np.int32)
        owned = self.arena.pool.owned(self._pf_key(rid))
        row[0, :len(owned)] = owned
        return jnp.asarray(row)

    def _store_put_paged(self, task: PrefillTask, n: int,
                         copy_private: bool) -> None:
        """Publish the first `n` tokens of a task as a store entry: the
        covering blocks are adopted (refcounted) by the store — zero copy —
        and only the bounded private leaves are snapshotted. Entry size is
        the REAL resident bytes, so LRU eviction can tell a 16-token prefix
        from a 2048-token one."""
        pool = self.arena.pool
        blocks = pool.owned(self._pf_key(task.rid))[:pool.blocks_for(n)]
        priv = jax.tree.map(jnp.copy, task.cache) if copy_private \
            else task.cache
        nbytes = (len(blocks) * self.arena.block_nbytes + _pytree_bytes(priv)
                  + _pytree_bytes(task.logits))
        self.store.put(task.prompt[:n], priv, task.logits, blocks=blocks,
                       nbytes=nbytes)

    def _release_result(self, rec: PrefillResult) -> None:
        """Drop an undelivered result (supersede/abort): a paged handoff
        still owns pool blocks that nobody will ever admit."""
        if isinstance(rec.cache, BlockHandoff):
            self.arena.pool.release(rec.cache.key)

    def _note_peak(self, task: PrefillTask) -> None:
        """Work-based memory metric: peak KV blocks pinned by a SINGLE
        in-flight prefill. Paged tasks grow per chunk, so the peak is
        blocks_for(prompt_len); a dense task pins a blocks_for(max_len)
        cache from its first chunk regardless of prompt length — exactly
        the prefill-phase over-commit paged prefill removes."""
        if self.paged:
            held = len(self.arena.pool.owned(self._pf_key(task.rid)))
        else:
            held = -(-self.max_len // self.block_size)
        if held > self.stats["prefill_kv_peak_blocks"]:
            self.stats["prefill_kv_peak_blocks"] = held

    # ---- scheduling --------------------------------------------------
    def start(self, rid: int, prompt: tuple, prefix_hint: int = 0,
              params: Optional[SamplingParams] = None) -> None:
        """Enqueue a prompt. Exact store hits complete immediately (drained
        by the next step()); partial hits resume at the stored boundary.
        prefix_hint (the proxy's Match_P, computed before self-insertion)
        marks a prefix shared with other prompts: the engine snapshots its
        cache at that boundary so later sharers can resume there."""
        # a re-dispatch of the same rid (instance fail/recover) supersedes any
        # queued task or undelivered result — otherwise both complete and the
        # proxy sees duplicate first tokens
        for t in list(self.queue):
            if t.rid == rid:
                self.queue.remove(t)
                if self.paged:
                    self.arena.pool.release(self._pf_key(rid))
        for r in self._ready:
            if r.rid == rid:
                self._release_result(r)
        self._ready = [r for r in self._ready if r.rid != rid]
        task = PrefillTask(rid, tuple(prompt), params=params or GREEDY,
                           t_start=time.monotonic())
        if (self.chunked and self.allow_partial_reuse
                and 8 <= prefix_hint < len(task.prompt)):
            task.snap = prefix_hint
        self._try_resume(task)
        self.queue.append(task)

    def _try_resume(self, task: PrefillTask) -> None:
        """Resume from the deepest stored prefix (exact hits: adopt whole)."""
        if self.paged:
            self._try_resume_paged(task)
            return
        n, cache, logits = self.store.lookup(task.prompt)
        if cache is None or n <= task.cursor:
            return
        if n == len(task.prompt):
            # stored caches are prefix-trimmed: pad the full-attention KV
            # back to the engine's max_len working shape (ring/mamba leaves
            # are shared — an adopted whole is never donated downstream)
            task.cache, task.logits = \
                self._resize_full_attn(cache, self.max_len), logits
            task.cursor = task.reused = n
            return
        if self.chunked and self.allow_partial_reuse:
            # copy — the threaded cache is donated chunk-to-chunk and must
            # not eat the store's buffers
            task.cache = self._resize_full_attn(cache, self.max_len,
                                                copy_rest=True)
            task.logits = logits
            task.cursor = task.reused = n
            self.stats["prefix_hits"] += 1
            self.stats["reused_tokens"] += n

    def _try_resume_paged(self, task: PrefillTask) -> None:
        """Paged resume: map the entry's FULL prefix blocks into the task's
        table (refcount++, zero copy); a partial tail block is copied into
        a private block — its content diverges as the task appends. Exact
        hits adopt the same way (the tail copy keeps two adopters of one
        prompt from clobbering each other's decode-time appends)."""
        ent = self.store.lookup_entry(task.prompt)
        if ent is None or ent.n <= task.cursor or ent.blocks is None:
            return
        if not (self.allow_partial_reuse or ent.n == len(task.prompt)):
            return
        pool, key = self.arena.pool, self._pf_key(task.rid)
        if key in pool:                 # mid-flight deepening is unsound
            return
        n = ent.n
        full = n // pool.block_size
        # pin the entry's blocks for the duration: reclaim-under-pressure
        # below may evict THIS entry, and without the pin its released
        # blocks would hit the free list while we are about to map them as
        # `shared` (and read the tail for the copy) — allocator corruption
        pin = ("resume-pin", task.rid)
        pool.adopt(pin, ent.blocks)
        try:
            tbl = pool.allocate(key, n, shared=ent.blocks[:full])
            if tbl is None:
                self.arena.reclaim(pool.blocks_for(n) - full)
                tbl = pool.allocate(key, n, shared=ent.blocks[:full])
                if tbl is None:
                    return              # backpressure: prefill from scratch
            if pool.blocks_for(n) > full:   # partial tail → copy-on-write
                self.arena.copy_block(ent.blocks[full], tbl[full])
        finally:
            pool.release(pin)
        # private leaves are donated chunk-to-chunk: always copy
        task.cache = jax.tree.map(jnp.copy, ent.cache)
        task.logits = ent.logits
        task.cursor = task.reused = n
        self.stats["blocks_mapped"] += full
        if n < len(task.prompt):
            self.stats["prefix_hits"] += 1
            self.stats["reused_tokens"] += n

    def has_work(self) -> bool:
        return bool(self.queue or self._ready)

    def abort(self, rid: int) -> bool:
        """Drop a queued / in-flight / completed-but-undelivered prompt.
        The task's private cache is released to the GC and its pool blocks
        (paged) are released; store snapshots it already published stay —
        they are shared cache, not request state (their blocks are
        refcounted under the store's own key)."""
        hit = False
        for t in list(self.queue):
            if t.rid == rid:
                self.queue.remove(t)
                hit = True
        if self.paged:
            self.arena.pool.release(self._pf_key(rid))
        n0 = len(self._ready)
        for r in self._ready:
            if r.rid == rid:
                self._release_result(r)
        self._ready = [r for r in self._ready if r.rid != rid]
        return hit or len(self._ready) != n0

    def drop_results(self) -> int:
        """Discard every completed-but-undelivered result, releasing paged
        handoff blocks (instance-death recovery: a dead engine's results
        will never be drained by the server loop — without this their
        ("handoff", i) pool keys leak). → results dropped."""
        n = len(self._ready)
        for r in self._ready:
            self._release_result(r)
        self._ready = []
        return n

    def step(self, token_budget: int = 1 << 30) -> list[PrefillResult]:
        """Run up to `token_budget` tokens of prefill work; → completed
        prompts. Chunked mode schedules shortest-remaining-first at chunk
        granularity (a short prompt preempts an in-flight long prefill at
        the next chunk boundary); unchunked mode is the pre-chunking engine:
        FIFO, one whole prompt per call. Paged tasks that cannot grow their
        block reservation are DEFERRED for the round (stats.defers) rather
        than over-committing — they retry when decode/store releases free
        blocks."""
        done, budget = self._ready, token_budget
        self._ready = []
        fresh: list[PrefillTask] = []
        blocked: set[int] = set()
        t0 = time.monotonic()
        while budget > 0:
            cands = [t for t in self.queue if t.rid not in blocked]
            if not cands:
                break
            task = (min(cands, key=lambda t: t.remaining)
                    if self.chunked else cands[0])
            if task.cursor == 0:
                # entries stored since enqueue (e.g. a queued sharer's
                # snapshot) are visible to tasks that have not started
                self._try_resume(task)
            if task.remaining > 0:
                ran = (self._run_chunk(task, min(budget, self.chunk))
                       if self.chunked else self._run_full(task))
                if ran == 0 and task.remaining > 0:
                    blocked.add(task.rid)       # pool backpressure: defer
                    continue
                budget -= ran
            if task.remaining == 0:
                self.queue.remove(task)
                fresh.append(self._finish(task))
        if fresh:
            done.extend(self._emit(fresh))
        self.stats["busy_s"] += time.monotonic() - t0
        return done

    def _run_chunk(self, task: PrefillTask, budget: int) -> int:
        t0 = time.monotonic()
        cl = min(self.chunk, task.remaining, max(budget, 1))
        if task.cursor < task.snap:
            cl = min(cl, task.snap - task.cursor)   # land on the boundary
        if self.paged and not self._grow_blocks(task, cl):
            self.stats["defers"] += 1
            return 0
        if task.cache is None:
            task.cache = (alloc_prefill_private_cache(
                self.lm.cfg, self.lm.mesh, self.lm.plan, self.max_len)
                if self.paged else
                alloc_cache(self.lm.cfg, self.lm.mesh, self.lm.plan, 1,
                            self.max_len))
        S = min(_bucket(cl, lo=8), self.chunk)
        toks = list(task.prompt[task.cursor:task.cursor + cl]) + [0] * (S - cl)
        if self.paged:
            # chunk KV is written straight into the arena blocks through
            # the task's table — the composed cache's full-attention leaves
            # ARE the shared arenas (donated and written back)
            composed = merge_arena_cache(self.lm.cfg, self.lm.plan,
                                         task.cache, self.arena.kv)
            composed, task.logits = self._resume_paged(
                self.params, jnp.asarray([toks], jnp.int32), composed,
                jnp.int32(cl), self.tables, self._table_row(task.rid))
            task.cache, self.arena.kv = split_arena_cache(
                self.lm.cfg, self.lm.plan, composed)
        else:
            # attend_limit=0: one trace per chunk bucket. (Passing a pow2
            # prefix bound trims attention flops but multiplies trace
            # count — a win on accelerators, a compile-stall hazard on the
            # CPU-real path.)
            task.cache, task.logits = self._resume(
                self.params, jnp.asarray([toks], jnp.int32), task.cache,
                jnp.int32(cl), self.tables, 0)
        task.cursor += cl
        self.stats["tokens"] += cl
        self.stats["chunks"] += 1
        self._note_peak(task)
        if task.cursor == task.snap:
            shared = task.prompt[:task.snap]
            if self.store.lookup(shared)[0] != task.snap:
                if self.paged:
                    self._store_put_paged(task, task.snap, copy_private=True)
                else:
                    # prefix-length snapshot (sizing fix): slice the
                    # full-attention KV to the boundary instead of pinning
                    # a max_len copy
                    self.store.put(
                        shared,
                        self._resize_full_attn(
                            task.cache,
                            min(_bucket(task.snap, lo=8), self.max_len),
                            copy_rest=True),
                        task.logits)
        task.compute_s += time.monotonic() - t0
        return cl

    def _run_full(self, task: PrefillTask) -> int:
        t0 = time.monotonic()
        S = len(task.prompt)
        # lo=8: same bucket floor as the chunked path — a short prompt must
        # not compile a gratuitous extra trace just because it arrived at
        # an unchunked engine
        pad = min(_bucket(S, lo=8), self.max_len) - S
        toks = jnp.asarray([list(task.prompt) + [0] * pad], jnp.int32)
        task.cache, task.logits = self._fn(self.params, toks, jnp.int32(S),
                                           self.tables)
        task.cursor = S
        self.stats["tokens"] += S
        self._note_peak(task)
        task.compute_s += time.monotonic() - t0
        return S

    def _finish(self, task: PrefillTask) -> PrefillTask:
        """Store bookkeeping for a completed prompt. The first token is NOT
        sampled here: finished tasks of one engine round are sampled in a
        single fused call (`_emit`) — the per-record `int(jnp.argmax(...))`
        host sync is gone. Paged tasks turn into a BlockHandoff: pool
        ownership moves from the task to the handoff record, which
        admission later renames to the decode rid — zero copy end to end."""
        L = len(task.prompt)
        if task.reused == L:                    # whole prompt adopted
            self.stats["cache_hits"] += 1
        else:
            self.stats["prefills"] += 1
            if self.paged:
                self._store_put_paged(task, L, copy_private=False)
            else:
                self.store.put(
                    task.prompt,
                    self._resize_full_attn(
                        task.cache, min(_bucket(L, lo=8), self.max_len)),
                    task.logits)
        if self.paged:
            pool, key = self.arena.pool, self._pf_key(task.rid)
            # class-level counter: several engines share one pool (arena),
            # so handoff keys must be unique ACROSS engines — per-engine
            # counters collide at ("handoff", 0)
            hkey = ("handoff", PrefillEngine._next_handoff_id)
            PrefillEngine._next_handoff_id += 1
            blocks = tuple(pool.transfer(key, hkey))
            task.handoff = BlockHandoff(hkey, blocks, task.cache, L)
        return task

    def _emit(self, tasks: list) -> list[PrefillResult]:
        toks = self.sample_first([t.logits for t in tasks],
                                 [t.params for t in tasks],
                                 [t.rid for t in tasks],
                                 [len(t.prompt) for t in tasks])
        t_done = time.monotonic()
        return [PrefillResult(t.rid, t.handoff if t.handoff is not None
                              else t.cache, int(tok), len(t.prompt),
                              t.reused, t.compute_s, t_done)
                for t, tok in zip(tasks, toks)]

    def sample_first(self, logits_list, params_list, rids, folds
                     ) -> np.ndarray:
        """Sample the first token for a batch of finished prompts under
        each one's SamplingParams in ONE jit call + ONE host fetch
        (pow2-padded to bound retraces). logits_list: [1, V] arrays;
        folds: context lengths (= prompt lengths)."""
        n = len(logits_list)
        npad = _bucket(n, lo=1)
        logits = tuple(logits_list) + (logits_list[-1],) * (npad - n)
        rows = [device_row(p, r) for p, r in zip(params_list, rids)]
        rows += [rows[-1]] * (npad - n)
        temp = jnp.asarray([r[0] for r in rows], jnp.float32)
        tk = jnp.asarray([r[1] for r in rows], jnp.int32)
        tp = jnp.asarray([r[2] for r in rows], jnp.float32)
        keys = jnp.asarray(np.stack([r[3] for r in rows]))
        fold = jnp.asarray(list(folds) + [folds[-1]] * (npad - n), jnp.int32)
        out = np.asarray(self._first(logits, temp, tk, tp, keys, fold))
        self.stats["host_fetches"] += 1
        return out[:n]

    # ---- blocking back-compat API ------------------------------------
    def process(self, prompt: tuple) -> tuple:
        """→ (cache B=1, first_token:int, elapsed_s). Runs the prompt to
        completion (chunked underneath when supported)."""
        t0 = time.monotonic()
        self.start(-1, tuple(prompt))
        while True:
            recs = self.step()
            self._ready.extend(r for r in recs if r.rid != -1)
            for rec in recs:
                if rec.rid == -1:
                    return rec.cache, rec.first_token, time.monotonic() - t0


# ======================================================================
@dataclass
class DecodeEngine:
    """Continuous-batch decode engine.

    paged=True (default): attention KV lives in physically paged per-layer
    arenas. Admission allocates real blocks from the KVPool and scatters the
    incoming B=1 dense cache into them (prefix-sharing admissions map the
    lender's full prefix blocks instead of writing them — only the partial
    tail block and the suffix are copied); each decode step writes the new
    token's K/V through the per-slot block table and attends over resident
    blocks only; preemption extracts the dense cache back out of the arenas
    and releases the blocks (refcounted — shared blocks survive until their
    last mapper leaves). paged=False preserves the slot-dense layout with
    accounting-only admission control.
    """
    lm: LM
    params: dict
    tables: Optional[dict]
    n_slots: int
    max_len: int
    hbm_budget_bytes: int = 1 << 34
    kv_blocks: Optional[int] = None   # explicit pool size (tests/benchmarks)
    paged: bool = True                # physically paged attention KV
    block_size: int = 16
    arena: Optional[KVArena] = None   # shared arena (co-located prefill)
    stats: dict = field(default_factory=lambda: {
        "steps": 0, "tokens": 0, "busy_s": 0.0, "kv_transfer_bytes": 0,
        "kv_transfer_bytes_padded": 0, "handoff_copy_bytes": 0,
        "admits": 0, "preemptions": 0, "moe_counts": None,
        "blocks_touched": 0, "blocks_shared": 0, "blocks_fresh": 0,
        "host_fetches": 0})

    def __post_init__(self):
        cfg = self.lm.cfg
        if self.paged:
            if self.arena is None:
                if self.kv_blocks is None:
                    # capacity parity with the dense layout: every slot can
                    # run to max_len; the pool turns that into admission
                    # flexibility
                    self.kv_blocks = self.n_slots * \
                        -(-self.max_len // self.block_size)
                self.arena = KVArena.build(self.lm, self.kv_blocks,
                                           self.block_size)
            self.block_size = self.arena.block_size
            self.kv_blocks = self.arena.pool.n_blocks
        self.max_blocks = -(-self.max_len // self.block_size)
        self.sparsity = None
        if self.paged:
            # engine-private side only: per-slot ring arenas + non-attention
            # state; the full-attention arenas live in the (possibly shared)
            # KVArena and are composed in around every jit call
            self.cache = alloc_paged_private_cache(
                cfg, self.lm.mesh, self.lm.plan, self.n_slots, self.max_len,
                self.block_size)
            self.tables_h = np.zeros((self.n_slots, self.max_blocks), np.int32)
            self._tbl_dev = jnp.asarray(self.tables_h)
            self._tbl_bucket = self.max_blocks
            self._tbl_dirty = False
            # online top-k block selection (OmniAttn dynamic sparsity):
            # resolved once from cfg.omniattn — the step jit reads the same
            # config, so controller and trace always agree
            self.sparsity = SparsityController.from_model(
                cfg, self.lm.plan, self.block_size, self.max_blocks)
            if self.sparsity is not None:
                self.stats.update(SparsityController.stats_keys())
        else:
            self.cache = alloc_cache(cfg, self.lm.mesh, self.lm.plan,
                                     self.n_slots, self.max_len)
            if self.kv_blocks is None:
                per_slot = kv_bytes(self.cache) // max(self.n_slots, 1)
                budget = max(self.hbm_budget_bytes // max(per_slot, 1),
                             self.n_slots) * 4
                # the accounting pool only needs to never constrain below the
                # slot-dense physical capacity — don't materialize a free
                # list for the raw HBM-budget block count (~1e5 ids)
                self.kv_blocks = min(budget,
                                     self.n_slots * self.max_blocks * 4)
        self.pool = self.arena.pool if self.paged else \
            KVPool(n_blocks=self.kv_blocks, block_size=self.block_size)
        # PD transfer-cost metering constants: a B=1 dense handoff cache is
        # `_dense_kv_nbytes` regardless of prompt length (the padded figure
        # the old meter charged); the TRUE payload is the bounded leaves
        # plus `_full_tok_nbytes` per resident token of full-attention KV.
        it = jnp.dtype(cfg.compute_dtype).itemsize
        n_full = sum(1 for sp in self.lm.plan.all_specs()
                     if full_attn_layer(cfg, sp))
        self._full_tok_nbytes = 2 * cfg.n_kv_heads * cfg.head_dim * it * n_full
        sds, _ = cache_struct(cfg, self.lm.mesh, self.lm.plan, 1, self.max_len)
        self._dense_kv_nbytes = sum(
            int(np.prod(s.shape)) * jnp.dtype(s.dtype).itemsize
            for s in jax.tree.leaves(sds))
        self.free = list(range(self.n_slots))
        self.slot_rid: dict[int, int] = {}
        self.rid_slot: dict[int, int] = {}
        self._prompts: dict[int, tuple] = {}   # live rid → prompt (sharing)
        # device-resident slot state threaded (donated) through the step jit;
        # host mirrors updated from values we already know — no device sync.
        # Per-slot sampling parameters + PRNG base keys live here too, so
        # the fused step samples the whole batch without any host traffic
        # (temp <= 0 rows take the greedy argmax branch).
        self.state = {"pos": jnp.zeros(self.n_slots, jnp.int32),
                      "tok": jnp.zeros(self.n_slots, jnp.int32),
                      "active": jnp.zeros(self.n_slots, bool),
                      "temp": jnp.zeros(self.n_slots, jnp.float32),
                      "top_k": jnp.zeros(self.n_slots, jnp.int32),
                      "top_p": jnp.ones(self.n_slots, jnp.float32),
                      "key": jnp.zeros((self.n_slots, 2), jnp.uint32)}
        n_moe = sum(1 for sp in self.lm.plan.all_specs() if sp.use_moe)
        if n_moe and cfg.moe.n_experts:
            # expert activation counts accumulate device-side too — fetched
            # (and reset) only at placement ticks via take_moe_counts()
            self.state["moe_counts"] = jnp.zeros((n_moe, cfg.moe.n_experts),
                                                 jnp.float32)
        if self.sparsity is not None:
            # online-sparsity window [blocks_scored, blocks_attended,
            # mass_sum, mass_n], layer-summed — accumulates device-side in
            # the step jit, drained only via take_sparsity_stats()
            self.state["sparsity"] = jnp.zeros(4, jnp.float32)
        self.pos_h = np.zeros(self.n_slots, np.int64)      # next write position
        self.tok_h = np.zeros(self.n_slots, np.int64)      # current input token
        self.tokens_h = np.zeros(self.n_slots, np.int64)   # pool-accounted tokens
        self.preempted: list[tuple] = []   # (rid, cache_one, next_tok, pos)
        if self.paged:
            self._insert = jax.jit(self._insert_paged_impl,
                                   donate_argnums=(0, 1))
            self._insert_handle = jax.jit(self._insert_handle_impl,
                                          donate_argnums=(0, 1))
            self._extract = jax.jit(self._extract_paged_impl)
        else:
            self._insert = jax.jit(self._insert_impl, donate_argnums=(0, 1))
            self._extract = jax.jit(self._extract_impl)
        self._step = jax.jit(self._step_impl, donate_argnums=(1, 2))

    # ---- arena compose/split -----------------------------------------
    # Paged jit calls take (private ∪ arena) and write the donated arena
    # leaves back, so the prefill engine sharing this arena never reads a
    # buffer this engine invalidated (execution is sequential in-process).
    def _full_cache(self):
        if not self.paged:
            return self.cache
        return merge_arena_cache(self.lm.cfg, self.lm.plan, self.cache,
                                 self.arena.kv)

    def _store_cache(self, cache):
        if not self.paged:
            self.cache = cache
            return
        self.cache, self.arena.kv = split_arena_cache(self.lm.cfg,
                                                      self.lm.plan, cache)

    def _true_kv_nbytes(self, n_tokens: int) -> int:
        """REAL bytes of a request's KV payload at `n_tokens` resident
        tokens: bounded leaves (ring KV, mamba state) plus per-token
        full-attention KV — the transfer-cost figure that does NOT meter
        max_len padding (a 64-token prompt in a max_len=2048 cache used to
        charge 32× its real bytes)."""
        bounded = self._dense_kv_nbytes - self._full_tok_nbytes * self.max_len
        return bounded + self._full_tok_nbytes * min(n_tokens, self.max_len)

    # ---- paged layout helpers (trace-level) --------------------------
    def _attn_classes(self):
        """[(spec, (sink, recent)) for period entries], same for rem."""
        cfg = self.lm.cfg
        per = [(s, cache_window(cfg, s)) for s in self.lm.plan.period]
        rem = [(s, cache_window(cfg, s)) for s in self.lm.plan.rem]
        return per, rem

    def _insert_attn_paged(self, win, entry, one, slot, wtbl, stacked):
        """Scatter one request's dense per-layer KV into arena blocks.
        Full layers write through `wtbl` (shared prefix entries redirected to
        the null block — mapped, not copied); ring layers overwrite the
        slot's statically owned block run. Full-layer writes recompute the
        written blocks' key summaries in the same jit, so dense→paged
        (re-)admission never leaves a stale summary (shared prefix entries
        redirect to the null block — the lender's summaries stand)."""
        sink, recent = win
        bs = self.block_size
        out = dict(entry)
        for name in ("k", "v"):
            a = entry[name]
            o = one[name][:, 0] if stacked else one[name][0]   # [(R,) L, K, h]
            if sink or recent:
                bpw = ring_block_count(sink, recent, bs)
                blocks = dense_kv_to_blocks(o, bpw, bs).astype(a.dtype)
                start = (0, slot * bpw, 0, 0, 0) if stacked else \
                    (slot * bpw, 0, 0, 0)
                a = jax.lax.dynamic_update_slice(a, blocks, start)
            else:
                blocks = dense_kv_to_blocks(o, self.max_blocks,
                                            bs).astype(a.dtype)
                a = a.at[:, wtbl].set(blocks) if stacked else \
                    a.at[wtbl].set(blocks)
            out[name] = a
        if wtbl is not None and "kmin" in entry:
            out["kmin"], out["kmax"], out["kmean"] = \
                attn_mod.update_block_summaries(
                    entry["kmin"], entry["kmax"], entry["kmean"], out["k"],
                    wtbl, stacked=stacked)
        return out

    def _extract_attn_paged(self, win, entry, slot, tbl, stacked):
        """Gather one slot's dense per-layer KV back out of the arenas."""
        sink, recent = win
        bs = self.block_size
        out = {}
        for name in ("k", "v"):
            a = entry[name]
            K, h = a.shape[-3], a.shape[-1]
            if sink or recent:
                W = sink + recent
                bpw = ring_block_count(sink, recent, bs)
                if stacked:
                    blocks = jax.lax.dynamic_slice(
                        a, (0, slot * bpw, 0, 0, 0),
                        (a.shape[0], bpw, K, bs, h))
                else:
                    blocks = jax.lax.dynamic_slice(
                        a, (slot * bpw, 0, 0, 0), (bpw, K, bs, h))
                x = blocks_to_dense_kv(blocks, W)
            else:
                blocks = a[:, tbl] if stacked else a[tbl]
                x = blocks_to_dense_kv(blocks, self.max_len)
            out[name] = x[:, None] if stacked else x[None]
        return out

    # ---- jit bodies --------------------------------------------------
    def _slot_state(self, state, slots, toks, poss, samp):
        """Write the admitted slots' scalar state + sampling rows."""
        temps, tks, tps, keys = samp
        state = dict(state)
        state.update(pos=state["pos"].at[slots].set(poss),
                     tok=state["tok"].at[slots].set(toks),
                     active=state["active"].at[slots].set(True),
                     temp=state["temp"].at[slots].set(temps),
                     top_k=state["top_k"].at[slots].set(tks),
                     top_p=state["top_p"].at[slots].set(tps),
                     key=state["key"].at[slots].set(keys))
        return state

    def _insert_impl(self, cache_all, state, caches, slots, toks, poss, samp):
        """Admit len(caches) B=1 caches into `slots` in one call."""
        per, rem = cache_all["period"], cache_all["rem"]
        for j in range(len(caches)):
            s = slots[j]
            per = jax.tree.map(lambda a, o, s=s: a.at[:, s].set(o[:, 0]),
                               per, caches[j]["period"])
            rem = jax.tree.map(lambda a, o, s=s: a.at[s].set(o[0]),
                               rem, caches[j]["rem"])
        state = self._slot_state(state, slots, toks, poss, samp)
        return {"period": per, "rem": rem, "pos": cache_all["pos"]}, state

    def _insert_paged_impl(self, cache_all, state, caches, slots, toks, poss,
                           samp, tbls, shns):
        """Paged admission: scatter each B=1 dense cache into arena blocks
        through its table row (tbls [n, max_blocks]); the first shns[j]
        entries are prefix blocks mapped from a lender and must not be
        written (redirected to the null block). Non-attention layer state
        stays per-slot."""
        per_cls, rem_cls = self._attn_classes()
        per = list(cache_all["period"])
        rem = list(cache_all["rem"])
        nb_iota = jnp.arange(self.max_blocks)
        for j in range(len(caches)):
            s = slots[j]
            wtbl = jnp.where(nb_iota < shns[j], 0, tbls[j])
            for i, (spec, win) in enumerate(per_cls):
                one = caches[j]["period"][i]
                if spec.kind == "attn":
                    per[i] = self._insert_attn_paged(win, per[i], one, s,
                                                     wtbl, stacked=True)
                else:
                    per[i] = jax.tree.map(
                        lambda a, o, s=s: a.at[:, s].set(o[:, 0]),
                        per[i], one)
            for i, (spec, win) in enumerate(rem_cls):
                one = caches[j]["rem"][i]
                if spec.kind == "attn":
                    rem[i] = self._insert_attn_paged(win, rem[i], one, s,
                                                     wtbl, stacked=False)
                else:
                    rem[i] = jax.tree.map(
                        lambda a, o, s=s: a.at[s].set(o[0]), rem[i], one)
        state = self._slot_state(state, slots, toks, poss, samp)
        return {"period": tuple(per), "rem": tuple(rem),
                "pos": cache_all["pos"]}, state

    def _insert_handle_impl(self, cache_all, state, privs, slots, toks, poss,
                            samp):
        """Zero-copy (block-handoff) admission: the full-attention KV is
        ALREADY in the arena blocks named by each request's table — only
        the bounded private leaves (ring KV scattered into the slot's
        static ring run, mamba state, scalars) are written. The dense
        scatter of `_insert_paged_impl` survives as the compat path."""
        per_cls, rem_cls = self._attn_classes()
        per = list(cache_all["period"])
        rem = list(cache_all["rem"])
        for j in range(len(privs)):
            s = slots[j]
            for i, (spec, win) in enumerate(per_cls):
                one = privs[j]["period"][i]
                if one is None:
                    continue                    # full-attn: lives in arena
                if spec.kind == "attn":
                    per[i] = self._insert_attn_paged(win, per[i], one, s,
                                                     None, stacked=True)
                else:
                    per[i] = jax.tree.map(
                        lambda a, o, s=s: a.at[:, s].set(o[:, 0]),
                        per[i], one)
            for i, (spec, win) in enumerate(rem_cls):
                one = privs[j]["rem"][i]
                if one is None:
                    continue
                if spec.kind == "attn":
                    rem[i] = self._insert_attn_paged(win, rem[i], one, s,
                                                     None, stacked=False)
                else:
                    rem[i] = jax.tree.map(
                        lambda a, o, s=s: a.at[s].set(o[0]), rem[i], one)
        state = self._slot_state(state, slots, toks, poss, samp)
        return {"period": tuple(per), "rem": tuple(rem),
                "pos": cache_all["pos"]}, state

    def _step_impl(self, params, cache, state, tables, block_tbl):
        new_cache, logits, aux = self.lm.decode(
            params, cache, state["tok"][:, None], state["pos"][:, None],
            tables=tables, token_mask=state["active"], block_tables=block_tbl)
        # fused per-slot sampling: the token following pos sees pos+1 context
        # tokens — folding that into the slot's base key makes the draw a
        # pure function of (seed, position), so preempt/resume and paged vs
        # dense layouts reproduce the same stream. Greedy slots (temp <= 0)
        # reduce to the old argmax bit-exactly.
        nxt = sample_tokens(logits, state["temp"], state["top_k"],
                            state["top_p"], state["key"], state["pos"] + 1)
        act = state["active"]
        new_state = dict(state)
        new_state.update(pos=state["pos"] + act.astype(jnp.int32),
                         tok=jnp.where(act, nxt, state["tok"]))
        if "moe_counts" in state:
            cnts = ([c.reshape(-1, c.shape[-1]) for c in aux["period_counts"]]
                    + [c[None] for c in aux["rem_counts"]])
            new_state["moe_counts"] = (state["moe_counts"] +
                                       jnp.concatenate(cnts, axis=0))
        if "sparsity" in state:
            # per-layer [4] vectors (period entries scan-stacked [n_rep, 4])
            vecs = [a.sum(0) for a in aux.get("period_sparsity", ())] \
                + list(aux.get("rem_sparsity", ()))
            if vecs:
                new_state["sparsity"] = state["sparsity"] + sum(vecs)
        return new_cache, new_state, nxt

    def _extract_impl(self, cache_all, slot):
        """Pull one slot back out as a B=1 cache (preemption path)."""
        per = jax.tree.map(
            lambda a: jax.lax.dynamic_slice_in_dim(a, slot, 1, axis=1),
            cache_all["period"])
        rem = jax.tree.map(
            lambda a: jax.lax.dynamic_slice_in_dim(a, slot, 1, axis=0),
            cache_all["rem"])
        return {"period": per, "rem": rem, "pos": cache_all["pos"]}

    def _extract_paged_impl(self, cache_all, slot, tbl):
        """Pull one slot's KV out of the arenas as a dense B=1 cache
        (preemption / re-admission interchange format)."""
        per_cls, rem_cls = self._attn_classes()
        per, rem = [], []
        for i, (spec, win) in enumerate(per_cls):
            e = cache_all["period"][i]
            if spec.kind == "attn":
                per.append(self._extract_attn_paged(win, e, slot, tbl,
                                                    stacked=True))
            else:
                per.append(jax.tree.map(
                    lambda a: jax.lax.dynamic_slice_in_dim(a, slot, 1, axis=1),
                    e))
        for i, (spec, win) in enumerate(rem_cls):
            e = cache_all["rem"][i]
            if spec.kind == "attn":
                rem.append(self._extract_attn_paged(win, e, slot, tbl,
                                                    stacked=False))
            else:
                rem.append(jax.tree.map(
                    lambda a: jax.lax.dynamic_slice_in_dim(a, slot, 1, axis=0),
                    e))
        return {"period": tuple(per), "rem": tuple(rem),
                "pos": cache_all["pos"]}

    # ------------------------------------------------------------------
    def _refresh_tables(self):
        """Device block-table refresh, with the resident-block count fed to
        the step jit pow2-BUCKETED (lo=8 floor, the prefill chunk-bucket
        convention): the jit traces once per bucket instead of once per
        block-boundary crossing as contexts grow, and short-context steps
        hand the kernels a narrow table — the paged_decode grid (and its
        per-block DMAs) scales with the bucket, not max_len. Every live
        slot's resident blocks fit the bucket by construction; stale rows
        of freed slots are clamped to the null block by the write guard."""
        cur = 1
        for slot in self.slot_rid:
            cur = max(cur, self.pool.blocks_for(int(self.tokens_h[slot])))
        nb = min(_bucket(cur, lo=8), self.max_blocks)
        if self._tbl_dirty or nb != self._tbl_bucket:
            self._tbl_dev = jnp.asarray(self.tables_h[:, :nb])
            self._tbl_bucket = nb
            self._tbl_dirty = False

    def take_sparsity_stats(self):
        """Fetch + reset the device-side online-sparsity window and fold it
        into stats (blocks_scored / blocks_attended / attn_mass_*, layer-
        averaged — see serving/sparsity.py). → the layer-averaged [4] np
        vector, or None when online sparsity is off. The only host sync for
        these counters — call at monitor ticks / run end, not per step."""
        acc = self.state.get("sparsity")
        if acc is None:
            return None
        v = np.asarray(acc, np.float64)
        self.state["sparsity"] = jnp.zeros_like(acc)
        self.sparsity.note(self.stats, v)
        L = max(self.sparsity.plan.n_sparse_layers, 1)
        return v / L

    def has_capacity(self) -> bool:
        return len(self.free) > 0

    def _find_shared(self, prompt, cached: int) -> list[int]:
        """Physical prefix blocks to map for an admission whose first
        `cached` tokens are radix-cached: a live request whose prompt shares
        that prefix lends its FULL prefix blocks (floor — the partial tail
        block is always privately copied by the borrower). Returns [] when
        no lender is resident (the credit is then not taken: PR 1 credited
        blocks that were not physically anywhere)."""
        shn = self.pool.shareable_blocks(cached)
        if shn <= 0 or prompt is None:
            return []
        prompt = tuple(prompt)
        for rid, ptoks in self._prompts.items():
            if (ptoks is not None and len(ptoks) >= cached
                    and tuple(ptoks[:cached]) == prompt[:cached]):
                blocks = self.pool.owned(rid)
                if len(blocks) >= shn:
                    return blocks[:shn]
        return []

    def _admit_handle(self, rid: int, hb: BlockHandoff, pos: int) -> bool:
        """Zero-copy admission: rename the handoff's pool ownership to the
        decode rid, extend capacity for the next token, and point the
        slot's table row at the (already written) blocks. Fails clean —
        ownership is handed back so the server can requeue the handle."""
        self.pool.transfer(hb.key, rid)
        grown = self.pool.extend(rid, pos, pos + 1)
        if grown is None:
            self.arena.reclaim(1)
            grown = self.pool.extend(rid, pos, pos + 1)
        if grown is None:
            self.pool.transfer(rid, hb.key)
            return False
        self.stats["blocks_fresh"] += len(grown)
        return True

    def admit_batch(self, items: list[tuple]) -> dict[int, bool]:
        """items: (rid, cache_one, next_token, pos, cached_tokens[, prompt
        [, sampling_params]]). `cache_one` is either a B=1 dense cache (the
        scatter compat path, also used for preemption re-admission) or a
        `BlockHandoff` (paged prefill: ownership of the already-written
        arena blocks transfers to the decode rid — zero KV copy). Inserts
        every admissible item in ONE donated jit call per kind;
        → {rid: admitted}. With paged KV and a dense cache, `prompt`
        enables prefix-sharing admission: full blocks of the cached prefix
        are mapped from a live lender instead of copied. `sampling_params`
        (SamplingParams, None → greedy) lands in the slot's device-side
        parameter tensors."""
        out: dict[int, bool] = {}
        batch, hbatch = [], []
        for item in items:
            rid, cache_one, tok, pos, cached = item[:5]
            prompt = item[5] if len(item) > 5 else None
            sparams = item[6] if len(item) > 6 else None
            handoff = isinstance(cache_one, BlockHandoff)
            if not self.free:
                out[rid] = False
                continue
            if handoff:
                if not self.paged:
                    raise ValueError("BlockHandoff admission needs paged KV")
                if not self._admit_handle(rid, cache_one, pos):
                    out[rid] = False
                    continue
                slot = self.free.pop()
                tbl = self.pool.owned(rid)
                row = np.zeros(self.max_blocks, np.int32)
                row[:len(tbl)] = tbl
                self.tables_h[slot] = row
                shn = 0
            elif self.paged:
                shared = self._find_shared(prompt, cached)
                tbl = self.pool.allocate(rid, pos + 1, shared=shared)
                if tbl is None:
                    self.arena.reclaim(self.pool.blocks_for(pos + 1)
                                       - len(shared))
                    tbl = self.pool.allocate(rid, pos + 1, shared=shared)
                if tbl is None:
                    out[rid] = False
                    continue
                self.stats["blocks_shared"] += len(shared)
                self.stats["blocks_fresh"] += len(tbl) - len(shared)
                slot = self.free.pop()
                row = np.zeros(self.max_blocks, np.int32)
                row[:len(tbl)] = tbl
                self.tables_h[slot] = row
                shn = len(shared)
            else:
                if self.pool.allocate(rid, pos + 1,
                                      cached_tokens=cached) is None:
                    out[rid] = False
                    continue
                slot = self.free.pop()
                row, shn = None, 0
            self.slot_rid[slot] = rid
            self.rid_slot[rid] = slot
            self._prompts[rid] = tuple(prompt) if prompt is not None else None
            self.pos_h[slot] = pos
            self.tok_h[slot] = tok
            self.tokens_h[slot] = pos + 1
            # transfer-cost model: TRUE payload bytes (resident tokens, not
            # the max_len allocation) next to the padded figure the old
            # meter charged; handoff_copy_bytes is the full-attention KV
            # physically copied at admission — 0 on the zero-copy path, the
            # whole max_len scatter on the dense compat path
            self.stats["kv_transfer_bytes"] += self._true_kv_nbytes(pos)
            self.stats["kv_transfer_bytes_padded"] += self._dense_kv_nbytes
            if not handoff:
                self.stats["handoff_copy_bytes"] += \
                    self._full_tok_nbytes * self.max_len
            self.stats["admits"] += 1
            rec = (slot, cache_one.private if handoff else cache_one, tok,
                   pos, row, shn, device_row(sparams, rid))
            (hbatch if handoff else batch).append(rec)
            out[rid] = True

        # pad to a pow2 batch by repeating the last insert (idempotent:
        # same slot, same values) — bounds jit retraces to log2(n_slots)
        def _prep(b):
            while len(b) & (len(b) - 1):
                b.append(b[-1])
            slots = jnp.asarray([x[0] for x in b], jnp.int32)
            toks = jnp.asarray([x[2] for x in b], jnp.int32)
            poss = jnp.asarray([x[3] for x in b], jnp.int32)
            caches = tuple(x[1] for x in b)
            samp = (jnp.asarray([x[6][0] for x in b], jnp.float32),
                    jnp.asarray([x[6][1] for x in b], jnp.int32),
                    jnp.asarray([x[6][2] for x in b], jnp.float32),
                    jnp.asarray(np.stack([x[6][3] for x in b])))
            return slots, toks, poss, caches, samp

        if batch:
            slots, toks, poss, caches, samp = _prep(batch)
            if self.paged:
                tbls = jnp.asarray(np.stack([b[4] for b in batch]), jnp.int32)
                shns = jnp.asarray([b[5] for b in batch], jnp.int32)
                cache, self.state = self._insert(
                    self._full_cache(), self.state, caches, slots, toks,
                    poss, samp, tbls, shns)
                self._store_cache(cache)
            else:
                self.cache, self.state = self._insert(
                    self.cache, self.state, caches, slots, toks, poss, samp)
        if hbatch:
            slots, toks, poss, privs, samp = _prep(hbatch)
            cache, self.state = self._insert_handle(
                self._full_cache(), self.state, privs, slots, toks, poss,
                samp)
            self._store_cache(cache)
        if self.paged and (batch or hbatch):
            self._tbl_dirty = True       # next step() re-buckets + uploads
        return out

    def admit(self, rid: int, cache_one, first_token: int, prompt_len: int,
              cached_tokens: int = 0, prompt: Optional[tuple] = None,
              params: Optional[SamplingParams] = None) -> bool:
        return self.admit_batch([(rid, cache_one, first_token, prompt_len,
                                  cached_tokens, prompt, params)])[rid]

    # ------------------------------------------------------------------
    def step(self) -> dict[int, int]:
        """One batched decode step → {rid: next_token} for active slots.
        Requests whose block allocation cannot grow are preempted into
        self.preempted (cache extracted for later re-admission)."""
        if not self.slot_rid:
            return {}
        t0 = time.monotonic()
        if self.paged:
            self._refresh_tables()
        cache, self.state, nxt = self._step(
            self.params, self._full_cache(), self.state, self.tables,
            self._tbl_dev if self.paged else None)
        self._store_cache(cache)
        next_np = np.asarray(nxt)          # the single per-step host fetch
        self.stats["host_fetches"] += 1
        out = {}
        for slot, rid in list(self.slot_rid.items()):
            tok = int(next_np[slot])
            out[rid] = tok
            self.pos_h[slot] += 1
            self.tok_h[slot] = tok
            # work-based read metric: full-attention blocks gathered for this
            # slot this step (the dense layout always touches max_blocks)
            self.stats["blocks_touched"] += (
                self.pool.blocks_for(int(self.tokens_h[slot]))
                if self.paged else self.max_blocks)
            # capacity is capped at max_len: a request decoding past it keeps
            # emitting (its writes are dropped — null block for paged, OOB
            # scatter drop for dense) but never grows its allocation —
            # growing would index past the table row
            cur = int(self.tokens_h[slot])
            new_tokens = min(cur + 1, self.max_len)
            nb_used = self.pool.blocks_for(cur)
            grown = self.pool.extend(rid, cur, new_tokens)
            if grown is None and self.paged:
                # before preempting, reclaim shared cache state (LRU prefix
                # store entries) — evicting a snapshot is always cheaper
                # than extracting and re-prefilling a live request
                if self.arena.reclaim(1):
                    grown = self.pool.extend(rid, cur, new_tokens)
            if grown is None:
                # the sampled token is already in `out` (delivered once); the
                # preemption record carries it as the resume input so it is
                # neither dropped nor replayed on re-admission
                self.stats["preemptions"] += 1
                self.preempted.append(self._preempt(rid))
                continue
            if grown and self.paged:
                for b in grown:
                    self.tables_h[slot, nb_used] = b
                    nb_used += 1
                self._tbl_dirty = True
                self.stats["blocks_fresh"] += len(grown)
            self.tokens_h[slot] = new_tokens
        dt = time.monotonic() - t0
        self.stats["steps"] += 1
        self.stats["tokens"] += len(out)
        self.stats["busy_s"] += dt
        return out

    def take_moe_counts(self):
        """Fetch + reset the device-side expert activation window ([L_moe, E]
        np array, or None for non-MoE models). The only host sync for counts
        — call it at monitor ticks, not per step."""
        c = self.state.get("moe_counts")
        if c is None:
            return None
        out = np.asarray(c, np.float64)
        self.state["moe_counts"] = jnp.zeros_like(c)
        self.stats["moe_counts"] = out          # last fetched window (stats)
        return out

    def _preempt(self, rid: int) -> tuple:
        slot = self.rid_slot[rid]
        if self.paged:
            cache_one = self._extract(self._full_cache(), jnp.int32(slot),
                                      jnp.asarray(self.tables_h[slot]))
        else:
            cache_one = self._extract(self.cache, jnp.int32(slot))
        rec = (rid, cache_one, int(self.tok_h[slot]), int(self.pos_h[slot]))
        self._free_slot(rid, slot)
        return rec

    def _free_slot(self, rid: int, slot: int):
        del self.slot_rid[slot]
        del self.rid_slot[rid]
        self._prompts.pop(rid, None)
        self.state["active"] = self.state["active"].at[slot].set(False)
        # a stale temp > 0 on a freed slot would permanently defeat the
        # all-greedy fast path in sample_tokens (jnp.all over every slot)
        self.state["temp"] = self.state["temp"].at[slot].set(0.0)
        self.free.append(slot)
        self.pool.release(rid)
        if self.paged:
            # the freed slot keeps decoding garbage until reused: its writes
            # must land in the null block, not in blocks the pool may hand to
            # another request
            self.tables_h[slot] = 0
            self._tbl_dirty = True

    def release(self, rid: int):
        slot = self.rid_slot.get(rid)
        if slot is not None:
            self._free_slot(rid, slot)
