"""SpecPlane: model-free speculative decoding — drafting side + controller.

Every decode step on the paged plane emits exactly one token per slot; this
module supplies the DRAFTS that let the batched verify jit
(``serving/decode.py::DecodeEngine._verify_impl``) emit several. Drafting is
draft-model-free (prompt-lookup speculation): candidate continuations come
from token statistics the serving system already holds —

  1. ``PromptLookupSource`` — each request's OWN history (prompt + emitted
     tokens), via per-request n-gram maps: the most recent PREVIOUS
     occurrence of the current tail n-gram proposes the tokens that
     followed it. This is the workhorse on repetitive/structured output
     (code, JSON, extraction, self-quoting chat).
  2. ``RadixDraftSource`` — the proxy's ``RadixTree`` of served prompts:
     when the live history is a strict prefix of a longer stored prompt
     (multi-turn prefix growth), the tree's stored continuation is the
     draft. Read-only: drafting never perturbs the tree's LRU order.
  3. ``SuffixTableSource`` — a global LRU n-gram → continuation table fed
     by FINISHED requests, giving cross-request speculation on shared
     phrasing.

Correctness never depends on draft quality: the verify jit accepts exactly
the longest prefix matching its own greedy argmax and re-derives every
emitted token from its own logits, so the emitted stream is bit-identical
to non-speculative greedy decode under ANY draft source (including an
adversarial one) — bad drafts only waste verify FLOPs. The controller
therefore restricts WHERE speculation runs, not what it may propose:

  - greedy slots only (temperature > 0 folds a sampler draw per position;
    the verify jit masks drafts for sampled slots in-trace, the controller
    just skips the wasted drafting work);
  - refuses stacks with SSM layers (no multi-token rollback path for
    recurrent state) and engines running OmniAttn online top-k selection
    (block selection is query-dependent, so verify-position selections
    would diverge from the baseline's per-step selections and break the
    bit-identity contract);
  - caps the draft length so the verify window fits the smallest ring
    (k + 1 ≤ min recent — the same bound chunked prefill obeys, and what
    keeps in-window ring slots distinct for the commit scatter).
"""
from __future__ import annotations

from collections import OrderedDict
from dataclasses import dataclass
from typing import Optional


@dataclass(frozen=True)
class SpecConfig:
    """Speculation knobs (ServerConfig.spec / DecodeEngine.spec)."""
    k: int = 4                  # max draft tokens per slot per verify step
    ngram: int = 3              # tail n-gram length for lookup matching
    suffix_entries: int = 512   # global suffix-table LRU capacity (entries)
    suffix_len: int = 8         # continuation tokens stored per suffix entry
    use_radix: bool = True      # consult the proxy RadixTree
    use_suffix: bool = True     # maintain the cross-request suffix table


# ======================================================================
class DraftSource:
    """One way of proposing continuations. All hooks are host-side and
    per-engine-thread; `draft` must be deterministic given the same call
    history (the bench's exact-vs-spec runs rely on reproducible drafting
    even though correctness does not)."""

    name = "base"

    def on_admit(self, rid, history: list) -> None:
        """`rid` entered a decode slot with `history` (prompt + first
        sampled token; a preemption resume sees prompt + resume token)."""

    def on_tokens(self, rid, history: list, n_new: int) -> None:
        """`history` grew by its last `n_new` entries (accepted tokens)."""

    def on_release(self, rid, history: list) -> None:
        """`rid` left its slot (finish / preempt / fault recovery)."""

    def draft(self, rid, history: list, k: int) -> list:
        return []


class PromptLookupSource(DraftSource):
    """Per-request prompt-lookup n-gram maps (two-level: current + previous
    occurrence). Registering token i stores, for every gram length 1..n,
    gram(...,i) → (i+1, previous start): the continuation start of the most
    recent occurrence, with one level of lookback so the just-registered
    tail gram (whose continuation is the unknown future) still exposes its
    previous occurrence. Drafting tries the longest gram first."""

    name = "prompt_lookup"

    def __init__(self, ngram: int):
        self.ngram = max(ngram, 1)
        self.maps: dict = {}            # rid → {gram tuple: (last, prev)}

    def _register(self, m: dict, h: list, i: int) -> None:
        for n in range(1, self.ngram + 1):
            if i + 1 < n:
                break
            g = tuple(h[i + 1 - n:i + 1])
            old = m.get(g)
            m[g] = (i + 1, old[0] if old is not None else None)

    def on_admit(self, rid, history):
        m = self.maps[rid] = {}
        for i in range(len(history)):
            self._register(m, history, i)

    def on_tokens(self, rid, history, n_new):
        m = self.maps.get(rid)
        if m is None:
            return
        for i in range(len(history) - n_new, len(history)):
            self._register(m, history, i)

    def on_release(self, rid, history):
        self.maps.pop(rid, None)

    def draft(self, rid, h, k):
        m = self.maps.get(rid)
        if not m:
            return []
        M = len(h)
        work = list(h)
        out: list = []
        # extend one token at a time THROUGH the map (longest gram first)
        # instead of copying a single history window: near the history tail
        # a window draft clips at the boundary, but on cyclic/repetitive
        # output each drafted token's own tail gram is back in the map, so
        # the walk keeps proposing right up to the k cap
        while len(out) < k:
            nxt = None
            for n in range(self.ngram, 0, -1):
                if len(work) < n:
                    continue
                ent = m.get(tuple(work[-n:]))
                if ent is None:
                    continue
                # a gram ending at the history tail was registered with
                # start M (its continuation is the unknown future) — use
                # its PREVIOUS occurrence instead
                p = ent[1] if ent[0] >= M else ent[0]
                if p is not None and p < M:
                    nxt = h[p]
                    break
            if nxt is None:
                break
            out.append(nxt)
            work.append(nxt)
        return out


class RadixDraftSource(DraftSource):
    """Prompt-lookup against the proxy's RadixTree of served prompts —
    read-only (`RadixTree.continuation` touches no LRU state, so spec
    on/off cannot change which prefixes stay cached)."""

    name = "radix"

    def __init__(self, tree):
        self.tree = tree

    def draft(self, rid, h, k):
        return list(self.tree.continuation(h, k))


class SuffixTableSource(DraftSource):
    """Global LRU n-gram → continuation table fed by finished requests.
    Capacity is an ENTRY count; insertion and lookup both refresh LRU
    order, eviction pops the stalest entry."""

    name = "suffix"

    def __init__(self, ngram: int, max_entries: int, cont_len: int):
        self.ngram = max(ngram, 1)
        self.max_entries = max_entries
        self.cont_len = max(cont_len, 1)
        self.table: OrderedDict = OrderedDict()

    def on_release(self, rid, h):
        n = self.ngram
        for i in range(n - 1, len(h) - 1):
            g = tuple(h[i + 1 - n:i + 1])
            self.table[g] = tuple(h[i + 1:i + 1 + self.cont_len])
            self.table.move_to_end(g)
        while len(self.table) > self.max_entries:
            self.table.popitem(last=False)

    def draft(self, rid, h, k):
        if len(h) < self.ngram:
            return []
        g = tuple(h[-self.ngram:])
        hit = self.table.get(g)
        if not hit:
            return []
        self.table.move_to_end(g)
        return list(hit[:k])


# ======================================================================
class SpecController:
    """Per-engine owner of drafting state, speculation policy, and the
    spec stats contract (the [4] device accumulator drained by
    ``DecodeEngine.take_spec_stats``)."""

    def __init__(self, cfg: SpecConfig, k: int, sources: list):
        self.cfg = cfg
        self.k = k                      # effective draft cap (ring-bounded)
        self.sources = sources
        self.hist: dict = {}            # rid → [int] prompt + emitted tokens

    # ---- construction -------------------------------------------------
    @staticmethod
    def from_model(lm, cfg: Optional[SpecConfig], *, sparsity=None,
                   radix=None) -> Optional["SpecController"]:
        """→ a controller when `cfg` enables speculation (k > 0), else
        None. Raises when the engine cannot honor the bit-identity
        contract: SSM layers (no multi-token rollback for recurrent state)
        or an active OmniAttn top-k SparsityController (query-dependent
        block selection diverges across verify positions). The draft cap is
        clamped to the smallest ring window (k + 1 ≤ recent) and silently
        degrades to OFF when even one draft cannot fit."""
        if cfg is None or cfg.k <= 0:
            return None
        if sparsity is not None:
            raise ValueError(
                "speculative decoding cannot compose with OmniAttn online "
                "top-k selection: block selection is query-dependent, so "
                "verify-window selections would diverge from the baseline's "
                "per-step selections and break greedy bit-identity")
        if any(s.kind != "attn" for s in lm.plan.all_specs()):
            raise ValueError(
                "speculative decoding requires an attention-only stack: "
                "SSM layers have no multi-token rollback path")
        supported, limit = lm.chunked_prefill_support
        if not supported:
            raise ValueError("stack does not support multi-position verify")
        k = min(cfg.k, max(limit - 1, 0))
        if k <= 0:
            return None             # no ring can fit a window: spec off
        sources: list = [PromptLookupSource(cfg.ngram)]
        if cfg.use_radix and radix is not None:
            sources.append(RadixDraftSource(radix))
        if cfg.use_suffix:
            sources.append(SuffixTableSource(cfg.ngram, cfg.suffix_entries,
                                             cfg.suffix_len))
        return SpecController(cfg, k, sources)

    # ---- slot lifecycle ----------------------------------------------
    def on_admit(self, rid, prompt, tok) -> None:
        h = [int(t) for t in (prompt or ())]
        if tok is not None:
            h.append(int(tok))
        self.hist[rid] = h
        for s in self.sources:
            s.on_admit(rid, h)

    def on_tokens(self, rid, toks) -> None:
        h = self.hist.get(rid)
        if h is None:
            return
        h.extend(int(t) for t in toks)
        for s in self.sources:
            s.on_tokens(rid, h, len(toks))

    def on_release(self, rid) -> None:
        h = self.hist.pop(rid, None)
        if h is None:
            return
        for s in self.sources:
            s.on_release(rid, h)

    # ---- drafting -----------------------------------------------------
    def draft(self, rid) -> list:
        """Up to `self.k` candidate continuations for `rid`, from the first
        source with an opinion (own-history lookup, then radix, then the
        cross-request suffix table). [] → this slot rides the window as a
        plain single-token row."""
        h = self.hist.get(rid)
        if not h:
            return []
        for s in self.sources:
            d = s.draft(rid, h, self.k)
            if d:
                return [int(t) for t in d[:self.k]]
        return []

    # ---- stats contract ----------------------------------------------
    @staticmethod
    def stats_keys() -> dict:
        """Engine-stats schema (benches reset these between warmup and
        measurement). Device-side [4] accumulator order:
        [drafted, accepted, emitted, verify steps]."""
        return {"spec_drafted": 0, "spec_accepted": 0,
                "spec_emitted": 0, "spec_verifies": 0}

    @staticmethod
    def note(stats: dict, vec) -> None:
        stats["spec_drafted"] += int(round(float(vec[0])))
        stats["spec_accepted"] += int(round(float(vec[1])))
        stats["spec_emitted"] += int(round(float(vec[2])))
        stats["spec_verifies"] += int(round(float(vec[3])))

    @staticmethod
    def draft_acceptance(stats: dict) -> float:
        """Fraction of drafted tokens the verify accepted (NaN: no drafts)."""
        d = stats.get("spec_drafted", 0)
        return stats.get("spec_accepted", 0) / d if d else float("nan")

    @staticmethod
    def tokens_per_verify(stats: dict) -> float:
        """Mean tokens emitted per verify step (NaN: no verifies)."""
        n = stats.get("spec_verifies", 0)
        return stats.get("spec_emitted", 0) / n if n else float("nan")
