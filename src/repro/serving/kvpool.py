"""Physical paged KV allocator + radix-backed prefix KV store.

`KVPool` hands out real block ids for the decode engine's per-layer KV
arenas (vLLM-style PagedAttention). Block id 0 is reserved as the null /
scratch block — table entries past a request's resident count point at it,
and writes from freed slots are redirected to it — so the pool allocates ids
in [1, n_blocks]. Blocks are refcounted: a prefix-sharing admission maps the
lender's full prefix blocks into the borrower's table (refcount++) instead
of copying, and `release` only frees a block when its last mapper leaves.

Sharing is restricted to FULL blocks of the cached prefix
(`shareable_blocks` = floor(cached / block_size)): a prefix that ends
mid-block leaves a partial tail block that the borrower must own privately
(its content diverges as the borrower appends), so the tail is always
freshly allocated and copied — crediting `ceil` here (the pre-paging
arithmetic) both under-allocated and let a sharer's release free a block
another request still mapped.

The pool also serves accounting-only admission control for the slot-dense
decode path (`cached_tokens` credit without physical sharing).

With paged prefill the pool is SHARED between the prefill and decode
engines (one arena): decode requests map blocks under their integer rid;
prefill tasks under ("prefill", rid); finished-but-unadmitted handoffs
under ("handoff", i); prefix-store snapshots under ("store", handle). Any
hashable key works — `rid` below is a mapping key, not necessarily an int.
"""
from __future__ import annotations

from collections import OrderedDict
from dataclasses import dataclass, field
from typing import List, Optional, Sequence, Tuple

from repro.core.proxy.radix import RadixTree


def _pytree_bytes(tree) -> int:
    """Device bytes of a pytree snapshot (non-array leaves count 0)."""
    if tree is None:
        return 0
    import jax
    return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(tree)
               if hasattr(x, "dtype"))


@dataclass
class StoreEntry:
    """One stored prefix: `n` tokens of KV as either a dense snapshot
    (`cache` holds the full-attention KV too) or — under paged prefill —
    a refcounted arena block list (`blocks`, held in the pool under this
    entry's key) plus the bounded private leaves (ring KV / mamba state) in
    `cache`. `nbytes` is the REAL resident size (prefix-length KV, not a
    max_len allocation) — what byte-capped LRU eviction weighs."""
    n: int
    tokens: tuple
    cache: object
    logits: object
    blocks: Optional[Tuple[int, ...]] = None
    nbytes: int = 0


class PrefixKVStore:
    """Radix-backed prefix → KV-cache store for the prefill engine.

    Entries are prefix-KV snapshots keyed by full stored prompts. `lookup`
    returns the deepest stored prompt that is a prefix of the query, so
    prefill resumes at that boundary (resuming mid-entry is unsound for
    ring caches — the ring beyond the cut holds later tokens). When
    constructed over the proxy's per-instance RadixTree, eq. 8 Match_P
    scoring and the engine agree on what is actually resident.

    Dense entries hold prefix-LENGTH caches (the engine trims the dense
    max_len allocation before storing); paged entries hold refcounted arena
    block lists adopted in the shared KVPool under ("store", handle) —
    dropping an entry (supersede, LRU, byte-cap, reclaim) releases its
    blocks and detaches its radix handle. Eviction is LRU over BOTH an
    entry-count cap and a real-byte cap, so a 16-token prefix no longer
    weighs the same as a 2048-token one.
    """

    _n_stores = 0       # namespace counter: several stores can share one
                        # pool (one per co-located prefill engine), so pool
                        # keys must be unique ACROSS stores, not just within

    def __init__(self, tree: Optional[RadixTree] = None, capacity: int = 32,
                 pool: Optional["KVPool"] = None,
                 capacity_bytes: Optional[int] = None):
        self.tree = tree if tree is not None else RadixTree()
        self.capacity = capacity
        self.capacity_bytes = capacity_bytes
        self.pool = pool
        self.entries: OrderedDict[int, StoreEntry] = OrderedDict()
        self._next_id = 0
        self._ns = PrefixKVStore._n_stores
        PrefixKVStore._n_stores += 1

    def _key(self, handle: int) -> tuple:
        return ("store", self._ns, handle)

    @property
    def size_bytes(self) -> int:
        return sum(e.nbytes for e in self.entries.values())

    def _drop(self, handle: int):
        ent = self.entries.pop(handle, None)
        if ent is None:
            return
        if ent.blocks is not None and self.pool is not None:
            self.pool.release(self._key(handle))
        self.tree.detach(ent.tokens, handle)

    def put(self, tokens, cache, logits, now: Optional[float] = None, *,
            blocks: Optional[Sequence[int]] = None,
            nbytes: Optional[int] = None):
        """Store a prefix snapshot. `blocks` (paged mode): arena block ids
        covering the prefix — adopted in the pool under this entry's key so
        a later release by the writing request cannot free them. `nbytes`:
        real resident bytes (computed from the pytree when omitted — pass
        it for paged entries, whose arena bytes live outside `cache`)."""
        if self.capacity <= 0:
            return
        tokens = tuple(tokens)
        # a payload already attached at exactly this boundary is about to be
        # superseded — drop its entry or the dead snapshot stays resident
        old = None
        for depth, handle in self.tree.payload_prefixes(tokens, now):
            if depth == len(tokens):
                old = handle
        handle = self._next_id
        self._next_id += 1
        if not self.tree.attach(tokens, handle, now):
            return       # tree evicted the path (prompt > tree capacity):
                         # an unreachable entry would only pin memory
        if old is not None:
            self._drop(old)
        if blocks is not None and self.pool is not None:
            self.pool.adopt(self._key(handle), blocks)
        if nbytes is None:
            nbytes = _pytree_bytes(cache) + _pytree_bytes(logits)
        self.entries[handle] = StoreEntry(len(tokens), tokens, cache, logits,
                                          tuple(blocks) if blocks is not None
                                          else None, nbytes)
        self._enforce_caps()

    def _enforce_caps(self):
        while len(self.entries) > self.capacity or (
                self.capacity_bytes is not None
                and self.size_bytes > self.capacity_bytes
                and len(self.entries) > 1):
            self._drop(next(iter(self.entries)))

    def lookup_entry(self, tokens, now: Optional[float] = None
                     ) -> Optional[StoreEntry]:
        """Deepest resident stored prefix of `tokens` (LRU-touched)."""
        for depth, handle in reversed(self.tree.payload_prefixes(tokens, now)):
            hit = self.entries.get(handle)
            if hit is not None and hit.n == depth:
                self.entries.move_to_end(handle)
                return hit
        return None

    def lookup(self, tokens, now: Optional[float] = None):
        """→ (n_matched, cache, logits) for the deepest resident stored
        prefix of `tokens`, or (0, None, None)."""
        hit = self.lookup_entry(tokens, now)
        if hit is None:
            return 0, None, None
        return hit.n, hit.cache, hit.logits

    def clear(self):
        """Drop every entry (benchmarks reset between warmup and the
        measured run; paged entries release their pool blocks)."""
        for handle in list(self.entries):
            self._drop(handle)

    def evict_for_blocks(self, n_blocks: int) -> int:
        """Backpressure reclaim: drop LRU paged entries until `n_blocks`
        pool blocks came free (an entry only frees blocks whose last mapper
        it was) or no paged entries remain. → blocks actually freed."""
        if self.pool is None:
            return 0
        start = self.pool.free_blocks
        for handle in list(self.entries):
            if self.pool.free_blocks - start >= n_blocks:
                break
            if self.entries[handle].blocks is not None:
                self._drop(handle)
        return self.pool.free_blocks - start

    def drop_containing(self, blocks) -> int:
        """Corruption recovery: drop every paged entry whose block list
        intersects `blocks` (a set of condemned arena block ids) — a stored
        prefix built on a quarantined block must never seed a resume.
        → number of entries dropped."""
        bad = set(blocks)
        dropped = 0
        for handle in list(self.entries):
            eb = self.entries[handle].blocks
            if eb is not None and bad & set(eb):
                self._drop(handle)
                dropped += 1
        return dropped


@dataclass
class KVPool:
    n_blocks: int                       # allocatable blocks (ids 1..n_blocks)
    block_size: int = 16
    refcount: dict = field(default_factory=dict)       # block id → mappers
    per_request: dict = field(default_factory=dict)    # rid → [block ids]
    _free: List[int] = field(default_factory=list)
    # blocks pulled from circulation by the corruption scan: never returned
    # to the free list, still counted in the conservation invariant
    quarantined: set = field(default_factory=set)
    # FaultPlane hook: next N real allocations/extensions fail as if the
    # pool were exhausted (callers must take their preempt/defer path)
    inject_alloc_failures: int = 0

    def __post_init__(self):
        self._free = list(range(self.n_blocks, 0, -1))   # pop() → id 1 first

    # ---- arithmetic ---------------------------------------------------
    def blocks_for(self, n_tokens: int) -> int:
        return -(-n_tokens // self.block_size)

    def shareable_blocks(self, cached_tokens: int) -> int:
        """FULL blocks of a cached prefix — the only ones a borrower may map.
        A prefix ending mid-block leaves a partial tail the borrower must
        own privately (floor, not ceil: the pre-paging bug)."""
        return cached_tokens // self.block_size

    @property
    def free_blocks(self) -> int:
        return len(self._free)

    @property
    def utilization(self) -> float:
        return 1.0 - len(self._free) / max(self.n_blocks, 1)

    def owned(self, rid: int) -> List[int]:
        return list(self.per_request.get(rid, ()))

    def __contains__(self, rid: int) -> bool:
        """True while `rid` holds any block mapping — abort-hygiene tests
        assert `rid not in pool` after a cancellation in any phase."""
        return rid in self.per_request

    @property
    def live_rids(self) -> List[int]:
        return list(self.per_request)

    # ---- admission ----------------------------------------------------
    def can_admit(self, n_tokens: int, cached_tokens: int = 0) -> bool:
        need = self.blocks_for(n_tokens) - self.shareable_blocks(cached_tokens)
        return max(need, 0) <= len(self._free)

    def allocate(self, rid: int, n_tokens: int, cached_tokens: int = 0,
                 shared: Optional[Sequence[int]] = None) -> Optional[List[int]]:
        """Admit `rid` with capacity for `n_tokens`. → the request's block
        table (logical order) or None if the pool cannot serve it.

        shared: physical block ids mapped from a lender's resident prefix
        (refcounted, never written by the borrower). Without `shared`,
        `cached_tokens` is an accounting-only credit (slot-dense engines):
        floor(cached/block_size) blocks are assumed resident elsewhere.
        """
        if rid in self.per_request:
            raise ValueError(f"rid {rid} already admitted")
        total = self.blocks_for(n_tokens)
        if shared is not None:
            shared = list(shared[:total])
            for b in shared:
                # a shared block must be mapped by SOMEONE (lender, store
                # entry, or pin) — silently refcounting a free-listed id
                # would let the pool hand the same block out twice
                if b not in self.refcount:
                    raise ValueError(f"sharing unmapped block {b}")
            fresh_n = total - len(shared)
        else:
            shared = []
            fresh_n = total - min(self.shareable_blocks(cached_tokens), total)
        if fresh_n > len(self._free):
            return None
        if fresh_n > 0 and self.inject_alloc_failures > 0:
            self.inject_alloc_failures -= 1
            return None
        fresh = [self._free.pop() for _ in range(fresh_n)]
        table = shared + fresh
        for b in table:
            self.refcount[b] = self.refcount.get(b, 0) + 1
        self.per_request[rid] = table
        return table

    def adopt(self, rid, blocks: Sequence[int]) -> List[int]:
        """Map an EXISTING block list under `rid` (refcount++ each; no
        allocation). Prefix-store snapshots and resume borrowers use this:
        the blocks stay alive until every mapper — writer, store entry,
        borrowers — has released."""
        if rid in self.per_request:
            raise ValueError(f"rid {rid} already admitted")
        table = list(blocks)
        for b in table:
            if b not in self.refcount:
                raise ValueError(f"adopting unmapped block {b}")
            self.refcount[b] += 1
        self.per_request[rid] = table
        return table

    def transfer(self, old_rid, new_rid) -> List[int]:
        """Rename a block mapping (zero refcount churn) — the zero-copy
        admission handoff: a finished prefill's blocks move from the
        handoff handle to the decode rid without touching a single byte."""
        if new_rid in self.per_request:
            raise ValueError(f"rid {new_rid} already admitted")
        if old_rid not in self.per_request:
            raise KeyError(f"rid {old_rid} holds no blocks")
        table = self.per_request.pop(old_rid)
        self.per_request[new_rid] = table
        return table

    def extend(self, rid: int, old_tokens: int, new_tokens: int
               ) -> Optional[List[int]]:
        """Grow `rid`'s allocation from old_tokens → new_tokens. → the newly
        allocated block ids ([] if the tail block still has room) or None if
        the pool is exhausted (caller preempts). New blocks are always
        private: shared prefix blocks are full by construction, so growth
        never lands in a block another request maps."""
        need = self.blocks_for(new_tokens) - self.blocks_for(old_tokens)
        if need <= 0:
            return []
        if need > len(self._free):
            return None
        if self.inject_alloc_failures > 0:
            self.inject_alloc_failures -= 1
            return None
        fresh = [self._free.pop() for _ in range(need)]
        for b in fresh:
            self.refcount[b] = self.refcount.get(b, 0) + 1
        self.per_request.setdefault(rid, []).extend(fresh)
        return fresh

    def shrink(self, rid: int, old_tokens: int, new_tokens: int) -> List[int]:
        """Shrink `rid`'s allocation from old_tokens → new_tokens, returning
        the block ids dropped from its table (tail-first order). The
        speculative-decode partial-accept path: blocks pre-extended to cover
        a draft window hand back the never-written tail when the window is
        cut short. Tail blocks past the prefix are private by construction
        (`extend` only allocates fresh ids), so a shrink back to the
        pre-extension count can never cut into a shared prefix; refcounts
        are still honored (a block another mapper holds is unmapped here
        but stays alive), and quarantined blocks skip the free list exactly
        as in `release`."""
        drop = self.blocks_for(old_tokens) - self.blocks_for(new_tokens)
        if drop <= 0:
            return []
        table = self.per_request.get(rid)
        if table is None:
            raise KeyError(f"rid {rid} holds no blocks")
        if drop > len(table):
            raise ValueError(f"shrink past rid {rid}'s table")
        released = []
        for _ in range(drop):
            b = table.pop()
            released.append(b)
            n = self.refcount.get(b, 0) - 1
            if n <= 0:
                self.refcount.pop(b, None)
                if b not in self.quarantined:
                    self._free.append(b)
            else:
                self.refcount[b] = n
        return released

    def release(self, rid: int):
        """Unmap all of `rid`'s blocks; a block returns to the free list only
        when its last mapper releases (prefix sharers keep it alive).
        Quarantined blocks never rejoin the free list."""
        for b in self.per_request.pop(rid, ()):
            n = self.refcount.get(b, 0) - 1
            if n <= 0:
                self.refcount.pop(b, None)
                if b not in self.quarantined:
                    self._free.append(b)
            else:
                self.refcount[b] = n

    def quarantine(self, b: int):
        """Pull block `b` out of circulation (corruption scan hit). A free
        block leaves the free list immediately; a mapped block stays mapped
        until its last holder releases (the caller is responsible for
        restarting those holders), after which `release` skips the free
        list. Idempotent."""
        if b in self.quarantined:
            return
        self.quarantined.add(b)
        try:
            self._free.remove(b)
        except ValueError:
            pass

    # ---- invariants (property tests) ---------------------------------
    def check_invariants(self, arena=None):
        """No block is both free and mapped; refcounts match mapper counts;
        block population is conserved. With `arena` (the KVArena whose
        blocks this pool hands out) additionally asserts the zero-stale-
        summary invariant: every arena block's stored key summaries equal a
        fresh reduction of its content — admission handoff, preemption/
        resume re-admission, and copy_block tail CoW must all leave the
        block-summary metadata plane coherent."""
        if arena is not None:
            arena.check_summaries()
        free = set(self._free)
        assert len(free) == len(self._free), "duplicate ids in free list"
        assert not (free & set(self.refcount)), "block both free and mapped"
        assert not (free & self.quarantined), "quarantined block in free list"
        assert free | set(self.refcount) | self.quarantined \
            == set(range(1, self.n_blocks + 1)), \
            "block population not conserved"
        counts: dict = {}
        for blocks in self.per_request.values():
            assert len(set(blocks)) == len(blocks), "duplicate block in table"
            for b in blocks:
                counts[b] = counts.get(b, 0) + 1
        assert counts == self.refcount, "refcounts diverge from mappings"
