"""Block-quantized KV accounting (vLLM-style paged allocator, host side).

The jit'd decode step operates on slot-dense caches; this allocator performs
admission control and prefix-reuse accounting in block units so the engine
refuses work that would exceed HBM — the part of PagedAttention that matters
for scheduling fidelity. Prefix-cache hits (via the proxy radix tree) are
credited as already-resident blocks.
"""
from __future__ import annotations

from dataclasses import dataclass, field


@dataclass
class KVPool:
    n_blocks: int
    block_size: int = 16
    free_blocks: int = field(init=False)
    per_request: dict = field(default_factory=dict)

    def __post_init__(self):
        self.free_blocks = self.n_blocks

    def blocks_for(self, n_tokens: int) -> int:
        return -(-n_tokens // self.block_size)

    def can_admit(self, n_tokens: int, cached_tokens: int = 0) -> bool:
        need = self.blocks_for(n_tokens) - self.blocks_for(cached_tokens)
        return need <= self.free_blocks

    def allocate(self, rid: int, n_tokens: int, cached_tokens: int = 0) -> bool:
        need = max(self.blocks_for(n_tokens) - self.blocks_for(cached_tokens), 0)
        if need > self.free_blocks:
            return False
        self.free_blocks -= need
        self.per_request[rid] = self.per_request.get(rid, 0) + need
        return True

    def extend(self, rid: int, old_tokens: int, new_tokens: int) -> bool:
        need = self.blocks_for(new_tokens) - self.blocks_for(old_tokens)
        if need <= 0:
            return True
        if need > self.free_blocks:
            return False
        self.free_blocks -= need
        self.per_request[rid] = self.per_request.get(rid, 0) + need
        return True

    def release(self, rid: int):
        self.free_blocks += self.per_request.pop(rid, 0)

    @property
    def utilization(self) -> float:
        return 1.0 - self.free_blocks / max(self.n_blocks, 1)
