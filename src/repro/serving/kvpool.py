"""Block-quantized KV accounting (vLLM-style paged allocator, host side).

The jit'd decode step operates on slot-dense caches; this allocator performs
admission control and prefix-reuse accounting in block units so the engine
refuses work that would exceed HBM — the part of PagedAttention that matters
for scheduling fidelity. Prefix-cache hits (via the proxy radix tree) are
credited as already-resident blocks.
"""
from __future__ import annotations

from collections import OrderedDict
from dataclasses import dataclass, field
from typing import Optional

from repro.core.proxy.radix import RadixTree


class PrefixKVStore:
    """Radix-backed prefix → KV-cache store for the prefill engine.

    Entries are (cache, logits) snapshots keyed by full stored prompts.
    `lookup` returns the deepest stored prompt that is a prefix of the query,
    so prefill resumes at that boundary (resuming mid-entry is unsound for
    ring caches — the ring beyond the cut holds later tokens). When
    constructed over the proxy's per-instance RadixTree, eq. 8 Match_P
    scoring and the engine agree on what is actually resident.

    LRU-capped on entry count; evicted handles left in the tree are treated
    as stale and skipped at lookup.
    """

    def __init__(self, tree: Optional[RadixTree] = None, capacity: int = 32):
        self.tree = tree if tree is not None else RadixTree()
        self.capacity = capacity
        self.entries: OrderedDict[int, tuple] = OrderedDict()
        self._next_id = 0

    def put(self, tokens, cache, logits, now: Optional[float] = None):
        if self.capacity <= 0:
            return
        handle = self._next_id
        self._next_id += 1
        if not self.tree.attach(tuple(tokens), handle, now):
            return       # tree evicted the path (prompt > tree capacity):
                         # an unreachable entry would only pin memory
        self.entries[handle] = (len(tokens), cache, logits)
        while len(self.entries) > self.capacity:
            self.entries.popitem(last=False)      # stale handle stays in tree

    def lookup(self, tokens, now: Optional[float] = None):
        """→ (n_matched, cache, logits) for the deepest resident stored
        prefix of `tokens`, or (0, None, None)."""
        for depth, handle in reversed(self.tree.payload_prefixes(tokens, now)):
            hit = self.entries.get(handle)
            if hit is not None and hit[0] == depth:
                self.entries.move_to_end(handle)
                return depth, hit[1], hit[2]
        return 0, None, None


@dataclass
class KVPool:
    n_blocks: int
    block_size: int = 16
    free_blocks: int = field(init=False)
    per_request: dict = field(default_factory=dict)

    def __post_init__(self):
        self.free_blocks = self.n_blocks

    def blocks_for(self, n_tokens: int) -> int:
        return -(-n_tokens // self.block_size)

    def can_admit(self, n_tokens: int, cached_tokens: int = 0) -> bool:
        need = self.blocks_for(n_tokens) - self.blocks_for(cached_tokens)
        return need <= self.free_blocks

    def allocate(self, rid: int, n_tokens: int, cached_tokens: int = 0) -> bool:
        need = max(self.blocks_for(n_tokens) - self.blocks_for(cached_tokens), 0)
        if need > self.free_blocks:
            return False
        self.free_blocks -= need
        self.per_request[rid] = self.per_request.get(rid, 0) + need
        return True

    def extend(self, rid: int, old_tokens: int, new_tokens: int) -> bool:
        need = self.blocks_for(new_tokens) - self.blocks_for(old_tokens)
        if need <= 0:
            return True
        if need > self.free_blocks:
            return False
        self.free_blocks -= need
        self.per_request[rid] = self.per_request.get(rid, 0) + need
        return True

    def release(self, rid: int):
        self.free_blocks += self.per_request.pop(rid, 0)

    @property
    def utilization(self) -> float:
        return 1.0 - self.free_blocks / max(self.n_blocks, 1)
