"""Physical paged KV allocator + radix-backed prefix KV store.

`KVPool` hands out real block ids for the decode engine's per-layer KV
arenas (vLLM-style PagedAttention). Block id 0 is reserved as the null /
scratch block — table entries past a request's resident count point at it,
and writes from freed slots are redirected to it — so the pool allocates ids
in [1, n_blocks]. Blocks are refcounted: a prefix-sharing admission maps the
lender's full prefix blocks into the borrower's table (refcount++) instead
of copying, and `release` only frees a block when its last mapper leaves.

Sharing is restricted to FULL blocks of the cached prefix
(`shareable_blocks` = floor(cached / block_size)): a prefix that ends
mid-block leaves a partial tail block that the borrower must own privately
(its content diverges as the borrower appends), so the tail is always
freshly allocated and copied — crediting `ceil` here (the pre-paging
arithmetic) both under-allocated and let a sharer's release free a block
another request still mapped.

The pool also serves accounting-only admission control for the slot-dense
decode path (`cached_tokens` credit without physical sharing).
"""
from __future__ import annotations

from collections import OrderedDict
from dataclasses import dataclass, field
from typing import List, Optional, Sequence

from repro.core.proxy.radix import RadixTree


class PrefixKVStore:
    """Radix-backed prefix → KV-cache store for the prefill engine.

    Entries are (cache, logits) snapshots keyed by full stored prompts.
    `lookup` returns the deepest stored prompt that is a prefix of the query,
    so prefill resumes at that boundary (resuming mid-entry is unsound for
    ring caches — the ring beyond the cut holds later tokens). When
    constructed over the proxy's per-instance RadixTree, eq. 8 Match_P
    scoring and the engine agree on what is actually resident.

    LRU-capped on entry count; evicted handles left in the tree are treated
    as stale and skipped at lookup. Re-storing a prompt supersedes the old
    entry: its handle is dropped immediately (not left pinning dead KV until
    LRU capacity happens to evict it).
    """

    def __init__(self, tree: Optional[RadixTree] = None, capacity: int = 32):
        self.tree = tree if tree is not None else RadixTree()
        self.capacity = capacity
        self.entries: OrderedDict[int, tuple] = OrderedDict()
        self._next_id = 0

    def put(self, tokens, cache, logits, now: Optional[float] = None):
        if self.capacity <= 0:
            return
        tokens = tuple(tokens)
        # a payload already attached at exactly this boundary is about to be
        # superseded — drop its entry or the dead snapshot stays resident
        old = None
        for depth, handle in self.tree.payload_prefixes(tokens, now):
            if depth == len(tokens):
                old = handle
        handle = self._next_id
        self._next_id += 1
        if not self.tree.attach(tokens, handle, now):
            return       # tree evicted the path (prompt > tree capacity):
                         # an unreachable entry would only pin memory
        if old is not None:
            self.entries.pop(old, None)
        self.entries[handle] = (len(tokens), cache, logits)
        while len(self.entries) > self.capacity:
            self.entries.popitem(last=False)      # stale handle stays in tree

    def lookup(self, tokens, now: Optional[float] = None):
        """→ (n_matched, cache, logits) for the deepest resident stored
        prefix of `tokens`, or (0, None, None)."""
        for depth, handle in reversed(self.tree.payload_prefixes(tokens, now)):
            hit = self.entries.get(handle)
            if hit is not None and hit[0] == depth:
                self.entries.move_to_end(handle)
                return depth, hit[1], hit[2]
        return 0, None, None


@dataclass
class KVPool:
    n_blocks: int                       # allocatable blocks (ids 1..n_blocks)
    block_size: int = 16
    refcount: dict = field(default_factory=dict)       # block id → mappers
    per_request: dict = field(default_factory=dict)    # rid → [block ids]
    _free: List[int] = field(default_factory=list)

    def __post_init__(self):
        self._free = list(range(self.n_blocks, 0, -1))   # pop() → id 1 first

    # ---- arithmetic ---------------------------------------------------
    def blocks_for(self, n_tokens: int) -> int:
        return -(-n_tokens // self.block_size)

    def shareable_blocks(self, cached_tokens: int) -> int:
        """FULL blocks of a cached prefix — the only ones a borrower may map.
        A prefix ending mid-block leaves a partial tail the borrower must
        own privately (floor, not ceil: the pre-paging bug)."""
        return cached_tokens // self.block_size

    @property
    def free_blocks(self) -> int:
        return len(self._free)

    @property
    def utilization(self) -> float:
        return 1.0 - len(self._free) / max(self.n_blocks, 1)

    def owned(self, rid: int) -> List[int]:
        return list(self.per_request.get(rid, ()))

    def __contains__(self, rid: int) -> bool:
        """True while `rid` holds any block mapping — abort-hygiene tests
        assert `rid not in pool` after a cancellation in any phase."""
        return rid in self.per_request

    @property
    def live_rids(self) -> List[int]:
        return list(self.per_request)

    # ---- admission ----------------------------------------------------
    def can_admit(self, n_tokens: int, cached_tokens: int = 0) -> bool:
        need = self.blocks_for(n_tokens) - self.shareable_blocks(cached_tokens)
        return max(need, 0) <= len(self._free)

    def allocate(self, rid: int, n_tokens: int, cached_tokens: int = 0,
                 shared: Optional[Sequence[int]] = None) -> Optional[List[int]]:
        """Admit `rid` with capacity for `n_tokens`. → the request's block
        table (logical order) or None if the pool cannot serve it.

        shared: physical block ids mapped from a lender's resident prefix
        (refcounted, never written by the borrower). Without `shared`,
        `cached_tokens` is an accounting-only credit (slot-dense engines):
        floor(cached/block_size) blocks are assumed resident elsewhere.
        """
        if rid in self.per_request:
            raise ValueError(f"rid {rid} already admitted")
        total = self.blocks_for(n_tokens)
        if shared is not None:
            shared = list(shared[:total])
            fresh_n = total - len(shared)
        else:
            shared = []
            fresh_n = total - min(self.shareable_blocks(cached_tokens), total)
        if fresh_n > len(self._free):
            return None
        fresh = [self._free.pop() for _ in range(fresh_n)]
        table = shared + fresh
        for b in table:
            self.refcount[b] = self.refcount.get(b, 0) + 1
        self.per_request[rid] = table
        return table

    def extend(self, rid: int, old_tokens: int, new_tokens: int
               ) -> Optional[List[int]]:
        """Grow `rid`'s allocation from old_tokens → new_tokens. → the newly
        allocated block ids ([] if the tail block still has room) or None if
        the pool is exhausted (caller preempts). New blocks are always
        private: shared prefix blocks are full by construction, so growth
        never lands in a block another request maps."""
        need = self.blocks_for(new_tokens) - self.blocks_for(old_tokens)
        if need <= 0:
            return []
        if need > len(self._free):
            return None
        fresh = [self._free.pop() for _ in range(need)]
        for b in fresh:
            self.refcount[b] = self.refcount.get(b, 0) + 1
        self.per_request.setdefault(rid, []).extend(fresh)
        return fresh

    def release(self, rid: int):
        """Unmap all of `rid`'s blocks; a block returns to the free list only
        when its last mapper releases (prefix sharers keep it alive)."""
        for b in self.per_request.pop(rid, ()):
            n = self.refcount.get(b, 0) - 1
            if n <= 0:
                self.refcount.pop(b, None)
                self._free.append(b)
            else:
                self.refcount[b] = n

    # ---- invariants (property tests) ---------------------------------
    def check_invariants(self):
        """No block is both free and mapped; refcounts match mapper counts;
        block population is conserved."""
        free = set(self._free)
        assert len(free) == len(self._free), "duplicate ids in free list"
        assert not (free & set(self.refcount)), "block both free and mapped"
        assert free | set(self.refcount) == set(range(1, self.n_blocks + 1)), \
            "block population not conserved"
        counts: dict = {}
        for blocks in self.per_request.values():
            assert len(set(blocks)) == len(blocks), "duplicate block in table"
            for b in blocks:
                counts[b] = counts.get(b, 0) + 1
        assert counts == self.refcount, "refcounts diverge from mappings"
