"""Device-side stat accumulators: the shared drain pattern.

Several engine counters (online-sparsity windows, MoE expert activation
counts, speculation windows) accumulate INSIDE the donated step jit — a
jnp array in the slot-state dict that each step adds to — and are fetched
(+ reset) only at monitor ticks or run end. That keeps the decode hot loop
at exactly one device→host fetch per step (`host_fetches == steps`): the
counters ride the donated state and never force their own sync.

`drain_accumulator` is the one implementation of the fetch-and-reset half
of that pattern; `take_sparsity_stats` / `take_moe_counts` /
`take_spec_stats` on the engine are thin wrappers that add their own
folding/interpretation on top.
"""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp
import numpy as np


def drain_accumulator(state: dict, key: str) -> Optional[np.ndarray]:
    """Fetch the device-side accumulator `state[key]` as float64 numpy and
    reset it to zeros in place. Returns None when the accumulator was never
    installed (feature off for this engine). This is a HOST SYNC — call it
    at monitor ticks / run end, never in the per-step loop."""
    acc = state.get(key)
    if acc is None:
        return None
    v = np.asarray(acc, np.float64)
    state[key] = jnp.zeros_like(acc)
    return v
