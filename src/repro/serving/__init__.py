from repro.core.proxy.params import (BackpressureError, RequestOutput,
                                     SamplingParams)
from repro.serving.arena import BlockHandoff, KVArena
from repro.serving.decode import DecodeEngine
from repro.serving.faults import FaultConfig, FaultPlane, FaultSpec
from repro.serving.placement import DevicePlacement
from repro.serving.prefill import PrefillEngine, PrefillResult, PrefillTask
from repro.serving.server import Server, ServerConfig
from repro.serving.sparsity import SparsityController, SparsityPlan
from repro.serving.spec import SpecConfig, SpecController

__all__ = ["BlockHandoff", "DecodeEngine", "DevicePlacement", "KVArena",
           "PrefillEngine", "PrefillResult", "PrefillTask",
           "Server", "ServerConfig", "SamplingParams", "RequestOutput",
           "BackpressureError", "FaultConfig", "FaultPlane", "FaultSpec",
           "SparsityController", "SparsityPlan",
           "SpecConfig", "SpecController"]
