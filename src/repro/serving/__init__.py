from repro.core.proxy.params import RequestOutput, SamplingParams
from repro.serving.engine import (BlockHandoff, DecodeEngine, KVArena,
                                  PrefillEngine)
from repro.serving.server import Server, ServerConfig
from repro.serving.sparsity import SparsityController, SparsityPlan

__all__ = ["BlockHandoff", "DecodeEngine", "KVArena", "PrefillEngine",
           "Server", "ServerConfig", "SamplingParams", "RequestOutput",
           "SparsityController", "SparsityPlan"]
