from repro.core.proxy.params import (BackpressureError, RequestOutput,
                                     SamplingParams)
from repro.serving.engine import (BlockHandoff, DecodeEngine, KVArena,
                                  PrefillEngine)
from repro.serving.faults import FaultConfig, FaultPlane, FaultSpec
from repro.serving.server import Server, ServerConfig
from repro.serving.sparsity import SparsityController, SparsityPlan

__all__ = ["BlockHandoff", "DecodeEngine", "KVArena", "PrefillEngine",
           "Server", "ServerConfig", "SamplingParams", "RequestOutput",
           "BackpressureError", "FaultConfig", "FaultPlane", "FaultSpec",
           "SparsityController", "SparsityPlan"]
