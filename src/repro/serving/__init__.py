from repro.core.proxy.params import RequestOutput, SamplingParams
from repro.serving.engine import DecodeEngine, PrefillEngine
from repro.serving.server import Server, ServerConfig

__all__ = ["DecodeEngine", "PrefillEngine", "Server", "ServerConfig",
           "SamplingParams", "RequestOutput"]
